"""Quickstart: the paper's full pipeline on a small synthetic collection.

Builds the two index mirrors, generates reference-list labels, trains the
Stage-0 quantile-GBRT predictors, and serves a query trace through the
hybrid first stage with a hard latency budget.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax.numpy as jnp

from repro.core import features as F, gbrt
from repro.core.labels import LabelConfig, generate_labels
from repro.index.builder import build_index
from repro.index.corpus import CorpusParams, build_corpus, build_queries
from repro.ltr.ranker import ltr_training_set, train_ltr
from repro.serving.pipeline import CascadePipeline
from repro.serving.scheduler import SchedulerConfig


def main():
    print("1) synthetic collection (8k docs) + query trace")
    corpus = build_corpus(CorpusParams(n_docs=8192, vocab=4096,
                                       avg_doclen=120, zipf_a=1.05))
    index = build_index(corpus, stop_k=16)
    ql = build_queries(corpus, 600, stop_k=16)

    print("2) oracle labels via MED-RBP reference lists")
    labels = generate_labels(index, corpus, ql,
                             LabelConfig(max_k=2048, batch=200,
                                         rho_grid=(256, 1024, 4096, 16384)))
    print(f"   oracle k:   median={np.median(labels.oracle_k):.0f} "
          f"mean={labels.oracle_k.mean():.0f} (heavy-tailed)")
    print(f"   oracle rho: median={np.median(labels.oracle_rho):.0f}")

    print("3) Stage-0 quantile-GBRT predictors (147 features)")
    x = np.asarray(F.extract(jnp.asarray(index.term_stats),
                             jnp.asarray(index.df),
                             jnp.asarray(ql.terms), jnp.asarray(ql.mask)))
    models = {}
    for name, y, tau in (("k", labels.oracle_k, 0.55),
                         ("rho", labels.oracle_rho, 0.45),
                         ("t", labels.t_bmw, 0.5)):
        models[name] = gbrt.fit(x, np.log1p(y.astype(np.float32)),
                                gbrt.GBRTParams(n_trees=32, depth=4,
                                                loss="quantile", tau=tau))

    print("4) Stage-2 LTR model from the reference lists")
    train_rows = np.flatnonzero(labels.keep)[:128]
    lf, lg = ltr_training_set(index, corpus, ql, labels.ref_lists, train_rows)
    ltr = train_ltr(lf, lg, n_trees=32)

    print("5) full-cascade serving under a latency budget")
    budget = float(np.percentile(labels.t_bmw, 90))
    pipe = CascadePipeline(index, models,
                           SchedulerConfig(algorithm=2, budget=budget,
                                           t_time=budget * 0.6,
                                           rho_max=1 << 14,
                                           t_k=float(np.median(
                                               labels.oracle_k))),
                           corpus=corpus, ltr=ltr)
    res = pipe.serve(ql.terms, ql.mask, ql.topic)
    s = res.stats
    print(f"   routed jass={s['jass']} bmw={s['bmw']} hedged={s['hedged']}")
    for name, p in s["stages"].items():
        print(f"   {name} p50={p['p50']:.2f} p99={p['p99']:.2f}")
    print(f"   cascade latency p50={s['p50']:.1f} p99={s['p99']:.1f} "
          f"max={s['max']:.1f} (budget {budget:.1f})")
    print(f"   over budget: {s['over_budget']} queries "
          f"({s['over_budget_pct']:.3f}%)")
    print(f"   vs fixed exhaustive BMW over budget: "
          f"{100 * np.mean(labels.t_bmw > budget):.1f}%")
    print(f"   final top-{res.final.shape[1]} lists from "
          f"{res.candidates_used.mean():.0f} candidates/query")


if __name__ == "__main__":
    main()
