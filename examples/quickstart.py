"""Quickstart: the paper's full pipeline on a small synthetic collection,
through the declarative ``SearchSystem`` API.

One spec describes the deployment (index layout, Stage-0 predictors,
routing thresholds, Stage-2 re-ranker, shards x replicas); ``build_system``
instantiates it, ``fit`` trains it, ``serve`` runs the multi-shard cascade
under a hard latency budget.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import numpy as np

from repro.configs.cascade_presets import get_preset
from repro.core.labels import LabelConfig, generate_labels
from repro.index.corpus import CorpusParams, build_corpus, build_queries
from repro.serving.system import build_system


def main():
    print("1) synthetic collection (8k docs) + query trace")
    corpus = build_corpus(CorpusParams(n_docs=8192, vocab=4096,
                                       avg_doclen=120, zipf_a=1.05))
    spec = get_preset("paper_200ms")
    system = build_system(spec, corpus)
    ql = build_queries(corpus, 600, stop_k=spec.index.stop_k)

    print("2) oracle labels via MED-RBP reference lists")
    labels = generate_labels(system.index, corpus, ql,
                             LabelConfig(max_k=2048, batch=200,
                                         rho_grid=(256, 1024, 4096, 16384)),
                             cost=system.cost)
    print(f"   oracle k:   median={np.median(labels.oracle_k):.0f} "
          f"mean={labels.oracle_k.mean():.0f} (heavy-tailed)")
    print(f"   oracle rho: median={np.median(labels.oracle_rho):.0f}")

    print("3) name the operating point from the data, then fit")
    budget = float(np.percentile(labels.t_bmw, 90))
    spec = dataclasses.replace(
        spec,
        routing=dataclasses.replace(spec.routing, budget=budget,
                                    rho_max=1 << 14),
        deploy=dataclasses.replace(spec.deploy, n_shards=2),
    ).validate()
    # reuse the step-1 index: only the deployment shape changed
    system = build_system(spec, system.index, corpus=corpus)
    system.fit(ql, labels)
    print(f"   spec: {spec.name} @ budget {budget:.1f}, "
          f"{spec.deploy.n_shards} shards x {spec.deploy.replicas} replicas")
    print(f"   round-trips: "
          f"{spec == type(spec).from_json(spec.to_json())}")

    print("4) full-cascade serving under the latency budget")
    res = system.serve(ql.terms, ql.mask, ql.topic)
    s = res.stats
    print(f"   routed jass={s['jass']} bmw={s['bmw']} hedged={s['hedged']}")
    for name, p in s["stages"].items():
        print(f"   {name} p50={p['p50']:.2f} p99={p['p99']:.2f}")
    print(f"   cascade latency p50={s['p50']:.1f} p99={s['p99']:.1f} "
          f"max={s['max']:.1f} (budget {budget:.1f})")
    print(f"   over budget: {s['over_budget']} queries "
          f"({s['over_budget_pct']:.3f}%)")
    print(f"   vs fixed exhaustive BMW over budget: "
          f"{100 * np.mean(labels.t_bmw > budget):.1f}%")
    print(f"   final top-{res.final.shape[1]} lists from "
          f"{res.candidates_used.mean():.0f} candidates/query")

    print("5) deployment health")
    st = system.stats()
    pool = st["pool"]
    print(f"   shards={st['n_shards']} ({st['shard_docs']} docs), "
          f"pool {pool['healthy']}/{pool['replicas']} healthy, "
          f"mirror split jass={pool['jass']}/bmw={pool['bmw']}")

    print("6) online serving: bursty traffic, micro-batching + admission")
    from repro.serving.online import estimate_capacity, fresh_probe
    from repro.serving.spec import TrafficSpec
    # probe capacity on a throwaway clone of the fitted operating point so
    # the warm-up batches don't perturb the measured system
    capacity = estimate_capacity(fresh_probe(system), ql.terms, ql.mask,
                                 ql.topic)
    traffic = TrafficSpec(arrival="bursty", qps=0.8 * capacity, seed=1)
    r = system.serve_online(ql.terms, ql.mask, ql.topic, traffic=traffic)
    s = r.stats
    print(f"   offered 0.8x capacity ({traffic.qps:.0f} qps, "
          f"{s['batches']} micro-batches, "
          f"mean size {s['batch']['mean_size']:.1f})")
    print(f"   response (queueing included): p50={s['response']['p50']:.1f} "
          f"p99.99={s['response']['p99.99']:.1f} "
          f"(budget {s['response_budget']:.0f})")
    print(f"   over budget: {s['over_budget']}, modes {s['modes']}")


if __name__ == "__main__":
    main()
