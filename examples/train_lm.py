"""Train a reduced LM config for a few hundred steps with the production
loop (sharded step, grad accumulation, async checkpoints, crash-resume).

    PYTHONPATH=src python examples/train_lm.py --arch yi_6b --steps 200
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    args = ap.parse_args()

    import jax
    from repro.configs import registry
    from repro.data import synthetic
    from repro.models import transformer as tr
    from repro.train import optimizer, train_loop

    config, _ = registry.get_reduced(args.arch)
    params, _ = tr.init(config, jax.random.PRNGKey(0))
    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={config.name} params={n/1e6:.1f}M")

    def loss_fn(p, batch):
        return tr.loss_fn(p, config, batch["tokens"], batch["labels"])

    gen = synthetic.lm_batches(config.vocab, args.batch, args.seq)
    cfg = train_loop.TrainConfig(
        steps=args.steps, microbatches=args.microbatches, ckpt_every=100,
        ckpt_dir=f"/tmp/repro_ckpt_{config.name}", log_every=20,
        opt=optimizer.AdamWConfig(lr=1e-3, warmup_steps=20,
                                  total_steps=args.steps))
    params, opt, losses = train_loop.run(params, loss_fn, gen, cfg)
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {args.steps} steps")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
