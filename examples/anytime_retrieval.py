"""The paper's technique transplanted to dense retrieval (two-tower arch):
candidates stored in popularity (impact) order, scored under a per-query
anytime budget predicted by Stage-0 — the JASS mechanism for embeddings.

    PYTHONPATH=src python examples/anytime_retrieval.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import recsys


def main():
    c, _ = registry.get_reduced("two_tower_retrieval")
    params, _ = recsys.init(c, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    n_cand = 512

    # candidate embeddings in *popularity order* (the impact-ordered mirror)
    cand = jax.random.normal(jax.random.PRNGKey(1), (n_cand, c.tower_mlp[-1]))
    popularity = np.sort(rng.zipf(1.3, n_cand))[::-1]

    user_ids = jnp.asarray(rng.randint(0, c.n_users, (1, c.n_user_feats)),
                           jnp.int32)
    mask = jnp.ones((1, c.n_user_feats), jnp.float32)
    q = recsys.tower_embed(params, c, "user_table", "user_mlp", user_ids,
                           mask)

    exhaustive_vals, exhaustive_idx = recsys.anytime_retrieval(
        q, cand, jnp.asarray(n_cand), 10)
    print("budget  overlap@10_vs_exhaustive  worst-case-work")
    for budget in (32, 64, 128, 256, 512):
        vals, idx = recsys.anytime_retrieval(q, cand, jnp.asarray(budget), 10)
        ov = len(np.intersect1d(np.asarray(idx),
                                np.asarray(exhaustive_idx))) / 10
        print(f"{budget:6d}  {ov:24.2f}  {budget} dots (deterministic)")
    print("\nthe budget bounds worst-case latency exactly like JASS's rho;"
          "\nStage-0 predicts it per query from request features.")


if __name__ == "__main__":
    main()
