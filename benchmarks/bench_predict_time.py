"""Paper Table 2 — response-time regression + tail-query classification for
QR / RF / LR (RMSE in log space, P/R/F1, macro variants, AUC)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import Experiment, cv_predict
from repro.core.predictors import regression_report


def run(exp: Experiment) -> dict:
    rows = exp.train_rows
    y = exp.labels.t_bmw[rows]
    out = {}
    for method in ("qr", "rf", "lr"):
        pred = cv_predict(exp, method, "t",
                          tau=0.5 if method == "qr" else 0.5)[rows]
        out[method.upper()] = regression_report(y, pred, tail_quantile=0.95)
    return {"report": out}


def render(res) -> str:
    cols = ["rmse", "precision", "recall", "f1", "macro_precision",
            "macro_recall", "macro_f1", "auc"]
    lines = ["system," + ",".join(cols)]
    for name, r in res["report"].items():
        lines.append(name + "," + ",".join(f"{r[c]:.3f}" for c in cols))
    return "\n".join(lines)
