"""Live-ingest certification: serving must stay inside the response-time
guarantee while the collection mutates, and a background merge must land
exactly where a from-scratch rebuild would.

Four studies over one fitted cascade (frozen thresholds, jnp backend):

* **post-merge bit parity** — serve → ingest a feed → merge; the resealed
  index and the post-merge results (top-k, final, modeled latency) must be
  **bit-identical** to a system built from scratch over the extended
  collection with the same spec.
* **worst-case accounting** — attaching a delta raises ``worst_case_us()``
  by exactly the capacity-sized delta-scan term (``CostModel.delta_time``
  at the postings capacity): the live scan is charged into the analytic
  bound, never absorbed silently.
* **inert mode** — ``IngestSpec(enabled=False)`` must be provably absent:
  offline serving bit-identical and the online event log tuple-identical
  to a spec with no ingest node at all.
* **serve-while-ingesting sweep** — offered load x {ingest on, off} with
  the seeded feed landing between queries and merges running on the same
  virtual clock.  Gate: **zero** response-budget violations everywhere,
  with the feed actually applied (non-vacuous).

Emits ``results/BENCH_ingest.json``; the CLI exits non-zero if any gate
fails.  CI runs it as a smoke.  Run standalone with
``PYTHONPATH=src:. python benchmarks/bench_ingest.py``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.bench_online import _build
from benchmarks.common import bench_payload, write_bench_artifact


def _index_identical(a, b) -> bool:
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, np.ndarray) or isinstance(vb, np.ndarray):
            if not np.array_equal(np.asarray(va), np.asarray(vb)):
                return False
        elif va != vb:
            return False
    return True


def _cell(res) -> dict:
    s = res.stats
    out = {
        "served": s["served"], "shed": s["shed"],
        "over_budget": s["over_budget"],
        "modes": s["modes"],
        "p50": s["response"]["p50"] if "response" in s else None,
        "p99.99": s["response"]["p99.99"] if "response" in s else None,
        "achieved_qps": s.get("achieved_qps"),
    }
    if "ingest" in s:
        i = s["ingest"]
        out["ingest"] = {
            "feed_batches_applied": i["feed_batches_applied"],
            "feed_batches_due": i["feed_batches_due"],
            "feed_throttled": i.get("feed_throttled", 0),
            "docs_ingested": i["docs_ingested"],
            "merges": i["merges"],
            "merge_deferred": i.get("merge_deferred", 0),
            "merges_forced": i.get("merges_forced", 0),
            "fill": i["fill"],
        }
    return out


def run_ingest(q_batch: int = 384, n_docs: int = 4096, seed: int = 7,
               loads: tuple = (0.5, 0.8, 0.95),
               feed_docs: int = 128,
               max_batch: int = 16, backend: str = "jnp") -> dict:
    from repro.configs.cascade_presets import get_preset
    from repro.index.builder import build_index
    from repro.index.corpus import (extend_corpus, slice_feed,
                                    synthesize_feed_docs)
    from repro.serving.online import estimate_capacity
    from repro.serving.spec import IngestSpec, TrafficSpec
    from repro.serving.system import build_system

    corpus, base, ql, fit_sys = _build(q_batch, n_docs, seed, backend,
                                       max_batch)
    index, models, ltr = fit_sys.index, fit_sys.models, fit_sys.ltr
    cost = fit_sys.cost
    # the shipped operating point's delta sizing (budget-sized capacities)
    ing = get_preset("live_ingest").ingest

    def system(ingest: IngestSpec | None = None, idx=None, corp=None):
        spec = base if ingest is None else dataclasses.replace(base,
                                                               ingest=ingest)
        return build_system(spec, idx if idx is not None else index,
                            corpus=corp if corp is not None else corpus,
                            models=models, ltr=ltr, cost=cost)

    # ---- post-merge bit parity vs the from-scratch rebuild oracle ----
    on_sys = system(ing)
    feed = synthesize_feed_docs(corpus, feed_docs, seed=seed + 3)
    took = on_sys.add_documents(feed)
    mid = on_sys.serve(ql.terms, ql.mask, ql.topic)
    live_hits = int((np.asarray(mid.topk) >= index.n_docs).sum())
    merged = on_sys.merge()
    after = on_sys.serve(ql.terms, ql.mask, ql.topic)
    # the delta admits the longest capacity-fitting prefix; the rebuild
    # oracle must see exactly the admitted docs
    ext = extend_corpus(corpus, slice_feed(feed, 0, took))
    oracle_idx = build_index(ext, stop_k=base.index.stop_k)
    fresh = system(ing, idx=oracle_idx, corp=ext)
    ref = fresh.serve(ql.terms, ql.mask, ql.topic)
    parity = {
        "docs_ingested": int(took), "docs_merged": int(merged),
        "live_candidate_slots": live_hits,
        "index_identical": _index_identical(on_sys.index, oracle_idx),
        "topk_identical": bool(np.array_equal(after.topk, ref.topk)),
        "final_identical": bool(np.array_equal(after.final, ref.final)),
        "latency_identical": bool(np.array_equal(after.latency,
                                                 ref.latency)),
    }

    # ---- worst-case accounting of the live delta scan ----
    off_sys = system()
    wc_off = float(off_sys.worst_case_us())
    wc_on = float(system(ing).worst_case_us())
    delta_term = float(cost.delta_time(ing.delta_postings))
    accounting = {
        "worst_case_off": wc_off, "worst_case_on": wc_on,
        "delta_scan_term": delta_term,
        "budget": float(base.routing.budget),
        "covers_delta": bool(wc_on >= wc_off + delta_term - 1e-9),
    }

    # ---- inert mode: enabled=False == no ingest node, bit for bit ----
    inert_spec = IngestSpec(enabled=False, delta_docs=ing.delta_docs,
                            feed_qps=ing.feed_qps)
    sys_a, sys_b = system(), system(inert_spec)
    ra = sys_a.serve(ql.terms, ql.mask, ql.topic)
    rb = sys_b.serve(ql.terms, ql.mask, ql.topic)
    capacity = estimate_capacity(system(), ql.terms, ql.mask, ql.topic)
    traffic_i = TrafficSpec(arrival="bursty", qps=0.8 * capacity,
                            seed=seed + 1)
    oa = system().serve_online(ql.terms, ql.mask, ql.topic,
                               traffic=traffic_i)
    ob = system(inert_spec).serve_online(ql.terms, ql.mask, ql.topic,
                                         traffic=traffic_i)
    inert = {
        "delta_absent": bool(sys_b.delta is None),
        "offline_topk_identical": bool(np.array_equal(ra.topk, rb.topk)),
        "offline_final_identical": bool(np.array_equal(ra.final, rb.final)),
        "offline_latency_identical": bool(np.array_equal(ra.latency,
                                                         rb.latency)),
        "online_event_log_identical": bool(oa.event_log == ob.event_log),
        "worst_case_identical": bool(sys_a.worst_case_us()
                                     == sys_b.worst_case_us()),
    }

    # ---- serve-while-ingesting sweep: zero violations under mutation ----
    # load is relative to the LIVE system's capacity: the delta-scan term
    # is part of every query's service time, so the mutable operating
    # point saturates earlier than the sealed one — that cost is the
    # price of ingest and the sweep prices it honestly (the sealed side
    # runs at the same offered qps for comparison)
    capacity_live = estimate_capacity(system(ing), ql.terms, ql.mask,
                                      ql.topic)
    sweep = []
    for load in loads:
        traffic = TrafficSpec(arrival="bursty", qps=load * capacity_live,
                              seed=seed + 1)
        r_on = system(ing).serve_online(ql.terms, ql.mask, ql.topic,
                                        traffic=traffic)
        r_off = system().serve_online(ql.terms, ql.mask, ql.topic,
                                      traffic=traffic)
        sweep.append({"load": load, "qps": float(load * capacity_live),
                      "on": _cell(r_on), "off": _cell(r_off)})

    enforced = [r[s] for r in sweep for s in ("on", "off")]
    applied = sum(r["on"]["ingest"]["feed_batches_applied"] for r in sweep)
    ingested = sum(r["on"]["ingest"]["docs_ingested"] for r in sweep)

    payload = bench_payload(
        "ingest",
        config={"q_batch": q_batch, "n_docs": n_docs, "seed": seed,
                "backend": backend, "max_batch": max_batch,
                "loads": list(loads), "feed_docs": feed_docs,
                "ingest": {"delta_docs": ing.delta_docs,
                           "delta_postings": ing.delta_postings,
                           "feed_qps": ing.feed_qps,
                           "feed_batch": ing.feed_batch,
                           "merge_threshold": ing.merge_threshold}},
        parity=parity,
        extra={
            "capacity_qps": {"sealed": float(capacity),
                             "live": float(capacity_live)},
            "accounting": accounting,
            "inert": inert,
            "sweep": sweep,
        })
    payload["gates"] = {
        "post_merge_bit_parity": (parity["index_identical"]
                                  and parity["topk_identical"]
                                  and parity["final_identical"]
                                  and parity["latency_identical"]),
        "worst_case_covers_delta": accounting["covers_delta"],
        "inert_bit_identical": all(inert.values()),
        "zero_violations": all(c["over_budget"] == 0 for c in enforced),
        "ingest_nonvacuous": (applied > 0 and ingested > 0
                              and parity["live_candidate_slots"] > 0),
    }
    payload["artifact"] = write_bench_artifact("ingest", payload)
    return payload


def render_ingest(res: dict) -> str:
    p, a, i = res["parity"], res["accounting"], res["inert"]
    lines = [
        f"post-merge parity: index={p['index_identical']} "
        f"topk={p['topk_identical']} final={p['final_identical']} "
        f"latency={p['latency_identical']} "
        f"(ingested {p['docs_ingested']}, merged {p['docs_merged']}, "
        f"{p['live_candidate_slots']} live candidate slots pre-merge)",
        f"worst case: off={a['worst_case_off']:.2f} "
        f"on={a['worst_case_on']:.2f} "
        f"(delta term {a['delta_scan_term']:.2f}, "
        f"budget {a['budget']:.0f}) covered={a['covers_delta']}",
        f"inert: {'identical' if all(i.values()) else 'DIVERGED'} "
        f"(offline+online vs no-ingest spec)",
        "load,side,served,shed,over,full,batches_applied/due,throttled,"
        "merges(def/forced)",
    ]
    for r in res["sweep"]:
        for side in ("on", "off"):
            c = r[side]
            if side == "on":
                g = c["ingest"]
                tail = (f"{g['feed_batches_applied']}/"
                        f"{g['feed_batches_due']},{g['feed_throttled']},"
                        f"{g['merges']}({g['merge_deferred']}/"
                        f"{g['merges_forced']})")
            else:
                tail = "-,-,-"
            lines.append(f"{r['load']:.2f},{side},{c['served']},"
                         f"{c['shed']},{c['over_budget']},"
                         f"{c['modes']['full']},{tail}")
    g = res["gates"]
    lines.append("gates: " + ", ".join(f"{k}={v}" for k, v in g.items()))
    return "\n".join(lines)


def main() -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--q-batch", type=int, default=384)
    ap.add_argument("--n-docs", type=int, default=4096)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--loads", type=float, nargs="+",
                    default=[0.5, 0.8, 0.95])
    ap.add_argument("--feed-docs", type=int, default=128)
    ap.add_argument("--backend", default="jnp",
                    help="jnp gives the bit-identical parity checks")
    args = ap.parse_args()
    res = run_ingest(q_batch=args.q_batch, n_docs=args.n_docs,
                     seed=args.seed, loads=tuple(args.loads),
                     feed_docs=args.feed_docs,
                     max_batch=args.max_batch, backend=args.backend)
    print(render_ingest(res))
    print(f"artifact: {res['artifact']}")
    failed = [k for k, v in res["gates"].items() if not v]
    if failed:
        print(f"INGEST CERTIFICATION FAILED: {', '.join(failed)}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
