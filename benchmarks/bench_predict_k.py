"""Paper Figs. 2 + 4 — predicting k: oracle vs QR (τ sweep) vs RF.

Shows (a) the distribution match (QR tracks the skewed oracle distribution,
RF overshoots the median) and (b) the median-k / mean-k vs achieved-MED
trade-off curves."""

from __future__ import annotations

import numpy as np

from benchmarks.common import Experiment, cv_predict, med_at_k


def _stats(v):
    return {"mean": float(np.mean(v)), "p50": float(np.median(v)),
            "p90": float(np.percentile(v, 90)),
            "p99": float(np.percentile(v, 99))}


def run(exp: Experiment, taus=(0.35, 0.45, 0.55, 0.65)) -> dict:
    rows = exp.train_rows
    oracle_k = exp.labels.oracle_k[rows]
    out = {"oracle": dict(_stats(oracle_k), med=float(
        med_at_k(exp.labels, rows, oracle_k).mean()))}

    for tau in taus:
        pred = cv_predict(exp, "qr", "k", tau=tau)[rows]
        kq = np.clip(np.round(pred), 10, 16384)
        out[f"qr_tau{tau:.2f}"] = dict(_stats(kq), med=float(
            med_at_k(exp.labels, rows, kq).mean()))

    # the paper's RF baseline (mean regression on the raw skewed target) —
    # overshoots the median, Fig. 2's observation
    pred_rf = cv_predict(exp, "rf_raw", "k")[rows]
    krf = np.clip(np.round(pred_rf), 10, 16384)
    out["rf_paper"] = dict(_stats(krf), med=float(
        med_at_k(exp.labels, rows, krf).mean()))
    # beyond-paper: RF on log1p(k) (variance-stabilized) for comparison
    pred_rfl = cv_predict(exp, "rf", "k")[rows]
    krfl = np.clip(np.round(pred_rfl), 10, 16384)
    out["rf_log(beyond-paper)"] = dict(_stats(krfl), med=float(
        med_at_k(exp.labels, rows, krfl).mean()))
    return {"systems": out}


def render(res) -> str:
    lines = ["system,mean_k,median_k,p90_k,p99_k,mean_med"]
    for name, s in res["systems"].items():
        lines.append(f"{name},{s['mean']:.0f},{s['p50']:.0f},{s['p90']:.0f},"
                     f"{s['p99']:.0f},{s['med']:.4f}")
    return "\n".join(lines)
