"""Result-cache certification: the two-level serving cache must buy
capacity without costing a single bit of correctness.

Four studies over one fitted cascade (frozen thresholds, jnp backend):

* **hit parity** — a warm L1 hit must return results **bit-identical** to
  the cache-off recompute (top-k and final top-t), and a *cold* cache-on
  serve must already match cache-off exactly (misses pay the probe in
  modeled time, never in output).  Certified under a no-trim run so the
  comparison is exact (``stage2_trimmed == stage2_skipped == 0``).
* **inert mode** — a disabled/zero-capacity :class:`CacheSpec` must be
  provably absent: offline serving bit-identical (top-k, final, modeled
  latency) and the online event log tuple-identical to the default spec.
* **skew sweep** — p50/p99.99 response + achieved QPS, cache-on vs
  cache-off, under Zipfian repetition s ∈ {0, 0.8, 1.2} at 0.8x the
  cache-off saturated capacity.
* **overload certification** — sweep offered load past cache-off
  saturation at s=1.2.  A load is *certified sustainable* when every
  query is served FULL with 0 response-budget violations and 0 sheds.
  Gate: the cache-on certified QPS is >= 1.2x the cache-off certified
  QPS (L1 hits are answered at the front door, so only misses consume
  engine-batch slots), with 0 violations everywhere.

Emits ``results/BENCH_cache.json``; the CLI exits non-zero if any gate
fails.  CI runs it as a smoke.  Run standalone with
``PYTHONPATH=src:. python benchmarks/bench_cache.py``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.bench_online import _build
from benchmarks.common import bench_payload, write_bench_artifact


def _cell(res) -> dict:
    """Summarize one online run for the JSON artifact."""
    s = res.stats
    out = {
        "served": s["served"], "shed": s["shed"],
        "over_budget": s["over_budget"],
        "modes": s["modes"],
        "p50": s["response"]["p50"] if "response" in s else None,
        "p99.99": s["response"]["p99.99"] if "response" in s else None,
        "achieved_qps": s.get("achieved_qps"),
    }
    if "cache" in s:
        c = s["cache"]
        out["hit_ratio"] = c["hit_ratio"]
        out["l1_hits"] = c["l1"]["hits"] if c.get("l1") else 0
        out["front_door_hits"] = c["front_door_hits"]
        out["hit_ewma"] = c.get("hit_ewma")
    return out


def _certified(cells: list) -> float:
    """Highest offered QPS at which every query was served FULL with zero
    budget violations and zero sheds (0.0 when no load qualifies)."""
    ok = [c["qps"] for c in cells
          if c["over_budget"] == 0 and c["shed"] == 0
          and c["modes"]["full"] == c["served"]]
    return float(max(ok)) if ok else 0.0


def run_cache(q_batch: int = 384, n_docs: int = 4096, seed: int = 7,
              skews: tuple = (0.0, 0.8, 1.2),
              sweep_load: float = 0.8,
              loads_off: tuple = (0.8, 1.0, 1.2, 1.5, 2.0),
              loads_on: tuple = (1.2, 1.5, 2.0, 2.5, 3.0),
              max_batch: int = 16, backend: str = "jnp") -> dict:
    from repro.serving.online import estimate_capacity
    from repro.serving.spec import CacheSpec, TrafficSpec
    from repro.serving.system import build_system

    corpus, base, ql, fit_sys = _build(q_batch, n_docs, seed, backend,
                                       max_batch)
    index, models, ltr = fit_sys.index, fit_sys.models, fit_sys.ltr
    cost = fit_sys.cost
    cache_spec = CacheSpec(enabled=True)

    def system(cache: CacheSpec | None = None):
        spec = base if cache is None else dataclasses.replace(base,
                                                              cache=cache)
        return build_system(spec, index, corpus=corpus, models=models,
                            ltr=ltr, cost=cost)

    # ---- hit parity: warm L1 hit == cache-off recompute, bit for bit ----
    off_sys = system()
    res_off = off_sys.serve(ql.terms, ql.mask, ql.topic)
    b_off = res_off.stats["budget"]
    no_trims = (b_off["stage2_trimmed"] == 0 and b_off["stage2_skipped"] == 0)
    on_sys = system(cache_spec)
    cold = on_sys.serve(ql.terms, ql.mask, ql.topic)
    warm = on_sys.serve(ql.terms, ql.mask, ql.topic)
    c = on_sys.cache.counters
    parity = {
        "no_trims_in_reference": bool(no_trims),
        "cold_topk_identical": bool(np.array_equal(cold.topk, res_off.topk)),
        "cold_final_identical": bool(np.array_equal(cold.final,
                                                    res_off.final)),
        "warm_topk_identical": bool(np.array_equal(warm.topk, res_off.topk)),
        "warm_final_identical": bool(np.array_equal(warm.final,
                                                    res_off.final)),
        "warm_all_l1_hits": bool(c["l1_hits"] == q_batch),
        "p50_off": res_off.stats["p50"], "p50_warm": warm.stats["p50"],
        "hit_speedup_p50": float(res_off.stats["p50"]
                                 / max(warm.stats["p50"], 1e-9)),
        "worst_case_off": float(off_sys.worst_case_us()),
        "worst_case_on": float(on_sys.worst_case_us()),
    }

    # ---- inert mode: zero-capacity spec == no cache, bit for bit ----
    inert_spec = CacheSpec(enabled=True, l1_entries=0, l2_entries=0)
    sys_a, sys_b = system(), system(inert_spec)
    ra = sys_a.serve(ql.terms, ql.mask, ql.topic)
    rb = sys_b.serve(ql.terms, ql.mask, ql.topic)
    traffic_i = TrafficSpec(arrival="bursty", qps=0.8 * 500.0, skew=0.8,
                            seed=seed + 1)
    oa = system().serve_online(ql.terms, ql.mask, ql.topic,
                               traffic=traffic_i)
    ob = system(inert_spec).serve_online(ql.terms, ql.mask, ql.topic,
                                         traffic=traffic_i)
    inert = {
        "cache_absent": bool(sys_b.cache is None),
        "offline_topk_identical": bool(np.array_equal(ra.topk, rb.topk)),
        "offline_final_identical": bool(np.array_equal(ra.final, rb.final)),
        "offline_latency_identical": bool(np.array_equal(ra.latency,
                                                         rb.latency)),
        "online_event_log_identical": bool(oa.event_log == ob.event_log),
    }

    # ---- skew sweep at a common sub-saturation load ----
    capacity_off = estimate_capacity(system(), ql.terms, ql.mask, ql.topic)
    sweep = []
    for skew in skews:
        traffic = TrafficSpec(arrival="poisson",
                              qps=sweep_load * capacity_off,
                              skew=skew, seed=seed + 1)
        r_on = system(cache_spec).serve_online(ql.terms, ql.mask, ql.topic,
                                               traffic=traffic)
        r_off = system().serve_online(ql.terms, ql.mask, ql.topic,
                                      traffic=traffic)
        sweep.append({"skew": skew, "load": sweep_load,
                      "qps": float(sweep_load * capacity_off),
                      "on": _cell(r_on), "off": _cell(r_off)})

    # ---- overload certification at the heaviest skew ----
    skew_hot = float(max(skews))
    grid = {"on": [], "off": []}
    for name, spec_c, loads in (("off", None, loads_off),
                                ("on", cache_spec, loads_on)):
        for load in loads:
            traffic = TrafficSpec(arrival="poisson",
                                  qps=load * capacity_off,
                                  skew=skew_hot, seed=seed + 1)
            r = system(spec_c).serve_online(ql.terms, ql.mask, ql.topic,
                                            traffic=traffic)
            grid[name].append({"load": load,
                               "qps": float(load * capacity_off),
                               **_cell(r)})

    certified_off = _certified(grid["off"])
    certified_on = _certified(grid["on"])
    hot_on = [r["on"] for r in sweep if r["skew"] == skew_hot]
    hit_ratio_hot = hot_on[0]["hit_ratio"] if hot_on else 0.0
    enforced = ([r["on"] for r in sweep] + [r["off"] for r in sweep]
                + grid["on"] + grid["off"])

    payload = bench_payload(
        "cache",
        config={"q_batch": q_batch, "n_docs": n_docs, "seed": seed,
                "backend": backend, "max_batch": max_batch,
                "skews": list(skews), "sweep_load": sweep_load,
                "loads_off": list(loads_off), "loads_on": list(loads_on),
                "cache": {"l1_entries": cache_spec.l1_entries,
                          "l2_entries": cache_spec.l2_entries}},
        parity=parity,
        extra={
            "capacity_off_qps": float(capacity_off),
            "inert": inert,
            "sweep": sweep,
            "grid": grid,
            "certified_qps": {"off": certified_off, "on": certified_on,
                              "speedup": (certified_on
                                          / max(certified_off, 1e-9))},
            "hit_ratio_at_hot_skew": float(hit_ratio_hot),
        })
    payload["gates"] = {
        "hits_bit_identical": (parity["no_trims_in_reference"]
                               and parity["cold_topk_identical"]
                               and parity["cold_final_identical"]
                               and parity["warm_topk_identical"]
                               and parity["warm_final_identical"]
                               and parity["warm_all_l1_hits"]),
        "inert_bit_identical": all(inert.values()),
        "guarantee_holds": all(r["over_budget"] == 0 for r in enforced),
        "capacity_speedup": (certified_off > 0
                             and certified_on
                             >= 1.2 * certified_off - 1e-9),
        "hits_nonvacuous": hit_ratio_hot >= 0.2,
    }
    payload["artifact"] = write_bench_artifact("cache", payload)
    return payload


def render_cache(res: dict) -> str:
    p, i, cq = res["parity"], res["inert"], res["certified_qps"]
    lines = [f"capacity(off)={res['capacity_off_qps']:.0f} qps; "
             f"worst-case bound off={p['worst_case_off']:.2f} "
             f"on={p['worst_case_on']:.2f}",
             f"hit parity: cold topk={p['cold_topk_identical']} "
             f"final={p['cold_final_identical']}; warm "
             f"topk={p['warm_topk_identical']} "
             f"final={p['warm_final_identical']} "
             f"(all-L1={p['warm_all_l1_hits']}, p50 speedup "
             f"{p['hit_speedup_p50']:.1f}x)",
             f"inert: {'identical' if all(i.values()) else 'DIVERGED'} "
             f"(offline+online vs no-cache spec)",
             "skew,side,p50,p99.99,qps,hit_ratio,front_door,over,shed"]
    for r in res["sweep"]:
        for side in ("off", "on"):
            c = r[side]
            hr = c.get("hit_ratio")
            lines.append(
                f"{r['skew']:.1f},{side},{c['p50']:.1f},{c['p99.99']:.1f},"
                f"{c['achieved_qps']:.0f},"
                f"{hr if hr is None else round(hr, 3)},"
                f"{c.get('front_door_hits', 0)},{c['over_budget']},"
                f"{c['shed']}")
    lines.append("load,side,full,trim+stage1,shed,over,qps")
    for side in ("off", "on"):
        for c in res["grid"][side]:
            m = c["modes"]
            degraded = c["served"] - m["full"]
            lines.append(f"{c['load']:.2f},{side},{m['full']},{degraded},"
                         f"{c['shed']},{c['over_budget']},"
                         f"{c['achieved_qps']:.0f}")
    lines.append(f"certified sustainable qps: off={cq['off']:.0f} "
                 f"on={cq['on']:.0f} ({cq['speedup']:.2f}x)")
    lines.append("gates: " + " ".join(f"{k}={v}"
                                      for k, v in res["gates"].items()))
    return "\n".join(lines)


def main() -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--q-batch", type=int, default=384)
    ap.add_argument("--n-docs", type=int, default=4096)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--skews", type=float, nargs="+",
                    default=[0.0, 0.8, 1.2])
    ap.add_argument("--loads-off", type=float, nargs="+",
                    default=[0.8, 1.0, 1.2, 1.5, 2.0])
    ap.add_argument("--loads-on", type=float, nargs="+",
                    default=[1.2, 1.5, 2.0, 2.5, 3.0])
    ap.add_argument("--backend", default="jnp",
                    help="jnp gives the bit-identical parity checks")
    args = ap.parse_args()
    res = run_cache(q_batch=args.q_batch, n_docs=args.n_docs,
                    seed=args.seed, skews=tuple(args.skews),
                    loads_off=tuple(args.loads_off),
                    loads_on=tuple(args.loads_on),
                    max_batch=args.max_batch, backend=args.backend)
    print(render_cache(res))
    print(f"artifact: {res['artifact']}")
    failed = [k for k, v in res["gates"].items() if not v]
    if failed:
        print(f"CACHE CERTIFICATION FAILED: {', '.join(failed)}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
