"""Tail-guarantee certification: the budget-enforcement subsystem vs the
seed scheduler, on the same trace, at bit-identical output.

The paper's headline claim is a *hard* response-time guarantee at 99.99 %:
budget-blowing executions are detected at ``budget·hedge_deadline`` and
re-issued to JASS with a **small** ρ cap, so the worst case is
``budget·d + ρ_late·c_s`` — under the budget whenever
``ρ_late ≤ SchedulerConfig.max_late_rho(cost)``.  The seed implementation
re-issued with ``min(ρ, rho_max)``, which ``clamp_parameters`` had already
applied — a no-op that left the tail unbounded.

This benchmark serves one trace through two systems sharing the index,
Stage-0 predictors, LTR model, and routing thresholds:

* **seed-mode** — ``late_rho = rho_max`` (the no-op re-issue) and
  ``enforce_budget=False`` (no JASS deadline re-route, no Stage-2 trim):
  the seed scheduler's semantics, which must leak >= 1 violation;
* **enforced** — a ``late_rho`` sized from the cost model so the analytic
  bound collapses to the budget: must show 0 violations.

Because hedging only affects *latency resolution* (results come from the
mirrors either way, and the Stage-2 reservation guarantees the candidate
trim never fires when the Stage-1 bound holds), the Stage-1 top-k and
final top-t must be bit-identical between the two runs on the jnp
backend — the guarantee costs nothing in effectiveness on a conforming
trace.  The budget is picked from the raw (unhedged) latency distribution
so the trace genuinely stresses the tail.

Emits ``results/BENCH_tail.json``; the CLI exits non-zero if the enforced
run has any violation, if the seed run leaks none (regression not
demonstrated), or if outputs diverge — CI runs it as a smoke.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import bench_payload, write_bench_artifact


def run_tail(q_batch: int = 256, n_docs: int = 8192, seed: int = 7,
             pcts: tuple = (85, 70, 50), backend: str = "jnp") -> dict:
    from repro.configs.cascade_presets import get_preset
    from repro.index.corpus import CorpusParams, build_corpus, build_queries
    from repro.serving.scheduler import SchedulerConfig
    from repro.serving.spec import BackendSpec
    from repro.serving.system import build_system

    corpus = build_corpus(CorpusParams(n_docs=n_docs,
                                       vocab=max(n_docs // 2, 2048),
                                       avg_doclen=96, zipf_a=1.05,
                                       seed=seed))
    base = dataclasses.replace(get_preset("paper_200ms"),
                               backend=BackendSpec(backend=backend))
    ql = build_queries(corpus, q_batch, stop_k=base.index.stop_k,
                       seed=seed + 4)

    fit_sys = build_system(base, corpus)
    fit_sys.fit(ql, None, seed=seed)
    index, models, ltr = fit_sys.index, fit_sys.models, fit_sys.ltr
    cost = fit_sys.cost
    # every configuration below routes with the SAME calibrated thresholds
    # and never adapts them, so the seed/enforced comparison is pure
    base = dataclasses.replace(
        base, routing=dataclasses.replace(
            base.routing, t_k=fit_sys._base_cfg.t_k,
            t_time=fit_sys._base_cfg.t_time, calibrate=False,
            adapt_every=0))

    def system(**routing_kw):
        spec = dataclasses.replace(
            base, routing=dataclasses.replace(base.routing, **routing_kw))
        return build_system(spec, index, corpus=corpus, models=models,
                            ltr=ltr)

    # raw tail: no hedging, no enforcement, effectively infinite budget —
    # the latency distribution the budget must be chosen against
    probe = system(budget=1e9, enable_hedging=False, enforce_budget=False)
    lat_raw = probe.serve(ql.terms, ql.mask, ql.topic).latency

    from repro.serving.latency import budget_attribution
    chosen = None
    for pct in pcts:
        budget = float(np.percentile(lat_raw, pct))
        budget1 = budget_attribution(budget, cost,
                                     base.stage2.k_serve)["stage1"]
        if budget1 <= 0:
            continue
        probe_cfg = SchedulerConfig(budget=budget1,
                                    hedge_deadline=base.routing.hedge_deadline)
        late_rho = min(probe_cfg.max_late_rho(cost), base.routing.rho_min)
        if late_rho < 1:
            continue

        seed_sys = system(budget=budget, late_rho=base.routing.rho_max,
                          enforce_budget=False)
        enf_sys = system(budget=budget, late_rho=late_rho,
                         enforce_budget=True)
        res_seed = seed_sys.serve(ql.terms, ql.mask, ql.topic)
        res_enf = enf_sys.serve(ql.terms, ql.mask, ql.topic)
        cand = (pct, budget, late_rho, seed_sys, enf_sys, res_seed,
                res_enf)
        if res_seed.stats["over_budget"] >= 1 and chosen is None:
            chosen = cand
        # keep lowering the budget until the *BMW* no-op late hedge is
        # exercised too (seed late_hedged >= 1), not just the JASS leak —
        # the headline fix must be on the certified path
        if (res_seed.stats["over_budget"] >= 1
                and res_seed.stats["late_hedged"] >= 1):
            chosen = cand
            break
    if chosen is None:
        raise RuntimeError("no feasible budget found on this trace — "
                           "raise q_batch/n_docs")
    pct, budget, late_rho, seed_sys, enf_sys, res_seed, res_enf = chosen

    identical_topk = bool(np.array_equal(res_seed.topk, res_enf.topk))
    identical_final = bool(np.array_equal(res_seed.final, res_enf.final))
    bound = enf_sys.worst_case_us()
    payload = bench_payload(
        "tail",
        config={"q_batch": q_batch, "n_docs": n_docs, "seed": seed,
                "backend": backend, "budget_percentile": pct},
        extra={
            "budget": budget,
            "late_rho": int(late_rho),
            "raw_max": float(lat_raw.max()),
            "worst_case_bound": float(bound),
            "bound_holds": bool(res_enf.latency.max() <= bound + 1e-9),
            "seed_scheduler": {
                "over_budget": int(res_seed.stats["over_budget"]),
                "over_budget_pct": float(res_seed.stats["over_budget_pct"]),
                "max": float(res_seed.latency.max()),
                "late_hedged": int(res_seed.stats["late_hedged"]),
            },
            "enforced": {
                "over_budget": int(res_enf.stats["over_budget"]),
                "over_budget_pct": float(res_enf.stats["over_budget_pct"]),
                "max": float(res_enf.latency.max()),
                "late_hedged": int(res_enf.stats["late_hedged"]),
                "late_hedged_jass": int(res_enf.stats["late_hedged_jass"]),
                "stage2_trimmed": int(
                    res_enf.stats["budget"]["stage2_trimmed"]),
                "stage2_skipped": int(
                    res_enf.stats["budget"]["stage2_skipped"]),
            },
            "identical_topk": identical_topk,
            "identical_final": identical_final,
            "regression_demonstrated": int(res_seed.stats["over_budget"]) >= 1,
            "bmw_late_hedge_exercised": int(res_seed.stats["late_hedged"]) >= 1,
            "guarantee_holds": int(res_enf.stats["over_budget"]) == 0,
        })
    payload["artifact"] = write_bench_artifact("tail", payload)
    return payload


def render_tail(res: dict) -> str:
    s, e = res["seed_scheduler"], res["enforced"]
    lines = [
        "scheduler,over_budget,over_pct,max_ms,late_hedged",
        f"seed(no-op late hedge),{s['over_budget']},"
        f"{s['over_budget_pct']:.2f},{s['max']:.1f},{s['late_hedged']}",
        f"enforced(late_rho={res['late_rho']}),{e['over_budget']},"
        f"{e['over_budget_pct']:.2f},{e['max']:.1f},"
        f"{e['late_hedged']}+{e['late_hedged_jass']}jass",
        f"budget={res['budget']:.1f} (p{res['config']['budget_percentile']}"
        f" of raw tail, raw max {res['raw_max']:.1f}); analytic bound "
        f"{res['worst_case_bound']:.1f} holds={res['bound_holds']}",
        f"bit-identical: topk={res['identical_topk']} "
        f"final={res['identical_final']}; stage2 trimmed="
        f"{e['stage2_trimmed']} skipped={e['stage2_skipped']}",
    ]
    return "\n".join(lines)


def main() -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--q-batch", type=int, default=256)
    ap.add_argument("--n-docs", type=int, default=8192)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--backend", default="jnp",
                    help="jnp gives the bit-identical parity check")
    args = ap.parse_args()
    res = run_tail(q_batch=args.q_batch, n_docs=args.n_docs, seed=args.seed,
                   backend=args.backend)
    print(render_tail(res))
    print(f"artifact: {res['artifact']}")
    checks = {
        "guarantee_holds": res["guarantee_holds"],
        "regression_demonstrated": res["regression_demonstrated"],
        "bmw_late_hedge_exercised": res["bmw_late_hedge_exercised"],
        "bound_holds": res["bound_holds"],
    }
    if args.backend == "jnp":
        checks["identical_topk"] = res["identical_topk"]
        checks["identical_final"] = res["identical_final"]
    failed = [k for k, v in checks.items() if not v]
    if failed:
        print(f"TAIL GUARANTEE CHECK FAILED: {', '.join(failed)}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
