"""Dense retrieval + hybrid fusion certification: the second Stage-1
modality must be exact, fast because it is batched, and free when disabled.

Four studies over one fitted cascade (frozen thresholds, jnp backend):

* **kernel/engine parity** — the tiled streaming kernel (interpret mode)
  must agree **bit for bit** with the jnp reference and the numpy
  brute-force oracle on ragged shapes and exact ties, and the sharded
  ``DenseEngine.serve`` (single and multi-shard through
  ``merge_shard_topk``) must reproduce the unsharded oracle exactly —
  grid-quantized embeddings make this determinism, not luck.
* **batched speedup** — one Q=64 batched kernel call vs 64 single-query
  calls on the same matrix.  Gate: >= 3x.  This is the reason the dense
  modality is a *batched* engine and not a per-query scorer.
* **route-mix sweep** — force the Stage-0 dispatch to all-lexical,
  all-dense, and mixed (via ``t_dense`` extremes), plus a theta-band
  configuration that exercises Stage-2 skips and lexical fallbacks.
  Gate: 0 budget violations and max latency <= ``worst_case_us()`` in
  every mix — the hard guarantee is per-route, not per-average.
* **inert mode** — ``DenseSpec(enabled=False)`` (even with every other
  dense/fusion knob set) must be provably absent: offline serving
  bit-identical (top-k, final, modeled latency) and the online event log
  tuple-identical to the dense-free spec.

Emits ``results/BENCH_dense.json``; the CLI exits non-zero if any gate
fails.  CI runs it as a smoke.  Run standalone with
``PYTHONPATH=src:. python benchmarks/bench_dense.py``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.bench_online import _build
from benchmarks.common import bench_payload, timed, write_bench_artifact


def _parity(corpus, ql, seed: int) -> dict:
    """Kernel backends and the sharded engine vs the numpy oracle."""
    import jax.numpy as jnp

    from repro.dense import DenseEngine, build_embeddings
    from repro.index.postings import shard_ranges
    from repro.kernels.dense_topk import dense_topk, dense_topk_oracle
    from repro.serving.spec import DenseSpec

    ds = DenseSpec(enabled=True, source="synthetic", seed=seed)
    doc_emb, term_table = build_embeddings(ds, corpus=None,
                                           n_docs=corpus.n_docs,
                                           vocab=corpus.vocab)
    eng1 = DenseEngine(doc_emb, term_table,
                       shard_ranges(corpus.n_docs, 1), backend="jnp")
    eng3 = DenseEngine(doc_emb, term_table,
                       shard_ranges(corpus.n_docs, 3), backend="jnp")
    q_emb = eng1.embed(ql.terms, ql.mask)
    out = {}

    # backends on a ragged slice (non-multiple docs + embed dim), two k's
    q_sub, d_sub = q_emb[:32], jnp.asarray(doc_emb[:1000])
    for k in (1, 33, 128):
        o_sc, o_ids = dense_topk_oracle(np.asarray(q_sub),
                                        doc_emb[:1000], k)
        for backend in ("jnp", "interpret"):
            sc, ids = dense_topk(q_sub, d_sub, k, backend=backend)
            out[f"kernel_{backend}_k{k}"] = bool(
                np.array_equal(np.asarray(sc), o_sc)
                and np.array_equal(np.asarray(ids, np.int64), o_ids))

    # ties: duplicated docs must resolve to the lower doc id everywhere
    dup = np.concatenate([doc_emb[:256]] * 2)
    t_sc, t_ids = dense_topk(q_emb[:16], jnp.asarray(dup), 64)
    o_sc, o_ids = dense_topk_oracle(q_emb[:16], dup, 64)
    out["kernel_tie_policy"] = bool(
        np.array_equal(np.asarray(t_sc), o_sc)
        and np.array_equal(np.asarray(t_ids, np.int64), o_ids))

    # sharded engine == unsharded oracle, single and multi-shard
    k = 128
    o_ids, o_sc = eng1.oracle(q_emb, k)
    for name, eng in (("1shard", eng1), ("3shard", eng3)):
        ids, sc = eng.serve(q_emb, k)
        out[f"engine_{name}"] = bool(np.array_equal(ids, o_ids)
                                     and np.array_equal(sc, o_sc))
    return out


def _speedup(corpus, ql, seed: int, q_batch: int = 64,
             reps: int = 5) -> dict:
    """One batched Q=64 call vs 64 single-query calls (jnp, jit'd both)."""
    import jax.numpy as jnp

    from repro.dense import build_embeddings
    from repro.kernels.dense_topk import dense_topk
    from repro.serving.spec import DenseSpec

    ds = DenseSpec(enabled=True, source="synthetic", seed=seed)
    doc_emb, term_table = build_embeddings(ds, corpus=None,
                                           n_docs=corpus.n_docs,
                                           vocab=corpus.vocab)
    from repro.dense import embed_queries
    q_emb = jnp.asarray(embed_queries(term_table, ql.terms[:q_batch],
                                      ql.mask[:q_batch]))
    docs = jnp.asarray(doc_emb)
    k = 128

    t_batch = timed(lambda: dense_topk(q_emb, docs, k), reps, warmup=2)

    def loop():
        return [dense_topk(q_emb[i:i + 1], docs, k)
                for i in range(q_batch)]

    t_loop = timed(loop, reps, warmup=1)
    speedup = float(np.median(t_loop) / max(np.median(t_batch), 1e-12))
    return {"q_batch": q_batch, "k": k,
            "batched_s": float(np.median(t_batch)),
            "loop_s": float(np.median(t_loop)),
            "speedup": speedup}


def run_dense(q_batch: int = 384, n_docs: int = 4096, seed: int = 7,
              max_batch: int = 16, backend: str = "jnp") -> dict:
    from repro.serving.spec import DenseSpec, FusionSpec, TrafficSpec
    from repro.serving.system import build_system

    corpus, base, ql, fit_sys = _build(q_batch, n_docs, seed, backend,
                                       max_batch)
    index, models, ltr = fit_sys.index, fit_sys.models, fit_sys.ltr
    cost = fit_sys.cost

    def system(dense: DenseSpec | None = None,
               fusion: FusionSpec | None = None):
        spec = base
        if dense is not None:
            spec = dataclasses.replace(spec, dense=dense)
        if fusion is not None:
            spec = dataclasses.replace(spec, fusion=fusion)
        return build_system(spec, index, corpus=corpus, models=models,
                            ltr=ltr, cost=cost)

    parity = _parity(corpus, ql, seed)
    speed = _speedup(corpus, ql, seed)

    # ---- route-mix sweep: every dispatch the router can emit ----
    # t_dense moves the lexical/dense decision boundary; the calibrated
    # t_time (t_dense=0) lands in the middle of the pred_t distribution
    mixes = {
        "mixed": DenseSpec(enabled=True, source="auto"),
        "all_lexical": DenseSpec(enabled=True, source="auto",
                                 t_dense=1e9),
        "all_dense": DenseSpec(enabled=True, source="auto",
                               t_dense=1e-6),
        # thetas chosen inside the observed top-1 score range so skips
        # AND fallbacks both fire on this trace
        "theta_bands": DenseSpec(enabled=True, source="auto",
                                 theta_high=0.45, theta_low=0.30),
    }
    sweep = []
    for name, ds in mixes.items():
        for method in (("rrf",) if name != "mixed" else ("rrf", "weighted")):
            sy = system(ds, FusionSpec(method=method))
            res = sy.serve(ql.terms, ql.mask, ql.topic)
            s = res.stats
            bound = float(sy.worst_case_us())
            sweep.append({
                "mix": name, "fusion": method,
                "dense": s["dense"], "over_budget": int(s["over_budget"]),
                "max_latency": float(np.max(res.latency)),
                "worst_case_bound": bound,
                "within_bound": bool(np.max(res.latency) <= bound + 1e-9),
            })

    # ---- inert mode: enabled=False with every other knob set ----
    off_spec = DenseSpec(enabled=False, embed_dim=64, tile_d=256,
                         source="synthetic", theta_high=0.45,
                         theta_low=0.30)
    sys_a, sys_b = system(), system(off_spec, FusionSpec(method="weighted"))
    ra = sys_a.serve(ql.terms, ql.mask, ql.topic)
    rb = sys_b.serve(ql.terms, ql.mask, ql.topic)
    traffic = TrafficSpec(arrival="bursty", qps=0.8 * 500.0, skew=0.8,
                          seed=seed + 1)
    oa = system().serve_online(ql.terms, ql.mask, ql.topic, traffic=traffic)
    ob = system(off_spec).serve_online(ql.terms, ql.mask, ql.topic,
                                       traffic=traffic)
    inert = {
        "engine_absent": bool(sys_b.dense is None),
        "offline_topk_identical": bool(np.array_equal(ra.topk, rb.topk)),
        "offline_final_identical": bool(np.array_equal(ra.final, rb.final)),
        "offline_latency_identical": bool(np.array_equal(ra.latency,
                                                         rb.latency)),
        "online_event_log_identical": bool(oa.event_log == ob.event_log),
    }

    mixed = [r for r in sweep if r["mix"] == "mixed"][0]["dense"]
    theta = [r for r in sweep if r["mix"] == "theta_bands"][0]["dense"]
    payload = bench_payload(
        "dense",
        config={"q_batch": q_batch, "n_docs": n_docs, "seed": seed,
                "backend": backend, "max_batch": max_batch},
        parity=parity,
        extra={"speedup": speed, "sweep": sweep, "inert": inert})
    payload["gates"] = {
        "kernel_engine_parity": all(parity.values()),
        "batched_speedup": speed["speedup"] >= 3.0,
        "route_guarantee": all(r["over_budget"] == 0 and r["within_bound"]
                               for r in sweep),
        "routes_nonvacuous": (mixed["lexical"] > 0 and mixed["fused"] > 0
                              and theta["theta_skips"] > 0
                              and theta["fallbacks"] > 0),
        "inert_bit_identical": all(inert.values()),
    }
    payload["artifact"] = write_bench_artifact("dense", payload)
    return payload


def render_dense(res: dict) -> str:
    p, sp, i = res["parity"], res["speedup"], res["inert"]
    bad = [k for k, v in p.items() if not v]
    lines = [f"parity: {'all bitwise' if not bad else 'DIVERGED: ' + str(bad)}",
             f"batched Q={sp['q_batch']}: {sp['batched_s']*1e3:.2f} ms vs "
             f"loop {sp['loop_s']*1e3:.2f} ms -> {sp['speedup']:.1f}x",
             "mix,fusion,lex,dense,fused,skips,fallbacks,over,max_ms,bound"]
    for r in res["sweep"]:
        d = r["dense"]
        lines.append(f"{r['mix']},{r['fusion']},{d['lexical']},"
                     f"{d['dense_only']},{d['fused']},{d['theta_skips']},"
                     f"{d['fallbacks']},{r['over_budget']},"
                     f"{r['max_latency']:.1f},{r['worst_case_bound']:.1f}")
    lines.append(f"inert: {'identical' if all(i.values()) else 'DIVERGED'} "
                 f"(offline+online vs dense-free spec)")
    lines.append("gates: " + " ".join(f"{k}={v}"
                                      for k, v in res["gates"].items()))
    return "\n".join(lines)


def main() -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--q-batch", type=int, default=384)
    ap.add_argument("--n-docs", type=int, default=4096)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--backend", default="jnp",
                    help="jnp gives the bit-identical parity checks")
    args = ap.parse_args()
    res = run_dense(q_batch=args.q_batch, n_docs=args.n_docs,
                    seed=args.seed, max_batch=args.max_batch,
                    backend=args.backend)
    print(render_dense(res))
    print(f"artifact: {res['artifact']}")
    failed = [k for k, v in res["gates"].items() if not v]
    if failed:
        print(f"DENSE CERTIFICATION FAILED: {', '.join(failed)}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
