"""Paper Table 4 — effectiveness on the 50 held-out queries (synthetic
graded judgments), with the TOST equivalence test vs the ideal run."""

from __future__ import annotations

import numpy as np

from benchmarks.common import Experiment, cv_predict
from repro.isn import oracle


def _judgments(exp, rows, pool_depth=50, seed=17):
    """Graded relevance from the ideal ranker: top pool_depth docs graded by
    noisy score band (the synthetic stand-in for TREC judgments — noise
    makes even the ideal run imperfect, as with human assessors)."""
    rng = np.random.RandomState(seed)
    qrels = {}
    for q in rows:
        ref = exp.labels.ref_lists[q][:pool_depth]
        base = np.clip(3 - np.arange(pool_depth) // 7, 0, 3)
        noise = rng.randint(-1, 2, pool_depth)
        grades = np.clip(base + noise, 0, 3).astype(np.int32)
        qrels[q] = dict(zip(ref.tolist(), grades.tolist()))
    return qrels


def _ndcg(run, rel, k=10):
    gains = np.asarray([rel.get(int(d), 0) for d in run[:k]], float)
    disc = 1.0 / np.log2(np.arange(2, k + 2))
    ideal = np.sort(list(rel.values()))[::-1][:k].astype(float)
    idcg = (((2 ** ideal) - 1) * disc[:len(ideal)]).sum()
    return float((((2 ** gains) - 1) * disc).sum() / max(idcg, 1e-9))


def _err(run, rel, k=10, max_grade=3):
    p_stop = [(2 ** rel.get(int(d), 0) - 1) / (2 ** max_grade)
              for d in run[:k]]
    err, p_reach = 0.0, 1.0
    for i, p in enumerate(p_stop):
        err += p_reach * p / (i + 1)
        p_reach *= (1 - p)
    return float(err)


def _rbp(run, rel, p=0.8, depth=50):
    gains = np.asarray([1.0 if rel.get(int(d), 0) >= 2 else 0.0
                        for d in run[:depth]])
    w = (1 - p) * p ** np.arange(len(gains))
    base = float((gains * w).sum())
    resid = float(p ** len(gains))
    return base, resid


def _tost(a, b, eps):
    """Two one-sided tests for equivalence of paired means (p<0.05)."""
    from scipy import stats
    d = np.asarray(a) - np.asarray(b)
    n = len(d)
    se = d.std(ddof=1) / np.sqrt(n) + 1e-12
    t1 = (d.mean() + eps) / se
    t2 = (d.mean() - eps) / se
    p1 = 1 - stats.t.cdf(t1, n - 1)
    p2 = stats.t.cdf(t2, n - 1)
    return max(p1, p2)


def _system_run(exp, rows, k_arr, rho_arr=None, depth=50):
    """Final-stage list: ideal ranker restricted to the candidate set."""
    runs = []
    for i, q in enumerate(rows):
        if rho_arr is None:
            acc, _ = oracle.exhaustive_scores(exp.index, exp.ql.terms,
                                              exp.ql.mask, np.asarray([q]))
        else:
            acc, _ = oracle.jass_scores(exp.index, exp.ql.terms, exp.ql.mask,
                                        np.asarray([q]),
                                        np.asarray([rho_arr[i]]))
        ids, _ = oracle._topk_ids(acc, int(k_arr[i]))
        cand = set(ids[0].tolist())
        run = [d for d in exp.labels.ref_lists[q] if int(d) in cand][:depth]
        runs.append(np.asarray(run + [-1] * (depth - len(run))))
    return runs


def run(exp: Experiment) -> dict:
    rows = exp.heldout_rows
    qrels = _judgments(exp, rows)
    pred_k = np.clip(np.round(cv_predict(exp, "qr", "k", tau=0.55)[rows]),
                     10, 16384).astype(np.int64)
    pred_rho = np.clip(np.round(cv_predict(exp, "qr", "rho", tau=0.45)[rows]),
                       1024, 1 << 20).astype(np.int64)
    rho_h = int(0.1 * exp.index.n_docs)

    systems = {
        "uog-ideal": [exp.labels.ref_lists[q][:50] for q in rows],
        "Hybrid_k": _system_run(exp, rows, pred_k, pred_rho),
        "Hybrid_h": _system_run(exp, rows, pred_k, pred_rho),
        "JASS_h": _system_run(exp, rows, np.full(len(rows), 3100),
                              np.full(len(rows), rho_h)),
    }
    out = {}
    per_q = {}
    for name, runs in systems.items():
        nd, er, rb, rs = [], [], [], []
        for i, q in enumerate(rows):
            nd.append(_ndcg(runs[i], qrels[q]))
            er.append(_err(runs[i], qrels[q]))
            b, r = _rbp(runs[i], qrels[q])
            rb.append(b)
            rs.append(r)
        out[name] = {"ndcg@10": float(np.mean(nd)),
                     "err@10": float(np.mean(er)),
                     "rbp0.8": float(np.mean(rb)),
                     "rbp_resid": float(np.mean(rs))}
        per_q[name] = {"ndcg": nd, "err": er, "rbp": rb}

    tost = {}
    for name in ("Hybrid_k", "Hybrid_h", "JASS_h"):
        for metric in ("ndcg", "err", "rbp"):
            eps = 0.1 * np.mean(per_q["uog-ideal"][metric])
            tost[f"{name}.{metric}"] = float(
                _tost(per_q["uog-ideal"][metric], per_q[name][metric], eps))
    return {"metrics": out, "tost_p": tost}


def render(res) -> str:
    lines = ["system,ndcg@10,err@10,rbp0.8,rbp_residual"]
    for name, m in res["metrics"].items():
        lines.append(f"{name},{m['ndcg@10']:.4f},{m['err@10']:.4f},"
                     f"{m['rbp0.8']:.4f},{m['rbp_resid']:.4f}")
    lines.append("# TOST equivalence p-values (p<0.05 => equivalent):")
    for k, v in res["tost_p"].items():
        lines.append(f"# {k}: p={v:.4f}")
    return "\n".join(lines)
