"""Shared experiment state for the paper-reproduction benchmarks.

Builds (and caches to results/) the full-scale study:
  * synthetic 65,536-doc collection + 31,642-query MQ2009-like trace,
  * oracle labels (k, ρ, time) + reference lists + stage-1 ranks,
  * 147 Stage-0 features,
  * cross-validated predictions for QR / RF / LR on all three targets.
"""

from __future__ import annotations

import json
import os
import pickle
import time
from dataclasses import dataclass, field

import numpy as np

RESULTS = os.environ.get("REPRO_RESULTS", "results")
N_QUERIES = int(os.environ.get("REPRO_QUERIES", "31642"))
HELD_OUT = 50            # first 50 queries = TREC WebTrack analogue
RBP_P = 0.95


def timed(fn, reps: int, warmup: int = 1) -> np.ndarray:
    """Wall-clock ``fn`` honestly under JAX async dispatch: every call's
    result (any pytree; non-JAX leaves are ignored) is
    ``jax.block_until_ready``'d *inside* the timed window, so a benchmark
    can never under-count by timing only the dispatch.  The first
    ``warmup`` calls are untimed (jit compilation).  Returns per-call
    seconds."""
    import jax

    def _sync(x):
        jax.block_until_ready(jax.tree_util.tree_leaves(x))

    for _ in range(warmup):
        _sync(fn())
    out = np.zeros(reps)
    for i in range(reps):
        t0 = time.perf_counter()
        _sync(fn())
        out[i] = time.perf_counter() - t0
    return out


BENCH_SCHEMA_VERSION = 1


def bench_payload(name: str, *, config: dict, rows=None, parity=None,
                  gates: dict | None = None, timestamp: str | None = None,
                  extra: dict | None = None) -> dict:
    """The shared ``BENCH_*.json`` envelope every bench emitter uses.

    Standardized keys make cross-PR trajectory diffs (and the
    ``obs_diff`` regression gate) mechanical instead of per-bench manual
    work: ``schema_version``, ``name``, ``config`` (the knobs the run was
    taken under), ``rows`` (the measured table), ``parity`` (bit-equality
    flags or None), ``gates`` (named pass/fail booleans) and an optional
    caller-passed ``timestamp`` (never generated here — artifacts must
    stay byte-deterministic for same-config runs).  Bench-specific keys
    ride in ``extra`` and are merged at the top level, so existing
    renderers and CI gates keep reading the names they always did.
    """
    payload: dict = {
        "schema_version": BENCH_SCHEMA_VERSION,
        "name": str(name),
        "config": dict(config),
        "rows": rows if rows is not None else [],
        "parity": parity,
    }
    if gates is not None:
        payload["gates"] = gates
    if timestamp is not None:
        payload["timestamp"] = str(timestamp)
    if extra:
        for k, v in extra.items():
            if k in payload:
                raise ValueError(f"extra key {k!r} collides with a "
                                 "schema key")
            payload[k] = v
    validate_bench_payload(payload)
    return payload


def validate_bench_payload(payload: dict) -> None:
    """Raise if a payload claiming the shared schema is malformed."""
    if payload.get("schema_version") != BENCH_SCHEMA_VERSION:
        raise ValueError("unknown bench schema_version "
                         f"{payload.get('schema_version')!r}")
    if not isinstance(payload.get("name"), str) or not payload["name"]:
        raise ValueError("bench payload needs a non-empty 'name'")
    if not isinstance(payload.get("config"), dict):
        raise ValueError("bench payload needs a 'config' dict")
    if not isinstance(payload.get("rows"), list):
        raise ValueError("bench payload 'rows' must be a list")
    parity = payload.get("parity")
    if parity is not None and not isinstance(parity, dict):
        raise ValueError("bench payload 'parity' must be a dict or None")
    if "gates" in payload:
        gates = payload["gates"]
        if (not isinstance(gates, dict)
                or not all(isinstance(v, (bool, np.bool_))
                           for v in gates.values())):
            raise ValueError("bench payload 'gates' must map names to "
                             "booleans")
    if "timestamp" in payload and not isinstance(payload["timestamp"],
                                                 str):
        raise ValueError("bench payload 'timestamp' must be a string "
                         "(caller-supplied)")


def write_bench_artifact(name: str, payload: dict) -> str:
    """Write a tracked benchmark artifact (``results/BENCH_<name>.json``).

    These artifacts record the perf trajectory across PRs (queries/sec,
    latency percentiles, speedups); keep the payload JSON-plain so diffs
    stay readable.  Payloads carrying ``schema_version`` are validated
    against the shared envelope (:func:`bench_payload`).
    """
    if "schema_version" in payload:
        validate_bench_payload(payload)
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True, default=float)
        f.write("\n")
    return path


@dataclass
class Experiment:
    corpus: object
    index: object
    ql: object
    labels: object
    x: np.ndarray
    preds: dict = field(default_factory=dict)   # (method, target, tau) -> arr

    @property
    def train_rows(self):
        keep = self.labels.keep.copy()
        keep[:HELD_OUT] = False
        return np.flatnonzero(keep)

    @property
    def heldout_rows(self):
        return np.arange(HELD_OUT)


def _collection(n_queries):
    from repro.index.builder import build_index
    from repro.index.corpus import CorpusParams, build_corpus, build_queries
    corpus = build_corpus(CorpusParams(n_docs=65536, vocab=16384,
                                       avg_doclen=200, zipf_a=1.05))
    index = build_index(corpus, stop_k=16)
    ql = build_queries(corpus, n_queries, stop_k=16)
    return corpus, index, ql


def load_experiment(n_queries: int = N_QUERIES, force: bool = False,
                    verbose: bool = True) -> Experiment:
    os.makedirs(RESULTS, exist_ok=True)
    cache = os.path.join(RESULTS, f"experiment_{n_queries}.pkl")
    if os.path.exists(cache) and not force:
        with open(cache, "rb") as f:
            return pickle.load(f)

    import jax.numpy as jnp
    from repro.core import features as F
    from repro.core.labels import LabelConfig, generate_labels

    t0 = time.time()
    corpus, index, ql = _collection(n_queries)
    if verbose:
        print(f"[common] collection built ({time.time()-t0:.0f}s, "
              f"{index.n_postings} postings)", flush=True)
    t0 = time.time()
    labels = generate_labels(index, corpus, ql, LabelConfig(), verbose=False)
    if verbose:
        print(f"[common] labels for {n_queries} queries "
              f"({time.time()-t0:.0f}s)", flush=True)
    x = np.asarray(F.extract(jnp.asarray(index.term_stats),
                             jnp.asarray(index.df),
                             jnp.asarray(ql.terms), jnp.asarray(ql.mask)))
    exp = Experiment(corpus, index, ql, labels, x)
    with open(cache, "wb") as f:
        pickle.dump(exp, f)
    return exp


# ---------------------------------------------------------------------------
# cross-validated predictions (cached per method/target/tau)
# ---------------------------------------------------------------------------

def cv_predict(exp: Experiment, method: str, target: str,
               tau: float = 0.5, n_folds: int = 5, n_trees: int = 48,
               force: bool = False) -> np.ndarray:
    """CV predictions over ALL queries (trained on kept, non-heldout rows).

    Held-out + filtered queries get predictions from the fold-0 model."""
    key = f"pred_{method}_{target}_{tau:.2f}_q{exp.x.shape[0]}"
    path = os.path.join(RESULTS, key + ".npy")
    if os.path.exists(path) and not force:
        return np.load(path)

    from repro.core import gbrt, linreg, random_forest as rf

    y_map = {"k": exp.labels.oracle_k, "rho": exp.labels.oracle_rho,
             "t": exp.labels.t_bmw}
    # "rf_raw" reproduces the paper's RF baseline: mean-targeting regression
    # on the raw heavy-tailed target (no variance-stabilizing transform)
    raw = method == "rf_raw"
    if raw:
        method = "rf"
    y = (y_map[target].astype(np.float32) if raw
         else np.log1p(y_map[target].astype(np.float32)))
    rows = exp.train_rows
    x = exp.x
    rng = np.random.RandomState(13)
    fold = rng.randint(0, n_folds, size=len(rows))
    pred = np.zeros(x.shape[0], np.float32)
    first_model = None
    for f in range(n_folds):
        tr = rows[fold != f]
        te = rows[fold == f]
        if method == "qr":
            m = gbrt.fit(x[tr], y[tr], gbrt.GBRTParams(
                n_trees=n_trees, depth=5, loss="quantile", tau=tau,
                learning_rate=0.15), seed=f)
            pred[te] = np.asarray(gbrt.predict(m, x[te]))
        elif method == "rf":
            m = rf.fit(x[tr], y[tr], rf.RFParams(n_trees=max(n_trees // 2, 16),
                                                 depth=6), seed=f)
            pred[te] = np.asarray(rf.predict(m, x[te]))
        else:
            m = linreg.fit(x[tr], y[tr])
            pred[te] = np.asarray(linreg.predict(m, x[te]))
        if first_model is None:
            first_model = m
    other = np.setdiff1d(np.arange(x.shape[0]), rows)
    if len(other):
        if method == "qr":
            pred[other] = np.asarray(gbrt.predict(first_model, x[other]))
        elif method == "rf":
            pred[other] = np.asarray(rf.predict(first_model, x[other]))
        else:
            pred[other] = np.asarray(linreg.predict(first_model, x[other]))
    pred = pred.clip(0, None) if raw else np.expm1(pred).clip(0, None)
    np.save(path, pred)
    return pred


def med_at_k(labels, rows, k_per_query) -> np.ndarray:
    """MED-RBP of re-ranked top-k candidates per query (from stage-1 ranks)."""
    from repro.core.reference import rbp_weights
    w = np.asarray(rbp_weights(labels.ref_lists.shape[1], RBP_P))
    ranks = labels.stage1_ranks[rows]
    kk = np.asarray(k_per_query).reshape(-1, 1)
    return (w[None, :] * (ranks >= kk)).sum(axis=1)


def ranks_in_system(index, ql, rows, acc, ref_lists, max_rank=16384):
    from repro.isn import oracle
    return oracle.ranks_of(acc, ref_lists[rows], max_rank)


def fixed_k_for_target(labels, rows, target_med: float, lo=8, hi=16384):
    """Smallest fixed k whose MEAN MED over `rows` hits the target."""
    while lo < hi:
        mid = (lo + hi) // 2
        m = med_at_k(labels, rows, np.full(len(rows), mid)).mean()
        if m <= target_med:
            hi = mid
        else:
            lo = mid + 1
    return lo
