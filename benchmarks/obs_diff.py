"""Observability regression gate: diff two telemetry snapshots.

``SearchSystem.snapshot()`` exports every serving metric (per-stage
latency quantiles, shed/trim/retry/failover counters, cache hit ratio,
ingest backpressure) as one deterministic dict.  That makes perf
regressions *diffable*: this module compares a current snapshot against a
committed baseline under per-metric tolerance rules and exits non-zero on
any regression, so the telemetry subsystem — not ad-hoc per-bench checks
— is the regression surface for future perf PRs.

Rules (see ``DEFAULT_TOL``):

* **latency histograms** (``*latency*``, ``*wait*``): each exported
  quantile (p50/p95/p99/p99.99) may not exceed the baseline by more than
  a relative tolerance plus an absolute slack — increases only; getting
  faster never fails the gate;
* **bad-event counters** (budget violations, sheds, trims/skips, retries,
  lost partitions): hard-fail when the baseline had zero and the current
  run has any; otherwise the same rel+abs slack applies;
* **cache hit ratio**: may not drop more than an absolute slack;
* a metric present in the baseline but missing from the current snapshot
  is itself a regression (telemetry coverage must not silently shrink).

Usage::

  PYTHONPATH=src python -m benchmarks.obs_diff BASE.json CUR.json
  PYTHONPATH=src python -m benchmarks.obs_diff --gate [--write-baseline]

``--gate`` serves a small deterministic trace (offline batch + online
simulation) with telemetry on, self-checks that an injected regression IS
flagged, then diffs against ``results/BENCH_obs_baseline.json`` and
writes ``results/BENCH_obs.json``.  CI runs it as a smoke.
"""

from __future__ import annotations

import argparse
import copy
import dataclasses
import json
import os
import sys

from benchmarks.common import RESULTS, bench_payload, write_bench_artifact

QUANTILES = ("p50", "p95", "p99", "p99.99")

DEFAULT_TOL = {
    "latency_rel": 0.25,    # quantile may grow 25% ...
    "latency_abs_us": 2.0,  # ... plus 2us absolute slack
    "count_rel": 0.25,      # bad-event counters: same shape
    "count_abs": 2.0,
    "hit_ratio_drop": 0.10,
}

# histogram name substrings whose growth is a regression
_LATENCY_HISTS = ("latency", "wait")
# counter names (exact, or section prefix before "{") where more is worse
_BAD_COUNTERS = ("budget_violations", "shed_queries", "stage2_trimmed",
                 "stage2_skipped")
# mirrored legacy-section keys where more is worse: section -> key substrs
_BAD_SECTION_KEYS = {
    "admission": ("shed_",),
    "scheduler": ("over_budget", "late_hedged"),
    "faults": ("retries", "lost_partitions", "transient", "degraded"),
    "ingest": ("feed_throttled", "merges_forced"),
}


def _is_latency_hist(key: str) -> bool:
    name = key.split("{", 1)[0]
    return any(s in name for s in _LATENCY_HISTS)


def _is_bad_counter(key: str) -> bool:
    name, _, rest = key.partition("{")
    if name in _BAD_COUNTERS:
        return True
    for section, subs in _BAD_SECTION_KEYS.items():
        if name == section and any(s in rest for s in subs):
            return True
    return False


def diff_snapshots(base: dict, cur: dict, tol: dict | None = None) -> list:
    """Regressions of ``cur`` relative to ``base`` (empty list = pass).

    Each finding is ``{"metric", "field", "base", "cur", "limit",
    "rule"}``; improvements never appear.
    """
    t = dict(DEFAULT_TOL, **(tol or {}))
    out: list[dict] = []

    def flag(metric, field, b, c, limit, rule):
        out.append({"metric": metric, "field": field, "base": float(b),
                    "cur": float(c), "limit": float(limit), "rule": rule})

    b_h = base.get("histograms", {})
    c_h = cur.get("histograms", {})
    for key, bh in sorted(b_h.items()):
        if not _is_latency_hist(key) or not bh.get("count"):
            continue
        ch = c_h.get(key)
        if ch is None:
            flag(key, "present", 1, 0, 1, "missing")
            continue
        for q in QUANTILES:
            if q not in bh or q not in ch:
                continue
            limit = bh[q] * (1.0 + t["latency_rel"]) + t["latency_abs_us"]
            if ch[q] > limit:
                flag(key, q, bh[q], ch[q], limit, "latency")

    b_c = base.get("counters", {})
    c_c = cur.get("counters", {})
    # union of keys: a bad-event counter absent from a snapshot is 0
    # (never incremented), so a new-in-cur violation still trips the
    # zero-to-nonzero rule — but coverage loss (in base, gone in cur)
    # is only a regression when the baseline actually saw events
    for key in sorted(set(b_c) | set(c_c)):
        if not _is_bad_counter(key):
            continue
        bv = b_c.get(key, 0)
        cv = c_c.get(key)
        if cv is None:
            if bv > 0:
                flag(key, "present", 1, 0, 1, "missing")
            continue
        if bv == 0:
            if cv > 0:
                flag(key, "total", bv, cv, 0, "zero_to_nonzero")
            continue
        limit = bv * (1.0 + t["count_rel"]) + t["count_abs"]
        if cv > limit:
            flag(key, "total", bv, cv, limit, "count")

    b_g = base.get("gauges", {})
    c_g = cur.get("gauges", {})
    if "cache_hit_ratio" in b_g:
        cv = c_g.get("cache_hit_ratio")
        limit = b_g["cache_hit_ratio"] - t["hit_ratio_drop"]
        if cv is None:
            flag("cache_hit_ratio", "present", 1, 0, 1, "missing")
        elif cv < limit:
            flag("cache_hit_ratio", "value", b_g["cache_hit_ratio"], cv,
                 limit, "hit_ratio")
    return out


def inject_regression(snap: dict) -> dict:
    """A tampered copy of ``snap`` that any sound gate must flag: doubled
    service-latency quantiles plus invented budget violations."""
    bad = copy.deepcopy(snap)
    h = bad.get("histograms", {}).get("service_latency_us")
    if h:
        for q in QUANTILES:
            if q in h:
                h[q] *= 2.0
    c = bad.setdefault("counters", {})
    c["budget_violations"] = c.get("budget_violations", 0) + 5
    return bad


def format_findings(findings: list) -> str:
    lines = [f"{len(findings)} regression(s):"]
    for f in findings:
        lines.append(f"  {f['metric']} {f['field']}: {f['base']:g} -> "
                     f"{f['cur']:g} (limit {f['limit']:g}, "
                     f"rule={f['rule']})")
    return "\n".join(lines)


def _load_snapshot(path: str) -> dict:
    """A snapshot file: either a raw ``snapshot()`` dict or a bench
    payload wrapping one under ``"snapshot"``."""
    with open(path) as f:
        d = json.load(f)
    return d.get("snapshot", d) if isinstance(d, dict) else d


def _gate_system(q_batch, n_docs, seed, max_batch):
    """A small fitted telemetry-on system + its query trace, built the
    same way ``bench_online`` builds its cascade (jnp backend, frozen
    thresholds) so the snapshot is deterministic for a given config."""
    from repro.configs.cascade_presets import get_preset
    from repro.index.corpus import CorpusParams, build_corpus, build_queries
    from repro.serving.spec import BackendSpec, TelemetrySpec
    from repro.serving.system import build_system

    corpus = build_corpus(CorpusParams(n_docs=n_docs,
                                       vocab=max(n_docs // 2, 1024),
                                       avg_doclen=96, zipf_a=1.05,
                                       seed=seed))
    base = dataclasses.replace(get_preset("paper_200ms"),
                               backend=BackendSpec(backend="jnp"))
    base = dataclasses.replace(
        base, online=dataclasses.replace(base.online, max_batch=max_batch))
    ql = build_queries(corpus, q_batch, stop_k=base.index.stop_k,
                       seed=seed + 4)
    fit_sys = build_system(base, corpus)
    fit_sys.fit(ql, None, seed=seed)
    base = dataclasses.replace(
        base, routing=dataclasses.replace(
            base.routing, t_k=fit_sys._base_cfg.t_k,
            t_time=fit_sys._base_cfg.t_time, calibrate=False,
            adapt_every=0),
        telemetry=TelemetrySpec(enabled=True))
    system = build_system(base, fit_sys.index, corpus=corpus,
                          models=fit_sys.models, ltr=fit_sys.ltr,
                          cost=fit_sys.cost)
    return system, ql, fit_sys


def run_gate(q_batch: int = 256, n_docs: int = 4096, seed: int = 7,
             max_batch: int = 8, load: float = 0.7,
             baseline: str | None = None,
             write_baseline: bool = False) -> dict:
    from repro.serving.online import estimate_capacity
    from repro.serving.spec import TrafficSpec

    if baseline is None:
        baseline = os.path.join(RESULTS, "BENCH_obs_baseline.json")
    system, ql, fit_sys = _gate_system(q_batch, n_docs, seed, max_batch)
    capacity = estimate_capacity(fit_sys, ql.terms, ql.mask, ql.topic)

    # one offline batch + one online trace through the same instrumented
    # system: the snapshot covers both serving paths
    system.serve(ql.terms, ql.mask, ql.topic)
    traffic = TrafficSpec(arrival="bursty", qps=load * capacity,
                          seed=seed + 1)
    system.serve_online(ql.terms, ql.mask, ql.topic, traffic=traffic)
    snap = system.snapshot()
    snap_lean = {k: v for k, v in snap.items() if k != "traces"}

    # the gate must have teeth before it is trusted with a verdict
    self_clean = not diff_snapshots(snap, snap)
    injected = diff_snapshots(snap, inject_regression(snap))
    rules_hit = {f["rule"] for f in injected}
    self_flags = bool(injected) and {"latency",
                                     "zero_to_nonzero"} <= rules_hit

    baseline_present = os.path.exists(baseline)
    findings = (diff_snapshots(_load_snapshot(baseline), snap)
                if baseline_present else [])

    config = {"q_batch": q_batch, "n_docs": n_docs, "seed": seed,
              "max_batch": max_batch, "load": load, "backend": "jnp",
              "tolerances": DEFAULT_TOL}
    if write_baseline:
        base_payload = bench_payload("obs_baseline", config=config,
                                     extra={"snapshot": snap_lean})
        base_payload["artifact"] = write_bench_artifact("obs_baseline",
                                                        base_payload)
        baseline_present, findings = True, []

    payload = bench_payload(
        "obs", config=config,
        gates={
            "self_check_clean": self_clean,
            "self_check_flags_regression": self_flags,
            "baseline_present": baseline_present,
            "no_regressions_vs_baseline": not findings,
        },
        extra={"snapshot": snap_lean, "findings": findings,
               "baseline": baseline,
               "capacity_qps": float(capacity),
               "traces_kept": len(snap.get("traces", []))})
    payload["artifact"] = write_bench_artifact("obs", payload)
    return payload


def render_gate(res: dict) -> str:
    g = res["gates"]
    snap = res["snapshot"]
    svc = snap["histograms"].get("service_latency_us", {})
    resp = snap["histograms"].get("response_latency_us", {})
    lines = [
        "gates: " + " ".join(f"{k}={'PASS' if v else 'FAIL'}"
                             for k, v in sorted(g.items())),
        f"service p50={svc.get('p50', 0):.0f} p99={svc.get('p99', 0):.0f} "
        f"p99.99={svc.get('p99.99', 0):.0f} us "
        f"(n={svc.get('count', 0)}); response "
        f"p99.99={resp.get('p99.99', 0):.0f} us "
        f"(n={resp.get('count', 0)})",
        f"baseline: {res['baseline']}"
        + ("" if g["baseline_present"] else " (absent — diff skipped)"),
    ]
    if res["findings"]:
        lines.append(format_findings(res["findings"]))
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("files", nargs="*",
                    help="BASE.json CUR.json for a pure snapshot diff")
    ap.add_argument("--gate", action="store_true",
                    help="serve the deterministic gate trace and diff "
                         "against the committed baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="with --gate: (re)write the committed baseline "
                         "from this run")
    ap.add_argument("--rel-tol", type=float, default=None,
                    help="override latency/count relative tolerance")
    args = ap.parse_args()

    if args.gate or args.write_baseline:
        res = run_gate(write_baseline=args.write_baseline)
        print(render_gate(res))
        print(f"artifact: {res['artifact']}")
        ok = all(res["gates"].values())
        return 0 if ok else 1

    if len(args.files) != 2:
        ap.error("need BASE.json CUR.json (or --gate)")
    tol = None
    if args.rel_tol is not None:
        tol = {"latency_rel": args.rel_tol, "count_rel": args.rel_tol}
    findings = diff_snapshots(_load_snapshot(args.files[0]),
                              _load_snapshot(args.files[1]), tol)
    if findings:
        print(format_findings(findings))
        return 1
    print("no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
