"""Benchmark entry point — one section per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--queries N] [--quick]``

Prints ``name,us_per_call,derived``-style CSV blocks per table and writes
the raw results to results/BENCH_*.json for EXPERIMENTS.md.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _section(title):
    print(f"\n=== {title} " + "=" * max(0, 60 - len(title)), flush=True)


def _save(name, res):
    from benchmarks.common import write_bench_artifact
    write_bench_artifact(name, res)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int,
                    default=int(os.environ.get("REPRO_QUERIES", "31642")))
    ap.add_argument("--quick", action="store_true",
                    help="small trace for smoke runs")
    ap.add_argument("--skip-roofline", action="store_true")
    args, _ = ap.parse_known_args()
    if args.quick:
        args.queries = 2000

    from benchmarks import (bench_cache, bench_dense, bench_engines,
                            bench_faults, bench_heldout, bench_hybrid,
                            bench_ingest, bench_kernels, bench_online,
                            bench_predict_k, bench_predict_rho,
                            bench_predict_time, bench_system, bench_tail,
                            bench_tail_overlap, obs_diff)
    from benchmarks.common import load_experiment

    t0 = time.time()
    _section("Kernel micro-benchmarks (name,us_per_call,derived)")
    kr = bench_kernels.run()
    print(bench_kernels.render(kr))
    _save("kernels", {"rows": [list(r) for r in kr["rows"]]})

    _section("Serving throughput (batched kernel pipeline vs lax.map)")
    from repro.isn.backend import resolve_backend
    sr = bench_engines.run_serving(backend=resolve_backend(None))
    print(bench_engines.render_serving(sr))
    print(f"artifact: {sr['artifact']}")

    _section("Cascade throughput (batched pipeline vs per-query loop)")
    cr = bench_hybrid.run_cascade()
    print(bench_hybrid.render_cascade(cr))
    print(f"artifact: {cr['artifact']}")

    _section("Multi-shard scaling (SearchSystem scatter-gather, Q=64)")
    ms = bench_system.run_system()
    print(bench_system.render_system(ms))
    print(f"artifact: {ms['artifact']}")

    _section("Tail guarantee (budget enforcement vs seed scheduler)")
    tl = bench_tail.run_tail()
    print(bench_tail.render_tail(tl))
    print(f"artifact: {tl['artifact']}")
    if not tl["guarantee_holds"]:
        raise RuntimeError("tail guarantee regressed: "
                           f"{tl['enforced']['over_budget']} queries over "
                           "budget with enforcement on")
    if not tl["regression_demonstrated"]:
        raise RuntimeError("tail benchmark lost its teeth: the seed "
                           "scheduler leaked no violations on this trace "
                           "(check the budget-percentile selection)")

    _section("Online response-time guarantee (micro-batching + admission)")
    ol = bench_online.run_online()
    print(bench_online.render_online(ol))
    print(f"artifact: {ol['artifact']}")
    if not ol["guarantee_holds"]:
        raise RuntimeError("online response-time guarantee regressed: a "
                           "served query exceeded the response budget "
                           "with admission control on")
    if not ol["regression_demonstrated"]:
        raise RuntimeError("online benchmark lost its teeth: the "
                           "no-admission/batch=1 baseline leaked no "
                           "violations at <= 0.8x capacity")

    _section("Result cache (hit parity, inertness, certified capacity)")
    ch = bench_cache.run_cache()
    print(bench_cache.render_cache(ch))
    print(f"artifact: {ch['artifact']}")
    if not ch["gates"]["hits_bit_identical"]:
        raise RuntimeError("cache hit parity regressed: a warm L1 hit (or "
                           "a cold cache-on serve) diverged from the "
                           "cache-off recompute")
    if not ch["gates"]["inert_bit_identical"]:
        raise RuntimeError("cache machinery is not inert: a zero-capacity "
                           "CacheSpec perturbed cache-free serving")
    if not ch["gates"]["guarantee_holds"]:
        raise RuntimeError("response-time guarantee regressed with the "
                           "cache attached: a served query exceeded the "
                           "response budget")
    if not ch["gates"]["capacity_speedup"]:
        raise RuntimeError("cache capacity claim regressed: certified "
                           "sustainable QPS at the hot skew is below 1.2x "
                           "the cache-off certified capacity")
    if not ch["gates"]["hits_nonvacuous"]:
        raise RuntimeError("cache benchmark lost its teeth: the hot-skew "
                           "trace produced almost no L1 hits")

    _section("Live ingest (post-merge parity, delta accounting, "
             "backpressure)")
    ig = bench_ingest.run_ingest()
    print(bench_ingest.render_ingest(ig))
    print(f"artifact: {ig['artifact']}")
    if not ig["gates"]["post_merge_bit_parity"]:
        raise RuntimeError("merge parity regressed: the post-merge index "
                           "or results diverged from a from-scratch "
                           "rebuild over the extended collection")
    if not ig["gates"]["worst_case_covers_delta"]:
        raise RuntimeError("delta accounting regressed: worst_case_us no "
                           "longer covers the capacity-sized live "
                           "delta-scan term")
    if not ig["gates"]["inert_bit_identical"]:
        raise RuntimeError("ingest machinery is not inert: a disabled "
                           "IngestSpec perturbed mutation-free serving")
    if not ig["gates"]["zero_violations"]:
        raise RuntimeError("response-time guarantee regressed under "
                           "mutation: a served query exceeded the "
                           "response budget while the feed was landing")
    if not ig["gates"]["ingest_nonvacuous"]:
        raise RuntimeError("ingest benchmark lost its teeth: no feed "
                           "batch was applied or no live doc ever "
                           "surfaced in a candidate list")

    _section("Dense retrieval + hybrid fusion (parity, speedup, routes)")
    dn = bench_dense.run_dense()
    print(bench_dense.render_dense(dn))
    print(f"artifact: {dn['artifact']}")
    if not dn["gates"]["kernel_engine_parity"]:
        raise RuntimeError("dense parity regressed: a kernel backend or "
                           "the sharded engine diverged from the numpy "
                           "oracle")
    if not dn["gates"]["batched_speedup"]:
        raise RuntimeError("dense batching claim regressed: the Q=64 "
                           "batched kernel call is below 3x the "
                           "per-query loop")
    if not dn["gates"]["route_guarantee"]:
        raise RuntimeError("dense route guarantee regressed: a route mix "
                           "produced a budget violation or exceeded the "
                           "worst-case bound")
    if not dn["gates"]["routes_nonvacuous"]:
        raise RuntimeError("dense benchmark lost its teeth: the mixed "
                           "dispatch or the theta bands carried no traffic")
    if not dn["gates"]["inert_bit_identical"]:
        raise RuntimeError("dense machinery is not inert: a disabled "
                           "DenseSpec perturbed dense-free serving")

    _section("Fault tolerance (crashes, stragglers, partition loss)")
    fl = bench_faults.run_faults()
    print(bench_faults.render_faults(fl))
    print(f"artifact: {fl['artifact']}")
    if not fl["guarantee_holds"]:
        raise RuntimeError("fault-tolerance guarantee regressed: a served "
                           "query exceeded the response budget under an "
                           "injected fault scenario")
    if not fl["coverage_certified"]:
        raise RuntimeError("degradation floor regressed: a served query "
                           "reported less coverage than the partitions the "
                           "fault schedule left reachable")
    if not (fl["inert_replay_identical"] and fl["inert_offline_identical"]):
        raise RuntimeError("fault machinery is not inert: an empty "
                           "FaultSpec perturbed fault-free serving")

    _section("Observability gate (telemetry snapshot vs baseline)")
    ob = obs_diff.run_gate()
    print(obs_diff.render_gate(ob))
    print(f"artifact: {ob['artifact']}")
    if not (ob["gates"]["self_check_clean"]
            and ob["gates"]["self_check_flags_regression"]):
        raise RuntimeError("observability gate lost its teeth: the "
                           "snapshot self-diff is dirty or an injected "
                           "regression went unflagged")
    if not ob["gates"]["no_regressions_vs_baseline"]:
        raise RuntimeError("observability gate regressed vs the committed "
                           "baseline:\n"
                           + obs_diff.format_findings(ob["findings"]))

    _section(f"Loading experiment ({args.queries} queries)")
    exp = load_experiment(args.queries)
    print(f"queries kept: {int(exp.labels.keep.sum())}/{args.queries} "
          f"(mismatch-filtered: {int((~exp.labels.keep).sum())})")

    _section("Fig 3: engine latency distributions")
    er = bench_engines.run(exp)
    print(bench_engines.render(er))
    # "engines" is the serving-throughput artifact written above — the
    # Fig-3 latency table gets its own name so neither clobbers the other
    _save("engine_latency", {"table": er["table"]})

    _section("Table 1: tail-latency query overlap")
    tr = bench_tail_overlap.run(er)
    print(bench_tail_overlap.render(tr))
    _save("tail_overlap", tr)

    _section("Fig 2+4: predicting k (oracle vs QR vs RF)")
    pk = bench_predict_k.run(exp)
    print(bench_predict_k.render(pk))
    _save("predict_k", pk)

    _section("Fig 5+6: predicting rho")
    pr = bench_predict_rho.run(exp)
    print(bench_predict_rho.render(pr))
    _save("predict_rho", pr)

    _section("Table 2: response-time prediction")
    pt = bench_predict_time.run(exp)
    print(bench_predict_time.render(pt))
    _save("predict_time", pt)

    _section("Fig 7 + Table 3: hybrid systems vs fixed baselines")
    hy = bench_hybrid.run(exp)
    print(bench_hybrid.render(hy))
    _save("hybrid", hy)

    _section("Table 4: held-out effectiveness + TOST")
    ho = bench_heldout.run(exp)
    print(bench_heldout.render(ho))
    _save("heldout", ho)

    if not args.skip_roofline and os.path.exists("results/dryrun.json"):
        _section("Roofline summary (from dry-run)")
        from benchmarks import roofline_report
        print(roofline_report.dominant_summary())

    print(f"\nall benchmarks done in {time.time()-t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
