"""Paper Fig. 3 — engine latency distributions across the query trace —
plus the serving-throughput study of the batched kernel-backed pipeline.

Fig. 3 systems: exhaustive BMW (θ=1.0), aggressive BMW (θ=1.2), exhaustive
JASS ("Jass_1b" analogue), heuristic JASS (ρ = 10% of collection,
"Jass_5m").

``run_serving`` measures wall-clock queries/sec of the batched
``daat_serve`` / ``saat_serve`` pipelines against their one-query-at-a-time
``lax.map`` baselines on a synthetic shard, verifies the top-k output is
identical, and emits the tracked ``results/BENCH_engines.json`` artifact
(queries/sec + p50/p99/p99.99 per engine) so the perf trajectory is
recorded from PR to PR.  Run standalone with
``PYTHONPATH=src:. python benchmarks/bench_engines.py``.
"""

from __future__ import annotations


import numpy as np

from benchmarks.common import Experiment, bench_payload, write_bench_artifact
from repro.isn import oracle
from repro.serving.latency import CostModel, percentiles


def run(exp: Experiment, k: int = 1000) -> dict:
    cost = CostModel.paper_scale()
    labels = exp.labels
    ql = exp.ql
    rows = exp.train_rows

    out = {}
    out["bmw_1.0"] = cost.daat_time(labels.work_bmw[rows],
                                    labels.blocks_bmw[rows])

    # aggressive BMW sweep (θ = 1.2)
    w12 = np.zeros(len(rows))
    b12 = np.zeros(len(rows))
    for lo in range(0, len(rows), 512):
        sub = rows[lo:lo + 512]
        _, w, b = oracle.bmw_scores(exp.index, ql.terms, ql.mask, sub,
                                    k=k, theta=1.2)
        w12[lo:lo + 512] = w
        b12[lo:lo + 512] = b
    out["bmw_1.2"] = cost.daat_time(w12, b12)

    out["jass_exh"] = cost.saat_time(labels.work_exhaustive[rows])
    rho_h = int(0.1 * exp.index.n_docs)      # the 10% heuristic
    wh = oracle.jass_work_only(exp.index, ql.terms[rows], ql.mask[rows],
                               rho_h)
    out["jass_heuristic"] = cost.saat_time(wh)

    table = {}
    for name, t in out.items():
        table[name] = percentiles(t)
    return {"times": out, "table": table, "rho_heuristic": rho_h}


def render(res) -> str:
    lines = ["system,mean,p50,p95,p99,p99.9,max"]
    for name, p in res["table"].items():
        lines.append(f"{name},{p['mean']:.1f},{p['p50']:.1f},{p['p95']:.1f},"
                     f"{p['p99']:.1f},{p['p99.9']:.1f},{p['max']:.1f}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# serving throughput: batched kernel-backed pipeline vs lax.map baseline
# ---------------------------------------------------------------------------

def _time_engine(fn, reps: int):
    """Wall-clock an engine call; returns per-batch seconds (first call is
    the untimed jit warmup)."""
    from benchmarks.common import timed
    return timed(fn, reps)


def _topk_identical(a, b) -> float:
    """Fraction of (query, rank) slots with identical doc ids."""
    return float(np.mean(np.asarray(a) == np.asarray(b)))


def run_serving(q_batch: int = 64, n_docs: int = 8192, reps: int = 25,
                k: int = 64, rho: int = 4096, seed: int = 5,
                backend: str = "jnp") -> dict:
    """Throughput study on a synthetic shard at batch size ``q_batch``.

    Engines: the batched pipeline (``backend`` — fused-jnp on CPU hosts,
    compiled Pallas on TPU) vs the ``lax.map`` + dense scatter-add + full
    top-k baseline, for both DAAT (θ=1.0) and SAAT (fixed ρ).  The batched
    pipeline must return the *same top-k* as the baseline — recorded per
    engine as ``topk_match``.
    """
    import jax.numpy as jnp
    from repro.index.builder import build_index
    from repro.index.corpus import CorpusParams, build_corpus, build_queries
    from repro.index.postings import shard_from_index
    from repro.isn.backend import query_lane_budget
    from repro.isn.daat import daat_serve, daat_serve_laxmap
    from repro.isn.saat import saat_serve, saat_serve_laxmap

    corpus = build_corpus(CorpusParams(n_docs=n_docs, vocab=max(n_docs // 2,
                                                                2048),
                                       avg_doclen=96, zipf_a=1.05,
                                       seed=seed))
    index = build_index(corpus, stop_k=16)
    ql = build_queries(corpus, q_batch, stop_k=16, seed=seed + 4)
    shard, spec = shard_from_index(index)
    terms = jnp.asarray(ql.terms)
    mask = jnp.asarray(ql.mask)
    theta = jnp.ones(q_batch, jnp.float32)
    rho_v = jnp.full(q_batch, rho, jnp.int32)

    daat_kw = dict(n_docs=spec.n_docs, n_blocks=spec.n_blocks,
                   block_size=spec.block_size, k=k, cap=spec.max_df,
                   bcap=spec.max_blocks_per_term)
    saat_kw = dict(n_docs=spec.n_docs, k=k, cap=rho)
    qcap = query_lane_budget(index.df, ql.terms, ql.mask)
    engines = {
        "daat_batched": lambda: daat_serve(shard, terms, mask, theta,
                                           tile_d=spec.tile_d,
                                           q_block=q_batch, qcap=qcap,
                                           backend=backend, **daat_kw),
        "daat_laxmap": lambda: daat_serve_laxmap(shard, terms, mask, theta,
                                                 **daat_kw),
        "saat_batched": lambda: saat_serve(shard, terms, mask, rho_v,
                                           tile_d=spec.tile_d,
                                           q_block=q_batch,
                                           backend=backend, **saat_kw),
        "saat_laxmap": lambda: saat_serve_laxmap(shard, terms, mask, rho_v,
                                                 **saat_kw),
    }

    out = {}
    results = {}
    for name, fn in engines.items():
        results[name] = fn()
        t = _time_engine(fn, reps)
        per_query_us = t / q_batch * 1e6
        out[name] = {
            "qps": float(q_batch / t.mean()),
            "batch_ms": float(t.mean() * 1e3),
            "p50_us": float(np.percentile(per_query_us, 50)),
            "p99_us": float(np.percentile(per_query_us, 99)),
            "p99.99_us": float(np.percentile(per_query_us, 99.99)),
        }

    for eng in ("daat", "saat"):
        match = _topk_identical(results[f"{eng}_batched"].topk_docs,
                                results[f"{eng}_laxmap"].topk_docs)
        speedup = out[f"{eng}_batched"]["qps"] / out[f"{eng}_laxmap"]["qps"]
        out[f"{eng}_batched"]["topk_match"] = match
        out[f"{eng}_batched"]["speedup_vs_laxmap"] = float(speedup)
        # SAAT accumulates integers (bit-exact across backends); DAAT sums
        # floats, where summation-order ties could in principle flip a rank
        floor = 1.0 if eng == "saat" else 0.999
        if match < floor:
            raise RuntimeError(
                f"{eng}_batched top-k diverged from the lax.map reference "
                f"(match={match:.4f} < {floor}); the batched pipeline must "
                f"reproduce the baseline — see tests/test_serving_pipeline.py")

    payload = bench_payload(
        "engines",
        config={"q_batch": q_batch, "n_docs": n_docs, "k": k, "rho": rho,
                "reps": reps, "backend": backend, "qcap": qcap,
                "tile_d": spec.tile_d, "tile_cap": spec.tile_cap},
        extra={"engines": out})
    payload["artifact"] = write_bench_artifact("engines", payload)
    return payload


def render_serving(res) -> str:
    lines = ["engine,qps,batch_ms,p50_us,p99_us,p99.99_us,speedup,topk_match"]
    for name, e in res["engines"].items():
        lines.append(
            f"{name},{e['qps']:.1f},{e['batch_ms']:.2f},{e['p50_us']:.1f},"
            f"{e['p99_us']:.1f},{e['p99.99_us']:.1f},"
            f"{e.get('speedup_vs_laxmap', 1.0):.2f},"
            f"{e.get('topk_match', 1.0):.4f}")
    return "\n".join(lines)


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--q-batch", type=int, default=64)
    ap.add_argument("--n-docs", type=int, default=8192)
    ap.add_argument("--reps", type=int, default=25)
    ap.add_argument("--backend", default=None,
                    help="pallas | interpret | jnp (default: auto)")
    args = ap.parse_args()
    from repro.isn.backend import resolve_backend
    res = run_serving(q_batch=args.q_batch, n_docs=args.n_docs,
                      reps=args.reps,
                      backend=resolve_backend(args.backend))
    print(render_serving(res))
    print(f"artifact: {res['artifact']}")


if __name__ == "__main__":
    main()
