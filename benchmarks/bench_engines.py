"""Paper Fig. 3 — engine latency distributions across the query trace.

Systems: exhaustive BMW (θ=1.0), aggressive BMW (θ=1.2), exhaustive JASS
("Jass_1b" analogue), heuristic JASS (ρ = 10% of collection, "Jass_5m").
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import Experiment
from repro.isn import oracle
from repro.serving.latency import CostModel, percentiles


def run(exp: Experiment, k: int = 1000) -> dict:
    cost = CostModel.paper_scale()
    labels = exp.labels
    ql = exp.ql
    rows = exp.train_rows

    out = {}
    out["bmw_1.0"] = cost.daat_time(labels.work_bmw[rows],
                                    labels.blocks_bmw[rows])

    # aggressive BMW sweep (θ = 1.2)
    w12 = np.zeros(len(rows))
    b12 = np.zeros(len(rows))
    for lo in range(0, len(rows), 512):
        sub = rows[lo:lo + 512]
        _, w, b = oracle.bmw_scores(exp.index, ql.terms, ql.mask, sub,
                                    k=k, theta=1.2)
        w12[lo:lo + 512] = w
        b12[lo:lo + 512] = b
    out["bmw_1.2"] = cost.daat_time(w12, b12)

    out["jass_exh"] = cost.saat_time(labels.work_exhaustive[rows])
    rho_h = int(0.1 * exp.index.n_docs)      # the 10% heuristic
    wh = oracle.jass_work_only(exp.index, ql.terms[rows], ql.mask[rows],
                               rho_h)
    out["jass_heuristic"] = cost.saat_time(wh)

    table = {}
    for name, t in out.items():
        table[name] = percentiles(t)
    return {"times": out, "table": table, "rho_heuristic": rho_h}


def render(res) -> str:
    lines = ["system,mean,p50,p95,p99,p99.9,max"]
    for name, p in res["table"].items():
        lines.append(f"{name},{p['mean']:.1f},{p['p50']:.1f},{p['p95']:.1f},"
                     f"{p['p99']:.1f},{p['p99.9']:.1f},{p['max']:.1f}")
    return "\n".join(lines)
