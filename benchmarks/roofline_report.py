"""Render the §Dry-run / §Roofline tables from results/dryrun.json."""

from __future__ import annotations

import json
import os


def model_flops(rec) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) per device, where D
    is tokens per device per step (training); serving kinds use 2·N·D."""
    meta = rec.get("meta", {})
    n = meta.get("active_params") or meta.get("params")
    if not n:
        return 0.0
    toks = meta.get("tokens_per_step", 0)
    if not toks:
        return 0.0
    per_dev = toks / max(rec.get("n_chips", 1), 1)
    shape = rec["shape"]
    factor = 6.0 if shape.startswith("train") else 2.0
    return factor * n * per_dev


def render(path="results/dryrun.json") -> str:
    with open(path) as f:
        recs = json.load(f)
    lines = []
    header = ("| arch | shape | mesh | compile s | flops/dev | bytes/dev | "
              "coll B/dev | compute ms | memory ms | coll ms | dominant | "
              "useful/HLO flops |")
    lines.append(header)
    lines.append("|" + "---|" * 12)
    for r in recs:
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"FAIL: {r['error'][:60]} |" + " |" * 8)
            continue
        t = r["roofline"]
        mf = model_flops(r)
        ratio = mf / r["flops_per_device"] if r["flops_per_device"] else 0
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compile_s']:.1f} | {r['flops_per_device']:.3g} | "
            f"{r['bytes_per_device']:.3g} | "
            f"{r['collective_bytes_per_device']:.3g} | "
            f"{t['compute_s']*1e3:.2f} | {t['memory_s']*1e3:.2f} | "
            f"{t['collective_s']*1e3:.2f} | {r['dominant'].replace('_s','')} |"
            f" {ratio:.2f} |")
    return "\n".join(lines)


def dominant_summary(path="results/dryrun.json") -> str:
    with open(path) as f:
        recs = json.load(f)
    ok = [r for r in recs if "error" not in r]
    out = [f"{len(ok)}/{len(recs)} cells compiled"]
    from collections import Counter
    doms = Counter(r["dominant"] for r in ok)
    out.append(f"dominant terms: {dict(doms)}")
    worst = sorted(
        (r for r in ok if r["mesh"] == "16x16" and r["shape"].startswith("train")),
        key=lambda r: (r["roofline"]["compute_s"]
                       / max(sum(r["roofline"].values()), 1e-12)))[:3]
    out.append("worst compute fraction (train cells): "
               + ", ".join(f"{r['arch']}×{r['shape']}" for r in worst))
    return "\n".join(out)


if __name__ == "__main__":
    print(render())
    print(dominant_summary())
