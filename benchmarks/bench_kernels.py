"""Kernel micro-benchmarks: wall time of the jnp reference path on this host
(the Pallas kernels themselves are TPU-targeted; interpret mode measures
Python, not hardware) plus the v5e roofline-derived time per call."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def run() -> dict:
    rng = np.random.RandomState(0)
    rows = []

    # impact_accumulate ref: rho=32k postings into a 196k accumulator
    from repro.kernels.impact_accumulate.ref import impact_accumulate_ref
    p, n = 32768, 196608
    docs = jnp.asarray(rng.randint(0, n, p), jnp.int32)
    imps = jnp.asarray(rng.randint(1, 256, p), jnp.int32)
    f = jax.jit(lambda d, i: impact_accumulate_ref(d, i, jnp.int32(0), n))
    us = _time(f, docs, imps)
    v5e = max(p * 8 / HBM_BW, p * 128 * 2 * 8 / PEAK_FLOPS) * 1e6
    rows.append(("impact_accumulate", us, f"v5e_est_us={v5e:.2f}"))

    # batched bucketed-mirror accumulate (the serving pipeline's hot loop):
    # jnp-equivalent math over a (T, CAP) bucketed layout at Q=16, plus the
    # v5e roofline estimate of the compiled (Q, T) Pallas grid
    q_b, n_tiles, cap_b, tile_d, L = 16, 16, 1024, 128, 8
    t_docs = jnp.asarray(rng.randint(-1, tile_d, (n_tiles, cap_b)), jnp.int32)
    t_terms = jnp.asarray(rng.randint(0, 512, (n_tiles, cap_b)), jnp.int32)
    t_imps = jnp.asarray(rng.randint(1, 256, (n_tiles, cap_b)), jnp.int32)
    qterms = jnp.asarray(rng.randint(0, 512, (q_b, L)), jnp.int32)

    def batched_ref(td, tt, ti, qt):
        match = jnp.any(tt[None, :, :, None] == qt[:, None, None, :], axis=-1)
        live = match & (td[None] >= 0)
        v = jnp.where(live, ti[None], 0)
        oh = (jnp.where(live, td[None], -1)[..., None]
              == jnp.arange(tile_d)[None, None, None]).astype(jnp.float32)
        return jnp.einsum("qtc,qtcd->qtd", v.astype(jnp.float32), oh)

    f = jax.jit(batched_ref)
    us = _time(f, t_docs, t_terms, t_imps, qterms)
    post = q_b * n_tiles * cap_b
    v5e = max(n_tiles * cap_b * 8 / HBM_BW,           # buckets read once/batch
              post * tile_d * 2 / PEAK_FLOPS) * 1e6
    rows.append(("impact_accumulate_batched", us, f"v5e_est_us={v5e:.2f}"))

    # flash attention ref at a train tile
    from repro.kernels.flash_attention.ref import attention_ref
    q = jnp.asarray(rng.randn(1, 4, 1024, 128), jnp.float32) * 0.3
    k = jnp.asarray(rng.randn(1, 1, 1024, 128), jnp.float32) * 0.3
    v = jnp.asarray(rng.randn(1, 1, 1024, 128), jnp.float32)
    f = jax.jit(lambda q, k, v: attention_ref(q, k, v, True))
    us = _time(f, q, k, v)
    fl = 4 * 1 * 4 * 1024 * 1024 * 128 / 2
    rows.append(("flash_attention", us,
                 f"v5e_est_us={fl / PEAK_FLOPS * 1e6:.2f}"))

    # histogram topk vs lax.top_k over a shard accumulator
    from repro.kernels.score_histogram.ref import score_histogram_ref
    sc = jnp.asarray(rng.randint(0, 2040, 196608), jnp.int32)
    f = jax.jit(lambda s: score_histogram_ref(s, 2048))
    us_h = _time(f, sc)
    g = jax.jit(lambda s: jax.lax.top_k(s, 1024))
    us_t = _time(g, sc)
    rows.append(("score_histogram", us_h, f"lax_topk_us={us_t:.1f}"))

    return {"rows": rows}


def render(res) -> str:
    lines = ["name,us_per_call,derived"]
    for name, us, derived in res["rows"]:
        lines.append(f"{name},{us:.1f},{derived}")
    return "\n".join(lines)
