"""Multi-shard scaling study of the spec-built ``SearchSystem``.

Serves the same Q-query batch through the full cascade at ``n_shards`` ∈
{1, 3} (same trained models, jnp backend by default) and records

* **output parity** — the merged scatter-gather top-k (and final top-t)
  must match the single-shard run exactly on the jnp backend (DAAT is
  rank-safe per shard; SAAT resolves a *global* impact-level cut; the
  merge tie-break is lower global doc id — see ``repro.serving.system``);
* **modeled Stage-1 tail** — per-query latency is the max over shards
  (scatter-gather), so sharding shrinks the tail even though total work is
  unchanged: the paper's tail story at deployment scale;
* wall-clock batch time per configuration and replica-pool health.

Emits ``results/BENCH_system.json``.  Run standalone with
``PYTHONPATH=src:. python benchmarks/bench_system.py``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import bench_payload, write_bench_artifact


def run_system(q_batch: int = 64, n_docs: int = 8192,
               shards: tuple = (1, 3), reps: int = 5, seed: int = 7,
               backend: str | None = None) -> dict:
    from repro.configs.cascade_presets import get_preset
    from repro.index.corpus import CorpusParams, build_corpus, build_queries
    from repro.isn.backend import resolve_backend
    from repro.serving.spec import BackendSpec, DeploySpec
    from repro.serving.system import build_system

    corpus = build_corpus(CorpusParams(n_docs=n_docs,
                                       vocab=max(n_docs // 2, 2048),
                                       avg_doclen=96, zipf_a=1.05,
                                       seed=seed))
    base = dataclasses.replace(get_preset("paper_200ms"),
                               backend=BackendSpec(backend=backend))
    ql = build_queries(corpus, q_batch, stop_k=base.index.stop_k,
                       seed=seed + 4)

    systems = {}
    models = ltr = index = None
    for n in shards:
        spec = dataclasses.replace(
            base, deploy=dataclasses.replace(base.deploy, n_shards=n))
        sys_n = build_system(spec, index if index is not None else corpus,
                             corpus=corpus, models=models, ltr=ltr)
        index = sys_n.index                      # build the index only once
        if models is None:
            sys_n.fit(ql, None, seed=seed)      # pseudo-labels: see fit()
            models, ltr = sys_n.models, sys_n.ltr
            # every configuration routes with the SAME calibrated
            # thresholds, so the comparison isolates the deployment shape
            base = dataclasses.replace(
                base, routing=dataclasses.replace(
                    base.routing, t_k=sys_n._base_cfg.t_k,
                    t_time=sys_n._base_cfg.t_time, calibrate=False))
            spec = dataclasses.replace(
                base, deploy=dataclasses.replace(base.deploy, n_shards=n))
            sys_n = build_system(spec, index, corpus=corpus, models=models,
                                 ltr=ltr)
        systems[n] = sys_n

    exact = resolve_backend(backend) == "jnp"
    results = {}
    ref = None
    for n, sys_n in systems.items():
        res = sys_n.serve(ql.terms, ql.mask, ql.topic)
        if ref is None:
            ref = res
        elif exact:
            if not np.array_equal(res.topk, ref.topk):
                raise RuntimeError(
                    f"scatter-gather divergence: n_shards={n} top-k != "
                    f"single-shard top-k on the jnp backend")
            if not np.array_equal(res.final, ref.final):
                raise RuntimeError(
                    f"scatter-gather divergence: n_shards={n} final top-t "
                    f"!= single-shard run")
        from benchmarks.common import timed
        t = timed(lambda: sys_n.serve(ql.terms, ql.mask, ql.topic), reps,
                  warmup=0)   # the parity serve above already warmed jit
        s1 = res.stage_latency["stage1"]
        results[f"shards_{n}"] = {
            "n_shards": n,
            "wall_batch_ms": float(t.mean() * 1e3),
            "wall_qps": float(q_batch / t.mean()),
            "stage1_ms": {"p50": float(np.percentile(s1, 50)),
                          "p99": float(np.percentile(s1, 99)),
                          "max": float(s1.max())},
            "cascade_ms": {"p50": res.stats["p50"], "p99": res.stats["p99"],
                           "max": res.stats["max"]},
            "pool": sys_n.stats()["pool"],
        }

    n0, n1 = shards[0], shards[-1]
    payload = bench_payload(
        "system",
        config={"q_batch": q_batch, "n_docs": n_docs,
                "shards": list(shards), "reps": reps, "seed": seed,
                "backend": backend or "auto"},
        extra={
            "topk_identical_across_shards": bool(exact),
            "stage1_max_shrink": (
                results[f"shards_{n0}"]["stage1_ms"]["max"]
                / max(results[f"shards_{n1}"]["stage1_ms"]["max"], 1e-9)),
            **results,
        })
    payload["artifact"] = write_bench_artifact("system", payload)
    return payload


def render_system(res) -> str:
    lines = ["n_shards,wall_batch_ms,wall_qps,stage1_p50,stage1_p99,"
             "stage1_max,cascade_p99"]
    for key, r in res.items():
        if not key.startswith("shards_"):
            continue
        lines.append(f"{r['n_shards']},{r['wall_batch_ms']:.2f},"
                     f"{r['wall_qps']:.1f},{r['stage1_ms']['p50']:.2f},"
                     f"{r['stage1_ms']['p99']:.2f},"
                     f"{r['stage1_ms']['max']:.2f},"
                     f"{r['cascade_ms']['p99']:.2f}")
    lines.append(f"stage1 max-latency shrink {res['config']['shards'][0]}→"
                 f"{res['config']['shards'][-1]} shards: "
                 f"{res['stage1_max_shrink']:.2f}x "
                 f"(identical top-k: {res['topk_identical_across_shards']})")
    return "\n".join(lines)


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--q-batch", type=int, default=64)
    ap.add_argument("--n-docs", type=int, default=8192)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--shards", type=int, nargs="+", default=[1, 3])
    ap.add_argument("--backend", default=None,
                    help="pallas | interpret | jnp (default: auto)")
    args = ap.parse_args()
    res = run_system(q_batch=args.q_batch, n_docs=args.n_docs,
                     reps=args.reps, shards=tuple(args.shards),
                     backend=args.backend)
    print(render_system(res))
    print(f"artifact: {res['artifact']}")


if __name__ == "__main__":
    main()
