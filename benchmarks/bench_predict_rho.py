"""Paper Figs. 5 + 6 — predicting ρ: distribution vs the 10% heuristic and
the QR/RF comparison at matched effectiveness."""

from __future__ import annotations

import numpy as np

from benchmarks.common import Experiment, cv_predict


def _stats(v):
    return {"mean": float(np.mean(v)), "p50": float(np.median(v)),
            "p90": float(np.percentile(v, 90)),
            "p99": float(np.percentile(v, 99))}


def run(exp: Experiment, taus=(0.45, 0.55)) -> dict:
    rows = exp.train_rows
    orho = exp.labels.oracle_rho[rows]
    heuristic = int(0.1 * exp.index.n_docs)
    out = {"oracle": _stats(orho),
           "heuristic_10pct": {"mean": heuristic, "p50": heuristic,
                               "p90": heuristic, "p99": heuristic}}
    for tau in taus:
        pred = cv_predict(exp, "qr", "rho", tau=tau)[rows]
        out[f"qr_tau{tau:.2f}"] = _stats(np.clip(pred, 256, 1 << 20))
    pred_rf = cv_predict(exp, "rf", "rho")[rows]
    out["rf"] = _stats(np.clip(pred_rf, 256, 1 << 20))
    frac_below = float(np.mean(orho < heuristic))
    return {"systems": out, "frac_oracle_below_heuristic": frac_below}


def render(res) -> str:
    lines = ["system,mean_rho,median_rho,p90_rho,p99_rho"]
    for name, s in res["systems"].items():
        lines.append(f"{name},{s['mean']:.0f},{s['p50']:.0f},{s['p90']:.0f},"
                     f"{s['p99']:.0f}")
    lines.append(f"# oracle rho below 10%-heuristic for "
                 f"{100*res['frac_oracle_below_heuristic']:.1f}% of queries")
    return "\n".join(lines)
