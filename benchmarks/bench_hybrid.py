"""Paper Fig. 7 + Table 3 — the hybrid systems vs fixed baselines at matched
MED targets, including the 200 ms / 99.99 % budget claim.

Systems per MED target (0.05, 0.10):
  BMW_1.0       fixed k (calibrated so mean MED == target), exhaustive DAAT
  JASS_exh      fixed k, exhaustive SAAT ("Jass_1b")
  JASS_h        fixed k, heuristic ρ = 10 % collection ("Jass_5m")
  Hybrid_k      Algorithm 1 (predict k, ρ)
  Hybrid_h      Algorithm 2 (predict k, ρ, time)
  Oracle_k/h    routing on true labels (upper bound)
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import (Experiment, cv_predict, fixed_k_for_target,
                               med_at_k)
from repro.core import hybrid
from repro.core.reference import rbp_weights
from repro.isn import oracle
from repro.serving.latency import CostModel

BUDGET = 200.0


def _jass_med(exp, rows, k_arr, rho_arr, batch=512):
    """MED of JASS top-k lists vs the ideal reference (per query)."""
    w = np.asarray(rbp_weights(exp.labels.ref_lists.shape[1], 0.95))
    med = np.zeros(len(rows))
    for lo in range(0, len(rows), batch):
        sub = rows[lo:lo + batch]
        acc, _ = oracle.jass_scores(exp.index, exp.ql.terms, exp.ql.mask,
                                    sub, rho_arr[lo:lo + batch])
        kmax = int(k_arr[lo:lo + batch].max())
        ids, _ = oracle._topk_ids(acc, kmax)
        for i in range(len(sub)):
            kq = int(k_arr[lo + i])
            hit = np.isin(exp.labels.ref_lists[sub[i]], ids[i][:kq])
            med[lo + i] = w[~hit].sum()
    return med


def _bmw_time_at_k(exp, rows, k_arr, batch=512):
    cost = CostModel.paper_scale()
    t = np.zeros(len(rows))
    for lo in range(0, len(rows), batch):
        sub = rows[lo:lo + batch]
        _, wrk, blk = oracle.bmw_scores(exp.index, exp.ql.terms, exp.ql.mask,
                                        sub, k=k_arr[lo:lo + batch])
        t[lo:lo + batch] = cost.daat_time(wrk, blk)
    return t


def _summarize(k_arr, t_arr, med_arr, budget=BUDGET):
    over = t_arr > budget
    return {
        "mean_k": float(np.mean(k_arr)), "median_k": float(np.median(k_arr)),
        "mean_t": float(np.mean(t_arr)), "median_t": float(np.median(t_arr)),
        "pct_over": 100.0 * float(np.mean(over)),
        "n_over": int(np.sum(over)),
        "mean_med": float(np.mean(med_arr)),
    }


def run(exp: Experiment, targets=(0.05, 0.10)) -> dict:
    cost = CostModel.paper_scale()
    labels = exp.labels
    rows = exp.train_rows
    nq = len(rows)
    rho_h = int(0.1 * exp.index.n_docs)
    rho_max = int((BUDGET * 0.9 - cost.saat_fixed_us)
                  / cost.saat_per_posting_us)

    pred_k = np.clip(np.round(cv_predict(exp, "qr", "k", tau=0.55)[rows]),
                     10, 16384)
    pred_rho = np.clip(np.round(cv_predict(exp, "qr", "rho", tau=0.45)[rows]),
                       1024, rho_max)
    pred_t = cv_predict(exp, "qr", "t", tau=0.5)[rows]

    results = {"rho_max": rho_max}
    for target in targets:
        block = {}
        k_fix = fixed_k_for_target(labels, rows, target)

        # fixed BMW (rank-safe, exhaustive-style DAAT)
        t_bmw_fix = _bmw_time_at_k(exp, rows, np.full(nq, k_fix))
        med_fix = med_at_k(labels, rows, np.full(nq, k_fix))
        block[f"BMW_1.0(k={k_fix})"] = _summarize(
            np.full(nq, k_fix), t_bmw_fix, med_fix)

        # fixed exhaustive JASS
        t_jexh = cost.saat_time(labels.work_exhaustive[rows])
        med_jexh = _jass_med(exp, rows, np.full(nq, k_fix),
                             np.full(nq, 1 << 62))
        block[f"JASS_exh(k={k_fix})"] = _summarize(
            np.full(nq, k_fix), t_jexh, med_jexh)

        # fixed heuristic JASS — needs a (usually larger) k to hit the target
        k_h = k_fix
        med_h = _jass_med(exp, rows, np.full(nq, k_h), np.full(nq, rho_h))
        for _ in range(6):
            if med_h.mean() <= target or k_h >= 16384:
                break
            k_h = int(k_h * 1.5)
            med_h = _jass_med(exp, rows, np.full(nq, k_h), np.full(nq, rho_h))
        wh = oracle.jass_work_only(exp.index, exp.ql.terms[rows],
                                   exp.ql.mask[rows], rho_h)
        block[f"JASS_h(k={k_h})"] = _summarize(
            np.full(nq, k_h), cost.saat_time(wh), med_h)

        # hybrids: calibrate a global multiplier on the predicted k so mean
        # MED hits the target (the paper trains at eps=0.001 and relaxes to
        # the target band). First pass assumes rank-safe membership; a
        # refinement pass folds in the JASS-routed approximation loss.
        lo_a, hi_a = 0.01, 4.0
        for _ in range(24):
            mid = (lo_a + hi_a) / 2
            m = med_at_k(labels, rows,
                         np.clip(np.round(pred_k * mid), 10, 16384)).mean()
            if m <= target:
                hi_a = mid
            else:
                lo_a = mid
        alpha = hi_a
        for _ in range(2):   # fold in JASS truncation loss
            k_try = np.clip(np.round(pred_k * alpha), 10, 16384)
            hc0 = hybrid.HybridConfig(t_k=float(np.percentile(k_try, 60)),
                                      t_time_us=BUDGET * 0.75,
                                      rho_max=rho_max)
            r0 = hybrid.route_algorithm2(k_try, pred_t, hc0)
            jm = r0 == hybrid.ROUTE_JASS
            med0 = med_at_k(labels, rows, k_try)
            if jm.any():
                med0[jm] = _jass_med(exp, rows[jm], k_try[jm].astype(np.int64),
                                     pred_rho[jm])
            achieved = med0.mean()
            if achieved <= target * 1.05:
                break
            alpha = min(alpha * (achieved / target) ** 0.7, 4.0)
        k_hyb = np.clip(np.round(pred_k * alpha), 10, 16384)
        hc = hybrid.HybridConfig(t_k=float(np.percentile(k_hyb, 60)),
                                 t_time_us=BUDGET * 0.75, rho_max=rho_max)

        for name, routes in (
            ("Hybrid_k", hybrid.route_algorithm1(k_hyb, hc)),
            ("Hybrid_h", hybrid.route_algorithm2(k_hyb, pred_t, hc)),
            ("Oracle_h", hybrid.route_algorithm2(
                labels.oracle_k[rows], labels.t_bmw[rows], hc)),
        ):
            jass = routes == hybrid.ROUTE_JASS
            k_use = (labels.oracle_k[rows] if name.startswith("Oracle")
                     else k_hyb).astype(np.int64)
            rho_use = (np.clip(labels.oracle_rho[rows], 1024, rho_max)
                       if name.startswith("Oracle") else pred_rho)
            t = np.zeros(nq)
            med = np.zeros(nq)
            if jass.any():
                jw = oracle.jass_work_only(exp.index,
                                           exp.ql.terms[rows[jass]],
                                           exp.ql.mask[rows[jass]],
                                           rho_use[jass])
                t[jass] = cost.saat_time(jw)
                med[jass] = _jass_med(exp, rows[jass], k_use[jass],
                                      rho_use[jass])
            if (~jass).any():
                t[~jass] = _bmw_time_at_k(exp, rows[~jass], k_use[~jass])
                med[~jass] = med_at_k(labels, rows[~jass], k_use[~jass])
            t = t + cost.predict_us
            block[name] = _summarize(k_use, t, med)
            block[name]["routed_jass_pct"] = 100.0 * float(jass.mean())
        results[f"target_{target}"] = block
    return results


def render(res) -> str:
    lines = []
    for tkey, block in res.items():
        if not tkey.startswith("target_"):
            continue
        lines.append(f"# MED-RBP target = {tkey.split('_')[1]} "
                     f"(budget {BUDGET:.0f} ms, rho_max {res['rho_max']})")
        lines.append("system,mean_k,median_k,mean_t,median_t,pct_over,"
                     "n_over,mean_med,jass_pct")
        for name, s in block.items():
            lines.append(
                f"{name},{s['mean_k']:.0f},{s['median_k']:.0f},"
                f"{s['mean_t']:.1f},{s['median_t']:.1f},{s['pct_over']:.4f},"
                f"{s['n_over']},{s['mean_med']:.4f},"
                f"{s.get('routed_jass_pct', float('nan')):.1f}")
    return "\n".join(lines)
