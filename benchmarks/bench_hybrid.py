"""Paper Fig. 7 + Table 3 — the hybrid systems vs fixed baselines at matched
MED targets, including the 200 ms / 99.99 % budget claim — plus the
end-to-end cascade throughput study (``run_cascade``).

Systems per MED target (0.05, 0.10):
  BMW_1.0       fixed k (calibrated so mean MED == target), exhaustive DAAT
  JASS_exh      fixed k, exhaustive SAAT ("Jass_1b")
  JASS_h        fixed k, heuristic ρ = 10 % collection ("Jass_5m")
  Hybrid_k      Algorithm 1 (predict k, ρ)
  Hybrid_h      Algorithm 2 (predict k, ρ, time)
  Oracle_k/h    routing on true labels (upper bound)

``run_cascade`` wall-clocks the unified batched cascade (a single-shard
spec-built ``repro.serving.system.SearchSystem``) against the per-query
baseline (per-model Stage-0 numpy round trips, ``lax.map`` engines, the
``rerank_loop`` Stage-2 driver), verifies the final top-t lists are
bit-identical, and emits ``results/BENCH_cascade.json``.  Run standalone
with ``PYTHONPATH=src:. python benchmarks/bench_hybrid.py``.
"""

from __future__ import annotations

import os

import numpy as np

from benchmarks.common import (Experiment, bench_payload, cv_predict,
                               fixed_k_for_target, med_at_k,
                               write_bench_artifact)
from repro.core import hybrid
from repro.core.reference import rbp_weights
from repro.isn import oracle
from repro.serving.latency import CostModel

BUDGET = 200.0


def _jass_med(exp, rows, k_arr, rho_arr, batch=512):
    """MED of JASS top-k lists vs the ideal reference (per query)."""
    w = np.asarray(rbp_weights(exp.labels.ref_lists.shape[1], 0.95))
    med = np.zeros(len(rows))
    for lo in range(0, len(rows), batch):
        sub = rows[lo:lo + batch]
        acc, _ = oracle.jass_scores(exp.index, exp.ql.terms, exp.ql.mask,
                                    sub, rho_arr[lo:lo + batch])
        kmax = int(k_arr[lo:lo + batch].max())
        ids, _ = oracle._topk_ids(acc, kmax)
        for i in range(len(sub)):
            kq = int(k_arr[lo + i])
            hit = np.isin(exp.labels.ref_lists[sub[i]], ids[i][:kq])
            med[lo + i] = w[~hit].sum()
    return med


def _bmw_time_at_k(exp, rows, k_arr, batch=512):
    cost = CostModel.paper_scale()
    t = np.zeros(len(rows))
    for lo in range(0, len(rows), batch):
        sub = rows[lo:lo + batch]
        _, wrk, blk = oracle.bmw_scores(exp.index, exp.ql.terms, exp.ql.mask,
                                        sub, k=k_arr[lo:lo + batch])
        t[lo:lo + batch] = cost.daat_time(wrk, blk)
    return t


def _summarize(k_arr, t_arr, med_arr, budget=BUDGET):
    over = t_arr > budget
    return {
        "mean_k": float(np.mean(k_arr)), "median_k": float(np.median(k_arr)),
        "mean_t": float(np.mean(t_arr)), "median_t": float(np.median(t_arr)),
        "pct_over": 100.0 * float(np.mean(over)),
        "n_over": int(np.sum(over)),
        "mean_med": float(np.mean(med_arr)),
    }


def run(exp: Experiment, targets=(0.05, 0.10)) -> dict:
    cost = CostModel.paper_scale()
    labels = exp.labels
    rows = exp.train_rows
    nq = len(rows)
    rho_h = int(0.1 * exp.index.n_docs)
    rho_max = int((BUDGET * 0.9 - cost.saat_fixed_us)
                  / cost.saat_per_posting_us)

    pred_k = np.clip(np.round(cv_predict(exp, "qr", "k", tau=0.55)[rows]),
                     10, 16384)
    pred_rho = np.clip(np.round(cv_predict(exp, "qr", "rho", tau=0.45)[rows]),
                       1024, rho_max)
    pred_t = cv_predict(exp, "qr", "t", tau=0.5)[rows]

    results = {"rho_max": rho_max}
    for target in targets:
        block = {}
        k_fix = fixed_k_for_target(labels, rows, target)

        # fixed BMW (rank-safe, exhaustive-style DAAT)
        t_bmw_fix = _bmw_time_at_k(exp, rows, np.full(nq, k_fix))
        med_fix = med_at_k(labels, rows, np.full(nq, k_fix))
        block[f"BMW_1.0(k={k_fix})"] = _summarize(
            np.full(nq, k_fix), t_bmw_fix, med_fix)

        # fixed exhaustive JASS
        t_jexh = cost.saat_time(labels.work_exhaustive[rows])
        med_jexh = _jass_med(exp, rows, np.full(nq, k_fix),
                             np.full(nq, 1 << 62))
        block[f"JASS_exh(k={k_fix})"] = _summarize(
            np.full(nq, k_fix), t_jexh, med_jexh)

        # fixed heuristic JASS — needs a (usually larger) k to hit the target
        k_h = k_fix
        med_h = _jass_med(exp, rows, np.full(nq, k_h), np.full(nq, rho_h))
        for _ in range(6):
            if med_h.mean() <= target or k_h >= 16384:
                break
            k_h = int(k_h * 1.5)
            med_h = _jass_med(exp, rows, np.full(nq, k_h), np.full(nq, rho_h))
        wh = oracle.jass_work_only(exp.index, exp.ql.terms[rows],
                                   exp.ql.mask[rows], rho_h)
        block[f"JASS_h(k={k_h})"] = _summarize(
            np.full(nq, k_h), cost.saat_time(wh), med_h)

        # hybrids: calibrate a global multiplier on the predicted k so mean
        # MED hits the target (the paper trains at eps=0.001 and relaxes to
        # the target band). First pass assumes rank-safe membership; a
        # refinement pass folds in the JASS-routed approximation loss.
        lo_a, hi_a = 0.01, 4.0
        for _ in range(24):
            mid = (lo_a + hi_a) / 2
            m = med_at_k(labels, rows,
                         np.clip(np.round(pred_k * mid), 10, 16384)).mean()
            if m <= target:
                hi_a = mid
            else:
                lo_a = mid
        alpha = hi_a
        for _ in range(2):   # fold in JASS truncation loss
            k_try = np.clip(np.round(pred_k * alpha), 10, 16384)
            hc0 = hybrid.HybridConfig(t_k=float(np.percentile(k_try, 60)),
                                      t_time_us=BUDGET * 0.75,
                                      rho_max=rho_max)
            r0 = hybrid.route_algorithm2(k_try, pred_t, hc0)
            jm = r0 == hybrid.ROUTE_JASS
            med0 = med_at_k(labels, rows, k_try)
            if jm.any():
                med0[jm] = _jass_med(exp, rows[jm], k_try[jm].astype(np.int64),
                                     pred_rho[jm])
            achieved = med0.mean()
            if achieved <= target * 1.05:
                break
            alpha = min(alpha * (achieved / target) ** 0.7, 4.0)
        k_hyb = np.clip(np.round(pred_k * alpha), 10, 16384)
        hc = hybrid.HybridConfig(t_k=float(np.percentile(k_hyb, 60)),
                                 t_time_us=BUDGET * 0.75, rho_max=rho_max)

        for name, routes in (
            ("Hybrid_k", hybrid.route_algorithm1(k_hyb, hc)),
            ("Hybrid_h", hybrid.route_algorithm2(k_hyb, pred_t, hc)),
            ("Oracle_h", hybrid.route_algorithm2(
                labels.oracle_k[rows], labels.t_bmw[rows], hc)),
        ):
            jass = routes == hybrid.ROUTE_JASS
            k_use = (labels.oracle_k[rows] if name.startswith("Oracle")
                     else k_hyb).astype(np.int64)
            rho_use = (np.clip(labels.oracle_rho[rows], 1024, rho_max)
                       if name.startswith("Oracle") else pred_rho)
            t = np.zeros(nq)
            med = np.zeros(nq)
            if jass.any():
                jw = oracle.jass_work_only(exp.index,
                                           exp.ql.terms[rows[jass]],
                                           exp.ql.mask[rows[jass]],
                                           rho_use[jass])
                t[jass] = cost.saat_time(jw)
                med[jass] = _jass_med(exp, rows[jass], k_use[jass],
                                      rho_use[jass])
            if (~jass).any():
                t[~jass] = _bmw_time_at_k(exp, rows[~jass], k_use[~jass])
                med[~jass] = med_at_k(labels, rows[~jass], k_use[~jass])
            t = t + cost.predict_us
            block[name] = _summarize(k_use, t, med)
            block[name]["routed_jass_pct"] = 100.0 * float(jass.mean())
        results[f"target_{target}"] = block
    return results


def render(res) -> str:
    lines = []
    for tkey, block in res.items():
        if not tkey.startswith("target_"):
            continue
        lines.append(f"# MED-RBP target = {tkey.split('_')[1]} "
                     f"(budget {BUDGET:.0f} ms, rho_max {res['rho_max']})")
        lines.append("system,mean_k,median_k,mean_t,median_t,pct_over,"
                     "n_over,mean_med,jass_pct")
        for name, s in block.items():
            lines.append(
                f"{name},{s['mean_k']:.0f},{s['median_k']:.0f},"
                f"{s['mean_t']:.1f},{s['median_t']:.1f},{s['pct_over']:.4f},"
                f"{s['n_over']},{s['mean_med']:.4f},"
                f"{s.get('routed_jass_pct', float('nan')):.1f}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# end-to-end cascade throughput: batched pipeline vs per-query loop baseline
# ---------------------------------------------------------------------------

def _loop_cascade_baseline(index, corpus, ql, shard, spec, models, ltr,
                           cfg, cost, k_serve, t_final):
    """The pre-pipeline cascade: per-model Stage-0 numpy round trips,
    one-query-at-a-time ``lax.map`` engines, per-query ``rerank_loop``."""
    import jax.numpy as jnp

    from repro.core import features as F, gbrt
    from repro.isn.daat import daat_serve_laxmap
    from repro.isn.saat import saat_serve_laxmap
    from repro.ltr.cascade import rerank_loop
    from repro.serving.scheduler import StageZeroScheduler

    terms, mask = ql.terms, ql.mask
    q = terms.shape[0]
    x = np.asarray(F.extract(jnp.asarray(index.term_stats),
                             jnp.asarray(index.df),
                             jnp.asarray(terms), jnp.asarray(mask)))
    pk = np.expm1(np.asarray(gbrt.predict(models["k"], x)))
    pr = np.expm1(np.asarray(gbrt.predict(models["rho"], x)))
    pt = np.expm1(np.asarray(gbrt.predict(models["t"], x)))
    sched = StageZeroScheduler(cfg, cost)
    routed = sched.route(pk, pr, pt)

    topk = np.zeros((q, k_serve), np.int64)
    if len(routed.jass_rows):
        rows = routed.jass_rows
        res = saat_serve_laxmap(shard, jnp.asarray(terms[rows]),
                                jnp.asarray(mask[rows]),
                                jnp.asarray(routed.rho[rows]),
                                n_docs=spec.n_docs, k=k_serve,
                                cap=int(cfg.rho_max))
        topk[rows] = np.asarray(res.topk_docs)
    if len(routed.bmw_rows):
        rows = routed.bmw_rows
        res = daat_serve_laxmap(shard, jnp.asarray(terms[rows]),
                                jnp.asarray(mask[rows]),
                                jnp.ones(len(rows), jnp.float32),
                                n_docs=spec.n_docs, n_blocks=spec.n_blocks,
                                block_size=spec.block_size, k=k_serve,
                                cap=spec.max_df,
                                bcap=spec.max_blocks_per_term)
        topk[rows] = np.asarray(res.topk_docs)

    k2 = np.minimum(routed.k, k_serve)
    res2 = rerank_loop(index, corpus, ql, np.arange(q), topk, k2, ltr,
                       t_final=t_final)
    return topk, res2.final, res2.candidates_used


def run_cascade(q_batch: int = 64, n_docs: int = 8192, reps: int = 10,
                k_serve: int = 128, t_final: int = 10,
                seed: int = 5, backend: str | None = None) -> dict:
    """End-to-end cascade throughput at batch size ``q_batch``.

    Both systems run the full Stage-0 → routing → Stage-1 → Stage-2 chain;
    the final top-t lists must be **bit-identical** (the batched Stage-2 on
    the jnp backend reproduces the numpy loop exactly) — any divergence
    raises.
    """
    from repro.core import features as F, gbrt
    from repro.index.builder import build_index
    from repro.index.corpus import CorpusParams, build_corpus, build_queries
    from repro.ltr.ranker import qd_features, train_ltr
    from repro.serving.scheduler import SchedulerConfig
    from repro.serving.spec import (BackendSpec, CascadeSpec, DeploySpec,
                                    Stage2Spec)
    from repro.serving.system import build_system, routing_spec
    import jax.numpy as jnp

    rng = np.random.RandomState(seed)
    corpus = build_corpus(CorpusParams(n_docs=n_docs,
                                       vocab=max(n_docs // 2, 2048),
                                       avg_doclen=96, zipf_a=1.05,
                                       seed=seed))
    index = build_index(corpus, stop_k=16)
    ql = build_queries(corpus, q_batch, stop_k=16, seed=seed + 4)

    # Stage-0 predictors from cheap pseudo-labels (routing only needs
    # plausible heavy-tailed targets; label oracles are benchmarked
    # elsewhere) + a Stage-2 LTR model on topical-affinity gains.
    x = np.asarray(F.extract(jnp.asarray(index.term_stats),
                             jnp.asarray(index.df),
                             jnp.asarray(ql.terms), jnp.asarray(ql.mask)))
    eff = (index.df[ql.terms] * (ql.mask > 0)).sum(axis=1).astype(np.float64)
    models = {}
    for name, scale, tau in (("k", 0.05, 0.55), ("rho", 0.5, 0.45),
                             ("t", 0.002, 0.5)):
        y = eff * scale * np.exp(rng.randn(q_batch) * 0.3)
        models[name] = gbrt.fit(x, np.log1p(y.astype(np.float32)),
                                gbrt.GBRTParams(n_trees=48, depth=5,
                                                loss="quantile", tau=tau))
    feats = []
    for q in range(min(q_batch, 32)):
        docs = rng.randint(0, n_docs, 64).astype(np.int64)
        feats.append(qd_features(index, corpus, ql.terms[q], ql.mask[q],
                                 ql.topic[q], docs))
    feats = np.concatenate(feats)
    gains = (feats[:, 5] + 0.2 * feats[:, 1]).astype(np.float32)
    ltr = train_ltr(feats, gains)

    cost = CostModel.paper_scale()
    pk0 = np.expm1(np.asarray(gbrt.predict(models["k"],
                                           jnp.asarray(x))))
    cfg = SchedulerConfig(algorithm=2, budget=BUDGET,
                          t_k=float(np.percentile(pk0, 60)),
                          t_time=BUDGET * 0.75, rho_max=1 << 14)
    spec = CascadeSpec(routing=routing_spec(cfg),
                       stage2=Stage2Spec(enabled=True, k_serve=k_serve,
                                         t_final=t_final),
                       backend=BackendSpec(backend=backend),
                       deploy=DeploySpec(n_shards=1, replicas=2),
                       name="bench_cascade")
    pipe = build_system(spec, index, corpus=corpus, models=models, ltr=ltr,
                        cost=cost)

    def run_batched():
        pipe.sched.stats = {k: 0 for k in pipe.sched.stats}
        return pipe.serve(ql.terms, ql.mask, ql.topic)

    def run_loop():
        return _loop_cascade_baseline(index, corpus, ql, pipe.shards[0],
                                      pipe.shard_specs[0], models, ltr, cfg,
                                      cost, k_serve, t_final)

    # shared honest timer: blocks on any device values inside the timed
    # window (both paths here return host numpy, but the serve path's
    # internals dispatch async jax calls)
    from benchmarks.common import timed

    res_b = run_batched()
    topk_l, final_l, used_l = run_loop()

    # bit-identity is the jnp-backend contract (left-to-right float sums
    # matching the numpy loop); the MXU kernels accumulate in a different
    # order, so on "pallas"/"interpret" near-ties may legitimately flip —
    # hold those to a slot-overlap floor instead.
    from repro.isn.backend import resolve_backend
    exact = resolve_backend(backend) == "jnp"
    identical = bool(np.array_equal(res_b.final, final_l))
    if not np.array_equal(res_b.candidates_used, used_l):
        raise RuntimeError("cascade divergence: candidate counts differ")
    if exact:
        if not np.array_equal(res_b.topk, topk_l):
            raise RuntimeError(
                "cascade divergence: batched Stage-1 top-k != lax.map "
                "baseline")
        if not identical:
            raise RuntimeError(
                "cascade divergence: batched final top-t != rerank_loop "
                "baseline — the batched Stage-2 must be bit-identical on "
                "the jnp backend")
    else:
        # a handful of near-tie flips is legitimate under the kernels'
        # accumulation order; an absolute allowance keeps the gate
        # reachable at small batch sizes (0.5 %, but never below 2 slots)
        mismatched = int(np.sum(res_b.final != final_l))
        allowance = max(2, res_b.final.size // 200)
        if mismatched > allowance:
            raise RuntimeError(
                f"cascade divergence: {mismatched} final top-t slots differ "
                f"(> {allowance} allowed) on the kernel backend")

    t_b = timed(run_batched, reps)
    t_l = timed(run_loop, max(reps // 2, 3))
    qps_b = q_batch / t_b.mean()
    qps_l = q_batch / t_l.mean()
    speedup = float(qps_b / qps_l)

    payload = bench_payload(
        "cascade",
        config={"q_batch": q_batch, "n_docs": n_docs, "k_serve": k_serve,
                "t_final": t_final, "reps": reps, "seed": seed,
                "backend": backend or "auto"},
        extra={
            "batched": {"qps": float(qps_b),
                        "batch_ms": float(t_b.mean() * 1e3)},
            "loop_baseline": {"qps": float(qps_l),
                              "batch_ms": float(t_l.mean() * 1e3)},
            "speedup_vs_loop": speedup,
            "final_topt_identical": identical,
            "stage_latency_ms": {name: float(np.mean(v)) for name, v in
                                 res_b.stage_latency.items()},
        })
    payload["artifact"] = write_bench_artifact("cascade", payload)
    # the throughput floor is defined at the reference configuration; tiny
    # smoke runs (CI) still enforce output parity above.  Wall-clock gates
    # are load-sensitive, so the floor is env-tunable (0 disables).
    floor = float(os.environ.get("REPRO_CASCADE_MIN_SPEEDUP", "5.0"))
    if q_batch >= 64 and speedup < floor:
        raise RuntimeError(
            f"cascade speedup regressed: {speedup:.2f}x < {floor}x over the "
            f"per-query rerank_loop baseline (see {payload['artifact']})")
    return payload


def render_cascade(res) -> str:
    b, l = res["batched"], res["loop_baseline"]
    return ("system,qps,batch_ms\n"
            f"cascade_batched,{b['qps']:.1f},{b['batch_ms']:.2f}\n"
            f"cascade_loop,{l['qps']:.1f},{l['batch_ms']:.2f}\n"
            f"speedup,{res['speedup_vs_loop']:.2f}x,"
            f"identical={res['final_topt_identical']}")


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--q-batch", type=int, default=64)
    ap.add_argument("--n-docs", type=int, default=8192)
    ap.add_argument("--reps", type=int, default=10)
    ap.add_argument("--backend", default=None,
                    help="pallas | interpret | jnp (default: auto)")
    args = ap.parse_args()
    res = run_cascade(q_batch=args.q_batch, n_docs=args.n_docs,
                      reps=args.reps, backend=args.backend)
    print(render_cascade(res))
    print(f"artifact: {res['artifact']}")


if __name__ == "__main__":
    main()
