"""Online response-time certification: the paper's 99.99 % claim under
*load*, not just for one pre-formed batch.

``bench_tail`` certifies the service-time tail; a system under continuous
traffic also pays queueing delay, and that is what the paper's "response
time guarantee" is about.  This benchmark sweeps offered load (as a
fraction of measured saturated capacity) x arrival process and serves the
same trace through two front doors sharing one fitted cascade:

* **online** — the enforcement scheduler behind dynamic micro-batching +
  admission control (``OnlineSpec``): must serve **0 queries over the
  response-time budget, queueing included**, at every swept load —
  degrading (trimmed Stage-2 / stage1-only) or shedding instead of
  breaching;
* **baseline** — no admission, ``max_batch=1`` (the seed's serving story:
  every batch pre-formed, no front door): the queue explodes once offered
  load exceeds single-query throughput, so response times blow through the
  budget.

It also certifies the micro-batcher: per-query Stage-1 top-k from the
online path (any batch size, padded Q buckets) must be **bit-identical**
to an unbatched offline ``serve()`` of the same queries on the jnp
backend.

Emits ``results/BENCH_online.json``; the CLI exits non-zero if any
enforced run leaks a violation at <= 0.8x capacity on the poisson or
bursty trace, if the baseline fails to violate there (regression not
demonstrated), or if the parity check fails.  CI runs it as a smoke.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import bench_payload, write_bench_artifact


def _build(q_batch, n_docs, seed, backend, max_batch):
    from repro.configs.cascade_presets import get_preset
    from repro.index.corpus import CorpusParams, build_corpus, build_queries
    from repro.serving.spec import BackendSpec

    corpus = build_corpus(CorpusParams(n_docs=n_docs,
                                       vocab=max(n_docs // 2, 1024),
                                       avg_doclen=96, zipf_a=1.05,
                                       seed=seed))
    base = dataclasses.replace(get_preset("paper_200ms"),
                               backend=BackendSpec(backend=backend))
    base = dataclasses.replace(
        base, online=dataclasses.replace(base.online, max_batch=max_batch))
    ql = build_queries(corpus, q_batch, stop_k=base.index.stop_k,
                       seed=seed + 4)

    from repro.serving.system import build_system
    fit_sys = build_system(base, corpus)
    fit_sys.fit(ql, None, seed=seed)
    # freeze the calibrated thresholds so every configuration below routes
    # identically (adaptation off keeps the parity check pure)
    base = dataclasses.replace(
        base, routing=dataclasses.replace(
            base.routing, t_k=fit_sys._base_cfg.t_k,
            t_time=fit_sys._base_cfg.t_time, calibrate=False,
            adapt_every=0))
    return corpus, base, ql, fit_sys


def run_online(q_batch: int = 384, n_docs: int = 4096, seed: int = 7,
               loads: tuple = (0.5, 0.8, 0.95),
               arrivals: tuple = ("poisson", "bursty"),
               max_batch: int = 16, backend: str = "jnp") -> dict:
    from repro.serving.online import estimate_capacity
    from repro.serving.spec import TrafficSpec
    from repro.serving.system import build_system

    corpus, base, ql, fit_sys = _build(q_batch, n_docs, seed, backend,
                                       max_batch)
    index, models, ltr = fit_sys.index, fit_sys.models, fit_sys.ltr
    cost = fit_sys.cost  # share the fitted cost model across every config

    def system(**online_kw):
        spec = dataclasses.replace(
            base, online=dataclasses.replace(base.online, **online_kw))
        return build_system(spec, index, corpus=corpus, models=models,
                            ltr=ltr, cost=cost)

    capacity = estimate_capacity(system(), ql.terms, ql.mask, ql.topic)
    budget_r = None  # read back from the simulator (single source of truth)

    rows = []
    for arrival in arrivals:
        for load in loads:
            traffic = TrafficSpec(arrival=arrival, qps=load * capacity,
                                  seed=seed + 1)
            on = system().serve_online(ql.terms, ql.mask, ql.topic,
                                       traffic=traffic)
            off = system(admission=False, max_batch=1,
                         batch_deadline_us=0.0).serve_online(
                ql.terms, ql.mask, ql.topic, traffic=traffic)
            s_on, s_off = on.stats, off.stats
            budget_r = s_on["response_budget"]
            rows.append({
                "arrival": arrival, "load": load,
                "qps": float(load * capacity),
                "online": {
                    "over_budget": s_on["over_budget"],
                    "served": s_on["served"], "shed": s_on["shed"],
                    "modes": s_on["modes"],
                    "p99.99": (s_on["response"]["p99.99"]
                               if "response" in s_on else None),
                    "max": (s_on["response"]["max"]
                            if "response" in s_on else None),
                    "mean_batch": (s_on["batch"]["mean_size"]
                                   if "batch" in s_on else None),
                },
                "baseline": {
                    "over_budget": s_off["over_budget"],
                    "served": s_off["served"],
                    "p99.99": (s_off["response"]["p99.99"]
                               if "response" in s_off else None),
                    "max": (s_off["response"]["max"]
                            if "response" in s_off else None),
                },
            })

    # ---- micro-batch parity: online top-k == unbatched offline serve ----
    parity = None
    if backend == "jnp":
        from repro.serving.online import FULL, SHED
        traffic = TrafficSpec(arrival="poisson", qps=0.8 * capacity,
                              seed=seed + 1)
        on = system().serve_online(ql.terms, ql.mask, ql.topic,
                                   traffic=traffic)
        ref_sys = system()
        served = np.flatnonzero(on.mode != SHED)
        ok_topk = ok_final = True
        # serve each query UNBATCHED (Q=1) and compare row for row
        for qid in served[:64]:  # a prefix is plenty; each is a device call
            r1 = ref_sys.serve(ql.terms[qid:qid + 1], ql.mask[qid:qid + 1],
                               ql.topic[qid:qid + 1])
            ok_topk &= bool(np.array_equal(r1.topk[0], on.topk[qid]))
            if int(on.mode[qid]) == FULL:
                ok_final &= bool(np.array_equal(r1.final[0], on.final[qid]))
        parity = {"checked": int(min(len(served), 64)),
                  "identical_topk": ok_topk, "identical_final": ok_final}

    certified = [r for r in rows if r["load"] <= 0.8 + 1e-9
                 and r["arrival"] in ("poisson", "bursty")]
    payload = bench_payload(
        "online",
        config={"q_batch": q_batch, "n_docs": n_docs, "seed": seed,
                "backend": backend, "max_batch": max_batch,
                "loads": list(loads), "arrivals": list(arrivals)},
        rows=rows,
        parity=parity,
        extra={
            "capacity_qps": float(capacity),
            "response_budget": float(budget_r),
            "worst_case_bound": float(fit_sys.worst_case_us()),
            "guarantee_holds": all(r["online"]["over_budget"] == 0
                                   for r in rows),
            # an empty certified subset must FAIL the gate, not
            # vacuously pass
            "regression_demonstrated": bool(certified) and all(
                r["baseline"]["over_budget"] >= 1 for r in certified),
        })
    payload["artifact"] = write_bench_artifact("online", payload)
    return payload


def render_online(res: dict) -> str:
    lines = [f"capacity={res['capacity_qps']:.0f} qps, response budget="
             f"{res['response_budget']:.0f} (service bound "
             f"{res['worst_case_bound']:.0f})",
             "arrival,load,online_over,online_shed,online_p99.99,"
             "base_over,base_p99.99"]
    def fmt(v):
        return f"{v:.1f}" if v is not None else "n/a"

    for r in res["rows"]:
        o, b = r["online"], r["baseline"]
        lines.append(
            f"{r['arrival']},{r['load']:.2f},{o['over_budget']},"
            f"{o['shed']},{fmt(o['p99.99'])},"
            f"{b['over_budget']},{fmt(b['p99.99'])}")
    if res["parity"]:
        p = res["parity"]
        lines.append(f"parity({p['checked']} queries): "
                     f"topk={p['identical_topk']} "
                     f"final={p['identical_final']}")
    lines.append(f"guarantee_holds={res['guarantee_holds']} "
                 f"regression_demonstrated={res['regression_demonstrated']}")
    return "\n".join(lines)


def main() -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--q-batch", type=int, default=384)
    ap.add_argument("--n-docs", type=int, default=4096)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--loads", type=float, nargs="+",
                    default=[0.5, 0.8, 0.95])
    ap.add_argument("--arrivals", nargs="+",
                    default=["poisson", "bursty"])
    ap.add_argument("--backend", default="jnp",
                    help="jnp gives the bit-identical parity check")
    args = ap.parse_args()
    res = run_online(q_batch=args.q_batch, n_docs=args.n_docs,
                     seed=args.seed, loads=tuple(args.loads),
                     arrivals=tuple(args.arrivals),
                     max_batch=args.max_batch, backend=args.backend)
    print(render_online(res))
    print(f"artifact: {res['artifact']}")
    checks = {
        "guarantee_holds": res["guarantee_holds"],
        "regression_demonstrated": res["regression_demonstrated"],
    }
    if args.backend == "jnp":
        checks["identical_topk"] = res["parity"]["identical_topk"]
        checks["identical_final"] = res["parity"]["identical_final"]
    failed = [k for k, v in checks.items() if not v]
    if failed:
        print(f"ONLINE GUARANTEE CHECK FAILED: {', '.join(failed)}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
