"""Fault-injection certification: the 99.99 % response-time guarantee
under replica crashes, stragglers, transient timeouts, and partition loss.

``bench_online`` certifies the response-time budget on a *healthy*
cluster; the paper's ISN architecture presumes replicas that fail.  This
benchmark serves the same trace through the fault-hardened operating
point (``fault_tolerant``: 4 partitions x 3 replicas, scatter-gather
failover with a bounded retry budget charged into the worst-case bound)
under every canonical fault scenario (``repro.serving.faults``):

* **crash_one** — a replica dies and never returns: failover must keep
  full coverage with zero violations;
* **rolling_restart** — staggered per-partition restarts: the health
  probe/recovery path;
* **stragglers** — ~10 % of replicas run 8x slow: hedging + enforcement;
* **timeout_storm** — 5 % transient per-request timeouts: bounded retry;
* **partition_outage** — one partition loses *every* replica: graceful
  degradation to partial coverage, never an exception, never a breach.

Certified per (load, scenario) row:

1. **0 served queries over the response budget** — the guarantee is a
   certificate, not a percentile;
2. **coverage >= surviving partitions / total** on every served query
   (checked against the ``FaultInjector`` ground truth at each batch's
   dispatch time) — degradation is never worse than the cluster state;
3. the **empty schedule is inert**: the "none" scenario replays
   bit-identically (event log, top-k, final lists), and an offline serve
   through the fault-capable build equals the failover-disabled build
   bit for bit.

The cost of surviving is *quantified*, not hidden: each row reports mean
coverage, degraded-query counts, retry/lost-partition counters, and the
fraction of FULL-mode queries whose re-ranked lists match the no-fault
run.  Emits ``results/BENCH_faults.json``; the CLI exits non-zero if any
certificate fails.  CI runs it as a smoke.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import bench_payload, write_bench_artifact


def _build(q_batch, n_docs, seed, max_batch, gather_us):
    from repro.configs.cascade_presets import get_preset
    from repro.index.corpus import CorpusParams, build_corpus, build_queries
    from repro.serving.spec import BackendSpec

    corpus = build_corpus(CorpusParams(n_docs=n_docs,
                                       vocab=max(n_docs // 2, 1024),
                                       avg_doclen=96, zipf_a=1.05,
                                       seed=seed))
    base = dataclasses.replace(get_preset("fault_tolerant"),
                               backend=BackendSpec(backend="jnp"))
    base = dataclasses.replace(
        base, online=dataclasses.replace(base.online, max_batch=max_batch))
    ql = build_queries(corpus, q_batch, stop_k=base.index.stop_k,
                       seed=seed + 4)

    from repro.serving.system import build_system
    fit_sys = build_system(base, corpus)
    fit_sys.fit(ql, None, seed=seed)
    # freeze the calibrated thresholds so every configuration routes
    # identically, and give the merge a real per-shard cost so the
    # partial-coverage admission rung is live
    base = dataclasses.replace(
        base, routing=dataclasses.replace(
            base.routing, t_k=fit_sys._base_cfg.t_k,
            t_time=fit_sys._base_cfg.t_time, calibrate=False,
            adapt_every=0))
    cost = dataclasses.replace(fit_sys.cost, gather_per_shard_us=gather_us)
    return corpus, base, ql, fit_sys, cost


def _coverage_floor_ok(res, injector, replicas, ns):
    """Every served query's coverage >= (partitions the schedule left
    reachable at its batch's dispatch time) / total — the ground-truth
    floor behind "graceful" degradation."""
    worst = 0.0
    for (qid, bid, t_arr, start, t_wait, svc, comp, m) in res.event_log:
        if bid < 0:          # shed — no coverage claim to certify
            continue
        floor = injector.surviving(replicas, start) / ns
        cov = 1.0 if res.coverage is None else float(res.coverage[qid])
        worst = max(worst, floor - cov)
        if cov < floor - 1e-9:
            return False, worst
    return True, worst


def run_faults(q_batch: int = 256, n_docs: int = 4096, seed: int = 7,
               loads: tuple = (0.5, 0.8), max_batch: int = 16,
               gather_us: float = 4.0) -> dict:
    from repro.serving.faults import SCENARIOS, FaultInjector, fault_scenario
    from repro.serving.online import FULL, estimate_capacity
    from repro.serving.spec import FaultSpec, TrafficSpec
    from repro.serving.system import build_system

    corpus, base, ql, fit_sys, cost = _build(q_batch, n_docs, seed,
                                             max_batch, gather_us)
    index, models, ltr = fit_sys.index, fit_sys.models, fit_sys.ltr
    ns = base.deploy.n_shards
    replicas = base.deploy.replicas

    def system(fault=None, failover=True):
        spec = base
        if not failover:
            spec = dataclasses.replace(spec, routing=dataclasses.replace(
                spec.routing, failover_timeout=0.0, max_retries=0))
        if fault is not None:
            spec = dataclasses.replace(spec, fault=fault)
        return build_system(spec.validate(), index, corpus=corpus,
                            models=models, ltr=ltr, cost=cost)

    capacity = estimate_capacity(system(), ql.terms, ql.mask, ql.topic)
    budget_r = None

    rows = []
    none_runs = {}           # load -> no-fault OnlineResult (the control)
    floors_hold = True
    for load in loads:
        qps = load * capacity
        horizon = 1000.0 * q_batch / qps      # trace span in time units
        traffic = TrafficSpec(arrival="poisson", qps=qps, seed=seed + 1)
        for scenario in SCENARIOS:
            fspec = fault_scenario(scenario, n_partitions=ns,
                                   replicas=replicas, horizon=horizon,
                                   seed=seed)
            res = system(fault=fspec).serve_online(ql.terms, ql.mask,
                                                   ql.topic, traffic=traffic)
            s = res.stats
            budget_r = s["response_budget"]
            if scenario == "none":
                none_runs[load] = res
            ok_floor, slack = _coverage_floor_ok(
                res, FaultInjector(fspec, ns), replicas, ns)
            floors_hold &= ok_floor

            # effectiveness cost of surviving: FULL-mode queries whose
            # re-ranked list still matches the no-fault control
            ctrl = none_runs[load]
            both = np.flatnonzero((res.mode == FULL) & (ctrl.mode == FULL))
            same = (float(np.mean(np.all(
                res.final[both] == ctrl.final[both], axis=1)))
                if len(both) and res.final is not None else None)

            cov = s.get("coverage", {})
            rows.append({
                "load": load, "qps": float(qps), "scenario": scenario,
                "over_budget": s["over_budget"],
                "served": s["served"], "shed": s["shed"],
                "modes": s["modes"],
                "p99.99": (s["response"]["p99.99"]
                           if "response" in s else None),
                "max": s["response"]["max"] if "response" in s else None,
                "coverage": {"min": cov.get("min", 1.0),
                             "mean": cov.get("mean", 1.0),
                             "degraded": cov.get("degraded", 0)},
                "coverage_floor_ok": ok_floor,
                "faults": s.get("faults"),
                "full_final_match_vs_none": same,
            })

    # ---- inertness: the empty schedule must not perturb serving --------
    load0 = loads[-1]
    traffic = TrafficSpec(arrival="poisson", qps=load0 * capacity,
                          seed=seed + 1)
    a = none_runs[load0]
    b = system(fault=FaultSpec()).serve_online(ql.terms, ql.mask, ql.topic,
                                               traffic=traffic)
    replay_identical = (
        a.event_log == b.event_log
        and bool(np.array_equal(a.topk, b.topk))
        and (a.final is None or bool(np.array_equal(a.final, b.final))))
    # offline: fault-capable build == failover-disabled build, bit for bit
    r_on = system().serve(ql.terms, ql.mask, ql.topic)
    r_off = system(failover=False).serve(ql.terms, ql.mask, ql.topic)
    offline_identical = (
        bool(np.array_equal(r_on.topk, r_off.topk))
        and bool(np.array_equal(r_on.latency, r_off.latency))
        and (r_on.final is None
             or bool(np.array_equal(r_on.final, r_off.final))))

    payload = bench_payload(
        "faults",
        config={"q_batch": q_batch, "n_docs": n_docs, "seed": seed,
                "max_batch": max_batch, "loads": list(loads),
                "gather_per_shard_us": gather_us,
                "n_shards": ns, "replicas": replicas,
                "failover_timeout": base.routing.failover_timeout,
                "max_retries": base.routing.max_retries},
        rows=rows,
        extra={
            "capacity_qps": float(capacity),
            "response_budget": float(budget_r),
            "worst_case_bound": float(system().worst_case_us()),
            "guarantee_holds": all(r["over_budget"] == 0 for r in rows),
            "coverage_certified": floors_hold,
            "inert_replay_identical": replay_identical,
            "inert_offline_identical": offline_identical,
            # the injector must actually bite somewhere, or the
            # certificate is vacuous (e.g. the schedule windows missed
            # the trace)
            "faults_demonstrated": any(
                r["faults"] and (r["faults"]["retries"] > 0
                                 or r["faults"]["lost_partitions"] > 0
                                 or r["faults"]["transient"] > 0)
                for r in rows if r["scenario"] != "none"),
        })
    payload["artifact"] = write_bench_artifact("faults", payload)
    return payload


def render_faults(res: dict) -> str:
    c = res["config"]
    lines = [f"capacity={res['capacity_qps']:.0f} qps, response budget="
             f"{res['response_budget']:.0f} (service bound "
             f"{res['worst_case_bound']:.0f}), "
             f"{c['n_shards']}x{c['replicas']} replicas, "
             f"failover={c['failover_timeout']:.0f}"
             f"x{c['max_retries']} retries",
             "load,scenario,over,shed,cov_min,cov_mean,degraded,"
             "retries,lost,final_match"]
    for r in res["rows"]:
        f = r["faults"] or {}
        m = r["full_final_match_vs_none"]
        lines.append(
            f"{r['load']:.2f},{r['scenario']},{r['over_budget']},"
            f"{r['shed']},{r['coverage']['min']:.2f},"
            f"{r['coverage']['mean']:.3f},{r['coverage']['degraded']},"
            f"{f.get('retries', 0)},{f.get('lost_partitions', 0)},"
            f"{'n/a' if m is None else f'{m:.2f}'}")
    lines.append(
        f"guarantee_holds={res['guarantee_holds']} "
        f"coverage_certified={res['coverage_certified']} "
        f"inert_replay={res['inert_replay_identical']} "
        f"inert_offline={res['inert_offline_identical']} "
        f"faults_demonstrated={res['faults_demonstrated']}")
    return "\n".join(lines)


def main() -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--q-batch", type=int, default=256)
    ap.add_argument("--n-docs", type=int, default=4096)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--loads", type=float, nargs="+", default=[0.5, 0.8])
    ap.add_argument("--gather-us", type=float, default=4.0,
                    help="per-extra-shard merge cost (makes the "
                         "partial-coverage admission rung live)")
    args = ap.parse_args()
    res = run_faults(q_batch=args.q_batch, n_docs=args.n_docs,
                     seed=args.seed, loads=tuple(args.loads),
                     max_batch=args.max_batch, gather_us=args.gather_us)
    print(render_faults(res))
    print(f"artifact: {res['artifact']}")
    checks = {k: res[k] for k in ("guarantee_holds", "coverage_certified",
                                  "inert_replay_identical",
                                  "inert_offline_identical",
                                  "faults_demonstrated")}
    failed = [k for k, v in checks.items() if not v]
    if failed:
        print(f"FAULT GUARANTEE CHECK FAILED: {', '.join(failed)}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
