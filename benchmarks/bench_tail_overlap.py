"""Paper Table 1 — overlap of 95th-percentile tail-latency queries between
systems (the motivation for index mirroring: BMW variants share tails,
budgeted JASS doesn't)."""

from __future__ import annotations

import numpy as np


def run(engines_res) -> dict:
    times = engines_res["times"]
    names = list(times)
    tails = {}
    for n, t in times.items():
        thr = np.percentile(t, 95)
        tails[n] = set(np.flatnonzero(t >= thr))
    overlap = {}
    for i, a in enumerate(names):
        for b in names[i + 1:]:
            inter = len(tails[a] & tails[b])
            overlap[f"{a}|{b}"] = 100.0 * inter / max(len(tails[a]), 1)
    return {"overlap": overlap}


def render(res) -> str:
    lines = ["pair,tail_overlap_pct"]
    for k, v in res["overlap"].items():
        lines.append(f"{k},{v:.1f}")
    return "\n".join(lines)
