"""Step builders: one compiled function per (arch × shape) dry-run cell.

For each cell this module constructs
  * the jit-able step function (train_step / prefill / decode_step / serve),
  * ShapeDtypeStruct stand-ins for every argument (no allocation),
  * NamedShardings resolved from the family × shape logical rules,
so ``dryrun.py`` can do ``jax.jit(fn, in_shardings=...).lower(*args).compile()``
per mesh and read off memory/cost/collective analyses.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.configs.shapes import FAMILY_SHAPES, ShapeCell, extras_dict, rules_for
from repro.models import common, gnn, recsys
from repro.models import transformer as tr
from repro.train import optimizer

SDS = jax.ShapeDtypeStruct


@dataclass
class Cell:
    arch_id: str
    shape_name: str
    family: str
    kind: str
    fn: Callable
    args: tuple
    in_shardings: Any
    out_shardings: Any
    donate_argnums: tuple
    meta: dict = field(default_factory=dict)


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def _zero_rules(rules: dict) -> dict:
    """ZeRO-1: optimizer moments additionally shard over the data axis on
    dims the model rules leave unsharded (stack / embed are the big ones).
    Grads reduce-scatter into this layout and updated params all-gather
    back — XLA derives both from the sharding annotations."""
    z = dict(rules)
    z["stack"] = ("data",) if z.get("stack") is None else z["stack"]
    z["embed"] = ("data",) if z.get("embed") is None else z["embed"]
    return z


def _shard_tree(mesh, names_tree, rules, shapes=None):
    """names -> NamedShardings; with `shapes` (a congruent SDS tree), specs
    are fitted per-leaf so non-divisible dims fall back to replication."""
    if shapes is None:
        return jax.tree.map(
            lambda names: _ns(mesh, common.resolve_pspec(names, rules, mesh)),
            names_tree, is_leaf=lambda x: isinstance(x, tuple))
    return jax.tree.map(
        lambda leaf, names: _ns(mesh, common.fit_spec_to_shape(
            common.resolve_pspec(names, rules, mesh), leaf.shape, mesh)),
        shapes, names_tree,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict))


def _batch_spec(mesh, rules, extra_dims=0):
    bspec = common.resolve_pspec(("batch",) + (None,) * extra_dims, rules, mesh)
    return _ns(mesh, bspec)


def _round_up(x, m):
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_cell(arch_id, config, cell: ShapeCell, mesh, rules) -> Cell:
    # divisibility fallbacks: if a raw count doesn't divide the TP degree,
    # drop that logical axis from sharding (flattened weight dims still
    # shard via their own names)
    model_ways = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    if config.moe is not None and config.moe.n_experts % model_ways != 0:
        rules["experts"] = None
    if cell.kind in ("decode", "prefill"):
        rules["kv_heads"] = None       # cache kv-head counts (4/8) < TP=16
    params, names = tr.init(config, abstract=True)
    names_tree = common.names_tree_of(params, names)
    p_shard = _shard_tree(mesh, names_tree, rules, params)
    b, s = cell.global_batch, cell.seq_len
    repl = _ns(mesh, P())
    tok_shard = _ns(mesh, common.resolve_pspec(("batch", None), rules, mesh))
    meta = {
        "params": config.param_count(),
        "active_params": config.active_param_count(),
        "tokens_per_step": b * s if cell.kind == "train" else b,
    }

    if cell.kind == "train":
        # per-arch layout pick (§Perf: FSDP default; tpsp where FSDP's
        # vocab/EP buffers exceed HBM)
        if getattr(config, "train_layout", "fsdp") == "tpsp":
            from repro.configs.shapes import LM_TRAIN_TPSP
            rules = dict(LM_TRAIN_TPSP)
        # FSDP batch axes: greedily take mesh axes while the global batch
        # stays divisible (multi-pod: 256 % 512 != 0 → ("pod", "data"))
        if rules.get("batch") == ("pod", "data", "model"):
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            taken, ways = [], 1
            for ax in ("pod", "data", "model"):
                if ax not in sizes:
                    continue
                if b % (ways * sizes[ax]) != 0:
                    break
                taken.append(ax)
                ways *= sizes[ax]
            rules["batch"] = tuple(taken) or None
            if ("model" not in taken and "model" in sizes
                    and s % sizes["model"] == 0):
                # hybrid FSDP+SP: batch alone can't cover the mesh (e.g.
                # 256 seqs on 512 chips) — shard the sequence over "model"
                # so saved activations stay bounded
                rules["seq"] = "model"
        tok_shard = _ns(mesh, common.resolve_pspec(("batch", None), rules,
                                                   mesh))
        opt = optimizer.abstract_init(params)
        zr = _zero_rules(rules)
        opt_shard = optimizer.OptState(
            m=_shard_tree(mesh, names_tree, zr, params),
            v=_shard_tree(mesh, names_tree, zr, params), step=repl)
        ocfg = optimizer.AdamWConfig()

        mb = getattr(config, "train_microbatches", 1)

        def train_step(params, opt, tokens, labels):
            if mb == 1:
                loss, grads = jax.value_and_grad(tr.loss_fn)(
                    params, config, tokens, labels, rules)
            else:
                # grad accumulation: halves activation temps per microbatch;
                # the bucketed psum of microbatch i overlaps compute of i+1
                tk = tokens.reshape(mb, b // mb, s)
                lb = labels.reshape(mb, b // mb, s)

                def acc(carry, sl):
                    l, g = jax.value_and_grad(tr.loss_fn)(
                        params, config, sl[0], sl[1], rules)
                    return (carry[0] + l,
                            jax.tree.map(jnp.add, carry[1], g)), None

                zero = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, p.dtype), params)
                (lsum, grads), _ = jax.lax.scan(acc, (0.0, zero), (tk, lb))
                loss = lsum / mb
                grads = jax.tree.map(lambda g: g / mb, grads)
            new_p, new_opt, metrics = optimizer.apply(params, grads, opt, ocfg)
            return new_p, new_opt, loss, metrics

        args = (params, opt,
                SDS((b, s), jnp.int32), SDS((b, s), jnp.int32))
        in_sh = (p_shard, opt_shard, tok_shard, tok_shard)
        out_sh = (p_shard, opt_shard, repl, {"grad_norm": repl, "lr": repl})
        return Cell(arch_id, cell.name, "lm", cell.kind, train_step, args,
                    in_sh, out_sh, donate_argnums=(0, 1), meta=meta)

    if cell.kind == "prefill":
        # cache is the big output: shard its sequence over model
        cache_rules = dict(rules, kv_seq="model")
        _, cache_names = tr.init_cache(config, b, s, abstract=True)
        cache_shard = jax.tree.map(
            lambda n: _ns(mesh, common.resolve_pspec(n, cache_rules, mesh)),
            cache_names, is_leaf=lambda x: isinstance(x, tuple))

        def prefill_step(params, tokens):
            return tr.prefill(params, config, tokens, rules)

        args = (params, SDS((b, s), jnp.int32))
        out_sh = (_ns(mesh, common.resolve_pspec(("batch", "vocab"), rules,
                                                 mesh)), cache_shard)
        return Cell(arch_id, cell.name, "lm", cell.kind, prefill_step, args,
                    (p_shard, tok_shard), out_sh, donate_argnums=(),
                    meta=meta)

    # decode
    cache, cache_names = tr.init_cache(config, b, s, abstract=True)
    batch_shardable = b % _mesh_batch_ways(mesh, rules) == 0 and b > 1
    dec_rules = dict(rules)
    if not batch_shardable:
        dec_rules["batch"] = None
        # batch=1 leaves the data axis idle: shard the KV sequence over
        # BOTH axes (103 GB moonshot cache -> 400 MB/device)
        dec_rules["kv_seq"] = ("data", "model")
    if (config.attention != "mla"
            and config.n_kv_heads % model_ways == 0 and model_ways > 1):
        # kv-head sharding also engages the model axis for the cache
        dec_rules["kv_heads"] = "model"
        dec_rules["kv_seq"] = ("data",) if not batch_shardable else None
    cache_shard = jax.tree.map(
        lambda n: _ns(mesh, common.resolve_pspec(n, dec_rules, mesh)),
        cache_names, is_leaf=lambda x: isinstance(x, tuple))
    tok1 = _ns(mesh, common.resolve_pspec(("batch",), dec_rules, mesh))

    def decode(params, token, cache, kv_len):
        logits, new_cache = tr.decode_step(params, config, token, cache,
                                           kv_len, dec_rules)
        return logits, new_cache

    args = (params, SDS((b,), jnp.int32), cache, SDS((b,), jnp.int32))
    in_sh = (p_shard, tok1, cache_shard, tok1)
    out_sh = (_ns(mesh, common.resolve_pspec(("batch", "vocab"), dec_rules,
                                             mesh)), cache_shard)
    return Cell(arch_id, cell.name, "lm", cell.kind, decode, args, in_sh,
                out_sh, donate_argnums=(2,), meta=meta)


def _mesh_batch_ways(mesh, rules):
    ways = 1
    r = rules.get("batch")
    r = (r,) if isinstance(r, str) else (r or ())
    for ax in r:
        if ax in mesh.axis_names:
            ways *= dict(zip(mesh.axis_names, mesh.devices.shape))[ax]
    return ways


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gnn_shapes(cell: ShapeCell, n_dev: int):
    ex = extras_dict(cell)
    if cell.name == "minibatch_lg":
        seeds = ex["batch_nodes"]
        f1, f2 = ex["fanouts"]
        e = seeds * f1 + seeds * f1 * f2
        n = seeds + seeds * f1 + seeds * f1 * f2
    elif cell.name == "molecule":
        n = ex["n_nodes"] * ex["batch"]
        e = ex["n_edges"] * ex["batch"]
    else:
        n, e = ex["n_nodes"], ex["n_edges"]
    t = e * ex["trip_factor"]
    pad = max(n_dev, 512)
    return (_round_up(n, pad), _round_up(e, pad), _round_up(t, pad),
            ex["d_feat"])


def _gnn_cell(arch_id, config, cell: ShapeCell, mesh, rules) -> Cell:
    n_dev = int(mesh.devices.size)
    n, e, t, d_feat = _gnn_shapes(cell, n_dev)
    kw = {"d_feat": d_feat}
    if cell.name == "ogb_products":
        kw["dtype"] = "bfloat16"   # halves the 61.8M-edge message tensors
    config = type(config)(**{**config.__dict__, **kw})
    params, names = gnn.init(config, abstract=True)
    names_tree = common.names_tree_of(params, names)
    p_shard = _shard_tree(mesh, names_tree, rules, params)
    repl = _ns(mesh, P())
    flat = _ns(mesh, common.resolve_pspec(("edges",), rules, mesh))
    flat2 = _ns(mesh, common.resolve_pspec(("edges", None), rules, mesh))
    nshard = _ns(mesh, common.resolve_pspec(("nodes",), rules, mesh))
    nshard2 = _ns(mesh, common.resolve_pspec(("nodes", None), rules, mesh))

    batch = {
        "feat": SDS((n, d_feat), jnp.float32),
        "pos": SDS((n, 3), jnp.float32),
        "edge_src": SDS((e,), jnp.int32),
        "edge_dst": SDS((e,), jnp.int32),
        "trip_kj": SDS((t,), jnp.int32),
        "trip_ji": SDS((t,), jnp.int32),
        "edge_mask": SDS((e,), jnp.float32),
        "trip_mask": SDS((t,), jnp.float32),
        "node_mask": SDS((n,), jnp.float32),
        "target": SDS((n,), jnp.float32),
    }
    b_shard = {
        "feat": nshard2, "pos": nshard2, "edge_src": flat, "edge_dst": flat,
        "trip_kj": flat, "trip_ji": flat, "edge_mask": flat,
        "trip_mask": flat, "node_mask": nshard, "target": nshard,
    }
    opt = optimizer.abstract_init(params)
    zr = _zero_rules(rules)
    opt_shard = optimizer.OptState(m=_shard_tree(mesh, names_tree, zr, params),
                                   v=_shard_tree(mesh, names_tree, zr, params),
                                   step=repl)
    ocfg = optimizer.AdamWConfig()

    if rules.get("partition_gnn"):
        # partitioned-graph layout (see gnn.loss_fn_partitioned): edge and
        # triplet arrays are per-shard local slices; one psum per pass
        flat_axes = tuple(a for a in ("pod", "data", "model")
                          if a in mesh.axis_names)
        edge_keys = ("edge_src", "edge_dst", "trip_kj", "trip_ji",
                     "edge_mask", "trip_mask")
        b_specs = {k: (P(flat_axes) if k in edge_keys else P())
                   for k in batch}

        def loss_sharded(params, batch):
            return shard_map(
                lambda p, b_: gnn.loss_fn_partitioned(p, config, b_,
                                                      flat_axes),
                mesh=mesh, in_specs=(P(), b_specs), out_specs=P(),
                check_rep=False)(params, batch)

        def train_step(params, opt, batch):
            loss, grads = jax.value_and_grad(loss_sharded)(params, batch)
            new_p, new_opt, metrics = optimizer.apply(params, grads, opt,
                                                      ocfg)
            return new_p, new_opt, loss, metrics
    else:
        def train_step(params, opt, batch):
            loss, grads = jax.value_and_grad(gnn.loss_fn)(params, config,
                                                          batch)
            new_p, new_opt, metrics = optimizer.apply(params, grads, opt,
                                                      ocfg)
            return new_p, new_opt, loss, metrics

    meta = {"n_nodes": n, "n_edges": e, "n_triplets": t,
            "params": sum(int(math.prod(l.shape))
                          for l in jax.tree.leaves(params))}
    return Cell(arch_id, cell.name, "gnn", "train", train_step,
                (params, opt, batch), (p_shard, opt_shard, b_shard),
                (p_shard, opt_shard, repl, {"grad_norm": repl, "lr": repl}),
                donate_argnums=(0, 1), meta=meta)


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def _recsys_batch(config, cell: ShapeCell, mesh, rules):
    b = cell.global_batch
    c = config
    if c.kind in ("deepfm", "xdeepfm"):
        batch = {"ids": SDS((b, c.n_sparse), jnp.int32),
                 "label": SDS((b,), jnp.int32)}
    elif c.kind == "two_tower":
        batch = {"user_ids": SDS((b, c.n_user_feats), jnp.int32),
                 "user_mask": SDS((b, c.n_user_feats), jnp.float32),
                 "item_ids": SDS((b, c.n_item_feats), jnp.int32),
                 "item_mask": SDS((b, c.n_item_feats), jnp.float32),
                 "log_q": SDS((b,), jnp.float32)}
    else:  # bert4rec
        m, cands = 8, 2048
        batch = {"items": SDS((b, c.seq_len), jnp.int32),
                 "positions": SDS((b, m), jnp.int32),
                 "label_idx": SDS((b, m), jnp.int32),
                 "candidates": SDS((cands,), jnp.int32)}
    shard = {}
    for k, v in batch.items():
        if k == "candidates":
            shard[k] = _ns(mesh, P())
        else:
            shard[k] = _ns(mesh, common.resolve_pspec(
                ("batch",) + (None,) * (len(v.shape) - 1), rules, mesh))
    return batch, shard


def _recsys_cell(arch_id, config, cell: ShapeCell, mesh, rules) -> Cell:
    c = config
    params, names = recsys.init(c, abstract=True)
    names_tree = common.names_tree_of(params, names)
    p_shard = _shard_tree(mesh, names_tree, rules, params)
    repl = _ns(mesh, P())
    meta = {"params": sum(int(math.prod(l.shape))
                          for l in jax.tree.leaves(params)),
            "rows": c.total_rows}

    if cell.kind == "train":
        batch, b_shard = _recsys_batch(c, cell, mesh, rules)
        opt = optimizer.abstract_init(params)
        zr = _zero_rules(rules)
        opt_shard = optimizer.OptState(m=_shard_tree(mesh, names_tree, zr, params),
                                       v=_shard_tree(mesh, names_tree, zr, params),
                                       step=repl)
        ocfg = optimizer.AdamWConfig()
        loss_fns = {"deepfm": recsys.ctr_loss, "xdeepfm": recsys.ctr_loss,
                    "two_tower": recsys.two_tower_loss,
                    "bert4rec": recsys.bert4rec_loss}
        lf = loss_fns[c.kind]

        def train_step(params, opt, batch):
            loss, grads = jax.value_and_grad(lf)(params, c, batch)
            new_p, new_opt, metrics = optimizer.apply(params, grads, opt, ocfg)
            return new_p, new_opt, loss, metrics

        return Cell(arch_id, cell.name, "recsys", "train", train_step,
                    (params, opt, batch), (p_shard, opt_shard, b_shard),
                    (p_shard, opt_shard, repl,
                     {"grad_norm": repl, "lr": repl}),
                    donate_argnums=(0, 1), meta=meta)

    if cell.kind == "serve":
        b = cell.global_batch
        bsh = _ns(mesh, common.resolve_pspec(("batch", None), rules, mesh))
        b1 = _ns(mesh, common.resolve_pspec(("batch",), rules, mesh))
        if c.kind in ("deepfm", "xdeepfm"):
            fn = (lambda p, ids: recsys.deepfm_logits(p, c, ids)) \
                if c.kind == "deepfm" else \
                (lambda p, ids: recsys.xdeepfm_logits(p, c, ids))
            args = (params, SDS((b, c.n_sparse), jnp.int32))
            return Cell(arch_id, cell.name, "recsys", "serve", fn, args,
                        (p_shard, bsh), b1, (), meta)
        if c.kind == "two_tower":
            cand = SDS((c.n_items, c.tower_mlp[-1]), jnp.float32)
            cand_sh = _ns(mesh, common.resolve_pspec(("candidates", None),
                                                     rules, mesh))

            def serve(params, user_ids, user_mask, cand_emb):
                u = recsys.tower_embed(params, c, "user_table", "user_mlp",
                                       user_ids, user_mask)
                v, i = recsys.sharded_streaming_topk(u, cand_emb, 100)
                return v, i

            args = (params, SDS((b, c.n_user_feats), jnp.int32),
                    SDS((b, c.n_user_feats), jnp.float32), cand)
            return Cell(arch_id, cell.name, "recsys", "serve", serve, args,
                        (p_shard, bsh, bsh, cand_sh), (bsh, bsh), (), meta)
        # bert4rec serve: next-item scores against the full item corpus
        def serve_b4r(params, items):
            h = recsys.bert4rec_hidden(params, c, items)[:, -1]   # (B, D)
            v, i = recsys.sharded_streaming_topk(h, params["item_embed"], 100)
            return v, i

        args = (params, SDS((b, c.seq_len), jnp.int32))
        return Cell(arch_id, cell.name, "recsys", "serve", serve_b4r, args,
                    (p_shard, bsh), (bsh, bsh), (), meta)

    # retrieval_cand
    n_cand = _round_up(extras_dict(cell)["n_candidates"],
                       max(int(mesh.devices.size), 512))
    if c.kind == "two_tower":
        cand_sh = _ns(mesh, common.resolve_pspec(("candidates", None), rules,
                                                 mesh))

        def retrieve(params, user_ids, user_mask, cand_emb, budget):
            u = recsys.tower_embed(params, c, "user_table", "user_mlp",
                                   user_ids, user_mask)
            v, i = recsys.anytime_retrieval(u, cand_emb, budget, 1000)
            return v, i

        args = (params, SDS((1, c.n_user_feats), jnp.int32),
                SDS((1, c.n_user_feats), jnp.float32),
                SDS((n_cand, c.tower_mlp[-1]), jnp.float32),
                SDS((), jnp.int32))
        return Cell(arch_id, cell.name, "recsys", "retrieval", retrieve, args,
                    (p_shard, _ns(mesh, P()), _ns(mesh, P()), cand_sh,
                     _ns(mesh, P())), (_ns(mesh, P()), _ns(mesh, P())), (),
                    meta)
    if c.kind in ("deepfm", "xdeepfm"):
        fn0 = recsys.deepfm_logits if c.kind == "deepfm" \
            else recsys.xdeepfm_logits
        csh = _ns(mesh, common.resolve_pspec(("candidates", None), rules,
                                             mesh))
        c1 = _ns(mesh, common.resolve_pspec(("candidates",), rules, mesh))

        def retrieve_ctr(params, ids):
            scores = fn0(params, c, ids)
            v, i = jax.lax.top_k(scores, 1000)
            return v, i

        args = (params, SDS((n_cand, c.n_sparse), jnp.int32))
        return Cell(arch_id, cell.name, "recsys", "retrieval", retrieve_ctr,
                    args, (p_shard, csh), (_ns(mesh, P()), _ns(mesh, P())),
                    (), meta)
    # bert4rec retrieval: one user history scored against all items
    def retrieve_b4r(params, items):
        h = recsys.bert4rec_hidden(params, c, items)[:, -1]
        v, i = recsys.sharded_streaming_topk(h, params["item_embed"], 1000)
        return v[0], i[0]

    args = (params, SDS((1, c.seq_len), jnp.int32))
    return Cell(arch_id, cell.name, "recsys", "retrieval", retrieve_b4r, args,
                (p_shard, _ns(mesh, P())), (_ns(mesh, P()), _ns(mesh, P())),
                (), meta)


# ---------------------------------------------------------------------------
# ISN (the paper's architecture)
# ---------------------------------------------------------------------------

def _isn_cell(arch_id, config, cell: ShapeCell, mesh, rules) -> Cell:
    from repro.isn import shard as isn_shard
    return isn_shard.build_serve_cell(arch_id, config, cell, mesh, rules,
                                      Cell)


# ---------------------------------------------------------------------------
# entry
# ---------------------------------------------------------------------------

def build_cell(arch_id: str, shape_name: str, mesh,
               rules_override: dict | None = None,
               config_override=None) -> Cell:
    config, family = registry.get_arch(arch_id)
    if config_override is not None:
        config = config_override
    cell = FAMILY_SHAPES[family][shape_name]
    rules = rules_for(family, cell)
    if rules_override:
        rules.update(rules_override)
    if family == "lm":
        return _lm_cell(arch_id, config, cell, mesh, rules)
    if family == "gnn":
        return _gnn_cell(arch_id, config, cell, mesh, rules)
    if family == "recsys":
        return _recsys_cell(arch_id, config, cell, mesh, rules)
    if family == "isn":
        return _isn_cell(arch_id, config, cell, mesh, rules)
    raise ValueError(family)
