"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
device query.
"""

from __future__ import annotations

import contextlib

import jax

from repro.models.common import use_abstract_mesh


@contextlib.contextmanager
def mesh_context(mesh):
    """Enter BOTH the physical and abstract mesh contexts.

    ``get_abstract_mesh()`` inside jit tracing only sees the mesh under
    ``use_abstract_mesh`` — model code (MoE shard_map, constraint helpers)
    relies on it.  On jax 0.4.37 (no abstract-mesh API) the thread-local
    fallback in ``repro.models.common`` carries the *concrete* mesh, which
    every consumer (axis_names / shape / NamedSharding) accepts."""
    if hasattr(jax.sharding, "use_abstract_mesh"):
        abstract = mesh.abstract_mesh
    else:
        abstract = mesh
    with mesh, use_abstract_mesh(abstract):
        yield mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Degenerate mesh over however many real devices exist (tests/smoke)."""
    n = len(jax.devices())
    data = max(n // model_axis, 1)
    return jax.make_mesh((data, model_axis), ("data", "model"))


def mesh_info(mesh) -> dict:
    return {
        "axes": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "n_devices": int(mesh.devices.size),
    }
