"""Serving entry point: name an operating point, build the system it
describes, fit it, and serve a query trace through the multi-shard cascade
with end-to-end tail-latency accounting.

The whole assembly is the declarative lifecycle —
``build_system(preset, corpus).fit(queries, labels).serve(...)`` — the
inline corpus/train/assemble code this file used to carry lives behind
``SearchSystem`` now.

``python -m repro.launch.serve --preset paper_200ms --shards 3``
"""

from __future__ import annotations

import argparse
import dataclasses


def _emit_telemetry(system, args):
    """Write/print the requested telemetry exports after a serve."""
    if system.telemetry is None:
        return
    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            f.write(system.render_snapshot("json"))
        print(f"[serve] wrote metrics snapshot to {args.metrics_json}")
    if args.metrics_prom:
        with open(args.metrics_prom, "w") as f:
            f.write(system.render_snapshot("prom"))
        print(f"[serve] wrote prometheus metrics to {args.metrics_prom}")
    if args.trace_slowest:
        from repro.serving.telemetry import why_slow
        traces = system.telemetry.traces.slowest(args.trace_slowest)
        print(f"[serve] {len(traces)} slowest traces "
              f"(of {system.telemetry.traces.offered} offered):")
        for tr in traces:
            w = why_slow(tr)
            mark = " VIOLATION" if tr.violation else ""
            print(f"[serve]   qid={tr.qid} latency={tr.latency_us:.1f} "
                  f"mode={tr.meta.get('mode', '?')}{mark}: {w['detail']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="paper_200ms",
                    help="named operating point "
                         "(repro.configs.cascade_presets)")
    ap.add_argument("--shards", type=int, default=1,
                    help="doc-range shards for scatter-gather Stage-1")
    ap.add_argument("--replicas", type=int, default=2,
                    help="ISN replicas per shard partition")
    ap.add_argument("--n-docs", type=int, default=16384)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--queries", type=int, default=2000)
    ap.add_argument("--budget", type=float, default=None,
                    help="override the preset's latency budget")
    ap.add_argument("--backend", default=None,
                    help="pallas | interpret | jnp (default: preset/auto)")
    ap.add_argument("--no-ltr", action="store_true",
                    help="serve the first stage only (no Stage-2 re-rank)")
    ap.add_argument("--pseudo-labels", action="store_true",
                    help="skip the label oracle; fit on cheap pseudo-labels "
                         "(CI smokes)")
    ap.add_argument("--spec-json", default=None,
                    help="write the resolved spec to this path and exit")
    ap.add_argument("--dryrun", action="store_true",
                    help="cost the resolved spec against the query log "
                         "WITHOUT building the index (repro.launch."
                         "dryrun_cascade) and exit")
    ap.add_argument("--online", action="store_true",
                    help="serve the trace under load through the online "
                         "subsystem (event-driven arrivals, micro-batching,"
                         " admission control) and report response-time "
                         "percentiles, queueing included")
    ap.add_argument("--arrival", default="poisson",
                    help="online arrival process: poisson | bursty | "
                         "diurnal | trace")
    ap.add_argument("--qps", type=float, default=None,
                    help="offered load (queries per 1000 cost units, i.e. "
                         "QPS at paper scale); default: --load x measured "
                         "capacity")
    ap.add_argument("--load", type=float, default=0.8,
                    help="offered load as a fraction of measured capacity "
                         "(used when --qps is not given)")
    ap.add_argument("--max-batch", type=int, default=None,
                    help="override the preset's micro-batch width cap")
    ap.add_argument("--no-admission", action="store_true",
                    help="disable admission control (baseline mode)")
    ap.add_argument("--cache", action="store_true",
                    help="put the two-level result cache in front of the "
                         "cascade (L1 exact results + L2 Stage-1 "
                         "candidates; repro.serving.cache)")
    ap.add_argument("--cache-entries", type=int, default=None,
                    help="entry cap for each cache level (implies --cache)")
    ap.add_argument("--cache-bytes", type=int, default=None,
                    help="byte cap for each cache level (implies --cache)")
    ap.add_argument("--dense", action="store_true",
                    help="enable the dense Stage-1 modality: Stage-0 "
                         "dispatches each query lexical / dense / "
                         "both+fused (repro.dense)")
    ap.add_argument("--fusion", default=None, choices=["rrf", "weighted"],
                    help="hybrid fusion method for both-routed queries "
                         "(implies --dense)")
    ap.add_argument("--theta-high", type=float, default=None,
                    help="top dense score above which Stage-2 is skipped "
                         "rank-safely (implies --dense)")
    ap.add_argument("--theta-low", type=float, default=None,
                    help="top dense score below which a rho_late-capped "
                         "lexical fallback replaces the dense candidates "
                         "(implies --dense)")
    ap.add_argument("--ingest", action="store_true",
                    help="serve while the collection mutates: a seeded "
                         "document feed lands in a capacity-bounded delta "
                         "tile-set, background merges reseal the index "
                         "(repro.index.delta); online mode only")
    ap.add_argument("--feed-qps", type=float, default=None,
                    help="feed-batch arrivals per 1000 cost units "
                         "(implies --ingest)")
    ap.add_argument("--delta-docs", type=int, default=None,
                    help="delta tile-set doc capacity; must be >= k_serve "
                         "(implies --ingest)")
    ap.add_argument("--delta-postings", type=int, default=None,
                    help="delta tile-set postings capacity — sizes the "
                         "worst-case delta-scan term charged into every "
                         "query's bound (implies --ingest)")
    ap.add_argument("--zipf-skew", type=float, default=0.0,
                    help="Zipfian query-repetition skew for --online "
                         "traffic (0 = every query distinct, in order)")
    ap.add_argument("--trace-path", default="",
                    help="recorded arrival timestamps (.npy or JSON list) "
                         "for --arrival trace")
    ap.add_argument("--traffic-seed", type=int, default=0)
    ap.add_argument("--fault-scenario", default=None,
                    help="inject a named deterministic fault schedule: "
                         "none | crash_one | rolling_restart | stragglers |"
                         " timeout_storm | partition_outage "
                         "(repro.serving.faults)")
    ap.add_argument("--fault-json", default=None,
                    help="inject a FaultSpec from a JSON file (overrides "
                         "--fault-scenario)")
    ap.add_argument("--failover-timeout", type=float, default=None,
                    help="scatter-gather shard timeout (cost units); "
                         "required (directly or via the preset) when the "
                         "fault schedule can kill requests")
    ap.add_argument("--max-retries", type=int, default=None,
                    help="bounded failover re-issues per (query, shard); "
                         "charged into the worst-case bound")
    ap.add_argument("--fault-horizon", type=float, default=10_000.0,
                    help="trace horizon (cost units) named scenarios are "
                         "sized against")
    ap.add_argument("--metrics-json", default=None,
                    help="write the telemetry snapshot (deterministic "
                         "JSON) to this path after serving (enables "
                         "telemetry)")
    ap.add_argument("--metrics-prom", default=None,
                    help="write the snapshot in Prometheus text format to "
                         "this path after serving (enables telemetry)")
    ap.add_argument("--trace-slowest", type=int, default=0,
                    help="print the N slowest/violating query traces with "
                         "a why-slow attribution (enables telemetry)")
    args = ap.parse_args()

    from repro.configs.cascade_presets import get_preset
    from repro.core.labels import LabelConfig, generate_labels
    from repro.index.corpus import CorpusParams, build_corpus, build_queries
    from repro.serving.system import build_system

    spec = get_preset(args.preset)
    online = spec.online
    if args.max_batch is not None:
        online = dataclasses.replace(online, max_batch=args.max_batch)
    if args.no_admission:
        online = dataclasses.replace(online, admission=False)
    routing = spec.routing
    if args.budget is not None:
        routing = dataclasses.replace(routing, budget=args.budget)
    if args.failover_timeout is not None:
        routing = dataclasses.replace(routing,
                                      failover_timeout=args.failover_timeout)
    if args.max_retries is not None:
        routing = dataclasses.replace(routing, max_retries=args.max_retries)
    fault = spec.fault
    if args.fault_json:
        import json

        from repro.serving.spec import FaultSpec
        with open(args.fault_json) as f:
            fault = FaultSpec(**json.load(f))
    elif args.fault_scenario:
        from repro.serving.faults import fault_scenario
        fault = fault_scenario(args.fault_scenario,
                               n_partitions=args.shards,
                               replicas=args.replicas,
                               horizon=args.fault_horizon,
                               seed=args.traffic_seed)
    cache = spec.cache
    if (args.cache or args.cache_entries is not None
            or args.cache_bytes is not None):
        kw = {"enabled": True}
        if args.cache_entries is not None:
            kw["l1_entries"] = kw["l2_entries"] = args.cache_entries
        if args.cache_bytes is not None:
            kw["l1_bytes"] = kw["l2_bytes"] = args.cache_bytes
        cache = dataclasses.replace(cache, **kw)
    ingest = spec.ingest
    if (args.ingest or args.feed_qps is not None
            or args.delta_docs is not None
            or args.delta_postings is not None):
        kw = {"enabled": True}
        if args.feed_qps is not None:
            kw["feed_qps"] = args.feed_qps
        if args.delta_docs is not None:
            kw["delta_docs"] = args.delta_docs
        if args.delta_postings is not None:
            kw["delta_postings"] = args.delta_postings
        ingest = dataclasses.replace(ingest, **kw)
    dense, fusion = spec.dense, spec.fusion
    if (args.dense or args.fusion is not None
            or args.theta_high is not None or args.theta_low is not None):
        kw = {"enabled": True}
        if args.theta_high is not None:
            kw["theta_high"] = args.theta_high
        if args.theta_low is not None:
            kw["theta_low"] = args.theta_low
        dense = dataclasses.replace(dense, **kw)
    if args.fusion is not None:
        fusion = dataclasses.replace(fusion, method=args.fusion)
    telemetry = spec.telemetry
    if args.metrics_json or args.metrics_prom or args.trace_slowest:
        telemetry = dataclasses.replace(telemetry, enabled=True)
    spec = dataclasses.replace(
        spec,
        deploy=dataclasses.replace(spec.deploy, n_shards=args.shards,
                                   replicas=args.replicas),
        routing=routing,
        fault=fault,
        cache=cache,
        dense=dense,
        fusion=fusion,
        ingest=ingest,
        telemetry=telemetry,
        stage2=(spec.stage2 if not args.no_ltr else
                dataclasses.replace(spec.stage2, enabled=False)),
        backend=(spec.backend if args.backend is None else
                 dataclasses.replace(spec.backend, backend=args.backend)),
        online=online,
    ).validate()
    if args.spec_json:
        with open(args.spec_json, "w") as f:
            f.write(spec.to_json() + "\n")
        print(f"[serve] wrote spec to {args.spec_json}")
        return

    print(f"[serve] preset={spec.name} shards={args.shards} "
          f"budget={spec.routing.budget:.0f}")
    print("[serve] building collection ...")
    corpus = build_corpus(CorpusParams(n_docs=args.n_docs, vocab=args.vocab,
                                       avg_doclen=150, zipf_a=1.05))
    if args.dryrun:
        from repro.launch.dryrun_cascade import dryrun, render
        print(render(dryrun(spec, corpus, n_queries=args.queries)))
        return
    system = build_system(spec, corpus)
    ql = build_queries(corpus, args.queries, stop_k=spec.index.stop_k)

    labels = None
    if not args.pseudo_labels:
        print("[serve] generating oracle labels ...")
        # label the trace with the SYSTEM's cost model: fit() treats the
        # label times as measured and regresses them back into the rates
        labels = generate_labels(system.index, corpus, ql,
                                 LabelConfig(max_k=4096, batch=256),
                                 cost=system.cost)
    print("[serve] fitting Stage-0 predictors"
          + ("" if args.no_ltr or not spec.stage2.enabled
             else " + Stage-2 LTR model") + " ...")
    system.fit(ql, labels)

    if args.online:
        from repro.serving.online import estimate_capacity, fresh_probe
        from repro.serving.spec import TrafficSpec
        topics = ql.topic if system.ltr is not None else None
        qps = args.qps
        if qps is None and args.arrival != "trace":
            print(f"[serve] measuring capacity (max_batch="
                  f"{spec.online.max_batch}) ...")
            # throwaway clone of the FITTED operating point (calibrated
            # thresholds + regressed cost), so the warm-up batches don't
            # perturb the measured system and the load fraction is
            # relative to its real capacity
            qps = args.load * estimate_capacity(fresh_probe(system),
                                                ql.terms, ql.mask, topics)
        qps = qps if qps is not None else 1.0  # unused by trace replay
        traffic = TrafficSpec(arrival=args.arrival, qps=qps,
                              seed=args.traffic_seed, skew=args.zipf_skew,
                              trace_path=args.trace_path)
        src = (f"trace {args.trace_path}" if args.arrival == "trace"
               else f"qps={qps:.1f}")
        print(f"[serve] online: {args.arrival} arrivals @ {src}, "
              f"max_batch={spec.online.max_batch} "
              f"deadline={spec.online.batch_deadline_us:.1f} "
              f"admission={spec.online.admission}")
        r = system.serve_online(ql.terms, ql.mask, topics, traffic=traffic)
        s = r.stats
        line = (f"[serve] served {s['served']}/{s['n_queries']} "
                f"(shed {s['shed']}, {s['shed_pct']:.2f}%) in "
                f"{s['batches']} batches")
        if s.get("batch"):
            line += f" (mean size {s['batch']['mean_size']:.1f})"
        print(line)
        print(f"[serve] modes: {s['modes']}")
        if "response" in s:
            p = s["response"]
            print(f"[serve] response ms (queueing included): "
                  f"p50={p['p50']:.1f} p99={p['p99']:.1f} "
                  f"p99.99={p['p99.99']:.1f} max={p['max']:.1f}")
            for name, sp in s["stages"].items():
                print(f"[serve] {name:7s} ms: p50={sp['p50']:.2f} "
                      f"p99={sp['p99']:.2f} max={sp['max']:.2f}")
        if "cache" in s:
            c = s["cache"]
            print(f"[serve] cache: hit_ratio={c['hit_ratio']:.3f} "
                  f"(l1={c['l1_hits']} l2={c['l2_hits']} "
                  f"miss={c['full_misses']}), front-door "
                  f"hits={c['front_door_hits']}"
                  + (f", ewma={c['hit_ewma']:.3f}" if "hit_ewma" in c
                     else ""))
        if "dense" in s:
            d = s["dense"]
            print(f"[serve] dense: lex={d['lexical']} "
                  f"dense={d['dense_only']} fused={d['fused']} "
                  f"theta_skips={d['theta_skips']} "
                  f"fallbacks={d['fallbacks']}")
        if "ingest" in s:
            i = s["ingest"]
            print(f"[serve] ingest: docs={i['docs_ingested']} in "
                  f"{i['feed_batches']} batches "
                  f"(due {i.get('feed_batches_due', '?')}, throttled "
                  f"{i.get('feed_throttled', 0)}), merges={i['merges']} "
                  f"(deferred {i.get('merge_deferred', 0)}, forced "
                  f"{i.get('merges_forced', 0)}), delta "
                  f"{i['delta_docs']}/{i['capacity_docs']} docs "
                  f"fill={i['fill']:.2f}, "
                  f"delta_us={i['delta_us']:.1f}")
        if "coverage" in s:
            c = s["coverage"]
            print(f"[serve] coverage: min={c['min']:.2f} "
                  f"mean={c['mean']:.3f} degraded={c['degraded']}")
        if "faults" in s:
            f = s["faults"]
            print(f"[serve] faults: retries={f['retries']} "
                  f"lost={f['lost_partitions']} no_route={f['no_route']} "
                  f"transient={f['transient']} probes={f['probes']} "
                  f"recovered={f['recovered']}")
        print(f"[serve] over response budget ({s['response_budget']:.0f}): "
              f"{s['over_budget']} ({s['over_budget_pct']:.4f}%)")
        _emit_telemetry(system, args)
        return

    print("[serve] serving trace through the cascade ...")
    res = system.serve(ql.terms, ql.mask,
                       ql.topic if system.ltr is not None else None)
    s = res.stats
    print(f"[serve] routed: jass={s['jass']} bmw={s['bmw']} "
          f"hedged={s['hedged']} late={s['late_hedged']}"
          f"+{s['late_hedged_jass']}jass")
    b = s["budget"]
    print(f"[serve] guarantee: enforce={b['enforce']} "
          f"worst-case bound={b['worst_case_bound']:.1f} "
          f"(budget {b['total']:.0f}, stage-1 reserve "
          f"{b['reserve']['stage1']:.1f}); "
          f"stage-2 trimmed={b['stage2_trimmed']} "
          f"skipped={b['stage2_skipped']}")
    if "cache" in s:
        c = s["cache"]
        print(f"[serve] cache: hit_ratio={c['hit_ratio']:.3f} "
              f"(l1={c['l1_hits']} l2={c['l2_hits']} "
              f"miss={c['full_misses']})")
    if "dense" in s:
        d = s["dense"]
        print(f"[serve] dense: lex={d['lexical']} dense={d['dense_only']} "
              f"fused={d['fused']} theta_skips={d['theta_skips']} "
              f"fallbacks={d['fallbacks']}")
    for name, p in s.get("stages", {}).items():
        print(f"[serve] {name:7s} ms: p50={p['p50']:.2f} p99={p['p99']:.2f} "
              f"max={p['max']:.2f}")
    print(f"[serve] cascade ms: p50={s['p50']:.1f} p99={s['p99']:.1f} "
          f"p99.99={s['p99.99']:.1f} max={s['max']:.1f}")
    print(f"[serve] over budget ({system.budget:.0f}): {s['over_budget']} "
          f"({s['over_budget_pct']:.4f}%)")
    if "coverage" in s:
        c = s["coverage"]
        f = s["faults"]
        print(f"[serve] faults: coverage min={c['min']:.2f} "
              f"mean={c['mean']:.3f} degraded={c['degraded']}; "
              f"retries={f['retries']} lost={f['lost_partitions']} "
              f"probes={f['probes']} recovered={f['recovered']}")
    if res.final is not None:
        print(f"[serve] stage-2: mean candidates="
              f"{res.candidates_used.mean():.1f} "
              f"final depth={res.final.shape[1]}")
    pool = system.stats()["pool"]
    print(f"[serve] pool: {pool['healthy']}/{pool['replicas']} healthy, "
          f"mirrors jass={pool['jass']} bmw={pool['bmw']} "
          f"(fraction {pool['jass_fraction']:.2f}), "
          f"served={pool['served']}")
    _emit_telemetry(system, args)


if __name__ == "__main__":
    main()
