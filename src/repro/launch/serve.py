"""Serving entry point: build a synthetic collection, train the Stage-0
predictors and the Stage-2 LTR model, and serve a query trace through the
**full cascade pipeline** (Stage-0 → hybrid routing → Stage-1 engines →
Stage-2 re-rank) with end-to-end tail-latency accounting.

``python -m repro.launch.serve --queries 2000 --budget 200``
"""

from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=16384)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--queries", type=int, default=2000)
    ap.add_argument("--budget", type=float, default=200.0)
    ap.add_argument("--algorithm", type=int, default=2)
    ap.add_argument("--t-final", type=int, default=10)
    ap.add_argument("--no-ltr", action="store_true",
                    help="serve the first stage only (no Stage-2 re-rank)")
    args = ap.parse_args()

    import numpy as np

    from repro.core import features as F, gbrt
    from repro.core.labels import LabelConfig, generate_labels
    from repro.index.builder import build_index
    from repro.index.corpus import CorpusParams, build_corpus, build_queries
    from repro.ltr.ranker import ltr_training_set, train_ltr
    from repro.serving.pipeline import CascadePipeline
    from repro.serving.scheduler import SchedulerConfig
    import jax.numpy as jnp

    print("[serve] building collection + labels ...")
    corpus = build_corpus(CorpusParams(n_docs=args.n_docs, vocab=args.vocab,
                                       avg_doclen=150, zipf_a=1.05))
    index = build_index(corpus, stop_k=16)
    ql = build_queries(corpus, args.queries, stop_k=16)
    labels = generate_labels(index, corpus, ql,
                             LabelConfig(max_k=4096, batch=256))

    x = np.asarray(F.extract(jnp.asarray(index.term_stats),
                             jnp.asarray(index.df),
                             jnp.asarray(ql.terms), jnp.asarray(ql.mask)))
    print("[serve] training Stage-0 predictors (QR) ...")
    models = {}
    for name, y, tau in (("k", labels.oracle_k, 0.55),
                         ("rho", labels.oracle_rho, 0.45),
                         ("t", labels.t_bmw, 0.5)):
        models[name] = gbrt.fit(
            x, np.log1p(y.astype(np.float32)),
            gbrt.GBRTParams(n_trees=48, depth=5, loss="quantile", tau=tau))

    ltr = None
    if not args.no_ltr:
        print("[serve] training Stage-2 LTR model ...")
        train_rows = np.flatnonzero(labels.keep)[:256]
        lf, lg = ltr_training_set(index, corpus, ql, labels.ref_lists,
                                  train_rows)
        ltr = train_ltr(lf, lg)

    cfg = SchedulerConfig(algorithm=args.algorithm, budget=args.budget,
                          rho_max=1 << 18)
    pipe = CascadePipeline(index, models, cfg, corpus=corpus, ltr=ltr,
                           t_final=args.t_final)
    print("[serve] serving trace through the cascade ...")
    res = pipe.serve(ql.terms, ql.mask, ql.topic)
    s = res.stats
    print(f"[serve] routed: jass={s['jass']} bmw={s['bmw']} "
          f"hedged={s['hedged']} late={s['late_hedged']}")
    for name, p in s.get("stages", {}).items():
        print(f"[serve] {name:7s} ms: p50={p['p50']:.2f} p99={p['p99']:.2f} "
              f"max={p['max']:.2f}")
    print(f"[serve] cascade ms: p50={s['p50']:.1f} p99={s['p99']:.1f} "
          f"p99.99={s['p99.99']:.1f} max={s['max']:.1f}")
    print(f"[serve] over budget ({args.budget:.0f}): {s['over_budget']} "
          f"({s['over_budget_pct']:.4f}%)")
    if res.final is not None:
        print(f"[serve] stage-2: mean candidates={res.candidates_used.mean():.1f} "
              f"final depth={res.final.shape[1]}")


if __name__ == "__main__":
    main()
