"""Training entry point: ``python -m repro.launch.train --arch <id> ...``

Runs a real (reduced-config by default) training job on the local devices
with the full production loop: sharded params, grad accumulation,
checkpoint/restart, resumable data cursor.
"""

from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--full-config", action="store_true",
                    help="use the production config (needs a real cluster)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--resume", action="store_true", default=True)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a crash at this step (restart testing)")
    args = ap.parse_args()

    import jax
    from repro.configs import registry
    from repro.data import synthetic
    from repro.data.pipeline import PrefetchingLoader
    from repro.models import transformer as tr
    from repro.train import train_loop

    config, family = (registry.get_arch if args.full_config
                      else registry.get_reduced)(args.arch)
    if family != "lm":
        raise SystemExit("train.py drives the LM family; see examples/ for "
                         "gnn/recsys training drivers")

    params, _ = tr.init(config, jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"[train] arch={config.name} params={n_params/1e6:.1f}M "
          f"devices={len(jax.devices())}")

    def loss_fn(params, batch):
        return tr.loss_fn(params, config, batch["tokens"], batch["labels"])

    gen = synthetic.lm_batches(config.vocab, args.batch, args.seq)
    loader = PrefetchingLoader(gen)
    cfg = train_loop.TrainConfig(steps=args.steps,
                                 microbatches=args.microbatches,
                                 ckpt_dir=args.ckpt_dir)
    params, opt, losses = train_loop.run(params, loss_fn, loader, cfg,
                                         resume=args.resume,
                                         fail_at=args.fail_at)
    print(f"[train] done: loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    loader.close()


if __name__ == "__main__":
    main()
