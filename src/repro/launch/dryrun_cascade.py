"""Spec-driven serving dry-run: cost a :class:`CascadeSpec` against a query
log *before* building the index.

The mesh dry-run (``repro.launch.dryrun``) answers "does this model fit and
what do the rooflines say" without training; this is the serving-side
counterpart: given an operating point (preset or spec JSON) and a corpus +
query trace, it predicts the cascade's latency distribution, budget
violations, and the hard worst-case bound from *collection statistics
alone* — document frequencies read straight off the corpus postings, no
inverted index, tile mirrors, or trained predictors required.  An operator
can therefore cost a ``DeploySpec`` (shards × replicas, ρ caps, budget,
late-hedge knobs) in seconds and only then pay for the build.

The costing is **hybrid**: pre- and post-build share one code path
(:class:`WorkProxies`), only the statistics powering the proxies differ.

Pre-build (corpus df only — deliberately conservative upper bounds):

* BMW/DAAT work per query = the full posting mass of its terms scaled by
  ``daat_prune`` (1.0 = exhaustive upper bound; the paper's dynamic
  pruning typically evaluates far less); blocks = mass / block_size;
* JASS/SAAT work = ``min(ρ, mass)`` — the anytime traversal can never do
  more than its budget nor more than the postings that exist;

Post-build (``index=`` given — strictly more accurate, same schema):

* df comes off the built index (stoplist already applied);
* JASS work resolves the ρ budget against the index's **real impact-level
  table** (``level_cum``) to the same global level cut the serving system
  uses — the exact posting count the traversal would touch, instead of
  the ``min(ρ, mass)`` ceiling;
* BMW blocks come from the real block-max structure (``block_count > 0``
  per term) instead of the perfectly-packed ``mass / block_size``
  estimate (a lower bound — the real spread is wider).

Either way, scatter-gather splits work uniformly across ``n_shards``
doc-range shards (the expectation under random doc placement) and charges
``CostModel.gather_time``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun_cascade --preset paper_200ms
  PYTHONPATH=src python -m repro.launch.dryrun_cascade \
      --spec-json spec.json --n-docs 65536 --queries 31642 --out dry.json
  PYTHONPATH=src python -m repro.launch.dryrun_cascade --build-index
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

from repro.index.corpus import Corpus, QueryLog, build_queries
from repro.serving.latency import (CostModel, budget_attribution,
                                   over_budget, percentiles,
                                   resolve_level_cut, stage2_afford)
from repro.serving.scheduler import StageZeroScheduler
from repro.serving.spec import CascadeSpec
from repro.serving.system import scheduler_config

# bytes per posting in the device mirrors: docid+impact int32 lanes (SAAT)
# + docid+score+block metadata (DAAT) — matches serving/latency.py
_MIRROR_BYTES_PER_POSTING = 8 + 10


def corpus_df(corpus: Corpus, stop_k: int = 0) -> np.ndarray:
    """Per-term document frequencies straight off the corpus postings —
    the only collection statistic the pre-build dry-run needs.
    ``stop_k`` zeroes the stoplisted most-frequent terms, matching what
    ``build_index`` would drop."""
    df = np.bincount(corpus.postings_term, minlength=corpus.vocab)
    df[:stop_k] = 0
    return df


class WorkProxies:
    """Per-query Stage-1 work estimates — the single code path behind the
    hybrid pre/post-build costing (see module docstring).

    Pre-build, only ``df`` is known; post-build, the real impact-level
    table sharpens JASS work to the exact global level cut (never above
    the ``min(ρ, mass)`` ceiling) and the real block-max structure
    replaces the perfectly-packed ``mass / block_size`` block estimate
    with the true per-term block spread — which can only be wider, so the
    pre-build path *under*-costs DAAT block overhead."""

    def __init__(self, df: np.ndarray, block_size: int,
                 level_cum: np.ndarray | None = None,
                 blocks_per_term: np.ndarray | None = None):
        self.df = np.asarray(df, np.float64)
        self.block_size = block_size
        self.level_cum = level_cum
        self.blocks_per_term = (None if blocks_per_term is None
                                else np.asarray(blocks_per_term, np.float64))

    @classmethod
    def from_corpus(cls, corpus: Corpus, spec: CascadeSpec) -> "WorkProxies":
        return cls(corpus_df(corpus, spec.index.stop_k),
                   spec.index.block_size)

    @classmethod
    def from_index(cls, index, spec: CascadeSpec) -> "WorkProxies":
        return cls(index.df, index.block_size,
                   level_cum=np.asarray(index.level_cum),
                   blocks_per_term=(np.asarray(index.block_count) > 0)
                   .sum(axis=1))

    @property
    def post_build(self) -> bool:
        return self.level_cum is not None

    def mass(self, terms, mask) -> np.ndarray:
        return (self.df[terms] * (mask > 0)).sum(axis=1)

    def bmw(self, terms, mask, daat_prune: float = 1.0):
        """(work, blocks) for a BMW/DAAT traversal."""
        work = self.mass(terms, mask) * daat_prune
        if self.blocks_per_term is None:
            blocks = work / self.block_size
        else:
            blocks = ((self.blocks_per_term[terms] * (mask > 0))
                      .sum(axis=1) * daat_prune)
        return work, blocks

    def jass(self, terms, mask, rows, rho) -> np.ndarray:
        """Postings a ρ-budgeted SAAT traversal touches for ``rows``."""
        rho = np.asarray(rho, np.float64)
        if self.level_cum is None:
            # row-local mass: don't re-reduce the whole query log just to
            # index a subset (jass_fn is called per enforcement mode and
            # per late-hedge re-issue)
            return np.minimum(rho, self.mass(terms[rows], mask[rows]))
        # the served system's own resolution (shared helper — see
        # SearchSystem._jass_split): the ρ budget picks the deepest
        # global impact-level cut that fits
        m = (mask[rows] > 0)[:, :, None]
        totals = (self.level_cum[terms[rows]] * m).sum(axis=1)  # (R, L)
        lstar, any_ok = resolve_level_cut(totals, rho)
        rr = np.arange(len(rows))
        return np.where(any_ok, totals[rr, lstar], 0).astype(np.float64)


def dryrun(spec: CascadeSpec, corpus: Corpus, ql: QueryLog | None = None,
           n_queries: int = 2000, seed: int = 7,
           daat_prune: float = 1.0, index=None) -> dict:
    """Modeled cascade latency for ``spec`` over a query log; returns the
    percentile table, violations with and without enforcement, the analytic
    worst-case bound, and a deployment size estimate.

    ``index``: an already-built :class:`~repro.index.builder.InvertedIndex`
    switches the work proxies to its real block-max / impact-level
    distributions (strictly more accurate; same output schema)."""
    spec.validate()
    cost = getattr(CostModel, spec.backend.cost)()
    proxies = (WorkProxies.from_index(index, spec) if index is not None
               else WorkProxies.from_corpus(corpus, spec))
    if ql is None:
        ql = build_queries(corpus, n_queries, stop_k=spec.index.stop_k,
                           seed=seed)
    q = len(ql.terms)
    ns = spec.deploy.n_shards
    mass = proxies.mass(ql.terms, ql.mask)

    # Stage-0 proxy predictions: the same posting-mass recipe fit() uses
    # for pseudo-labels, so routing exercises both mirrors realistically
    rng = np.random.RandomState(seed)
    noise = [np.exp(rng.randn(q) * 0.3) for _ in range(3)]
    pred_k = mass * 0.05 * noise[0]
    pred_rho = mass * 0.5 * noise[1]
    work_bmw, blocks_bmw = proxies.bmw(ql.terms, ql.mask, daat_prune)
    pred_t = cost.daat_time(work_bmw, blocks_bmw) * noise[2]

    # the same budget attribution SearchSystem.set_models applies
    cfg = scheduler_config(spec.routing)
    reserve = budget_attribution(
        cfg.budget, cost,
        spec.stage2.k_serve if spec.stage2.enabled else None)
    reserve2, budget1 = reserve["stage2"], reserve["stage1"]
    if spec.dense.enabled:
        # mirror SearchSystem._attribute_budget: the fusion merge is carved
        # out of the stage-1 share so both-routed queries stay in bound
        budget1 = max(budget1 - cost.fusion_us, 0.0)

    # dense Stage-1 is shape-static: every query scores every doc tile of
    # its shard, so the per-shard time is exact from the spec alone —
    # ceil(shard_docs / tile_d) tiles through CostModel.dense_time
    dense_tiles = 0
    t_dense_r = None
    if spec.dense.enabled:
        shard_docs = -(-corpus.n_docs // ns)       # largest contiguous range
        dense_tiles = -(-shard_docs // spec.dense.tile_d)
        t_dense_r = cost.gather_time(np.broadcast_to(
            cost.dense_time(dense_tiles), (ns, q)))

    def shardwise(time_fn, work, *extra):
        per = [time_fn(work / ns, *(e / ns for e in extra))
               for _ in range(ns)]
        return cost.gather_time(np.stack(per))

    t_bmw = shardwise(cost.daat_time, work_bmw, blocks_bmw)

    def jass_fn(rows, rho):
        work = proxies.jass(ql.terms, ql.mask, rows, rho)
        return shardwise(cost.saat_time, work)

    out = {}
    for mode, mode_cfg in (
            ("enforced", dataclasses.replace(cfg, budget=budget1)),
            ("unenforced", dataclasses.replace(
                cfg, budget=budget1, enforce_budget=False,
                late_rho=cfg.rho_max))):
        sched = StageZeroScheduler(mode_cfg, cost)
        routed = sched.route(pred_k, pred_rho, pred_t)
        modality = None
        if spec.dense.enabled:
            # the same dispatch rule SearchSystem._modality applies, on the
            # same predicted traversal time the router saw
            ds = spec.dense
            td = ds.t_dense if ds.t_dense > 0 else sched.cfg.t_time
            modality = np.full(q, 2, np.int64)
            modality[pred_t <= td * (1.0 - ds.fuse_band)] = 0
            modality[pred_t > td * (1.0 + ds.fuse_band)] = 1
            lex = modality != 1

            def keep(rows, stat):
                kept = rows[lex[rows]]
                sched.stats[stat] -= int(len(rows) - len(kept))
                return kept

            routed = dataclasses.replace(
                routed, jass_rows=keep(routed.jass_rows, "jass"),
                bmw_rows=keep(routed.bmw_rows, "bmw"),
                hedged_rows=keep(routed.hedged_rows, "hedged"))
        lat01 = sched.resolve_times(routed, t_bmw, jass_fn)
        if modality is not None:
            pd = cost.predict_us
            lat01 = np.where(modality == 1, pd + t_dense_r, lat01)
            lat01 = np.where(modality == 2,
                             pd + np.maximum(lat01 - pd, t_dense_r)
                             + cost.fusion_us, lat01)
        lat = lat01
        trimmed = skipped = 0
        if spec.stage2.enabled:
            k2 = np.minimum(routed.k, spec.stage2.k_serve)
            if mode_cfg.enforce_budget:
                afford = stage2_afford(cost, cfg.budget - lat01,
                                       spec.stage2.k_serve)
                trimmed = int(np.sum((0 < afford) & (afford < k2)))
                skipped = int(np.sum((afford == 0) & (k2 > 0)))
                k2 = np.minimum(k2, afford)
            lat = lat01 + np.where(k2 > 0, cost.ltr_time(k2), 0.0)
        n_over, pct = over_budget(lat, cfg.budget)
        out[mode] = {"percentiles": percentiles(lat),
                     "over_budget": n_over, "over_budget_pct": pct,
                     "routed": {k: int(sched.stats[k]) for k in
                                ("jass", "bmw", "hedged", "late_hedged",
                                 "late_hedged_jass")},
                     "stage2_trimmed": trimmed, "stage2_skipped": skipped}
        if modality is not None:
            out[mode]["dense"] = {
                "lexical": int(np.sum(modality == 0)),
                "dense_only": int(np.sum(modality == 1)),
                "fused": int(np.sum(modality == 2))}

    n_postings = int(corpus.n_postings)
    enforced_cfg = dataclasses.replace(cfg, budget=budget1)
    bound = enforced_cfg.worst_case_us(cost, ns)
    if spec.dense.enabled:
        # the same dense/both/fallback route bounds SearchSystem.
        # worst_case_us charges — analytic, from the tile count alone
        pd = cost.predict_us
        gather = cost.gather_per_shard_us * (ns - 1)
        td_b = (float(cost.dense_time(dense_tiles)) + gather
                + enforced_cfg.retry_us())
        fb = (float(cost.saat_time(np.float64(
                  enforced_cfg.resolved_late_rho()))) + gather
              if np.isfinite(spec.dense.theta_low) else 0.0)
        bound = max(bound, pd + td_b + fb,
                    pd + max(bound - pd, td_b) + cost.fusion_us)
    out["config"] = {
        "spec": spec.name, "n_queries": q, "n_shards": ns,
        "replicas": spec.deploy.replicas, "budget": cfg.budget,
        "stage1_budget": budget1, "daat_prune": daat_prune,
        "costing": "index" if proxies.post_build else "corpus",
        "worst_case_bound": bound + reserve2,
        "dense_tiles": dense_tiles,
        "max_late_rho": enforced_cfg.max_late_rho(cost, ns),
        "late_rho": enforced_cfg.resolved_late_rho(),
    }
    out["deploy_estimate"] = {
        "n_postings": n_postings,
        "mirror_bytes_per_shard": (n_postings * _MIRROR_BYTES_PER_POSTING
                                   // ns),
        "total_replica_bytes": (n_postings * _MIRROR_BYTES_PER_POSTING
                                * spec.deploy.replicas),
    }
    return out


def render(res: dict) -> str:
    c = res["config"]
    lines = [f"dryrun spec={c['spec']} shards={c['n_shards']} "
             f"costing={c.get('costing', 'corpus')} "
             f"budget={c['budget']:.1f} (stage-1 {c['stage1_budget']:.1f}) "
             f"late_rho={c['late_rho']} (max admissible "
             f"{c['max_late_rho']}) bound={c['worst_case_bound']:.1f}",
             "mode,p50,p99,p99.99,max,over_budget,late_hedged"]
    for mode in ("enforced", "unenforced"):
        r = res[mode]
        p = r["percentiles"]
        late = r["routed"]["late_hedged"] + r["routed"]["late_hedged_jass"]
        lines.append(f"{mode},{p['p50']:.1f},{p['p99']:.1f},"
                     f"{p['p99.99']:.1f},{p['max']:.1f},"
                     f"{r['over_budget']},{late}")
        if "dense" in r:
            d = r["dense"]
            lines.append(f"  dense mix: lex={d['lexical']} "
                         f"dense={d['dense_only']} fused={d['fused']} "
                         f"({c['dense_tiles']} tiles/shard)")
    d = res["deploy_estimate"]
    lines.append(f"deploy: {d['n_postings']} postings, "
                 f"{d['mirror_bytes_per_shard'] / 1e6:.1f} MB mirror/shard, "
                 f"{d['total_replica_bytes'] / 1e6:.1f} MB total replicas")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="paper_200ms")
    ap.add_argument("--spec-json", default=None,
                    help="cost a serialized CascadeSpec instead of a preset")
    ap.add_argument("--n-docs", type=int, default=16384)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--queries", type=int, default=2000)
    ap.add_argument("--shards", type=int, default=None)
    ap.add_argument("--daat-prune", type=float, default=1.0,
                    help="fraction of posting mass BMW evaluates "
                         "(1.0 = exhaustive upper bound)")
    ap.add_argument("--build-index", action="store_true",
                    help="build the index first and cost from its real "
                         "block-max/impact distributions (post-build "
                         "hybrid path)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    from repro.configs.cascade_presets import get_preset
    from repro.index.corpus import CorpusParams, build_corpus

    if args.spec_json:
        with open(args.spec_json) as f:
            spec = CascadeSpec.from_json(f.read())
    else:
        spec = get_preset(args.preset)
    if args.shards is not None:
        spec = dataclasses.replace(
            spec, deploy=dataclasses.replace(spec.deploy,
                                             n_shards=args.shards))
    corpus = build_corpus(CorpusParams(n_docs=args.n_docs, vocab=args.vocab,
                                       avg_doclen=150, zipf_a=1.05))
    index = None
    if args.build_index:
        from repro.index.builder import build_index
        index = build_index(corpus, block_size=spec.index.block_size,
                            stop_k=spec.index.stop_k)
    res = dryrun(spec, corpus, n_queries=args.queries,
                 daat_prune=args.daat_prune, index=index)
    print(render(res))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=2, default=float)
            f.write("\n")
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
