"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and extract memory / FLOP / collective analyses.

MUST be run as a module entry point; the XLA host-device flag below has to
land before jax initializes devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi_6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun.json
"""

import os

os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.launch.mesh import make_production_mesh, mesh_context   # noqa: E402
from repro.launch.steps import build_cell            # noqa: E402

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # B/s
ICI_BW = 50e9              # B/s per link

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(tok_dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(tok_dtype, 4)


_OP_RE = re.compile(
    r"=\s*(\(?(?:[a-z0-9]+\[[0-9,]*\]\S*\s*,?\s*)+\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in a (per-device) HLO
    module, keyed by op kind ('-done' ops skipped so starts count once)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shapes = _SHAPE_RE.findall(m.group(1))
        kind = m.group(2)
        out[kind] += sum(_shape_bytes(d, dims) for d, dims in shapes)
        counts[kind] += 1
    out["n_ops"] = counts
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def roofline(flops, mem_bytes, coll_bytes, n_chips) -> dict:
    """Three roofline terms in seconds (per device; the SPMD-partitioned
    module is a per-device program, so terms divide by per-chip rates)."""
    return {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": mem_bytes / HBM_BW,
        "collective_s": coll_bytes / ICI_BW,
    }


def memory_traffic_bytes(mem_info: dict, hlo_bytes: float) -> float:
    """HBM traffic estimate for the memory roofline term.

    XLA's HLO 'bytes accessed' counts every operand of every op at full
    size with no fusion model — on the host backend it overcounts real TPU
    traffic by 1-2 orders of magnitude.  The allocation-derived estimate
    (arguments read + outputs written + temp buffers written & read once)
    tracks what an IO-efficient schedule actually moves; the raw HLO number
    is kept in the record as an unfused upper bound."""
    a = mem_info.get("argument_size") or 0
    o = mem_info.get("output_size") or 0
    t = mem_info.get("temp_size") or 0
    est = a + o + 2 * t
    if est <= 0:
        return hlo_bytes
    return min(est, hlo_bytes) if hlo_bytes else est


# families whose step functions scan over a depth axis: HLO cost analysis
# counts loop bodies ONCE, so flops/bytes/collectives are extrapolated from
# two fully-unrolled reduced-depth compiles: cost(L) = outside + L·per_layer
_DEPTH_FIELD = {"lm": "n_layers", "gnn": "n_blocks", "recsys": "n_blocks"}


def _compile_cell(cell, mesh):
    jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings,
                     donate_argnums=cell.donate_argnums)
    return jitted.lower(*cell.args).compile()


def _cost_triple(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    return flops, bytes_acc, coll["total"], coll["n_ops"]


def exact_costs(arch, shape, mesh, cell, rules_override=None):
    """Extrapolated per-device costs from unrolled depth-1/2 variants."""
    import dataclasses

    from repro.configs import registry as reg
    config, family = reg.get_arch(arch)
    field = _DEPTH_FIELD.get(cell.family)
    depth = getattr(config, field, None) if field else None
    if not depth or depth < 1 or not hasattr(config, "cost_exact"):
        return None
    # depths (2, 3): single-layer modules get anomalous XLA layouts (e.g.
    # collectives hoisted differently), so the delta is taken deeper
    d_lo, d_hi = (2, 3) if depth >= 3 else (1, 2)
    costs = {}
    for d in (d_lo, d_hi):
        kw = {field: d, "cost_exact": True}
        if hasattr(config, "train_microbatches"):
            kw["train_microbatches"] = 1   # the accumulation scan would be
            # counted once; totals are microbatch-invariant
        cfg_d = dataclasses.replace(config, **kw)
        cell_d = build_cell(arch, shape, mesh, rules_override,
                            config_override=cfg_d)
        costs[d] = _cost_triple(_compile_cell(cell_d, mesh))
    span = d_hi - d_lo
    per = tuple((costs[d_hi][i] - costs[d_lo][i]) / span for i in range(3))
    outside = tuple(costs[d_lo][i] - d_lo * per[i] for i in range(3))
    total = tuple(max(outside[i] + depth * per[i],
                      costs[d_hi][i]) for i in range(3))
    return {"flops": total[0], "bytes": total[1], "coll": total[2],
            "per_layer": per, "outside": outside, "depth": depth}


def run_cell(arch: str, shape: str, multi_pod: bool,
             rules_override=None, exact: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(mesh.devices.size)
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, rules_override)
    with mesh_context(mesh):
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate_argnums)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_size": getattr(mem, "argument_size_in_bytes", None),
            "output_size": getattr(mem, "output_size_in_bytes", None),
            "temp_size": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size": getattr(mem, "generated_code_size_in_bytes",
                                           None),
        }
    except Exception as e:  # pragma: no cover
        mem_info = {"error": str(e)}

    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))
    except Exception as e:  # pragma: no cover
        flops, bytes_acc, cost = 0.0, 0.0, {"error": str(e)}

    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    # scan-aware exact costs (loop bodies are counted once by HLO cost
    # analysis; extrapolate from unrolled reduced-depth compiles)
    exact_info = None
    if exact:
        try:
            with mesh_context(mesh):
                exact_info = exact_costs(arch, shape, mesh, cell,
                                         rules_override)
        except Exception as e:
            exact_info = {"error": str(e)}
    if exact_info and "error" not in (exact_info or {}):
        flops = exact_info["flops"]
        bytes_acc = exact_info["bytes"]
        coll_total = exact_info["coll"]
    else:
        coll_total = coll["total"]

    mem_bytes = memory_traffic_bytes(mem_info, bytes_acc)
    terms = roofline(flops, mem_bytes, coll_total, n_chips)
    dominant = max(terms, key=terms.get)

    rec = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": flops, "bytes_per_device": mem_bytes,
        "hlo_bytes_unfused": bytes_acc,
        "collective_bytes_per_device": coll_total,
        "collective_ops": coll["n_ops"],
        "memory": mem_info,
        "roofline": terms,
        "dominant": dominant,
        "exact": bool(exact_info and "error" not in exact_info),
        "meta": cell.meta,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    from repro.configs.registry import all_cells
    cells = all_cells() if args.all else [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.mesh]

    results = []
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}"
            try:
                rec = run_cell(arch, shape, mp)
                r = rec["roofline"]
                print(f"[OK] {tag}: compile={rec['compile_s']}s "
                      f"flops/dev={rec['flops_per_device']:.3g} "
                      f"compute={r['compute_s']*1e3:.3g}ms "
                      f"mem={r['memory_s']*1e3:.3g}ms "
                      f"coll={r['collective_s']*1e3:.3g}ms "
                      f"dominant={rec['dominant']}", flush=True)
                results.append(rec)
            except Exception as e:
                print(f"[FAIL] {tag}: {e}", flush=True)
                traceback.print_exc()
                results.append({"arch": arch, "shape": shape,
                                "mesh": "2x16x16" if mp else "16x16",
                                "error": str(e)})
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.out}")
    n_fail = sum(1 for r in results if "error" in r)
    print(f"{len(results) - n_fail}/{len(results)} cells OK")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
