"""The dense Stage-1 engine: sharded query×doc similarity top-k.

A first-class second modality next to the lexical DAAT/SAAT engines, built
to slot into the existing deployment shape unchanged:

* the embedding matrix is partitioned by the **same contiguous doc ranges**
  as the inverted index (``shard_ranges``), per-shard results carry global
  doc ids, and the multi-shard merge is the existing ``merge_shard_topk``
  — ascending doc-range order + stable ``top_k`` preserve the lower-global-
  doc-id tie-break, and ``drop`` masks (fault loss / partial coverage)
  degrade a dense query exactly like a lexical one;
* per-shard cost is **shape-static** — every query scores every doc tile,
  so ``CostModel.dense_time(n_tiles)`` is exact from the spec alone, which
  is what makes the dense route's contribution to ``worst_case_us``
  analytic (no df tables, no per-query work counters).

``serve`` is bit-identical to the numpy brute-force oracle on every
backend thanks to grid-quantized embeddings (``repro.dense.embeddings``).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.dense.embeddings import embed_queries
from repro.isn.backend import merge_shard_topk
from repro.kernels.dense_topk.ops import dense_topk
from repro.kernels.dense_topk.ref import dense_topk_oracle

SCORE_FILL = float(np.finfo(np.float32).min)


class DenseEngine:
    """Doc-range-sharded dense retrieval over a quantized embedding matrix.

    Args:
      doc_emb: (n_docs, d) float32 grid-quantized doc embeddings.
      term_table: (vocab, d) float32 grid-quantized per-term vectors
        (queries embed as the quantized mean of their active terms).
      ranges: the deployment's ``shard_ranges`` output — the SAME doc-range
        partitioning the lexical shards use.
      tile_d: docs per kernel grid tile (lane-width multiple).
      backend: ``pallas | interpret | jnp`` kernel switch.
    """

    def __init__(self, doc_emb: np.ndarray, term_table: np.ndarray,
                 ranges, *, tile_d: int = 512, backend: str | None = None):
        self.doc_emb = np.asarray(doc_emb, np.float32)
        self.term_table = np.asarray(term_table, np.float32)
        self.tile_d = int(tile_d)
        self.backend = backend if backend is not None else "jnp"
        self.d = self.doc_emb.shape[1]
        self.doc_lo = [lo for lo, _ in ranges]
        self.shard_emb = [jnp.asarray(self.doc_emb[lo:hi])
                          for lo, hi in ranges]
        self.shard_docs = [hi - lo for lo, hi in ranges]
        # live delta segment (capacity-padded, appended above the ranges)
        self.delta_emb = None
        self.delta_live = 0
        self.delta_lo = 0

    @property
    def n_shards(self) -> int:
        return len(self.shard_emb)

    def n_tiles(self, s: int) -> int:
        """Kernel grid tiles of shard ``s`` — the shape-static work unit."""
        return -(-self.shard_docs[s] // self.tile_d)

    def max_tiles(self) -> int:
        """Largest per-shard tile count: the scatter-gather bound's term."""
        return max(self.n_tiles(s) for s in range(self.n_shards))

    def embed(self, terms: np.ndarray, mask: np.ndarray) -> np.ndarray:
        """(Q, d) quantized query embeddings (row-independent)."""
        return embed_queries(self.term_table, terms, mask)

    def set_delta(self, emb: np.ndarray, n_live: int, doc_lo: int) -> None:
        """Attach/refresh the live delta segment.

        ``emb`` is the capacity-padded (cap, d) quantized matrix (rows
        >= ``n_live`` are ghosts), ``doc_lo`` the global id of delta doc 0.
        The shape is the fixed delta capacity so the kernel signature never
        changes as documents stream in.
        """
        self.delta_emb = jnp.asarray(np.asarray(emb, np.float32))
        self.delta_live = int(n_live)
        self.delta_lo = int(doc_lo)

    def clear_delta(self) -> None:
        self.delta_emb = None
        self.delta_live = 0
        self.delta_lo = 0

    def delta_tiles(self) -> int:
        """Kernel grid tiles the delta scan adds to every query's cost."""
        if self.delta_emb is None:
            return 0
        return -(-int(self.delta_emb.shape[0]) // self.tile_d)

    def serve(self, q_emb: np.ndarray, k: int, drop=None):
        """Scatter-gather dense top-k: (ids, scores), each (Q, k).

        Ids are global; ``drop`` ((n_shards, Q) bool) excludes lost /
        never-requested shard responses exactly like the lexical merge
        (surviving-shard merge, ``-1`` padding).  Requires
        ``k <= min(shard docs)`` — the deployment invariant ``SearchSystem``
        already enforces for the lexical grid.
        """
        sc_list, id_list = [], []
        for s in range(self.n_shards):
            sc, ids = dense_topk(jnp.asarray(q_emb), self.shard_emb[s], k,
                                 tile_d=self.tile_d, backend=self.backend)
            sc_list.append(sc)
            id_list.append(ids + self.doc_lo[s])
        if self.n_shards == 1 and self.delta_emb is None:
            ids = np.asarray(id_list[0]).astype(np.int64)
            sc = np.asarray(sc_list[0])
            if drop is not None and drop[0].any():
                ids[drop[0]] = -1
                sc[drop[0]] = SCORE_FILL
            return ids, sc
        if self.delta_emb is not None:
            # Rank the WHOLE delta segment (its capacity is small and
            # static), then mask ghost rows explicitly: a ghost's zero
            # vector scores 0, which would outrank genuinely negative live
            # scores, and requesting only k could let ghosts displace live
            # docs from the candidate list. A full ranking plus post-mask
            # makes padding provably inert.
            cap = int(self.delta_emb.shape[0])
            dsc, dids = dense_topk(jnp.asarray(q_emb), self.delta_emb, cap,
                                   tile_d=self.tile_d, backend=self.backend)
            dsc = np.asarray(dsc).copy()
            dids = np.asarray(dids)
            ghost = dids >= self.delta_live
            dsc[ghost] = SCORE_FILL
            dids = np.where(ghost, -1, dids + self.delta_lo)
            sc_list.append(dsc)
            id_list.append(dids)
            if drop is not None:
                drop = np.concatenate(
                    [np.asarray(drop),
                     np.zeros((1, np.asarray(drop).shape[1]), bool)])
        ids, sc = merge_shard_topk(sc_list, id_list, k, drop=drop)
        return np.asarray(ids).astype(np.int64), np.asarray(sc)

    def oracle(self, q_emb: np.ndarray, k: int):
        """Brute-force ground truth over the unsharded matrix: (ids,
        scores) — what ``serve`` must match bit for bit."""
        sc, ids = dense_topk_oracle(q_emb, self.doc_emb, k)
        return ids, sc
