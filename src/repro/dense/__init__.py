from repro.dense.embeddings import (GRID, build_embeddings, embed_queries,
                                    quantize, synthetic_embeddings,
                                    two_tower_embeddings)
from repro.dense.engine import DenseEngine
from repro.dense.fusion import (M_BOTH, M_DENSE, M_LEX, fuse, rrf_fuse,
                                weighted_fuse)

__all__ = ["GRID", "build_embeddings", "embed_queries", "quantize",
           "synthetic_embeddings", "two_tower_embeddings", "DenseEngine",
           "M_LEX", "M_DENSE", "M_BOTH", "fuse", "rrf_fuse",
           "weighted_fuse"]
