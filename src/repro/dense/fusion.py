"""Hybrid fusion: merging lexical and dense candidate lists.

Two deterministic, host-side fusion rules over per-query ranked lists:

* **RRF** (reciprocal-rank fusion) — score(d) = Σ 1/(k0 + rank(d) + 1)
  over the lists containing d; rank-only, so it needs no score
  calibration across modalities.
* **weighted** — per-query min-max normalize each list's scores to [0, 1],
  then ``w_dense·dense + (1 - w_dense)·lexical``.

Both break exact score ties toward the **lower global doc id** — the same
tie policy as ``merge_shard_topk`` and the dense kernel, so a fused list
is as replay-deterministic as its inputs.  ``-1`` ids (degraded-coverage
padding) are excluded; a fused list short of ``k`` is ``-1``-padded.

Modality codes (Stage-0 dispatch): ``M_LEX`` lexical only, ``M_DENSE``
dense only, ``M_BOTH`` both engines + fusion.
"""

from __future__ import annotations

import numpy as np

M_LEX, M_DENSE, M_BOTH = 0, 1, 2


def _merge_contrib(k: int, *lists):
    """Sum per-doc contributions over (ids, contrib) lists; return the
    (ids, scores) top-k, ties toward the lower doc id."""
    q = lists[0][0].shape[0]
    out_ids = np.full((q, k), -1, np.int64)
    out_sc = np.zeros((q, k), np.float32)
    for i in range(q):
        ids = np.concatenate([np.asarray(l[0][i], np.int64) for l in lists])
        sc = np.concatenate([np.asarray(l[1][i], np.float64) for l in lists])
        live = ids >= 0
        ids, sc = ids[live], sc[live]
        if not len(ids):
            continue
        uniq, inv = np.unique(ids, return_inverse=True)
        tot = np.zeros(len(uniq))
        np.add.at(tot, inv, sc)
        # lexsort: last key is primary -> score desc, then doc id asc
        order = np.lexsort((uniq, -tot))[:k]
        out_ids[i, :len(order)] = uniq[order]
        out_sc[i, :len(order)] = tot[order]
    return out_ids, out_sc


def rrf_fuse(lex_ids: np.ndarray, dense_ids: np.ndarray, k: int,
             k0: float = 60.0):
    """Reciprocal-rank fusion of two (Q, k_in) ranked id lists."""
    def contrib(ids):
        r = np.arange(ids.shape[1], dtype=np.float64)
        return np.broadcast_to(1.0 / (k0 + r + 1.0), ids.shape)
    return _merge_contrib(k, (lex_ids, contrib(lex_ids)),
                          (dense_ids, contrib(dense_ids)))


def _minmax(sc: np.ndarray, ids: np.ndarray) -> np.ndarray:
    """Per-query min-max over live entries; constant lists map to 1."""
    sc = np.asarray(sc, np.float64)
    live = ids >= 0
    out = np.zeros_like(sc)
    for i in range(sc.shape[0]):
        row = sc[i][live[i]]
        if not len(row):
            continue
        lo, hi = row.min(), row.max()
        out[i][live[i]] = (row - lo) / (hi - lo) if hi > lo else 1.0
    return out


def weighted_fuse(lex_ids: np.ndarray, lex_sc: np.ndarray,
                  dense_ids: np.ndarray, dense_sc: np.ndarray, k: int,
                  w_dense: float = 0.5):
    """Min-max-normalized weighted score fusion of two ranked lists."""
    return _merge_contrib(
        k,
        (lex_ids, (1.0 - w_dense) * _minmax(lex_sc, lex_ids)),
        (dense_ids, w_dense * _minmax(dense_sc, dense_ids)))


def fuse(fusion_spec, lex_ids, lex_sc, dense_ids, dense_sc, k: int):
    """Apply a :class:`~repro.serving.spec.FusionSpec` to one batch."""
    if fusion_spec.method == "rrf":
        return rrf_fuse(lex_ids, dense_ids, k, k0=fusion_spec.rrf_k0)
    return weighted_fuse(lex_ids, lex_sc, dense_ids, dense_sc, k,
                         w_dense=fusion_spec.w_dense)
