"""Embedding sources for the dense Stage-1 modality.

Two sources behind one contract:

* ``two_tower`` — the idle ``configs/two_tower_retrieval.REDUCED`` tower.
  Doc embeddings come from the item tower over per-doc feature ids
  (dominant topic + doc identity, both mod the table size), a per-term
  embedding table from the user tower, so queries and docs that share
  topical structure score high — real signal, not noise.
* ``synthetic`` — seeded Gaussian doc/term tables needing nothing but the
  collection shape (pre-built indexes without a corpus, CI smokes).

Exact-parity quantization
-------------------------
Every embedding this module emits is snapped to the grid of integer
multiples of ``1/GRID`` (a power of two) with magnitude <= 2.  With
``GRID = 64`` and embed dims <= a few hundred, every pairwise product is an
integer multiple of ``2^-12`` and every partial sum of a query·doc dot
product stays well inside float32's 24-bit mantissa — so the dot product
is *exactly* representable and independent of accumulation order.  That is
what makes the numpy brute-force oracle, the jnp reference, the tiled
Pallas kernel, and the multi-shard merge agree bit for bit (certified by
``benchmarks/bench_dense.py``), and what keeps dense scores deterministic
enough to live in cache keys and replay logs.
"""

from __future__ import annotations

import numpy as np

GRID = 64          # embeddings are integer multiples of 1/GRID (2^-6)
_CLIP = 2.0        # |value| <= 2 keeps dot products far from f32 exactness
                   # limits for any realistic embed dim


def quantize(x: np.ndarray) -> np.ndarray:
    """Snap to the exact float32 grid: round(x·GRID)/GRID, clipped."""
    g = np.rint(np.asarray(x, np.float64) * GRID)
    return (np.clip(g, -_CLIP * GRID, _CLIP * GRID) / GRID).astype(np.float32)


def embed_queries(term_table: np.ndarray, terms: np.ndarray,
                  mask: np.ndarray) -> np.ndarray:
    """(Q, d) quantized query embeddings: mean of active term vectors.

    Row-independent and deterministic, so sub-batch serving (cache-miss
    splits, online micro-batches) embeds bit-identically to the full batch.
    The mean is re-quantized, putting query vectors back on the exact grid
    the parity argument needs.
    """
    terms = np.asarray(terms)
    w = (np.asarray(mask) > 0).astype(np.float32)
    v = term_table[terms] * w[:, :, None]                  # (Q, L, d)
    cnt = np.maximum(w.sum(axis=1, keepdims=True), 1.0)
    return quantize(v.sum(axis=1) / cnt)


def synthetic_embeddings(n_docs: int, vocab: int, d: int = 32,
                         seed: int = 0):
    """Seeded Gaussian (doc_emb (N, d), term_table (V, d)), quantized."""
    rng = np.random.RandomState(seed)
    scale = 1.0 / np.sqrt(d)
    return (quantize(rng.randn(n_docs, d) * scale),
            quantize(rng.randn(vocab, d) * scale))


def two_tower_embeddings(corpus, seed: int = 0, batch: int = 4096):
    """(doc_emb (N, d), term_table (V, d)) from the REDUCED two-tower model.

    Docs go through the item tower with (dominant topic, doc id) feature
    ids; vocabulary terms go through the user tower one-term bags.  Both
    outputs are L2-normalized by the tower and then grid-quantized.
    """
    import jax
    import jax.numpy as jnp

    from repro.configs.two_tower_retrieval import REDUCED
    from repro.models import recsys

    c = REDUCED
    params, _ = recsys.init(c, jax.random.PRNGKey(seed))
    n = corpus.params.n_docs
    vocab = corpus.params.vocab

    topic = np.argmax(np.asarray(corpus.doc_topics), axis=1)
    doc_ids = np.stack([topic % c.n_items,
                        np.arange(n, dtype=np.int64) % c.n_items], axis=1)
    doc_mask = np.ones_like(doc_ids, np.float32)
    chunks = []
    for lo in range(0, n, batch):
        hi = min(lo + batch, n)
        chunks.append(np.asarray(recsys.tower_embed(
            params, c, "item_table", "item_mlp",
            jnp.asarray(doc_ids[lo:hi]), jnp.asarray(doc_mask[lo:hi]))))
    doc_emb = quantize(np.concatenate(chunks))

    term_ids = (np.arange(vocab, dtype=np.int64) % c.n_users)[:, None]
    term_mask = np.ones_like(term_ids, np.float32)
    chunks = []
    for lo in range(0, vocab, batch):
        hi = min(lo + batch, vocab)
        chunks.append(np.asarray(recsys.tower_embed(
            params, c, "user_table", "user_mlp",
            jnp.asarray(term_ids[lo:hi]), jnp.asarray(term_mask[lo:hi]))))
    term_table = quantize(np.concatenate(chunks))
    return doc_emb, term_table


def build_embeddings(dense_spec, corpus=None, *, n_docs: int,
                     vocab: int):
    """Resolve a DenseSpec's embedding source to (doc_emb, term_table).

    ``source="auto"`` uses the two-tower path when a corpus is available
    and falls back to the synthetic tables otherwise (pre-built indexes
    ship no topic mixtures); an explicit ``"two_tower"`` without a corpus
    is an error rather than a silent downgrade.
    """
    source = dense_spec.source
    if source == "auto":
        source = "two_tower" if corpus is not None else "synthetic"
    if source == "two_tower":
        if corpus is None:
            raise ValueError("DenseSpec.source='two_tower' needs the corpus "
                             "(doc topic mixtures feed the item tower); "
                             "use source='synthetic' or 'auto' with a "
                             "pre-built index")
        return two_tower_embeddings(corpus, seed=dense_spec.seed)
    return synthetic_embeddings(n_docs, vocab, d=dense_spec.embed_dim,
                                seed=dense_spec.seed)


def delta_doc_embeddings(dense_spec, *, n_sealed: int, n_new: int,
                         vocab: int, topics: np.ndarray | None = None,
                         corpus=None) -> np.ndarray:
    """(n_new, d) rows for docs appended at global ids >= ``n_sealed``.

    Both sources are per-row functions of the (global doc id, doc features)
    pair — the synthetic table because RandomState fills row-major (the
    first ``n`` rows of a grown draw equal the ``n``-doc draw bitwise), the
    two-tower path because the item tower sees only (dominant topic,
    doc id).  So incrementally embedding the delta through the same
    quantized source is bit-identical to slicing a full rebuild at the
    grown size — the property the delta-vs-rebuild dense parity test pins.
    """
    source = dense_spec.source
    if source == "auto":
        source = "two_tower" if corpus is not None else "synthetic"
    if source == "two_tower":
        import jax
        import jax.numpy as jnp

        from repro.configs.two_tower_retrieval import REDUCED
        from repro.models import recsys

        if topics is None:
            raise ValueError("two_tower delta embeddings need the feed "
                             "docs' topic mixtures")
        c = REDUCED
        params, _ = recsys.init(c, jax.random.PRNGKey(dense_spec.seed))
        topic = np.argmax(np.asarray(topics), axis=1)
        gids = np.arange(n_sealed, n_sealed + n_new, dtype=np.int64)
        doc_ids = np.stack([topic % c.n_items, gids % c.n_items], axis=1)
        doc_mask = np.ones_like(doc_ids, np.float32)
        emb = recsys.tower_embed(params, c, "item_table", "item_mlp",
                                 jnp.asarray(doc_ids),
                                 jnp.asarray(doc_mask))
        return quantize(np.asarray(emb))
    full, _ = synthetic_embeddings(n_sealed + n_new, vocab,
                                   d=dense_spec.embed_dim,
                                   seed=dense_spec.seed)
    return full[n_sealed:]
