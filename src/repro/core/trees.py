"""Array-based decision-tree ensembles in pure JAX.

LightGBM-style histogram trees, built level-wise with fully vectorized
``segment_sum`` histograms so training jits end-to-end.  Trees are complete
binary trees of fixed depth stored as dense arrays, so inference is a
branch-free O(depth) gather chain — cheap enough to run *inside* the serving
step (the paper's "Stage-0" predictions must add <1 ms per query).

Feature values are pre-binned (quantile binning) to uint8; split thresholds
are bin indices.  The binner (``fit_bins``/``apply_bins``) is part of the
model so raw features can be used at serving time.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


class TreeParams(NamedTuple):
    depth: int = 6              # number of split levels; 2**depth leaves
    n_bins: int = 64
    min_child_weight: float = 10.0
    l2: float = 1.0             # ridge term on leaf scores


class Forest(NamedTuple):
    """A stacked ensemble of complete binary trees.

    feat:   (T, depth, 2**(depth-1)) int32 — split feature per node
    thresh: (T, depth, 2**(depth-1)) int32 — split bin; go right if bin > thresh
    leaf:   (T, 2**depth) float32 — leaf scores
    """
    feat: jnp.ndarray
    thresh: jnp.ndarray
    leaf: jnp.ndarray


# ---------------------------------------------------------------------------
# Binning
# ---------------------------------------------------------------------------

def fit_bins(x: np.ndarray, n_bins: int) -> np.ndarray:
    """Quantile bin edges, shape (F, n_bins - 1). Host-side (numpy)."""
    qs = np.linspace(0.0, 100.0, n_bins + 1)[1:-1]
    edges = np.percentile(np.asarray(x), qs, axis=0).T.astype(np.float32)
    # strictly increasing edges keep searchsorted well-behaved on constant cols
    edges = np.maximum.accumulate(edges + 1e-9 * np.arange(edges.shape[1]), axis=1)
    return edges


@jax.jit
def apply_bins(x: jnp.ndarray, edges: jnp.ndarray) -> jnp.ndarray:
    """(n, F) raw floats -> (n, F) uint8 bin ids via vectorized searchsorted."""
    bins = jnp.sum(x[:, :, None] > edges[None, :, :], axis=-1)
    return bins.astype(jnp.uint8)


# ---------------------------------------------------------------------------
# Level-wise histogram tree builder
# ---------------------------------------------------------------------------

def _level_histograms(xb, node, grad, weight, n_nodes, n_bins):
    """Weighted gradient/weight histograms per (node, feature, bin)."""
    n, f = xb.shape
    keys = (node[:, None].astype(jnp.int32) * f
            + jnp.arange(f, dtype=jnp.int32)[None, :]) * n_bins + xb.astype(jnp.int32)
    num_seg = n_nodes * f * n_bins
    gw = (grad * weight)[:, None] * jnp.ones((1, f), jnp.float32)
    ww = weight[:, None] * jnp.ones((1, f), jnp.float32)
    hist_g = jax.ops.segment_sum(gw.reshape(-1), keys.reshape(-1), num_segments=num_seg)
    hist_w = jax.ops.segment_sum(ww.reshape(-1), keys.reshape(-1), num_segments=num_seg)
    return (hist_g.reshape(n_nodes, f, n_bins), hist_w.reshape(n_nodes, f, n_bins))


def build_tree(xb: jnp.ndarray, target: jnp.ndarray, weight: jnp.ndarray,
               feat_mask: jnp.ndarray, params: TreeParams):
    """Fit one regression tree to `target` with variance-reduction splits.

    Args:
      xb: (n, F) uint8 binned features.
      target: (n,) regression target (pseudo-gradient for boosting).
      weight: (n,) sample weights (0 excludes a row; Poisson for bagging).
      feat_mask: (F,) bool — features eligible for splitting (attribute bagging).
    Returns:
      (feat, thresh) arrays of shape (depth, 2**(depth-1)) and the final
      (n,) leaf assignment in [0, 2**depth).
    """
    n, f = xb.shape
    d_max = params.depth
    width = 2 ** (d_max - 1)
    node = jnp.zeros((n,), jnp.int32)
    feats, threshs = [], []
    for d in range(d_max):
        n_nodes = 2 ** d
        hg, hw = _level_histograms(xb, node, target, weight, n_nodes, params.n_bins)
        cg = jnp.cumsum(hg, axis=-1)
        cw = jnp.cumsum(hw, axis=-1)
        tg = cg[..., -1:]
        tw = cw[..., -1:]
        lam = params.l2
        gain = (cg ** 2 / (cw + lam) + (tg - cg) ** 2 / (tw - cw + lam)
                - tg ** 2 / (tw + lam))
        ok = ((cw >= params.min_child_weight)
              & (tw - cw >= params.min_child_weight)
              & feat_mask[None, :, None])
        gain = jnp.where(ok, gain, NEG_INF)
        flat = gain.reshape(n_nodes, -1)
        best = jnp.argmax(flat, axis=-1)
        best_gain = jnp.take_along_axis(flat, best[:, None], axis=-1)[:, 0]
        bf = (best // params.n_bins).astype(jnp.int32)
        bb = (best % params.n_bins).astype(jnp.int32)
        # unsplittable node -> pass-through split (everything goes left)
        dead = best_gain <= NEG_INF / 2
        bf = jnp.where(dead, 0, bf)
        bb = jnp.where(dead, params.n_bins - 1, bb).astype(jnp.int32)
        fx = jnp.take_along_axis(xb.astype(jnp.int32), bf[node][:, None], axis=1)[:, 0]
        go_right = (fx > bb[node]).astype(jnp.int32)
        node = node * 2 + go_right
        pad = width - n_nodes
        feats.append(jnp.pad(bf, (0, pad)))
        threshs.append(jnp.pad(bb, (0, pad)))
    return jnp.stack(feats), jnp.stack(threshs), node


def leaf_means(leaf_id, values, weight, n_leaves, l2=1.0):
    sw = jax.ops.segment_sum(weight, leaf_id, num_segments=n_leaves)
    sv = jax.ops.segment_sum(values * weight, leaf_id, num_segments=n_leaves)
    return sv / (sw + l2)


def leaf_quantiles(leaf_id, values, weight, n_leaves, tau):
    """Exact per-leaf tau-quantile of ``values`` (weight treated as 0/1 mask).

    Rows with weight <= 0 are parked in a dummy leaf.  Implemented with one
    lexsort + prefix bookkeeping, no per-leaf loop.
    """
    n = values.shape[0]
    lid = jnp.where(weight > 0, leaf_id, n_leaves).astype(jnp.int32)
    order = jnp.lexsort((values, lid))
    s_leaf = lid[order]
    s_val = values[order]
    counts = jax.ops.segment_sum(jnp.ones((n,), jnp.float32), lid,
                                 num_segments=n_leaves + 1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.float32),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(n, dtype=jnp.float32) - starts[s_leaf]
    target_rank = jnp.floor(tau * jnp.maximum(counts - 1.0, 0.0))
    hit = pos == target_rank[s_leaf]
    out = jnp.zeros((n_leaves + 1,), jnp.float32).at[s_leaf].add(
        jnp.where(hit, s_val, 0.0))
    return out[:n_leaves]


# ---------------------------------------------------------------------------
# Inference
# ---------------------------------------------------------------------------

def _descend(feat, thresh, xb_row, depth):
    node = jnp.zeros((), jnp.int32)
    for d in range(depth):
        f = feat[d, node]
        b = thresh[d, node]
        node = node * 2 + (xb_row[f].astype(jnp.int32) > b).astype(jnp.int32)
    return node


@functools.partial(jax.jit, static_argnames=("depth", "reduce"))
def forest_predict_binned(forest: Forest, xb: jnp.ndarray, depth: int,
                          reduce: str = "sum") -> jnp.ndarray:
    """Predict from pre-binned features. reduce: 'sum' (boosting) | 'mean' (bagging)."""
    def per_row(row):
        leaves = jax.vmap(lambda ft, th, lf: lf[_descend(ft, th, row, depth)])(
            forest.feat, forest.thresh, forest.leaf)
        return jnp.sum(leaves) if reduce == "sum" else jnp.mean(leaves)
    return jax.vmap(per_row)(xb)


@functools.partial(jax.jit, static_argnames=("depth", "reduce"))
def forest_predict_stacked(forests: Forest, xb: jnp.ndarray, depth: int,
                           reduce: str = "sum") -> jnp.ndarray:
    """Predict M stacked ensembles in one fused on-device call.

    ``forests`` is a Forest whose arrays carry a leading (M,) model axis
    (same tree count and depth per model — stack with ``jnp.stack``);
    ``xb`` is (M, n, F) pre-binned features, one binning per model.  The
    per-model math is the exact gather chain of ``forest_predict_binned``
    vmapped over the model axis, so the Stage-0 k/ρ/t predictors run as one
    array program instead of three dispatches.  Returns (M, n).
    """
    return jax.vmap(
        lambda f, b: forest_predict_binned(f, b, depth, reduce))(forests, xb)
