"""The unified Stage-0 prediction framework (paper §4).

One feature pipeline, three regression targets — k, ρ, response time — and
three model families (quantile-GBRT "QR", random forest "RF", ridge "LR"),
trained with k-fold cross validation so every query's prediction comes from
a model that never saw it (the paper uses 10 folds).

Targets are learned in log space (the label distributions are heavy-tailed;
Fig. 2/5 in the paper) and predictions are exponentiated back.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.core import gbrt, linreg, random_forest


@dataclass(frozen=True)
class PredictorConfig:
    method: str = "qr"                # qr | rf | lr
    n_folds: int = 10
    log_target: bool = True
    tau: float = 0.55                 # QR quantile (paper's best fit for k)
    n_trees: int = 64
    depth: int = 5
    learning_rate: float = 0.15
    seed: int = 0


@dataclass
class CVPrediction:
    pred: np.ndarray                  # (Q,) cross-validated predictions
    models: list = field(default_factory=list)
    config: PredictorConfig = PredictorConfig()


def _fit_predict(method, xtr, ytr, xte, cfg: PredictorConfig, seed):
    if method == "qr":
        p = gbrt.GBRTParams(n_trees=cfg.n_trees, depth=cfg.depth,
                            learning_rate=cfg.learning_rate,
                            loss="quantile", tau=cfg.tau)
        m = gbrt.fit(xtr, ytr, p, seed=seed)
        return m, np.asarray(gbrt.predict(m, xte))
    if method == "rf":
        p = random_forest.RFParams(n_trees=cfg.n_trees, depth=cfg.depth + 1)
        m = random_forest.fit(xtr, ytr, p, seed=seed)
        return m, np.asarray(random_forest.predict(m, xte))
    if method == "lr":
        m = linreg.fit(xtr, ytr)
        return m, np.asarray(linreg.predict(m, xte))
    raise ValueError(method)


def cross_val_predict(x: np.ndarray, y: np.ndarray,
                      cfg: PredictorConfig) -> CVPrediction:
    """K-fold CV predictions for one target."""
    q = x.shape[0]
    rng = np.random.RandomState(cfg.seed)
    fold = rng.randint(0, cfg.n_folds, size=q)
    t = np.log1p(np.maximum(y, 0)) if cfg.log_target else y.astype(np.float32)
    pred = np.zeros(q, np.float32)
    models = []
    for f in range(cfg.n_folds):
        te = fold == f
        tr = ~te
        m, p = _fit_predict(cfg.method, x[tr], t[tr], x[te], cfg,
                            seed=cfg.seed * 100 + f)
        pred[te] = p
        models.append(m)
    if cfg.log_target:
        pred = np.expm1(pred)
    return CVPrediction(pred=np.maximum(pred, 0), models=models, config=cfg)


@dataclass
class StageZeroPredictions:
    """The full Stage-0 bundle the scheduler consumes."""
    k: np.ndarray
    rho: np.ndarray
    time_us: np.ndarray


def predict_all(x: np.ndarray, labels_k: np.ndarray, labels_rho: np.ndarray,
                labels_t: np.ndarray, method: str = "qr",
                tau_k: float = 0.55, tau_rho: float = 0.45,
                tau_t: float = 0.5, n_folds: int = 10,
                **kw) -> StageZeroPredictions:
    """Train the three regressors and return CV predictions for every query.

    The per-target quantiles follow the paper: τ = 0.55 for k, τ = 0.45 for
    ρ (best-fit distributions, Figs. 2 and 5)."""
    base = dict(method=method, n_folds=n_folds, **kw)
    pk = cross_val_predict(x, labels_k, PredictorConfig(tau=tau_k, **base))
    pr = cross_val_predict(x, labels_rho, PredictorConfig(tau=tau_rho, **base))
    pt = cross_val_predict(x, labels_t, PredictorConfig(tau=tau_t, **base))
    return StageZeroPredictions(k=pk.pred, rho=pr.pred, time_us=pt.pred)


# ---------------------------------------------------------------------------
# evaluation helpers (paper Table 2)
# ---------------------------------------------------------------------------

def regression_report(y: np.ndarray, pred: np.ndarray,
                      tail_quantile: float = 0.95) -> dict:
    """RMSE in log space + binary tail-query classification metrics.

    Tail threshold is learned as the minimum value in the top (1-q) of the
    *training* distribution, per the paper's Table 2 protocol."""
    ly, lp = np.log1p(y), np.log1p(np.maximum(pred, 0))
    rmse = float(np.sqrt(np.mean((ly - lp) ** 2)))
    thr = np.quantile(y, tail_quantile)
    pos = y >= thr
    pred_pos = pred >= thr
    tp = int(np.sum(pos & pred_pos))
    fp = int(np.sum(~pos & pred_pos))
    fn = int(np.sum(pos & ~pred_pos))
    tn = int(np.sum(~pos & ~pred_pos))
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn, 1)
    f1 = 2 * prec * rec / max(prec + rec, 1e-9)
    nprec = tn / max(tn + fn, 1)
    nrec = tn / max(tn + fp, 1)
    nf1 = 2 * nprec * nrec / max(nprec + nrec, 1e-9)
    # AUC via rank statistic
    order = np.argsort(pred)
    r = np.empty(len(pred)); r[order] = np.arange(1, len(pred) + 1)
    n_pos, n_neg = int(pos.sum()), int((~pos).sum())
    auc = ((r[pos].sum() - n_pos * (n_pos + 1) / 2) / max(n_pos * n_neg, 1))
    return {
        "rmse": rmse, "precision": prec, "recall": rec, "f1": f1,
        "macro_precision": (prec + nprec) / 2, "macro_recall": (rec + nrec) / 2,
        "macro_f1": (f1 + nf1) / 2, "auc": float(auc),
    }
