"""Gradient-boosted regression trees with L2 or quantile (pinball) loss.

The paper's preferred predictor ("QR") is a GBRT minimizing the pinball loss
ξ_τ(y - f) = (y - f)(τ - 1{y < f}); each boosting round fits a histogram tree
to the negative gradient and then *refits every leaf to the exact in-leaf
τ-quantile of the residuals* (the line-search step), which is what makes the
ensemble estimate the conditional τ-quantile rather than the mean.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import trees as T


class GBRTParams(NamedTuple):
    n_trees: int = 64
    depth: int = 5
    n_bins: int = 64
    learning_rate: float = 0.15
    min_child_weight: float = 20.0
    l2: float = 1.0
    loss: str = "l2"          # "l2" | "quantile"
    tau: float = 0.5          # quantile target (used when loss == "quantile")
    colsample: float = 1.0    # feature fraction per tree
    subsample: float = 1.0    # row fraction per tree (without replacement mask)


class GBRTModel(NamedTuple):
    forest: T.Forest
    base: jnp.ndarray          # scalar initial prediction
    bin_edges: jnp.ndarray     # (F, n_bins - 1)
    params: GBRTParams


def _pseudo_gradient(y, f, loss, tau):
    if loss == "l2":
        return y - f
    # pinball: -dξ/df = tau - 1{y < f}
    return jnp.where(y >= f, tau, tau - 1.0)


def _leaf_values(leaf_id, y, f, w, n_leaves, p: GBRTParams):
    if p.loss == "l2":
        return T.leaf_means(leaf_id, y - f, w, n_leaves, p.l2)
    return T.leaf_quantiles(leaf_id, y - f, w, n_leaves, p.tau)


@functools.partial(jax.jit, static_argnames=("p",))
def _fit_binned(xb, y, p: GBRTParams, rng):
    n, nf = xb.shape
    tp = T.TreeParams(p.depth, p.n_bins, p.min_child_weight, p.l2)
    n_leaves = 2 ** p.depth
    if p.loss == "l2":
        base = jnp.mean(y)
    else:
        base = jnp.quantile(y, p.tau)

    def step(carry, key):
        f = carry
        k1, k2 = jax.random.split(key)
        fmask = (jax.random.uniform(k1, (nf,)) < p.colsample) if p.colsample < 1.0 \
            else jnp.ones((nf,), bool)
        w = (jax.random.uniform(k2, (n,)) < p.subsample).astype(jnp.float32) \
            if p.subsample < 1.0 else jnp.ones((n,), jnp.float32)
        g = _pseudo_gradient(y, f, p.loss, p.tau)
        feat, thresh, leaf_id = T.build_tree(xb, g, w, fmask, tp)
        leaves = _leaf_values(leaf_id, y, f, w, n_leaves, p) * p.learning_rate
        f = f + leaves[leaf_id]
        return f, (feat, thresh, leaves)

    keys = jax.random.split(rng, p.n_trees)
    f0 = jnp.full((n,), base, jnp.float32)
    _, (feats, threshs, leaves) = jax.lax.scan(step, f0, keys)
    return T.Forest(feats, threshs, leaves), base


def fit(x: np.ndarray, y: np.ndarray, params: GBRTParams, seed: int = 0) -> GBRTModel:
    edges = T.fit_bins(np.asarray(x, np.float32), params.n_bins)
    xb = T.apply_bins(jnp.asarray(x, jnp.float32), jnp.asarray(edges))
    forest, base = _fit_binned(xb, jnp.asarray(y, jnp.float32), params,
                               jax.random.PRNGKey(seed))
    return GBRTModel(forest, base, jnp.asarray(edges), params)


def predict(model: GBRTModel, x: jnp.ndarray) -> jnp.ndarray:
    xb = T.apply_bins(jnp.asarray(x, jnp.float32), model.bin_edges)
    return model.base + T.forest_predict_binned(
        model.forest, xb, model.params.depth, reduce="sum")


# ---------------------------------------------------------------------------
# fused multi-model inference (Stage-0 serves k, ρ and t in one call)
# ---------------------------------------------------------------------------

class StackedGBRT(NamedTuple):
    """M same-shaped GBRT ensembles stacked along a leading model axis so
    inference for all of them is one fused device call (the per-query
    Stage-0 budget in the paper is < 0.75 ms for *all three* predictions)."""
    forest: T.Forest           # every leaf carries a leading (M,) axis
    base: jnp.ndarray          # (M,)
    bin_edges: jnp.ndarray     # (M, F, n_bins - 1)


def stack_models(models: list[GBRTModel]) -> tuple[StackedGBRT, int]:
    """Stack models sharing (n_trees, depth, n_bins); loss/τ may differ.

    Returns (stacked, depth); raises ValueError on shape mismatch so callers
    can fall back to per-model prediction.
    """
    shapes = {(m.params.n_trees, m.params.depth, m.params.n_bins)
              for m in models}
    if len(shapes) != 1:
        raise ValueError(f"cannot stack GBRTs with mixed shapes: {shapes}")
    feats = {m.bin_edges.shape for m in models}
    if len(feats) != 1:
        raise ValueError(f"cannot stack GBRTs with mixed feature sets: {feats}")
    forest = T.Forest(*(jnp.stack([getattr(m.forest, f) for m in models])
                        for f in T.Forest._fields))
    base = jnp.stack([jnp.asarray(m.base, jnp.float32).reshape(())
                      for m in models])
    edges = jnp.stack([m.bin_edges for m in models])
    (_, depth, _), = shapes
    return StackedGBRT(forest, base, edges), depth


@functools.partial(jax.jit, static_argnames=("depth",))
def predict_stacked(stacked: StackedGBRT, x: jnp.ndarray,
                    depth: int) -> jnp.ndarray:
    """(M, Q) predictions for all stacked models in one fused call."""
    x = jnp.asarray(x, jnp.float32)
    xb = jax.vmap(lambda e: T.apply_bins(x, e))(stacked.bin_edges)
    preds = T.forest_predict_stacked(stacked.forest, xb, depth)
    return stacked.base[:, None] + preds
