"""Reference-list comparison metrics: RBP, RBO, MED-RBP.

The paper trains its per-query predictors *without relevance judgments* by
measuring Maximized Effectiveness Difference (MED, Tan & Clarke 2015) between a
candidate first-stage list and an idealized reference ("last stage") run.

All functions are pure jnp and vmap/jit friendly.  Ranked lists are int32
document-id arrays; ``-1`` entries are padding and never match a real doc.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

PAD = -1


def rbp_weights(depth: int, p: float) -> jnp.ndarray:
    """Per-rank RBP user-model weights ``(1 - p) * p**rank`` for rank 0..depth-1."""
    ranks = jnp.arange(depth, dtype=jnp.float32)
    return (1.0 - p) * jnp.power(p, ranks)


def rbp(gains: jnp.ndarray, p: float) -> jnp.ndarray:
    """Rank-biased precision of a gain vector (gains in [0, 1], rank major)."""
    w = rbp_weights(gains.shape[-1], p)
    return jnp.sum(gains * w, axis=-1)


def _membership_matrix(list_a: jnp.ndarray, list_b: jnp.ndarray) -> jnp.ndarray:
    """(len_a, len_b) bool matrix: a[i] == b[j] and a[i] is not padding."""
    eq = list_a[:, None] == list_b[None, :]
    return eq & (list_a[:, None] != PAD)


def med_rbp(ref: jnp.ndarray, run: jnp.ndarray, p: float) -> jnp.ndarray:
    """Maximized effectiveness difference MED-RBP(ref, run).

    For each document the adversary picks a binary relevance maximizing
    ``RBP(ref) - RBP(run)``.  A document at rank i contributes weight
    ``(1-p) p**i`` to whichever list contains it (0 if absent), so the max
    difference is ``sum_d max(0, w_ref(d) - w_run(d))``.  Documents that are in
    neither list contribute nothing.  This is the effectiveness *loss* of
    ``run`` relative to the reference; it is 0 iff run covers ref's prefix
    mass, and monotonically non-increasing as run deepens.
    """
    wa = rbp_weights(ref.shape[-1], p)
    wb = rbp_weights(run.shape[-1], p)
    m = _membership_matrix(ref, run).astype(jnp.float32)
    # weight each ref doc receives inside `run` (0 when absent)
    w_in_run = m @ wb
    valid = (ref != PAD).astype(jnp.float32)
    return jnp.sum(jnp.maximum(wa * valid - w_in_run, 0.0), axis=-1)


def med_rbp_at_cutoffs(ref: jnp.ndarray, stage1_rank_of_ref: jnp.ndarray,
                       cutoffs: jnp.ndarray, p: float) -> jnp.ndarray:
    """MED-RBP of the *re-ranked candidate set* at several first-stage cutoffs.

    If the final ranker is fixed, re-ranking the top-k candidate set recovers
    the reference doc d iff d's first-stage rank < k.  So the loss at cutoff k
    is the RBP mass of reference docs whose stage-1 rank >= k.

    Args:
      ref: (depth,) reference doc ids (PAD allowed).
      stage1_rank_of_ref: (depth,) 0-based rank of each ref doc in the stage-1
        full ranking (use a large sentinel, e.g. 2**30, when absent).
      cutoffs: (c,) candidate-set sizes k.
    Returns:
      (c,) MED-RBP loss per cutoff.
    """
    wa = rbp_weights(ref.shape[-1], p) * (ref != PAD)
    lost = stage1_rank_of_ref[None, :] >= cutoffs[:, None]  # (c, depth)
    return jnp.sum(wa[None, :] * lost, axis=-1)


def oracle_cutoff(ref: jnp.ndarray, stage1_rank_of_ref: jnp.ndarray,
                  cutoffs: jnp.ndarray, p: float, eps: float) -> jnp.ndarray:
    """Smallest cutoff in ``cutoffs`` (ascending) with MED-RBP <= eps.

    Falls back to the largest cutoff when none satisfies the target.
    """
    med = med_rbp_at_cutoffs(ref, stage1_rank_of_ref, cutoffs, p)
    ok = med <= eps
    first = jnp.argmax(ok)  # first True, or 0 if none
    any_ok = jnp.any(ok)
    idx = jnp.where(any_ok, first, cutoffs.shape[0] - 1)
    return cutoffs[idx]


def overlap(list_a: jnp.ndarray, list_b: jnp.ndarray) -> jnp.ndarray:
    """Set overlap |A ∩ B| / |A| (padding-aware)."""
    m = _membership_matrix(list_a, list_b)
    inter = jnp.sum(jnp.any(m, axis=-1).astype(jnp.float32), axis=-1)
    size_a = jnp.maximum(jnp.sum((list_a != PAD).astype(jnp.float32), axis=-1), 1.0)
    return inter / size_a


def rbo(list_a: jnp.ndarray, list_b: jnp.ndarray, p: float) -> jnp.ndarray:
    """Rank-biased overlap (extrapolated to the evaluated depth).

    RBO = (1-p) * sum_{d=1..D} p^{d-1} * |A_d ∩ B_d| / d   (prefix agreement)
    plus the final-depth extrapolation term  p^D * |A_D ∩ B_D| / D.
    """
    depth = list_a.shape[-1]
    m = _membership_matrix(list_a, list_b).astype(jnp.float32)
    # inter_at[d] = |A_{1..d} ∩ B_{1..d}|: 2-D prefix sum of the match matrix
    pref = jnp.cumsum(jnp.cumsum(m, axis=-1), axis=-2)
    d_idx = jnp.arange(depth)
    inter_at = pref[d_idx, d_idx]
    d = jnp.arange(1, depth + 1, dtype=jnp.float32)
    agreement = inter_at / d
    w = jnp.power(p, d - 1.0)
    base = (1.0 - p) * jnp.sum(w * agreement, axis=-1)
    extrap = jnp.power(p, float(depth)) * agreement[-1]
    return base + extrap


@functools.partial(jax.jit, static_argnames=("p",))
def batched_med_rbp(ref: jnp.ndarray, run: jnp.ndarray, p: float = 0.95) -> jnp.ndarray:
    return jax.vmap(lambda a, b: med_rbp(a, b, p))(ref, run)


@functools.partial(jax.jit, static_argnames=("p",))
def batched_rbo(ref: jnp.ndarray, run: jnp.ndarray, p: float = 0.95) -> jnp.ndarray:
    return jax.vmap(lambda a, b: rbo(a, b, p))(ref, run)
