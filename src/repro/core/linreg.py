"""Ridge linear regression — the paper's "LR" baseline (Macdonald et al. 2012
used linear models for response-time prediction)."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class LinRegModel(NamedTuple):
    w: jnp.ndarray
    b: jnp.ndarray
    mu: jnp.ndarray
    sigma: jnp.ndarray


def fit(x: np.ndarray, y: np.ndarray, l2: float = 1.0) -> LinRegModel:
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    mu = jnp.mean(x, axis=0)
    sigma = jnp.std(x, axis=0) + 1e-6
    xs = (x - mu) / sigma
    f = xs.shape[1]
    gram = xs.T @ xs + l2 * jnp.eye(f)
    w = jnp.linalg.solve(gram, xs.T @ (y - jnp.mean(y)))
    return LinRegModel(w, jnp.mean(y), mu, sigma)


@jax.jit
def predict(model: LinRegModel, x: jnp.ndarray) -> jnp.ndarray:
    xs = (jnp.asarray(x, jnp.float32) - model.mu) / model.sigma
    return xs @ model.w + model.b
