"""Stage-0 pre-retrieval feature extraction (147 features).

Following Culpepper et al. [16] and the paper: for each query term we read
aggregate statistics of its postings-list *scores* under six similarity
functions (TF·IDF, BM25, query likelihood, Bose-Einstein, DPH, DFR/PL2) —
{max, arithmetic mean, geometric mean, harmonic mean, median, std} — and
aggregate each statistic over the query terms with {max, min, mean, variance},
giving 6 × 6 × 4 = 144 features, plus 3 query-level features (query length,
log total document frequency, log min document frequency) = 147.

The per-term statistics are precomputed at index-build time into a dense
``(vocab, 36)`` table (`repro.index.builder.term_stat_table`), so query
featurization is a gather + masked reduce: O(|q| · 36) — this is what makes
sub-millisecond Stage-0 prediction feasible at an ISN.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

N_SIMS = 6
N_STATS = 6
N_TERM_FEATURES = N_SIMS * N_STATS        # 36
N_QUERY_AGGS = 4
N_FEATURES = N_TERM_FEATURES * N_QUERY_AGGS + 3   # 147

SIM_NAMES = ("tfidf", "bm25", "ql", "bose_einstein", "dph", "pl2")
STAT_NAMES = ("max", "amean", "gmean", "hmean", "median", "std")
QPAD = 0  # padded query slots hold term id 0 with mask 0


@functools.partial(jax.jit, static_argnames=())
def extract(term_stats: jnp.ndarray, term_df: jnp.ndarray,
            query_terms: jnp.ndarray, query_mask: jnp.ndarray) -> jnp.ndarray:
    """Featurize a batch of queries.

    Args:
      term_stats: (V, 36) per-term score statistics.
      term_df: (V,) document frequencies.
      query_terms: (Q, L) padded term ids.
      query_mask: (Q, L) 1.0 for real terms.
    Returns:
      (Q, 147) float32 feature matrix.
    """
    stats = term_stats[query_terms]                      # (Q, L, 36)
    m = query_mask[:, :, None]
    big = 1e30
    n_terms = jnp.maximum(jnp.sum(query_mask, axis=1), 1.0)  # (Q,)

    mx = jnp.max(jnp.where(m > 0, stats, -big), axis=1)
    mn = jnp.min(jnp.where(m > 0, stats, big), axis=1)
    mean = jnp.sum(stats * m, axis=1) / n_terms[:, None]
    var = jnp.sum((stats - mean[:, None, :]) ** 2 * m, axis=1) / n_terms[:, None]

    df = term_df[query_terms].astype(jnp.float32)        # (Q, L)
    sum_df = jnp.sum(df * query_mask, axis=1)
    min_df = jnp.min(jnp.where(query_mask > 0, df, big), axis=1)
    qlevel = jnp.stack([n_terms,
                        jnp.log1p(sum_df),
                        jnp.log1p(min_df)], axis=1)

    out = jnp.concatenate([mx, mn, mean, var, qlevel], axis=1)
    return out.astype(jnp.float32)


def feature_names() -> list[str]:
    names = []
    for agg in ("qmax", "qmin", "qmean", "qvar"):
        for sim in SIM_NAMES:
            for stat in STAT_NAMES:
                names.append(f"{agg}.{sim}.{stat}")
    names += ["q_len", "log_sum_df", "log_min_df"]
    assert len(names) == N_FEATURES
    return names
