"""Random-forest regressor (the paper's "RF" baseline predictor).

Bagged histogram trees: Poisson(1) bootstrap weights (the vectorized
equivalent of sampling with replacement) plus per-tree attribute bagging.
All trees are built in one vmapped jit.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import trees as T


class RFParams(NamedTuple):
    n_trees: int = 64
    depth: int = 6
    n_bins: int = 64
    min_child_weight: float = 10.0
    l2: float = 1.0
    max_features: float = 0.4   # fraction of features per tree


class RFModel(NamedTuple):
    forest: T.Forest
    bin_edges: jnp.ndarray
    params: RFParams


@functools.partial(jax.jit, static_argnames=("p",))
def _fit_binned(xb, y, p: RFParams, rng):
    n, nf = xb.shape
    tp = T.TreeParams(p.depth, p.n_bins, p.min_child_weight, p.l2)
    n_leaves = 2 ** p.depth

    def one_tree(key):
        k1, k2 = jax.random.split(key)
        w = jax.random.poisson(k1, 1.0, (n,)).astype(jnp.float32)
        fmask = jax.random.uniform(k2, (nf,)) < p.max_features
        # never allow an all-false mask
        fmask = fmask.at[jax.random.randint(k2, (), 0, nf)].set(True)
        feat, thresh, leaf_id = T.build_tree(xb, y, w, fmask, tp)
        leaves = T.leaf_means(leaf_id, y, w, n_leaves, p.l2)
        return feat, thresh, leaves

    keys = jax.random.split(rng, p.n_trees)
    feats, threshs, leaves = jax.vmap(one_tree)(keys)
    return T.Forest(feats, threshs, leaves)


def fit(x: np.ndarray, y: np.ndarray, params: RFParams, seed: int = 0) -> RFModel:
    edges = T.fit_bins(np.asarray(x, np.float32), params.n_bins)
    xb = T.apply_bins(jnp.asarray(x, jnp.float32), jnp.asarray(edges))
    forest = _fit_binned(xb, jnp.asarray(y, jnp.float32), params,
                         jax.random.PRNGKey(seed))
    return RFModel(forest, jnp.asarray(edges), params)


def predict(model: RFModel, x: jnp.ndarray) -> jnp.ndarray:
    xb = T.apply_bins(jnp.asarray(x, jnp.float32), model.bin_edges)
    return T.forest_predict_binned(model.forest, xb, model.params.depth,
                                   reduce="mean")
