"""Oracle label generation via reference lists (the paper's §3 methodology).

For every query we compute, from exhaustive runs on the synthetic collection:

* the reference list — the idealized last-stage ranking (BM25 + latent
  topical affinity over the whole collection, the stand-in for
  uogTRMQdph40);
* ``oracle_k``  — the smallest first-stage cutoff k with
  MED-RBP₀.₉₅ ≤ ε (ε = 0.001 by default, as in the paper);
* ``oracle_rho`` — the smallest JASS postings budget (from a geometric
  grid) whose top-``oracle_k`` list keeps MED-RBP ≤ ε at the fixed
  optimal k (the paper fixes k at its oracle value when labelling ρ);
* first-stage response-time labels for DAAT/BMW from the cost model —
  the prediction target for R_t.

Also applies the paper's query filtering: queries whose MED at the maximum
cutoff exceeds ``mismatch_med`` (0.5 in the paper) are dropped as
early/late-stage mismatches.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.reference import rbp_weights
from repro.index.builder import InvertedIndex
from repro.index.corpus import Corpus, QueryLog
from repro.isn import oracle
from repro.serving.latency import CostModel


@dataclass
class LabelConfig:
    ref_depth: int = 100
    rbp_p: float = 0.95
    eps: float = 0.001
    max_k: int = 16384
    rho_grid: tuple = (1024, 2048, 4096, 8192, 16384, 32768, 65536,
                       131072, 262144, 524288, 1048576)
    gamma: float = 6.0
    mismatch_med: float = 0.5
    time_k: int = 1000          # fixed k for the response-time labels
    batch: int = 256


@dataclass
class LabelSet:
    keep: np.ndarray            # (Q,) bool — survived mismatch filtering
    ref_lists: np.ndarray       # (Q, depth)
    oracle_k: np.ndarray        # (Q,)
    oracle_rho: np.ndarray      # (Q,)
    med_at_max: np.ndarray      # (Q,)
    work_exhaustive: np.ndarray # (Q,)
    work_bmw: np.ndarray        # (Q,) at time_k, theta=1
    blocks_bmw: np.ndarray
    t_bmw: np.ndarray           # (Q,) modeled µs
    t_exh: np.ndarray           # (Q,) modeled µs (exhaustive SAAT)
    stage1_ranks: np.ndarray | None = None  # (Q, depth) ranks of ref docs in
                                            # the exact stage-1 ranking


def _ideal_reference(index, corpus, ql, rows, acc, cfg: LabelConfig):
    """Idealized last stage over the *whole* collection: exact BM25 plus a
    latent topical affinity only the (expensive, later-stage) ranker sees."""
    aff = corpus.doc_topics[:, ql.topic[rows]].T          # (B, N)
    scale = np.maximum(acc.max(axis=1, keepdims=True), 1.0)
    ideal = acc + cfg.gamma * aff * (acc > 0) * scale / 10.0
    ids, _ = oracle._topk_ids(ideal, cfg.ref_depth)
    return ids


def _oracle_k_row(ranks, w, eps, max_k):
    """Greedy exclusion: drop ref docs from deepest stage-1 rank upward while
    the excluded RBP mass stays <= eps; k* = deepest remaining rank + 1."""
    order = np.argsort(-ranks)
    excl = np.cumsum(w[order])
    drop = excl <= eps
    kept_ranks = ranks[order][~drop]
    if len(kept_ranks) == 0:
        return 1
    k = int(kept_ranks[0]) + 1
    return min(k, max_k)


def generate_labels(index: InvertedIndex, corpus: Corpus, ql: QueryLog,
                    cfg: LabelConfig = LabelConfig(),
                    cost: CostModel | None = None,
                    verbose: bool = False) -> LabelSet:
    cost = cost or CostModel.paper_scale()
    q = ql.terms.shape[0]
    w = rbp_weights(cfg.ref_depth, cfg.rbp_p)
    w = np.asarray(w)

    ref_lists = np.zeros((q, cfg.ref_depth), np.int64)
    stage1_ranks = np.zeros((q, cfg.ref_depth), np.int64)
    oracle_k = np.zeros(q, np.int64)
    oracle_rho = np.zeros(q, np.int64)
    med_at_max = np.zeros(q, np.float64)
    work_exh = np.zeros(q, np.int64)
    work_bmw = np.zeros(q, np.int64)
    blocks_bmw = np.zeros(q, np.int64)

    for lo in range(0, q, cfg.batch):
        rows = np.arange(lo, min(lo + cfg.batch, q))
        acc, _ = oracle.exhaustive_scores(index, ql.terms, ql.mask, rows)
        ref = _ideal_reference(index, corpus, ql, rows, acc, cfg)
        ref_lists[rows] = ref
        ranks = oracle.ranks_of(acc, ref, cfg.max_k)
        stage1_ranks[rows] = ranks

        # per-query exhaustive work (for R_t features/labels)
        for i, r in enumerate(rows):
            m = ql.mask[r] > 0
            work_exh[r] = int(index.df[ql.terms[r][m]].sum())

        # oracle k + mismatch filter
        capped = np.minimum(ranks, cfg.max_k)
        for i, r in enumerate(rows):
            oracle_k[r] = _oracle_k_row(capped[i], w, cfg.eps, cfg.max_k)
            med_at_max[r] = float(np.sum(w[ranks[i] >= cfg.max_k]))

        # oracle rho at fixed k = oracle_k: smallest budget whose list shows
        # "no measurable difference" vs the *exhaustive* JASS traversal
        # (paper §5 "Predicting ρ" — the ρ reference is exhaustive JASS, so
        # quantization effects cancel)
        ref_depth_rho = min(256, index.n_docs)   # RBP mass beyond ~150 < 1e-3
        acc_exh_j, _ = oracle.jass_scores(index, ql.terms, ql.mask, rows,
                                          rho=1 << 62)
        ref_j, _ = oracle._topk_ids(acc_exh_j, ref_depth_rho)
        w_rho = np.asarray(rbp_weights(ref_depth_rho, cfg.rbp_p))
        pending = np.ones(len(rows), bool)
        rho_val = np.full(len(rows), cfg.rho_grid[-1], np.int64)
        for rho in cfg.rho_grid:
            if not pending.any():
                break
            accj, _ = oracle.jass_scores(index, ql.terms, ql.mask,
                                         rows[pending], rho)
            sub = np.flatnonzero(pending)
            kk = int(min(max(oracle_k[rows[sub]].max(), 1), index.n_docs))
            ids_j, _ = oracle._topk_ids(accj, kk)
            for j, si in enumerate(sub):
                r = rows[si]
                kq = int(oracle_k[r])
                depth = min(kq, ref_depth_rho)
                in_topk = np.isin(ref_j[si][:depth], ids_j[j][:kq])
                med = float(np.sum(w_rho[:depth][~in_topk]))
                if med <= cfg.eps:
                    rho_val[si] = rho
                    pending[si] = False
        oracle_rho[rows] = rho_val

        # BMW work/time labels at the paper's fixed LtR depth
        _, wb, bb = oracle.bmw_scores(index, ql.terms, ql.mask, rows,
                                      k=cfg.time_k, theta=1.0)
        work_bmw[rows] = wb
        blocks_bmw[rows] = bb
        if verbose:
            print(f"labels {rows[-1] + 1}/{q}", flush=True)

    keep = med_at_max <= cfg.mismatch_med
    return LabelSet(
        keep=keep, ref_lists=ref_lists, oracle_k=oracle_k,
        oracle_rho=oracle_rho, med_at_max=med_at_max,
        work_exhaustive=work_exh, work_bmw=work_bmw, blocks_bmw=blocks_bmw,
        t_bmw=cost.daat_time(work_bmw, blocks_bmw),
        t_exh=cost.saat_time(work_exh),
        stage1_ranks=stage1_ranks,
    )
