"""Hybrid ISN routing — Algorithms 1 and 2 from the paper.

Given the Stage-0 predictions for a query trace, decide per query which
index mirror serves it and with what parameters:

* Algorithm 1 (``Hybrid_k``):  P_k > T_k          → JASS(P_k, min(P_ρ, ρ_max))
                                else               → BMW(P_k), rank-safe
* Algorithm 2 (``Hybrid_h``):  P_k > T_k OR P_t > T_t → JASS, else BMW

ρ is always capped at ρ_max; operating points whose ρ_max · per-posting
cost is under the budget get the worst-case guarantee from the cap alone.
For the large-ρ_max presets the guarantee comes from the scheduler's
deadline re-route instead (`repro.serving.scheduler`, "Guarantee
accounting"): stragglers are re-issued with the small `late_rho` cap.

These are pure routing functions over arrays; the online path
(`repro.serving.scheduler`) applies the same logic per request batch.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

ROUTE_BMW = 0
ROUTE_JASS = 1


@dataclass(frozen=True)
class HybridConfig:
    t_k: float = 1000.0        # k threshold T_k
    t_time_us: float = 150.0   # response-time threshold T_t (Algorithm 2)
    rho_max: int = 1 << 20     # postings cap → worst-case guarantee
    rho_min: int = 4096        # floor: never run JASS below this budget
    k_min: int = 10
    k_max: int = 16384


def route_algorithm1(pred_k: np.ndarray, cfg: HybridConfig) -> np.ndarray:
    return np.where(pred_k > cfg.t_k, ROUTE_JASS, ROUTE_BMW)


def route_algorithm2(pred_k: np.ndarray, pred_t_us: np.ndarray,
                     cfg: HybridConfig) -> np.ndarray:
    jass = (pred_k > cfg.t_k) | (pred_t_us > cfg.t_time_us)
    return np.where(jass, ROUTE_JASS, ROUTE_BMW)


def clamp_parameters(pred_k: np.ndarray, pred_rho: np.ndarray,
                     cfg: HybridConfig) -> tuple[np.ndarray, np.ndarray]:
    k = np.clip(np.round(pred_k), cfg.k_min, cfg.k_max).astype(np.int64)
    rho = np.clip(np.round(pred_rho), cfg.rho_min, cfg.rho_max).astype(np.int64)
    return k, rho
