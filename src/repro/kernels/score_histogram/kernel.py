"""Quantized-score histogram — the TPU-native top-k primitive for retrieval.

JASS scores are small integers (sum of ≤ L quantized impacts ≤ L·255), so
*exact* top-k selection over a shard's accumulator does not need a sort:
histogram the scores, scan the histogram from the top to find the k-th
score threshold, then take docs with score ≥ threshold.  The histogram is
the only O(N) pass, and on TPU it becomes — once again — a one-hot matmul:

    hist_tile = onesᵀ (1 × TILE_N) @ onehot(score_bin) (TILE_N × n_bins)

Grid steps accumulate partial histograms into a single VMEM block (the
output block index_map is constant, a standard Pallas reduction idiom).
The wrapper (`ops.py`) does the tiny (n_bins,) cumulative scan and the
final masked selection.  This replaces `jax.lax.top_k`'s O(N log N) sort
with O(N) streaming work — one of the beyond-paper optimizations evaluated
in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(scores_ref, hist_ref, *, n_bins: int, tile_n: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    s = scores_ref[0, :]
    live = (s >= 0).astype(jnp.float32)
    sb = jnp.clip(s, 0, n_bins - 1)
    onehot = (sb[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, n_bins), 1)
              ).astype(jnp.float32) * live[:, None]
    part = jax.lax.dot_general(jnp.ones((1, tile_n), jnp.float32), onehot,
                               (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    hist_ref[0, :] += part[0, :].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_bins", "tile_n", "interpret"))
def score_histogram(scores: jnp.ndarray, *, n_bins: int = 2048,
                    tile_n: int = 2048, interpret: bool = True) -> jnp.ndarray:
    """scores: (N,) int32 (N multiple of tile_n; pad with -1) -> (n_bins,)."""
    n = scores.shape[0]
    assert n % tile_n == 0
    kern = functools.partial(_hist_kernel, n_bins=n_bins, tile_n=tile_n)
    return pl.pallas_call(
        kern,
        grid=(n // tile_n,),
        in_specs=[pl.BlockSpec((1, tile_n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, n_bins), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n_bins), jnp.int32),
        interpret=interpret,
    )(scores.reshape(n // tile_n, tile_n))[0]
