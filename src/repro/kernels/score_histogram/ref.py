"""Pure-jnp oracle for the quantized-score histogram kernel."""

from __future__ import annotations

import jax.numpy as jnp


def score_histogram_ref(scores: jnp.ndarray, n_bins: int) -> jnp.ndarray:
    """Histogram of integer scores clipped to [0, n_bins).  scores: (N,) int32.

    Entries < 0 are ignored (padding / masked docs)."""
    live = scores >= 0
    s = jnp.clip(jnp.where(live, scores, 0), 0, n_bins - 1)
    return jnp.zeros((n_bins,), jnp.int32).at[s].add(live.astype(jnp.int32))
