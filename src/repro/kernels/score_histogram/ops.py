"""Histogram-based exact top-k for integer (quantized) score accumulators."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.score_histogram.kernel import score_histogram
from repro.kernels.score_histogram.ref import score_histogram_ref


@functools.partial(jax.jit, static_argnames=("k", "n_bins", "interpret"))
def histogram_topk(scores: jnp.ndarray, *, k: int, n_bins: int = 2048,
                   interpret: bool = True):
    """Exact top-k of an int32 score vector via histogram thresholding.

    Returns (values, indices) like jax.lax.top_k (ties broken by index).
    Cost: one O(N) histogram pass + one O(N) selection pass, no sort.
    """
    n = scores.shape[0]
    tile = 2048 if n % 2048 == 0 else 512 if n % 512 == 0 else 1
    if tile == 1:
        hist = score_histogram_ref(scores, n_bins)
    else:
        hist = score_histogram(scores, n_bins=n_bins, tile_n=tile,
                               interpret=interpret)
    # threshold: smallest score t with count(score >= t) >= k
    ge = jnp.cumsum(hist[::-1])[::-1]          # ge[t] = #scores >= t
    t = jnp.argmin(jnp.where(ge >= k, jnp.arange(n_bins), n_bins)[::-1])
    t = n_bins - 1 - t                          # largest t with ge[t] >= k
    t = jnp.where(ge[0] < k, 0, t)
    # selection: strict > t always included; == t filled by index order
    key = jnp.where(scores > t, scores.astype(jnp.int64) + n_bins, 0)
    key = jnp.where(scores == t, scores.astype(jnp.int64), key)
    vals, idx = jax.lax.top_k(key, k)           # small-k partial select
    return scores[idx], idx


__all__ = ["histogram_topk", "score_histogram", "score_histogram_ref"]
