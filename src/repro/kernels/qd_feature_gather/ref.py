"""Pure-jnp oracle for the Stage-2 qd-feature gather kernel."""

from __future__ import annotations

import jax.numpy as jnp


def qd_feature_gather_ref(lane_docs: jnp.ndarray, lane_scores: jnp.ndarray,
                          cand: jnp.ndarray):
    """Per-(query, candidate) term-score aggregates, dense reference.

    Args:
      lane_docs: (Q, P) int32 doc ids, -1 = dead lane.
      lane_scores: (Q, P) float32 exact scores.
      cand: (Q, C) int32 candidate doc ids, -1 = padding.
    Returns:
      (bm25, mx, cnt): (Q, C) Σ score / max score / match count.
    """
    match = ((lane_docs[:, :, None] == cand[:, None, :])
             & (lane_docs >= 0)[:, :, None] & (cand >= 0)[:, None, :])
    sc = jnp.where(match, lane_scores[:, :, None], 0.0)
    return (jnp.sum(sc, axis=1), jnp.max(sc, axis=1),
            jnp.sum(match, axis=1).astype(jnp.int32))
