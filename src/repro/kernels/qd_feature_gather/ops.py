"""jit'd wrappers: lane padding/layout -> Pallas qd-feature gather — the
entry point the Stage-2 batched re-ranker imports (mirrors the other
serving kernels' ops layer)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.qd_feature_gather.kernel import qd_feature_gather_lanes
from repro.kernels.qd_feature_gather.ref import qd_feature_gather_ref

LANE_MULTIPLE = 128   # TPU lane width: candidate axis is the minor dim


@functools.partial(jax.jit, static_argnames=("p_tile", "interpret"))
def qd_feature_gather(lane_docs: jnp.ndarray, lane_scores: jnp.ndarray,
                      cand: jnp.ndarray, *, p_tile: int = 512,
                      interpret: bool = True):
    """Pad lanes/candidates to kernel-friendly shapes and dispatch.

    The lane axis is padded to a multiple of ``p_tile`` with dead lanes and
    the candidate axis to the TPU lane width with -1 (never matched); both
    paddings are sliced back off, so the result matches
    ``qd_feature_gather_ref`` on the original shapes.
    """
    q, p = lane_docs.shape
    c = cand.shape[1]
    p_pad = (-p) % p_tile if p else p_tile
    c_pad = (-c) % LANE_MULTIPLE if c else LANE_MULTIPLE
    if p_pad:
        lane_docs = jnp.pad(lane_docs, ((0, 0), (0, p_pad)),
                            constant_values=-1)
        lane_scores = jnp.pad(lane_scores, ((0, 0), (0, p_pad)))
    if c_pad:
        cand = jnp.pad(cand, ((0, 0), (0, c_pad)), constant_values=-1)
    bm25, mx, cnt = qd_feature_gather_lanes(
        lane_docs, lane_scores, cand, p_tile=p_tile, interpret=interpret)
    return bm25[:, :c], mx[:, :c], cnt[:, :c]


__all__ = ["qd_feature_gather", "qd_feature_gather_ref"]
