"""Stage-2 query-document feature gather as a lane-match MXU reduction.

The LTR re-ranker needs, for every (query, candidate) pair, the per-term
exact-score aggregates {Σ score, max score, #matching terms} over the
query's postings.  A scalar per-term binary search is hostile to the TPU's
vector units, so the batched serving path compacts each query's ragged
per-term posting ranges into dense ``(Q, P)`` lanes (the same
``compact_lanes`` layout the DAAT engine uses) and this kernel reduces them
against the candidate grid:

    match = lanes_doc[p] == cand[c]            (P × C in-register compare)
    bm25  = scoresᵀ (1 × P) @ match (P × C)     — one-hot MXU matmul
    cnt   = 1ᵀ @ match
    mx    = column-max of score·match           — VPU reduce

Postings are unique (term, doc) pairs, so a candidate matches at most one
lane per query term — ``cnt`` is exactly the number of matching terms and
``mx`` the max per-term score, i.e. the aggregates ``qd_features`` needs.

The grid is (Q, n_ptiles): lane tiles stream through VMEM and accumulate
into the same (1, C) output block (sequential TPU grid ⇒ the revisited
block is a safe accumulator), so VMEM per step is O(P_TILE · C) no matter
how long the query's posting lanes are.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qd_gather_kernel(cand_ref, docs_ref, scores_ref, bm25_ref, mx_ref,
                      cnt_ref):
    """One (query, lane-tile) grid step: reduce a lane tile into (1, C)."""
    pt = pl.program_id(1)
    d = docs_ref[0, :]                          # (PT,) int32, -1 = dead lane
    s = scores_ref[0, :]                        # (PT,) float32
    c = cand_ref[0, :]                          # (C,) int32, -1 = pad
    match = ((d[:, None] == c[None, :])
             & (d[:, None] >= 0) & (c[None, :] >= 0))       # (PT, C)
    mf = match.astype(jnp.float32)
    part_sum = jax.lax.dot_general(s[None, :], mf,
                                   (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)[0]
    part_cnt = jax.lax.dot_general(jnp.ones((1, d.shape[0]), jnp.float32), mf,
                                   (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)[0]
    part_mx = jnp.max(jnp.where(match, s[:, None], 0.0), axis=0)

    @pl.when(pt == 0)
    def _init():
        bm25_ref[0, :] = part_sum
        mx_ref[0, :] = part_mx
        cnt_ref[0, :] = part_cnt.astype(jnp.int32)

    @pl.when(pt > 0)
    def _accumulate():
        bm25_ref[0, :] += part_sum
        mx_ref[0, :] = jnp.maximum(mx_ref[0, :], part_mx)
        cnt_ref[0, :] += part_cnt.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("p_tile", "interpret"))
def qd_feature_gather_lanes(lane_docs: jnp.ndarray, lane_scores: jnp.ndarray,
                            cand: jnp.ndarray, *, p_tile: int = 512,
                            interpret: bool = True):
    """Per-(query, candidate) term-score aggregates over compacted lanes.

    Args:
      lane_docs: (Q, P) int32 doc ids of the query's postings, -1 dead.
      lane_scores: (Q, P) float32 exact scores, 0 in dead lanes.
      cand: (Q, C) int32 candidate doc ids, -1 padding.
      p_tile: posting lanes per grid step (P must be a multiple).
    Returns:
      (bm25, mx, cnt): (Q, C) float32/float32/int32 — Σ score, max score and
      match count per candidate.
    """
    q, p = lane_docs.shape
    c = cand.shape[1]
    assert p % p_tile == 0, (p, p_tile)
    n_ptiles = p // p_tile
    return pl.pallas_call(
        _qd_gather_kernel,
        grid=(q, n_ptiles),
        in_specs=[
            pl.BlockSpec((1, c), lambda qi, t: (qi, 0)),
            pl.BlockSpec((1, p_tile), lambda qi, t: (qi, t)),
            pl.BlockSpec((1, p_tile), lambda qi, t: (qi, t)),
        ],
        out_specs=[
            pl.BlockSpec((1, c), lambda qi, t: (qi, 0)),
            pl.BlockSpec((1, c), lambda qi, t: (qi, 0)),
            pl.BlockSpec((1, c), lambda qi, t: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, c), jnp.float32),
            jax.ShapeDtypeStruct((q, c), jnp.float32),
            jax.ShapeDtypeStruct((q, c), jnp.int32),
        ],
        interpret=interpret,
    )(cand, lane_docs, lane_scores)
