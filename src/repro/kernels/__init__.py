"""Pallas kernels for the first-stage retrieval hot loops.

Each package holds ``kernel.py`` (the Pallas program), ``ops.py`` (jit'd
layout/dispatch wrappers — what the engines import), and ``ref.py`` (a
pure-jnp oracle the tests hold the kernel to).

Serving kernels share one **bucketed postings layout**: at index-build
time every posting of a shard is tiled into the ``(n_tiles, tile_cap)``
bucket of its ``tile_d``-doc tile (``IndexShard.tile_docs/terms/scores/
imps`` — see ``repro.index.postings``), doc ids rebased tile-locally and
buckets lane-padded.  A batched kernel then runs a (Q, n_tiles) grid: the
tile buckets are indexed by the tile coordinate only, so the whole query
batch reads the same shard-resident blocks zero-copy; term matching
happens in-register and each step reduces one bucket into a
``(1, tile_d)`` accumulator tile with a one-hot MXU matmul.

* ``blockmax_score`` — DAAT/BMW exact scoring.  Per-block survival flags
  ride in per (query, tile); pruned tiles skip their load/matmul entirely
  via ``pl.when``, so latency tracks the *surviving* work per query.
* ``impact_accumulate`` — SAAT/JASS accumulation.  The ρ budget arrives as
  the per-query impact-level cut ``lstar``; compiled cost is a
  deterministic function of the layout (the structural 200 ms guarantee).
* ``qd_feature_gather`` — Stage-2 LTR featurization: per-(query,
  candidate) term-score aggregates {Σ score, max, match count} over the
  batch's compacted posting lanes, reduced with the same one-hot MXU
  matmul idiom (grid (Q, lane-tiles), accumulating output block).
* ``score_histogram`` — histogram-based top-k over quantized accumulators.
* ``flash_attention`` — attention kernels for the stage-2/LM workloads.

Backend dispatch: the engines (``repro.isn.daat`` / ``repro.isn.saat``)
select ``backend="pallas"`` (compiled, TPU), ``"interpret"`` (same kernel
program under the Pallas interpreter — CPU tests), or ``"jnp"`` (fused
batched gather/scatter fast path for CPU hosts) via
``repro.isn.backend.resolve_backend``; parity across all three is enforced
by ``tests/test_serving_pipeline.py``.
"""
