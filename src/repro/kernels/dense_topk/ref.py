"""Brute-force oracles for the dense similarity top-k kernel.

Two references with one contract: exact scores, ties toward the lower doc
id.  ``dense_topk_oracle`` is the host-side numpy ground truth (full score
matrix + stable argsort); ``dense_topk_ref`` is the jnp backend the serving
path uses off-TPU (``lax.top_k`` keeps the earliest position on ties, which
over a doc-ordered score row is the same tie-break).

Bitwise agreement between the two — and with the tiled Pallas kernel — is
not a float accident: the dense index stores embeddings snapped to an exact
power-of-two grid (``repro.dense.embeddings.quantize``), so every product
and every partial sum of a query·doc dot product is exactly representable
in float32 and the result is independent of accumulation order.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_topk_oracle(q_emb: np.ndarray, doc_emb: np.ndarray, k: int):
    """numpy brute force: (scores, ids), each (Q, k), ties -> lower doc id.

    ``-scores`` under a stable argsort keeps ascending index order inside
    every tie group, i.e. the lower doc id wins — the cascade-wide tie
    policy (``merge_shard_topk`` docstring).
    """
    q = np.asarray(q_emb, np.float32)
    d = np.asarray(doc_emb, np.float32)
    scores = q @ d.T                                        # (Q, N)
    order = np.argsort(-scores, axis=1, kind="stable")[:, :k]
    return (np.take_along_axis(scores, order, axis=1).astype(np.float32),
            order.astype(np.int64))


def dense_topk_ref(q_emb: jnp.ndarray, doc_emb: jnp.ndarray, k: int):
    """Pure-jnp reference: full (Q, N) score matrix + ``lax.top_k``."""
    scores = jnp.dot(jnp.asarray(q_emb, jnp.float32),
                     jnp.asarray(doc_emb, jnp.float32).T,
                     preferred_element_type=jnp.float32)
    sc, ids = jax.lax.top_k(scores, k)
    return sc, ids
