"""Dense Stage-1 retrieval: batched tiled query×doc top-k on the MXU.

The dense modality's serving kernel: one (Q, n_tiles) grid streams the
(n_docs, d) embedding matrix through VMEM in ``tile_d``-doc tiles.  Each
grid step scores its tile against one query row with a single MXU matmul
(``(1, d) @ (d, tile_d)``) and folds the tile into a running per-query
top-k held in revisited ``(1, k_pad)`` output blocks — the same
concat-then-``top_k`` streaming merge as ``repro.models.recsys.
streaming_topk``, moved inside the kernel so the full (Q, n_docs) score
matrix never materializes.  The sequential TPU grid makes the revisited
blocks safe accumulators (the idiom of ``qd_feature_gather``).

Tie-break: tiles are visited in ascending doc order, the running list sits
*before* the new tile in the concat, and ``lax.top_k`` keeps the earliest
position on ties — so equal scores resolve toward the lower doc id, the
cascade-wide tie policy (``merge_shard_topk``).  Ghost lanes in the ragged
tail tile score ``float32 min`` with id ``-1`` and can never surface while
``k <= n_docs`` (the ops layer enforces it).

VMEM per step is O(tile_d · d + k_pad), independent of n_docs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dense_topk_kernel(q_ref, emb_ref, sc_ref, id_ref, *, k_pad: int,
                       tile_d: int, n_docs: int):
    """One (query, doc-tile) grid step: score the tile, merge the top-k."""
    t = pl.program_id(1)
    tile = emb_ref[...]                                   # (tile_d, d)
    part = jax.lax.dot_general(q_ref[0:1, :], tile,
                               (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    ids = (t * tile_d
           + jax.lax.broadcasted_iota(jnp.int32, (1, tile_d), 1))
    fill = jnp.finfo(jnp.float32).min
    live = ids < n_docs                                   # ragged tail tile
    part = jnp.where(live, part, fill)
    ids = jnp.where(live, ids, -1)

    @pl.when(t == 0)
    def _init():
        sc_ref[0:1, :] = jnp.full((1, k_pad), fill, jnp.float32)
        id_ref[0:1, :] = jnp.full((1, k_pad), -1, jnp.int32)

    cat_sc = jnp.concatenate([sc_ref[0:1, :], part], axis=1)
    cat_id = jnp.concatenate([id_ref[0:1, :], ids], axis=1)
    best_sc, pos = jax.lax.top_k(cat_sc, k_pad)
    sc_ref[0:1, :] = best_sc
    id_ref[0:1, :] = jnp.take_along_axis(cat_id, pos, axis=1)


@functools.partial(jax.jit, static_argnames=("k_pad", "tile_d", "n_docs",
                                             "interpret"))
def dense_topk_tiles(q_emb: jnp.ndarray, doc_emb: jnp.ndarray, *,
                     k_pad: int, tile_d: int, n_docs: int,
                     interpret: bool = True):
    """Streaming top-k of ``q_emb @ doc_embᵀ`` over doc tiles.

    Args:
      q_emb: (Q, d) float32 query embeddings; d a lane multiple.
      doc_emb: (n_tiles·tile_d, d) float32, rows past ``n_docs`` are pad.
      k_pad: results per query (lane multiple; callers slice back to k).
    Returns:
      (scores, ids): (Q, k_pad) float32 / int32, score-descending, ties
      toward the lower doc id; ghost entries score float32-min with id -1.
    """
    q, d = q_emb.shape
    n_tiles = doc_emb.shape[0] // tile_d
    assert doc_emb.shape[0] == n_tiles * tile_d, (doc_emb.shape, tile_d)
    kern = functools.partial(_dense_topk_kernel, k_pad=k_pad,
                             tile_d=tile_d, n_docs=n_docs)
    return pl.pallas_call(
        kern,
        grid=(q, n_tiles),
        in_specs=[
            pl.BlockSpec((1, d), lambda qi, t: (qi, 0)),
            pl.BlockSpec((tile_d, d), lambda qi, t: (t, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k_pad), lambda qi, t: (qi, 0)),
            pl.BlockSpec((1, k_pad), lambda qi, t: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, k_pad), jnp.float32),
            jax.ShapeDtypeStruct((q, k_pad), jnp.int32),
        ],
        interpret=interpret,
    )(q_emb, doc_emb)
