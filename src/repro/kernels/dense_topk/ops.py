"""jit'd wrappers: shape padding -> dense top-k kernel dispatch — the entry
point the dense Stage-1 engine imports, with the same ``pallas | interpret
| jnp`` switch as the other serving kernels."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.dense_topk.kernel import dense_topk_tiles
from repro.kernels.dense_topk.ref import dense_topk_ref

LANE_MULTIPLE = 128   # TPU lane width: embed dim and k live on the minor axis


@functools.partial(jax.jit, static_argnames=("k", "tile_d", "backend"))
def dense_topk(q_emb: jnp.ndarray, doc_emb: jnp.ndarray, k: int, *,
               tile_d: int = 512, backend: str = "jnp"):
    """Top-k of ``q_emb @ doc_embᵀ``: (scores, ids), each (Q, k).

    ``backend="jnp"`` runs the dense reference (full score matrix +
    ``lax.top_k``); ``"pallas"`` / ``"interpret"`` run the tiled streaming
    kernel compiled / in interpreter mode.  The embed dim is zero-padded to
    the lane width (zero products are exact — no parity cost) and the doc
    axis to a ``tile_d`` multiple; ghost docs are masked in-kernel.  All
    backends agree bitwise on grid-quantized embeddings (see
    ``kernels/dense_topk/ref.py``).
    """
    q_emb = jnp.asarray(q_emb, jnp.float32)
    doc_emb = jnp.asarray(doc_emb, jnp.float32)
    n, d = doc_emb.shape
    if not 1 <= k <= n:
        raise ValueError(f"k={k} must be in [1, n_docs={n}]")
    if backend == "jnp":
        return dense_topk_ref(q_emb, doc_emb, k)
    if tile_d % LANE_MULTIPLE:
        raise ValueError(f"tile_d={tile_d} must be a multiple of "
                         f"{LANE_MULTIPLE}")
    d_pad = (-d) % LANE_MULTIPLE
    if d_pad:
        q_emb = jnp.pad(q_emb, ((0, 0), (0, d_pad)))
        doc_emb = jnp.pad(doc_emb, ((0, 0), (0, d_pad)))
    n_pad = (-n) % tile_d
    if n_pad:
        doc_emb = jnp.pad(doc_emb, ((0, n_pad), (0, 0)))
    k_pad = -(-k // LANE_MULTIPLE) * LANE_MULTIPLE
    sc, ids = dense_topk_tiles(q_emb, doc_emb, k_pad=k_pad, tile_d=tile_d,
                               n_docs=n, interpret=(backend != "pallas"))
    # ids stay int32 on device (x64 is disabled); hosts widen as needed
    return sc[:, :k], ids[:, :k]


__all__ = ["dense_topk", "dense_topk_ref"]
