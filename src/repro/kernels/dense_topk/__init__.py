from repro.kernels.dense_topk.ops import dense_topk
from repro.kernels.dense_topk.ref import dense_topk_oracle, dense_topk_ref

__all__ = ["dense_topk", "dense_topk_oracle", "dense_topk_ref"]
