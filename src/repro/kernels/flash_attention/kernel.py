"""Tiled online-softmax attention kernels (FlashAttention-style) for TPU.

Two kernels:

* ``flash_attention``  — prefill/training: grid (B, H, S/TQ, S/TK), causal
  tiles above the diagonal are skipped whole (grid-level work skipping, the
  same predication idiom as the retrieval kernels).  Running max / sum /
  accumulator live in VMEM scratch across the innermost (key) grid axis.
* ``flash_decode``     — single-token decode with a split-KV grid
  (FlashDecoding): each grid step reduces one KV chunk to partial
  (acc, m, l) statistics; the wrapper merges splits with a stable
  log-sum-exp combine.  This is the kernel behind the ``decode_32k`` and
  ``long_500k`` shapes, where the KV cache is sequence-sharded and each
  shard reduces its local splits before a cross-shard merge.

GQA is handled in the BlockSpec index maps (kv head = q head // group) so
no KV duplication ever materializes.

VMEM at defaults (TQ=TK=128, D=128, fp32 accum): q/k/v tiles 3·64 KB +
acc 64 KB + stats ≈ 1 KB — comfortably double-bufferable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, tq: int, tk: int, n_tk: int):
    jq = pl.program_id(2)
    jk = pl.program_id(3)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = (not causal) or (jk * tk <= jq * tq + tq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)          # (TQ, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (TK, D)
        v = v_ref[0, 0].astype(jnp.float32)          # (TK, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = jq * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
            cols = jk * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(jk == n_tk - 1)
    def _fini():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "tq", "tk", "scale",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, tq: int = 128,
                    tk: int = 128, scale: float | None = None,
                    interpret: bool = True):
    """q: (B, H, S, D); k, v: (B, Hkv, S, D) -> (B, H, S, D)."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    scale = scale if scale is not None else d ** -0.5
    tq, tk = min(tq, s), min(tk, s)
    n_tq, n_tk = s // tq, s // tk
    kern = functools.partial(_flash_kernel, scale=scale, causal=causal,
                             tq=tq, tk=tk, n_tk=n_tk)
    return pl.pallas_call(
        kern,
        grid=(b, h, n_tq, n_tk),
        in_specs=[
            pl.BlockSpec((1, 1, tq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, tk, d),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
            pl.BlockSpec((1, 1, tk, d),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, tq, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq,), jnp.float32),
            pltpu.VMEM((tq,), jnp.float32),
            pltpu.VMEM((tq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# split-KV decode
# ---------------------------------------------------------------------------

def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, *,
                   scale: float, tk: int):
    sp = pl.program_id(2)
    q = q_ref[0].astype(jnp.float32)                 # (1, D)
    k = k_ref[0, 0].astype(jnp.float32)              # (TK, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)[0] * scale
    pos = sp * tk + jax.lax.broadcasted_iota(jnp.int32, (tk,), 0)
    s = jnp.where(pos < len_ref[0], s, NEG_INF)
    m = jnp.max(s)
    p = jnp.exp(s - m)
    l = jnp.sum(p)
    acc = jax.lax.dot_general(p[None, :], v, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    o_ref[0, 0, 0] = acc[0]
    m_ref[0, 0, 0] = m
    l_ref[0, 0, 0] = l


@functools.partial(jax.jit, static_argnames=("tk", "scale", "interpret"))
def flash_decode(q, k, v, kv_len, *, tk: int = 512, scale: float | None = None,
                 interpret: bool = True):
    """q: (B, H, D); k, v: (B, Hkv, S, D); kv_len: (B,) -> (B, H, D).

    Returns the attention output after merging the per-split partials.
    """
    b, h, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    group = h // hkv
    scale = scale if scale is not None else d ** -0.5
    tk = min(tk, s)
    n_sp = s // tk
    kern = functools.partial(_decode_kernel, scale=scale, tk=tk)
    out, m, l = pl.pallas_call(
        kern,
        grid=(b, h, n_sp),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda bi, hi, si: (bi, hi, 0)),
            pl.BlockSpec((1, 1, tk, d),
                         lambda bi, hi, si: (bi, hi // group, si, 0)),
            pl.BlockSpec((1, 1, tk, d),
                         lambda bi, hi, si: (bi, hi // group, si, 0)),
            pl.BlockSpec((1,), lambda bi, hi, si: (bi,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda bi, hi, si: (bi, hi, si, 0)),
            pl.BlockSpec((1, 1, 1), lambda bi, hi, si: (bi, hi, si)),
            pl.BlockSpec((1, 1, 1), lambda bi, hi, si: (bi, hi, si)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, n_sp, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, n_sp), jnp.float32),
            jax.ShapeDtypeStruct((b, h, n_sp), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, kv_len)

    # stable cross-split merge: softmax over all splits =
    #   Σ_i e^{m_i - m*} acc_i  /  Σ_i e^{m_i - m*} l_i
    m_star = jnp.max(m, axis=-1, keepdims=True)          # (B, H, 1)
    scale_sp = jnp.exp(m - m_star)                       # (B, H, n_sp)
    denom = jnp.maximum(jnp.sum(scale_sp * l, axis=-1, keepdims=True), 1e-30)
    merged = jnp.sum(out * scale_sp[..., None], axis=2) / denom
    return merged.astype(q.dtype)
