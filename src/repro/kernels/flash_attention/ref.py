"""Pure-jnp oracles for flash attention (prefill) and flash decode."""

from __future__ import annotations

import jax.numpy as jnp


def _expand_kv(k, n_heads):
    """(B, Hkv, S, D) -> (B, H, S, D) by GQA head-group broadcast."""
    b, hkv, s, d = k.shape
    group = n_heads // hkv
    return jnp.repeat(k, group, axis=1)


def attention_ref(q, k, v, causal: bool = True, scale: float | None = None):
    """(B, H, S, D) x (B, Hkv, S, D) -> (B, H, S, D), fp32 math."""
    b, h, s, d = q.shape
    scale = scale if scale is not None else d ** -0.5
    k = _expand_kv(k, h).astype(jnp.float32)
    v = _expand_kv(v, h).astype(jnp.float32)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
    w = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v).astype(q.dtype)


def decode_ref(q, k, v, kv_len=None, scale: float | None = None):
    """Single-token decode: q (B, H, D), kv (B, Hkv, S, D) -> (B, H, D).

    kv_len: (B,) optional valid cache lengths (positions >= kv_len masked).
    """
    b, h, d = q.shape
    s = k.shape[2]
    scale = scale if scale is not None else d ** -0.5
    k = _expand_kv(k, h).astype(jnp.float32)
    v = _expand_kv(v, h).astype(jnp.float32)
    logits = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), k) * scale
    if kv_len is not None:
        pos = jnp.arange(s)
        logits = jnp.where(pos[None, None, :] < kv_len[:, None, None],
                           logits, -1e30)
    w = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return jnp.einsum("bhk,bhkd->bhd", w, v).astype(q.dtype)
