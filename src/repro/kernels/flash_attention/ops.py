"""Public attention ops: dispatch to Pallas kernels on TPU, jnp ref on CPU.

The model code calls these; `use_kernel` defaults to False on CPU (the
interpret-mode kernels are exercised by tests, not the training loop, since
interpreting every step would be slow) and to True under TPU lowering.
"""

from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention, flash_decode
from repro.kernels.flash_attention.ref import attention_ref, decode_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(q, k, v, causal: bool = True, use_kernel: bool | None = None):
    use_kernel = _on_tpu() if use_kernel is None else use_kernel
    if use_kernel:
        return flash_attention(q, k, v, causal=causal,
                               interpret=not _on_tpu())
    return attention_ref(q, k, v, causal=causal)


def decode_attention(q, k, v, kv_len, use_kernel: bool | None = None):
    use_kernel = _on_tpu() if use_kernel is None else use_kernel
    if use_kernel:
        return flash_decode(q, k, v, kv_len, interpret=not _on_tpu())
    return decode_ref(q, k, v, kv_len)


__all__ = ["attention", "decode_attention", "flash_attention", "flash_decode",
           "attention_ref", "decode_ref"]
