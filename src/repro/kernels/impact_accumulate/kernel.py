"""SAAT impact accumulation as an MXU matmul — the TPU adaptation of JASS's
scatter loop (`acc[doc] += impact`).

Hardware mapping
----------------
A scalar scatter-add is hostile to the TPU's vector/matrix units, so the
postings are *bucketed by document tile* (done by `ops.py` with one sort —
the JASS ρ budget is an impact-level mask, so processing order inside a
bucket is irrelevant) and each grid step reduces one bucket with a one-hot
matmul:

    acc[tile] = impactsᵀ (1 × CAP)  @  onehot(local_doc) (CAP × TILE_D)

Capacity bound: postings are unique (term, doc) pairs, so a TILE_D-doc tile
receives at most TILE_D × L postings for an L-term query — CAP = TILE_D × L
can never overflow.  VMEM per step: CAP·(4+4) B + TILE_D·4 B ≈ 10 KB at
TILE_D=128, L=8 — far under the ~16 MB budget, so several grid steps can be
double-buffered.

The ρ budget appears as the scalar `lstar` (impact-level cut): lanes with
impact < lstar contribute zero, and the *grid itself* is sized by the
bucketed layout, so compiled cost is a deterministic function of ρ_max —
the structural version of the paper's 200 ms guarantee.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _accumulate_kernel(lstar_ref, docs_ref, imps_ref, acc_ref, *, tile_d: int):
    """One bucket -> one accumulator tile."""
    local = docs_ref[0, :]                        # (CAP,) int32, -1 = pad
    imps = imps_ref[0, :]                         # (CAP,)
    live = (local >= 0) & (imps >= lstar_ref[0])
    v = jnp.where(live, imps, 0).astype(jnp.float32)
    d = jnp.where(live, local, -1)
    onehot = (d[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, tile_d), 1)
              ).astype(jnp.float32)               # (CAP, TILE_D)
    acc = jax.lax.dot_general(v[None, :], onehot,
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    acc_ref[0, :] = acc[0, :].astype(jnp.int32)


def _accumulate_kernel_batched(qterms_ref, lstar_ref, docs_ref, terms_ref,
                               imps_ref, acc_ref, *, tile_d: int):
    """One (query, doc-tile) grid step over the shard's bucketed mirror.

    The ρ budget arrives as the per-query impact-level cut ``lstar``: a lane
    contributes iff its term is one of the query's terms AND its impact
    reaches the cut.  The grid is (Q, n_tiles) with the tile buckets indexed
    by the tile coordinate only — one launch serves the whole query batch
    against a zero-copy view of the shard, and compiled cost stays a
    deterministic function of the shard layout (the structural 200 ms
    guarantee survives batching).
    """
    local = docs_ref[0, :]                        # (CAP,) tile-local, -1 pad
    tterm = terms_ref[0, :]                       # (CAP,) term ids, -1 pad
    imps = imps_ref[0, :]                         # (CAP,)
    qt = qterms_ref[0, :]                         # (L,) query terms, -1 pad
    match = jnp.any(tterm[:, None] == qt[None, :], axis=1)
    live = (local >= 0) & match & (imps >= lstar_ref[0])
    v = jnp.where(live, imps, 0).astype(jnp.float32)
    d = jnp.where(live, local, -1)
    onehot = (d[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, tile_d), 1)
              ).astype(jnp.float32)
    acc = jax.lax.dot_general(v[None, :], onehot,
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    acc_ref[0, 0, :] = acc[0, :].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("tile_d", "interpret"))
def impact_accumulate_batched(tile_docs: jnp.ndarray, tile_terms: jnp.ndarray,
                              tile_imps: jnp.ndarray, qterms: jnp.ndarray,
                              lstar: jnp.ndarray, *, tile_d: int,
                              interpret: bool = True) -> jnp.ndarray:
    """Batched impact accumulation over the shard's bucketed mirror.

    Args:
      tile_docs/tile_terms/tile_imps: (n_tiles, CAP) build-time bucketed
        shard mirror — shared (zero-copy) across the query batch.
      qterms: (Q, L) query term ids, -1 in masked-out slots.
      lstar: (Q,) int32 per-query impact-level cuts from the ρ budgets.
    Returns:
      (Q, n_tiles, tile_d) int32 accumulator tiles.
    """
    n_tiles, cap = tile_docs.shape
    q, L = qterms.shape
    kern = functools.partial(_accumulate_kernel_batched, tile_d=tile_d)
    return pl.pallas_call(
        kern,
        grid=(q, n_tiles),
        in_specs=[
            pl.BlockSpec((1, L), lambda qi, t: (qi, 0)),
            pl.BlockSpec((1,), lambda qi, t: (qi,)),
            pl.BlockSpec((1, cap), lambda qi, t: (t, 0)),
            pl.BlockSpec((1, cap), lambda qi, t: (t, 0)),
            pl.BlockSpec((1, cap), lambda qi, t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, tile_d), lambda qi, t: (qi, t, 0)),
        out_shape=jax.ShapeDtypeStruct((q, n_tiles, tile_d), jnp.int32),
        interpret=interpret,
    )(qterms, lstar, tile_docs, tile_terms, tile_imps)


@functools.partial(jax.jit, static_argnames=("tile_d", "interpret"))
def impact_accumulate_bucketed(docs_b: jnp.ndarray, imps_b: jnp.ndarray,
                               lstar: jnp.ndarray, *, tile_d: int,
                               interpret: bool = True) -> jnp.ndarray:
    """Run the Pallas kernel over a bucketed postings layout.

    Args:
      docs_b: (n_tiles, CAP) int32 — doc ids *local to each tile*, -1 padding.
      imps_b: (n_tiles, CAP) int32.
      lstar:  () int32 — impact-level cut from the ρ budget.
      tile_d: docs per accumulator tile.
    Returns:
      (n_tiles, tile_d) int32 accumulator tiles (reshape to (N,) outside).
    """
    n_tiles, cap = docs_b.shape
    kern = functools.partial(_accumulate_kernel, tile_d=tile_d)
    return pl.pallas_call(
        kern,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),            # lstar (replicated)
            pl.BlockSpec((1, cap), lambda i: (i, 0)),      # docs bucket
            pl.BlockSpec((1, cap), lambda i: (i, 0)),      # imps bucket
        ],
        out_specs=pl.BlockSpec((1, tile_d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, tile_d), jnp.int32),
        interpret=interpret,
    )(lstar.reshape(1), docs_b, imps_b)
