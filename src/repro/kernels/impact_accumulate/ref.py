"""Pure-jnp oracle for the SAAT impact-accumulation kernel."""

from __future__ import annotations

import jax.numpy as jnp


def impact_accumulate_ref(docs: jnp.ndarray, imps: jnp.ndarray,
                          lstar: jnp.ndarray, n_docs: int) -> jnp.ndarray:
    """Accumulate quantized impacts of postings whose impact >= lstar.

    Args:
      docs: (P,) int32 doc ids; entries with doc < 0 are padding.
      imps: (P,) int32 quantized impacts.
      lstar: scalar int32 — the JASS level cut resolved from the ρ budget.
      n_docs: accumulator size.
    Returns:
      (n_docs,) int32 accumulator.
    """
    live = (docs >= 0) & (imps >= lstar)
    d = jnp.where(live, docs, 0)
    v = jnp.where(live, imps, 0)
    return jnp.zeros((n_docs,), jnp.int32).at[d].add(v)
