"""jit'd wrappers: flat postings -> bucketed layout -> Pallas accumulate,
and the batched shard-mirror entry point used by the serving pipeline."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.impact_accumulate.kernel import (impact_accumulate_batched,
                                                    impact_accumulate_bucketed)
from repro.kernels.impact_accumulate.ref import impact_accumulate_ref


@functools.partial(jax.jit, static_argnames=("tile_d", "interpret"))
def impact_accumulate_tiles(tile_docs: jnp.ndarray, tile_terms: jnp.ndarray,
                            tile_imps: jnp.ndarray, qterms: jnp.ndarray,
                            lstar: jnp.ndarray, *, tile_d: int,
                            interpret: bool = True) -> jnp.ndarray:
    """Batched SAAT accumulation over the shard's bucketed mirror.

    Thin dispatch onto ``impact_accumulate_batched``; exists so the engines
    depend on the ops layer (mirroring ``blockmax_score_tiles``) rather than
    on kernel internals.  Returns (Q, n_tiles, tile_d) int32 tiles.
    """
    return impact_accumulate_batched(tile_docs, tile_terms, tile_imps,
                                     qterms, lstar.astype(jnp.int32),
                                     tile_d=tile_d, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("n_docs", "tile_d", "cap",
                                             "interpret"))
def impact_accumulate(docs: jnp.ndarray, imps: jnp.ndarray,
                      lstar: jnp.ndarray, *, n_docs: int, tile_d: int = 128,
                      cap: int | None = None,
                      interpret: bool = True) -> jnp.ndarray:
    """Accumulate postings (docs, imps) with impact >= lstar into a dense
    (n_docs,) accumulator via the bucketed MXU kernel.

    `cap` must be >= the max postings per doc tile.  For unique (term, doc)
    postings of an L-term query, cap = tile_d * L is a hard bound; callers
    with tighter knowledge (e.g. ρ_max ≪ tile budget) may pass less and the
    wrapper falls back to the jnp scatter for overflow lanes (exactness is
    never sacrificed).
    """
    p = docs.shape[0]
    n_tiles = -(-n_docs // tile_d)
    cap = cap if cap is not None else tile_d * 8

    live = docs >= 0
    tile = jnp.where(live, docs // tile_d, n_tiles)         # pad -> ghost tile
    order = jnp.argsort(tile)
    tile_s = tile[order]
    docs_s = jnp.where(live[order], docs[order] - tile_s * tile_d, -1)
    imps_s = imps[order]

    counts = jnp.zeros((n_tiles + 1,), jnp.int32).at[tile_s].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    pos = jnp.arange(p, dtype=jnp.int32) - starts[tile_s]

    fits = (pos < cap) & (tile_s < n_tiles)
    slot = jnp.where(fits, tile_s * cap + pos, n_tiles * cap)
    docs_b = jnp.full((n_tiles * cap + 1,), -1, jnp.int32
                      ).at[slot].set(jnp.where(fits, docs_s, -1))
    imps_b = jnp.zeros((n_tiles * cap + 1,), jnp.int32
                       ).at[slot].set(jnp.where(fits, imps_s, 0))

    acc_t = impact_accumulate_bucketed(
        docs_b[:-1].reshape(n_tiles, cap), imps_b[:-1].reshape(n_tiles, cap),
        lstar, tile_d=tile_d, interpret=interpret)
    acc = acc_t.reshape(n_tiles * tile_d)[:n_docs]

    # overflow fallback (cap exceeded): exact jnp scatter of the residue
    over = live[order] & ~fits & (tile_s < n_tiles)
    d_of = jnp.where(over, docs[order], 0)
    v_of = jnp.where(over & (imps_s >= lstar), imps_s, 0)
    acc = acc.at[d_of].add(v_of)
    return acc


__all__ = ["impact_accumulate", "impact_accumulate_ref",
           "impact_accumulate_tiles"]
