"""jit'd wrappers: flat postings + block survival -> Pallas masked scoring,
and the batched shard-mirror entry point used by the serving pipeline."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.blockmax_score.kernel import (blockmax_score_batched,
                                                 blockmax_score_bucketed)
from repro.kernels.blockmax_score.ref import blockmax_score_ref


@functools.partial(jax.jit, static_argnames=("tile_d", "block_size",
                                             "n_blocks", "interpret"))
def blockmax_score_tiles(tile_docs: jnp.ndarray, tile_terms: jnp.ndarray,
                         tile_scores: jnp.ndarray, qterms: jnp.ndarray,
                         survive: jnp.ndarray, *, tile_d: int,
                         block_size: int, n_blocks: int,
                         interpret: bool = True) -> jnp.ndarray:
    """Batched masked scoring over the shard's bucketed mirror.

    Args:
      tile_docs/tile_terms/tile_scores: (n_tiles, CAP) build-time bucketed
        shard mirror (see ``IndexShard``).
      qterms: (Q, L) query term ids with -1 in masked-out slots.
      survive: (Q, n_blocks) bool/int — per-query pruning-block survival.
    Returns:
      (Q, n_tiles, tile_d) float32 accumulator tiles; reduce with the tiled
      top-k merge (``repro.isn.backend.topk_from_tiles``).
    """
    n_tiles = tile_docs.shape[0]
    q = qterms.shape[0]
    bpt = tile_d // block_size
    pad = n_tiles * bpt - n_blocks
    sb = jnp.pad(survive.astype(jnp.int32), ((0, 0), (0, pad)))
    sb = sb.reshape(q, n_tiles, bpt)
    st = (jnp.sum(sb, axis=2) > 0).astype(jnp.int32)
    return blockmax_score_batched(tile_docs, tile_terms, tile_scores,
                                  qterms, sb, st, tile_d=tile_d,
                                  block_size=block_size, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("n_docs", "block_size", "tile_d",
                                             "cap", "interpret"))
def blockmax_score(docs: jnp.ndarray, scores: jnp.ndarray,
                   survive: jnp.ndarray, *, n_docs: int, block_size: int,
                   tile_d: int = 128, cap: int = 1024,
                   interpret: bool = True) -> jnp.ndarray:
    """Exact scoring restricted to surviving blocks.

    ``tile_d`` must be a multiple of ``block_size`` (a kernel tile covers
    whole pruning blocks); a tile survives if any of its blocks survives —
    postings in its dead blocks are masked lane-wise before bucketing.
    """
    assert tile_d % block_size == 0
    p = docs.shape[0]
    n_tiles = -(-n_docs // tile_d)

    live = docs >= 0
    blk = jnp.where(live, docs // block_size, 0)
    keep = live & survive[blk]
    docs_m = jnp.where(keep, docs, -1)

    tile = jnp.where(keep, docs_m // tile_d, n_tiles)
    order = jnp.argsort(tile)
    tile_s = tile[order]
    docs_s = jnp.where(keep[order], docs_m[order] - tile_s * tile_d, -1)
    scores_s = scores[order]

    counts = jnp.zeros((n_tiles + 1,), jnp.int32).at[tile_s].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    pos = jnp.arange(p, dtype=jnp.int32) - starts[tile_s]
    fits = (pos < cap) & (tile_s < n_tiles)
    slot = jnp.where(fits, tile_s * cap + pos, n_tiles * cap)
    docs_b = jnp.full((n_tiles * cap + 1,), -1, jnp.int32
                      ).at[slot].set(jnp.where(fits, docs_s, -1))
    scores_b = jnp.zeros((n_tiles * cap + 1,), jnp.float32
                         ).at[slot].set(jnp.where(fits, scores_s, 0.0))

    # tile survives if any posting reached it
    survive_t = (counts[:n_tiles] > 0).astype(jnp.int32)

    acc_t = blockmax_score_bucketed(
        docs_b[:-1].reshape(n_tiles, cap), scores_b[:-1].reshape(n_tiles, cap),
        survive_t, tile_d=tile_d, interpret=interpret)
    acc = acc_t.reshape(n_tiles * tile_d)[:n_docs]

    over = keep[order] & ~fits & (tile_s < n_tiles)
    d_of = jnp.where(over, docs_m[order], 0)
    v_of = jnp.where(over, scores_s, 0.0)
    return acc.at[d_of].add(v_of)


__all__ = ["blockmax_score", "blockmax_score_ref", "blockmax_score_tiles"]
