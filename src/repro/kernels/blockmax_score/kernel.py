"""Block-max pruned DAAT scoring — the TPU adaptation of BMW's skip logic.

Same bucketed one-hot-matmul layout as ``impact_accumulate`` (one doc tile
per grid step) plus the BMW ingredient: a per-tile *survival predicate*
derived from the block upper bounds.  Pruned tiles skip their matmul
entirely via ``pl.when`` — on TPU the grid step reduces to a predicated
no-op, so latency is proportional to the number of *surviving* blocks.
This is structurally why DAAT keeps a data-dependent tail (the paper's
Fig. 3): the amount of surviving work varies per query, whereas the SAAT
kernel's grid is budget-bounded.

VMEM per step at TILE_D=128, CAP=1024: postings 8 KB + tile 512 B.  The
survive flags ride in as an int32 vector indexed per grid step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_kernel(docs_ref, scores_ref, survive_ref, acc_ref, *, tile_d: int):
    i = pl.program_id(0)

    @pl.when(survive_ref[0] > 0)
    def _():
        local = docs_ref[0, :]
        sc = scores_ref[0, :]
        live = local >= 0
        v = jnp.where(live, sc, 0.0)
        d = jnp.where(live, local, -1)
        onehot = (d[:, None]
                  == jax.lax.broadcasted_iota(jnp.int32, (1, tile_d), 1)
                  ).astype(jnp.float32)
        acc = jax.lax.dot_general(v[None, :], onehot,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        acc_ref[0, :] = acc[0, :]

    @pl.when(survive_ref[0] == 0)
    def _():
        acc_ref[0, :] = jnp.zeros((tile_d,), jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile_d", "interpret"))
def blockmax_score_bucketed(docs_b: jnp.ndarray, scores_b: jnp.ndarray,
                            survive_t: jnp.ndarray, *, tile_d: int,
                            interpret: bool = True) -> jnp.ndarray:
    """docs_b/scores_b: (n_tiles, CAP) bucketed postings (local ids, -1 pad);
    survive_t: (n_tiles,) int32 tile-level survival flags."""
    n_tiles, cap = docs_b.shape
    kern = functools.partial(_score_kernel, tile_d=tile_d)
    return pl.pallas_call(
        kern,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, cap), lambda i: (i, 0)),
            pl.BlockSpec((1, cap), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, tile_d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, tile_d), jnp.float32),
        interpret=interpret,
    )(docs_b, scores_b, survive_t)
