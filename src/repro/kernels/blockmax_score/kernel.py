"""Block-max pruned DAAT scoring — the TPU adaptation of BMW's skip logic.

Same bucketed one-hot-matmul layout as ``impact_accumulate`` (one doc tile
per grid step) plus the BMW ingredient: a per-tile *survival predicate*
derived from the block upper bounds.  Pruned tiles skip their matmul
entirely via ``pl.when`` — on TPU the grid step reduces to a predicated
no-op, so latency is proportional to the number of *surviving* blocks.
This is structurally why DAAT keeps a data-dependent tail (the paper's
Fig. 3): the amount of surviving work varies per query, whereas the SAAT
kernel's grid is budget-bounded.

VMEM per step at TILE_D=128, CAP=1024: postings 8 KB + tile 512 B.  The
survive flags ride in as an int32 vector indexed per grid step.

Two entry points:

* ``blockmax_score_bucketed`` — single query over per-query bucketed
  postings (the original layout; ``ops.blockmax_score`` buckets on the fly).
* ``blockmax_score_batched`` — a (Q, n_tiles) grid over the shard's
  build-time bucketed mirror (``IndexShard.tile_*``): tile buckets are
  indexed by the tile coordinate only (zero-copy across the query batch) and
  term matching runs in-kernel, so a whole query batch is served by one
  grid launch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_kernel(docs_ref, scores_ref, survive_ref, acc_ref, *, tile_d: int):
    i = pl.program_id(0)

    @pl.when(survive_ref[0] > 0)
    def _():
        local = docs_ref[0, :]
        sc = scores_ref[0, :]
        live = local >= 0
        v = jnp.where(live, sc, 0.0)
        d = jnp.where(live, local, -1)
        onehot = (d[:, None]
                  == jax.lax.broadcasted_iota(jnp.int32, (1, tile_d), 1)
                  ).astype(jnp.float32)
        acc = jax.lax.dot_general(v[None, :], onehot,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        acc_ref[0, :] = acc[0, :]

    @pl.when(survive_ref[0] == 0)
    def _():
        acc_ref[0, :] = jnp.zeros((tile_d,), jnp.float32)


def _score_kernel_batched(qterms_ref, survive_b_ref, survive_t_ref,
                          docs_ref, terms_ref, scores_ref, acc_ref, *,
                          tile_d: int, block_size: int, bpt: int):
    """One (query, doc-tile) grid step over the shard's bucketed mirror.

    The tile buckets (docs/terms/scores) are indexed by the tile coordinate
    only, so the same HBM blocks serve every query in the batch — the
    bucketed shard mirror is read zero-copy.  Term matching happens
    in-register: a lane is live iff its term is one of the query's terms AND
    its pruning block survives.  Pruned tiles skip the load/matmul entirely
    via ``pl.when``, which is what makes DAAT latency track the surviving
    work per query.
    """

    @pl.when(survive_t_ref[0, 0] > 0)
    def _():
        local = docs_ref[0, :]                    # (CAP,) tile-local, -1 pad
        tterm = terms_ref[0, :]                   # (CAP,) term ids, -1 pad
        sc = scores_ref[0, :]
        qt = qterms_ref[0, :]                     # (L,) query terms, -1 pad
        match = jnp.any(tterm[:, None] == qt[None, :], axis=1)
        # block-in-tile survival: bpt is tiny (tile_d/block_size), so a
        # compare-reduce beats a vector gather on the VPU
        blk = jnp.where(local >= 0, local, 0) // block_size
        sb = survive_b_ref[0, 0, :]               # (bpt,) int32 flags
        blk_oh = blk[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (1, bpt), 1)
        blk_live = jnp.sum(jnp.where(blk_oh, sb[None, :], 0), axis=1) > 0
        live = (local >= 0) & match & blk_live
        v = jnp.where(live, sc, 0.0)
        d = jnp.where(live, local, -1)
        onehot = (d[:, None]
                  == jax.lax.broadcasted_iota(jnp.int32, (1, tile_d), 1)
                  ).astype(jnp.float32)
        acc = jax.lax.dot_general(v[None, :], onehot,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        acc_ref[0, 0, :] = acc[0, :]

    @pl.when(survive_t_ref[0, 0] == 0)
    def _():
        acc_ref[0, 0, :] = jnp.zeros((tile_d,), jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile_d", "block_size",
                                             "interpret"))
def blockmax_score_batched(tile_docs: jnp.ndarray, tile_terms: jnp.ndarray,
                           tile_scores: jnp.ndarray, qterms: jnp.ndarray,
                           survive_b: jnp.ndarray, survive_t: jnp.ndarray,
                           *, tile_d: int, block_size: int,
                           interpret: bool = True) -> jnp.ndarray:
    """Batched exact scoring over the shard's bucketed postings mirror.

    Args:
      tile_docs/tile_terms/tile_scores: (n_tiles, CAP) bucketed shard mirror
        (tile-local doc ids with -1 padding) — shared across the batch.
      qterms: (Q, L) query term ids, -1 for masked-out slots.
      survive_b: (Q, n_tiles, bpt) int32 per-block survival flags.
      survive_t: (Q, n_tiles) int32 per-tile survival (any block survives).
    Returns:
      (Q, n_tiles, tile_d) float32 accumulator tiles.
    """
    n_tiles, cap = tile_docs.shape
    q, L = qterms.shape
    bpt = tile_d // block_size
    kern = functools.partial(_score_kernel_batched, tile_d=tile_d,
                             block_size=block_size, bpt=bpt)
    return pl.pallas_call(
        kern,
        grid=(q, n_tiles),
        in_specs=[
            pl.BlockSpec((1, L), lambda qi, t: (qi, 0)),
            pl.BlockSpec((1, 1, bpt), lambda qi, t: (qi, t, 0)),
            pl.BlockSpec((1, 1), lambda qi, t: (qi, t)),
            pl.BlockSpec((1, cap), lambda qi, t: (t, 0)),
            pl.BlockSpec((1, cap), lambda qi, t: (t, 0)),
            pl.BlockSpec((1, cap), lambda qi, t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, tile_d), lambda qi, t: (qi, t, 0)),
        out_shape=jax.ShapeDtypeStruct((q, n_tiles, tile_d), jnp.float32),
        interpret=interpret,
    )(qterms, survive_b, survive_t, tile_docs, tile_terms, tile_scores)


@functools.partial(jax.jit, static_argnames=("tile_d", "interpret"))
def blockmax_score_bucketed(docs_b: jnp.ndarray, scores_b: jnp.ndarray,
                            survive_t: jnp.ndarray, *, tile_d: int,
                            interpret: bool = True) -> jnp.ndarray:
    """docs_b/scores_b: (n_tiles, CAP) bucketed postings (local ids, -1 pad);
    survive_t: (n_tiles,) int32 tile-level survival flags."""
    n_tiles, cap = docs_b.shape
    kern = functools.partial(_score_kernel, tile_d=tile_d)
    return pl.pallas_call(
        kern,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, cap), lambda i: (i, 0)),
            pl.BlockSpec((1, cap), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, tile_d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, tile_d), jnp.float32),
        interpret=interpret,
    )(docs_b, scores_b, survive_t)
