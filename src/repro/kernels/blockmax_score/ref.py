"""Pure-jnp oracle for the block-max masked scoring kernel."""

from __future__ import annotations

import jax.numpy as jnp


def blockmax_score_ref(docs: jnp.ndarray, scores: jnp.ndarray,
                       survive: jnp.ndarray, n_docs: int,
                       block_size: int) -> jnp.ndarray:
    """Accumulate exact scores of postings whose doc block survives pruning.

    Args:
      docs: (P,) int32 doc ids, -1 padding.
      scores: (P,) float32 exact scores.
      survive: (n_blocks,) bool — blocks with upper bound > θ·τ.
    Returns:
      (n_docs,) float32 accumulator.
    """
    live = docs >= 0
    blk = jnp.where(live, docs // block_size, 0)
    keep = live & survive[blk]
    d = jnp.where(keep, docs, 0)
    v = jnp.where(keep, scores, 0.0)
    return jnp.zeros((n_docs,), jnp.float32).at[d].add(v)
