"""Later-stage re-ranking (stages 1+ of the cascade).

The paper's effectiveness story is about how many candidates the first
stage must pass on; this module is the consumer: query-document features
(BM25 decomposition + topical affinity) and a GBRT point-wise LTR model
trained from reference-list labels — plus the cascade driver that chains
stage-0 prediction → candidate generation → re-ranking.

Two implementations of the feature extractor coexist:

* ``qd_features`` — the original per-query numpy loop (one CSR
  ``searchsorted`` per query term).  Kept as the parity oracle for
  ``rerank_loop``.
* ``qd_features_batched`` — the serving path: one array program over the
  whole ``(Q, C)`` candidate grid.  The per-term exact scores come from a
  branch-free CSR binary search over *all* query terms at once (``"jnp"``
  backend — the portable CPU fast path, bit-identical to the loop) or from
  the ``qd_feature_gather`` Pallas kernel over compacted posting lanes
  (``"pallas"`` / ``"interpret"`` backends — the TPU path, same backend
  switch as the Stage-1 engines).  Transcendentals are precomputed
  host-side into gather tables (``Stage2Arrays.log1p_doclen``) so the
  batched features match the numpy loop bit-for-bit on the jnp backend.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gbrt
from repro.isn.backend import compact_lanes
from repro.kernels.qd_feature_gather.ops import qd_feature_gather

N_LTR_FEATURES = 8


def qd_features(index, corpus, terms_row, mask_row, topic, doc_ids):
    """Per-(query, doc) LTR features for a candidate list."""
    t = terms_row[mask_row > 0]
    feats = np.zeros((len(doc_ids), N_LTR_FEATURES), np.float32)
    dl = index.doclen[doc_ids].astype(np.float32)
    feats[:, 0] = np.log1p(dl)
    # per-term exact scores via CSR binary search
    bm25 = np.zeros(len(doc_ids), np.float32)
    n_match = np.zeros(len(doc_ids), np.float32)
    mx = np.zeros(len(doc_ids), np.float32)
    for tt in t:
        lo, hi = index.offsets[tt], index.offsets[tt + 1]
        if hi <= lo:
            continue                      # term absent from this shard
        seg = index.docs[lo:hi]
        pos = np.searchsorted(seg, doc_ids)
        pos = np.minimum(pos, hi - lo - 1)
        hit = seg[pos] == doc_ids
        sc = np.where(hit, index.bm25_score[lo:hi][pos], 0.0)
        bm25 += sc
        mx = np.maximum(mx, sc)
        n_match += hit
    feats[:, 1] = bm25
    feats[:, 2] = mx
    feats[:, 3] = n_match / max(len(t), 1)
    feats[:, 4] = bm25 / np.maximum(dl, 1.0)
    feats[:, 5] = corpus.doc_topics[doc_ids, topic]
    feats[:, 6] = corpus.doc_topics[doc_ids].max(axis=1)
    feats[:, 7] = len(t)
    return feats


# ---------------------------------------------------------------------------
# batched (Q, C) candidate-grid featurization
# ---------------------------------------------------------------------------

class Stage2Arrays(NamedTuple):
    """Device-resident inputs of the batched Stage-2 featurizer."""
    offsets: jnp.ndarray       # (V+1,) int32 — doc-ordered CSR
    docs: jnp.ndarray          # (P,) int32, doc-sorted within each term
    score: jnp.ndarray         # (P,) float32 exact BM25
    doclen: jnp.ndarray        # (N,) float32
    log1p_doclen: jnp.ndarray  # (N,) float32 — np.log1p table (exactness)
    doc_topics: jnp.ndarray    # (N, K) float32
    doc_topics_max: jnp.ndarray  # (N,) float32 — row max, precomputed


def stage2_arrays(index, corpus) -> Stage2Arrays:
    """Materialize the Stage-2 gather tables from the index + corpus."""
    dl32 = index.doclen.astype(np.float32)
    return Stage2Arrays(
        offsets=jnp.asarray(index.offsets, jnp.int32),
        docs=jnp.asarray(index.docs, jnp.int32),
        score=jnp.asarray(index.bm25_score, jnp.float32),
        doclen=jnp.asarray(dl32),
        log1p_doclen=jnp.asarray(np.log1p(dl32)),
        doc_topics=jnp.asarray(corpus.doc_topics, jnp.float32),
        doc_topics_max=jnp.asarray(corpus.doc_topics.max(axis=1)
                                   .astype(np.float32)),
    )


def csr_search_iters(max_df: int) -> int:
    """Bisection steps that exhaust a posting range of ``max_df`` entries."""
    return max(1, int(np.ceil(np.log2(max(max_df, 2)))) + 1)


def _csr_term_stats(offsets, docs, score, terms, tmask, cand, cmask,
                    n_iter: int):
    """(Σ score, max score, match count) per (query, candidate) via a
    branch-free CSR binary search over all query terms at once.

    Each of the ``n_iter`` unrolled steps halves every (q, l, c) search
    range with pure gathers — no Python loop over queries or terms.  The
    final per-term reduction is unrolled left-to-right over the (≤ L) term
    slots, matching the numpy loop's accumulation order bit-for-bit.
    """
    q, l_dim = terms.shape
    c_dim = cand.shape[1]
    p = docs.shape[0]
    lo = offsets[terms][:, :, None]                    # (Q, L, 1)
    hi = offsets[terms + 1][:, :, None]
    tgt = cand[:, None, :]                             # (Q, 1, C)
    lo_b = jnp.broadcast_to(lo, (q, l_dim, c_dim))
    hi_b = jnp.broadcast_to(hi, (q, l_dim, c_dim))
    for _ in range(n_iter):
        active = lo_b < hi_b
        mid = (lo_b + hi_b) // 2
        v = docs[jnp.minimum(mid, p - 1)]
        go_right = (v < tgt) & active
        lo_b = jnp.where(go_right, mid + 1, lo_b)
        hi_b = jnp.where(active & ~go_right, mid, hi_b)
    pos = jnp.minimum(lo_b, p - 1)
    hit = ((lo_b < hi) & (docs[pos] == tgt)
           & tmask[:, :, None] & cmask[:, None, :])
    sc = jnp.where(hit, score[pos], 0.0)               # (Q, L, C)
    # left-to-right over term slots: dead slots add an exact 0.0
    bm25, mx, nm = sc[:, 0], sc[:, 0], hit[:, 0].astype(jnp.float32)
    for l in range(1, l_dim):
        bm25 = bm25 + sc[:, l]
        mx = jnp.maximum(mx, sc[:, l])
        nm = nm + hit[:, l].astype(jnp.float32)
    return bm25, mx, nm


def _lane_term_stats(offsets, docs, score, terms, tmask, cand, qcap: int,
                     p_tile: int, interpret: bool):
    """Kernel-backed aggregates: compact the batch's ragged per-term posting
    ranges into (Q, qcap) dense lanes, then one ``qd_feature_gather``
    launch over the candidate grid."""
    base = offsets[terms]                              # (Q, L)
    dfs = (offsets[terms + 1] - base) * tmask.astype(jnp.int32)
    pos, live = compact_lanes(base, dfs.astype(jnp.int32), qcap)
    pos = jnp.minimum(pos, docs.shape[0] - 1)
    lane_docs = jnp.where(live, docs[pos], -1)
    lane_scores = jnp.where(live, score[pos], 0.0)
    bm25, mx, cnt = qd_feature_gather(lane_docs, lane_scores, cand,
                                      p_tile=p_tile, interpret=interpret)
    return bm25, mx, cnt.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("n_iter", "backend", "qcap",
                                             "p_tile"))
def qd_features_batched(arrs: Stage2Arrays, terms: jnp.ndarray,
                        mask: jnp.ndarray, topics: jnp.ndarray,
                        cand: jnp.ndarray, *, n_iter: int,
                        backend: str = "jnp", qcap: int | None = None,
                        p_tile: int = 512) -> jnp.ndarray:
    """LTR features for the whole (Q, C) candidate grid in one call.

    Args:
      arrs: ``stage2_arrays`` gather tables.
      terms/mask: (Q, L) padded query terms.
      topics: (Q,) query topic ids.
      cand: (Q, C) candidate doc ids, -1 padding (padded rows yield garbage
        features — mask downstream, as ``rerank_batched`` does).
      n_iter: static bisection depth (``csr_search_iters(max_df)``).
      backend: "jnp" (CSR binary search — bit-identical to the numpy loop)
        or "interpret"/"pallas" (``qd_feature_gather`` kernel over compacted
        lanes; ``qcap`` must then bound the batch's per-query postings).
    Returns:
      (Q, C, 8) float32 feature grid.
    """
    tmask = mask > 0
    cmask = cand >= 0
    c_safe = jnp.maximum(cand, 0)
    if backend == "jnp":
        bm25, mx, nm = _csr_term_stats(arrs.offsets, arrs.docs, arrs.score,
                                       terms, tmask, cand, cmask, n_iter)
    else:
        if qcap is None:
            raise ValueError("kernel backends need a static qcap lane budget")
        bm25, mx, nm = _lane_term_stats(arrs.offsets, arrs.docs, arrs.score,
                                        terms, tmask, cand, qcap, p_tile,
                                        backend == "interpret")
    dl = arrs.doclen[c_safe]                           # (Q, C)
    n_terms = jnp.sum(tmask.astype(jnp.float32), axis=1)
    feats = jnp.stack([
        arrs.log1p_doclen[c_safe],
        bm25,
        mx,
        nm / jnp.maximum(n_terms, 1.0)[:, None],
        bm25 / jnp.maximum(dl, 1.0),
        arrs.doc_topics[c_safe, topics[:, None]],
        arrs.doc_topics_max[c_safe],
        jnp.broadcast_to(n_terms[:, None], c_safe.shape),
    ], axis=-1)
    return feats.astype(jnp.float32)


@dataclass
class LTRModel:
    model: object

    def score(self, feats: np.ndarray) -> np.ndarray:
        return np.asarray(gbrt.predict(self.model, feats))


def train_ltr(feats: np.ndarray, gains: np.ndarray,
              n_trees: int = 48) -> LTRModel:
    m = gbrt.fit(feats, gains.astype(np.float32),
                 gbrt.GBRTParams(n_trees=n_trees, depth=4, loss="l2",
                                 learning_rate=0.2))
    return LTRModel(m)


def ltr_training_set(index, corpus, ql, ref_lists, rows,
                     n_pos: int = 24, n_neg: int = 24, seed: int = 0):
    """(features, gains) pairs from reference lists: graded gains for the
    top reference docs, zero for random negatives."""
    rng = np.random.RandomState(seed)
    feats, gains = [], []
    for q in rows:
        pos = ref_lists[q][:n_pos]
        neg = rng.randint(0, index.n_docs, n_neg)
        docs = np.concatenate([pos, neg]).astype(np.int64)
        g = np.concatenate([1.0 / np.log2(np.arange(len(pos)) + 2),
                            np.zeros(len(neg))])
        feats.append(qd_features(index, corpus, ql.terms[q], ql.mask[q],
                                 ql.topic[q], docs))
        gains.append(g)
    return np.concatenate(feats), np.concatenate(gains).astype(np.float32)
