"""Later-stage re-ranking (stages 1+ of the cascade).

The paper's effectiveness story is about how many candidates the first
stage must pass on; this module is the consumer: query-document features
(BM25 decomposition + topical affinity) and a GBRT point-wise LTR model
trained from reference-list labels — plus the cascade driver that chains
stage-0 prediction → candidate generation → re-ranking.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import gbrt

N_LTR_FEATURES = 8


def qd_features(index, corpus, terms_row, mask_row, topic, doc_ids):
    """Per-(query, doc) LTR features for a candidate list."""
    t = terms_row[mask_row > 0]
    feats = np.zeros((len(doc_ids), N_LTR_FEATURES), np.float32)
    dl = index.doclen[doc_ids].astype(np.float32)
    feats[:, 0] = np.log1p(dl)
    # per-term exact scores via CSR binary search
    bm25 = np.zeros(len(doc_ids), np.float32)
    n_match = np.zeros(len(doc_ids), np.float32)
    mx = np.zeros(len(doc_ids), np.float32)
    for tt in t:
        lo, hi = index.offsets[tt], index.offsets[tt + 1]
        seg = index.docs[lo:hi]
        pos = np.searchsorted(seg, doc_ids)
        pos = np.minimum(pos, max(hi - lo - 1, 0))
        hit = seg[pos] == doc_ids if hi > lo else np.zeros(len(doc_ids), bool)
        sc = np.where(hit, index.bm25_score[lo:hi][pos], 0.0)
        bm25 += sc
        mx = np.maximum(mx, sc)
        n_match += hit
    feats[:, 1] = bm25
    feats[:, 2] = mx
    feats[:, 3] = n_match / max(len(t), 1)
    feats[:, 4] = bm25 / np.maximum(dl, 1.0)
    feats[:, 5] = corpus.doc_topics[doc_ids, topic]
    feats[:, 6] = corpus.doc_topics[doc_ids].max(axis=1)
    feats[:, 7] = len(t)
    return feats


@dataclass
class LTRModel:
    model: object

    def score(self, feats: np.ndarray) -> np.ndarray:
        return np.asarray(gbrt.predict(self.model, feats))


def train_ltr(feats: np.ndarray, gains: np.ndarray,
              n_trees: int = 48) -> LTRModel:
    m = gbrt.fit(feats, gains.astype(np.float32),
                 gbrt.GBRTParams(n_trees=n_trees, depth=4, loss="l2",
                                 learning_rate=0.2))
    return LTRModel(m)
