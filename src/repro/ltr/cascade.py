"""Multi-stage cascade driver: Stage-0 predict → Stage-1 candidates (hybrid
ISN) → Stage-2 LTR re-rank → final top-t.

``rerank_batched`` is the serving path: one array program over the whole
(Q, C) candidate grid — batched featurization (``qd_features_batched``),
one fused GBRT inference over all (query, candidate) rows, and a masked
``top_k`` selection whose tie-breaking (lower candidate rank first)
matches the stable argsort of the loop.  ``rerank_loop`` keeps the original
one-query-at-a-time driver as the parity oracle; on the ``"jnp"`` backend
the batched path reproduces it bit-for-bit
(``tests/test_cascade_pipeline.py``, ``benchmarks/bench_hybrid.py``).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gbrt
from repro.ltr.ranker import (LTRModel, Stage2Arrays, csr_search_iters,
                              qd_features, qd_features_batched)


@dataclass
class CascadeResult:
    final: np.ndarray           # (Q, t) doc ids
    candidates_used: np.ndarray # (Q,) candidate count entering stage 2


def rerank_loop(index, corpus, ql, rows, candidate_lists, k_per_query,
                ltr: LTRModel, t_final: int = 10) -> CascadeResult:
    """One-query-at-a-time cascade (per-term CSR searchsorted + one GBRT
    dispatch per query) — the parity oracle and benchmark baseline for
    ``rerank_batched``."""
    out = np.zeros((len(rows), t_final), np.int64)
    used = np.zeros(len(rows), np.int64)
    for i, q in enumerate(rows):
        k = int(k_per_query[i])
        cand = candidate_lists[i][:k]
        cand = cand[cand >= 0]
        used[i] = len(cand)
        if len(cand) == 0:
            continue
        f = qd_features(index, corpus, ql.terms[q], ql.mask[q],
                        ql.topic[q], cand)
        sc = ltr.score(f)
        order = np.argsort(-sc, kind="stable")[:t_final]
        picks = cand[order]
        out[i, :len(picks)] = picks
        if len(picks) < t_final:
            out[i, len(picks):] = -1
    return CascadeResult(final=out, candidates_used=used)


def rerank_batched(arrs: Stage2Arrays, ltr: LTRModel, terms, mask, topics,
                   cand, k_per_query, *, t_final: int = 10, n_iter: int,
                   backend: str = "jnp", qcap: int | None = None,
                   lane_need: int | None = None,
                   p_tile: int = 512) -> CascadeResult:
    """Batched Stage-2: re-rank every query's candidate grid in one array
    program.

    Args:
      arrs: ``stage2_arrays`` gather tables.
      terms/mask/topics: the (Q, L)/(Q,) query batch.
      cand: (Q, C) candidate doc ids (-1 padding), e.g. the Stage-1 top-k.
      k_per_query: (Q,) per-query candidate budgets (the Stage-0 P_k
        prediction, clamped); only the first k columns of each row enter
        the re-ranker.
      lane_need: kernel backends only — the batch's max per-query posting
        total, if the caller already knows it (a ``query_lane_budget``
        result qualifies: it bounds the total by construction).  When
        omitted it is re-derived from ``arrs.offsets``, which costs a
        device-to-host copy of the offsets table per call.
      n_iter / backend / qcap: see ``qd_features_batched``.
    """
    q, c = np.shape(cand)
    if backend != "jnp":
        # compact_lanes silently drops lanes past qcap — refuse rather than
        # return wrong features (size qcap with query_lane_budget)
        if lane_need is None:
            off = np.asarray(arrs.offsets)
            t_np = np.asarray(terms)
            df = off[t_np + 1] - off[t_np]
            lane_need = int((df * (np.asarray(mask) > 0)).sum(axis=1).max())
        if qcap is None or qcap < lane_need:
            raise ValueError(
                f"qcap={qcap} does not cover the batch's per-query posting "
                f"total ({lane_need}); size it with "
                f"repro.isn.backend.query_lane_budget")
    terms = jnp.asarray(terms)
    mask = jnp.asarray(mask)
    cand_j = jnp.asarray(cand, jnp.int32)
    feats = qd_features_batched(arrs, terms, mask,
                                jnp.asarray(topics, jnp.int32), cand_j,
                                n_iter=n_iter, backend=backend, qcap=qcap,
                                p_tile=p_tile)
    sc = gbrt.predict(ltr.model, feats.reshape(q * c, -1)).reshape(q, c)
    valid = (cand_j >= 0) & (jnp.arange(c, dtype=jnp.int32)[None, :]
                             < jnp.asarray(k_per_query, jnp.int32)[:, None])
    sc = jnp.where(valid, sc, -jnp.inf)
    kk = min(t_final, c)
    top_sc, order = jax.lax.top_k(sc, kk)
    picks = jnp.take_along_axis(cand_j, order, axis=1)
    picks = jnp.where(jnp.isfinite(top_sc), picks, -1)
    used = jnp.sum(valid, axis=1)
    final = jnp.where(used[:, None] > 0, picks, 0)
    if kk < t_final:
        final = jnp.pad(final, ((0, 0), (0, t_final - kk)),
                        constant_values=-1)
        final = jnp.where(used[:, None] > 0, final, 0)
    return CascadeResult(final=np.asarray(final).astype(np.int64),
                         candidates_used=np.asarray(used).astype(np.int64))
