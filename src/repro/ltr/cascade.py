"""Multi-stage cascade driver: Stage-0 predict → Stage-1 candidates (hybrid
ISN) → Stage-2 LTR re-rank → final top-t."""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.ltr.ranker import LTRModel, qd_features


@dataclass
class CascadeResult:
    final: np.ndarray           # (Q, t) doc ids
    candidates_used: np.ndarray # (Q,) candidate count entering stage 2


def rerank(index, corpus, ql, rows, candidate_lists, k_per_query,
           ltr: LTRModel, t_final: int = 10) -> CascadeResult:
    out = np.zeros((len(rows), t_final), np.int64)
    used = np.zeros(len(rows), np.int64)
    for i, q in enumerate(rows):
        k = int(k_per_query[i])
        cand = candidate_lists[i][:k]
        cand = cand[cand >= 0]
        used[i] = len(cand)
        if len(cand) == 0:
            continue
        f = qd_features(index, corpus, ql.terms[q], ql.mask[q],
                        ql.topic[q], cand)
        sc = ltr.score(f)
        order = np.argsort(-sc, kind="stable")[:t_final]
        picks = cand[order]
        out[i, :len(picks)] = picks
        if len(picks) < t_final:
            out[i, len(picks):] = -1
    return CascadeResult(final=out, candidates_used=used)
