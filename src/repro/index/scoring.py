"""Similarity functions over postings arrays.

Six models — TF·IDF, BM25, query likelihood (Dirichlet), Bose–Einstein (Bo1),
DPH and PL2 (DFR) — matching the feature families the paper builds its 147
Stage-0 features from.  All functions are vectorized over flat postings
arrays (numpy at index-build time; the jnp twins in `repro.isn` score at
query time).
"""

from __future__ import annotations

import numpy as np

LOG2E = np.log2(np.e)


def bm25(tf, df, dl, n_docs, avg_dl, k1=0.9, b=0.4):
    idf = np.log(1.0 + (n_docs - df + 0.5) / (df + 0.5))
    norm = tf + k1 * (1.0 - b + b * dl / avg_dl)
    return idf * tf * (k1 + 1.0) / norm


def tfidf(tf, df, dl, n_docs, avg_dl):
    return (1.0 + np.log(tf)) * np.log(1.0 + n_docs / df)


def ql_dirichlet(tf, cf, dl, total_tokens, mu=1500.0):
    p_c = cf / total_tokens
    return np.log1p(tf / (mu * p_c)) + np.log(mu / (dl + mu))


def bose_einstein(tf, cf, n_docs):
    lam = cf / n_docs
    return (tf * np.log2((1.0 + lam) / lam) + np.log2(1.0 + lam))


def dph(tf, cf, dl, n_docs, avg_dl):
    f = np.clip(tf / dl, 1e-9, 1.0 - 1e-9)
    norm = (1.0 - f) ** 2 / (tf + 1.0)
    return norm * (tf * np.log2(np.maximum(tf * (avg_dl / dl) * (n_docs / cf), 1e-9))
                   + 0.5 * np.log2(np.maximum(2.0 * np.pi * tf * (1.0 - f), 1e-9)))


def pl2(tf, cf, dl, n_docs, avg_dl, c=1.0):
    tfn = tf * np.log2(1.0 + c * avg_dl / dl)
    lam = np.maximum(cf / n_docs, 1e-9)
    tfn = np.maximum(tfn, 1e-6)
    return (1.0 / (tfn + 1.0)) * (
        tfn * np.log2(tfn / lam) + (lam - tfn) * LOG2E
        + 0.5 * np.log2(np.maximum(2.0 * np.pi * tfn, 1e-9)))


def all_similarity_scores(tf, df, cf, dl, n_docs, avg_dl, total_tokens):
    """(P, 6) score matrix for flat postings, column order matching
    repro.core.features.SIM_NAMES."""
    cols = [
        tfidf(tf, df, dl, n_docs, avg_dl),
        bm25(tf, df, dl, n_docs, avg_dl),
        ql_dirichlet(tf, cf, dl, total_tokens),
        bose_einstein(tf, cf, n_docs),
        dph(tf, cf, dl, n_docs, avg_dl),
        pl2(tf, cf, dl, n_docs, avg_dl),
    ]
    return np.stack([c.astype(np.float32) for c in cols], axis=1)


def quantize_impacts(scores: np.ndarray, n_levels: int = 255,
                     smax: float | None = None) -> tuple[np.ndarray, float]:
    """ATIRE-style linear impact quantization to [1, n_levels] (uint8).

    ``smax`` pins the quantization scale (the live-delta path quantizes feed
    postings on the sealed index's frozen scale so impacts stay comparable
    across segments); by default the scale is the score maximum. Scores above
    a pinned ``smax`` clip to ``n_levels``.
    """
    if smax is None:
        smax = float(scores.max()) if len(scores) else 1.0
    q = np.ceil(scores / smax * n_levels).astype(np.int32)
    return np.clip(q, 1, n_levels).astype(np.uint8), smax
