"""Device-resident index shard structures for the JAX/TPU serving engines.

An ISN holds one *document shard* of the corpus in HBM, in three mirrors:

* impact-ordered arrays for SAAT (JASS) — per-term postings sorted by
  descending quantized impact, plus per-term per-level cumulative counts so
  the ρ budget resolves to per-term prefixes in O(levels);
* document-ordered arrays for DAAT (BMW) — per-term postings sorted by
  docid with exact scores, plus a *sparse* per-term block-max structure
  (term-major CSR of (block_id, block_max, block_count)) — dense
  (V × n_blocks) does not scale to 2M-term vocabularies;
* a **bucketed (doc-tile-major) mirror** feeding the batched Pallas serving
  kernels — every posting pre-tiled at index-build time into the
  ``(n_tiles, tile_cap)`` bucket of its ``tile_d``-doc tile, carrying
  (tile-local doc id, term id, exact score, quantized impact).  The kernels'
  one-doc-tile-per-grid-step layout is then a zero-copy view of the shard:
  one grid step loads one bucket row, matches terms against the query
  in-register, and reduces with a one-hot MXU matmul.  Pruned tiles are
  skipped via predication, so per-query HBM traffic is proportional to the
  *surviving* tiles rather than the collection size.

All fields are plain jnp arrays so a shard can be a pytree leaf under
``shard_map`` and a ShapeDtypeStruct bundle for the compile-only dry-run.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.builder import InvertedIndex, impact_order_layout, pack_tiles


class IndexShardSpec(NamedTuple):
    n_docs: int            # docs in this shard
    vocab: int
    n_postings: int        # padded postings count
    n_blocks: int          # doc blocks in this shard
    n_block_entries: int   # padded (term, block) entries
    n_levels: int
    block_size: int
    max_df: int            # static cap for per-term gathers
    max_blocks_per_term: int
    quant_scale: float
    tile_d: int            # docs per bucketed serving tile
    tile_cap: int          # lane-padded postings capacity per tile
    n_tiles: int


class IndexShard(NamedTuple):
    """One document shard of the index mirrors (pytree of jnp arrays)."""
    # --- shared / collection stats ---
    df: jnp.ndarray            # (V,) int32
    offsets: jnp.ndarray       # (V+1,) int32 into postings arrays

    # --- impact-ordered mirror (SAAT / JASS) ---
    docs_imp: jnp.ndarray      # (P,) int32 local doc ids
    imp: jnp.ndarray           # (P,) int32 quantized impacts (from uint8)
    level_cum: jnp.ndarray     # (V, n_levels) int32: count with impact >= l

    # --- document-ordered mirror (DAAT / BMW) ---
    docs: jnp.ndarray          # (P,) int32 local doc ids (term, doc sorted)
    score: jnp.ndarray         # (P,) float32 exact BM25
    bm_offsets: jnp.ndarray    # (V+1,) int32 into block arrays
    bm_block_id: jnp.ndarray   # (PB,) int32 doc-block id
    bm_block_max: jnp.ndarray  # (PB,) float32 block upper bound (scaled)
    bm_block_cnt: jnp.ndarray  # (PB,) int32 postings in this (term, block)

    # --- bucketed doc-tile-major mirror (batched serving kernels) ---
    tile_docs: jnp.ndarray     # (n_tiles, tile_cap) int32 tile-local, -1 pad
    tile_terms: jnp.ndarray    # (n_tiles, tile_cap) int32 term ids, -1 pad
    tile_scores: jnp.ndarray   # (n_tiles, tile_cap) float32 exact BM25
    tile_imps: jnp.ndarray     # (n_tiles, tile_cap) int32 quantized impacts


def shard_ranges(n_docs: int, n_shards: int) -> list[tuple[int, int]]:
    """Contiguous doc-range partition of [0, n_docs) into n_shards shards.

    Ranges are as even as possible (first ``n_docs % n_shards`` shards get
    one extra doc) and returned in ascending order — the order the
    scatter-gather merge relies on for its doc-id tie-break.
    """
    if not 1 <= n_shards <= n_docs:
        raise ValueError(f"n_shards must be in [1, {n_docs}], got {n_shards}")
    base, extra = divmod(n_docs, n_shards)
    bounds = [0]
    for s in range(n_shards):
        bounds.append(bounds[-1] + base + (1 if s < extra else 0))
    return list(zip(bounds[:-1], bounds[1:]))


def _pad_to(arr: np.ndarray, size: int, fill) -> np.ndarray:
    """Right-pad a 1-D postings column to a static capacity.

    Pads are inert by construction: every serving gather is offsets/df
    addressed (compact lanes mask ``lane < df``), so a padded tail is never
    combined into a score.
    """
    if size < len(arr):
        raise ValueError(f"pad size {size} below array length {len(arr)}")
    out = np.full(size, fill, arr.dtype)
    out[:len(arr)] = arr
    return out


def shard_from_index(index: InvertedIndex, doc_lo: int = 0,
                     doc_hi: int | None = None,
                     tile_d: int = 128, *,
                     tile_cap: int | None = None,
                     pad_postings: int | None = None,
                     max_df: int | None = None,
                     max_blocks_per_term: int | None = None,
                     ) -> tuple[IndexShard, IndexShardSpec]:
    """Materialize the device structures for docs in [doc_lo, doc_hi).

    The keyword overrides pin *capacity* shapes and static caps instead of
    the data-derived ones, so a delta tile-set rebuilt on every ingest batch
    keeps one jit signature while it fills: ``pad_postings`` pads every
    postings column (and the sparse block-max CSR) to that length,
    ``tile_cap`` pins the bucketed mirror's lane capacity, and
    ``max_df``/``max_blocks_per_term`` pin the per-term gather caps.
    """
    doc_hi = index.n_docs if doc_hi is None else doc_hi
    n_local = doc_hi - doc_lo
    v = index.vocab
    bs = index.block_size
    if tile_d % bs:
        raise ValueError(f"tile_d={tile_d} must be a multiple of "
                         f"block_size={bs}")

    sel = (index.docs >= doc_lo) & (index.docs < doc_hi)
    term_of = np.repeat(np.arange(v), np.diff(index.offsets))
    t = term_of[sel]
    d = (index.docs[sel] - doc_lo).astype(np.int32)
    s = index.bm25_score[sel].astype(np.float32)
    im = index.impact[sel].astype(np.int32)

    df = np.bincount(t, minlength=v).astype(np.int32)
    offsets = np.zeros(v + 1, np.int64)
    np.cumsum(df, out=offsets[1:])

    # postings already (term, doc)-sorted; within-shard selection keeps order
    docs = d
    score = s

    # impact-ordered: per-term sort by impact desc
    order, level_cum = impact_order_layout(t, d, im, v)
    docs_imp = d[order]
    imp = im[order]

    # sparse block-max
    if len(d):
        blk = (d // bs).astype(np.int64)
        key = t.astype(np.int64) * (1 << 32) + blk
        start = np.flatnonzero(np.r_[True, key[1:] != key[:-1]])
        b_term = t[start]
        b_id = blk[start].astype(np.int32)
        b_max = np.maximum.reduceat(s, start).astype(np.float32)
        b_cnt = np.diff(np.r_[start, len(key)]).astype(np.int32)
    else:
        b_term = np.zeros(0, np.int64)
        b_id = np.zeros(0, np.int32)
        b_max = np.zeros(0, np.float32)
        b_cnt = np.zeros(0, np.int32)
    bm_df = np.bincount(b_term, minlength=v)
    bm_offsets = np.zeros(v + 1, np.int64)
    np.cumsum(bm_df, out=bm_offsets[1:])

    if pad_postings is not None:
        docs = _pad_to(docs, pad_postings, 0)
        score = _pad_to(score, pad_postings, 0.0)
        docs_imp = _pad_to(docs_imp, pad_postings, 0)
        imp = _pad_to(imp, pad_postings, 0)
        b_id = _pad_to(b_id, pad_postings, 0)
        b_max = _pad_to(b_max, pad_postings, 0.0)
        b_cnt = _pad_to(b_cnt, pad_postings, 0)

    # bucketed doc-tile-major mirror for the batched serving kernels
    tile_docs, tile_terms, (tile_scores, tile_imps), tcap = \
        pack_tiles(
            d, t, [(s, 0.0, np.float32), (im, 0, np.int32)], n_local, tile_d,
            tile_cap=tile_cap)

    n_blocks = (n_local + bs - 1) // bs
    n_tiles = max(1, (n_local + tile_d - 1) // tile_d)
    spec = IndexShardSpec(
        n_docs=n_local, vocab=v, n_postings=len(docs), n_blocks=n_blocks,
        n_block_entries=len(b_id), n_levels=256, block_size=bs,
        max_df=(max_df if max_df is not None
                else int(df.max()) if len(df) else 1),
        max_blocks_per_term=(max_blocks_per_term
                             if max_blocks_per_term is not None
                             else int(bm_df.max()) if len(bm_df) else 1),
        quant_scale=index.quant_scale,
        tile_d=tile_d, tile_cap=tcap, n_tiles=n_tiles)

    shard = IndexShard(
        df=jnp.asarray(df),
        offsets=jnp.asarray(offsets, jnp.int32),
        docs_imp=jnp.asarray(docs_imp),
        imp=jnp.asarray(imp, jnp.int32),
        level_cum=jnp.asarray(level_cum, jnp.int32),
        docs=jnp.asarray(docs),
        score=jnp.asarray(score),
        bm_offsets=jnp.asarray(bm_offsets, jnp.int32),
        bm_block_id=jnp.asarray(b_id),
        bm_block_max=jnp.asarray(b_max),
        bm_block_cnt=jnp.asarray(b_cnt),
        tile_docs=jnp.asarray(tile_docs),
        tile_terms=jnp.asarray(tile_terms),
        tile_scores=jnp.asarray(tile_scores),
        tile_imps=jnp.asarray(tile_imps),
    )
    return shard, spec


def shard_specs(spec: IndexShardSpec) -> IndexShard:
    """ShapeDtypeStruct stand-ins with the same pytree structure — used by the
    multi-pod dry-run so no index is ever materialized."""
    sds = jax.ShapeDtypeStruct
    v, p, pb = spec.vocab, spec.n_postings, spec.n_block_entries
    nt, tc = spec.n_tiles, spec.tile_cap
    return IndexShard(
        df=sds((v,), jnp.int32),
        offsets=sds((v + 1,), jnp.int32),
        docs_imp=sds((p,), jnp.int32),
        imp=sds((p,), jnp.int32),
        level_cum=sds((v, spec.n_levels), jnp.int32),
        docs=sds((p,), jnp.int32),
        score=sds((p,), jnp.float32),
        bm_offsets=sds((v + 1,), jnp.int32),
        bm_block_id=sds((pb,), jnp.int32),
        bm_block_max=sds((pb,), jnp.float32),
        bm_block_cnt=sds((pb,), jnp.int32),
        tile_docs=sds((nt, tc), jnp.int32),
        tile_terms=sds((nt, tc), jnp.int32),
        tile_scores=sds((nt, tc), jnp.float32),
        tile_imps=sds((nt, tc), jnp.int32),
    )
