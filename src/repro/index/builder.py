"""Index construction: document-ordered (block-max) and impact-ordered
(quantized, JASS-style) layouts plus the Stage-0 per-term statistics table.

Mirrors the paper's setup: one corpus, two physical index layouts serving as
"index mirrors" on different ISN replicas — a BMW-style block-max index for
rank-safe DAAT and an ATIRE/JASS-style impact-ordered index for anytime SAAT.

The assembly core is shared between two producers:

* ``build_index`` — the sealed from-scratch build (stoplist derived from the
  corpus, collection statistics computed over the postings being indexed);
* the live **delta tile-set** (``index/delta.py``) — an append-only segment
  over freshly fed documents, scored with the *frozen* statistics of the
  sealed index (``CollectionStats``) so live results converge bit-exactly to
  the post-merge rebuild once the delta is folded in.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.index import scoring
from repro.index.corpus import Corpus


@dataclass
class InvertedIndex:
    # collection stats
    n_docs: int
    vocab: int
    avg_dl: float
    total_tokens: float
    doclen: np.ndarray             # (N,)
    df: np.ndarray                 # (V,)
    cf: np.ndarray                 # (V,)

    # document-ordered CSR (sorted by term, doc)
    offsets: np.ndarray            # (V+1,)
    docs: np.ndarray               # (P,)
    tf: np.ndarray                 # (P,)
    bm25_score: np.ndarray         # (P,) float32 exact scores
    impact: np.ndarray             # (P,) uint8 quantized bm25
    quant_scale: float             # impact -> score scale (score≈imp/255*scale)

    # block-max structure (document-ordered)
    block_size: int
    n_blocks: int
    block_max: np.ndarray          # (V, n_blocks) uint8, 0 = term absent
    block_count: np.ndarray        # (V, n_blocks) uint16 postings per block

    # impact-ordered layout (per-term descending impact)
    docs_imp: np.ndarray           # (P,)
    imp_sorted: np.ndarray         # (P,) uint8
    level_cum: np.ndarray          # (V, 256) int32: #postings with impact >= l

    # stage-0 features
    term_stats: np.ndarray         # (V, 36) float32

    # term ids dropped at build time (the stop_k most frequent); retained so
    # a live delta segment applies the same stoplist to incoming feed docs
    stoplist: np.ndarray = None    # (S,) int64

    @property
    def n_postings(self) -> int:
        return self.docs.shape[0]


@dataclass(frozen=True)
class CollectionStats:
    """Collection-level quantities that price a posting.

    A live delta segment scores its postings with the *sealed* index's stats
    (frozen at seal time) rather than its own — otherwise per-posting scores
    would drift as the delta grows and live results could never match the
    post-merge rebuild posting-for-posting.
    """
    n_docs: int
    avg_dl: float
    total_tokens: float
    df: np.ndarray                 # (V,) float64
    cf: np.ndarray                 # (V,) float64
    quant_scale: float             # frozen impact quantization scale


def frozen_stats(index: InvertedIndex) -> CollectionStats:
    """Snapshot the scoring statistics of a sealed index."""
    return CollectionStats(
        n_docs=index.n_docs, avg_dl=index.avg_dl,
        total_tokens=index.total_tokens,
        df=np.asarray(index.df, np.float64),
        cf=np.asarray(index.cf, np.float64),
        quant_scale=index.quant_scale)


def _per_term_stats(term_ids, scores, offsets, df, vocab):
    """{max, amean, gmean, hmean, median, std} per term for one sim column."""
    eps = 1e-3
    nz = np.maximum(df.astype(np.float64), 1.0)
    shifted = scores - scores.min() + eps

    s1 = np.bincount(term_ids, weights=shifted, minlength=vocab)
    s2 = np.bincount(term_ids, weights=shifted ** 2, minlength=vocab)
    slog = np.bincount(term_ids, weights=np.log(shifted), minlength=vocab)
    sinv = np.bincount(term_ids, weights=1.0 / shifted, minlength=vocab)

    amean = s1 / nz
    gmean = np.exp(slog / nz)
    hmean = nz / np.maximum(sinv, 1e-12)
    std = np.sqrt(np.maximum(s2 / nz - amean ** 2, 0.0))

    # max + median from a per-term sort
    order = np.lexsort((shifted, term_ids))
    sorted_s = shifted[order]
    has = df > 0
    last = np.maximum(offsets[1:] - 1, 0)
    mx = np.where(has, sorted_s[np.minimum(last, len(sorted_s) - 1)], 0.0)
    mid = offsets[:-1] + np.maximum((df - 1) // 2, 0)
    med = np.where(has, sorted_s[np.minimum(mid, len(sorted_s) - 1)], 0.0)

    cols = np.stack([mx, amean, gmean, hmean, med, std], axis=1)
    return np.where(has[:, None], cols, 0.0).astype(np.float32)


def pack_tiles(docs: np.ndarray, terms: np.ndarray,
               values: list[tuple[np.ndarray, float, np.dtype]],
               n_docs: int, tile_d: int,
               lane_multiple: int = 128,
               tile_cap: int | None = None):
    """Pre-tile postings into ``(n_tiles, cap)`` doc-local buckets.

    This is the build-time half of the serving kernels' one-doc-tile-per-
    grid-step layout: every posting lands in the bucket of its ``tile_d``-doc
    tile, doc ids are rebased to be tile-local, and each bucket is padded to
    a common lane-aligned ``cap`` so the whole structure is a dense
    ``(n_tiles, cap)`` array the kernels can view with zero per-query copies.

    The one tiling helper shared by the sealed build, the append-only delta
    tile-set, and the merge re-tile.

    Args:
      docs: (P,) doc ids local to the shard.
      terms: (P,) term id of each posting.
      values: per-posting payload columns as (array, fill, dtype) tuples
        (e.g. exact scores, quantized impacts).
      n_docs: shard size (defines the tile count).
      tile_d: docs per tile; must match the kernels' accumulator tile.
      lane_multiple: pad cap to a multiple of this (TPU lane width).
      tile_cap: pin the lane capacity to this static value instead of the
        data-derived one — the delta tile-set passes its postings capacity so
        every rebuild keeps a single jit signature as documents stream in.

    Returns:
      (tile_docs, tile_terms, bucketed_values, cap) where ``tile_docs`` is
      (n_tiles, cap) int32 tile-local doc ids with -1 padding, ``tile_terms``
      is (n_tiles, cap) int32 with -1 padding, and ``bucketed_values`` is a
      list of (n_tiles, cap) arrays in ``values`` order.
    """
    n_tiles = max(1, -(-n_docs // tile_d))
    p = len(docs)
    tile = (docs // tile_d).astype(np.int64)
    counts = np.bincount(tile, minlength=n_tiles)
    cap = max(int(counts.max()) if p else 0, 1)
    cap = -(-cap // lane_multiple) * lane_multiple
    if tile_cap is not None:
        if tile_cap < cap:
            raise ValueError(f"tile_cap={tile_cap} below required cap={cap}")
        cap = tile_cap

    order = np.argsort(tile, kind="stable")   # keeps (term, doc) order in-tile
    tsort = tile[order]
    starts = np.zeros(n_tiles + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    slot = tsort * cap + (np.arange(p, dtype=np.int64) - starts[tsort])

    tile_docs = np.full(n_tiles * cap, -1, np.int32)
    tile_docs[slot] = (docs[order] - tsort * tile_d).astype(np.int32)
    tile_terms = np.full(n_tiles * cap, -1, np.int32)
    tile_terms[slot] = terms[order].astype(np.int32)
    bucketed = []
    for arr, fill, dtype in values:
        b = np.full(n_tiles * cap, fill, dtype)
        b[slot] = arr[order].astype(dtype)
        bucketed.append(b.reshape(n_tiles, cap))
    return (tile_docs.reshape(n_tiles, cap), tile_terms.reshape(n_tiles, cap),
            bucketed, cap)


def impact_order_layout(term: np.ndarray, doc: np.ndarray,
                        impact: np.ndarray, vocab: int):
    """Impact-ordered mirror layout shared by the monolithic build and the
    per-shard slicer: the per-term impact-descending (doc-ascending within a
    level) permutation plus the (V, 256) cumulative level table
    ``level_cum[t, l] = # postings of t with impact >= l``."""
    order = np.lexsort((doc, -impact.astype(np.int32), term))
    lvl = np.bincount(term.astype(np.int64) * 256 + impact,
                      minlength=vocab * 256).reshape(vocab, 256)
    level_cum = np.flip(np.cumsum(np.flip(lvl, axis=1), axis=1),
                        axis=1).astype(np.int32)
    return order, level_cum


def assemble_index(term: np.ndarray, doc: np.ndarray, tf: np.ndarray,
                   doclen: np.ndarray, vocab: int, *,
                   block_size: int = 64, n_levels: int = 255,
                   stoplist: np.ndarray | None = None,
                   frozen: CollectionStats | None = None) -> InvertedIndex:
    """Assemble every index mirror from prepared postings.

    ``term``/``doc``/``tf`` must already be stoplist-filtered and
    (term, doc)-sorted; ``tf`` float64. With ``frozen`` set, per-posting
    scores and impact quantization use those sealed collection statistics
    instead of the combined ones — the live-delta discipline. Structural
    quantities (df, offsets, layouts) always describe the postings given.
    """
    n, v = len(doclen), vocab
    p = len(term)

    df = np.bincount(term, minlength=v).astype(np.int64)
    cf = np.bincount(term, weights=tf, minlength=v)
    offsets = np.zeros(v + 1, np.int64)
    np.cumsum(df, out=offsets[1:])

    doclen_f = doclen.astype(np.float64)
    dl = doclen_f[doc]
    if frozen is None:
        score_n = n
        avg_dl = float(doclen_f.mean())
        total_tokens = float(doclen_f.sum())
        df_p = df[term].astype(np.float64)
        cf_p = cf[term]
        smax = None
    else:
        score_n = frozen.n_docs
        avg_dl = frozen.avg_dl
        total_tokens = frozen.total_tokens
        df_p = frozen.df[term]
        cf_p = frozen.cf[term]
        smax = frozen.quant_scale

    sims = scoring.all_similarity_scores(tf, df_p, cf_p, dl, score_n, avg_dl,
                                         total_tokens)  # (P, 6)
    bm25_sc = sims[:, 1].astype(np.float32)
    impact, qmax = scoring.quantize_impacts(bm25_sc, n_levels, smax=smax)

    # ---- block-max structure ----
    n_blocks = (n + block_size - 1) // block_size
    block_max = np.zeros((v, n_blocks), np.uint8)
    block_count = np.zeros((v, n_blocks), np.uint16)
    if p:
        blk = (doc // block_size).astype(np.int64)
        key = term.astype(np.int64) * n_blocks + blk
        # postings are (term, doc)-sorted => (term, block) groups contiguous
        group_start = np.flatnonzero(np.r_[True, key[1:] != key[:-1]])
        gmax = np.maximum.reduceat(impact.astype(np.int32), group_start)
        gcount = np.diff(np.r_[group_start, len(key)])
        gkey = key[group_start]
        block_max.reshape(-1)[gkey] = gmax.astype(np.uint8)
        block_count.reshape(-1)[gkey] = \
            np.minimum(gcount, 65535).astype(np.uint16)

    # ---- impact-ordered layout ----
    order, level_cum = impact_order_layout(term, doc, impact, v)
    docs_imp = doc[order]
    imp_sorted = impact[order]

    # ---- stage-0 term statistics table ----
    if p:
        stats = [
            _per_term_stats(term, sims[:, s].astype(np.float64), offsets,
                            df, v)
            for s in range(sims.shape[1])
        ]
        # layout: (V, 6 sims * 6 stats), sim-major to match feature_names()
        term_stats = np.concatenate(stats, axis=1)
    else:
        term_stats = np.zeros((v, 36), np.float32)

    if stoplist is None:
        stoplist = np.zeros(0, np.int64)

    return InvertedIndex(
        n_docs=n, vocab=v, avg_dl=avg_dl, total_tokens=total_tokens,
        doclen=doclen, df=df.astype(np.int32), cf=cf.astype(np.float32),
        offsets=offsets, docs=doc, tf=tf.astype(np.int32),
        bm25_score=bm25_sc, impact=impact, quant_scale=qmax,
        block_size=block_size, n_blocks=n_blocks,
        block_max=block_max, block_count=block_count,
        docs_imp=docs_imp, imp_sorted=imp_sorted, level_cum=level_cum,
        term_stats=term_stats,
        stoplist=np.asarray(stoplist, np.int64),
    )


def build_index(corpus: Corpus, block_size: int = 64,
                n_levels: int = 255, stop_k: int = 64) -> InvertedIndex:
    term = corpus.postings_term
    doc = corpus.postings_doc
    tf = corpus.postings_tf.astype(np.float64)

    stoplist = np.zeros(0, np.int64)
    if stop_k > 0:
        # stop the collection (paper: Indri stoplist): drop the stop_k most
        # frequent terms from the index entirely
        cf_all = np.bincount(term, weights=tf, minlength=corpus.vocab)
        stoplist = np.argsort(-cf_all)[:stop_k]
        keep = ~np.isin(term, stoplist)
        term, doc, tf = term[keep], doc[keep], tf[keep]

    return assemble_index(term, doc, tf, corpus.doclen, corpus.vocab,
                          block_size=block_size, n_levels=n_levels,
                          stoplist=stoplist)
