"""Append-only delta tile-set: the mutable half of the index layer.

A sealed index never changes; documents fed while serving land in a
``DeltaStore`` — a capacity-bounded segment materialized as one extra
``IndexShard`` (the *delta pseudo-shard*) that both lexical engines and the
dense engine scan alongside the sealed shards. Three disciplines make live
results converge bit-exactly to a from-scratch rebuild:

* **Frozen statistics** — delta postings are scored and quantized with the
  sealed index's collection stats (``CollectionStats``), so a posting's
  score is a pure function of (tf, dl, sealed stats) and does not drift as
  the delta fills.
* **Global ids above the sealed collection** — delta docs get ids
  ``>= sealed n_docs`` and the delta segment is appended *after* the sealed
  shards in the scatter-gather merge, so ``merge_shard_topk``'s
  lower-global-doc-id tie policy is preserved exactly.
* **Shape-static capacity padding** — the delta shard's arrays are padded to
  fixed capacities (``delta_docs`` / ``delta_postings``), so the serving jit
  signature is identical for every fill level; only a merge (which reseals
  the collection) retraces.

``merge()`` folds the retained *raw* feed (pre-stoplist, so the stoplist can
be recomputed over the combined collection) into the sealed corpus with a
per-term counted interleave and rebuilds — bit-identical to
``build_index(extend_corpus(corpus, feed))``, the independent oracle.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.index.builder import (CollectionStats, InvertedIndex,
                                 assemble_index, build_index, frozen_stats)
from repro.index.corpus import Corpus, FeedDocs
from repro.index.postings import IndexShard, IndexShardSpec, shard_from_index


def _round_up(x: int, m: int) -> int:
    return -(-max(int(x), 1) // m) * m


def merge_feed_postings(corpus: Corpus, feed: FeedDocs) -> Corpus:
    """Interleave raw feed postings into the sealed corpus in O(P).

    Both inputs are (term, doc)-sorted and every feed doc id rebases above
    the sealed collection, so within each term the sealed slice precedes the
    feed slice — a counted interleave reproduces the combined (term, doc)
    sort order without a global lexsort over all postings.
    """
    v = corpus.vocab
    n, m = corpus.n_docs, feed.n_docs
    ct, cd, cf = corpus.postings_term, corpus.postings_doc, corpus.postings_tf
    dt = feed.postings_term
    dd = feed.postings_doc.astype(np.int32) + n
    df_tf = feed.postings_tf

    cnt_s = np.bincount(ct, minlength=v).astype(np.int64)
    cnt_d = np.bincount(dt, minlength=v).astype(np.int64)
    off = np.zeros(v + 1, np.int64)
    np.cumsum(cnt_s + cnt_d, out=off[1:])
    start_s = np.zeros(v + 1, np.int64)
    np.cumsum(cnt_s, out=start_s[1:])
    start_d = np.zeros(v + 1, np.int64)
    np.cumsum(cnt_d, out=start_d[1:])

    pos_s = off[ct] + (np.arange(len(ct), dtype=np.int64) - start_s[ct])
    pos_d = (off[dt] + cnt_s[dt]
             + (np.arange(len(dt), dtype=np.int64) - start_d[dt]))

    p = len(ct) + len(dt)
    term = np.empty(p, np.int32)
    doc = np.empty(p, np.int32)
    tf = np.empty(p, np.int32)
    term[pos_s], term[pos_d] = ct, dt
    doc[pos_s], doc[pos_d] = cd, dd
    tf[pos_s], tf[pos_d] = cf, df_tf

    params = dataclasses.replace(corpus.params, n_docs=n + m)
    return Corpus(
        params,
        np.concatenate([corpus.doclen, feed.doclen]).astype(np.int32),
        term, doc, tf,
        np.concatenate([corpus.doc_topics, feed.doc_topics]),
        corpus.topic_perm, corpus.zipf_probs)


class DeltaStore:
    """Capacity-bounded live segment over a sealed ``InvertedIndex``."""

    def __init__(self, index: InvertedIndex, *, capacity_docs: int,
                 capacity_postings: int, tile_d: int = 128,
                 n_levels: int = 255):
        if capacity_docs < 1 or capacity_postings < 1:
            raise ValueError("delta capacities must be >= 1")
        self.capacity_docs = int(capacity_docs)
        self.capacity_postings = int(capacity_postings)
        self.tile_d = int(tile_d)
        self.n_levels = int(n_levels)
        self.reset(index)

    # ------------------------------------------------------------------ state
    def reset(self, index: InvertedIndex) -> None:
        """(Re)anchor on a sealed index: freeze its stats, empty the feed."""
        self.frozen: CollectionStats = frozen_stats(index)
        self.stoplist = np.asarray(
            index.stoplist if index.stoplist is not None else [], np.int64)
        self.stop_k = int(len(self.stoplist))
        self.block_size = index.block_size
        self.vocab = index.vocab
        self.base_docs = index.n_docs       # global id of delta doc 0
        # raw retained feed (pre-stoplist; delta-local doc ids, unsorted)
        self._raw_term = np.zeros(0, np.int32)
        self._raw_doc = np.zeros(0, np.int32)
        self._raw_tf = np.zeros(0, np.int32)
        self._raw_doclen = np.zeros(0, np.int32)
        self._topics = None
        self.n_docs = 0
        self.n_postings_kept = 0
        self._rebuild()

    def admit_count(self, feed: FeedDocs) -> int:
        """How many leading docs of ``feed`` fit the remaining capacity."""
        room_docs = self.capacity_docs - self.n_docs
        if room_docs <= 0:
            return 0
        keep = ~np.isin(feed.postings_term, self.stoplist)
        per_doc = np.bincount(feed.postings_doc[keep],
                              minlength=feed.n_docs).astype(np.int64)
        cum = np.cumsum(per_doc)
        room_p = self.capacity_postings - self.n_postings_kept
        fit = int(np.searchsorted(cum, room_p, side="right"))
        return min(fit, room_docs, feed.n_docs)

    def add(self, feed: FeedDocs) -> int:
        """Append the longest admissible prefix of ``feed``; returns the doc
        count actually ingested (0 = full, caller should merge first)."""
        take = self.admit_count(feed)
        if take == 0:
            if self.n_docs == 0 and feed.n_docs > 0:
                raise ValueError(
                    "delta capacity too small for a single feed doc")
            return 0
        sel = feed.postings_doc < take
        self._raw_term = np.concatenate(
            [self._raw_term, feed.postings_term[sel]])
        self._raw_doc = np.concatenate(
            [self._raw_doc, feed.postings_doc[sel] + self.n_docs])
        self._raw_tf = np.concatenate([self._raw_tf, feed.postings_tf[sel]])
        self._raw_doclen = np.concatenate(
            [self._raw_doclen, feed.doclen[:take]])
        topics = feed.doc_topics[:take]
        self._topics = (topics if self._topics is None or not len(self._topics)
                        else np.concatenate([self._topics, topics]))
        self.n_docs += take
        self._rebuild()
        return take

    @property
    def doc_topics(self) -> np.ndarray:
        return (self._topics if self._topics is not None
                else np.zeros((0, 1), np.float32))

    def _rebuild(self) -> None:
        """Re-tile the (stoplist-filtered, frozen-scored) live postings into
        a capacity-padded shard. Every rebuild emits identical shapes."""
        keep = ~np.isin(self._raw_term, self.stoplist)
        term = self._raw_term[keep].astype(np.int64)
        doc = self._raw_doc[keep].astype(np.int64)
        tf = self._raw_tf[keep].astype(np.float64)
        order = np.lexsort((doc, term))
        term, doc, tf = term[order], doc[order], tf[order]
        self.n_postings_kept = int(len(term))

        doclen = np.zeros(self.capacity_docs, np.int32)
        doclen[:self.n_docs] = self._raw_doclen
        mini = assemble_index(term, doc, tf, doclen, self.vocab,
                              block_size=self.block_size,
                              n_levels=self.n_levels,
                              stoplist=self.stoplist, frozen=self.frozen)
        self.index = mini
        self.shard, self.shard_spec = shard_from_index(
            mini, 0, self.capacity_docs, tile_d=self.tile_d,
            tile_cap=_round_up(self.capacity_postings, 128),
            pad_postings=self.capacity_postings,
            max_df=self.capacity_docs,
            max_blocks_per_term=mini.n_blocks)
        self.level_cum = np.asarray(mini.level_cum)

    # ------------------------------------------------------------------ merge
    def raw_feed(self) -> FeedDocs:
        """All retained feed docs as one (term, doc)-sorted raw batch."""
        order = np.lexsort((self._raw_doc, self._raw_term))
        return FeedDocs(
            doclen=self._raw_doclen,
            doc_topics=self.doc_topics if self.n_docs else
            np.zeros((0, 1), np.float32),
            postings_term=self._raw_term[order],
            postings_doc=self._raw_doc[order],
            postings_tf=self._raw_tf[order])

    def merged(self, corpus: Corpus) -> tuple[Corpus, InvertedIndex]:
        """Fold the delta into the sealed collection.

        The combined corpus is produced by the counted interleave and the
        index rebuilt from scratch over it — including a recomputed stoplist
        (the raw feed is retained pre-stoplist precisely so term drift can
        re-rank the stop set). Bit-identical to
        ``build_index(extend_corpus(corpus, self.raw_feed()))``.
        """
        new_corpus = merge_feed_postings(corpus, self.raw_feed())
        new_index = build_index(new_corpus, block_size=self.block_size,
                                n_levels=self.n_levels, stop_k=self.stop_k)
        return new_corpus, new_index

    # ------------------------------------------------------------------ views
    def segment(self) -> tuple[IndexShard, IndexShardSpec]:
        return self.shard, self.shard_spec

    @property
    def fill(self) -> float:
        """Fraction of the *binding* capacity axis in use (docs or
        postings, whichever runs out first)."""
        return max(self.n_docs / self.capacity_docs,
                   self.n_postings_kept / self.capacity_postings)

    def stats(self) -> dict:
        return {
            "delta_docs": int(self.n_docs),
            "delta_postings": int(self.n_postings_kept),
            "capacity_docs": self.capacity_docs,
            "capacity_postings": self.capacity_postings,
            "fill": float(self.fill),
            "base_docs": int(self.base_docs),
        }

    def export_metrics(self, reg) -> None:
        """Mirror delta occupancy into a telemetry registry (the ingest
        backpressure surface: fill drives the feed/merge gates)."""
        for k, v in self.stats().items():
            reg.gauge("ingest", key=k).set(v)
