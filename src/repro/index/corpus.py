"""Synthetic web-scale corpus + query workload generator.

ClueWeb09B and the MQ2009 query trace cannot ship in this container, so we
generate a corpus with the statistical properties the paper's mechanisms
depend on:

* Zipfian term-frequency distribution (drives postings-list length skew →
  the heavy-tailed per-query work distribution behind tail latencies);
* log-normal document lengths (drives BM25 length normalization);
* latent topic structure shared between documents and queries, giving an
  "ideal" final-stage ranker (BM25 + topical affinity) that genuinely
  disagrees with first-stage BM25 on hard queries — which is what makes the
  oracle-k / oracle-ρ label distributions skewed, as in the paper (Fig. 2/5).

Everything here is host-side numpy (index build is offline in production).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class CorpusParams:
    n_docs: int = 65536
    vocab: int = 32768
    avg_doclen: int = 150
    zipf_a: float = 1.15          # background term distribution skew
    n_topics: int = 32
    topical_fraction: float = 0.35
    seed: int = 1


@dataclass
class Corpus:
    params: CorpusParams
    doclen: np.ndarray            # (N,) int32
    postings_term: np.ndarray     # (P,) int32, sorted by (term, doc)
    postings_doc: np.ndarray      # (P,) int32
    postings_tf: np.ndarray       # (P,) int32
    doc_topics: np.ndarray        # (N, K) float32 topic mixtures
    topic_perm: np.ndarray        # (K, V) int32 topic-specific term permutation
    zipf_probs: np.ndarray        # (V,) float32

    @property
    def n_docs(self) -> int:
        return self.params.n_docs

    @property
    def vocab(self) -> int:
        return self.params.vocab

    @property
    def n_postings(self) -> int:
        return self.postings_term.shape[0]


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-a)
    return (p / p.sum()).astype(np.float64)


def build_corpus(params: CorpusParams = CorpusParams()) -> Corpus:
    rng = np.random.RandomState(params.seed)
    n, v, k = params.n_docs, params.vocab, params.n_topics

    doclen = np.maximum(
        rng.lognormal(mean=np.log(params.avg_doclen), sigma=0.6, size=n), 8
    ).astype(np.int64)
    total = int(doclen.sum())

    # document topic mixtures (sparse dirichlet via gamma)
    alpha = 0.08
    gam = rng.gamma(alpha, size=(n, k)).astype(np.float32) + 1e-8
    doc_topics = gam / gam.sum(axis=1, keepdims=True)

    zipf = _zipf_probs(v, params.zipf_a)
    cdf = np.cumsum(zipf)

    # token -> doc assignment
    tok_doc = np.repeat(np.arange(n, dtype=np.int32), doclen)

    # background terms: inverse-CDF Zipf sampling
    u = rng.random_sample(total)
    tok_term = np.searchsorted(cdf, u).astype(np.int32)
    tok_term = np.minimum(tok_term, v - 1)

    # topical terms: topic id per token (gumbel-max over doc mixture), then a
    # topic-permuted Zipf draw so each topic concentrates on its own terms
    topical = rng.random_sample(total) < params.topical_fraction
    n_topical = int(topical.sum())
    logits = np.log(doc_topics[tok_doc[topical]])
    gumbel = -np.log(-np.log(rng.random_sample((n_topical, k)) + 1e-12) + 1e-12)
    tok_topic = np.argmax(logits + gumbel, axis=1).astype(np.int32)
    topic_perm = np.stack([rng.permutation(v).astype(np.int32) for _ in range(k)])
    base_draw = np.minimum(
        np.searchsorted(cdf, rng.random_sample(n_topical)), v - 1)
    tok_term[topical] = topic_perm[tok_topic, base_draw]

    # URL-style docid reordering (Silvestri 2007; the paper's §2 notes this
    # improves both compression and pruning): cluster docids by dominant
    # topic so postings of topical terms are block-local, which is what
    # gives BMW's per-block upper bounds their discriminative power.
    dominant = np.argmax(doc_topics, axis=1)
    order = np.argsort(dominant, kind="stable").astype(np.int32)
    inv = np.empty(n, np.int32)
    inv[order] = np.arange(n, dtype=np.int32)
    tok_doc = inv[tok_doc]
    doclen = doclen[order]
    doc_topics = doc_topics[order]

    # aggregate to postings: unique (term, doc) with counts
    key = tok_term.astype(np.int64) * n + tok_doc.astype(np.int64)
    uniq, counts = np.unique(key, return_counts=True)
    postings_term = (uniq // n).astype(np.int32)
    postings_doc = (uniq % n).astype(np.int32)
    postings_tf = counts.astype(np.int32)

    return Corpus(params, doclen.astype(np.int32), postings_term, postings_doc,
                  postings_tf, doc_topics, topic_perm, zipf.astype(np.float32))


@dataclass(frozen=True)
class FeedDocs:
    """A batch of freshly crawled documents awaiting ingest.

    Doc ids are *local* to the batch (0..n_docs); the delta store rebases
    them above the sealed collection when it appends. Postings are raw
    (pre-stoplist) and (term, doc)-sorted, exactly the corpus convention, so
    a merge can interleave them with the sealed corpus without re-deriving
    anything.
    """
    doclen: np.ndarray            # (M,) int32
    doc_topics: np.ndarray        # (M, K) float32
    postings_term: np.ndarray     # (P,) int32, sorted by (term, doc)
    postings_doc: np.ndarray      # (P,) int32 batch-local
    postings_tf: np.ndarray       # (P,) int32

    @property
    def n_docs(self) -> int:
        return int(self.doclen.shape[0])

    @property
    def n_postings(self) -> int:
        return int(self.postings_term.shape[0])


def slice_feed(feed: FeedDocs, lo: int, hi: int) -> FeedDocs:
    """Docs [lo, hi) of a feed as a standalone batch (ids rebased to 0)."""
    sel = (feed.postings_doc >= lo) & (feed.postings_doc < hi)
    return FeedDocs(
        doclen=feed.doclen[lo:hi],
        doc_topics=feed.doc_topics[lo:hi],
        postings_term=feed.postings_term[sel],
        postings_doc=feed.postings_doc[sel] - lo,
        postings_tf=feed.postings_tf[sel])


def synthesize_feed_docs(corpus: Corpus, n_docs: int,
                         seed: int = 99) -> FeedDocs:
    """Draw feed documents from the same generative family as the corpus.

    Reuses the corpus's Zipf background, topic permutations, and length
    distribution so fed documents are statistically indistinguishable from
    sealed ones — but applies *no* URL-style docid reordering: a live feed
    arrives in crawl order, which is exactly the regime that stresses the
    delta tile-set (block-max bounds are weaker on unclustered postings).
    """
    rng = np.random.RandomState(seed)
    p = corpus.params
    m, v, k = n_docs, corpus.vocab, p.n_topics

    doclen = np.maximum(
        rng.lognormal(mean=np.log(p.avg_doclen), sigma=0.6, size=m), 8
    ).astype(np.int64)
    total = int(doclen.sum())

    gam = rng.gamma(0.08, size=(m, k)).astype(np.float32) + 1e-8
    doc_topics = gam / gam.sum(axis=1, keepdims=True)

    zipf = corpus.zipf_probs.astype(np.float64)
    cdf = np.cumsum(zipf / zipf.sum())

    tok_doc = np.repeat(np.arange(m, dtype=np.int32), doclen)
    u = rng.random_sample(total)
    tok_term = np.minimum(np.searchsorted(cdf, u), v - 1).astype(np.int32)

    topical = rng.random_sample(total) < p.topical_fraction
    n_topical = int(topical.sum())
    logits = np.log(doc_topics[tok_doc[topical]])
    gumbel = -np.log(-np.log(rng.random_sample((n_topical, k)) + 1e-12)
                     + 1e-12)
    tok_topic = np.argmax(logits + gumbel, axis=1).astype(np.int32)
    base_draw = np.minimum(
        np.searchsorted(cdf, rng.random_sample(n_topical)), v - 1)
    tok_term[topical] = corpus.topic_perm[tok_topic, base_draw]

    key = tok_term.astype(np.int64) * m + tok_doc.astype(np.int64)
    uniq, counts = np.unique(key, return_counts=True)
    return FeedDocs(
        doclen=doclen.astype(np.int32),
        doc_topics=doc_topics,
        postings_term=(uniq // m).astype(np.int32),
        postings_doc=(uniq % m).astype(np.int32),
        postings_tf=counts.astype(np.int32))


def extend_corpus(corpus: Corpus, feed: FeedDocs) -> Corpus:
    """The merged collection: feed docs appended at ids >= corpus.n_docs.

    This is the from-scratch oracle the background merge must reproduce
    bit-identically — an independent construction (global lexsort rather
    than the merge's per-term counted interleave).
    """
    import dataclasses

    n, m = corpus.n_docs, feed.n_docs
    term = np.concatenate([corpus.postings_term, feed.postings_term])
    doc = np.concatenate([corpus.postings_doc,
                          feed.postings_doc.astype(np.int32) + n])
    tf = np.concatenate([corpus.postings_tf, feed.postings_tf])
    order = np.lexsort((doc, term))
    params = dataclasses.replace(corpus.params, n_docs=n + m)
    return Corpus(
        params,
        np.concatenate([corpus.doclen, feed.doclen]).astype(np.int32),
        term[order].astype(np.int32), doc[order].astype(np.int32),
        tf[order].astype(np.int32),
        np.concatenate([corpus.doc_topics, feed.doc_topics]),
        corpus.topic_perm, corpus.zipf_probs)


@dataclass
class QueryLog:
    terms: np.ndarray        # (Q, L) int32, padded with 0
    mask: np.ndarray         # (Q, L) float32
    topic: np.ndarray        # (Q,) int32 latent topic of the query intent
    lengths: np.ndarray      # (Q,) int32


def build_queries(corpus: Corpus, n_queries: int, max_len: int = 8,
                  seed: int = 7, stop_k: int = 64) -> QueryLog:
    """MQ2009-like trace: lengths 2..5 (single-term queries filtered, as in
    the paper), terms drawn from a popularity-skewed mixture of background
    and topical vocabulary.  The top ``stop_k`` background terms are stopped
    (must match ``build_index``'s stoplist)."""
    rng = np.random.RandomState(seed)
    v = corpus.vocab
    k = corpus.params.n_topics
    lengths = rng.randint(2, 6, size=n_queries)
    topic = rng.randint(0, k, size=n_queries).astype(np.int32)

    # queries favour more common terms than the collection background, but
    # never contain stopped terms
    probs = corpus.zipf_probs ** 0.65
    probs[:stop_k] = 0.0
    probs = probs / probs.sum()
    cdf = np.cumsum(probs)

    terms = np.zeros((n_queries, max_len), np.int32)
    mask = np.zeros((n_queries, max_len), np.float32)
    for q in range(n_queries):
        l = lengths[q]
        draws = np.minimum(np.searchsorted(cdf, rng.random_sample(l)), v - 1)
        topical = rng.random_sample(l) < 0.5
        draws[topical] = corpus.topic_perm[topic[q], draws[topical]]
        draws = np.unique(draws)[:l]
        terms[q, :len(draws)] = draws
        mask[q, :len(draws)] = 1.0
    return QueryLog(terms, mask, topic, lengths.astype(np.int32))
