"""Elastic scaling: restore a layout-independent checkpoint onto a different
mesh (device count changed after node failure / preemption).

Checkpoints store unsharded logical arrays, so elasticity reduces to
recomputing NamedShardings for the new mesh and device_put-ing — plus
re-deriving data-pipeline cursors so no sample is skipped or repeated.
"""

from __future__ import annotations

import jax

from repro.models import common


def reshard_tree(tree, names_tree, rules, mesh):
    """Place an (unsharded, host) pytree onto `mesh` per the logical rules."""
    def place(leaf, names):
        spec = common.fit_spec_to_shape(
            common.resolve_pspec(names, rules, mesh), leaf.shape, mesh)
        return jax.device_put(leaf, jax.sharding.NamedSharding(mesh, spec))
    return jax.tree.map(place, tree, names_tree,
                        is_leaf=lambda x: hasattr(x, "shape")
                        and not isinstance(x, dict))


def rebalance_batch_size(global_batch: int, old_ways: int, new_ways: int):
    """Keep the global batch when the DP degree changes; returns the new
    per-replica batch and the padded global batch if not divisible."""
    per = -(-global_batch // new_ways)
    return per, per * new_ways


def data_cursor_after_restart(step: int, global_batch: int) -> int:
    """Deterministic data-pipeline cursor: sample index to resume from."""
    return step * global_batch
