"""Fault-tolerant checkpointing: atomic, async, layout-independent.

Production requirements implemented here:

* **Atomicity** — write to a temp dir, fsync, then `os.rename` (POSIX-atomic)
  so a crash mid-write never corrupts the latest checkpoint.
* **Integrity** — a manifest with per-array checksums; restore verifies and
  falls back to the previous step on mismatch (torn-write detection).
* **Async** — `save_async` hands the host copy to a writer thread so the
  accelerator keeps stepping (double-buffered; at most one pending write).
* **Layout independence / elasticity** — arrays are saved *unsharded* by
  logical name; restore re-shards onto whatever mesh the job restarts with
  (different device counts included — see `repro.train.elastic`).
* **Retention** — keep the last N checkpoints, delete older ones only
  after the newest is durable.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree, prefix=""):
    # dict keys sorted to match jax pytree flattening order
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif hasattr(tree, "_fields"):
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _checksum(a: np.ndarray) -> str:
    return hashlib.sha1(np.ascontiguousarray(a).view(np.uint8)).hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: threading.Thread | None = None

    # ---------------- write path ----------------

    def save(self, step: int, tree, extra: dict | None = None):
        arrays = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        self._write(step, arrays, extra or {})

    def save_async(self, step: int, tree, extra: dict | None = None):
        """Device->host copy happens now; disk write on a worker thread."""
        self.wait()
        arrays = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        t = threading.Thread(target=self._write, args=(step, arrays,
                                                       extra or {}))
        t.start()
        self._pending = t

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, arrays: dict, extra: dict):
        tmp = os.path.join(self.dir, f".tmp_step_{step}_{os.getpid()}")
        final = os.path.join(self.dir, f"step_{step:010d}")
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "time": time.time(), "extra": extra,
                    "arrays": {}}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        for k, a in arrays.items():
            manifest["arrays"][k] = {"shape": list(a.shape),
                                     "dtype": str(a.dtype),
                                     "sha1": _checksum(a)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.list_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ---------------- read path ----------------

    def list_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def restore_latest(self, template, mesh=None, shardings=None):
        """Restore the newest *valid* checkpoint into `template`'s structure.

        Returns (step, tree, extra) or (None, None, None) if nothing valid.
        Corrupt checkpoints (checksum/manifest mismatch) are skipped.
        """
        for step in reversed(self.list_steps()):
            path = os.path.join(self.dir, f"step_{step:010d}")
            try:
                with open(os.path.join(path, "manifest.json")) as f:
                    manifest = json.load(f)
                data = np.load(os.path.join(path, "arrays.npz"))
                arrays = {}
                for k, info in manifest["arrays"].items():
                    a = data[k]
                    if _checksum(a) != info["sha1"]:
                        raise IOError(f"checksum mismatch for {k}")
                    arrays[k] = a
                tree = self._unflatten(template, arrays, mesh, shardings)
                return step, tree, manifest.get("extra", {})
            except Exception as e:
                print(f"[ckpt] step {step} invalid ({e}); trying older")
        return None, None, None

    def _unflatten(self, template, arrays, mesh, shardings):
        flat_t = _flatten(template)
        sh_flat = _flatten(shardings) if shardings is not None else None
        leaves, treedef = jax.tree.flatten(template)
        out = {}
        for k in flat_t:
            a = arrays[k]
            if sh_flat is not None and k in sh_flat:
                out[k] = jax.device_put(a, sh_flat[k])
            else:
                out[k] = jax.numpy.asarray(a)
        ordered = [out[k] for k in flat_t]
        return jax.tree.unflatten(treedef, ordered)
