"""Gradient compression for the data-parallel all-reduce.

int8 quantization with error feedback (residual carried to the next step)
and optional top-k sparsification.  At 1000+ nodes the DP all-reduce is the
dominant cross-pod collective; 4× compression on it moves the §Roofline
collective term directly (evaluated in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(g: jnp.ndarray):
    """Per-tensor symmetric int8. Returns (q, scale)."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, error):
    """Quantize grads + carry quantization error (error feedback).

    Returns (quantized pytree of (q, scale), new_error)."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, s = quantize_int8(g)
        back = dequantize_int8(q, s)
        return (q, s), g - back
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    qtree = jax.tree.unflatten(tdef, [p[0] for p in pairs])
    etree = jax.tree.unflatten(tdef, [p[1] for p in pairs])
    return qtree, etree


def decompress_grads(qtree):
    return jax.tree.map(lambda pair: dequantize_int8(*pair), qtree,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and len(x) == 2)


def init_error(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def topk_sparsify(g: jnp.ndarray, frac: float = 0.01):
    """Keep the top `frac` entries by magnitude (flattened); rest zeroed.
    Returns (values, indices, original shape) for sparse all-gather."""
    flat = g.reshape(-1)
    k = max(int(flat.shape[0] * frac), 1)
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx, g.shape
