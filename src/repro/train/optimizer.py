"""AdamW + schedules + gradient clipping, pure JAX (no optax dependency).

Optimizer state mirrors the parameter pytree (same sharding), so the whole
train state shards under one names-tree.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


class OptState(NamedTuple):
    m: dict
    v: dict
    step: jnp.ndarray


def init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(m=zeros,
                    v=jax.tree.map(jnp.copy, zeros),
                    step=jnp.zeros((), jnp.int32))


def abstract_init(params) -> OptState:
    sds = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                       params)
    return OptState(m=sds, v=jax.tree.map(lambda x: x, sds),
                    step=jax.ShapeDtypeStruct((), jnp.int32))


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cosine = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (0.1 + 0.9 * cosine)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply(params, grads, opt: OptState, cfg: AdamWConfig):
    """One AdamW update. Returns (new_params, new_opt, metrics)."""
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
    step = opt.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt.m)
    flat_v = jax.tree.leaves(opt.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, OptState(new_m, new_v, step), {"grad_norm": gn, "lr": lr}
