"""Production training loop: grad accumulation with bucketed overlap,
checkpoint/restart, failure injection hooks, and throughput accounting.

The loop is engine-agnostic (takes a loss_fn + params); `repro/launch/train.py`
wires it to the LM/GNN/recsys models.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp

from repro.train import checkpoint as ckpt_lib
from repro.train import compression, optimizer


@dataclass
class TrainConfig:
    steps: int = 200
    microbatches: int = 1             # grad accumulation factor
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    compress_grads: bool = False
    opt: optimizer.AdamWConfig = field(default_factory=optimizer.AdamWConfig)


def make_train_step(loss_fn: Callable, cfg: TrainConfig):
    """Returns jit-able train_step(params, opt, batch) -> (params, opt, loss).

    With microbatches > 1, grads accumulate over a lax.scan of microbatch
    slices — the bucketed psum of microbatch i overlaps compute of i+1 on
    real hardware (XLA async collectives).
    """
    def step(params, opt, batch):
        if cfg.microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                return x.reshape((cfg.microbatches,
                                  x.shape[0] // cfg.microbatches) + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                acc, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, lsum + l), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, lsum), _ = jax.lax.scan(acc_fn, (zero, 0.0), micro)
            grads = jax.tree.map(lambda g: g / cfg.microbatches, grads)
            loss = lsum / cfg.microbatches
        if cfg.compress_grads:
            q, _ = compression.compress_grads(
                grads, compression.init_error(grads))
            grads = compression.decompress_grads(q)
        new_p, new_opt, metrics = optimizer.apply(params, grads, opt, cfg.opt)
        return new_p, new_opt, loss, metrics
    return step


def run(params, loss_fn: Callable, data_iter, cfg: TrainConfig,
        resume: bool = True, fail_at: int | None = None):
    """Train with checkpoint/restart. `fail_at` injects a crash (tests)."""
    mgr = ckpt_lib.CheckpointManager(cfg.ckpt_dir)
    opt = optimizer.init(params)
    start = 0
    if resume:
        step0, state, extra = mgr.restore_latest(
            {"params": params, "opt_m": opt.m, "opt_v": opt.v})
        if step0 is not None:
            params = state["params"]
            opt = optimizer.OptState(state["opt_m"], state["opt_v"],
                                     jnp.asarray(step0, jnp.int32))
            start = step0
            print(f"[train] resumed from step {step0}")

    step_fn = jax.jit(make_train_step(loss_fn, cfg))
    losses = []
    t0 = time.time()
    for step in range(start, cfg.steps):
        batch = next(data_iter)
        params, opt, loss, metrics = step_fn(params, opt, batch)
        losses.append(float(loss))
        if fail_at is not None and step == fail_at:
            mgr.wait()
            raise RuntimeError(f"injected failure at step {step}")
        if (step + 1) % cfg.ckpt_every == 0:
            mgr.save_async(step + 1, {"params": params, "opt_m": opt.m,
                                      "opt_v": opt.v})
        if (step + 1) % cfg.log_every == 0:
            dt = time.time() - t0
            print(f"[train] step {step + 1} loss={float(loss):.4f} "
                  f"({(step + 1 - start) / dt:.2f} steps/s)")
    mgr.wait()
    mgr.save(cfg.steps, {"params": params, "opt_m": opt.m, "opt_v": opt.v})
    return params, opt, losses
