"""Synthetic data generators for every architecture family."""

from __future__ import annotations

import numpy as np


def lm_batches(vocab: int, batch: int, seq: int, seed: int = 0,
               start_index: int = 0):
    """Deterministic, resumable token stream (Zipfian unigrams with local
    structure). `start_index` is the elastic-restart cursor."""
    probs = (np.arange(1, vocab + 1) ** -1.1)
    probs = probs / probs.sum()
    cdf = np.cumsum(probs)
    i = start_index
    while True:
        rng = np.random.RandomState((seed * 1_000_003 + i) % (1 << 31))
        u = rng.random_sample((batch, seq + 1))
        toks = np.minimum(np.searchsorted(cdf, u), vocab - 1).astype(np.int32)
        # inject local repetition so the loss can actually fall
        rep = rng.random_sample((batch, seq)) < 0.3
        toks[:, 1:][rep] = toks[:, :-1][rep]
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        i += batch


def ctr_batches(n_fields: int, rows_per_field: int, batch: int, seed: int = 0):
    """Criteo-like CTR stream: skewed categorical ids + a planted logistic
    ground truth so AUC is learnable."""
    rng = np.random.RandomState(seed)
    w = rng.randn(n_fields) * 0.5
    while True:
        z = rng.zipf(1.3, size=(batch, n_fields)) % rows_per_field
        ids = (z + np.arange(n_fields) * rows_per_field).astype(np.int32)
        logit = (np.sin(z * 0.7) * w).sum(axis=1) - 0.5
        label = (rng.random_sample(batch) < 1 / (1 + np.exp(-logit)))
        yield {"ids": ids, "label": label.astype(np.int32)}


def seqrec_batches(n_items: int, batch: int, seq: int, n_masked: int = 8,
                   n_cands: int = 256, seed: int = 0):
    """BERT4Rec-style masked item sequences with sampled-softmax candidates."""
    rng = np.random.RandomState(seed)
    mask_token = n_items
    while True:
        items = (rng.zipf(1.2, size=(batch, seq)) % n_items).astype(np.int32)
        pos = np.stack([rng.choice(seq, n_masked, replace=False)
                        for _ in range(batch)]).astype(np.int32)
        true_items = np.take_along_axis(items, pos, axis=1)
        for b in range(batch):
            items[b, pos[b]] = mask_token
        cands = rng.randint(0, n_items, size=n_cands).astype(np.int32)
        cands[:n_masked] = true_items[0]
        label_idx = rng.randint(0, n_cands, size=(batch, n_masked))
        # plant each true item into the candidate set
        for b in range(batch):
            slots = rng.choice(n_cands, n_masked, replace=False)
            cands_local = cands.copy()
            label_idx[b] = slots
        cands[label_idx[0]] = true_items[0]
        yield {"items": items, "positions": pos,
               "label_idx": label_idx.astype(np.int32), "candidates": cands}


def molecule_batches(n_graphs: int, n_nodes: int, n_edges: int, d_feat: int,
                     trip_factor: int = 4, seed: int = 0):
    """Batched small molecules: random 3-D conformers, radius-ish edges,
    exact-ish triplets, and a smooth geometric regression target."""
    rng = np.random.RandomState(seed)
    while True:
        yield make_molecule_batch(rng, n_graphs, n_nodes, n_edges, d_feat,
                                  trip_factor)


def make_molecule_batch(rng, n_graphs, n_nodes, n_edges, d_feat,
                        trip_factor=4):
    n = n_graphs * n_nodes
    e = n_graphs * n_edges
    t = e * trip_factor
    pos = rng.randn(n, 3).astype(np.float32) * 1.5
    feat = rng.randn(n, d_feat).astype(np.float32) * 0.3
    src = np.zeros(e, np.int32)
    dst = np.zeros(e, np.int32)
    for g in range(n_graphs):
        s = rng.randint(0, n_nodes, n_edges) + g * n_nodes
        d = rng.randint(0, n_nodes, n_edges) + g * n_nodes
        src[g * n_edges:(g + 1) * n_edges] = s
        dst[g * n_edges:(g + 1) * n_edges] = d
    # triplets: edge pairs sharing the middle node
    order = np.argsort(dst, kind="stable")
    sorted_dst = dst[order]
    ji = rng.randint(0, e, t)
    j = src[ji]
    lo = np.searchsorted(sorted_dst, j, "left")
    hi = np.searchsorted(sorted_dst, j, "right")
    span = np.maximum(hi - lo, 1)
    kj = order[np.minimum(lo + rng.randint(0, 1 << 30, t) % span, e - 1)]
    tmask = ((hi > lo) & (kj != ji)).astype(np.float32)
    # smooth target: sum of inverse pairwise distances along edges
    dvec = pos[src] - pos[dst]
    dd = np.sqrt((dvec ** 2).sum(1) + 1e-6)
    target = np.zeros(n, np.float32)
    np.add.at(target, dst, 1.0 / (1.0 + dd))
    return {
        "feat": feat, "pos": pos,
        "edge_src": src, "edge_dst": dst,
        "trip_kj": kj.astype(np.int32), "trip_ji": ji.astype(np.int32),
        "edge_mask": np.ones(e, np.float32), "trip_mask": tmask,
        "node_mask": np.ones(n, np.float32), "target": target,
    }
