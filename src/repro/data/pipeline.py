"""Sharding-aware host data pipeline: prefetch thread + device placement +
deterministic resumable cursors (elastic restarts resume exactly)."""

from __future__ import annotations

import queue
import threading

import jax
import numpy as np


class PrefetchingLoader:
    """Wraps a host generator with a background prefetch thread and
    device_put onto per-argument shardings."""

    def __init__(self, gen, shardings=None, depth: int = 2):
        self.gen = gen
        self.shardings = shardings
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.t = threading.Thread(target=self._worker, daemon=True)
        self.t.start()

    def _worker(self):
        try:
            for item in self.gen:
                if self._stop.is_set():
                    return
                if self.shardings is not None:
                    item = jax.tree.map(
                        lambda x, s: jax.device_put(np.asarray(x), s),
                        item, self.shardings)
                self.q.put(item)
        finally:
            self.q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
