"""Moonlight-16B-A3B (kimi/moonshot): MoE, 64 experts top-6 (+2 shared),
DeepSeek-V3-style. [hf:moonshotai/Moonlight-16B-A3B]"""

from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

FAMILY = "lm"

CONFIG = LMConfig(
    name="moonshot-v1-16b-a3b", n_layers=48, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=1408, vocab=163840, head_dim=128,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
                  capacity_factor=1.25),
    dtype="bfloat16", remat="full",
    train_layout="tpsp", train_microbatches=2,   # §Perf: EP+TP with 2-way
                           # grad accumulation is the config that fits HBM
)

REDUCED = LMConfig(
    name="moonshot-reduced", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=1024, head_dim=32,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=128, n_shared=1,
                  capacity_factor=8.0),  # drop-free at smoke scale
    dtype="float32", remat="none",
)
