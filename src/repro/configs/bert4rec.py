"""BERT4Rec: bidirectional transformer over item sequences (encoder-only —
no autoregressive decode shapes). [arXiv:1904.06690]"""

from repro.models.recsys import RecsysConfig

FAMILY = "recsys"

CONFIG = RecsysConfig(
    name="bert4rec", kind="bert4rec", embed_dim=64, n_blocks=2, n_heads=2,
    seq_len=200, n_items=1_000_000, dtype="float32",
)

REDUCED = RecsysConfig(
    name="bert4rec-reduced", kind="bert4rec", embed_dim=16, n_blocks=2,
    n_heads=2, seq_len=24, n_items=256, dtype="float32",
)
