"""DeepFM: FM + deep MLP over 39 sparse fields. [arXiv:1703.04247]"""

from repro.models.recsys import RecsysConfig

FAMILY = "recsys"

CONFIG = RecsysConfig(
    name="deepfm", kind="deepfm", n_sparse=39, embed_dim=10,
    rows_per_field=1_000_000, mlp=(400, 400, 400), dtype="float32",
)

REDUCED = RecsysConfig(
    name="deepfm-reduced", kind="deepfm", n_sparse=8, embed_dim=6,
    rows_per_field=128, mlp=(32, 32), dtype="float32",
)
