"""Minitron-8B: width-pruned Nemotron-4, GQA kv=8, 256k vocab.
[arXiv:2407.14679; hf:nvidia/Minitron-8B-Base]"""

from repro.models.transformer import LMConfig

FAMILY = "lm"

CONFIG = LMConfig(
    name="minitron-8b", n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=16384, vocab=256000, head_dim=128, dtype="bfloat16", remat="full",
    train_layout="tpsp",   # §Perf: FSDP is 2x less collective-bound but the
                           # 256k-vocab CE buffers exceed HBM at 256-way batch
    train_microbatches=2,
)

REDUCED = LMConfig(
    name="minitron-8b-reduced", n_layers=2, d_model=128, n_heads=8,
    n_kv_heads=2, d_ff=512, vocab=1024, head_dim=16, dtype="float32",
    remat="none",
)
