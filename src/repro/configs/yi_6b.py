"""Yi-6B: llama-arch dense transformer with GQA (kv=4).
[arXiv:2403.04652; hf:01-ai/Yi-6B]"""

from repro.models.transformer import LMConfig

FAMILY = "lm"

CONFIG = LMConfig(
    name="yi-6b", n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4,
    d_ff=11008, vocab=64000, head_dim=128, rope_theta=5_000_000.0,
    dtype="bfloat16", remat="full",
)

REDUCED = LMConfig(
    name="yi-6b-reduced", n_layers=2, d_model=128, n_heads=8, n_kv_heads=1,
    d_ff=344, vocab=512, head_dim=16, dtype="float32", remat="none",
)
