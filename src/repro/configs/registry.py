"""Architecture registry: ``get_arch(id)`` -> (config, family).

One module per assigned architecture under ``repro/configs/``; this file
collects them and provides the reduced (smoke-test) variants.
"""

from __future__ import annotations

import importlib

ARCH_IDS = [
    "yi_6b", "minitron_8b", "minicpm3_4b", "moonshot_v1_16b_a3b",
    "granite_moe_3b_a800m",
    "dimenet",
    "bert4rec", "xdeepfm", "two_tower_retrieval", "deepfm",
    "paper_isn",
]

_ALIAS = {i.replace("_", "-"): i for i in ARCH_IDS}


def get_arch(arch_id: str):
    arch_id = _ALIAS.get(arch_id, arch_id)
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG, mod.FAMILY


def get_reduced(arch_id: str):
    arch_id = _ALIAS.get(arch_id, arch_id)
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.REDUCED, mod.FAMILY


def all_cells():
    """Every (arch × shape) dry-run cell (40 assigned + paper ISN extras)."""
    from repro.configs.shapes import FAMILY_SHAPES
    cells = []
    for a in ARCH_IDS:
        _, family = get_arch(a)
        for s in FAMILY_SHAPES[family]:
            cells.append((a, s))
    return cells
