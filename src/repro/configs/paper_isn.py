"""The paper's own architecture: a hybrid first-stage ISN (index server
node) — document-sharded BMW + JASS index mirrors behind the Stage-0
prediction framework, production scale (50M docs / 2M terms / ~15B
postings across a 256-chip pod)."""

from dataclasses import dataclass

FAMILY = "isn"


@dataclass(frozen=True)
class ISNConfig:
    name: str = "paper-isn"
    n_docs: int = 50_331_648          # 196,608 docs / shard on 16x16
    vocab: int = 2_000_000
    postings_per_shard: int = 58_982_400
    block_entries_per_shard: int = 29_491_200
    n_levels: int = 32
    block_size: int = 64
    k_max: int = 4096
    rho_max: int = 131_072            # per-shard budget (≈ 33.5M global)
    query_len: int = 8
    queries_per_step: int = 4096      # global serve batch
    tile_d: int = 128                 # docs per bucketed serving tile
    tile_cap: int = 65_536            # lane-padded postings capacity / tile


CONFIG = ISNConfig()

REDUCED = ISNConfig(
    name="paper-isn-reduced", n_docs=8192, vocab=4096,
    postings_per_shard=750_000, block_entries_per_shard=350_000,
    n_levels=256, block_size=64, k_max=128, rho_max=4096, query_len=8,
    queries_per_step=32, tile_d=128, tile_cap=16_384,
)
