"""Two-tower retrieval with in-batch sampled softmax (Yi et al. RecSys'19).

The arch where the paper's technique lands *directly*: retrieval_cand is
first-stage candidate generation with a per-query anytime budget."""

from repro.models.recsys import RecsysConfig

FAMILY = "recsys"

CONFIG = RecsysConfig(
    name="two-tower-retrieval", kind="two_tower", embed_dim=256,
    tower_mlp=(1024, 512, 256), n_users=8_000_000, n_items=2_000_000,
    n_user_feats=16, n_item_feats=8, dtype="float32",
)

REDUCED = RecsysConfig(
    name="two-tower-reduced", kind="two_tower", embed_dim=32,
    tower_mlp=(64, 32), n_users=1024, n_items=512, n_user_feats=4,
    n_item_feats=2, dtype="float32",
)
