"""DimeNet: directional message passing GNN. [arXiv:2003.03123]"""

from repro.models.gnn import DimeNetConfig

FAMILY = "gnn"

CONFIG = DimeNetConfig(
    name="dimenet", n_blocks=6, d_hidden=128, n_bilinear=8, n_spherical=7,
    n_radial=6, d_feat=16, dtype="float32",
)

REDUCED = DimeNetConfig(
    name="dimenet-reduced", n_blocks=2, d_hidden=32, n_bilinear=4,
    n_spherical=3, n_radial=4, d_feat=8, dtype="float32",
)
