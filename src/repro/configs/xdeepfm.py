"""xDeepFM: compressed interaction network (CIN) 200-200-200 + deep MLP.
[arXiv:1803.05170]"""

from repro.models.recsys import RecsysConfig

FAMILY = "recsys"

CONFIG = RecsysConfig(
    name="xdeepfm", kind="xdeepfm", n_sparse=39, embed_dim=10,
    rows_per_field=1_000_000, cin_layers=(200, 200, 200), mlp=(400, 400),
    dtype="float32",
)

REDUCED = RecsysConfig(
    name="xdeepfm-reduced", kind="xdeepfm", n_sparse=8, embed_dim=6,
    rows_per_field=128, cin_layers=(16, 16), mlp=(32,), dtype="float32",
)
