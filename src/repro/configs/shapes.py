"""Assigned input-shape registry (one set per architecture family) and the
per-(family, shape) logical-sharding rules.

Every (arch × shape) cell the dry-run compiles is defined here; the rules
are the primary §Perf hillclimbing lever (changing a rule re-lowers the
same model under a different collective schedule).
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode | serve | retrieval
    seq_len: int = 0
    global_batch: int = 0
    extras: tuple = ()


LM_SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", seq_len=4096, global_batch=256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", seq_len=32768,
                             global_batch=32),
    "decode_32k": ShapeCell("decode_32k", "decode", seq_len=32768,
                            global_batch=128),
    # decode against a 524k KV cache is linear per token (sub-quadratic);
    # run via the split-KV decode path with sequence-sharded cache
    "long_500k": ShapeCell("long_500k", "decode", seq_len=524288,
                           global_batch=1),
}

GNN_SHAPES = {
    "full_graph_sm": ShapeCell("full_graph_sm", "train",
                               extras=(("n_nodes", 2708), ("n_edges", 10556),
                                       ("d_feat", 1433), ("trip_factor", 4))),
    "minibatch_lg": ShapeCell("minibatch_lg", "train",
                              extras=(("n_nodes", 232965),
                                      ("n_edges", 114615892),
                                      ("batch_nodes", 1024),
                                      ("fanouts", (15, 10)),
                                      ("d_feat", 602), ("trip_factor", 2))),
    "ogb_products": ShapeCell("ogb_products", "train",
                              extras=(("n_nodes", 2449029),
                                      ("n_edges", 61859140),
                                      ("d_feat", 100), ("trip_factor", 1))),
    "molecule": ShapeCell("molecule", "train",
                          extras=(("n_nodes", 30), ("n_edges", 64),
                                  ("batch", 128), ("d_feat", 16),
                                  ("trip_factor", 4))),
}

RECSYS_SHAPES = {
    "train_batch": ShapeCell("train_batch", "train", global_batch=65536),
    "serve_p99": ShapeCell("serve_p99", "serve", global_batch=512),
    "serve_bulk": ShapeCell("serve_bulk", "serve", global_batch=262144),
    "retrieval_cand": ShapeCell("retrieval_cand", "retrieval", global_batch=1,
                                extras=(("n_candidates", 1_000_000),)),
}

# the paper's own architecture (first-stage ISN); additive to the 40 cells
ISN_SHAPES = {
    "serve_trace": ShapeCell("serve_trace", "serve", global_batch=4096),
}

FAMILY_SHAPES = {
    "lm": LM_SHAPES,
    "gnn": GNN_SHAPES,
    "recsys": RECSYS_SHAPES,
    "isn": ISN_SHAPES,
}


def extras_dict(cell: ShapeCell) -> dict:
    return dict(cell.extras)


# ---------------------------------------------------------------------------
# sharding rules per (family, shape-kind)
# ---------------------------------------------------------------------------

# Default LM-train layout: FSDP — batch over as many mesh axes as divide
# it (resolved per cell), weights/optimizer fully sharded and gathered per
# layer. §Perf iteration: TP+SP at this batch is 6.7× more collective-bound
# (344 GB vs 52 GB per device per step on yi-6b); FSDP leaves the cell
# compute-dominant. TP+SP remains available as a rules_override.
_LM_TRAIN = {
    "batch": ("pod", "data", "model"), "embed": None,
    "heads": ("data", "model"), "kv_heads": ("data", "model"), "qk": None,
    "ffn": ("data", "model"), "vocab": ("data", "model"),
    "experts": "model", "seq": None, "kv_seq": None, "stack": None,
}

# the paper-faithful-era TP+SP layout (kept for §Perf comparisons)
LM_TRAIN_TPSP = {
    "batch": ("pod", "data"), "embed": None, "heads": "model",
    "kv_heads": "model", "qk": None, "ffn": "model", "vocab": "model",
    "experts": "model", "seq": "model", "kv_seq": None, "stack": None,
}

# decode/prefill: weights stay resident (TP) — per-layer FSDP gathers would
# swamp a single-token step; the KV cache sequence shards over "model"
_LM_DECODE = dict(LM_TRAIN_TPSP, kv_seq="model", seq=None)

_GNN = {
    # nodes replicated (feature tables are ~1 GB at most: cheap vs the
    # all-gather storm of cross-shard edge gathers); edges + triplets shard
    # over the whole mesh; partitioned layout (triplets shard-local, one
    # node-aggregation psum per pass) is the §Perf default — 304× less
    # collective than the pjit baseline on ogb_products
    "batch": ("pod", "data"), "nodes": None,
    "edges": ("pod", "data", "model"), "stack": None, "embed": None,
    "ffn": None, "partition_gnn": True,
}

_RECSYS = {
    "batch": ("pod", "data"), "rows": "model", "ffn": "model",
    "heads": "model", "candidates": ("pod", "data", "model"), "stack": None,
    "embed": None,
    "vocab": "model", "seq": None, "kv_seq": None, "qk": None,
    "experts": "model",
}

_ISN = {
    "batch": ("pod", "data"), "docs": "model", "postings": "model",
    "blocks": "model", "vocab": None, "stack": None, "embed": None,
    "ffn": None,
}


def rules_for(family: str, shape: ShapeCell) -> dict:
    if family == "lm":
        if shape.kind == "decode":
            return dict(_LM_DECODE)
        if shape.kind == "prefill":
            return dict(LM_TRAIN_TPSP)
        return dict(_LM_TRAIN)
    if family == "gnn":
        return dict(_GNN)
    if family == "recsys":
        return dict(_RECSYS)
    if family == "isn":
        return dict(_ISN)
    raise ValueError(family)
