"""Granite-MoE 3B-A800M: 40 experts top-8, GQA kv=8.
[hf:ibm-granite/granite-3.0-3b-a800m-base]"""

from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

FAMILY = "lm"

CONFIG = LMConfig(
    name="granite-moe-3b-a800m", n_layers=32, d_model=1536, n_heads=24,
    n_kv_heads=8, d_ff=512, vocab=49155, head_dim=64,
    moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512, n_shared=0,
                  capacity_factor=1.25),
    dtype="bfloat16", remat="full",
)

REDUCED = LMConfig(
    name="granite-reduced", n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
    d_ff=64, vocab=512, head_dim=16,
    moe=MoEConfig(n_experts=8, top_k=4, d_ff_expert=64, n_shared=0,
                  capacity_factor=8.0),  # drop-free at smoke scale
    dtype="float32", remat="none",
)
