"""Named cascade operating points.

The judgment-free trade-off framing (Clarke et al., arXiv:1506.00717) and
the dynamic trade-off predictors (Culpepper/Clarke/Lin, arXiv:1610.02502)
both assume an operator can *name* a deployment operating point and
instantiate it; this registry is that name → :class:`CascadeSpec` mapping.

    from repro.configs.cascade_presets import get_preset
    system = build_system(get_preset("paper_200ms"), corpus)

Presets (serving-time units follow ``CostModel.paper_scale``, i.e. ms on
the synthetic experiment collection):

=============  ==========================================================
paper_200ms    The paper's headline point: 200 ms budget, Algorithm 2
               routing with hedging, full Stage-2 re-rank.
throughput     Capacity-first: tighter budget, shallower candidate grid,
               hedging off (duplicated work costs capacity).
quality        Effectiveness-first: deep candidate grid, generous budget
               and ρ cap, deeper final lists.
stage1_only    First stage as the product: no Stage-2 re-rank, latency is
               the Stage-0+1 tail alone.
fault_tolerant The paper point hardened for lossy clusters: 4 shards x 3
               replicas, scatter-gather failover (25 ms shard timeout, 2
               bounded retries charged into the worst-case bound), so the
               200 ms guarantee survives replica crashes and stragglers;
               pair with a ``FaultSpec`` (``fault=...`` override or
               ``--fault-scenario``) to actually inject them.
cached         The paper point with the two-level result cache in front
               (L1 exact results + L2 Stage-1 candidates): repeated
               queries are answered at the front door in
               ``predict + cache_hit_us``, buying certified capacity on
               skewed traffic.  Threshold adaptation is frozen
               (``adapt_every=0``) so cache keys — which embed the route
               signature — stay stable across the trace.
live_ingest    The paper point serving while the collection mutates: a
               capacity-bounded delta tile-set absorbs a seeded document
               feed (its worst-case scan charged into every query's bound
               and into admission), background merges reseal the index in
               idle gaps — deferred under load, forced only when the delta
               is full — and the feed throttles strictly before queries
               degrade.  Adaptation frozen like ``cached``: ingest bumps
               the cache epoch, stable routes keep replay deterministic.
hybrid_fusion  The paper point with the dense Stage-1 modality enabled:
               Stage-0 dispatches each query lexical / dense / both+fused
               from its predicted traversal time, both-routed lists merge
               by RRF inside a reserved ``fusion_us`` slice of the stage-1
               budget, and the confidence bands (θ_high skips Stage-2
               rank-safely, θ_low re-issues a ρ_late-capped lexical
               fallback) stay inside the 200 ms bound.  Adaptation frozen
               like ``cached``: the modality is part of the route.
=============  ==========================================================

Every preset trains with ``RoutingSpec.calibrate=True``, so the routing
thresholds (t_k, t_time) are re-anchored to the trained predictors'
distribution at ``fit`` time — the spec names the trade-off, the data
names the thresholds.

Every preset also ships the hard-guarantee knobs explicitly:
``hedge_deadline`` (straggler detection fraction) and ``late_rho`` (the
SMALL re-issue cap — the worst case is
``budget·hedge_deadline + ρ_late·c_s``, see ``repro.serving.scheduler``),
with ``enforce_budget=True`` so the deadline re-route covers JASS routes
and Stage-2 grids are trimmed when a query's budget is already spent.

Each preset also names its **online traffic policy** (``OnlineSpec``:
micro-batch width/deadline + admission ladder) for
``SearchSystem.serve_online`` — ``throughput`` batches wide,
``quality`` refuses to degrade its candidate grid (shed instead).
"""

from __future__ import annotations

import dataclasses

from repro.serving.spec import (CacheSpec, CascadeSpec, DenseSpec,
                                DeploySpec, FusionSpec, IngestSpec,
                                OnlineSpec, RoutingSpec, Stage2Spec)


def _paper_200ms() -> CascadeSpec:
    return CascadeSpec(
        name="paper_200ms",
        routing=RoutingSpec(algorithm=2, budget=200.0, rho_max=1 << 18,
                            hedge_deadline=0.5, late_rho=4096,
                            adapt_every=1, calibrate=True),
        stage2=Stage2Spec(enabled=True, k_serve=128, t_final=10),
        deploy=DeploySpec(n_shards=1, replicas=2),
        online=OnlineSpec(max_batch=32, batch_deadline_us=5.0,
                          admission=True, degrade=True),
    )


def _throughput() -> CascadeSpec:
    return CascadeSpec(
        name="throughput",
        routing=RoutingSpec(algorithm=2, budget=120.0, rho_max=1 << 16,
                            enable_hedging=False, hedge_deadline=0.5,
                            late_rho=2048, calibrate=True),
        stage2=Stage2Spec(enabled=True, k_serve=64, t_final=10),
        deploy=DeploySpec(n_shards=1, replicas=2),
        # capacity-first: wider batches, a longer forming window
        online=OnlineSpec(max_batch=64, batch_deadline_us=10.0,
                          admission=True, degrade=True),
    )


def _quality() -> CascadeSpec:
    return CascadeSpec(
        name="quality",
        routing=RoutingSpec(algorithm=2, budget=400.0, rho_max=1 << 18,
                            hedge_deadline=0.6, late_rho=8192,
                            calibrate=True),
        stage2=Stage2Spec(enabled=True, k_serve=256, t_final=20,
                          ltr_trees=64),
        deploy=DeploySpec(n_shards=1, replicas=2),
        # effectiveness-first: never degrade the grid — shed instead
        online=OnlineSpec(max_batch=16, batch_deadline_us=2.0,
                          admission=True, degrade=False),
    )


def _stage1_only() -> CascadeSpec:
    return CascadeSpec(
        name="stage1_only",
        routing=RoutingSpec(algorithm=2, budget=200.0, rho_max=1 << 18,
                            hedge_deadline=0.5, late_rho=4096,
                            calibrate=True),
        stage2=Stage2Spec(enabled=False, k_serve=128, t_final=10),
        deploy=DeploySpec(n_shards=1, replicas=2),
    )


def _fault_tolerant() -> CascadeSpec:
    # bound check (paper_scale, ms): reissue = 0.45*B1 + (3 + 4096*0.0064)
    # + retry(2*25) = 90 + 29.2 + 50 = 169.2 < B1 after the Stage-2
    # reservation — the hard guarantee still collapses to the budget with
    # the whole retry cascade charged in (see SchedulerConfig.retry_us)
    return CascadeSpec(
        name="fault_tolerant",
        routing=RoutingSpec(algorithm=2, budget=200.0, rho_max=1 << 18,
                            hedge_deadline=0.45, late_rho=4096,
                            adapt_every=1, calibrate=True,
                            failover_timeout=25.0, max_retries=2),
        stage2=Stage2Spec(enabled=True, k_serve=128, t_final=10),
        deploy=DeploySpec(n_shards=4, replicas=3),
        online=OnlineSpec(max_batch=32, batch_deadline_us=5.0,
                          admission=True, degrade=True),
    )


def _cached() -> CascadeSpec:
    return CascadeSpec(
        name="cached",
        # adapt_every=0: online threshold adaptation would rewrite the
        # route signature embedded in every cache key (stale hits are
        # impossible either way, but churning keys wastes the cache)
        routing=RoutingSpec(algorithm=2, budget=200.0, rho_max=1 << 18,
                            hedge_deadline=0.5, late_rho=4096,
                            adapt_every=0, calibrate=True),
        stage2=Stage2Spec(enabled=True, k_serve=128, t_final=10),
        deploy=DeploySpec(n_shards=1, replicas=2),
        online=OnlineSpec(max_batch=32, batch_deadline_us=5.0,
                          admission=True, degrade=True),
        cache=CacheSpec(enabled=True),
    )


def _live_ingest() -> CascadeSpec:
    # the delta capacities are budget-sized, not storage-sized: the
    # worst-case delta scan (delta_time(8192 postings) + the dense tiles
    # over 256 capacity docs when dense is on) is charged into EVERY
    # query's bound, so an oversized delta would push the full-service
    # floor past the 200 ms budget and shed everything.  delta_docs must
    # also stay >= k_serve so the delta pseudo-shard can fill a top-k.
    # adapt_every=0 for the same reason as `cached`: ingest bumps the
    # cache epoch on every applied batch, and stable route signatures
    # keep the event log replayable bit-for-bit.
    return CascadeSpec(
        name="live_ingest",
        routing=RoutingSpec(algorithm=2, budget=200.0, rho_max=1 << 18,
                            hedge_deadline=0.5, late_rho=4096,
                            adapt_every=0, calibrate=True),
        stage2=Stage2Spec(enabled=True, k_serve=128, t_final=10),
        deploy=DeploySpec(n_shards=1, replicas=2),
        online=OnlineSpec(max_batch=32, batch_deadline_us=5.0,
                          admission=True, degrade=True),
        ingest=IngestSpec(enabled=True, delta_docs=256, delta_postings=8192,
                          feed_qps=8.0, feed_batch=16,
                          merge_threshold=0.6),
    )


def _hybrid_fusion() -> CascadeSpec:
    # theta bands sit inside the observed top-1 dense score range of both
    # embedding sources (~0.23–0.58 on the experiment collection), so all
    # five routes — lexical, dense, fused, theta-skip, theta-fallback —
    # actually carry traffic.  adapt_every=0 for the same reason as
    # `cached`: the resolved modality is part of the route signature.
    return CascadeSpec(
        name="hybrid_fusion",
        routing=RoutingSpec(algorithm=2, budget=200.0, rho_max=1 << 18,
                            hedge_deadline=0.5, late_rho=4096,
                            adapt_every=0, calibrate=True),
        stage2=Stage2Spec(enabled=True, k_serve=128, t_final=10),
        deploy=DeploySpec(n_shards=1, replicas=2),
        online=OnlineSpec(max_batch=32, batch_deadline_us=5.0,
                          admission=True, degrade=True),
        dense=DenseSpec(enabled=True, source="auto", fuse_band=0.25,
                        theta_high=0.45, theta_low=0.25),
        fusion=FusionSpec(method="rrf"),
    )


PRESETS = {
    "paper_200ms": _paper_200ms,
    "throughput": _throughput,
    "quality": _quality,
    "stage1_only": _stage1_only,
    "fault_tolerant": _fault_tolerant,
    "cached": _cached,
    "live_ingest": _live_ingest,
    "hybrid_fusion": _hybrid_fusion,
}


def get_preset(name: str, **overrides) -> CascadeSpec:
    """A fresh validated spec for a named operating point.

    ``overrides`` replace top-level ``CascadeSpec`` fields (already-built
    node values, e.g. ``deploy=DeploySpec(n_shards=4)``).
    """
    try:
        spec = PRESETS[name]()
    except KeyError:
        raise ValueError(f"unknown preset {name!r}; "
                         f"available: {sorted(PRESETS)}") from None
    if overrides:
        spec = dataclasses.replace(spec, **overrides)
    return spec.validate()
