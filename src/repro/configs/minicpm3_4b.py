"""MiniCPM3-4B: multi-head latent attention (MLA), 62 layers.
[hf:openbmb/MiniCPM3-4B]"""

from repro.models.attention import MLAConfig
from repro.models.transformer import LMConfig

FAMILY = "lm"

CONFIG = LMConfig(
    name="minicpm3-4b", n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab=73448, head_dim=64, attention="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, qk_nope_dim=64,
                  qk_rope_dim=32, v_head_dim=64),
    dtype="bfloat16", remat="full",
)

REDUCED = LMConfig(
    name="minicpm3-4b-reduced", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=4, d_ff=256, vocab=512, head_dim=32, attention="mla",
    mla=MLAConfig(q_lora_rank=48, kv_lora_rank=32, qk_nope_dim=16,
                  qk_rope_dim=8, v_head_dim=32),
    dtype="float32", remat="none",
)
