"""Attention implementations for the LM family.

* ``chunked_attention`` — production jnp path: lax.scan over KV chunks with
  online softmax, so peak logits memory is (B, H, S, chunk) instead of
  (B, H, S, S).  This is what the multi-pod dry-run lowers (Pallas TPU
  kernels can't lower on the host-CPU dry-run platform); on real TPU the
  dispatcher swaps in `repro.kernels.flash_attention`.
* ``gqa_decode`` — single-token decode against a (possibly sequence-
  sharded) KV cache; lowers to flash_decode on TPU.
* ``mla_*`` — DeepSeek/MiniCPM3-style multi-head latent attention: queries
  and KV are low-rank compressed; the decode path uses the absorbed-matmul
  form so the cache stays in the 288-dim latent space.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common

NEG_INF = -1e30


def _repeat_kv(k, n_heads):
    group = n_heads // k.shape[1]
    if group == 1:
        return k
    return jnp.repeat(k, group, axis=1)


def chunked_attention(q, k, v, *, causal: bool, chunk: int = 512,
                      scale: float | None = None, unroll: bool = False):
    """q: (B, H, Sq, D); k/v: (B, Hkv, Sk, D). Online-softmax over KV chunks."""
    b, h, sq, d = q.shape
    sk, dv = k.shape[2], v.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    chunk = min(chunk, sk)
    n_chunks = sk // chunk
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    qf = q.astype(jnp.float32) * scale

    def step(carry, inputs):
        m, l, acc = carry
        kc, vc, base = inputs
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kc.astype(jnp.float32))
        if causal:
            rows = jnp.arange(sq)[:, None]
            cols = base + jnp.arange(chunk)[None, :]
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vc.astype(jnp.float32))
        return (m_new, l, acc), None

    ks = k.reshape(b, h, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(b, h, n_chunks, chunk, dv).transpose(2, 0, 1, 3, 4)
    bases = jnp.arange(n_chunks) * chunk
    init = (jnp.full((b, h, sq), NEG_INF, jnp.float32),
            jnp.zeros((b, h, sq), jnp.float32),
            jnp.zeros((b, h, sq, dv), jnp.float32))
    # remat each chunk: backward recomputes the (sq, chunk) score tile
    # instead of saving it — matching what the flash kernel does on TPU
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(step), init,
                                  (ks, vs, bases),
                                  unroll=n_chunks if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def gqa_decode(q, k_cache, v_cache, kv_len, scale: float | None = None):
    """q: (B, H, D); caches (B, Hkv, S, D); kv_len (B,) -> (B, H, D)."""
    b, h, d = q.shape
    s = k_cache.shape[2]
    scale = scale if scale is not None else d ** -0.5
    k = _repeat_kv(k_cache, h).astype(jnp.float32)
    v = _repeat_kv(v_cache, h).astype(jnp.float32)
    logits = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), k) * scale
    pos = jnp.arange(s)
    logits = jnp.where(pos[None, None, :] < kv_len[:, None, None], logits,
                       NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", w, v).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention)
# ---------------------------------------------------------------------------

class MLAConfig(NamedTuple):
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64


def mla_params(pf, prefix: str, d_model: int, n_heads: int, cfg: MLAConfig):
    h, qn, qr, vd = n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wdq": pf.dense(f"{prefix}/wdq", (d_model, cfg.q_lora_rank),
                        ("embed", "qk")),
        "q_norm": pf.ones(f"{prefix}/q_norm", (cfg.q_lora_rank,), ("qk",)),
        "wuq": pf.dense(f"{prefix}/wuq", (cfg.q_lora_rank, h * (qn + qr)),
                        ("qk", "heads")),
        "wdkv": pf.dense(f"{prefix}/wdkv", (d_model, cfg.kv_lora_rank + qr),
                         ("embed", "qk")),
        "kv_norm": pf.ones(f"{prefix}/kv_norm", (cfg.kv_lora_rank,), ("qk",)),
        "wuk": pf.dense(f"{prefix}/wuk", (cfg.kv_lora_rank, h * qn),
                        ("qk", "heads")),
        "wuv": pf.dense(f"{prefix}/wuv", (cfg.kv_lora_rank, h * vd),
                        ("qk", "heads")),
        "wo": pf.dense(f"{prefix}/wo", (h * vd, d_model), ("heads", "embed")),
    }


def mla_forward(p, x, positions, n_heads: int, cfg: MLAConfig,
                causal: bool = True, unroll: bool = False):
    """Training/prefill MLA: decompress K/V per head, chunked attention."""
    b, s, dm = x.shape
    h, qn, qr, vd = n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    cq = common.rms_norm(x @ p["wdq"], p["q_norm"])
    q = (cq @ p["wuq"]).reshape(b, s, h, qn + qr)
    q_nope, q_rope = q[..., :qn], q[..., qn:]
    q_rope = common.rope(q_rope.transpose(0, 2, 1, 3),
                         positions[:, None, :]).transpose(0, 2, 1, 3)

    dkv = x @ p["wdkv"]
    c_kv = common.rms_norm(dkv[..., :cfg.kv_lora_rank], p["kv_norm"])
    k_rope = common.rope(dkv[..., cfg.kv_lora_rank:][:, None, :, :],
                         positions[:, None, :])          # (B, 1, S, qr) shared
    k_nope = (c_kv @ p["wuk"]).reshape(b, s, h, qn)
    v = (c_kv @ p["wuv"]).reshape(b, s, h, vd)

    qh = jnp.concatenate([q_nope, q_rope], axis=-1).transpose(0, 2, 1, 3)
    kh = jnp.concatenate(
        [k_nope.transpose(0, 2, 1, 3),
         jnp.broadcast_to(k_rope, (b, h, s, qr))], axis=-1)
    vh = v.transpose(0, 2, 1, 3)
    scale = (qn + qr) ** -0.5
    out = chunked_attention(qh, kh, vh, causal=causal, scale=scale,
                            unroll=unroll)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * vd)
    return out @ p["wo"]


def mla_decode(p, x, c_cache, rope_cache, kv_len, n_heads: int,
               cfg: MLAConfig, q_pos=None):
    """Absorbed-matmul decode: queries are projected into the KV latent space
    so attention runs against the compressed cache directly.

    x: (B, d_model) current token; c_cache: (B, S, kv_rank);
    rope_cache: (B, S, qk_rope_dim); kv_len: (B,) valid cache entries
    (including the current token); q_pos: (B,) RoPE position of the query
    (defaults to kv_len - 1, the current token's position).
    """
    b, dm = x.shape
    h, qn, qr, vd = n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    s = c_cache.shape[1]
    pos = (q_pos if q_pos is not None else kv_len - 1).astype(jnp.float32)

    cq = common.rms_norm(x @ p["wdq"], p["q_norm"])
    q = (cq @ p["wuq"]).reshape(b, h, qn + qr)
    q_nope, q_rope = q[..., :qn], q[..., qn:]
    q_rope = common.rope(q_rope[:, :, None, :], pos[:, None, None])[:, :, 0]

    # absorb W_uk into the query: q_lat (B, H, r)
    wuk = p["wuk"].reshape(r, h, qn)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope, wuk)

    logits = (jnp.einsum("bhr,bsr->bhs", q_lat.astype(jnp.float32),
                         c_cache.astype(jnp.float32))
              + jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32),
                           rope_cache.astype(jnp.float32)))
    logits = logits * ((qn + qr) ** -0.5)
    mask = jnp.arange(s)[None, None, :] < kv_len[:, None, None]
    w = jax.nn.softmax(jnp.where(mask, logits, NEG_INF), axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", w, c_cache.astype(jnp.float32))
    # absorb W_uv on the way out
    wuv = p["wuv"].reshape(r, h, vd)
    out = jnp.einsum("bhr,rhv->bhv", ctx.astype(x.dtype), wuv)
    return out.reshape(b, h * vd) @ p["wo"]
