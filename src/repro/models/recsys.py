"""RecSys architectures: DeepFM, xDeepFM (CIN), two-tower retrieval, BERT4Rec.

Huge row-sharded embedding tables + a small interaction network — the
lookup is the hot path (see `repro.models.embedding`).  The two-tower
retrieval arch is where the paper's technique applies *directly*: its
``retrieval_cand`` shape is first-stage candidate generation, and
``anytime_retrieval`` scores popularity-ordered candidate tiles under a
ρ-style budget with a per-query predicted k — the JASS mechanism
transplanted to dense retrieval (see DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map

from repro.models import common, embedding
from repro.models.attention import chunked_attention


# ---------------------------------------------------------------------------
# configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str                      # deepfm | xdeepfm | two_tower | bert4rec
    n_sparse: int = 39
    embed_dim: int = 10
    rows_per_field: int = 1_000_000
    mlp: tuple = (400, 400, 400)
    cin_layers: tuple = ()
    # two-tower
    tower_mlp: tuple = (1024, 512, 256)
    n_users: int = 8_000_000
    n_items: int = 2_000_000
    n_user_feats: int = 16
    n_item_feats: int = 8
    # bert4rec
    seq_len: int = 200
    n_blocks: int = 2
    n_heads: int = 2
    dtype: str = "float32"
    cost_exact: bool = False

    @property
    def jdtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def total_rows(self) -> int:
        if self.kind == "two_tower":
            return self.n_users + self.n_items
        if self.kind == "bert4rec":
            return self.n_items
        return self.n_sparse * self.rows_per_field

    def param_count(self) -> int:
        p, _ = init(self, abstract=True)
        return sum(int(jnp.prod(jnp.asarray(l.shape)))
                   for l in jax.tree.leaves(p))


def _mlp_params(pf, prefix, dims):
    # interaction nets are tiny (≤ a few 100k params) — replicate; the model
    # axis is reserved for the embedding-table rows
    ps = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        ps[f"w{i}"] = pf.dense(f"{prefix}/w{i}", (a, b), (None, None))
        ps[f"b{i}"] = pf.zeros(f"{prefix}/b{i}", (b,), (None,))
    return ps


def _mlp(ps, x, act=jax.nn.relu, last_act=False):
    n = len([k for k in ps if k.startswith("w")])
    for i in range(n):
        x = x @ ps[f"w{i}"] + ps[f"b{i}"]
        if i < n - 1 or last_act:
            x = act(x)
    return x


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init(c: RecsysConfig, rng=None, abstract: bool = False):
    pf = common.ParamFactory(rng if rng is not None else jax.random.PRNGKey(0),
                             abstract=abstract, dtype=c.jdtype)
    d = c.embed_dim
    if c.kind in ("deepfm", "xdeepfm"):
        rows = c.n_sparse * c.rows_per_field
        params = {
            "table": pf.dense("table", (rows, d), ("rows", None), scale=0.01),
            "linear": pf.dense("linear", (rows, 1), ("rows", None), scale=0.01),
            "mlp": _mlp_params(pf, "mlp",
                               (c.n_sparse * d,) + c.mlp + (1,)),
        }
        if c.kind == "xdeepfm":
            cin = {}
            hk = c.n_sparse
            for i, h_next in enumerate(c.cin_layers):
                cin[f"w{i}"] = pf.dense(f"cin/w{i}", (hk * c.n_sparse, h_next),
                                        (None, None), scale=0.05)
                hk = h_next
            params["cin"] = cin
            params["cin_out"] = pf.dense(
                "cin_out", (sum(c.cin_layers), 1), (None, None))
        return params, pf.names

    if c.kind == "two_tower":
        d = c.tower_mlp[-1]
        de = 256
        params = {
            "user_table": pf.dense("user_table", (c.n_users, de),
                                   ("rows", None), scale=0.01),
            "item_table": pf.dense("item_table", (c.n_items, de),
                                   ("rows", None), scale=0.01),
            "user_mlp": _mlp_params(pf, "user_mlp", (de,) + c.tower_mlp),
            "item_mlp": _mlp_params(pf, "item_mlp", (de,) + c.tower_mlp),
        }
        return params, pf.names

    if c.kind == "bert4rec":
        d = c.embed_dim
        padded_items = ((c.n_items + 2 + 255) // 256) * 256
        params = {
            "item_embed": pf.dense("item_embed", (padded_items, d),
                                   ("rows", None), scale=0.02),
            "pos_embed": pf.dense("pos_embed", (c.seq_len, d), (None, None),
                                  scale=0.02),
            "blocks": common.stack_layer_params(
                lambda f, pre: {
                    "wq": f.dense(f"{pre}/wq", (d, d), (None, "heads")),
                    "wk": f.dense(f"{pre}/wk", (d, d), (None, "heads")),
                    "wv": f.dense(f"{pre}/wv", (d, d), (None, "heads")),
                    "wo": f.dense(f"{pre}/wo", (d, d), ("heads", None)),
                    "w1": f.dense(f"{pre}/w1", (d, 4 * d), (None, "ffn")),
                    "b1": f.zeros(f"{pre}/b1", (4 * d,), ("ffn",)),
                    "w2": f.dense(f"{pre}/w2", (4 * d, d), ("ffn", None)),
                    "b2": f.zeros(f"{pre}/b2", (d,), (None,)),
                    "ln1": f.ones(f"{pre}/ln1", (d,), (None,)),
                    "ln2": f.ones(f"{pre}/ln2", (d,), (None,)),
                }, pf, c.n_blocks, "blocks"),
            "final_ln": pf.ones("final_ln", (d,), (None,)),
        }
        return params, pf.names
    raise ValueError(c.kind)


# ---------------------------------------------------------------------------
# forwards
# ---------------------------------------------------------------------------

def _field_embed(params, c, ids):
    """ids (B, n_sparse) with per-field offsets already applied -> (B, F, D)."""
    return embedding.lookup(params["table"], ids)


def deepfm_logits(params, c: RecsysConfig, ids):
    e = _field_embed(params, c, ids)                        # (B, F, D)
    lin = jnp.sum(embedding.lookup(params["linear"], ids)[..., 0], axis=1)
    s = jnp.sum(e, axis=1)
    fm = 0.5 * jnp.sum(s * s - jnp.sum(e * e, axis=1), axis=-1)
    deep = _mlp(params["mlp"], e.reshape(e.shape[0], -1))[:, 0]
    return lin + fm + deep


def xdeepfm_logits(params, c: RecsysConfig, ids):
    e = _field_embed(params, c, ids)                        # (B, m, D)
    x0, xk = e, e
    pools = []
    for i in range(len(c.cin_layers)):
        z = jnp.einsum("bhd,bmd->bhmd", xk, x0)
        b, hk, m, d = z.shape
        xk = jnp.einsum("bnd,nh->bhd", z.reshape(b, hk * m, d),
                        params["cin"][f"w{i}"])
        pools.append(jnp.sum(xk, axis=-1))                  # (B, h)
    cin_term = (jnp.concatenate(pools, axis=-1) @ params["cin_out"])[:, 0]
    lin = jnp.sum(embedding.lookup(params["linear"], ids)[..., 0], axis=1)
    deep = _mlp(params["mlp"], e.reshape(e.shape[0], -1))[:, 0]
    return lin + cin_term + deep


def ctr_loss(params, c: RecsysConfig, batch):
    logit_fn = deepfm_logits if c.kind == "deepfm" else xdeepfm_logits
    logits = logit_fn(params, c, batch["ids"])
    y = batch["label"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def tower_embed(params, c: RecsysConfig, table_key, mlp_key, ids, mask):
    e = embedding.embedding_bag(params[table_key], ids, mask, mode="mean")
    z = _mlp(params[mlp_key], e, last_act=False)
    return z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True), 1e-6)


def two_tower_loss(params, c: RecsysConfig, batch, temp: float = 20.0):
    """In-batch sampled softmax with logQ correction (Yi et al. RecSys'19)."""
    u = tower_embed(params, c, "user_table", "user_mlp",
                    batch["user_ids"], batch["user_mask"])
    i = tower_embed(params, c, "item_table", "item_mlp",
                    batch["item_ids"], batch["item_mask"])
    logits = (u @ i.T) * temp - batch["log_q"][None, :]
    labels = jnp.arange(u.shape[0])
    return common.cross_entropy(logits[:, None, :], labels[:, None],
                                u.shape[0])


def retrieval_scores(params, c: RecsysConfig, query_emb, cand_emb):
    """Score one query against the candidate corpus. cand_emb is the
    precomputed item-tower output (n_cand, d), sharded over "candidates"."""
    return cand_emb @ query_emb[0]


def streaming_topk(q_emb, cand_emb, k: int, tile: int = 16384):
    """Top-k of ``q_emb @ cand_embᵀ`` without materializing the full score
    matrix: lax.scan over candidate tiles with a running (B, k) top-k merge.

    Peak transient is (B, tile) instead of (B, n_cand) — the difference
    between 2 TB and 1 GB at serve_bulk scale (EXPERIMENTS.md §Perf).
    q_emb: (B, D); cand_emb: (N, D), N % tile == 0.  Returns (vals, idx).
    """
    b, d = q_emb.shape
    n = cand_emb.shape[0]
    tile = min(tile, n)
    n_pad = (-n) % tile
    if n_pad:
        cand_emb = jnp.concatenate(
            [cand_emb, jnp.zeros((n_pad, d), cand_emb.dtype)], axis=0)
    n_tiles = (n + n_pad) // tile
    tiles = cand_emb.reshape(n_tiles, tile, d)
    bases = jnp.arange(n_tiles, dtype=jnp.int32) * tile

    def step(carry, inp):
        best_v, best_i = carry
        emb, base = inp
        s = q_emb @ emb.T                                   # (B, tile)
        idx = base + jnp.arange(tile, dtype=jnp.int32)
        s = jnp.where(idx[None, :] < n, s, -jnp.inf)        # mask padding
        v, i = jax.lax.top_k(s, min(k, tile))
        i = jnp.take(idx, i)
        v2 = jnp.concatenate([best_v, v], axis=1)
        i2 = jnp.concatenate([best_i, i], axis=1)
        v3, p = jax.lax.top_k(v2, k)
        return (v3, jnp.take_along_axis(i2, p, axis=1)), None

    init = (jnp.full((b, k), -jnp.inf, q_emb.dtype),
            jnp.zeros((b, k), jnp.int32))
    (vals, idx), _ = jax.lax.scan(step, init, (tiles, bases))
    return vals, idx


def sharded_streaming_topk(q_emb, cand_emb, k: int, tile: int = 8192):
    """Distributed retrieval top-k: each "model" shard streams its local
    candidate rows (streaming_topk), then one k-sized all-gather + merge —
    the same local-topk/merge pattern as the paper's ISN aggregation.

    Collective payload: B·k·(score,id) per shard instead of per-tile score
    gathers (ms vs hundreds of ms at serve_bulk scale, §Perf)."""
    from repro.models import common as _c
    mesh = _c.get_abstract_mesh_or_none()
    sizes = dict(mesh.shape) if mesh is not None else {}
    mw = sizes.get("model", 1)
    b, n = q_emb.shape[0], cand_emb.shape[0]
    batch_axes = tuple(a for a in ("pod", "data") if a in sizes)
    bw = 1
    for a in batch_axes:
        bw *= sizes[a]
    if mesh is None or mw <= 1 or n % mw or (b % bw if bw else 0):
        return streaming_topk(q_emb, cand_emb, k, tile)
    n_local = n // mw

    def local_fn(q, cand_local):
        v, i = streaming_topk(q, cand_local, k, tile)
        i = i + jax.lax.axis_index("model") * n_local
        av = jax.lax.all_gather(v, "model", axis=1, tiled=True)
        ai = jax.lax.all_gather(i, "model", axis=1, tiled=True)
        v2, p = jax.lax.top_k(av, k)
        return v2, jnp.take_along_axis(ai, p, axis=1)

    from jax.sharding import PartitionSpec as P
    qspec = P(batch_axes if batch_axes else None, None)
    return shard_map(local_fn, mesh=mesh,
                         in_specs=(qspec, P("model", None)),
                         out_specs=(qspec, qspec),
                         check_rep=False)(q_emb, cand_emb)


def anytime_retrieval(query_emb, cand_emb, prior_order_len: jnp.ndarray,
                      k: int):
    """The paper's anytime budget transplanted to dense retrieval.

    cand_emb must be stored in *popularity (impact) order*; the Stage-0
    predictor supplies a per-query budget ``prior_order_len`` (#candidates
    to score).  Scoring beyond the budget is masked, so worst-case latency
    is bounded exactly like JASS's ρ cap.
    """
    n = cand_emb.shape[0]
    scores = cand_emb @ query_emb[0]
    live = jnp.arange(n) < prior_order_len
    scores = jnp.where(live, scores, -jnp.inf)
    return jax.lax.top_k(scores, k)


def bert4rec_logits(params, c: RecsysConfig, items):
    """items: (B, S) -> (B, S, n_items+2) full-vocab logits (small scales /
    serving; training uses the sampled-softmax loss below)."""
    x = bert4rec_hidden(params, c, items)
    return x @ params["item_embed"].T


def bert4rec_hidden(params, c: RecsysConfig, items):
    """items: (B, S) -> final hidden states (B, S, D)."""
    b, s = items.shape
    d = c.embed_dim
    x = embedding.lookup(params["item_embed"], items) + params["pos_embed"][None]

    def block(x, bp):
        h = common.rms_norm(x, bp["ln1"])
        q = (h @ bp["wq"]).reshape(b, s, c.n_heads, -1).transpose(0, 2, 1, 3)
        kk = (h @ bp["wk"]).reshape(b, s, c.n_heads, -1).transpose(0, 2, 1, 3)
        v = (h @ bp["wv"]).reshape(b, s, c.n_heads, -1).transpose(0, 2, 1, 3)
        o = chunked_attention(q, kk, v, causal=False)
        o = o.transpose(0, 2, 1, 3).reshape(b, s, d)
        x = x + o @ bp["wo"]
        h = common.rms_norm(x, bp["ln2"])
        x = x + common.gelu_mlp(h, bp["w1"], bp["b1"], bp["w2"], bp["b2"])
        return x, None

    x, _ = jax.lax.scan(block, x, params["blocks"],
                        unroll=c.n_blocks if c.cost_exact else 1)
    return common.rms_norm(x, params["final_ln"])


def bert4rec_loss(params, c: RecsysConfig, batch):
    """Masked-item training with sampled softmax (full 1M-item softmax per
    masked position is infeasible; BERT4Rec evaluates with sampled negatives
    as well).  batch: items (B, S); positions (B, M) masked slots;
    candidates (C,) shared negative pool (includes the true items);
    label_idx (B, M) index of the true item within candidates."""
    h = bert4rec_hidden(params, c, batch["items"])           # (B, S, D)
    hm = jnp.take_along_axis(
        h, batch["positions"][..., None], axis=1)            # (B, M, D)
    cand = embedding.lookup(params["item_embed"], batch["candidates"])
    logits = jnp.einsum("bmd,cd->bmc", hm, cand)
    return common.cross_entropy(logits, batch["label_idx"],
                                batch["candidates"].shape[0])
