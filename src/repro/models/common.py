"""Shared model-building blocks: init helpers, norms, RoPE, logical sharding.

All models are functional (pure init/apply), pytree-parameterized, and carry
their sharding intent through *logical axis names* resolved against per-run
rules — the standard MaxText-style pattern, implemented minimally:

    dense(..., names=("embed", "ffn"))       # annotate
    rules = {"embed": None, "ffn": "model"}  # resolve per arch × shape
    pspec = resolve_pspec(names, rules)      # -> PartitionSpec

Resolving at jit boundary (in_shardings / with_sharding_constraint) is what
the dry-run exercises on the production meshes.
"""

from __future__ import annotations

import contextlib
import math
import threading
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Pytree = Any

# ---------------------------------------------------------------------------
# abstract-mesh compat (jax 0.4.37)
# ---------------------------------------------------------------------------

# jax.sharding.{get,use}_abstract_mesh only exist on jax >= 0.5.  The
# thread-local fallback preserves the contract the model stack relies on:
# inside ``use_abstract_mesh(m)``, ``get_abstract_mesh()`` returns ``m`` —
# including during jit tracing, which runs on the calling thread.
_MESH_STACK = threading.local()


def _fallback_get_abstract_mesh():
    stack = getattr(_MESH_STACK, "stack", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def _fallback_use_abstract_mesh(mesh):
    stack = getattr(_MESH_STACK, "stack", None)
    if stack is None:
        stack = _MESH_STACK.stack = []
    stack.append(mesh)
    try:
        yield mesh
    finally:
        stack.pop()


get_abstract_mesh = getattr(jax.sharding, "get_abstract_mesh",
                            _fallback_get_abstract_mesh)
use_abstract_mesh = getattr(jax.sharding, "use_abstract_mesh",
                            _fallback_use_abstract_mesh)

# ---------------------------------------------------------------------------
# logical sharding
# ---------------------------------------------------------------------------

# default rules for a ("data", "model") mesh; "pod" extends data-parallelism
DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "qk": None,
    "ffn": "model",
    "vocab": "model",
    "experts": "model",
    "seq": None,
    "kv_seq": None,
    "rows": "model",       # embedding-table rows (recsys)
    "cols": None,
    "nodes": ("pod", "data", "model"),   # flat GNN sharding
    "edges": ("pod", "data", "model"),
    "candidates": "model",
    "stack": None,         # scan-over-layers leading axis
}


def resolve_pspec(names: tuple, rules: dict, mesh=None) -> P:
    """Map logical axis names to a PartitionSpec under `rules`.

    Axes whose mesh axis is absent from `mesh` (e.g. "pod" on the single-pod
    mesh) are dropped from the spec.
    """
    mesh_axes = set(mesh.axis_names) if mesh is not None else None
    used: set = set()

    def ok(ax):
        return (mesh_axes is None or ax in mesh_axes) and ax not in used

    spec = []
    for n in names:
        r = rules.get(n, None) if n is not None else None
        if r is None:
            spec.append(None)
        elif isinstance(r, tuple):
            kept = tuple(a for a in r if ok(a))
            used.update(kept)
            spec.append(kept if len(kept) > 1 else (kept[0] if kept else None))
        else:
            if ok(r):
                used.add(r)
                spec.append(r)
            else:
                spec.append(None)
    return P(*spec)


def tree_pspecs(names_tree: Pytree, rules: dict, mesh=None) -> Pytree:
    return jax.tree.map(lambda names: resolve_pspec(names, rules, mesh),
                        names_tree, is_leaf=lambda x: isinstance(x, tuple))


def fit_spec_to_shape(spec: P, shape: tuple, mesh) -> P:
    """Drop sharding on dims the mesh axes don't divide evenly."""
    sizes = dict(mesh.shape)
    fixed = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        axes = (ax,) if isinstance(ax, str) else (ax or ())
        ways = 1
        for a in axes:
            ways *= sizes[a]
        fixed.append(ax if ways > 0 and dim % ways == 0 else None)
    return P(*fixed)


def constrain(x, names: tuple, rules: dict, mesh=None):
    """with_sharding_constraint via logical names (no-op when no mesh is in
    scope, e.g. single-device smoke tests)."""
    m = mesh or get_abstract_mesh_or_none()
    if m is None:
        return x
    spec = fit_spec_to_shape(resolve_pspec(names, rules, m), x.shape, m)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(m, spec))


def get_abstract_mesh_or_none():
    m = get_abstract_mesh()
    return m if m is not None and m and m.axis_names else None


# ---------------------------------------------------------------------------
# parameter construction
# ---------------------------------------------------------------------------

class ParamFactory:
    """Creates parameters and records their logical sharding names.

    `abstract=True` produces ShapeDtypeStructs (for .lower()/dry-run) so no
    multi-GB model is ever materialized on the host.
    """

    def __init__(self, rng, abstract: bool = False, dtype=jnp.float32):
        self._rng = rng
        self.abstract = abstract
        self.dtype = dtype
        self.names: dict = {}

    def _next(self):
        self._rng, sub = jax.random.split(self._rng)
        return sub

    def dense(self, path: str, shape: tuple, names: tuple, scale=None):
        assert len(shape) == len(names), (path, shape, names)
        self.names[path] = names
        if self.abstract:
            return jax.ShapeDtypeStruct(shape, self.dtype)
        fan_in = shape[0] if len(shape) >= 1 else 1
        scale = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(self._next(), shape, self.dtype) * scale)

    def zeros(self, path: str, shape: tuple, names: tuple):
        self.names[path] = names
        if self.abstract:
            return jax.ShapeDtypeStruct(shape, self.dtype)
        return jnp.zeros(shape, self.dtype)

    def ones(self, path: str, shape: tuple, names: tuple):
        self.names[path] = names
        if self.abstract:
            return jax.ShapeDtypeStruct(shape, self.dtype)
        return jnp.ones(shape, self.dtype)


def names_tree_of(params: Pytree, names: dict) -> Pytree:
    """Reconstruct a names-tree congruent with `params`.

    Relies on the convention that the `path` string passed to the factory
    equals the '/'-joined nesting keys of the leaf in the returned tree.
    """
    flat, treedef = jax.tree.flatten_with_path(params)
    out = []
    for path, _ in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append(names[key])
    return jax.tree.unflatten(treedef, out)


class _StackedFactory:
    """Wraps a ParamFactory so every leaf gets a leading (n_layers,) axis —
    the layout lax.scan-over-layers consumes."""

    def __init__(self, pf: ParamFactory, n_layers: int):
        self._pf = pf
        self._n = n_layers
        self.abstract = pf.abstract
        self.dtype = pf.dtype

    def dense(self, path, shape, names, scale=None):
        return self._pf.dense(path, (self._n,) + shape, ("stack",) + names,
                              scale)

    def zeros(self, path, shape, names):
        return self._pf.zeros(path, (self._n,) + shape, ("stack",) + names)

    def ones(self, path, shape, names):
        return self._pf.ones(path, (self._n,) + shape, ("stack",) + names)


def stack_layer_params(factory_fn: Callable, pf: ParamFactory,
                       n_layers: int, prefix: str) -> dict:
    """Build per-layer params with a leading stacked axis for lax.scan."""
    return factory_fn(_StackedFactory(pf, n_layers), prefix)


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x, gamma, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def rope(x, positions, theta: float = 10000.0):
    """Rotary embedding. x: (..., S, D even); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = x @ w_gate
    u = x @ w_up
    return (jax.nn.silu(g) * u) @ w_down


def gelu_mlp(x, w1, b1, w2, b2):
    return jax.nn.gelu(x @ w1 + b1) @ w2 + b2


def cross_entropy(logits, labels, vocab: int):
    """Mean token cross-entropy, fp32, ignoring labels < 0.

    When the logits dim is padded beyond `vocab` (vocab-axis sharding
    padding), the padded slots are masked out of the partition function."""
    logits = logits.astype(jnp.float32)
    if logits.shape[-1] > vocab:
        pad_mask = jnp.arange(logits.shape[-1]) < vocab
        logits = jnp.where(pad_mask, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    loss = lse - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(loss * mask) / jnp.maximum(jnp.sum(mask), 1.0)
