"""DimeNet (directional message passing, arXiv:2003.03123) in JAX, plus the
neighbor sampler the ``minibatch_lg`` shape requires.

Message passing is built on ``jax.ops.segment_sum`` over explicit edge /
triplet index arrays (JAX's sparse story is BCOO-only, so scatter-reduce
over an edge list IS the system).  The three kernel regimes of the GNN pool
show up as:

* edge gather + segment reduce      (embedding + output blocks)
* triplet gather (k→j→i) + bilinear (interaction blocks — DimeNet's core)
* radial/spherical basis evaluation (Bessel + angular cosine basis)

For non-geometric graphs (cora/ogbn-products cells) positions are synthetic
(`input_specs` supplies them) — DimeNet requires distances/angles; noted in
DESIGN.md §Arch-applicability.  Triplet counts on mega-graphs are capped by
``triplet_budget`` (Σ deg² ≈ 1.5 B on ogbn-products is infeasible and the
budget is itself an anytime knob, the ρ-analogue for this family).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import common


@dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    d_feat: int = 16            # input node-feature dim
    cutoff: float = 5.0
    d_out: int = 1
    dtype: str = "float32"
    cost_exact: bool = False

    @property
    def jdtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# basis functions
# ---------------------------------------------------------------------------

def bessel_rbf(d, n_radial: int, cutoff: float):
    """sin(nπ d/c) / d radial Bessel basis. d: (E,) -> (E, n_radial)."""
    d = jnp.maximum(d, 1e-6)
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    x = d[:, None] / cutoff
    return jnp.sqrt(2.0 / cutoff) * jnp.sin(n * math.pi * x) / d[:, None]


def angular_sbf(d_kj, angle, n_spherical: int, n_radial: int, cutoff: float):
    """Simplified spherical basis: radial Bessel ⊗ cos(l·α).
    -> (T, n_spherical * n_radial)."""
    rad = bessel_rbf(d_kj, n_radial, cutoff)                  # (T, R)
    l = jnp.arange(n_spherical, dtype=jnp.float32)
    ang = jnp.cos(l[None, :] * angle[:, None])                # (T, L)
    return (rad[:, None, :] * ang[:, :, None]).reshape(
        d_kj.shape[0], n_spherical * n_radial)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def _mlp_params(pf, prefix, dims, names_in="embed", names_out="ffn"):
    ps = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        ps[f"w{i}"] = pf.dense(f"{prefix}/w{i}", (a, b), (None, None))
        ps[f"b{i}"] = pf.zeros(f"{prefix}/b{i}", (b,), (None,))
    return ps


def _mlp(ps, x, act=jax.nn.silu, last_act=False):
    n = len([k for k in ps if k.startswith("w")])
    for i in range(n):
        x = x @ ps[f"w{i}"] + ps[f"b{i}"]
        if i < n - 1 or last_act:
            x = act(x)
    return x


def init(c: DimeNetConfig, rng=None, abstract: bool = False):
    pf = common.ParamFactory(rng if rng is not None else jax.random.PRNGKey(0),
                             abstract=abstract, dtype=c.jdtype)
    h, sb = c.d_hidden, c.n_spherical * c.n_radial
    params = {
        "feat_proj": pf.dense("feat_proj", (c.d_feat, h), (None, None)),
        "rbf_proj": pf.dense("rbf_proj", (c.n_radial, h), (None, None)),
        "embed_mlp": _mlp_params(pf, "embed_mlp", (3 * h, h, h)),
        "blocks": common.stack_layer_params(
            lambda f, pre: {
                "w_msg": f.dense(f"{pre}/w_msg", (h, h), (None, None)),
                "rbf_gate": f.dense(f"{pre}/rbf_gate", (c.n_radial, h),
                                    (None, None)),
                "sbf_proj": f.dense(f"{pre}/sbf_proj", (sb, c.n_bilinear),
                                    (None, None)),
                "bilinear": f.dense(f"{pre}/bilinear",
                                    (h, c.n_bilinear, h), (None, None, None),
                                    scale=1.0 / math.sqrt(h * c.n_bilinear)),
                "update": _mlp_params(f, f"{pre}/update", (h, h, h)),
            }, pf, c.n_blocks, "blocks"),
        "out_mlp": _mlp_params(pf, "out_mlp", (h, h, c.d_out)),
    }
    return params, pf.names


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def forward(params, c: DimeNetConfig, feat, pos, edge_src, edge_dst,
            trip_kj, trip_ji, edge_mask, trip_mask, node_mask):
    """DimeNet forward.

    feat: (N, F) node features; pos: (N, 3); edge_src/dst: (E,) int32;
    trip_kj/ji: (T,) indices into edges forming (k→j, j→i) pairs;
    masks: 1.0 valid / 0.0 padding. Returns per-node outputs (N, d_out).
    """
    n, e = feat.shape[0], edge_src.shape[0]
    h = c.d_hidden

    vec = pos[edge_src] - pos[edge_dst]                     # (E, 3)
    dist = jnp.sqrt(jnp.sum(vec * vec, axis=-1) + 1e-12)
    rbf = bessel_rbf(dist, c.n_radial, c.cutoff) * edge_mask[:, None]

    # triplet geometry: angle between edge kj and ji at node j
    v1 = vec[trip_kj]
    v2 = vec[trip_ji]
    cosang = jnp.sum(v1 * v2, axis=-1) / (
        jnp.linalg.norm(v1, axis=-1) * jnp.linalg.norm(v2, axis=-1) + 1e-9)
    angle = jnp.arccos(jnp.clip(cosang, -1.0, 1.0))
    sbf = angular_sbf(dist[trip_kj], angle, c.n_spherical, c.n_radial,
                      c.cutoff) * trip_mask[:, None]

    x = feat @ params["feat_proj"]                          # (N, H)
    m = _mlp(params["embed_mlp"],
             jnp.concatenate([x[edge_src], x[edge_dst],
                              rbf @ params["rbf_proj"]], axis=-1))
    m = m * edge_mask[:, None]

    def block(m, bp):
        t = (m @ bp["w_msg"])[trip_kj]                      # (T, H)
        sp = sbf @ bp["sbf_proj"]                           # (T, B)
        t2 = jnp.einsum("th,tb,hbo->to", t, sp, bp["bilinear"])
        agg = jax.ops.segment_sum(t2 * trip_mask[:, None], trip_ji,
                                  num_segments=e)
        gate = rbf @ bp["rbf_gate"]
        m_new = m + _mlp(bp["update"], (m + agg) * gate)
        return m_new * edge_mask[:, None], None

    m, _ = jax.lax.scan(block, m, params["blocks"],
                        unroll=c.n_blocks if c.cost_exact else 1)

    node_acc = jax.ops.segment_sum(m, edge_dst, num_segments=n)
    out = _mlp(params["out_mlp"], node_acc)
    return out * node_mask[:, None]


def loss_fn(params, c: DimeNetConfig, batch):
    out = forward(params, c, batch["feat"], batch["pos"], batch["edge_src"],
                  batch["edge_dst"], batch["trip_kj"], batch["trip_ji"],
                  batch["edge_mask"], batch["trip_mask"], batch["node_mask"])
    err = (out[:, 0] - batch["target"]) * batch["node_mask"]
    return jnp.sum(err * err) / jnp.maximum(jnp.sum(batch["node_mask"]), 1.0)


def loss_fn_partitioned(params, c: DimeNetConfig, batch, psum_axes):
    """Partitioned-graph loss: runs inside shard_map with *edge-local*
    arrays (edges partitioned by middle node; triplets sampled
    intra-partition so every gather/scatter in the interaction blocks is
    shard-local).  The ONLY collective is one psum of the node aggregation
    per forward/backward — vs per-block all-gathers of the 32 GB edge
    message tensor in the pjit baseline (EXPERIMENTS.md §Perf).

    batch arrays: feat/pos/node_mask/target replicated (N, ...); edge and
    triplet arrays local slices with *global* node ids but *local* edge
    indices.
    """
    n = batch["feat"].shape[0]
    e = batch["edge_src"].shape[0]
    h = c.d_hidden
    feat, pos = batch["feat"], batch["pos"]
    edge_src, edge_dst = batch["edge_src"], batch["edge_dst"]
    trip_kj, trip_ji = batch["trip_kj"], batch["trip_ji"]
    edge_mask, trip_mask = batch["edge_mask"], batch["trip_mask"]

    vec = pos[edge_src] - pos[edge_dst]
    dist = jnp.sqrt(jnp.sum(vec * vec, axis=-1) + 1e-12)
    rbf = bessel_rbf(dist, c.n_radial, c.cutoff) * edge_mask[:, None]
    v1, v2 = vec[trip_kj], vec[trip_ji]
    cosang = jnp.sum(v1 * v2, axis=-1) / (
        jnp.linalg.norm(v1, axis=-1) * jnp.linalg.norm(v2, axis=-1) + 1e-9)
    angle = jnp.arccos(jnp.clip(cosang, -1.0, 1.0))
    sbf = angular_sbf(dist[trip_kj], angle, c.n_spherical, c.n_radial,
                      c.cutoff) * trip_mask[:, None]

    x = feat @ params["feat_proj"]
    m = _mlp(params["embed_mlp"],
             jnp.concatenate([x[edge_src], x[edge_dst],
                              rbf @ params["rbf_proj"]], axis=-1))
    m = m * edge_mask[:, None]

    def block(m, bp):
        t = (m @ bp["w_msg"])[trip_kj]
        sp = sbf @ bp["sbf_proj"]
        t2 = jnp.einsum("th,tb,hbo->to", t, sp, bp["bilinear"])
        agg = jax.ops.segment_sum(t2 * trip_mask[:, None], trip_ji,
                                  num_segments=e)            # LOCAL edges
        gate = rbf @ bp["rbf_gate"]
        m_new = m + _mlp(bp["update"], (m + agg) * gate)
        return m_new * edge_mask[:, None], None

    m, _ = jax.lax.scan(block, m, params["blocks"],
                        unroll=c.n_blocks if c.cost_exact else 1)

    node_acc = jax.ops.segment_sum(m, edge_dst, num_segments=n)
    node_acc = jax.lax.psum(node_acc, psum_axes)             # the collective
    out = _mlp(params["out_mlp"], node_acc)
    err = (out[:, 0] - batch["target"]) * batch["node_mask"]
    return jnp.sum(err * err) / jnp.maximum(jnp.sum(batch["node_mask"]), 1.0)


# ---------------------------------------------------------------------------
# neighbor sampler (minibatch_lg)
# ---------------------------------------------------------------------------

def neighbor_sample(neighbors: jnp.ndarray, degrees: jnp.ndarray,
                    seeds: jnp.ndarray, fanouts: tuple, rng) -> dict:
    """Uniform fanout sampling over a padded adjacency (GraphSAGE-style).

    neighbors: (N, max_deg) padded neighbor ids; degrees: (N,).
    Returns flat edge lists (dst, src) per hop, concatenated, with masks.
    Sampling is with replacement (standard for uniform samplers at this
    fanout; duplicates act as importance weights).
    """
    frontier = seeds
    f_mask = jnp.ones_like(seeds, dtype=jnp.float32)
    edges_src, edges_dst, masks = [], [], []
    for hop, fanout in enumerate(fanouts):
        rng, sub = jax.random.split(rng)
        deg = jnp.maximum(degrees[frontier], 1)
        draw = jax.random.randint(sub, (frontier.shape[0], fanout), 0, 1 << 30)
        idx = draw % deg[:, None]
        src = jnp.take_along_axis(neighbors[frontier], idx, axis=1)
        dst = jnp.broadcast_to(frontier[:, None], src.shape)
        m = jnp.broadcast_to((f_mask * (degrees[frontier] > 0))[:, None],
                             src.shape).astype(jnp.float32)
        edges_src.append(src.reshape(-1))
        edges_dst.append(dst.reshape(-1))
        masks.append(m.reshape(-1))
        frontier = src.reshape(-1)
        f_mask = m.reshape(-1)
    return {
        "edge_src": jnp.concatenate(edges_src),
        "edge_dst": jnp.concatenate(edges_dst),
        "edge_mask": jnp.concatenate(masks),
    }


def build_triplets(edge_src, edge_dst, budget: int, rng):
    """Sample up to `budget` triplets (k→j, j→i): pairs of edges sharing j.

    Exact enumeration is Σ deg² (infeasible at ogbn-products scale); we
    sample uniformly over edge pairs with matching middle node via sorted
    buckets.  Returns (trip_kj, trip_ji, trip_mask).
    """
    e = edge_src.shape[0]
    # group edges by their destination (j for kj-edges)
    order = jnp.argsort(edge_dst)
    rng, s1 = jax.random.split(rng)
    # candidate ji edges sampled uniformly; for each, pick a kj edge whose
    # dst == src(ji) by binary search into the sorted dst array
    ji = jax.random.randint(s1, (budget,), 0, e)
    j = edge_src[ji]
    sorted_dst = edge_dst[order]
    lo = jnp.searchsorted(sorted_dst, j, side="left")
    hi = jnp.searchsorted(sorted_dst, j, side="right")
    rng, s2 = jax.random.split(rng)
    off = jax.random.randint(s2, (budget,), 0, 1 << 30)
    span = jnp.maximum(hi - lo, 1)
    kj = order[jnp.minimum(lo + off % span, e - 1)]
    valid = (hi > lo) & (kj != ji)
    return kj, ji, valid.astype(jnp.float32)
