"""Sharded embedding tables + EmbeddingBag for the recsys family.

JAX has no native EmbeddingBag — built here from ``jnp.take`` + masked
reduction (rectangular padded bags) / ``jax.ops.segment_sum`` (ragged bags).
Tables are row-sharded over the "rows"→model mesh axis (the classic recsys
table-parallel layout); under pjit the lookup lowers to per-shard partial
gathers + an all-reduce.  ``sharded_lookup_manual`` is the explicit
shard_map twin used when we want the collective schedule pinned down (and
it is what the dry-run exercises for the table-parallel cells).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """(R, D) x (...,) int32 -> (..., D)."""
    return table[ids]


def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray, mask: jnp.ndarray,
                  mode: str = "sum") -> jnp.ndarray:
    """Padded-bag EmbeddingBag: ids (B, L), mask (B, L) -> (B, D)."""
    e = table[ids] * mask[..., None]
    if mode == "sum":
        return jnp.sum(e, axis=-2)
    if mode == "mean":
        return jnp.sum(e, axis=-2) / jnp.maximum(
            jnp.sum(mask, axis=-1, keepdims=True), 1.0)
    if mode == "max":
        neg = jnp.where(mask[..., None] > 0, e, -jnp.inf)
        return jnp.max(neg, axis=-2)
    raise ValueError(mode)


def ragged_embedding_bag(table: jnp.ndarray, flat_ids: jnp.ndarray,
                         bag_ids: jnp.ndarray, n_bags: int,
                         weights: jnp.ndarray | None = None) -> jnp.ndarray:
    """Ragged bags via segment_sum: flat_ids (P,), bag_ids (P,) -> (n_bags, D)."""
    rows = table[flat_ids]
    if weights is not None:
        rows = rows * weights[:, None]
    return jax.ops.segment_sum(rows, bag_ids, num_segments=n_bags)


def sharded_lookup_manual(table_local: jnp.ndarray, ids: jnp.ndarray,
                          axis_name: str, shard_rows: int) -> jnp.ndarray:
    """Explicit table-parallel lookup inside shard_map.

    Each shard holds rows [i·shard_rows, (i+1)·shard_rows); out-of-range ids
    contribute zeros and the psum recovers the full rows.
    """
    idx = jax.lax.axis_index(axis_name)
    lo = idx * shard_rows
    local = ids - lo
    valid = (local >= 0) & (local < shard_rows)
    rows = table_local[jnp.clip(local, 0, shard_rows - 1)]
    rows = jnp.where(valid[..., None], rows, 0)
    return jax.lax.psum(rows, axis_name)
