"""Mixture-of-experts FFN with sort-based dispatch and an explicit
expert-parallel shard_map region.

Why shard_map: under plain pjit the data-dependent dispatch scatter defeats
the SPMD partitioner — it materializes *global* (E, capacity, d) buffers
(tens of GB at 1M tokens).  Here the routing/bucketing runs on each shard's
local tokens only:

* experts divisible by the model axis → expert weights shard over "model",
  tokens shard over ("pod","data") and stay replicated across "model";
  each model-rank serves its expert slice for its data-shard's tokens and a
  psum over "model" combines per-token outputs (the EP collective visible
  in the dry-run HLO).
* experts NOT divisible (granite's 40 on a 16-way axis) → expert weights
  replicate, tokens shard over the whole mesh, no combine collective.

Tokens beyond an expert's local capacity are dropped (Switch/GShard
semantics; the aux loss keeps drops rare).  Shared (always-on) experts are
ordinary dense FFN handled by pjit outside the region.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.models import common


class MoEConfig(NamedTuple):
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


def moe_params(pf, prefix: str, d_model: int, cfg: MoEConfig):
    e, f = cfg.n_experts, cfg.d_ff_expert
    p = {
        "router": pf.dense(f"{prefix}/router", (d_model, e), (None, None),
                           scale=0.02),
        "w_gate": pf.dense(f"{prefix}/w_gate", (e, d_model, f),
                           ("experts", "embed", "ffn")),
        "w_up": pf.dense(f"{prefix}/w_up", (e, d_model, f),
                         ("experts", "embed", "ffn")),
        "w_down": pf.dense(f"{prefix}/w_down", (e, f, d_model),
                           ("experts", "ffn", "embed")),
    }
    if cfg.n_shared:
        fs = f * cfg.n_shared
        p["shared_gate"] = pf.dense(f"{prefix}/shared_gate", (d_model, fs),
                                    ("embed", "ffn"))
        p["shared_up"] = pf.dense(f"{prefix}/shared_up", (d_model, fs),
                                  ("embed", "ffn"))
        p["shared_down"] = pf.dense(f"{prefix}/shared_down", (fs, d_model),
                                    ("ffn", "embed"))
    return p


def _dispatch_compute(router, w_gate, w_up, w_down, x, cfg: MoEConfig,
                      e_offset, e_local: int, cap: int):
    """Route local tokens, bucket into (e_local, cap, d), compute, combine.

    Returns (y (t, d) — zeros for tokens served by other shards, aux)."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k

    logits = (x @ router).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    topv, tope = jax.lax.top_k(gates, k)
    topv = topv / jnp.maximum(jnp.sum(topv, axis=-1, keepdims=True), 1e-9)

    me = jnp.mean(gates, axis=0)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(tope, e, dtype=jnp.float32),
                          axis=1), axis=0) / k
    aux = cfg.router_aux_weight * e * jnp.sum(me * ce)

    e_f = tope.reshape(-1)
    t_f = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    w_f = topv.reshape(-1).astype(x.dtype)
    local = e_f - e_offset
    mine = (local >= 0) & (local < e_local)
    local = jnp.where(mine, local, e_local)            # ghost bucket

    order = jnp.argsort(local)
    l_s, t_s, w_s = local[order], t_f[order], w_f[order]
    counts = jnp.zeros((e_local + 1,), jnp.int32).at[l_s].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    pos = jnp.arange(t * k, dtype=jnp.int32) - starts[l_s]
    fits = (pos < cap) & (l_s < e_local)
    slot = jnp.where(fits, l_s * cap + pos, e_local * cap)

    xe = jnp.zeros((e_local * cap + 1, d), x.dtype).at[slot].set(x[t_s])
    xe = xe[:-1].reshape(e_local, cap, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_gate)) \
        * jnp.einsum("ecd,edf->ecf", xe, w_up)
    ye = jnp.einsum("ecf,efd->ecd", h, w_down)

    y_flat = jnp.concatenate([ye.reshape(e_local * cap, d),
                              jnp.zeros((1, d), x.dtype)], axis=0)
    contrib = y_flat[slot] * w_s[:, None]
    y = jnp.zeros((t, d), x.dtype).at[t_s].add(
        jnp.where(fits[:, None], contrib, 0))
    return y, aux


def _axis_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.shape.values())) \
        if hasattr(mesh.shape, "values") else dict(mesh.shape)


def moe_forward(p, x, cfg: MoEConfig, rules=None):
    """x: (T, d_model) -> (T, d_model), plus router aux loss."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    mesh = common.get_abstract_mesh_or_none()

    def shared_part(y):
        if cfg.n_shared:
            y = y + (jax.nn.silu(x @ p["shared_gate"]) * (x @ p["shared_up"])
                     ) @ p["shared_down"]
        return y

    if mesh is None:
        cap = max(int(t * k / e * cfg.capacity_factor), 4)
        y, aux = _dispatch_compute(p["router"], p["w_gate"], p["w_up"],
                                   p["w_down"], x, cfg, 0, e, cap)
        return shared_part(y), aux

    sizes = dict(mesh.shape)
    model_ways = sizes.get("model", 1)
    ep = e % model_ways == 0 and model_ways > 1
    tok_axes = tuple(a for a in ("pod", "data") if a in sizes)
    if not ep and "model" in sizes:
        tok_axes = tok_axes + ("model",)
    # drop trailing axes until the token count divides evenly
    while tok_axes and t % math.prod(sizes[a] for a in tok_axes) != 0:
        tok_axes = tok_axes[:-1]
    tok_ways = math.prod(sizes[a] for a in tok_axes) if tok_axes else 1
    t_local = t // tok_ways
    e_local = e // model_ways if ep else e
    cap = max(int(t_local * k / e * cfg.capacity_factor), 4)

    xspec = P(tok_axes if tok_axes else None, None)
    wspec = P("model", None, None) if ep else P(None, None, None)

    def local_fn(router, w_gate, w_up, w_down, x_local):
        e_off = jax.lax.axis_index("model") * e_local if ep else 0
        y, aux = _dispatch_compute(router, w_gate, w_up, w_down, x_local,
                                   cfg, e_off, e_local, cap)
        if ep:
            y = jax.lax.psum(y, "model")
        if tok_axes:
            aux = jax.lax.pmean(aux, tok_axes)
        return y, aux

    # explicit reshard into the region's token layout — without this, SPMD
    # crosses from the (e.g. 256-way FSDP) layout to the EP layout inside
    # shard_map via involuntary full replication (tens of GB at 1M tokens)
    x = jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, xspec))
    y, aux = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(None, None), wspec, wspec,
                  P("model", None, None) if ep else P(None, None, None),
                  xspec),
        out_specs=(xspec, P()),
        check_rep=False,
    )(p["router"], p["w_gate"], p["w_up"], p["w_down"], x)
    return shared_part(y), aux
