"""Decoder-only transformer family covering the assigned LM architectures:
dense GQA (yi-6b, minitron-8b), MLA (minicpm3-4b), and MoE (moonshot /
granite).  Functional init/apply with scan-over-layers (keeps HLO small so
80 dry-run compiles stay tractable), logical-axis sharding annotations, and
three entry points per model: ``train_step`` targets, ``prefill`` and
``decode_step`` (KV cache / latent cache).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common
from repro.models.attention import MLAConfig
from repro.models.moe import MoEConfig, moe_forward, moe_params


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    attention: str = "gqa"                # "gqa" | "mla"
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: str = "full"                   # "none" | "full" | "dots"
    cost_exact: bool = False              # unroll scans so HLO cost analysis
                                          # counts every layer (dry-run only)
    train_layout: str = "fsdp"            # "fsdp" | "tpsp" (§Perf per-arch)
    train_microbatches: int = 1           # grad-accumulation factor

    @property
    def jdtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to a multiple of 256 so the vocab axis shards on
        any practical TP degree (standard Megatron/MaxText practice)."""
        return ((self.vocab + 255) // 256) * 256

    def param_count(self) -> int:
        """Total parameters (for MODEL_FLOPS = 6·N·D accounting)."""
        c = self
        embed = c.vocab * c.d_model * 2
        if c.attention == "mla":
            m = c.mla
            a = (c.d_model * m.q_lora_rank
                 + m.q_lora_rank * c.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
                 + c.d_model * (m.kv_lora_rank + m.qk_rope_dim)
                 + m.kv_lora_rank * c.n_heads * (m.qk_nope_dim + m.v_head_dim)
                 + c.n_heads * m.v_head_dim * c.d_model)
        else:
            a = c.d_model * c.head_dim * (c.n_heads + 2 * c.n_kv_heads) \
                + c.n_heads * c.head_dim * c.d_model
        if c.moe is not None:
            f = 3 * c.d_model * c.moe.d_ff_expert
            ff = c.moe.n_experts * f + c.moe.n_shared * f \
                + c.d_model * c.moe.n_experts
        else:
            ff = 3 * c.d_model * c.d_ff
        return embed + c.n_layers * (a + ff + 2 * c.d_model)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if self.moe is None:
            return self.param_count()
        c, m = self, self.moe
        f = 3 * c.d_model * m.d_ff_expert
        dense_ff = (m.top_k + m.n_shared) * f + c.d_model * m.n_experts
        full = self.param_count()
        all_ff = m.n_experts * f + m.n_shared * f + c.d_model * m.n_experts
        return full - c.n_layers * (all_ff - dense_ff)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_params(pf, prefix: str, c: LMConfig):
    p = {}
    if c.attention == "mla":
        p["attn"] = attn.mla_params(pf, f"{prefix}/attn", c.d_model,
                                    c.n_heads, c.mla)
    else:
        dm, hd = c.d_model, c.head_dim
        p["attn"] = {
            "wq": pf.dense(f"{prefix}/attn/wq", (dm, c.n_heads * hd),
                           ("embed", "heads")),
            "wk": pf.dense(f"{prefix}/attn/wk", (dm, c.n_kv_heads * hd),
                           ("embed", "kv_heads")),
            "wv": pf.dense(f"{prefix}/attn/wv", (dm, c.n_kv_heads * hd),
                           ("embed", "kv_heads")),
            "wo": pf.dense(f"{prefix}/attn/wo", (c.n_heads * hd, dm),
                           ("heads", "embed")),
        }
    if c.moe is not None:
        p["ffn"] = moe_params(pf, f"{prefix}/ffn", c.d_model, c.moe)
    else:
        p["ffn"] = {
            "w_gate": pf.dense(f"{prefix}/ffn/w_gate", (c.d_model, c.d_ff),
                               ("embed", "ffn")),
            "w_up": pf.dense(f"{prefix}/ffn/w_up", (c.d_model, c.d_ff),
                             ("embed", "ffn")),
            "w_down": pf.dense(f"{prefix}/ffn/w_down", (c.d_ff, c.d_model),
                               ("ffn", "embed")),
        }
    p["ln1"] = pf.ones(f"{prefix}/ln1", (c.d_model,), ("embed",))
    p["ln2"] = pf.ones(f"{prefix}/ln2", (c.d_model,), ("embed",))
    return p


def init(c: LMConfig, rng=None, abstract: bool = False):
    """Returns (params, names_dict)."""
    pf = common.ParamFactory(rng if rng is not None else jax.random.PRNGKey(0),
                             abstract=abstract, dtype=c.jdtype)
    params = {
        "embed": pf.dense("embed", (c.padded_vocab, c.d_model),
                          ("vocab", "embed"), scale=0.02),
        "unembed": pf.dense("unembed", (c.d_model, c.padded_vocab),
                            ("embed", "vocab")),
        "final_ln": pf.ones("final_ln", (c.d_model,), ("embed",)),
        "layers": common.stack_layer_params(
            lambda f, pre: _layer_params(f, pre, c), pf, c.n_layers, "layers"),
    }
    return params, pf.names


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _attn_block(p, x, positions, c: LMConfig, causal=True):
    b, s, _ = x.shape
    if c.attention == "mla":
        return attn.mla_forward(p, x, positions, c.n_heads, c.mla,
                                causal=causal, unroll=c.cost_exact)
    hd = c.head_dim
    q = (x @ p["wq"]).reshape(b, s, c.n_heads, hd).transpose(0, 2, 1, 3)
    k = (x @ p["wk"]).reshape(b, s, c.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = (x @ p["wv"]).reshape(b, s, c.n_kv_heads, hd).transpose(0, 2, 1, 3)
    q = common.rope(q, positions[:, None, :], c.rope_theta)
    k = common.rope(k, positions[:, None, :], c.rope_theta)
    o = attn.chunked_attention(q, k, v, causal=causal,
                               unroll=c.cost_exact)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, c.n_heads * hd)
    return o @ p["wo"]


def _layer_fwd(lp, x, positions, c: LMConfig, rules, causal=True):
    h = common.rms_norm(x, lp["ln1"], c.norm_eps)
    x = x + _attn_block(lp["attn"], h, positions, c, causal)
    x = common.constrain(x, ("batch", "seq", "embed"), rules)
    h = common.rms_norm(x, lp["ln2"], c.norm_eps)
    if c.moe is not None:
        b, s, d = h.shape
        y, aux = moe_forward(lp["ffn"], h.reshape(b * s, d), c.moe)
        y = y.reshape(b, s, d)
    else:
        y = common.swiglu(h, lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                          lp["ffn"]["w_down"])
        aux = jnp.zeros((), jnp.float32)
    x = x + y
    x = common.constrain(x, ("batch", "seq", "embed"), rules)
    return x, aux


def forward(params, c: LMConfig, tokens, rules=None, causal=True):
    """tokens (B, S) -> logits (B, S, V). scan over stacked layers + remat."""
    rules = rules or common.DEFAULT_RULES
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x = params["embed"][tokens].astype(c.jdtype)
    x = common.constrain(x, ("batch", "seq", "embed"), rules)

    def body(x, lp):
        y, aux = _layer_fwd(lp, x, positions, c, rules, causal)
        return y, aux

    if c.remat == "full":
        body = jax.checkpoint(body)
    elif c.remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    x, aux = jax.lax.scan(body, x, params["layers"],
                          unroll=c.n_layers if c.cost_exact else 1)
    x = common.rms_norm(x, params["final_ln"], c.norm_eps)
    logits = x @ params["unembed"]
    logits = common.constrain(logits, ("batch", "seq", "vocab"), rules)
    return logits, jnp.sum(aux)


def forward_hidden(params, c: LMConfig, tokens, rules=None, causal=True):
    """Like forward() but stops at the final hidden states (B, S, d)."""
    rules = rules or common.DEFAULT_RULES
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x = params["embed"][tokens].astype(c.jdtype)
    x = common.constrain(x, ("batch", "seq", "embed"), rules)

    def body(x, lp):
        return _layer_fwd(lp, x, positions, c, rules, causal)

    if c.remat == "full":
        body = jax.checkpoint(body)
    elif c.remat == "dots":
        body = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    x, aux = jax.lax.scan(body, x, params["layers"],
                          unroll=c.n_layers if c.cost_exact else 1)
    x = common.rms_norm(x, params["final_ln"], c.norm_eps)
    return x, jnp.sum(aux)


def loss_fn(params, c: LMConfig, tokens, labels, rules=None,
            ce_chunk: int = 512):
    """Cross-entropy via a sequence-chunked scan: (chunk, V) logits tiles
    are computed, reduced, and (with the checkpointed body) rematerialized
    in backward — the full (B, S, V) logits never exist. This is what keeps
    the 256k-vocab archs inside HBM (EXPERIMENTS.md §Perf)."""
    x, aux = forward_hidden(params, c, tokens, rules)
    b, s, d = x.shape
    ce_chunk = min(ce_chunk, s)
    n_chunks = s // ce_chunk
    xs = x.reshape(b, n_chunks, ce_chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, n_chunks, ce_chunk).transpose(1, 0, 2)

    # CE tiles must be vocab-sharded over "model": otherwise the unembed
    # cotangent (d_model × padded_vocab, fp32) materializes unsharded in
    # the chunk-scan carry — 4.2 GB × n_chunks at 256k vocab (§Perf)
    ce_rules = dict(rules or common.DEFAULT_RULES)
    ce_rules["batch"] = ("pod", "data")
    ce_rules["seq"] = None
    ce_rules["vocab"] = "model"

    def step(carry, inp):
        xc, lc = inp
        logits = xc @ params["unembed"]
        logits = common.constrain(logits, ("batch", "seq", "vocab"), ce_rules)
        loss_sum, count = _ce_sum(logits, lc, c.vocab)
        return (carry[0] + loss_sum, carry[1] + count), None

    step = jax.checkpoint(step)
    unroll = n_chunks if c.cost_exact else 1
    (loss_sum, count), _ = jax.lax.scan(step, (0.0, 0.0), (xs, ls),
                                        unroll=unroll)
    return loss_sum / jnp.maximum(count, 1.0) + aux


def _ce_sum(logits, labels, vocab: int):
    logits = logits.astype(jnp.float32)
    if logits.shape[-1] > vocab:
        pad_mask = jnp.arange(logits.shape[-1]) < vocab
        logits = jnp.where(pad_mask, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - gold) * mask), jnp.sum(mask)


def prefill(params, c: LMConfig, tokens, rules=None):
    """Run the prompt through the model, building the decode cache.

    Returns (last-token logits (B, V), cache) — cache layout matches
    ``init_cache`` so decode_step can continue from it.
    """
    rules = rules or common.DEFAULT_RULES
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x = params["embed"][tokens].astype(c.jdtype)
    x = common.constrain(x, ("batch", "seq", "embed"), rules)

    def body(x, lp):
        h = common.rms_norm(x, lp["ln1"], c.norm_eps)
        if c.attention == "mla":
            m = c.mla
            dkv = h @ lp["attn"]["wdkv"]
            c_kv = common.rms_norm(dkv[..., :m.kv_lora_rank],
                                   lp["attn"]["kv_norm"])
            k_rope = common.rope(dkv[..., m.kv_lora_rank:],
                                 positions)                    # (B, S, qr)
            o = attn.mla_forward(lp["attn"], h, positions, c.n_heads, m,
                                 unroll=c.cost_exact)
            kv_out = {"c": common.constrain(c_kv, ("batch", "kv_seq", "qk"),
                                            rules),
                      "rope": k_rope}
        else:
            hd = c.head_dim
            q = (h @ lp["attn"]["wq"]).reshape(b, s, c.n_heads, hd
                                               ).transpose(0, 2, 1, 3)
            k = (h @ lp["attn"]["wk"]).reshape(b, s, c.n_kv_heads, hd
                                               ).transpose(0, 2, 1, 3)
            v = (h @ lp["attn"]["wv"]).reshape(b, s, c.n_kv_heads, hd
                                               ).transpose(0, 2, 1, 3)
            q = common.rope(q, positions[:, None, :], c.rope_theta)
            k = common.rope(k, positions[:, None, :], c.rope_theta)
            o = attn.chunked_attention(q, k, v, causal=True,
                                       unroll=c.cost_exact)
            o = o.transpose(0, 2, 1, 3).reshape(b, s, c.n_heads * hd) \
                @ lp["attn"]["wo"]
            kv_out = {
                "k": common.constrain(k, ("batch", "kv_heads", "kv_seq", None),
                                      rules),
                "v": common.constrain(v, ("batch", "kv_heads", "kv_seq", None),
                                      rules)}
        x = x + o
        h = common.rms_norm(x, lp["ln2"], c.norm_eps)
        if c.moe is not None:
            y, _ = moe_forward(lp["ffn"], h.reshape(b * s, -1), c.moe)
            y = y.reshape(b, s, -1)
        else:
            y = common.swiglu(h, lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                              lp["ffn"]["w_down"])
        x = common.constrain(x + y, ("batch", "seq", "embed"), rules)
        return x, kv_out

    x, cache = jax.lax.scan(body, x, params["layers"],
                            unroll=c.n_layers if c.cost_exact else 1)
    x = common.rms_norm(x[:, -1], params["final_ln"], c.norm_eps)
    logits = x @ params["unembed"]
    return logits, cache


# ---------------------------------------------------------------------------
# decode (KV / latent cache)
# ---------------------------------------------------------------------------

def init_cache(c: LMConfig, batch: int, max_len: int, abstract: bool = False):
    """GQA: k/v caches (L, B, Hkv, S, hd). MLA: latent + rope caches."""
    dt = c.jdtype
    if c.attention == "mla":
        shapes = {
            "c": ((c.n_layers, batch, max_len, c.mla.kv_lora_rank),
                  ("stack", "batch", "kv_seq", "qk")),
            "rope": ((c.n_layers, batch, max_len, c.mla.qk_rope_dim),
                     ("stack", "batch", "kv_seq", "qk")),
        }
    else:
        kv = (c.n_layers, batch, c.n_kv_heads, max_len, c.head_dim)
        shapes = {"k": (kv, ("stack", "batch", "kv_heads", "kv_seq", None)),
                  "v": (kv, ("stack", "batch", "kv_heads", "kv_seq", None))}
    names = {k: v[1] for k, v in shapes.items()}
    if abstract:
        return ({k: jax.ShapeDtypeStruct(v[0], dt) for k, v in shapes.items()},
                names)
    return ({k: jnp.zeros(v[0], dt) for k, v in shapes.items()}, names)


def decode_step(params, c: LMConfig, token, cache, kv_len, rules=None):
    """One autoregressive step.

    token: (B,) int32; kv_len: (B,) current cache fill. Returns
    (logits (B, V), updated cache).
    """
    rules = rules or common.DEFAULT_RULES
    b = token.shape[0]
    x = params["embed"][token].astype(c.jdtype)      # (B, d)
    pos = kv_len.astype(jnp.float32)

    def body(x, per_layer):
        lp, cache_l = per_layer
        h = common.rms_norm(x, lp["ln1"], c.norm_eps)
        hd = c.head_dim
        q = (h @ lp["attn"]["wq"]).reshape(b, c.n_heads, hd)
        kk = (h @ lp["attn"]["wk"]).reshape(b, c.n_kv_heads, hd)
        vv = (h @ lp["attn"]["wv"]).reshape(b, c.n_kv_heads, hd)
        q = common.rope(q[:, :, None, :], pos[:, None, None])[:, :, 0]
        kk = common.rope(kk[:, :, None, :], pos[:, None, None])[:, :, 0]
        k_cache = _cache_insert(cache_l["k"], kk, kv_len)
        v_cache = _cache_insert(cache_l["v"], vv, kv_len)
        o = attn.gqa_decode(q, k_cache, v_cache, kv_len + 1)
        o = (o.reshape(b, c.n_heads * hd) @ lp["attn"]["wo"])
        new_cache = {"k": k_cache, "v": v_cache}
        x = x + o
        h = common.rms_norm(x, lp["ln2"], c.norm_eps)
        if c.moe is not None:
            y, _ = moe_forward(lp["ffn"], h, c.moe)
        else:
            y = common.swiglu(h, lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                              lp["ffn"]["w_down"])
        return x + y, new_cache

    # MLA: insert current latent into cache before the scan body uses it
    if c.attention == "mla":
        def body_mla(x, per_layer):
            lp, cache_l = per_layer
            h = common.rms_norm(x, lp["ln1"], c.norm_eps)
            dkv = h @ lp["attn"]["wdkv"]
            r = c.mla.kv_lora_rank
            c_new = common.rms_norm(dkv[..., :r], lp["attn"]["kv_norm"])
            rope_new = common.rope(dkv[..., r:][:, None, :],
                                   pos[:, None])[:, 0]
            c_cache = _cache_insert_2d(cache_l["c"], c_new, kv_len)
            rope_cache = _cache_insert_2d(cache_l["rope"], rope_new, kv_len)
            o = attn.mla_decode(lp["attn"], h, c_cache, rope_cache,
                                kv_len + 1, c.n_heads, c.mla)
            x = x + o
            h2 = common.rms_norm(x, lp["ln2"], c.norm_eps)
            if c.moe is not None:
                y, _ = moe_forward(lp["ffn"], h2, c.moe)
            else:
                y = common.swiglu(h2, lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                                  lp["ffn"]["w_down"])
            return x + y, {"c": c_cache, "rope": rope_cache}
        body = body_mla

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache),
                                unroll=c.n_layers if c.cost_exact else 1)
    x = common.rms_norm(x, params["final_ln"], c.norm_eps)
    logits = x @ params["unembed"]
    return logits, new_cache


def _cache_insert(cache, new, kv_len):
    """cache (B, H, S, D), new (B, H, D) inserted at position kv_len (B,).

    Select-based insert: reads+writes the cache once (a bounded memory-term
    cost) but stays collective-free when the sequence axis is sharded —
    SPMD lowers a dynamic-update-slice across a sharded axis via full-cache
    replication (§Perf: 1.37 s of collective per decode step on the 500k
    cells), whereas the select is purely local."""
    b, h, s, d = cache.shape
    pos = jnp.arange(s)[None, None, :, None]
    return jnp.where(pos == kv_len[:, None, None, None],
                     new[:, :, None, :].astype(cache.dtype), cache)


def _cache_insert_2d(cache, new, kv_len):
    """cache (B, S, R), new (B, R) at position kv_len (B,)."""
    def one(c, n, l):
        return jax.lax.dynamic_update_slice(c, n[None, :], (l, 0))
    return jax.vmap(one)(cache, new, kv_len)
