"""Stage-0 scheduler: the online side of the paper's hybrid architecture.

Receives query batches, runs the Stage-0 predictions (features + GBRT),
routes each query to the JASS or BMW replica pool (Algorithms 1/2), enforces
the ρ_max budget cap, and applies straggler mitigation:

* **hedging** — a query routed to BMW whose *predicted* time lies inside the
  uncertainty band ``[T(1-b), T(1+b)]`` around the routing threshold is
  duplicated onto the JASS mirror; the first responder wins (the JASS copy
  has a hard deadline by construction).  Queries predicted *far* above the
  band are not hedged — Algorithm 2 already routed the confidently-slow ones
  to JASS, and duplicating every slow-predicted straggler would waste a full
  JASS execution per query.
* **deadline re-route (late hedge)** — an execution that exceeds the
  detection deadline ``budget · hedge_deadline`` is re-issued to JASS with
  the dedicated small ``late_rho`` cap.  This is the mechanism that turns
  the paper's 99.99 % into a *hard* guarantee.

Guarantee accounting
--------------------
With ``B`` the scheduler budget, ``d = hedge_deadline``, ``ρ_late`` the
late-hedge cap and ``c_s``/``f_s`` the JASS per-posting/fixed costs, every
query's resolved first-stage time obeys

    t  ≤  max(B,  d·B + f_s + ρ_late·c_s)  + predict_us

term by term: a query either finishes under ``B`` on its own, or it is
detected at ``d·B`` and re-issued with at most ``ρ_late`` postings of
anytime JASS work (``f_s + ρ_late·c_s``); Stage-0 prediction cost is paid
unconditionally.  Choosing ``ρ_late`` so that
``f_s + ρ_late·c_s ≤ (1-d)·B`` collapses the bound to ``B`` exactly — that
is what :meth:`SchedulerConfig.max_late_rho` computes (per-shard under
scatter-gather: the re-issue waits for its slowest shard and pays the
fan-out/merge overhead, so the admissible ρ_late shrinks with shards) and
what
``benchmarks/bench_tail.py`` certifies (0 violations on a full trace).
With ``enforce_budget`` the same deadline re-route also covers JASS-routed
queries whose ρ cap alone does not bound them under ``B`` (large
``rho_max`` operating points), so the bound is cascade-wide, not
BMW-only.  The seed implementation re-issued with ``min(ρ, rho_max)`` —
a no-op after ``clamp_parameters`` — leaving the tail unbounded; see
CHANGES.md PR 4.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import hybrid
from repro.serving.latency import CostModel


@dataclass
class SchedulerConfig:
    algorithm: int = 2                  # paper Algorithm 1 or 2
    t_k: float = 1000.0
    t_time: float = 150.0               # same units as the cost model
    rho_max: int = 1 << 20
    rho_min: int = 4096
    budget: float = 200.0
    hedge_band: float = 0.25            # hedge if pred_t in [T(1-b), T(1+b)]
    enable_hedging: bool = True
    hedge_deadline: float = 0.5         # detect stragglers at budget * this
    late_rho: int = 0                   # late-hedge re-issue ρ cap
                                        # (0 = auto: rho_min)
    enforce_budget: bool = True         # deadline re-route JASS rows too
    failover_timeout: float = 0.0       # scatter-gather shard timeout
                                        # (0 = no failover)
    max_retries: int = 0                # bounded re-issues per (query, shard)

    def resolved_late_rho(self) -> int:
        """The effective late-hedge ρ cap (``late_rho`` or ``rho_min``)."""
        return int(self.late_rho) if self.late_rho > 0 else int(self.rho_min)

    def retry_us(self) -> float:
        """Worst-case failover wait charged into the bound: each of the
        ``max_retries`` re-issues is detected after ``failover_timeout``
        (the original request's timeout is the first detection and is also
        how a lost partition is declared, so ``max_retries`` timeouts cover
        the retry cascade on top of whichever attempt finally serves)."""
        return self.max_retries * self.failover_timeout

    def max_late_rho(self, cost: CostModel, n_shards: int = 1) -> int:
        """Largest ρ_late for which the worst-case bound collapses to the
        budget itself: f_s + ρ·c_s + gather ≤ (1 - hedge_deadline)·budget.

        Under scatter-gather the late re-issue is itself sharded — its
        global level cut can land entirely on one slow shard, and the query
        still pays the per-extra-shard fan-out/merge overhead
        (``CostModel.gather_per_shard_us``) on top of that shard's
        traversal.  Budgeting the re-issue globally (``n_shards=1``) would
        let that overhead silently eat the hedge headroom, so the gather
        term is subtracted from the slack here, exactly mirroring
        :meth:`worst_case_us`.  With failover enabled the re-issue can
        additionally wait out ``max_retries`` shard timeouts before its
        serving attempt runs (:meth:`retry_us`), so that term shrinks the
        admissible ρ_late the same way."""
        slack = ((1.0 - self.hedge_deadline) * self.budget
                 - cost.saat_fixed_us
                 - cost.gather_per_shard_us * (n_shards - 1)
                 - self.retry_us())
        if cost.saat_per_posting_us <= 0:
            return self.rho_max if slack >= 0 else 0
        return max(int(slack / cost.saat_per_posting_us), 0)

    def worst_case_us(self, cost: CostModel, n_shards: int = 1) -> float:
        """The documented hard bound on any resolved first-stage latency
        (see module docstring *Guarantee accounting*)."""
        gather = cost.gather_per_shard_us * (n_shards - 1)
        late = float(cost.saat_time(np.float64(self.resolved_late_rho())))
        # with failover, any attempt (including the late re-issue) can wait
        # out max_retries shard timeouts before the serving attempt runs
        reissue = (self.budget * self.hedge_deadline + late + gather
                   + self.retry_us())
        bound = max(self.budget, reissue)
        if not self.enforce_budget:
            # JASS rows are bounded only by their ρ_max-capped traversal
            bound = max(bound,
                        float(cost.saat_time(np.float64(self.rho_max)))
                        + gather + self.retry_us())
        return bound + cost.predict_us


@dataclass
class RoutedBatch:
    jass_rows: np.ndarray
    bmw_rows: np.ndarray
    hedged_rows: np.ndarray
    k: np.ndarray
    rho: np.ndarray


class StageZeroScheduler:
    """Routes queries given Stage-0 predictions; tracks outcome stats."""

    def __init__(self, cfg: SchedulerConfig, cost: CostModel | None = None):
        self.cfg = cfg
        self.cost = cost or CostModel.paper_scale()
        self.stats = {"jass": 0, "bmw": 0, "hedged": 0, "late_hedged": 0,
                      "late_hedged_jass": 0}

    def route(self, pred_k: np.ndarray, pred_rho: np.ndarray,
              pred_t: np.ndarray) -> RoutedBatch:
        cfg = self.cfg
        hc = hybrid.HybridConfig(t_k=cfg.t_k, t_time_us=cfg.t_time,
                                 rho_max=cfg.rho_max, rho_min=cfg.rho_min)
        if cfg.algorithm == 1:
            routes = hybrid.route_algorithm1(pred_k, hc)
        else:
            routes = hybrid.route_algorithm2(pred_k, pred_t, hc)
        k, rho = hybrid.clamp_parameters(pred_k, pred_rho, hc)

        bmw = routes == hybrid.ROUTE_BMW
        jass = ~bmw
        hedged = np.zeros_like(bmw)
        if cfg.enable_hedging:
            # the documented band is two-sided: only *uncertain* predictions
            # near the threshold hedge; far-above-band queries rely on the
            # deadline re-route instead of a duplicated JASS execution
            band = ((pred_t > cfg.t_time * (1 - cfg.hedge_band))
                    & (pred_t <= cfg.t_time * (1 + cfg.hedge_band)) & bmw)
            hedged = band
        self.stats["jass"] += int(jass.sum())
        self.stats["bmw"] += int(bmw.sum())
        self.stats["hedged"] += int(hedged.sum())
        return RoutedBatch(
            jass_rows=np.flatnonzero(jass), bmw_rows=np.flatnonzero(bmw),
            hedged_rows=np.flatnonzero(hedged), k=k, rho=rho)

    def _late_hedge(self, routed: RoutedBatch, rows: np.ndarray,
                    t: np.ndarray, work_jass_fn) -> np.ndarray:
        """Deadline re-route: detect at ``budget·hedge_deadline``, re-issue
        with ``min(ρ, late_rho)`` postings of JASS work; the query finishes
        at whichever execution responds first."""
        cfg = self.cfg
        late_cap = np.minimum(routed.rho[rows], cfg.resolved_late_rho())
        tj = work_jass_fn(rows, late_cap)
        return np.minimum(t, cfg.budget * cfg.hedge_deadline + tj)

    def resolve_times(self, routed: RoutedBatch, t_bmw: np.ndarray,
                      work_jass_fn, late_jass_fn=None) -> np.ndarray:
        """Final per-query latency under hedging semantics.

        t_bmw: modeled/measured BMW time for every query (used for rows
        routed to BMW); work_jass_fn(rows, rho) -> JASS times for rows.
        Hedged BMW queries finish at min(bmw, jass); any execution that
        blows the detection deadline is late-hedged — re-issued with the
        dedicated small ``late_rho`` cap, so the worst case is bounded by
        ``budget·hedge_deadline + ρ_late·c_s`` (*Guarantee accounting* in
        the module docstring).

        ``late_jass_fn`` (defaults to ``work_jass_fn``) prices the late-
        hedge re-issue separately: under fault injection the primary
        executions run on (possibly faulted) routed replicas while the
        deadline re-issue goes to a *fresh healthy* replica, so it pays
        nominal JASS cost, not the faulted one."""
        n = len(routed.k)
        t = np.zeros(n)
        cfg = self.cfg
        if late_jass_fn is None:
            late_jass_fn = work_jass_fn
        if len(routed.jass_rows):
            rows = routed.jass_rows
            tj = work_jass_fn(rows, routed.rho[rows])
            if cfg.enforce_budget:
                late = tj > cfg.budget
                if late.any():
                    tj = tj.copy()
                    tj[late] = self._late_hedge(routed, rows[late], tj[late],
                                                late_jass_fn)
                    self.stats["late_hedged_jass"] += int(late.sum())
            t[rows] = tj
        if len(routed.bmw_rows):
            tb = t_bmw[routed.bmw_rows].copy()
            hedge_mask = np.isin(routed.bmw_rows, routed.hedged_rows)
            if hedge_mask.any():
                rows = routed.bmw_rows[hedge_mask]
                tj = work_jass_fn(rows, routed.rho[rows])
                tb[hedge_mask] = np.minimum(tb[hedge_mask],
                                            tj + self.cost.predict_us)
            # late hedge: detect at the deadline, re-issue with the SMALL
            # dedicated cap (the seed used rho_max here — a no-op after
            # clamp_parameters, leaving the tail unbounded)
            late = tb > cfg.budget
            if late.any():
                rows = routed.bmw_rows[late]
                tb[late] = self._late_hedge(routed, rows, tb[late],
                                            late_jass_fn)
                self.stats["late_hedged"] += int(late.sum())
            t[routed.bmw_rows] = tb
        return t + self.cost.predict_us
