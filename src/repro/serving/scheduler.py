"""Stage-0 scheduler: the online side of the paper's hybrid architecture.

Receives query batches, runs the Stage-0 predictions (features + GBRT),
routes each query to the JASS or BMW replica pool (Algorithms 1/2), enforces
the ρ_max budget cap, and applies straggler mitigation:

* **hedging** — a query routed to BMW whose *predicted* time is within the
  uncertainty band of the threshold is duplicated onto the JASS mirror; the
  first responder wins (the JASS copy has a hard deadline by construction).
* **deadline re-route** — if a BMW execution exceeds the budget fraction
  `hedge_deadline`, the query is re-issued to JASS with a small ρ (late
  hedge), bounding the worst case at `budget + ρ_cap·c` — this is the
  mechanism that turns the paper's 99.99 % into a hard guarantee.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import hybrid
from repro.serving.latency import CostModel


@dataclass
class SchedulerConfig:
    algorithm: int = 2                  # paper Algorithm 1 or 2
    t_k: float = 1000.0
    t_time: float = 150.0               # same units as the cost model
    rho_max: int = 1 << 20
    rho_min: int = 4096
    budget: float = 200.0
    hedge_band: float = 0.25            # hedge if pred_t in [T(1-b), T(1+b)]
    enable_hedging: bool = True


@dataclass
class RoutedBatch:
    jass_rows: np.ndarray
    bmw_rows: np.ndarray
    hedged_rows: np.ndarray
    k: np.ndarray
    rho: np.ndarray


class StageZeroScheduler:
    """Routes queries given Stage-0 predictions; tracks outcome stats."""

    def __init__(self, cfg: SchedulerConfig, cost: CostModel | None = None):
        self.cfg = cfg
        self.cost = cost or CostModel.paper_scale()
        self.stats = {"jass": 0, "bmw": 0, "hedged": 0, "late_hedged": 0}

    def route(self, pred_k: np.ndarray, pred_rho: np.ndarray,
              pred_t: np.ndarray) -> RoutedBatch:
        cfg = self.cfg
        hc = hybrid.HybridConfig(t_k=cfg.t_k, t_time_us=cfg.t_time,
                                 rho_max=cfg.rho_max, rho_min=cfg.rho_min)
        if cfg.algorithm == 1:
            routes = hybrid.route_algorithm1(pred_k, hc)
        else:
            routes = hybrid.route_algorithm2(pred_k, pred_t, hc)
        k, rho = hybrid.clamp_parameters(pred_k, pred_rho, hc)

        bmw = routes == hybrid.ROUTE_BMW
        jass = ~bmw
        hedged = np.zeros_like(bmw)
        if cfg.enable_hedging:
            band = (pred_t > cfg.t_time * (1 - cfg.hedge_band)) & bmw
            hedged = band
        self.stats["jass"] += int(jass.sum())
        self.stats["bmw"] += int(bmw.sum())
        self.stats["hedged"] += int(hedged.sum())
        return RoutedBatch(
            jass_rows=np.flatnonzero(jass), bmw_rows=np.flatnonzero(bmw),
            hedged_rows=np.flatnonzero(hedged), k=k, rho=rho)

    def resolve_times(self, routed: RoutedBatch, t_bmw: np.ndarray,
                      work_jass_fn) -> np.ndarray:
        """Final per-query latency under hedging semantics.

        t_bmw: modeled/measured BMW time for every query (used for rows
        routed to BMW); work_jass_fn(rows, rho) -> JASS times for rows.
        Hedged BMW queries finish at min(bmw, jass); BMW queries that blow
        the budget are late-hedged: budget_detect + jass re-issue."""
        n = len(routed.k)
        t = np.zeros(n)
        cfg = self.cfg
        if len(routed.jass_rows):
            t[routed.jass_rows] = work_jass_fn(routed.jass_rows,
                                               routed.rho[routed.jass_rows])
        if len(routed.bmw_rows):
            tb = t_bmw[routed.bmw_rows].copy()
            hedge_mask = np.isin(routed.bmw_rows, routed.hedged_rows)
            if hedge_mask.any():
                rows = routed.bmw_rows[hedge_mask]
                tj = work_jass_fn(rows, routed.rho[rows])
                tb[hedge_mask] = np.minimum(tb[hedge_mask],
                                            tj + self.cost.predict_us)
            # late hedge: detect at deadline, re-issue to JASS
            late = tb > cfg.budget
            if late.any():
                rows = routed.bmw_rows[late]
                tj = work_jass_fn(rows, np.minimum(routed.rho[rows],
                                                   cfg.rho_max))
                tb[late] = np.minimum(tb[late], cfg.budget * 0.5 + tj)
                self.stats["late_hedged"] += int(late.sum())
            t[routed.bmw_rows] = tb
        return t + self.cost.predict_us
