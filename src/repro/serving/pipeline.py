"""Compatibility shim: the historical ``CascadePipeline`` constructor on
top of the spec-built ``SearchSystem``.

The unified cascade (Stage-0 → routing → Stage-1 → Stage-2 as one batched
array program) now lives in ``repro.serving.system.SearchSystem``, built
from a declarative ``repro.serving.spec.CascadeSpec`` — which adds
multi-shard scatter-gather Stage-1, replica-pool load balancing, and the
spec/preset lifecycle (``build_system(spec).fit(...).serve(...)``).

``CascadePipeline`` keeps the pre-spec keyword surface (untyped model dict
plus loose knobs) for existing callers and tests: it assembles the
equivalent single-shard ``CascadeSpec`` internally and delegates
everything to ``SearchSystem``.  A one-shard system is bit-identical to
the historical pipeline — same engine calls, same latency accounting, same
top-k/final lists.  New code should build a spec (or pick a preset from
``repro.configs.cascade_presets``) and use ``build_system`` directly.
"""

from __future__ import annotations

import numpy as np

from repro.index.builder import InvertedIndex
from repro.ltr.ranker import LTRModel
from repro.serving.latency import CostModel
from repro.serving.scheduler import SchedulerConfig
from repro.serving.spec import (BackendSpec, CascadeSpec, DeploySpec,
                                IndexSpec, Stage2Spec)
from repro.serving.system import (PipelineResult, SearchSystem,  # noqa: F401
                                  routing_spec)


class CascadePipeline(SearchSystem):
    """The whole multi-stage retrieval cascade as one batched query program.

    Thin shim over :class:`~repro.serving.system.SearchSystem` with the
    historical keyword surface; a single-shard spec is assembled from the
    old knobs, so results are bit-identical to the pre-spec pipeline.

    Args:
      index: the built collection (both mirrors + Stage-0 stats).
      models: ``{"k": GBRTModel, "rho": ..., "t": ...}`` Stage-0 predictors.
      cfg: scheduler/routing configuration.
      corpus: required when ``ltr`` is given (Stage-2 reads doc topics).
      ltr: Stage-2 point-wise LTR model; None serves Stage-1 only.
      k_serve: Stage-1 retrieval depth (the candidate grid width C).
      t_final: result-list depth after Stage-2.
      backend: Stage-1/Stage-2 kernel backend override
        ("pallas" | "interpret" | "jnp" | None = auto).
    """

    def __init__(self, index: InvertedIndex, models: dict,
                 cfg: SchedulerConfig, *, corpus=None,
                 ltr: LTRModel | None = None, k_serve: int = 128,
                 t_final: int = 10, cost: CostModel | None = None,
                 backend: str | None = None):
        spec = CascadeSpec(
            index=IndexSpec(block_size=index.block_size),
            routing=routing_spec(cfg),
            stage2=Stage2Spec(enabled=ltr is not None, k_serve=k_serve,
                              t_final=t_final),
            backend=BackendSpec(backend=backend),
            # replicas=2 so the single partition holds one replica of EACH
            # mirror (a 1-replica pool is JASS-only and would count all BMW
            # traffic through the mirror-exhaustion fallback)
            deploy=DeploySpec(n_shards=1, replicas=2, rebalance_every=0),
            name="compat_pipeline",
        )
        super().__init__(spec, index, corpus=corpus, models=models, ltr=ltr,
                         cost=cost)

    # historical attribute surface: the single shard and its spec
    @property
    def shard(self):
        return self.shards[0]

    @property
    def spec(self):
        return self.shard_specs[0]

    def stage1(self, terms: np.ndarray, mask: np.ndarray, routed):
        """Historical signature: returns (topk, t_bmw).  Threads a fresh
        per-call split memo so same-batch duplicates share their SAAT
        level-cut resolution."""
        topk, _, t_bmw, _ = self._stage1_full(terms, mask, routed, {})
        return topk, t_bmw
