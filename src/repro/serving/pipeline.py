"""Unified batched cascade pipeline: Stage-0 → routing → Stage-1 → Stage-2.

The paper's framework spans *all* stages of a multi-stage architecture, and
so does this pipeline: one query batch flows through

* **Stage-0** — feature extraction + Forest inference for all three
  predictors (k, ρ, t) in one fused on-device call: the k/ρ/t ensembles
  are stacked along a model axis (``gbrt.stack_models``) and evaluated
  with ``trees.forest_predict_stacked`` — no per-model numpy round trips.
* **Routing** — the Stage-0 scheduler (Algorithms 1/2 + hedging) as pure
  array ops over the prediction vectors.
* **Stage-1** — the routed sub-batches dispatch through the batched
  ``daat_serve`` / ``saat_serve`` engines (Pallas kernels on TPU, fused
  jnp elsewhere) over one shard's index mirrors.
* **Stage-2** — the batched LTR re-ranker (``rerank_batched``): a (Q, C)
  candidate-grid featurization (CSR binary search or the
  ``qd_feature_gather`` kernel) + one fused GBRT inference + masked top-t.

Latency accounting covers the **cascade**, not just Stage-1: per-stage
arrays (`stage0`/`stage1`/`stage2`) are threaded through the result and
``stats`` reports percentiles / over-budget counts of their sum, which is
what the paper's 200 ms tail guarantee is about end to end.  When an LTR
model is attached, the worst-case Stage-2 cost (``ltr_time(k_serve)`` —
deterministic, since the candidate grid is capped at ``k_serve``) is
*reserved* out of the scheduler's budget, so the late-hedge machinery
enforces Stage-0+1 against the remainder and the end-to-end guarantee
survives re-ranking.

``repro.serving.server.HybridServer`` is a thin compatibility wrapper over
this pipeline (Stage-1 only); ``repro.launch.serve`` runs the full
cascade.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

import jax.numpy as jnp
import numpy as np

from repro.core import features as F
from repro.core import gbrt
from repro.index.builder import InvertedIndex
from repro.index.postings import shard_from_index
from repro.isn.backend import query_lane_budget, resolve_backend
from repro.isn.daat import daat_serve
from repro.isn.saat import saat_serve
from repro.ltr.cascade import CascadeResult, rerank_batched
from repro.ltr.ranker import LTRModel, csr_search_iters, stage2_arrays
from repro.serving.latency import CostModel, over_budget, percentiles
from repro.serving.scheduler import SchedulerConfig, StageZeroScheduler


@dataclass
class PipelineResult:
    """One served batch, end to end."""
    topk: np.ndarray                 # (Q, k_serve) Stage-1 candidates
    final: np.ndarray | None         # (Q, t_final) re-ranked (None: no LTR)
    candidates_used: np.ndarray | None   # (Q,) candidates entering Stage-2
    latency: np.ndarray              # (Q,) full-cascade latency
    stage_latency: dict              # {"stage0"|"stage1"|"stage2": (Q,)}
    stats: dict


class CascadePipeline:
    """The whole multi-stage retrieval cascade as one batched query program.

    Args:
      index: the built collection (both mirrors + Stage-0 stats).
      models: ``{"k": GBRTModel, "rho": ..., "t": ...}`` Stage-0 predictors.
      cfg: scheduler/routing configuration.
      corpus: required when ``ltr`` is given (Stage-2 reads doc topics).
      ltr: Stage-2 point-wise LTR model; None serves Stage-1 only.
      k_serve: Stage-1 retrieval depth (the candidate grid width C).
      t_final: result-list depth after Stage-2.
      backend: Stage-1/Stage-2 kernel backend override
        ("pallas" | "interpret" | "jnp" | None = auto).
    """

    def __init__(self, index: InvertedIndex, models: dict,
                 cfg: SchedulerConfig, *, corpus=None,
                 ltr: LTRModel | None = None, k_serve: int = 128,
                 t_final: int = 10, cost: CostModel | None = None,
                 backend: str | None = None):
        self.index = index
        self.shard, self.spec = shard_from_index(index)
        self.models = models
        self.cost = cost or CostModel.paper_scale()
        self.budget = cfg.budget
        if ltr is not None:
            # reserve the (deterministic) worst-case Stage-2 cost so the
            # scheduler's late-hedge enforces the *cascade* budget
            reserve = float(self.cost.ltr_time(np.asarray(k_serve)))
            cfg = replace(cfg, budget=max(cfg.budget - reserve, 0.0))
        self.sched = StageZeroScheduler(cfg, self.cost)
        self.k_serve = k_serve
        self.t_final = t_final
        self.backend = backend
        self.term_stats = jnp.asarray(index.term_stats)
        self.df = jnp.asarray(index.df)
        # fused Stage-0: one stacked forest when the three ensembles share a
        # shape (the launch path always trains them that way); per-model
        # fallback otherwise — same predictions either way, bit-for-bit.
        try:
            self._stacked, self._stack_depth = gbrt.stack_models(
                [models[n] for n in ("k", "rho", "t")])
        except ValueError:
            self._stacked = None
        self.ltr = ltr
        if ltr is not None:
            if corpus is None:
                raise ValueError("Stage-2 re-ranking needs the corpus "
                                 "(doc topic mixtures)")
            self.s2 = stage2_arrays(index, corpus)
            self.n_iter = csr_search_iters(int(index.df.max()))

    # ------------------------------------------------------------------
    # stages
    # ------------------------------------------------------------------

    def stage0(self, terms: np.ndarray, mask: np.ndarray):
        """All three predictions in one fused device call: (pk, pr, pt)."""
        x = F.extract(self.term_stats, self.df, jnp.asarray(terms),
                      jnp.asarray(mask))
        if self._stacked is not None:
            p = np.expm1(np.asarray(
                gbrt.predict_stacked(self._stacked, x, self._stack_depth)))
            return p[0], p[1], p[2]
        return tuple(np.expm1(np.asarray(gbrt.predict(self.models[n], x)))
                     for n in ("k", "rho", "t"))

    def stage1(self, terms: np.ndarray, mask: np.ndarray, routed):
        """Dispatch the routed sub-batches through the batched engines.

        Returns (topk, t_bmw, jass_time_fn) — the scheduler folds the times
        into per-query latency under hedging semantics."""
        q = terms.shape[0]
        topk = np.zeros((q, self.k_serve), np.int64)
        t_bmw = np.zeros(q)

        if len(routed.jass_rows):
            rows = routed.jass_rows
            res = saat_serve(self.shard, jnp.asarray(terms[rows]),
                             jnp.asarray(mask[rows]),
                             jnp.asarray(routed.rho[rows]),
                             n_docs=self.spec.n_docs, k=self.k_serve,
                             cap=int(self.sched.cfg.rho_max),
                             tile_d=self.spec.tile_d, backend=self.backend)
            topk[rows] = np.asarray(res.topk_docs)
        if len(routed.bmw_rows):
            rows = routed.bmw_rows
            qcap = query_lane_budget(self.index.df, terms[rows], mask[rows])
            res = daat_serve(self.shard, jnp.asarray(terms[rows]),
                             jnp.asarray(mask[rows]),
                             jnp.ones(len(rows), jnp.float32),
                             n_docs=self.spec.n_docs,
                             n_blocks=self.spec.n_blocks,
                             block_size=self.spec.block_size, k=self.k_serve,
                             cap=self.spec.max_df,
                             bcap=self.spec.max_blocks_per_term, qcap=qcap,
                             tile_d=self.spec.tile_d, backend=self.backend)
            topk[rows] = np.asarray(res.topk_docs)
            t_bmw[rows] = self.cost.daat_time(np.asarray(res.work),
                                              np.asarray(res.blocks))
        return topk, t_bmw

    def _jass_time(self, terms, mask):
        """Deterministic JASS time: the ρ budget resolves to a level cut;
        time follows the cut's work — one vectorized reduction per call."""
        def fn(rows, rho):
            lc = self.index.level_cum[terms[rows]]
            lc = lc * (mask[rows] > 0)[:, :, None]
            total = lc.sum(axis=1)                       # (R, n_levels)
            ok = total <= np.asarray(rho).reshape(-1, 1)
            lstar = np.argmax(ok, axis=1)
            w = np.where(ok.any(axis=1),
                         np.take_along_axis(total, lstar[:, None],
                                            axis=1)[:, 0], 0)
            return self.cost.saat_time(w.astype(np.float64))
        return fn

    def stage2(self, terms, mask, topics, cand, k_per_query) -> CascadeResult:
        """Batched LTR re-rank of the Stage-1 candidate grid."""
        backend = resolve_backend(self.backend)
        qcap = None
        if backend != "jnp":
            qcap = query_lane_budget(self.index.df, terms, mask)
        return rerank_batched(self.s2, self.ltr, terms, mask, topics,
                              cand, k_per_query, t_final=self.t_final,
                              n_iter=self.n_iter, backend=backend, qcap=qcap,
                              lane_need=qcap)

    # ------------------------------------------------------------------
    # end to end
    # ------------------------------------------------------------------

    def serve(self, terms: np.ndarray, mask: np.ndarray,
              topics: np.ndarray | None = None) -> PipelineResult:
        q = terms.shape[0]
        pk, pr, pt = self.stage0(terms, mask)
        routed = self.sched.route(pk, pr, pt)
        topk, t_bmw = self.stage1(terms, mask, routed)

        lat01 = self.sched.resolve_times(routed, t_bmw,
                                         self._jass_time(terms, mask))
        t0 = np.full(q, self.cost.predict_us)
        stage_latency = {"stage0": t0, "stage1": lat01 - t0}

        final = None
        used = None
        if self.ltr is not None:
            if topics is None:
                raise ValueError("Stage-2 re-ranking needs per-query topics")
            k2 = np.minimum(routed.k, self.k_serve)
            res2 = self.stage2(terms, mask, topics, topk.astype(np.int32), k2)
            final, used = res2.final, res2.candidates_used
            stage_latency["stage2"] = self.cost.ltr_time(used)
        else:
            stage_latency["stage2"] = np.zeros(q)

        lat = lat01 + stage_latency["stage2"]
        stats = dict(self.sched.stats)
        stats.update(percentiles(lat))
        n_over, pct = over_budget(lat, self.budget)
        stats["over_budget"] = n_over
        stats["over_budget_pct"] = pct
        stats["stages"] = {name: percentiles(t)
                           for name, t in stage_latency.items()
                           if np.any(t > 0)}
        return PipelineResult(topk=topk, final=final, candidates_used=used,
                              latency=lat, stage_latency=stage_latency,
                              stats=stats)
