"""Dynamic micro-batcher: form Stage-1 batches under a deadline/size policy.

The batched engines amortize dispatch across the Q axis, but an online
server cannot wait for a full batch at low load — the classic dynamic
batching trade-off (cf. the Kuaishou pre-ranking serving stack,
arXiv:2304.02434).  Policy here:

* a batch **closes** as soon as ``max_batch`` admitted queries are waiting,
  or when the *oldest* waiting query has waited ``batch_deadline_us``
  (whichever comes first), but never before the server is free;
* a closed batch is **padded** up to the next power-of-two Q bucket
  (``OnlineSpec.bucket_q``) by replicating a real query, so the engines see
  a handful of distinct ``(Q, n_tiles)`` grid shapes instead of one per
  batch size — the Q-axis analogue of the posting-lane rounding in
  ``isn.backend.query_lane_budget``.  Pads are served (their work is real
  in a deployment) and dropped from per-query results.

Because every stage of the cascade is row-independent on the jnp backend,
a query's top-k is bit-identical whether it is served alone, in any batch,
or next to pad rows — certified by ``benchmarks/bench_online.py``.
"""

from __future__ import annotations

import numpy as np

from repro.serving.spec import OnlineSpec


def bucket_size(n: int, max_batch: int, bucket_q: bool = True) -> int:
    """The padded Q width for a batch of ``n`` real queries: the next
    power of two, capped at ``max_batch`` (identity when bucketing is
    off)."""
    if n < 1:
        raise ValueError("empty batch")
    if n > max_batch:
        raise ValueError(f"batch of {n} exceeds max_batch={max_batch}")
    if not bucket_q:
        return n
    return min(1 << int(np.ceil(np.log2(n))), max_batch)


def pad_batch(rows: np.ndarray, max_batch: int,
              bucket_q: bool = True) -> tuple[np.ndarray, int]:
    """(padded row indices, n_real): pads replicate ``rows[0]`` — a real
    query, so the batch max service time (device occupancy) is unchanged
    and row-independent stages are unaffected."""
    rows = np.asarray(rows, np.int64)
    n = len(rows)
    width = bucket_size(n, max_batch, bucket_q)
    if width == n:
        return rows, n
    return np.concatenate([rows, np.full(width - n, rows[0], np.int64)]), n


class MicroBatcher:
    """Incremental batch former over an arrival-ordered queue.

    The simulator owns the clock and the queue; this class answers one
    question — *when does the next batch close, and with which queries?* —
    via :meth:`close`.  Kept separate so the policy is testable without an
    event loop.
    """

    def __init__(self, cfg: OnlineSpec):
        cfg.validate()
        self.cfg = cfg

    def export_metrics(self, reg) -> None:
        """Mirror the batching policy knobs into a telemetry registry (so
        a snapshot names the operating point it was taken under)."""
        reg.gauge("batcher", key="max_batch").set(self.cfg.max_batch)
        reg.gauge("batcher", key="batch_deadline_us").set(
            self.cfg.batch_deadline_us)
        reg.gauge("batcher", key="bucket_q").set(
            1.0 if self.cfg.bucket_q else 0.0)
        reg.gauge("batcher", key="dispatch_us").set(self.cfg.dispatch_us)

    def deadline(self, oldest_arrival: float, server_free: float) -> float:
        """Latest close time for a non-full batch headed by a query that
        arrived at ``oldest_arrival``: its deadline, or the moment the
        server frees up, whichever is later (a busy server extends the
        window — waiting costs nothing while the device is occupied)."""
        return max(oldest_arrival + self.cfg.batch_deadline_us, server_free)

    def close(self, pending_arrivals: np.ndarray,
              server_free: float) -> tuple[int, float]:
        """(batch size, close time) for the current queue.

        ``pending_arrivals`` are the arrival times of queued queries in
        order; the head must exist.  Returns how many queries the next
        batch takes and the virtual time it closes."""
        arr = np.asarray(pending_arrivals, np.float64)
        if arr.size == 0:
            raise ValueError("close() needs a non-empty queue")
        if arr.size >= self.cfg.max_batch:
            # full batch: closes as soon as its last member is here and
            # the server is free
            take = self.cfg.max_batch
            return take, max(float(arr[take - 1]), server_free)
        return int(arr.size), self.deadline(float(arr[0]), server_free)
