"""Online traffic subsystem: event-driven arrivals, dynamic micro-batching,
and admission control — the layer that turns the cascade's *service-time*
guarantees into **response-time** guarantees under load.

    from repro.serving.online import simulate, estimate_capacity
    from repro.serving.spec import TrafficSpec

    res = system.serve_online(ql.terms, ql.mask, ql.topic,
                              traffic=TrafficSpec(arrival="poisson",
                                                  qps=120.0))
    res.stats["response"]["p99.99"], res.stats["over_budget"]

See ``traffic`` (arrival processes), ``batcher`` (micro-batch policy),
``admission`` (degrade/shed ladder), and ``simulator`` (the event loop).
"""

from repro.serving.online.admission import (FULL, MODE_NAMES, PARTIAL, SHED,
                                            STAGE1, TRIM,
                                            AdmissionController)
from repro.serving.online.batcher import (MicroBatcher, bucket_size,
                                          pad_batch)
from repro.serving.online.simulator import (OnlineResult, estimate_capacity,
                                            fresh_probe, simulate)
from repro.serving.online.traffic import (arrival_times, load_trace,
                                          zipf_query_mix)

__all__ = [
    "AdmissionController", "FULL", "MODE_NAMES", "MicroBatcher",
    "OnlineResult", "PARTIAL", "SHED", "STAGE1", "TRIM", "arrival_times",
    "bucket_size", "estimate_capacity", "fresh_probe", "load_trace",
    "pad_batch", "simulate", "zipf_query_mix",
]
