"""Arrival-process generators: timestamped query streams from a seeded PRNG.

The paper's 99.99 % claim is about a system under *continuous load* —
response time = queueing delay + service time — so the online subsystem
needs arrival processes whose burstiness actually stresses the queue, not
just a pre-formed batch.  Four generators, all deterministic in
``TrafficSpec.seed``:

* **poisson** — memoryless baseline (exponential interarrivals at ``qps``);
* **bursty** — 2-state MMPP: a burst state at ``qps * burst_factor`` and a
  quiet state whose rate is solved so the long-run mean stays ``qps``;
  exponential dwell times.  This is the tail-stressing workload: queue
  depth during a burst is what admission control exists for;
* **diurnal** — sinusoidal rate ramp ``qps * (1 + a*sin(2πt/period))``
  sampled by thinning against the peak rate (a compressed day cycle);
* **trace** — replay recorded timestamps from a JSON list or ``.npy``
  array, shifted to start at 0.

Timestamps are in cost-model time units (ms at ``CostModel.paper_scale``);
``qps`` is queries per 1000 units, i.e. literally queries/second there.
"""

from __future__ import annotations

import json

import numpy as np

from repro.serving.spec import TrafficSpec

_KILO = 1000.0  # time units per "second" (the qps denominator)


def _poisson(rng: np.random.RandomState, n: int, qps: float) -> np.ndarray:
    return np.cumsum(rng.exponential(_KILO / qps, size=n))


def _bursty(rng: np.random.RandomState, n: int, spec: TrafficSpec
            ) -> np.ndarray:
    """2-state Markov-modulated Poisson process.

    Long-run mean rate:  f·r_hi + (1-f)·r_lo = qps  with
    r_hi = qps·burst_factor, so r_lo = qps·(1 - f·burst_factor)/(1 - f)
    (positive by ``TrafficSpec.validate``).  Dwell means follow the
    stationary fractions: burst dwell ``burst_dwell_us``, quiet dwell
    ``burst_dwell_us · (1-f)/f``.
    """
    f = spec.burst_fraction
    r_hi = spec.qps * spec.burst_factor / _KILO
    r_lo = spec.qps * (1.0 - f * spec.burst_factor) / (1.0 - f) / _KILO
    dwell = {True: spec.burst_dwell_us,
             False: spec.burst_dwell_us * (1.0 - f) / f}
    out = np.empty(n)
    t, got, burst = 0.0, 0, False
    seg_end = rng.exponential(dwell[burst])
    while got < n:
        # exponential interarrival at the current state's rate; a gap that
        # crosses the state boundary is redrawn from the boundary at the
        # new rate (memorylessness makes this exact for a piecewise-
        # constant-rate Poisson process)
        gap = rng.exponential(1.0 / (r_hi if burst else r_lo))
        if t + gap > seg_end:
            t = seg_end
            burst = not burst
            seg_end = t + rng.exponential(dwell[burst])
            continue
        t += gap
        out[got] = t
        got += 1
    return out


def _diurnal(rng: np.random.RandomState, n: int, spec: TrafficSpec
             ) -> np.ndarray:
    """Thinning against the peak rate ``qps * (1 + amplitude)``."""
    peak = spec.qps * (1.0 + spec.diurnal_amplitude) / _KILO
    out = np.empty(n)
    t, got = 0.0, 0
    while got < n:
        t += rng.exponential(1.0 / peak)
        rate = (spec.qps / _KILO) * (1.0 + spec.diurnal_amplitude
                                     * np.sin(2.0 * np.pi * t
                                              / spec.diurnal_period_us))
        if rng.random_sample() * peak <= rate:
            out[got] = t
            got += 1
    return out


def load_trace(path: str) -> np.ndarray:
    """Recorded arrival timestamps from a ``.npy`` array or a JSON list."""
    if path.endswith(".npy"):
        ts = np.load(path)
    else:
        with open(path) as f:
            ts = np.asarray(json.load(f), np.float64)
    return np.asarray(ts, np.float64).ravel()


def zipf_query_mix(spec: TrafficSpec, n: int,
                   n_unique: int | None = None) -> np.ndarray:
    """``n`` query-log row indices with Zipfian repetition: arrival ``j``
    serves log row ``out[j]``, drawn with probability ∝ 1/rank^skew over
    the first ``n_unique`` rows (default: all of them).  This is the
    *identity* half of a production workload — a small head of queries
    repeating constantly — composable with ANY arrival process above:
    identities are drawn from their own seeded stream
    (``seed + 0x5EED``), so toggling ``skew`` never moves a timestamp.

    ``skew <= 0`` returns the uniform in-order replay ``arange(n) %
    n_unique`` — the historical behavior, bit-identical and RNG-free.
    """
    spec.validate()
    if n < 1:
        raise ValueError("need n >= 1 arrivals")
    n_unique = int(n_unique) if n_unique is not None else int(n)
    if n_unique < 1:
        raise ValueError("need n_unique >= 1 distinct queries")
    if spec.skew <= 0:
        return np.arange(n, dtype=np.int64) % n_unique
    ranks = np.arange(1, n_unique + 1, dtype=np.float64)
    p = ranks ** -float(spec.skew)
    p /= p.sum()
    rng = np.random.RandomState(spec.seed + 0x5EED)
    return rng.choice(n_unique, size=n, p=p).astype(np.int64)


def feed_arrival_times(ingest, n: int) -> np.ndarray:
    """``n`` non-decreasing feed-batch arrival timestamps for an
    :class:`~repro.serving.spec.IngestSpec` — a Poisson process at
    ``feed_qps`` (batches per 1000 time units), drawn from its own seeded
    stream (``seed + 0xFEED``, the same independence discipline as
    ``zipf_query_mix``) so toggling ingest never moves a query timestamp."""
    if n < 1:
        raise ValueError("need n >= 1 feed arrivals")
    rng = np.random.RandomState(int(ingest.seed) + 0xFEED)
    return np.maximum.accumulate(_poisson(rng, n, float(ingest.feed_qps)))


def arrival_times(spec: TrafficSpec, n: int) -> np.ndarray:
    """``n`` non-decreasing arrival timestamps for the process ``spec``
    names, starting at >= 0.  Deterministic in ``spec.seed``."""
    spec.validate()
    if n < 1:
        raise ValueError("need n >= 1 arrivals")
    if spec.arrival == "trace":
        ts = load_trace(spec.trace_path)
        if len(ts) < n:
            raise ValueError(f"trace {spec.trace_path!r} has {len(ts)} "
                             f"timestamps, need {n}")
        ts = np.sort(ts[:n])
        return ts - ts[0]
    rng = np.random.RandomState(spec.seed)
    if spec.arrival == "poisson":
        out = _poisson(rng, n, spec.qps)
    elif spec.arrival == "bursty":
        out = _bursty(rng, n, spec)
    else:
        out = _diurnal(rng, n, spec)
    return np.maximum.accumulate(out)  # guard fp monotonicity
