"""Event-driven online serving simulator: queueing + dynamic micro-batching
+ admission control around ``SearchSystem``, under one virtual clock.

This is the layer that upgrades every guarantee in the repo from
*service-time of a pre-formed batch* to **response time under load**:

    response = queueing delay + dispatch + service

The loop is a discrete-event simulation in cost-model time units (ms at
``CostModel.paper_scale``).  Arrivals come from a seeded
:class:`~repro.serving.spec.TrafficSpec` process; the
:class:`~repro.serving.online.batcher.MicroBatcher` closes batches under
the ``batch_deadline_us`` / ``max_batch`` policy; the
:class:`~repro.serving.online.admission.AdmissionController` degrades
(trimmed Stage-2 → stage1-only) or sheds queries whose wait already ate the
response budget; each closed batch is padded to a power-of-two Q bucket and
served through ``SearchSystem.serve`` — so queueing delay threads straight
through the existing per-query latency accounting (``CostModel``, per-stage
arrays) and the ``ReplicaPool`` EWMA feedback, which keeps adapting online
exactly as in offline serving.

Occupancy model: the batched engines process a batch in lockstep, so the
device is occupied for ``dispatch_us + max(service)`` while per-query
completions land at ``start + dispatch_us + service_i`` (results stream out
of the gather as they finish).  Everything is deterministic in
``(TrafficSpec.seed, DeploySpec.seed)``: same spec pair → bit-identical
event log and percentiles.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.serving.latency import over_budget, percentiles
from repro.serving.online.admission import (FULL, MODE_NAMES, SHED,
                                            AdmissionController)
from repro.serving.online.batcher import MicroBatcher, pad_batch
from repro.serving.online.traffic import (arrival_times, feed_arrival_times,
                                          zipf_query_mix)
from repro.serving.spec import OnlineSpec, TrafficSpec
from repro.serving.telemetry import QueryTrace, Span

_NOT_SERVED = -1.0  # sentinel in per-query arrays / the event log (not NaN:
                    # the determinism contract is tuple equality)
INGEST_EVENT = -3   # event-log qid marker: an applied feed batch
MERGE_EVENT = -4    # event-log qid marker: a background merge (reseal)


@dataclass
class OnlineResult:
    """One simulated trace, end to end (arrays indexed by query id)."""
    arrival: np.ndarray          # (Q,) arrival timestamps
    wait: np.ndarray             # (Q,) queueing delay (-1 = shed at arrival)
    service: np.ndarray          # (Q,) service time (-1 = shed)
    completion: np.ndarray       # (Q,) completion timestamp (-1 = shed)
    response: np.ndarray         # (Q,) completion - arrival (-1 = shed)
    mode: np.ndarray             # (Q,) FULL|TRIM|STAGE1|PARTIAL|SHED
    batch_of: np.ndarray         # (Q,) batch id (-1 = shed, -2 = answered
                                 # at the front door by an L1 cache hit)
    topk: np.ndarray             # (Q, k_serve) Stage-1 candidates (-1 = shed)
    final: np.ndarray | None     # (Q, t_final) re-ranked (None: no LTR)
    event_log: list = field(default_factory=list)
    # event_log rows: (qid, batch_id, arrival, start, wait, service,
    #                  completion, mode) — plain floats/ints, bit-comparable
    stats: dict = field(default_factory=dict)
    coverage: np.ndarray | None = None   # (Q,) fraction of partitions that
                                         # answered (-1 = shed; None: the
                                         # fault/partial path never engaged)


def simulate(system, terms: np.ndarray, mask: np.ndarray,
             topics: np.ndarray | None, traffic: TrafficSpec,
             online: OnlineSpec | None = None) -> OnlineResult:
    """Serve the whole query log through the online event loop."""
    online = online if online is not None else system.cascade_spec.online
    online.validate()
    q = len(terms)
    arr = arrival_times(traffic, q)
    if traffic.skew > 0:
        # Zipfian repetition: arrival j serves log row mix[j] (identities
        # drawn from their own seeded stream, so the timestamps above are
        # untouched).  skew=0 keeps the in-order replay bit-identical.
        mix = zipf_query_mix(traffic, q)
        terms = terms[mix]
        mask = mask[mix]
        if topics is not None:
            topics = topics[mix]
    batcher = MicroBatcher(online)
    k_serve = system.k_serve if system.ltr is not None else None
    reserve2 = system._budget_reserve["stage2"]
    stage1_bound = system.worst_case_us() - reserve2
    budget_r = online.response_budget_us or 2.0 * system.budget
    ns = system.n_shards
    # partial-coverage rung: per-shard-count Stage-1 bounds.  Only offered
    # when narrowing the fan-out actually buys back bound time (multi-shard
    # + nonzero merge overhead); otherwise the ladder is exactly as before.
    partial_bounds = None
    if ns > 1 and system.cost.gather_per_shard_us > 0:
        partial_bounds = [system.sched.cfg.worst_case_us(system.cost, m)
                          for m in range(1, ns + 1)]
    cache_on = getattr(system, "cache", None) is not None
    dense_on = getattr(system, "dense", None) is not None
    # a guaranteed L1 hit bypasses the cascade: its hard service bound is
    # just prediction + lookup — the cache rung of the admission ladder
    hit_bound = (system.cost.predict_us + system.cost.cache_hit_us
                 if cache_on else None)
    adm = (AdmissionController(online, system.cost, stage1_bound, k_serve,
                               budget_r, partial_bounds=partial_bounds,
                               cache_bound=hit_bound,
                               hit_alpha=(system.cache.spec.hit_alpha
                                          if cache_on else 0.2))
           if online.admission else None)

    mode = np.full(q, SHED, np.int64)
    wait = np.full(q, _NOT_SERVED)
    service = np.full(q, _NOT_SERVED)
    completion = np.full(q, _NOT_SERVED)
    batch_of = np.full(q, -1, np.int64)
    topk = np.full((q, system.k_serve), -1, np.int64)
    final = (np.full((q, system.t_final), -1, np.int64)
             if system.ltr is not None else None)
    faulted = system.faults.active or partial_bounds is not None
    coverage = np.full(q, _NOT_SERVED) if faulted else None
    stage_acc: dict = {}
    events: list = []
    batch_meta: list = []
    dense_acc = {"lexical": 0, "dense_only": 0, "fused": 0,
                 "theta_skips": 0, "fallbacks": 0}

    def count_dense(info: dict | None, n: int) -> None:
        # only the real rows — batch padding duplicates a row's modality
        if not dense_on or info is None:
            return
        m = np.asarray(info["modality"][:n])
        dense_acc["lexical"] += int(np.sum(m == 0))
        dense_acc["dense_only"] += int(np.sum(m == 1))
        dense_acc["fused"] += int(np.sum(m == 2))
        dense_acc["theta_skips"] += int(np.sum(info["theta_skip"][:n]))
        dense_acc["fallbacks"] += int(np.sum(info["fallback"][:n]))

    pending: list[int] = []
    t_free = 0.0
    i = 0
    n_front = 0

    # ---- telemetry (inert when the spec leaves it disabled: tel is None
    # and every hook below is skipped, so the event log, per-query arrays
    # and stats keys are bit-identical to the pre-telemetry simulator)
    tel = getattr(system, "telemetry", None)
    if tel is not None:
        tel.attach_online(adm, batcher)
        tel.registry.gauge("response_budget_us").set(budget_r)

    def tel_shed(qid: int, where: str, w: float, now: float) -> None:
        """Shed counters + a minimal trace naming the admission rung.
        A shed is a failure to serve — it ranks as a violation in the
        trace reservoir (else zero-latency shed rows could never compete
        with served queries for a slot)."""
        tel.registry.counter("shed_queries", where=where).inc()
        if tel.traces.would_keep(w, True):
            root = Span("query")
            root.child("admission", 0.0, 0.0, decision="shed", where=where)
            tel.traces.offer(QueryTrace(
                qid=int(qid), clock_us=float(now), latency_us=float(w),
                budget_us=budget_r, violation=True, root=root,
                meta={"mode": "shed", "where": where,
                      "wait_us": float(w), "service_us": 0.0}))

    # ---- live ingest: a seeded feed-arrival process on the same virtual
    # clock.  Feed batches and background merges charge the server's
    # t_free (they occupy the engine host), and both are gated by the
    # admission controller's backpressure ladder: merges defer to load,
    # the feed throttles before queries shed.  With ingest disabled this
    # whole block is inert — no arrivals, no events, no clock charges.
    ingest_on = getattr(system, "delta", None) is not None
    feed_times = np.zeros(0)
    full_feed = None
    fi = 0
    if ingest_on:
        from repro.index.corpus import slice_feed, synthesize_feed_docs
        if system.corpus is None:
            raise ValueError("online ingest needs the corpus the sealed "
                             "index was built from")
        ing = system.cascade_spec.ingest
        fb = ing.feed_batch
        horizon = float(arr[-1])
        n_feed = max(1, int(horizon * ing.feed_qps / 1000.0 * 2.0) + 4)
        feed_times = feed_arrival_times(ing, n_feed)
        feed_times = feed_times[feed_times <= horizon]
        if len(feed_times):
            full_feed = synthesize_feed_docs(system.corpus,
                                             int(len(feed_times)) * fb,
                                             seed=ing.seed)

    def run_ingest(now: float) -> None:
        """Apply every due feed batch (and any merge it needs) at ``now``."""
        nonlocal fi, t_free
        if not ingest_on:
            return
        while fi < len(feed_times) and feed_times[fi] <= now:
            t_feed = float(feed_times[fi])
            batch = slice_feed(full_feed, fi * fb, (fi + 1) * fb)
            # merge first when the delta is past its threshold — or cannot
            # take this batch at all (then the merge is forced through)
            need = system.delta.admit_count(batch) < batch.n_docs
            if ((need or system.delta.fill >= ing.merge_threshold)
                    and system.delta.n_docs):
                ok = (adm.merge_gate(now, t_free, len(pending), full=need)
                      if adm is not None else True)
                if ok:
                    merged = system.merge()
                    t_start = max(t_free, now)
                    t_free = t_start + ing.merge_us
                    events.append((MERGE_EVENT, MERGE_EVENT, t_feed,
                                   t_start, 0.0, float(ing.merge_us),
                                   float(t_free), int(merged)))
                elif need:
                    return      # feed blocked until a merge is allowed
            if adm is not None and not adm.feed_gate(
                    t_feed, t_free, len(pending), pause_us=ing.ingest_us):
                return          # throttled: this batch retries later
            took = system.add_documents(batch)
            t_start = max(t_free, now)
            t_free = t_start + ing.ingest_us
            events.append((INGEST_EVENT, int(fi), t_feed, t_start,
                           float(t_start - t_feed), float(ing.ingest_us),
                           float(t_free), int(took)))
            fi += 1

    def admit(qid: int) -> None:
        nonlocal n_front
        run_ingest(float(arr[qid]))
        if cache_on:
            # front-door lookup at arrival: an exact-result L1 hit is
            # answered from the broker's memory (prediction + probe) and
            # never consumes an engine-batch slot — this is where caching
            # buys capacity, since batch occupancy is a max over rows.
            # The peek and the serve below share the clock ``arr[qid]``
            # (same fault epoch, no intervening fills), so the peek's
            # verdict is binding.
            t_arr = float(arr[qid])
            hit = system.cache_peek(
                terms[qid:qid + 1], mask[qid:qid + 1],
                topics[qid:qid + 1] if system.ltr is not None else None,
                now=t_arr)
            if bool(hit[0]):
                if tel is not None:
                    tel.batch_context = {"qid": np.array([qid]),
                                         "wait": np.zeros(1),
                                         "mode": np.array(["full"]),
                                         "budget": budget_r}
                res = system.serve(
                    terms[qid:qid + 1], mask[qid:qid + 1],
                    topics[qid:qid + 1] if system.ltr is not None else None,
                    now=t_arr)
                if tel is not None:
                    tel.batch_context = None
                    tel.registry.counter("front_door_hits").inc()
                    tel.registry.histogram("response_latency_us").observe(
                        res.latency[0])
                svc = float(res.latency[0])
                mode[qid] = FULL
                wait[qid] = 0.0
                service[qid] = svc
                completion[qid] = t_arr + svc
                batch_of[qid] = -2          # -2 = served at the front door
                topk[qid] = res.topk[0]
                if final is not None and res.final is not None:
                    final[qid] = res.final[0]
                if coverage is not None:
                    coverage[qid] = 1.0
                for name, t in res.stage_latency.items():
                    stage_acc.setdefault(name, []).append(
                        np.asarray(t, np.float64))
                count_dense(res.dense, 1)
                events.append((qid, -2, t_arr, t_arr, 0.0, svc,
                               float(completion[qid]), FULL))
                n_front += 1
                if adm is not None:
                    adm.observe_hits(1, 1)
                return
        ok = (adm.at_arrival(float(arr[qid]), t_free, len(pending))
              if adm is not None else True)
        if ok:
            pending.append(qid)
        else:
            events.append((qid, -1, float(arr[qid]), _NOT_SERVED,
                           _NOT_SERVED, _NOT_SERVED, _NOT_SERVED, SHED))
            if tel is not None:
                tel_shed(qid, "arrival", 0.0, float(arr[qid]))

    def dispatch(rows: np.ndarray, t_start: float) -> None:
        nonlocal t_free
        run_ingest(t_start)
        # an ingest/merge pause that ran past the close pushes the batch
        # start back: the extra wait is real and the admission ladder
        # prices it (feed work degrades queries honestly, never silently)
        t_start = max(t_start, t_free)
        waits = t_start - arr[rows]
        hits = None
        if cache_on:
            # dispatch-time peek at the same clock serve() will run at —
            # no recency moves, no RNG, so replay stays deterministic
            hits = system.cache_peek(
                terms[rows], mask[rows],
                topics[rows] if system.ltr is not None else None,
                now=float(t_start))
        if adm is not None:
            m, cap, scap = adm.at_dispatch(waits, hits)
        else:
            m = np.full(len(rows), FULL, np.int64)
            cap = None
            scap = None
        mode[rows] = m
        wait[rows] = waits
        keep = m != SHED
        for r, w in zip(rows[~keep], waits[~keep]):
            events.append((int(r), -1, float(arr[r]), float(t_start),
                           float(w), _NOT_SERVED, _NOT_SERVED, SHED))
            if tel is not None:
                tel_shed(int(r), "dispatch", float(w), float(t_start))
        if not keep.any():
            return
        served = rows[keep]
        padded, n_real = pad_batch(served, online.max_batch, online.bucket_q)
        if tel is not None:
            # queue state at batch close: this batch + whatever is still
            # waiting behind it
            depth = len(rows) + len(pending)
            tel.registry.gauge("queue_depth").set(depth)
            tel.registry.histogram("queue_depth_at_close").observe(depth)
            n_pad = len(padded) - n_real
            w_k = waits[keep]
            m_k = m[keep]
            # pad rows replicate a real query: qid=-1 keeps them out of
            # the trace reservoir (their metrics rows are sliced off by
            # [:n_real] everywhere else)
            qids = padded.copy()
            qids[n_real:] = -1
            tel.batch_context = {
                "wait": np.concatenate([w_k, np.full(n_pad, w_k[0])]),
                "mode": np.array(
                    [MODE_NAMES[int(x)] for x in
                     np.concatenate([m_k,
                                     np.full(n_pad, m_k[0], np.int64)])]),
                "qid": qids,
                "budget": budget_r,
            }
        cap_p = None
        if cap is not None and k_serve is not None:
            cap_k = cap[keep]
            cap_p = np.concatenate(
                [cap_k, np.full(len(padded) - n_real, cap_k[0], np.int64)])
        shard_p = None
        if scap is not None and bool((scap[keep] < ns).any()):
            sc_k = scap[keep]
            shard_p = np.concatenate(
                [sc_k, np.full(len(padded) - n_real, sc_k[0], np.int64)])
        if cache_on:
            c_pre = (system.cache.counters["l1_hits"],
                     system.cache.counters["lookups"])
        res = system.serve(terms[padded], mask[padded],
                           topics[padded] if system.ltr is not None
                           else None, stage2_cap=cap_p, shard_cap=shard_p,
                           now=float(t_start))
        if cache_on and adm is not None:
            # feed the batch's realized hit ratio into the admission EWMA
            adm.observe_hits(
                system.cache.counters["l1_hits"] - c_pre[0],
                system.cache.counters["lookups"] - c_pre[1])
        bid = len(batch_meta)
        svc = np.asarray(res.latency[:n_real], np.float64)
        occupancy = online.dispatch_us + float(np.max(res.latency))
        service[served] = svc
        completion[served] = t_start + online.dispatch_us + svc
        batch_of[served] = bid
        topk[served] = res.topk[:n_real]
        if coverage is not None:
            coverage[served] = (res.coverage[:n_real]
                                if res.coverage is not None else 1.0)
        if final is not None and res.final is not None:
            final[served] = res.final[:n_real]
        for name, t in res.stage_latency.items():
            stage_acc.setdefault(name, []).append(
                np.asarray(t[:n_real], np.float64))
        count_dense(res.dense, n_real)
        for j, r in enumerate(served):
            events.append((int(r), bid, float(arr[r]), float(t_start),
                           float(t_start - arr[r]), float(svc[j]),
                           float(completion[r]), int(m[keep][j])))
        batch_meta.append({"size": int(n_real), "width": int(len(padded)),
                           "start": float(t_start),
                           "occupancy": float(occupancy)})
        t_free = t_start + occupancy
        if adm is not None:
            adm.observe_batch(occupancy)
        if tel is not None:
            tel.batch_context = None
            reg = tel.registry
            reg.histogram("queue_wait_us").observe(waits[keep])
            reg.histogram("response_latency_us").observe(
                waits[keep] + online.dispatch_us + svc)
            reg.histogram("batch_occupancy_us").observe(occupancy)
            for x in m[keep]:
                reg.counter("served_mode", mode=MODE_NAMES[int(x)]).inc()
            tel.maybe_snapshot(system, t_free)

    while i < q or pending:
        if not pending:
            admit(i)
            i += 1
            continue
        # pull in every arrival that lands before the batch would close —
        # the queue is NOT capped at max_batch, so a long occupancy builds
        # real backlog (that depth is what arrival-time admission and
        # queue_cap act on); each admission can re-shape the close (a
        # filling batch closes earlier, a shed leaves it open)
        while True:
            take, t_close = batcher.close(arr[pending], t_free)
            if i < q and arr[i] <= t_close:
                admit(i)
                i += 1
                continue
            break
        rows = np.asarray(pending[:take], np.int64)
        del pending[:take]
        dispatch(rows, t_close)

    served_rows = np.flatnonzero(mode != SHED)
    resp = np.full(q, _NOT_SERVED)
    resp[served_rows] = (completion[served_rows] - arr[served_rows])
    n_over, pct = over_budget(resp[served_rows], budget_r)
    stats = {
        "n_queries": q,
        "served": int(len(served_rows)),
        "shed": int(q - len(served_rows)),
        "shed_pct": 100.0 * (q - len(served_rows)) / q,
        "response_budget": float(budget_r),
        "over_budget": n_over,
        "over_budget_pct": pct,
        "modes": {MODE_NAMES[k]: int(np.sum(mode == k)) for k in MODE_NAMES},
        "batches": len(batch_meta),
        "traffic": traffic.to_dict(),
        "admission": dict(adm.stats) if adm is not None else None,
        "worst_case_bound": float(system.worst_case_us()),
    }
    if cache_on:
        stats["cache"] = system.cache.stats()
        stats["cache"]["front_door_hits"] = n_front
        if adm is not None:
            stats["cache"]["hit_ewma"] = float(adm.hit_ewma)
    if dense_on:
        stats["dense"] = dense_acc
    if ingest_on:
        stats["ingest"] = system.stats()["ingest"]
        stats["ingest"]["feed_batches_due"] = int(len(feed_times))
        stats["ingest"]["feed_batches_applied"] = int(fi)
        if adm is not None:
            for key in ("feed_applied", "feed_throttled", "merges_applied",
                        "merges_forced", "merge_deferred"):
                stats["ingest"][key] = int(adm.stats[key])
    if faulted:
        if system.faults.active:
            stats["faults"] = dict(system._fault_counters)
        cov = coverage[served_rows]
        stats["coverage"] = {
            "min": float(cov.min()) if len(cov) else 1.0,
            "mean": float(cov.mean()) if len(cov) else 1.0,
            "degraded": int(np.sum((cov >= 0) & (cov < 1.0))),
        }
    makespan = float(arr[-1] - arr[0]) if q > 1 else 0.0
    if makespan > 0:
        stats["offered_qps"] = 1000.0 * q / makespan
    if len(served_rows):
        stats["response"] = percentiles(resp[served_rows])
        stages = {"queue": percentiles(wait[served_rows])}
        for name, chunks in stage_acc.items():
            t = np.concatenate(chunks)
            if np.any(t > 0):
                stages[name] = percentiles(t)
        stats["stages"] = stages
        span = float(completion[served_rows].max())
        if span > 0:
            stats["achieved_qps"] = 1000.0 * len(served_rows) / span
    if batch_meta:
        sizes = np.asarray([b["size"] for b in batch_meta], np.float64)
        occ = np.asarray([b["occupancy"] for b in batch_meta], np.float64)
        stats["batch"] = {"count": len(batch_meta),
                          "mean_size": float(sizes.mean()),
                          "max_size": int(sizes.max()),
                          "mean_occupancy": float(occ.mean())}
    if tel is not None:
        stats["telemetry"] = {"snapshots": len(tel.snapshots),
                              "traces_kept": len(tel.traces),
                              "traces_offered": tel.traces.offered}
    return OnlineResult(arrival=arr, wait=wait, service=service,
                        completion=completion, response=resp, mode=mode,
                        batch_of=batch_of, topk=topk, final=final,
                        event_log=events, stats=stats, coverage=coverage)


def fresh_probe(system):
    """A throwaway clone of a fitted system — same index, models, LTR
    model, **calibrated** spec, and (possibly label-regressed) cost model
    — for measurements like :func:`estimate_capacity` that must not
    perturb the production system's pool EWMAs or adaptive thresholds.
    Cloning the live ``cascade_spec``/``cost`` (not the pre-fit template)
    is what makes the probe route and cost identically to the system it
    stands in for."""
    from repro.serving.system import build_system
    return build_system(system.cascade_spec, system.index,
                        corpus=system.corpus, models=system.models,
                        ltr=system.ltr, cost=system.cost)


def estimate_capacity(system, terms: np.ndarray, mask: np.ndarray,
                      topics: np.ndarray | None,
                      online: OnlineSpec | None = None,
                      n_batches: int = 4) -> float:
    """Saturated-throughput estimate (queries per 1000 time units): serve
    ``n_batches`` full ``max_batch``-wide batches back to back and return
    ``max_batch / mean(occupancy)``.

    This *serves real batches* (it warms the jit cache and perturbs the
    replica pool's EWMAs) — probe a throwaway clone (:func:`fresh_probe`)
    when the measurement must not touch production state."""
    online = online if online is not None else system.cascade_spec.online
    b = online.max_batch
    occ = []
    for k in range(n_batches):
        rows = (np.arange(b) + k * b) % len(terms)
        res = system.serve(terms[rows], mask[rows],
                           topics[rows] if system.ltr is not None else None)
        occ.append(online.dispatch_us + float(res.latency.max()))
    return 1000.0 * b / float(np.mean(occ))
