"""SLA-aware admission control and load shedding.

The offline guarantee (``SchedulerConfig.worst_case_us`` + the Stage-2
reservation) bounds *service* time; under load the response budget also has
to pay queueing delay, and no scheduler knob can un-spend time a query
already burned in the queue.  The only correct moves are made *before*
dispatch — degrade or shed while there is still slack, never breach:

ladder (per query, at batch dispatch, from its actual wait)
-----------------------------------------------------------
With ``slack = response_budget - wait - dispatch_us`` and ``S1`` the hard
Stage-0+1 service bound (``worst_case_us`` minus the Stage-2 reserve):

1. **full**    — ``slack >= S1 + ltr_time(k_serve)``: nothing to do;
2. **trim**    — Stage-2 still fits for some smaller candidate grid:
   cap candidates at ``stage2_afford(cost, slack - S1, k_serve)``;
3. **stage1**  — ``slack >= S1`` only: serve the rank-safe Stage-1 list,
   skip Stage-2 outright (cap 0);
4. **partial** — the full scatter-gather does not fit, but a *narrower*
   one does: query only the first ``m`` partitions (``m`` the largest
   shard count whose Stage-1 bound fits the slack — each extra shard
   costs ``CostModel.gather_per_shard_us`` of merge fan-out), serving the
   rank-safe order over partial coverage.  Only reachable on multi-shard
   deployments with a nonzero gather overhead (otherwise shard count does
   not buy back any bound) — see the fault-tolerance section of the
   README;
5. **shed**    — even one partition cannot finish inside the budget:
   reject.  A rejection at arrival time (predicted wait from queue depth
   and the observed batch-occupancy EWMA) is cheaper than one at dispatch
   — the query never occupies the queue.

Every *served* query therefore satisfies
``wait + dispatch + service <= response_budget`` by construction, which is
exactly what ``benchmarks/bench_online.py`` certifies (0 violations,
queueing included) where the no-admission baseline leaks.

Cache-aware admission
---------------------
With a serving cache attached (``cache_bound`` = the hard service bound of
a guaranteed L1 hit, ``predict_us + cache_hit_us``), the ladder gains a
rung *above* full service: a query the dispatch-time peek proves is an L1
hit is admitted at FULL whenever ``slack >= cache_bound`` — a hit bypasses
the cascade, so it needs none of the Stage-1/Stage-2 reserves and consumes
(almost) no server occupancy.  The controller also learns the live hit
ratio ``h`` via EWMA (:meth:`observe_hits`) and folds it into the
*arrival-time* floor:

    floor_eff = h * cache_bound + (1 - h) * floor

i.e. the expected service bound of the mix actually being served — the
hit-ratio-adjusted capacity.  Observed capacity adapts on its own: hits
shrink real batch occupancies, and :meth:`observe_batch` folds those into
the wait estimator.  Both folds only move *predictions* (who gets
admitted); the dispatch-time guarantee still prices every non-hit row at
its full analytic bound, so 0 violations is preserved at any hit ratio —
including a sudden drop to 0 (the EWMA re-learns, dispatch never lies).
"""

from __future__ import annotations

import numpy as np

from repro.serving.latency import CostModel, stage2_afford
from repro.serving.spec import OnlineSpec

# per-query service modes, in degradation order
FULL, TRIM, STAGE1, PARTIAL, SHED = 0, 1, 2, 3, 4
MODE_NAMES = {FULL: "full", TRIM: "trim", STAGE1: "stage1",
              PARTIAL: "partial", SHED: "shed"}


class AdmissionController:
    """Admission decisions from queue state + the analytic service bounds.

    ``stage1_bound`` is the hard bound on Stage-0+1 service
    (``SearchSystem.worst_case_us() - stage2 reserve``); ``k_serve`` the
    full candidate width (``None`` disables the Stage-2 rungs — a
    stage1-only deployment ladder is admit/partial/shed).

    ``partial_bounds`` (optional, ascending, length ``n_shards``) are the
    hard Stage-0+1 bounds when only ``m`` partitions are queried
    (``partial_bounds[m-1] = SchedulerConfig.worst_case_us(cost, m)``);
    they enable the partial-coverage rung.  ``None`` — or bounds that do
    not actually shrink with shard count (``gather_per_shard_us == 0``) —
    leave the rung unreachable and the ladder exactly as before.
    """

    def __init__(self, cfg: OnlineSpec, cost: CostModel,
                 stage1_bound: float, k_serve: int | None,
                 response_budget: float,
                 partial_bounds=None, cache_bound: float | None = None,
                 hit_alpha: float = 0.2):
        cfg.validate()
        if response_budget <= 0:
            raise ValueError("response_budget must be positive")
        self.cfg = cfg
        self.cost = cost
        self.stage1_bound = float(stage1_bound)
        self.k_serve = k_serve
        self.response_budget = float(response_budget)
        self._partial_bounds = None
        if partial_bounds is not None and len(partial_bounds) > 1:
            pb = np.asarray(partial_bounds, np.float64)
            if np.any(np.diff(pb) < 0):
                raise ValueError("partial_bounds must be ascending in "
                                 "shard count")
            if pb[-1] > self.stage1_bound + 1e-6:
                raise ValueError("partial_bounds[-1] (the full fan-out "
                                 "bound) must not exceed stage1_bound")
            if pb[0] < pb[-1]:         # narrowing actually buys back time
                self._partial_bounds = pb
        # the full-service bound (stage1 + worst-case Stage-2) is a run
        # constant — hoisted out of the per-arrival hot path
        self._full_bound = self.stage1_bound + (
            float(cost.ltr_time(np.asarray(k_serve)))
            if k_serve is not None else 0.0)
        # the most degraded service still offered: one-partition coverage
        # when the partial rung is live, stage1-only otherwise
        self._degrade_floor = (float(self._partial_bounds[0])
                               if self._partial_bounds is not None
                               else self.stage1_bound)
        # observed batch-occupancy EWMA for the arrival-time wait estimate;
        # starts at the conservative worst case so a cold start over-sheds
        # rather than over-admits
        self.occupancy_ewma = cfg.dispatch_us + self._full_bound
        # cache-aware rung: hard service bound of a guaranteed L1 hit
        # (None = no cache attached), and the live hit-ratio EWMA —
        # pessimistic 0 at cold start, so an empty cache changes nothing
        self.cache_bound = (float(cache_bound) if cache_bound is not None
                            else None)
        self.hit_alpha = float(hit_alpha)
        self.hit_ewma = 0.0
        self.stats = {"shed_arrival": 0, "shed_queue_cap": 0,
                      "shed_dispatch": 0, "degraded": 0, "partial": 0,
                      "admitted": 0, "cache_admitted": 0,
                      "feed_applied": 0, "feed_throttled": 0,
                      "merges_applied": 0, "merges_forced": 0,
                      "merge_deferred": 0}

    # ------------------------------------------------------------------
    def export_metrics(self, reg) -> None:
        """Mirror the ladder's decision counters + live estimators into a
        telemetry registry."""
        for k, v in self.stats.items():
            reg.counter("admission", key=k).set_total(v)
        reg.gauge("admission_occupancy_ewma_us").set(self.occupancy_ewma)
        reg.gauge("admission_hit_ewma").set(self.hit_ewma)
        reg.gauge("response_budget_us").set(self.response_budget)
        reg.gauge("admission_stage1_bound_us").set(self.stage1_bound)

    def observe_batch(self, occupancy: float, alpha: float = 0.2) -> None:
        """Fold an observed batch occupancy into the wait estimator."""
        self.occupancy_ewma = ((1 - alpha) * self.occupancy_ewma
                               + alpha * float(occupancy))

    def observe_hits(self, n_hits: int, n_lookups: int) -> None:
        """Fold one batch's L1 hit count into the hit-ratio EWMA (no-op on
        an empty batch, so padding rows never dilute the estimate)."""
        if n_lookups <= 0:
            return
        self.hit_ewma = ((1 - self.hit_alpha) * self.hit_ewma
                         + self.hit_alpha * (n_hits / n_lookups))

    def feed_gate(self, arrival: float, server_free: float,
                  queue_depth: int, pause_us: float = 0.0) -> bool:
        """Feed-vs-query backpressure: admit an ingest batch only while a
        query arriving *after* the ingest pause would still be served at
        FULL service.  The gate prices the pause into the wait estimate
        and demands the full-service bound — strictly more slack than the
        degrade floor the query shed rung needs — so the feed is throttled
        before any query degrades, and long before one sheds.  Queries
        always win the contest for server time."""
        batches_ahead = queue_depth // self.cfg.max_batch
        wait_est = (max(server_free + pause_us - arrival, 0.0)
                    + batches_ahead * self.occupancy_ewma)
        if (wait_est + self.cfg.dispatch_us + self._full_bound
                > self.response_budget):
            self.stats["feed_throttled"] += 1
            return False
        self.stats["feed_applied"] += 1
        return True

    def merge_gate(self, now: float, server_free: float,
                   queue_depth: int, *, full: bool) -> bool:
        """Background-merge backpressure: a merge reseals the index (jit
        retrace + cache flush) and occupies the server, so it only runs in
        an idle gap — empty queue, server free.  ``full=True`` (the delta
        cannot take the next due feed batch) forces it through regardless:
        deferring then would stall the feed forever, and the forced merge
        still lands *before* the queries queued behind it are priced, so
        their dispatch-time slack accounts for the pause."""
        if full:
            self.stats["merges_forced"] += 1
            self.stats["merges_applied"] += 1
            return True
        if queue_depth > 0 or server_free > now:
            self.stats["merge_deferred"] += 1
            return False
        self.stats["merges_applied"] += 1
        return True

    def at_arrival(self, arrival: float, server_free: float,
                   queue_depth: int) -> bool:
        """Admit-to-queue decision: predicted wait = residual busy time +
        the full batches already queued ahead, each costing the occupancy
        EWMA.  Shed when even stage1-only service cannot fit — the query
        would only burn queue space it cannot convert into an answer."""
        if self.cfg.queue_cap and queue_depth >= self.cfg.queue_cap:
            self.stats["shed_queue_cap"] += 1
            return False
        batches_ahead = queue_depth // self.cfg.max_batch
        wait_est = (max(server_free - arrival, 0.0)
                    + batches_ahead * self.occupancy_ewma)
        floor = (self._degrade_floor if self.cfg.degrade
                 else self._full_bound)
        if self.cache_bound is not None:
            # hit-ratio-adjusted floor: the expected service bound of the
            # mix actually served (h·hit + (1-h)·miss) — see module
            # docstring.  Prediction only; dispatch still prices every
            # non-hit at the full bound.
            floor = (self.hit_ewma * self.cache_bound
                     + (1.0 - self.hit_ewma) * floor)
        if wait_est + self.cfg.dispatch_us + floor > self.response_budget:
            self.stats["shed_arrival"] += 1
            return False
        self.stats["admitted"] += 1
        return True

    def _partial_rung(self, mode: np.ndarray, slack: np.ndarray,
                      fits_s1: np.ndarray) -> np.ndarray | None:
        """Apply the partial-coverage rung to rows the full fan-out cannot
        serve; returns the per-query shard cap (or ``None`` when the rung
        is unreachable)."""
        if self._partial_bounds is None or not self.cfg.degrade:
            return None
        ns = len(self._partial_bounds)
        # largest shard count whose Stage-1 bound fits the slack
        m = np.searchsorted(self._partial_bounds, slack + 1e-9,
                            side="right")
        part = ~fits_s1 & (m >= 1)
        mode[part] = PARTIAL
        shard_cap = np.full(len(slack), ns, np.int64)
        shard_cap[part] = np.minimum(m[part], ns - 1)
        self.stats["partial"] += int(part.sum())
        return shard_cap

    def _hit_override(self, mode: np.ndarray, slack: np.ndarray,
                      hits) -> np.ndarray | None:
        """Rows the dispatch-time cache peek *proves* are L1 hits are
        admitted at FULL whenever their slack covers the hit bound — a hit
        bypasses the cascade, so none of the Stage-1/Stage-2 reserves
        apply.  Returns the override mask (``None`` when no cache/peek).
        Un-does any rung counters the override supersedes."""
        if hits is None or self.cache_bound is None:
            return None
        hit_ok = (np.asarray(hits, bool)
                  & (slack >= self.cache_bound - 1e-9))
        if not hit_ok.any():
            return hit_ok
        self.stats["cache_admitted"] += int(np.sum(hit_ok
                                                   & (mode != FULL)))
        self.stats["partial"] -= int(np.sum(hit_ok & (mode == PARTIAL)))
        mode[hit_ok] = FULL
        return hit_ok

    def at_dispatch(self, waits: np.ndarray, hits=None
                    ) -> tuple[np.ndarray, np.ndarray | None,
                               np.ndarray | None]:
        """(mode, stage2_cap, shard_cap) per query from its *actual* wait
        at batch close.  ``stage2_cap`` is ``None`` for stage1-only
        deployments; shed rows get cap 0 (they are never served).
        ``shard_cap`` is ``None`` unless the partial-coverage rung is live
        (``partial_bounds``); partial rows serve the rank-safe Stage-1
        order over their first ``shard_cap`` partitions (stage2_cap 0).
        ``hits`` is an optional per-query bool mask of guaranteed L1 cache
        hits (``SearchSystem.cache_peek`` at the dispatch clock): those
        rows take the cache rung (see module docstring)."""
        waits = np.asarray(waits, np.float64)
        slack = self.response_budget - waits - self.cfg.dispatch_us
        mode = np.full(len(waits), SHED, np.int64)
        fits_s1 = slack >= self.stage1_bound - 1e-9
        if self.k_serve is None:
            mode[fits_s1] = FULL
            shard_cap = self._partial_rung(mode, slack, fits_s1)
            hit_ok = self._hit_override(mode, slack, hits)
            if hit_ok is not None and shard_cap is not None:
                shard_cap[hit_ok] = len(self._partial_bounds)
            self.stats["shed_dispatch"] += int(np.sum(mode == SHED))
            return mode, None, shard_cap
        afford = stage2_afford(self.cost, slack - self.stage1_bound,
                               self.k_serve)
        if not self.cfg.degrade:
            # admit/shed only: full service or nothing
            full = fits_s1 & (afford >= self.k_serve)
            mode[full] = FULL
            self._hit_override(mode, slack, hits)
            full = mode == FULL
            self.stats["shed_dispatch"] += int(np.sum(~full))
            return (mode, np.where(full, self.k_serve, 0).astype(np.int64),
                    None)
        mode[fits_s1 & (afford == 0)] = STAGE1
        mode[fits_s1 & (0 < afford) & (afford < self.k_serve)] = TRIM
        mode[fits_s1 & (afford >= self.k_serve)] = FULL
        shard_cap = self._partial_rung(mode, slack, fits_s1)
        hit_ok = self._hit_override(mode, slack, hits)
        cap = np.where(fits_s1, afford, 0).astype(np.int64)
        if hit_ok is not None:
            cap[hit_ok] = self.k_serve
            if shard_cap is not None:
                shard_cap[hit_ok] = len(self._partial_bounds)
        else:
            hit_ok = np.zeros(len(waits), bool)
        self.stats["shed_dispatch"] += int(np.sum(mode == SHED))
        self.stats["degraded"] += int(np.sum(fits_s1 & ~hit_ok
                                             & (afford < self.k_serve)))
        cap = np.minimum(np.maximum(cap, 0), self.k_serve)
        return mode, cap, shard_cap
