"""ISN replica pool management: mirror placement, load balancing, failure
handling — the distributed-IR layer the paper's "index mirroring" rides on
(paper §4: "selecting algorithm a ∈ A actually refers to selecting an ISN
configured to run algorithm a").

A deployment is a set of *partitions* (document shards); each partition has
R replicas, each replica built as one mirror type (BMW or JASS).  The pool:

* routes a (query, mirror) request to the least-loaded healthy replica of
  every partition (power-of-two-choices);
* tracks in-flight work with an EWMA latency estimate per replica —
  stragglers get deprioritized before they fail health checks;
* handles replica failure/recovery (mark unhealthy after `fail_after`
  consecutive timeouts; re-admit after a probe succeeds);
* rebalances mirror ratios from the observed routing mix (the paper routes
  ~40–60 % to JASS at its operating points; a static 50/50 mirror split
  wastes capacity if the scheduler's mix drifts).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import numpy as np

BMW, JASS = "bmw", "jass"


@dataclass
class Replica:
    partition: int
    mirror: str
    replica_id: int
    inflight: int = 0
    ewma_latency: float = 1.0
    healthy: bool = True
    consecutive_failures: int = 0
    served: int = 0


@dataclass
class PoolConfig:
    n_partitions: int = 4
    replicas_per_partition: int = 4
    jass_fraction: float = 0.5
    ewma_alpha: float = 0.2
    fail_after: int = 3


class ReplicaPool:
    def __init__(self, cfg: PoolConfig, seed: int = 0):
        self.cfg = cfg
        self.rng = np.random.RandomState(seed)
        self.replicas: list[Replica] = []
        for p in range(cfg.n_partitions):
            n_jass = max(int(round(cfg.replicas_per_partition
                                   * cfg.jass_fraction)), 1)
            for r in range(cfg.replicas_per_partition):
                mirror = JASS if r < n_jass else BMW
                self.replicas.append(Replica(p, mirror, r))

    # ------------------------------------------------------------------
    def candidates(self, partition: int, mirror: str):
        return [r for r in self.replicas
                if r.partition == partition and r.mirror == mirror
                and r.healthy]

    def _pick_from(self, cands: list[Replica]) -> Replica | None:
        """Power-of-two-choices on (inflight, ewma latency) over an
        explicit candidate list (RNG draw only when there is a choice)."""
        if not cands:
            return None
        if len(cands) == 1:
            return cands[0]
        a, b = self.rng.choice(len(cands), size=2, replace=False)
        ra, rb = cands[a], cands[b]
        # expected time-to-drain; the random pair ordering breaks ties fairly
        key = (lambda r: (r.inflight + 1) * r.ewma_latency)
        return ra if key(ra) <= key(rb) else rb

    def pick(self, partition: int, mirror: str) -> Replica | None:
        """Power-of-two-choices on (inflight, ewma latency)."""
        cands = self.candidates(partition, mirror)
        if not cands:
            # mirror exhausted (failures): fall back to the other mirror —
            # JASS can always stand in for BMW (rank-safety traded for the
            # budget guarantee), BMW for JASS (budget risk, logged)
            other = JASS if mirror == BMW else BMW
            cands = self.candidates(partition, other)
        return self._pick_from(cands)

    def route_query(self, mirror: str) -> list[Replica] | None:
        """A query fans out to one replica of EVERY partition; all-or-
        nothing — a partition with no healthy replica releases the picks
        already made so no inflight count leaks."""
        picks = []
        for p in range(self.cfg.n_partitions):
            r = self.pick(p, mirror)
            if r is None:
                for rr in picks:
                    rr.inflight = max(rr.inflight - 1, 0)
                return None
            r.inflight += 1
            picks.append(r)
        return picks

    def route_query_partial(self, mirror: str) -> list[Replica | None]:
        """Like :meth:`route_query` but a partition with no healthy replica
        yields ``None`` in its slot instead of aborting the whole fan-out —
        the degraded-serving entry point.  When every partition is healthy
        the pick sequence (and RNG stream) is identical to
        :meth:`route_query`."""
        picks: list[Replica | None] = []
        for p in range(self.cfg.n_partitions):
            r = self.pick(p, mirror)
            if r is not None:
                r.inflight += 1
            picks.append(r)
        return picks

    def pick_retry(self, partition: int, mirror: str,
                   tried_ids: set[int]) -> Replica | None:
        """Failover pick for a timed-out shard request: prefer a healthy
        replica of the same partition not yet tried for this (query, shard)
        — routed mirror first, then the other mirror — and only then allow
        a re-try of an already-tried healthy replica (transient timeouts
        clear).  Returns ``None`` when the partition has no healthy replica
        at all."""
        other = JASS if mirror == BMW else BMW
        for pool in (self.candidates(partition, mirror),
                     self.candidates(partition, other)):
            fresh = [r for r in pool if id(r) not in tried_ids]
            if fresh:
                return self._pick_from(fresh)
        return self.pick(partition, mirror)

    def probe_unhealthy(self, is_up_fn=None) -> tuple[int, int]:
        """Probe every unhealthy replica; ``is_up_fn(replica) -> bool``
        decides the probe outcome (default: always up, i.e. the fault has
        cleared).  Returns (probes sent, replicas recovered)."""
        probes = recovered = 0
        for r in self.replicas:
            if r.healthy:
                continue
            probes += 1
            ok = True if is_up_fn is None else bool(is_up_fn(r))
            self.probe(r, ok=ok)
            recovered += int(ok)
        return probes, recovered

    def complete(self, replica: Replica, latency: float, ok: bool = True):
        replica.inflight = max(replica.inflight - 1, 0)
        if ok:
            a = self.cfg.ewma_alpha
            replica.ewma_latency = ((1 - a) * replica.ewma_latency
                                    + a * latency)
            replica.consecutive_failures = 0
            replica.served += 1
        else:
            replica.consecutive_failures += 1
            if replica.consecutive_failures >= self.cfg.fail_after:
                replica.healthy = False

    def probe(self, replica: Replica, ok: bool):
        """Health-check a failed replica; re-admit on success."""
        if ok:
            replica.healthy = True
            replica.consecutive_failures = 0
            replica.inflight = 0

    # ------------------------------------------------------------------
    def rebalance(self, observed_jass_fraction: float):
        """Re-split mirrors toward the observed routing mix (rounded to
        whole replicas; each partition keeps >= 1 of each mirror).

        Driven online by ``SearchSystem.serve`` from the scheduler's
        observed JASS fraction (``DeploySpec.rebalance_every``), not just by
        offline simulation.  A partition needs >= 2 replicas to hold both
        mirrors — single-replica deployments keep their static split."""
        cfg = self.cfg
        if cfg.replicas_per_partition < 2:
            return
        want = int(round(cfg.replicas_per_partition
                         * np.clip(observed_jass_fraction, 0.2, 0.8)))
        want = min(max(want, 1), cfg.replicas_per_partition - 1)
        for p in range(cfg.n_partitions):
            reps = sorted((r for r in self.replicas if r.partition == p),
                          key=lambda r: r.replica_id)
            for i, r in enumerate(reps):
                mirror = JASS if i < want else BMW
                if mirror != r.mirror:
                    r.mirror = mirror
                    # latency history belongs to the old mirror; restart
                    # the estimate so pick() is not biased by stale data
                    r.ewma_latency = 1.0
        self.cfg = PoolConfig(**{**cfg.__dict__,
                                 "jass_fraction": want
                                 / cfg.replicas_per_partition})

    def mirror_ewma(self) -> dict:
        """Mean EWMA latency per mirror over replicas that have served —
        the pool-side signal ``SearchSystem._adapt_routing`` feeds back
        into the ``t_time`` routing threshold (None until a mirror has
        observed traffic)."""
        out = {}
        for m in (JASS, BMW):
            v = [r.ewma_latency for r in self.replicas
                 if r.mirror == m and r.served]
            out[m] = float(np.mean(v)) if v else None
        return out

    def stats(self) -> dict:
        healthy = sum(r.healthy for r in self.replicas)
        return {
            "replicas": len(self.replicas),
            "healthy": healthy,
            "jass": sum(r.mirror == JASS for r in self.replicas),
            "bmw": sum(r.mirror == BMW for r in self.replicas),
            "jass_fraction": self.cfg.jass_fraction,
            "served": sum(r.served for r in self.replicas),
            "max_inflight": max((r.inflight for r in self.replicas),
                                default=0),
            "ewma_latency": self.mirror_ewma(),
        }

    def export_metrics(self, reg) -> None:
        """Mirror pool health into a telemetry registry."""
        s = self.stats()
        reg.counter("pool_served").set_total(s["served"])
        for k in ("replicas", "healthy", "jass", "bmw", "jass_fraction",
                  "max_inflight"):
            reg.gauge("pool", key=k).set(s[k])
        for m, name in ((JASS, "jass"), (BMW, "bmw")):
            v = s["ewma_latency"][m]
            if v is not None:
                reg.gauge("pool_ewma_latency_us", mirror=name).set(v)
