"""``SearchSystem``: one declarative spec → a multi-shard serving cascade.

This is the unified serving facade the paper's framework implies: a
:class:`~repro.serving.spec.CascadeSpec` names an operating point and
``build_system`` instantiates the full lifecycle —

    spec = get_preset("paper_200ms")
    system = build_system(spec, corpus)      # builds + shards the index
    system.fit(ql, labels)                   # Stage-0 predictors + LTR
    res = system.serve(ql.terms, ql.mask, ql.topic)
    system.stats()                           # tails + pool health

Deployment shape (``DeploySpec``)
---------------------------------
The index is partitioned into ``n_shards`` contiguous **doc-range shards**
(``shard_from_index`` over ``shard_ranges``); Stage-1 fans each routed
sub-batch out across every shard's batched DAAT/SAAT engine and merges the
per-shard top-k with ``merge_shard_topk`` — shards are merged in ascending
doc-range order, so score ties break toward the **lower global doc id**,
exactly the tie-break of a single-shard run (a one-shard deployment is
bit-identical to the historical ``CascadePipeline``).

Multi-shard exactness: DAAT is rank-safe per shard, so the merged top-k is
the exact global top-k.  For SAAT, the ρ budget resolves to a **global**
impact-level cut (from the full-collection level table); each shard then
processes exactly its slice of that cut's posting set, so the union equals
the single-shard traversal and — accumulation being integer — the merged
top-k matches bit-for-bit.

Latency is scatter-gather: a query finishes when its *slowest* shard
responds (``CostModel.gather_time`` = max over shards + fan-out overhead)
— the tail is a max, which is the paper's tail-latency story at deployment
scale.  Each partition is backed by a :class:`~repro.serving.replicas.
ReplicaPool` of BMW/JASS mirror replicas: every served query routes through
power-of-two-choices replica selection, observed per-(query, shard)
latencies feed the pool's EWMA estimates back, and the mirror split is
re-balanced online toward the scheduler's observed routing mix.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

import jax.numpy as jnp
import numpy as np

from repro.core import features as F
from repro.core import gbrt
from repro.dense import (M_BOTH, M_DENSE, M_LEX, DenseEngine,
                         build_embeddings, fuse)
from repro.dense.embeddings import delta_doc_embeddings
from repro.dense.engine import SCORE_FILL
from repro.index.builder import InvertedIndex, build_index
from repro.index.corpus import Corpus, FeedDocs
from repro.index.delta import DeltaStore
from repro.index.postings import shard_from_index, shard_ranges
from repro.isn.backend import (merge_shard_topk, query_lane_budget,
                               resolve_backend)
from repro.isn.daat import daat_serve
from repro.isn.saat import saat_serve
from repro.ltr.cascade import CascadeResult, rerank_batched
from repro.ltr.ranker import (LTRModel, csr_search_iters, ltr_training_set,
                              qd_features, stage2_arrays, train_ltr)
from repro.serving.cache import (HEALTHY_EPOCH, ServingCache, ingest_epoch,
                                 l1_key, l2_key, normalize_query, route_sig)
from repro.serving.faults import FaultInjector
from repro.serving.latency import (CostModel, budget_attribution,
                                   over_budget, percentiles,
                                   resolve_level_cut, stage2_afford)
from repro.serving.replicas import BMW, JASS, PoolConfig, ReplicaPool
from repro.serving.scheduler import (RoutedBatch, SchedulerConfig,
                                     StageZeroScheduler)
from repro.serving.spec import CascadeSpec, RoutingSpec
from repro.serving.telemetry import QueryTrace, Span, Telemetry
from repro.serving.telemetry.export import (legacy_stats_view,
                                            render_json,
                                            render_prometheus)


@dataclass
class PipelineResult:
    """One served batch, end to end."""
    topk: np.ndarray                 # (Q, k_serve) Stage-1 candidates
    final: np.ndarray | None         # (Q, t_final) re-ranked (None: no LTR)
    candidates_used: np.ndarray | None   # (Q,) candidates entering Stage-2
    latency: np.ndarray              # (Q,) full-cascade latency
    stage_latency: dict              # {"stage0"|"stage1"|"stage2": (Q,)}
    stats: dict
    coverage: np.ndarray | None = None   # (Q,) fraction of partitions that
                                         # answered (None: full coverage,
                                         # no fault/partial path engaged)
    dense: dict | None = None        # {"modality", "theta_skip",
                                     #  "fallback"} (Q,) vectors (None:
                                     #  dense modality disabled)


def scheduler_config(routing: RoutingSpec) -> SchedulerConfig:
    """The runtime scheduler configuration a RoutingSpec describes."""
    return SchedulerConfig(
        algorithm=routing.algorithm, t_k=routing.t_k, t_time=routing.t_time,
        rho_max=routing.rho_max, rho_min=routing.rho_min,
        budget=routing.budget, hedge_band=routing.hedge_band,
        enable_hedging=routing.enable_hedging,
        hedge_deadline=routing.hedge_deadline, late_rho=routing.late_rho,
        enforce_budget=routing.enforce_budget,
        failover_timeout=routing.failover_timeout,
        max_retries=routing.max_retries)


def routing_spec(cfg: SchedulerConfig) -> RoutingSpec:
    """The RoutingSpec describing a runtime SchedulerConfig (shim path)."""
    return RoutingSpec(
        algorithm=cfg.algorithm, t_k=cfg.t_k, t_time=cfg.t_time,
        rho_max=cfg.rho_max, rho_min=cfg.rho_min, budget=cfg.budget,
        hedge_band=cfg.hedge_band, enable_hedging=cfg.enable_hedging,
        hedge_deadline=cfg.hedge_deadline, late_rho=cfg.late_rho,
        enforce_budget=cfg.enforce_budget,
        failover_timeout=cfg.failover_timeout, max_retries=cfg.max_retries)


def build_system(spec: CascadeSpec, corpus_or_index, *, corpus=None,
                 models: dict | None = None, ltr: LTRModel | None = None,
                 cost: CostModel | None = None) -> "SearchSystem":
    """Instantiate the deployment a spec describes.

    ``corpus_or_index`` is either a :class:`Corpus` (the index is built
    with the spec's ``IndexSpec``) or a pre-built :class:`InvertedIndex`
    (pass ``corpus=`` separately if Stage-2 needs doc topics).  Pre-trained
    ``models``/``ltr`` can be attached directly; otherwise call
    :meth:`SearchSystem.fit`.

    With a pre-built index the spec's ``block_size`` is reconciled from
    the index (the index is ground truth), so ``to_json()`` describes the
    deployed layout; ``stop_k`` is not recoverable from a built index —
    when shipping a spec for rebuild elsewhere, keep it truthful.
    """
    if isinstance(corpus_or_index, InvertedIndex):
        index = corpus_or_index
    elif isinstance(corpus_or_index, Corpus):
        corpus = corpus_or_index if corpus is None else corpus
        index = build_index(corpus_or_index,
                            block_size=spec.index.block_size,
                            stop_k=spec.index.stop_k)
    else:
        raise TypeError("build_system needs a Corpus or an InvertedIndex, "
                        f"got {type(corpus_or_index).__name__}")
    return SearchSystem(spec, index, corpus=corpus, models=models, ltr=ltr,
                        cost=cost)


class SearchSystem:
    """A spec-built multi-shard cascade with the full serving lifecycle."""

    def __init__(self, spec: CascadeSpec, index: InvertedIndex, *,
                 corpus=None, models: dict | None = None,
                 ltr: LTRModel | None = None, cost: CostModel | None = None):
        if index.block_size != spec.index.block_size:
            # the built index is ground truth for its own layout; fold it
            # back so spec.to_json() describes the deployed system
            spec = replace(spec, index=replace(spec.index,
                                               block_size=index.block_size))
        spec.validate()
        self.cascade_spec = spec
        self.index = index
        self.corpus = corpus
        self.cost = cost or getattr(CostModel, spec.backend.cost)()
        self.k_serve = spec.stage2.k_serve
        self.t_final = spec.stage2.t_final
        self.backend = spec.backend.backend
        self.budget = spec.routing.budget
        self._base_cfg = scheduler_config(spec.routing)

        # ---- shard the index into doc-range partitions ----
        self._attach_index(index)

        # ---- live ingest (spec.ingest; inert by default) ----
        # None keeps every serve path, cache key, and timing term
        # bit-identical to the sealed-only system — the same discipline as
        # FaultSpec/CacheSpec/DenseSpec.  The delta scan's cost is a single
        # shape-static term (its arrays are capacity-padded), charged at
        # capacity to every served query and to worst_case_us().
        self.delta: DeltaStore | None = None
        self._delta_us = 0.0
        self._ingest_counters = {
            "epoch": 0,          # cache-epoch bumps (feeds + merges)
            "feed_batches": 0,   # applied ingest batches
            "docs_ingested": 0,  # docs accepted into the delta
            "merges": 0,         # background merges (reseals)
            "docs_merged": 0,    # docs folded into the sealed index
        }
        if spec.ingest.active:
            if spec.ingest.delta_docs < self.k_serve:
                raise ValueError(
                    f"ingest.delta_docs={spec.ingest.delta_docs} is below "
                    f"k_serve={self.k_serve}; the delta segment must be "
                    "able to answer a full candidate list")
            self.delta = DeltaStore(
                index, capacity_docs=spec.ingest.delta_docs,
                capacity_postings=spec.ingest.delta_postings,
                tile_d=spec.index.tile_d)
            self._delta_us = float(
                self.cost.delta_time(self.delta.capacity_postings))
            if self.dense is not None:
                # the dense delta segment is capacity-padded too, so its
                # tile count — and hence its cost term — is spec-static
                d_tiles = -(-self.delta.capacity_docs // self.dense.tile_d)
                self._delta_us += self.cost.dense_tile_us * d_tiles

        self.pool = ReplicaPool(
            PoolConfig(n_partitions=spec.deploy.n_shards,
                       replicas_per_partition=spec.deploy.replicas,
                       jass_fraction=spec.deploy.jass_fraction),
            seed=spec.deploy.seed)
        # deterministic fault injection (spec.fault; inert by default) +
        # the serving clock fault windows are evaluated against.  serve()
        # advances the clock by each batch's occupancy; the online
        # simulator drives it explicitly (now=dispatch time).
        self.faults = FaultInjector(spec.fault, spec.deploy.n_shards)
        self._clock = 0.0
        # two-level result/candidate cache (spec.cache; inert by default):
        # None keeps every serve path bit-identical to the uncached system
        # — the same inertness discipline as FaultSpec
        self.cache = (ServingCache(spec.cache) if spec.cache.active
                      else None)
        # deterministic observability (spec.telemetry; inert by default):
        # None keeps every serve path bit-identical to the pre-telemetry
        # system — every hook below guards on `self.telemetry is None`,
        # the same inertness discipline as FaultSpec/CacheSpec
        self.telemetry = (Telemetry(spec.telemetry, spec.routing.budget)
                          if spec.telemetry.active else None)
        self._tel_suppress = False    # True inside a cache-miss sub-serve
                                      # so batch metrics aren't double-fed
        self._tel_cache_tag = None    # "miss" tags sub-serve traces
        self._fault_counters = {
            "retries": 0,        # failover re-issues after a shard timeout
            "transient": 0,      # attempts killed by the timeout storm
            "down_requests": 0,  # attempts sent to a crashed/outaged replica
            "lost_partitions": 0,   # (query, shard) slots lost after retries
            "no_route": 0,       # partitions with no healthy replica at all
            "degraded_queries": 0,  # queries served with partial coverage
            "probes": 0,         # health probes sent to unhealthy replicas
            "recovered": 0,      # probes that re-admitted a replica
        }
        self._debug_shard_lists = None   # tests: set to [] to capture the
                                         # per-shard candidate lists
        self._batches = 0
        self._last_stats: dict = {}
        self._budget_reserve = self._attribute_budget(self.budget, None)
        self._adapt_last = {"late_hedged": 0, "bmw": 0}
        # rolling pinball loss of the t-predictor against observed BMW
        # engine times — drives the hedge_deadline adaptation (None until
        # a batch with BMW traffic has been served)
        self._pinball_ewma: float | None = None

        self.models: dict | None = None
        self.ltr: LTRModel | None = None
        self._stacked = None
        self.sched = StageZeroScheduler(self._base_cfg, self.cost)
        if models is not None:
            self.set_models(models, ltr)
        elif ltr is not None:
            raise ValueError("ltr without Stage-0 models — pass both")

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def _attach_index(self, index: InvertedIndex) -> None:
        """(Re)build every index-derived serving structure — doc-range
        shards, host-side df/level tables, the dense engine.  Called at
        construction and again when a background merge reseals the
        collection: resealing changes the doc ranges (and so the jit
        signatures), which is exactly the once-per-merge retrace the
        delta's capacity padding exists to avoid on the per-batch path."""
        spec = self.cascade_spec
        self.index = index
        ranges = shard_ranges(index.n_docs, spec.deploy.n_shards)
        self.doc_lo = [lo for lo, _ in ranges]
        built = [shard_from_index(index, lo, hi, tile_d=spec.index.tile_d)
                 for lo, hi in ranges]
        self.shards = [s for s, _ in built]
        self.shard_specs = [sp for _, sp in built]
        min_docs = min(sp.n_docs for sp in self.shard_specs)
        if min_docs < self.k_serve:
            raise ValueError(
                f"k_serve={self.k_serve} exceeds the smallest shard "
                f"({min_docs} docs at n_shards={spec.deploy.n_shards}); "
                f"use fewer shards or a smaller k_serve")
        self._df_host = [np.asarray(s.df) for s in self.shards]
        # host-side impact-level tables: the global SAAT level cut (and the
        # deterministic JASS cost) are resolved against the full collection,
        # then split per shard — see module docstring for why this keeps
        # multi-shard SAAT bit-identical to the single-shard traversal
        self._level_cum_host = ([index.level_cum] if len(self.shards) == 1
                                else [np.asarray(s.level_cum)
                                      for s in self.shards])

        self.term_stats = jnp.asarray(index.term_stats)
        self.df = jnp.asarray(index.df)

        # ---- dense Stage-1 modality (spec.dense; inert by default) ----
        # None keeps every serve path and cache key bit-identical to the
        # lexical-only system — the same discipline as FaultSpec/CacheSpec.
        # The embedding matrix is partitioned by the SAME doc ranges as the
        # inverted index, so merge_shard_topk and the pool failover
        # protocol apply to dense traffic unchanged.
        self.dense = None
        if spec.dense.enabled:
            doc_emb, term_table = build_embeddings(
                spec.dense, corpus=self.corpus, n_docs=index.n_docs,
                vocab=int(np.asarray(index.df).shape[0]))
            self.dense = DenseEngine(doc_emb, term_table, ranges,
                                     tile_d=spec.dense.tile_d,
                                     backend=self.backend)

    def _attribute_budget(self, budget: float, k_serve: int | None) -> dict:
        """``budget_attribution`` plus the dense modality's fusion reserve:
        with dense enabled, ``fusion_us`` is carved out of the scheduler's
        stage-1 share, so a both-routed query — max(lexical, dense) plus
        the host-side merge — still lands inside the cascade budget."""
        reserve = budget_attribution(budget, self.cost, k_serve)
        if self.cascade_spec.dense.enabled:
            reserve["fusion"] = self.cost.fusion_us
            reserve["stage1"] = max(reserve["stage1"] - self.cost.fusion_us,
                                    0.0)
        return reserve

    # ------------------------------------------------------------------
    # lifecycle: attach / train models
    # ------------------------------------------------------------------

    def set_models(self, models: dict, ltr: LTRModel | None = None):
        """Attach pre-trained Stage-0 predictors (and optionally the
        Stage-2 LTR model); rebuilds the scheduler so the cascade budget
        reservation matches the attached stages."""
        self.models = models
        # fused Stage-0: one stacked forest when the three ensembles share a
        # shape (fit() always trains them that way); per-model fallback
        # otherwise — same predictions either way, bit-for-bit.
        try:
            self._stacked, self._stack_depth = gbrt.stack_models(
                [models[n] for n in ("k", "rho", "t")])
        except ValueError:
            self._stacked = None
        self.ltr = ltr
        cfg = self._base_cfg
        # budget attribution: reserve the unconditional Stage-0 prediction
        # cost and the (deterministic) worst-case Stage-2 cost, so the
        # scheduler's deadline re-route enforces the *cascade* budget with
        # what remains — see "Guarantee accounting" in serving/latency.py
        if ltr is not None:
            if self.corpus is None:
                raise ValueError("Stage-2 re-ranking needs the corpus "
                                 "(doc topic mixtures)")
            self.s2 = stage2_arrays(self.index, self.corpus)
            self.n_iter = csr_search_iters(int(self.index.df.max()))
        self._budget_reserve = self._attribute_budget(
            cfg.budget, self.k_serve if ltr is not None else None)
        cfg = replace(cfg, budget=self._budget_reserve["stage1"])
        self.sched = StageZeroScheduler(cfg, self.cost)
        return self

    def fit(self, ql, labels=None, *, seed: int = 0) -> "SearchSystem":
        """Train the spec's Stage-0 predictors (and Stage-2 LTR model when
        enabled) from a query log.

        ``labels`` is a ``generate_labels`` result (oracle k/ρ/t targets +
        reference lists).  ``labels=None`` falls back to cheap pseudo-labels
        derived from posting-list mass — enough to exercise routing and
        re-ranking in benchmarks and CI smokes without the label oracle.
        """
        s0 = self.cascade_spec.stage0
        x = np.asarray(F.extract(self.term_stats, self.df,
                                 jnp.asarray(ql.terms), jnp.asarray(ql.mask)))
        rng = np.random.RandomState(seed)
        if labels is not None:
            targets = {"k": labels.oracle_k, "rho": labels.oracle_rho,
                       "t": labels.t_bmw}
        else:
            eff = ((self.index.df[ql.terms] * (ql.mask > 0))
                   .sum(axis=1).astype(np.float64))
            targets = {n: eff * sc * np.exp(rng.randn(len(eff)) * 0.3)
                       for n, sc in (("k", 0.05), ("rho", 0.5), ("t", 0.002))}
        taus = {"k": s0.tau_k, "rho": s0.tau_rho, "t": s0.tau_t}
        models = {
            name: gbrt.fit(
                x, np.log1p(y.astype(np.float32)),
                gbrt.GBRTParams(n_trees=s0.n_trees, depth=s0.depth,
                                loss="quantile", tau=taus[name]))
            for name, y in targets.items()}

        ltr = None
        if self.cascade_spec.stage2.enabled:
            if self.corpus is None:
                raise ValueError("Stage-2 training needs the corpus")
            s2 = self.cascade_spec.stage2
            if labels is not None:
                rows = np.flatnonzero(labels.keep)[:s2.n_train_queries]
                lf, lg = ltr_training_set(self.index, self.corpus, ql,
                                          labels.ref_lists, rows)
            else:
                feats = []
                for q in range(min(len(ql.terms), 32)):
                    docs = rng.randint(0, self.index.n_docs, 64)
                    feats.append(qd_features(self.index, self.corpus,
                                             ql.terms[q], ql.mask[q],
                                             ql.topic[q],
                                             docs.astype(np.int64)))
                lf = np.concatenate(feats)
                lg = (lf[:, 5] + 0.2 * lf[:, 1]).astype(np.float32)
            ltr = train_ltr(lf, lg, n_trees=s2.ltr_trees)

        if labels is not None and self.cascade_spec.backend.calibrate_cost:
            # close the cost-model loop: the label oracle measured per-query
            # (work, latency) pairs — regress the engine rates from them so
            # the budget enforcement runs on observed constants, not the
            # static roofline prior (rejected fits keep the prior)
            keep = labels.keep
            self.cost = self.cost.regressed(
                work_saat=labels.work_exhaustive[keep],
                t_saat=labels.t_exh[keep],
                work_daat=labels.work_bmw[keep],
                blocks_daat=labels.blocks_bmw[keep],
                t_daat=labels.t_bmw[keep])

        if self.cascade_spec.routing.calibrate:
            # name the operating point from the data: route on the trained
            # predictors' own distribution (paper trains thresholds the
            # same way), keeping both pools in play on any collection
            pk = np.expm1(np.asarray(gbrt.predict(models["k"],
                                                  jnp.asarray(x))))
            pt = np.expm1(np.asarray(gbrt.predict(models["t"],
                                                  jnp.asarray(x))))
            t_k = float(np.percentile(pk, 60))
            t_time = float(min(self.budget * 0.75, np.percentile(pt, 75)))
            self._base_cfg = replace(self._base_cfg, t_k=t_k, t_time=t_time)
            # fold the resolved thresholds back into the spec so
            # to_json() captures the *operating* point, not the template —
            # a round-tripped spec then serves bit-identically
            self.cascade_spec = replace(
                self.cascade_spec,
                routing=replace(self.cascade_spec.routing, t_k=t_k,
                                t_time=t_time))
        return self.set_models(models, ltr)

    # ------------------------------------------------------------------
    # stages
    # ------------------------------------------------------------------

    def stage0(self, terms: np.ndarray, mask: np.ndarray):
        """All three predictions in one fused device call: (pk, pr, pt)."""
        if self.models is None:
            raise RuntimeError("no Stage-0 models: call fit() or "
                               "set_models() first")
        x = F.extract(self.term_stats, self.df, jnp.asarray(terms),
                      jnp.asarray(mask))
        if self._stacked is not None:
            p = np.expm1(np.asarray(
                gbrt.predict_stacked(self._stacked, x, self._stack_depth)))
            return p[0], p[1], p[2]
        return tuple(np.expm1(np.asarray(gbrt.predict(self.models[n], x)))
                     for n in ("k", "rho", "t"))

    def _modality(self, pt: np.ndarray) -> np.ndarray:
        """Stage-0 modality dispatch from the predicted lexical time:
        cheap queries stay lexical, predicted-expensive ones go dense only
        (the dense cost is shape-static, so it undercuts any traversal the
        t-predictor priced above ``t_dense``), and the uncertainty band in
        between runs both engines and fuses."""
        ds = self.cascade_spec.dense
        td = ds.t_dense if ds.t_dense > 0 else self.sched.cfg.t_time
        m = np.full(len(pt), M_BOTH, np.int64)
        m[pt <= td * (1.0 - ds.fuse_band)] = M_LEX
        m[pt > td * (1.0 + ds.fuse_band)] = M_DENSE
        return m

    def _restrict_lexical(self, routed: RoutedBatch,
                          modality: np.ndarray) -> RoutedBatch:
        """Strip dense-only rows from a routed batch: those queries never
        touch the lexical engines, and the scheduler's mirror counters
        (which drive pool rebalance and ``_adapt_routing``) must not claim
        they did."""
        lex = modality != M_DENSE

        def keep(rows, stat):
            kept = rows[lex[rows]]
            self.sched.stats[stat] -= int(len(rows) - len(kept))
            return kept

        return replace(routed,
                       jass_rows=keep(routed.jass_rows, "jass"),
                       bmw_rows=keep(routed.bmw_rows, "bmw"),
                       hedged_rows=keep(routed.hedged_rows, "hedged"))

    def _jass_split(self, terms, mask, rows, rho, cache: dict | None = None):
        """Resolve the ρ budget to the global impact-level cut and split the
        cut's work per segment.  Returns (per-segment work list, any_ok).

        With a live delta attached the list carries one extra trailing
        entry — the delta segment's slice of the same global cut (its
        level table participates in the cut resolution, so ρ budgets the
        *whole* collection including undigested feed docs).  Timing/pool
        consumers slice ``work_s[:n_shards]``: the delta's scan cost is
        charged as the shape-static ``_delta_us`` term, never from its
        per-query work.

        ``cache`` memoizes on (rows, rho) for the duration of one served
        batch — stage-1 budgeting, hedging resolution, and pool feedback
        all ask for the same splits, and the host-side level-table gather
        is the heaviest numpy work in the serve path.
        """
        key = None
        if cache is not None:
            key = (np.asarray(rows).tobytes(),
                   np.asarray(rho, np.float64).tobytes())
            if key in cache:
                return cache[key]
        m = (mask[rows] > 0)[:, :, None]
        totals = [(lc[terms[rows]] * m).sum(axis=1)       # (R, n_levels)
                  for lc in self._level_cum_host]
        if self.delta is not None:
            totals.append((self.delta.level_cum[terms[rows]] * m)
                          .sum(axis=1))
        total_g = totals[0] if len(totals) == 1 else np.sum(totals, axis=0)
        lstar, any_ok = resolve_level_cut(total_g, rho)
        rr = np.arange(len(rows))
        work_s = [np.where(any_ok, t[rr, lstar], 0) for t in totals]
        if key is not None:
            cache[key] = (work_s, any_ok)
        return work_s, any_ok

    def _jass_time(self, terms, mask, cache: dict | None = None):
        """Deterministic JASS time under scatter-gather: the ρ budget
        resolves to a global level cut, each shard's slice of the cut costs
        its own work, and the query waits for the slowest shard."""
        def fn(rows, rho):
            work_s, _ = self._jass_split(terms, mask, rows, rho, cache)
            t = np.stack([self.cost.saat_time(w.astype(np.float64))
                          for w in work_s[:self.n_shards]])
            return self.cost.gather_time(t)
        return fn

    def stage1(self, terms: np.ndarray, mask: np.ndarray, routed):
        """Public alias of :meth:`_stage1_full` (shims may narrow the
        return signature; ``serve`` always uses the full form).  Threads a
        fresh per-call split memo so same-batch duplicate queries share
        their SAAT level-cut resolution instead of recomputing it."""
        return self._stage1_full(terms, mask, routed, {})

    def _stage1_full(self, terms: np.ndarray, mask: np.ndarray, routed,
                     cache: dict | None = None, drop=None):
        """Fan the routed sub-batches out across every shard's batched
        engine and merge the per-shard top-k.

        Returns (topk, topk_sc, t_bmw, t_shards): merged global candidates
        and their merged scores (engine-native units; ``SCORE_FILL`` marks
        never-served / dropped slots — the fusion layer needs the scores,
        lexical-only callers may ignore them), the scatter-gather BMW time
        per query, and the (n_shards, Q) per-shard engine-time matrix that
        feeds the replica pool's EWMA estimates.

        ``drop`` ((n_shards, Q) bool, optional) marks (shard, query) slots
        whose response was lost (fault injection) or never requested
        (partial-coverage admission): their candidates are excluded from
        the merge (padded with ``-1`` ids when fewer than ``k_serve``
        survive), so a degraded query's list is exactly the merge over its
        surviving partitions.
        """
        q = terms.shape[0]
        ns = self.n_shards
        topk = np.zeros((q, self.k_serve), np.int64)
        topk_sc = np.full((q, self.k_serve), SCORE_FILL, np.float32)
        t_bmw = np.zeros(q)
        t_shards = np.zeros((ns, q))

        if len(routed.jass_rows):
            rows = routed.jass_rows
            rho_rows = routed.rho[rows]
            if ns > 1 or self.delta is not None:
                # one global level cut → per-segment budgets that reproduce
                # exactly the single-shard posting set (see module
                # docstring); a live delta is one more segment of the cut
                work_s, any_ok = self._jass_split(terms, mask, rows,
                                                  rho_rows, cache)
                rho_per_shard = [np.where(any_ok, w, -1.0).astype(np.float64)
                                 for w in work_s]
            else:
                rho_per_shard = [rho_rows]
            sc_list, id_list = [], []
            for s in range(ns):
                res = saat_serve(self.shards[s], jnp.asarray(terms[rows]),
                                 jnp.asarray(mask[rows]),
                                 jnp.asarray(rho_per_shard[s]),
                                 n_docs=self.shard_specs[s].n_docs,
                                 k=self.k_serve,
                                 cap=int(self.sched.cfg.rho_max),
                                 tile_d=self.shard_specs[s].tile_d,
                                 backend=self.backend)
                sc_list.append(res.topk_scores)
                id_list.append(res.topk_docs + self.doc_lo[s])
                t_shards[s, rows] = self.cost.saat_time(
                    np.asarray(res.work).astype(np.float64))
            if self.delta is not None:
                # the delta pseudo-shard scans its slice of the same global
                # cut; appended LAST so merge ties keep breaking toward the
                # lower global doc id (delta ids all sit above the sealed
                # collection).  Its time is the static _delta_us term.
                dsp = self.delta.shard_spec
                res = saat_serve(self.delta.shard, jnp.asarray(terms[rows]),
                                 jnp.asarray(mask[rows]),
                                 jnp.asarray(rho_per_shard[ns]),
                                 n_docs=dsp.n_docs, k=self.k_serve,
                                 cap=int(self.sched.cfg.rho_max),
                                 tile_d=dsp.tile_d, backend=self.backend)
                sc_list.append(res.topk_scores)
                id_list.append(res.topk_docs + self.delta.base_docs)
            if self._debug_shard_lists is not None:
                self._debug_shard_lists.append(
                    (rows, [np.asarray(a) for a in sc_list],
                     [np.asarray(a) for a in id_list]))
            if ns == 1 and self.delta is None:
                topk[rows] = np.asarray(id_list[0])
                topk_sc[rows] = np.asarray(sc_list[0]).astype(np.float32)
                if drop is not None and drop[0, rows].any():
                    dead = rows[drop[0, rows]]
                    topk[dead] = -1
                    topk_sc[dead] = SCORE_FILL
            else:
                dr = None if drop is None else drop[:, rows]
                if dr is not None and self.delta is not None:
                    # the delta segment is local to the merge host — never
                    # lost, never admission-dropped
                    dr = np.concatenate(
                        [dr, np.zeros((1, len(rows)), bool)])
                ids, sc = merge_shard_topk(sc_list, id_list, self.k_serve,
                                           drop=dr)
                topk[rows] = np.asarray(ids)
                topk_sc[rows] = np.asarray(sc).astype(np.float32)

        if len(routed.bmw_rows):
            rows = routed.bmw_rows
            sc_list, id_list = [], []
            for s in range(ns):
                spec_s = self.shard_specs[s]
                qcap = query_lane_budget(self._df_host[s], terms[rows],
                                         mask[rows])
                res = daat_serve(self.shards[s], jnp.asarray(terms[rows]),
                                 jnp.asarray(mask[rows]),
                                 jnp.ones(len(rows), jnp.float32),
                                 n_docs=spec_s.n_docs,
                                 n_blocks=spec_s.n_blocks,
                                 block_size=spec_s.block_size,
                                 k=self.k_serve, cap=spec_s.max_df,
                                 bcap=spec_s.max_blocks_per_term, qcap=qcap,
                                 tile_d=spec_s.tile_d, backend=self.backend)
                sc_list.append(res.topk_scores)
                id_list.append(res.topk_docs + self.doc_lo[s])
                t_shards[s, rows] = self.cost.daat_time(
                    np.asarray(res.work), np.asarray(res.blocks))
            if self.delta is not None:
                # rank-safe BMW over the capacity-padded delta segment: the
                # qcap default (L * cap) is spec-static, so fill level never
                # changes the jit signature
                dsp = self.delta.shard_spec
                res = daat_serve(self.delta.shard, jnp.asarray(terms[rows]),
                                 jnp.asarray(mask[rows]),
                                 jnp.ones(len(rows), jnp.float32),
                                 n_docs=dsp.n_docs, n_blocks=dsp.n_blocks,
                                 block_size=dsp.block_size, k=self.k_serve,
                                 cap=dsp.max_df,
                                 bcap=dsp.max_blocks_per_term,
                                 tile_d=dsp.tile_d, backend=self.backend)
                sc_list.append(res.topk_scores)
                id_list.append(res.topk_docs + self.delta.base_docs)
            if self._debug_shard_lists is not None:
                self._debug_shard_lists.append(
                    (rows, [np.asarray(a) for a in sc_list],
                     [np.asarray(a) for a in id_list]))
            if ns == 1 and self.delta is None:
                topk[rows] = np.asarray(id_list[0])
                topk_sc[rows] = np.asarray(sc_list[0]).astype(np.float32)
                if drop is not None and drop[0, rows].any():
                    dead = rows[drop[0, rows]]
                    topk[dead] = -1
                    topk_sc[dead] = SCORE_FILL
            else:
                dr = None if drop is None else drop[:, rows]
                if dr is not None and self.delta is not None:
                    dr = np.concatenate(
                        [dr, np.zeros((1, len(rows)), bool)])
                ids, sc = merge_shard_topk(sc_list, id_list, self.k_serve,
                                           drop=dr)
                topk[rows] = np.asarray(ids)
                topk_sc[rows] = np.asarray(sc).astype(np.float32)
            t_bmw[rows] = self.cost.gather_time(t_shards[:, rows])
        return topk, topk_sc, t_bmw, t_shards

    def stage2(self, terms, mask, topics, cand, k_per_query) -> CascadeResult:
        """Batched LTR re-rank of the merged Stage-1 candidate grid (the
        re-ranker sees global doc ids, so it is shard-agnostic)."""
        backend = resolve_backend(self.backend)
        qcap = None
        if backend != "jnp":
            qcap = query_lane_budget(self.index.df, terms, mask)
        return rerank_batched(self.s2, self.ltr, terms, mask, topics,
                              cand, k_per_query, t_final=self.t_final,
                              n_iter=self.n_iter, backend=backend, qcap=qcap,
                              lane_need=qcap)

    # ------------------------------------------------------------------
    # replica-pool bookkeeping
    # ------------------------------------------------------------------

    def _pool_route(self, routed, n_queries: int):
        """Pick one replica of every partition for each query (its routed
        mirror; hedged queries also occupy the JASS mirror).  A partition
        with no healthy replica yields ``None`` in its slot (degraded
        serving), never an exception — with a fully-healthy pool the pick
        sequence is identical to the historical all-or-nothing route."""
        is_jass = np.zeros(n_queries, bool)
        is_jass[routed.jass_rows] = True
        picks = [self.pool.route_query_partial(JASS if is_jass[i] else BMW)
                 for i in range(n_queries)]
        hedge_picks = {int(i): self.pool.route_query(JASS)
                       for i in routed.hedged_rows}
        return picks, hedge_picks

    def _fault_plan(self, picks, routed, now: float):
        """Run the scatter-gather failure protocol for one batch against
        the fault schedule at clock ``now``.

        For every (query, shard) request: an attempt to a crashed/outaged
        replica — or one killed by a transient-timeout draw — is detected
        after ``failover_timeout``, reported ``ok=False`` to the pool (so
        ``fail_after`` can trip), and re-issued to a different healthy
        replica of the same partition, at most ``max_retries`` times.  When
        the chain is exhausted the slot is declared lost and the query
        degrades to partial coverage.

        Mutates ``picks`` in place (final serving replica, or ``None`` for
        a lost slot) and returns ``(delay, mult, lost)``: per-(shard,
        query) accumulated timeout wait, straggler slowdown of the serving
        replica, and the lost mask.
        """
        cfg = self.sched.cfg
        timeout, max_retries = cfg.failover_timeout, cfg.max_retries
        ns, q = self.n_shards, len(picks)
        delay = np.zeros((ns, q))
        mult = np.ones((ns, q))
        lost = np.zeros((ns, q), bool)
        ctr = self._fault_counters
        is_jass = np.zeros(q, bool)
        is_jass[routed.jass_rows] = True
        for i, reps in enumerate(picks):
            mirror = JASS if is_jass[i] else BMW
            for s in range(ns):
                r = reps[s]
                if r is None:            # no healthy replica to even try
                    lost[s, i] = True
                    ctr["no_route"] += 1
                    continue
                tried = {id(r)}
                failures = 0
                while True:
                    if not self.faults.is_up(s, r.replica_id, now):
                        ctr["down_requests"] += 1
                    elif self.faults.transient(now):
                        ctr["transient"] += 1
                    else:                # attempt serves
                        mult[s, i] = self.faults.slowdown(s, r.replica_id,
                                                          now)
                        reps[s] = r
                        break
                    # attempt dead: detected at the timeout, charged to the
                    # query's wait and to the replica's health record
                    self.pool.complete(r, latency=timeout, ok=False)
                    delay[s, i] += timeout
                    failures += 1
                    nxt = (self.pool.pick_retry(s, mirror, tried)
                           if failures <= max_retries else None)
                    if nxt is None:      # retry budget / pool exhausted
                        lost[s, i] = True
                        reps[s] = None
                        ctr["lost_partitions"] += 1
                        break
                    ctr["retries"] += 1
                    nxt.inflight += 1
                    tried.add(id(nxt))
                    r = nxt
        return delay, mult, lost

    def _pool_complete(self, terms, mask, routed, picks, hedge_picks,
                       t_shards, cache: dict | None = None):
        """Feed observed per-(query, shard) latencies back into the pool."""
        for i, reps in enumerate(picks):
            if reps is None:
                continue
            for s, r in enumerate(reps):
                if r is None:            # lost/dropped slot: already
                    continue             # released by the failure protocol
                self.pool.complete(r, latency=float(t_shards[s, i]))
        if hedge_picks:
            rows = np.fromiter(hedge_picks, dtype=np.int64)
            work_s, _ = self._jass_split(terms, mask, rows,
                                         routed.rho[rows], cache)
            t_h = np.stack([self.cost.saat_time(w.astype(np.float64))
                            for w in work_s[:self.n_shards]])
            for j, i in enumerate(rows):
                reps = hedge_picks[int(i)]
                if reps is None:
                    continue
                for s, r in enumerate(reps):
                    self.pool.complete(r, latency=float(t_h[s, j]))
        self._batches += 1
        every = self.cascade_spec.deploy.rebalance_every
        if every and self._batches % every == 0:
            n_j = len(routed.jass_rows)
            n_b = len(routed.bmw_rows)
            if n_j + n_b:
                self.pool.rebalance(n_j / (n_j + n_b))

    # ------------------------------------------------------------------
    # end to end
    # ------------------------------------------------------------------

    def serve(self, terms: np.ndarray, mask: np.ndarray,
              topics: np.ndarray | None = None, *,
              stage2_cap: np.ndarray | None = None,
              shard_cap: np.ndarray | None = None,
              now: float | None = None) -> PipelineResult:
        """Serve one batch through the full cascade.

        ``stage2_cap`` is an optional per-query hard cap on the Stage-2
        candidate grid (admission control's degrade ladder: ``k_serve`` =
        full service, ``0 < cap < k_serve`` = trimmed re-rank, ``0`` =
        stage1-only — the rank-safe Stage-1 order is served directly).

        ``shard_cap`` is an optional per-query cap on the number of
        partitions queried (admission's partial-coverage rung: queries
        only the first ``shard_cap[i]`` partitions, trading coverage for
        gather overhead).  ``now`` pins the serving clock the fault
        schedule is evaluated against (default: the system's own clock,
        advanced by each batch's occupancy; the online simulator passes
        its dispatch time).  With an inert fault spec and no ``shard_cap``
        this path is bit-identical to fault-free serving.

        With an active :class:`~repro.serving.spec.CacheSpec` every query
        is first looked up in the two-level serving cache (L1 exact
        results bypass the cascade, L2 candidates skip retrieval and
        re-run Stage-2) and full-coverage results are filled back; with
        the cache disabled (the default) this method IS the direct
        cascade, bit-identical to the pre-cache system.
        """
        if self.cache is None:
            return self._serve_direct(terms, mask, topics,
                                      stage2_cap=stage2_cap,
                                      shard_cap=shard_cap, now=now)
        return self._serve_cached(terms, mask, topics,
                                  stage2_cap=stage2_cap,
                                  shard_cap=shard_cap, now=now)

    def _serve_direct(self, terms: np.ndarray, mask: np.ndarray,
                      topics: np.ndarray | None = None, *,
                      stage2_cap: np.ndarray | None = None,
                      shard_cap: np.ndarray | None = None,
                      now: float | None = None) -> PipelineResult:
        """The uncached cascade (see :meth:`serve` for the contract)."""
        q = terms.shape[0]
        ns = self.n_shards
        now = float(self._clock if now is None else now)
        faulted = self.faults.active or shard_cap is not None
        if self.faults.active:
            # drive recovery from the serve loop: probe unhealthy replicas
            # against the schedule (a cleared window re-admits the replica)
            probes, rec = self.pool.probe_unhealthy(
                lambda r: self.faults.is_up(r.partition, r.replica_id, now))
            self._fault_counters["probes"] += probes
            self._fault_counters["recovered"] += rec
        pk, pr, pt = self.stage0(terms, mask)
        routed = self.sched.route(pk, pr, pt)
        modality = None
        if self.dense is not None:
            # modality dispatch: dense-only rows leave the lexical
            # sub-batches entirely (their replica picks below still pin the
            # co-located partition replicas the dense engine runs on, so
            # the failure protocol covers dense traffic too)
            modality = self._modality(pt)
            routed = self._restrict_lexical(routed, modality)
        # route replicas before the engines run so the pool sees the whole
        # batch in flight (power-of-two-choices balances against inflight)
        picks, hedge_picks = self._pool_route(routed, q)

        drop = None
        coverage = None
        if faulted:
            # admission-chosen partial coverage: the trailing partitions
            # are never requested — release their routed picks
            dropped = np.zeros((ns, q), bool)
            if shard_cap is not None:
                cap = np.clip(np.asarray(shard_cap, np.int64), 1, ns)
                for i in range(q):
                    for s in range(int(cap[i]), ns):
                        r = picks[i][s]
                        if r is not None:
                            r.inflight = max(r.inflight - 1, 0)
                            picks[i][s] = None
                        dropped[s, i] = True
            # injected faults: timeout detection, bounded failover, loss
            delay, mult, lost = self._fault_plan(picks, routed, now)
            lost &= ~dropped
            drop = lost | dropped
            coverage = 1.0 - drop.sum(axis=0) / ns
            n_deg = int((coverage < 1.0).sum())
            self._fault_counters["degraded_queries"] += n_deg

        split_cache: dict = {}
        topk, topk_sc, t_bmw, t_shards = self._stage1_full(
            terms, mask, routed, split_cache, drop=drop)

        theta_skip = np.zeros(q, bool)
        fallback = np.zeros(q, bool)
        fb_extra = np.zeros(q)          # theta_low lexical-fallback latency
        t_dense_mat = None              # (ns, Q) per-shard dense time
        d_rows = (np.flatnonzero(modality != M_LEX)
                  if self.dense is not None else np.zeros(0, np.int64))
        if len(d_rows):
            ds = self.cascade_spec.dense
            q_emb = self.dense.embed(terms[d_rows], mask[d_rows])
            d_ids, d_sc = self.dense.serve(
                q_emb, self.k_serve,
                drop=None if drop is None else drop[:, d_rows])
            # shape-static per-shard dense time: every query scores every
            # tile of every shard, so the matrix is query-independent
            t_dense_mat = np.zeros((ns, q))
            for s in range(ns):
                t_dense_mat[s, d_rows] = float(
                    self.cost.dense_time(self.dense.n_tiles(s)))
            dmod = modality[d_rows]
            only_rows = d_rows[dmod == M_DENSE]
            both_rows = d_rows[dmod == M_BOTH]
            # dense-only rows serve the dense list; both rows fuse the two
            topk[only_rows] = d_ids[dmod == M_DENSE]
            topk_sc[only_rows] = d_sc[dmod == M_DENSE]
            if len(both_rows):
                f_ids, f_sc = fuse(self.cascade_spec.fusion,
                                   topk[both_rows], topk_sc[both_rows],
                                   d_ids[dmod == M_BOTH],
                                   d_sc[dmod == M_BOTH], self.k_serve)
                topk[both_rows] = f_ids
                topk_sc[both_rows] = f_sc
            top_dense = d_sc[:, 0].astype(np.float64)
            if np.isfinite(ds.theta_high):
                # high-confidence shortcut: Stage-2 is skipped rank-safely
                # (the existing zero-grid path serves the Stage-1 order)
                theta_skip[d_rows] = top_dense >= ds.theta_high
            if np.isfinite(ds.theta_low) and len(only_rows):
                fb_rows = only_rows[top_dense[dmod == M_DENSE]
                                    < ds.theta_low]
                if len(fb_rows):
                    # low-confidence dense-only rows re-issue a ρ-capped
                    # lexical traversal — same cap and nominal-healthy
                    # pricing as the scheduler's late hedge, so the route
                    # stays inside worst_case_us
                    fb_routed = RoutedBatch(
                        jass_rows=fb_rows,
                        bmw_rows=np.zeros(0, np.int64),
                        hedged_rows=np.zeros(0, np.int64),
                        k=routed.k,
                        rho=np.minimum(
                            routed.rho,
                            float(self.sched.cfg.resolved_late_rho())))
                    fb_topk, fb_sc, _, fb_tsh = self._stage1_full(
                        terms, mask, fb_routed, split_cache)
                    topk[fb_rows] = fb_topk[fb_rows]
                    topk_sc[fb_rows] = fb_sc[fb_rows]
                    fb_extra[fb_rows] = self.cost.gather_time(
                        fb_tsh[:, fb_rows])
                    fallback[fb_rows] = True

        if faulted:
            # per-shard completion time under the plan: a served slot pays
            # its retry wait plus the (possibly straggler-slowed) engine
            # time; a lost slot pays the full detection chain; a dropped
            # slot was never requested.  The query still waits for its
            # slowest slot (scatter-gather), and pays merge fan-out only
            # over the partitions that answered.
            t_fault = np.where(dropped, 0.0,
                               delay + np.where(lost, 0.0, t_shards * mult))
            n_live = ns - drop.sum(axis=0)
            gather_ov = (self.cost.gather_per_shard_us
                         * np.maximum(n_live - 1, 0))

            def _gather_fault(tmat, rows):
                return tmat.max(axis=0) + gather_ov[rows]

            t_bmw = np.zeros(q)
            if len(routed.bmw_rows):
                rows = routed.bmw_rows
                t_bmw[rows] = _gather_fault(t_fault[:, rows], rows)

            def jass_fault_fn(rows, rho):
                work_s, _ = self._jass_split(terms, mask, rows, rho,
                                             split_cache)
                t = np.stack([self.cost.saat_time(w.astype(np.float64))
                              for w in work_s[:ns]])
                tf = np.where(dropped[:, rows], 0.0,
                              delay[:, rows]
                              + np.where(lost[:, rows], 0.0,
                                         t * mult[:, rows]))
                return _gather_fault(tf, rows)

            # the deadline re-issue goes to a fresh healthy replica, so it
            # pays nominal JASS cost — the retry wait it could still incur
            # is charged analytically via SchedulerConfig.retry_us()
            lat01 = self.sched.resolve_times(
                routed, t_bmw, jass_fault_fn,
                late_jass_fn=self._jass_time(terms, mask, split_cache))
            t_pool = t_fault
            if t_dense_mat is not None:
                # dense requests ride the same failure protocol: a served
                # slot pays its retry wait + (possibly straggler-slowed)
                # dense engine time, lost/dropped slots exactly as lexical
                t_dense_eff = np.where(dropped, 0.0,
                                       delay + np.where(lost, 0.0,
                                                        t_dense_mat * mult))
                t_pool = np.maximum(t_pool, t_dense_eff)
                tdr = np.zeros(q)
                tdr[d_rows] = (t_dense_eff[:, d_rows].max(axis=0)
                               + gather_ov[d_rows])
        else:
            lat01 = self.sched.resolve_times(
                routed, t_bmw, self._jass_time(terms, mask, split_cache))
            t_pool = t_shards
            if t_dense_mat is not None:
                # a partition replica hosting both engines is busy for the
                # max of its co-located work
                t_pool = np.maximum(t_pool, t_dense_mat)
                tdr = np.zeros(q)
                tdr[d_rows] = self.cost.gather_time(t_dense_mat[:, d_rows])
        if len(d_rows):
            # dense-only: predict + dense scatter-gather (+ any theta_low
            # fallback); both: the two engines run in parallel, the query
            # waits for the slower and pays the host-side fusion merge
            pd = self.cost.predict_us
            only = modality == M_DENSE
            both = modality == M_BOTH
            lat01 = np.where(only, pd + tdr + fb_extra, lat01)
            lat01 = np.where(both,
                             pd + np.maximum(lat01 - pd, tdr)
                             + self.cost.fusion_us, lat01)
        if self.delta is not None:
            # every served query scans the delta segment; its arrays are
            # capacity-padded, so the cost is one shape-static term —
            # charged here, BEFORE budget enforcement trims Stage-2, and
            # identically inside worst_case_us()
            lat01 = lat01 + self._delta_us
        t0 = np.full(q, self.cost.predict_us)
        stage_latency = {"stage0": t0, "stage1": lat01 - t0}

        if len(routed.bmw_rows):
            # online quantile-error signal for the t predictor: pinball
            # loss of pred_t against the observed BMW engine time, at the
            # predictor's own training tau — feeds _adapt_routing's
            # hedge_deadline loop
            tau = self.cascade_spec.stage0.tau_t
            e = t_bmw[routed.bmw_rows] - pt[routed.bmw_rows]
            pin = float(np.mean(np.maximum(tau * e, (tau - 1.0) * e)))
            self._pinball_ewma = (pin if self._pinball_ewma is None
                                  else 0.8 * self._pinball_ewma + 0.2 * pin)

        final = None
        used = None
        enforce = self.sched.cfg.enforce_budget
        trimmed = skipped = 0
        if self.ltr is not None:
            if topics is None:
                raise ValueError("Stage-2 re-ranking needs per-query topics")
            k2 = np.minimum(routed.k, self.k_serve)
            if stage2_cap is not None:
                # admission-control degrade ladder: the cap is decided from
                # response-time slack (queueing included), before the
                # service-budget enforcement below
                k2 = np.minimum(k2, np.asarray(stage2_cap, np.int64))
            if drop is not None:
                # degraded queries may hold fewer than k_serve real
                # candidates (-1 padding from the masked merge): never ask
                # Stage-2 to rank the padding
                k2 = np.minimum(k2, (topk >= 0).sum(axis=1))
            if theta_skip.any():
                # dense confidence shortcut: the Stage-1 order is served
                # directly (rank-safe), zeroed BEFORE enforcement so these
                # rows never count as budget-driven skips
                k2 = np.where(theta_skip, 0, k2)
            if enforce:
                # cascade hedge: a query whose Stage-1 time already ate the
                # budget gets its candidate grid trimmed (masked re-rank) —
                # or skipped outright — so ltr_time cannot push it over.
                # When the Stage-1 bound holds, the Stage-2 reservation
                # guarantees afford >= k_serve and this is a no-op.
                afford = stage2_afford(self.cost, self.budget - lat01,
                                       self.k_serve)
                trimmed = int(np.sum((0 < afford) & (afford < k2)))
                skipped = int(np.sum((afford == 0) & (k2 > 0)))
                k2 = np.minimum(k2, afford)
            cand = topk if drop is None else np.where(topk >= 0, topk, 0)
            res2 = self.stage2(terms, mask, topics, cand.astype(np.int32), k2)
            final, used = res2.final, res2.candidates_used
            skip_rows = np.flatnonzero(k2 == 0)
            if len(skip_rows):
                # zero-grid queries (enforcement skip or admission's
                # stage1-only rung) serve their Stage-1 order directly
                # (the rank-safe list) at zero Stage-2 cost
                final[skip_rows] = topk[skip_rows, :self.t_final]
            stage_latency["stage2"] = np.where(
                used > 0, self.cost.ltr_time(used), 0.0)
        else:
            stage_latency["stage2"] = np.zeros(q)

        self._pool_complete(terms, mask, routed, picks, hedge_picks,
                            t_pool, split_cache)
        every = self.cascade_spec.routing.adapt_every
        if every and self._batches % every == 0:
            self._adapt_routing()

        lat = lat01 + stage_latency["stage2"]
        # the serving clock advances by the batch's occupancy so fault
        # windows expressed in cost-model time mean the same thing whether
        # serve() is driven offline or by the online event loop
        self._clock = now + (float(lat.max()) if q else 0.0)
        dense_info = None
        if self.dense is not None:
            dense_info = {"modality": modality, "theta_skip": theta_skip,
                          "fallback": fallback}
        stats = self._build_stats(
            lat, stage_latency, trimmed, skipped, faulted, coverage, now,
            dense_info=dense_info)
        if self.telemetry is not None:
            self._record_traces(
                q=q, now=now, lat=lat, stage_latency=stage_latency,
                pk=pk, pr=pr, pt=pt, routed=routed, modality=modality,
                theta_skip=theta_skip, fallback=fallback, used=used,
                t_shards=t_shards, faulted=faulted,
                delay=delay if faulted else None,
                mult=mult if faulted else None,
                lost=lost if faulted else None,
                dropped=dropped if faulted else None, coverage=coverage)
        return PipelineResult(topk=topk, final=final, candidates_used=used,
                              latency=lat, stage_latency=stage_latency,
                              stats=stats, coverage=coverage,
                              dense=dense_info)

    # ------------------------------------------------------------------
    # result/candidate caching
    # ------------------------------------------------------------------

    def _cache_epoch(self, now: float):
        """The coverage/fault epoch cache entries are tagged with at clock
        ``now``: the per-partition reachability vector plus the transient-
        storm window flag.  Entries only hit inside the epoch they were
        filled in, so serving across a fault transition (a partition dying
        or healing, a storm starting) re-derives from the live cascade
        instead of trusting results certified under different coverage.
        With an inert fault spec this is one constant — no per-query work,
        no RNG (``transient`` draws are never consumed here).

        With live ingest attached the epoch additionally carries the
        ingest counter (bumped on every applied feed batch and every
        merge), so entries filled against one delta state never hit after
        the collection has changed under them."""
        if not self.faults.active:
            base = HEALTHY_EPOCH
        else:
            reps = self.cascade_spec.deploy.replicas
            up = tuple(self.faults.partition_up(p, reps, now)
                       for p in range(self.n_shards))
            sp = self.faults.spec
            storm = bool(sp.timeout_p > 0
                         and sp.timeout_start <= now < sp.timeout_end)
            base = up + (storm,)
        if self.delta is not None:
            return ingest_epoch(base, self._ingest_counters["epoch"])
        return base

    def _pure_route(self, pk, pr, pt):
        """Route a batch WITHOUT counting it: ``StageZeroScheduler.route``
        accumulates routing stats, but cache-key derivation must not double
        count rows the miss sub-batch re-routes for real below."""
        saved = dict(self.sched.stats)
        routed = self.sched.route(pk, pr, pt)
        self.sched.stats.clear()
        self.sched.stats.update(saved)
        return routed

    def cache_peek(self, terms: np.ndarray, mask: np.ndarray,
                   topics: np.ndarray | None = None, *,
                   now: float | None = None) -> np.ndarray:
        """Per-query bool mask of *guaranteed* L1 hits at clock ``now`` —
        rows for which :meth:`serve` (called at the same clock, before any
        other serve) will bypass the cascade at full service.  Probes only
        the FULL-mode key (``cap = k_serve``), mutates nothing (no recency
        moves, no stats, no RNG), so admission can peek at dispatch time
        without perturbing replay determinism."""
        q = terms.shape[0]
        out = np.zeros(q, bool)
        if self.cache is None or self.cache.l1 is None:
            return out
        now = float(self._clock if now is None else now)
        epoch = self._cache_epoch(now)
        pk, pr, pt = self.stage0(terms, mask)
        routed = self._pure_route(pk, pr, pt)
        modality = self._modality(pt) if self.dense is not None else None
        is_jass = np.zeros(q, bool)
        is_jass[routed.jass_rows] = True
        for i in range(q):
            qk = normalize_query(terms[i], mask[i],
                                 None if topics is None else topics[i])
            rs = route_sig(bool(is_jass[i]), float(routed.rho[i]),
                           float(routed.k[i]),
                           b"" if modality is None
                           else b"|M%d" % modality[i])
            out[i] = self.cache.l1_contains(
                l1_key(qk, rs, self.k_serve, self.t_final, self.k_serve),
                epoch)
        return out

    def _serve_cached(self, terms: np.ndarray, mask: np.ndarray,
                      topics: np.ndarray | None = None, *,
                      stage2_cap: np.ndarray | None = None,
                      shard_cap: np.ndarray | None = None,
                      now: float | None = None) -> PipelineResult:
        """serve() with the two-level cache in front of the cascade.

        Per query: L1 hit → the cached (topk, final, used) row at
        ``predict_us + cache_hit_us``; L2 hit → cached Stage-1 candidates,
        fresh Stage-2 re-rank; miss → the full cascade via
        :meth:`_serve_direct` on the miss sub-batch (row-independent
        batched kernels keep sub-batch results bit-identical to the
        full-batch ones).  Every query pays the ``cache_hit_us`` lookup —
        that is the term :meth:`worst_case_us` charges.

        Correctness guards: rows admitted at partial coverage
        (``shard_cap < n_shards``) bypass the cache entirely, results that
        came back with ``coverage < 1`` are never filled, and every entry
        carries the fill-time fault epoch (see :meth:`_cache_epoch`).  A
        hit may serve the *untrimmed* re-rank where a cold serve would
        have had to trim for budget — the hit has the slack to spend;
        whenever enforcement didn't trim the cold path, hit == recompute
        bit-for-bit (certified by ``benchmarks/bench_cache.py``).
        """
        q = terms.shape[0]
        ns = self.n_shards
        now = float(self._clock if now is None else now)
        cache = self.cache
        epoch = self._cache_epoch(now)
        pk, pr, pt = self.stage0(terms, mask)
        routed = self._pure_route(pk, pr, pt)
        # the resolved modality is part of the route: lexical, dense and
        # fused entries for the same query must never collide (with dense
        # disabled the suffix is b"" and keys are byte-identical)
        modality = self._modality(pt) if self.dense is not None else None
        is_jass = np.zeros(q, bool)
        is_jass[routed.jass_rows] = True

        cap = np.full(q, self.k_serve, np.int64)
        if stage2_cap is not None:
            cap = np.minimum(np.asarray(stage2_cap, np.int64), self.k_serve)
        # the partial-coverage rung deliberately queries fewer partitions:
        # those rows neither look up nor fill (a full-coverage cached
        # result would silently upgrade the admission decision)
        eligible = (np.ones(q, bool) if shard_cap is None
                    else np.asarray(shard_cap, np.int64) >= ns)

        keys1 = [None] * q
        keys2 = [None] * q
        l1_hit = np.zeros(q, bool)
        l2_hit = np.zeros(q, bool)
        l1_vals: dict = {}
        l2_vals: dict = {}
        for i in range(q):
            if not eligible[i]:
                cache.counters["skipped_partial"] += 1
                continue
            cache.counters["lookups"] += 1
            qk = normalize_query(terms[i], mask[i],
                                 None if topics is None else topics[i])
            rs = route_sig(bool(is_jass[i]), float(routed.rho[i]),
                           float(routed.k[i]),
                           b"" if modality is None
                           else b"|M%d" % modality[i])
            keys1[i] = l1_key(qk, rs, self.k_serve, self.t_final,
                              int(cap[i]))
            v = cache.l1_get(keys1[i], epoch)
            if v is not None:
                l1_hit[i] = True
                l1_vals[i] = v
                cache.counters["l1_hits"] += 1
                continue
            keys2[i] = l2_key(qk, rs)
            if self.ltr is not None:
                v2 = cache.l2_get(keys2[i], epoch)
                if v2 is not None:
                    l2_hit[i] = True
                    l2_vals[i] = v2
                    cache.counters["l2_hits"] += 1
                    continue
            cache.counters["full_misses"] += 1

        hit_us = self.cost.cache_hit_us
        topk = np.zeros((q, self.k_serve), np.int64)
        final_rows: list = [None] * q
        used = np.zeros(q, np.int64) if self.ltr is not None else None
        t0 = np.full(q, self.cost.predict_us)
        t1 = np.zeros(q)
        t2 = np.zeros(q)
        faulted = self.faults.active or shard_cap is not None
        coverage = np.ones(q) if faulted else None
        trimmed = skipped = 0

        rows1 = np.flatnonzero(l1_hit)
        for i in rows1:
            tk, f, u = l1_vals[i]
            topk[i] = tk
            if self.ltr is not None:
                final_rows[i] = f
                used[i] = u
        t1[rows1] = hit_us

        rows2 = np.flatnonzero(l2_hit)
        skip_flags = None
        if len(rows2):
            vals = [l2_vals[i] for i in rows2]
            if self.dense is not None:
                # dense-mode L2 entries carry the fill-time theta-skip
                # decision, so a hit replays the same Stage-2 shortcut the
                # cold serve took
                cand = np.stack([v[0] for v in vals])
                skip_flags = np.array([bool(v[1]) for v in vals])
            else:
                cand = np.stack(vals)
            topk[rows2] = cand
            t1[rows2] = hit_us
            k2 = np.minimum(np.minimum(routed.k[rows2], self.k_serve),
                            cap[rows2]).astype(np.int64)
            if skip_flags is not None:
                k2[skip_flags] = 0
            if self.sched.cfg.enforce_budget:
                # same enforcement as the cold path, priced at the hit's
                # actual stage-1 cost — a hit has the slack to afford the
                # full grid whenever the reserve holds
                afford = stage2_afford(
                    self.cost,
                    self.budget - (self.cost.predict_us + hit_us),
                    self.k_serve)
                trimmed += int(np.sum((0 < afford) & (afford < k2)))
                skipped += int(np.sum((afford == 0) & (k2 > 0)))
                k2 = np.minimum(k2, afford)
            res2 = self.stage2(terms[rows2], mask[rows2], topics[rows2],
                               cand.astype(np.int32), k2)
            f2, u2 = res2.final, res2.candidates_used
            skip = np.flatnonzero(k2 == 0)
            if len(skip):
                f2[skip] = cand[skip, :self.t_final]
            for j, i in enumerate(rows2):
                final_rows[i] = f2[j]
                used[i] = u2[j]
            t2[rows2] = np.where(u2 > 0, self.cost.ltr_time(u2), 0.0)
            # promote: the fresh full-coverage re-rank is exactly an L1
            # entry for this (query, route, stage-2 params) point
            for j, i in enumerate(rows2):
                cache.l1_put(keys1[i],
                             (topk[i].copy(), f2[j].copy(), int(u2[j])),
                             epoch)

        miss_rows = np.flatnonzero(~(l1_hit | l2_hit))
        sub = None
        if len(miss_rows):
            tel = self.telemetry
            outer_ctx = tel.batch_context if tel is not None else None
            if tel is not None:
                # the sub-serve records the miss rows' traces (it is the
                # real cascade execution) tagged "miss", but must not
                # re-feed batch metrics: this batch feeds them once below
                if outer_ctx is not None:
                    tel.batch_context = {
                        k: (v[miss_rows] if isinstance(v, np.ndarray)
                            else v)
                        for k, v in outer_ctx.items()}
                self._tel_suppress = True
                self._tel_cache_tag = "miss"
            try:
                sub = self._serve_direct(
                    terms[miss_rows], mask[miss_rows],
                    None if topics is None else topics[miss_rows],
                    stage2_cap=(None if stage2_cap is None
                                else np.asarray(stage2_cap)[miss_rows]),
                    shard_cap=(None if shard_cap is None
                               else np.asarray(shard_cap)[miss_rows]),
                    now=now)
            finally:
                if tel is not None:
                    tel.batch_context = outer_ctx
                    self._tel_suppress = False
                    self._tel_cache_tag = None
            topk[miss_rows] = sub.topk
            if self.ltr is not None:
                for j, i in enumerate(miss_rows):
                    final_rows[i] = sub.final[j]
                used[miss_rows] = sub.candidates_used
            t0[miss_rows] = sub.stage_latency["stage0"]
            # misses pay the failed lookup on top of the cascade
            t1[miss_rows] = sub.stage_latency["stage1"] + hit_us
            t2[miss_rows] = sub.stage_latency["stage2"]
            if coverage is not None and sub.coverage is not None:
                coverage[miss_rows] = sub.coverage
            sb = sub.stats["budget"]
            trimmed += sb["stage2_trimmed"]
            skipped += sb["stage2_skipped"]
            for j, i in enumerate(miss_rows):
                if not eligible[i]:
                    continue
                if sub.coverage is not None and sub.coverage[j] < 1.0:
                    cache.counters["skipped_partial"] += 1
                    continue   # partial coverage is never cached
                if self.ltr is not None:
                    v2 = sub.topk[j].copy()
                    if self.dense is not None:
                        v2 = (v2, bool(sub.dense["theta_skip"][j]))
                    cache.l2_put(keys2[i], v2, epoch)
                    cache.l1_put(keys1[i],
                                 (sub.topk[j].copy(), sub.final[j].copy(),
                                  int(sub.candidates_used[j])), epoch)
                else:
                    cache.l1_put(keys1[i],
                                 (sub.topk[j].copy(), None, None), epoch)

        final = (np.stack(final_rows) if self.ltr is not None else None)
        lat = t0 + t1 + t2
        stage_latency = {"stage0": t0, "stage1": t1, "stage2": t2}
        # the batch advances the shared serving clock exactly like the
        # direct path (the miss sub-serve's advance is overridden: the
        # batch's occupancy is the max over ALL its rows)
        self._clock = now + (float(lat.max()) if q else 0.0)

        dense_info = None
        if self.dense is not None:
            theta_all = np.zeros(q, bool)
            fb_all = np.zeros(q, bool)
            if sub is not None:
                theta_all[miss_rows] = sub.dense["theta_skip"]
                fb_all[miss_rows] = sub.dense["fallback"]
            if skip_flags is not None:
                theta_all[rows2] = skip_flags
            # L1 rows keep False flags: their final list already baked in
            # whatever shortcut the fill-time serve took
            dense_info = {"modality": modality, "theta_skip": theta_all,
                          "fallback": fb_all}
        stats = self._build_stats(
            lat, stage_latency, trimmed, skipped, faulted, coverage, now,
            dense_info=dense_info, cache_stats=cache.stats())
        if self.telemetry is not None:
            self._record_hit_traces(l1_hit, l2_hit, lat, t0, t2, hit_us,
                                    now)
        return PipelineResult(topk=topk, final=final, candidates_used=used,
                              latency=lat, stage_latency=stage_latency,
                              stats=stats, coverage=coverage,
                              dense=dense_info)

    # ------------------------------------------------------------------
    # batch stats + telemetry
    # ------------------------------------------------------------------

    def _build_stats(self, lat, stage_latency, trimmed, skipped, faulted,
                     coverage, now, *, dense_info=None,
                     cache_stats=None) -> dict:
        """The per-batch stats dict both serve paths report — one builder
        so the direct and cached paths cannot drift — plus the telemetry
        feed (per-query/per-stage histograms and degradation counters)
        when a registry is attached."""
        q = len(lat)
        stats = dict(self.sched.stats)
        stats.update(percentiles(lat))
        n_over, pct = over_budget(lat, self.budget)
        stats["over_budget"] = n_over
        stats["over_budget_pct"] = pct
        stats["stages"] = {}
        for name, t in stage_latency.items():
            if not np.any(t > 0):
                continue
            entry = percentiles(t)
            # per-stage budget attribution: each stage is accountable to
            # its reserved share of the cascade budget (fused routes spend
            # the fusion reserve inside stage 1)
            b = (self._budget_reserve[name]
                 + (self._budget_reserve.get("fusion", 0.0)
                    if name == "stage1" else 0.0))
            entry["budget"] = b
            entry["over_budget"] = over_budget(t, b)[0]
            stats["stages"][name] = entry
        stats["budget"] = {
            "total": self.budget,
            "reserve": dict(self._budget_reserve),
            "enforce": self.sched.cfg.enforce_budget,
            "worst_case_bound": self.worst_case_us(),
            "stage2_trimmed": trimmed,
            "stage2_skipped": skipped,
        }
        stats["n_shards"] = self.n_shards
        stats["pool"] = self.pool.stats()
        if faulted:
            stats["faults"] = dict(self._fault_counters)
            stats["faults"]["clock"] = now
            stats["coverage"] = {
                "min": float(coverage.min()) if q else 1.0,
                "mean": float(coverage.mean()) if q else 1.0,
                "degraded": int((coverage < 1.0).sum()),
            }
        if cache_stats is not None:
            stats["cache"] = cache_stats
        if dense_info is not None:
            modality = dense_info["modality"]
            stats["dense"] = {
                "lexical": int(np.sum(modality == M_LEX)),
                "dense_only": int(np.sum(modality == M_DENSE)),
                "fused": int(np.sum(modality == M_BOTH)),
                "theta_skips": int(dense_info["theta_skip"].sum()),
                "fallbacks": int(dense_info["fallback"].sum()),
            }
        tel = self.telemetry
        if tel is not None and not self._tel_suppress:
            # micro-batch pads carry qid=-1 in the batch context: real
            # device work, but not queries — keep them out of the
            # per-query latency histograms and counters
            ctx_q = (tel.batch_context or {}).get("qid")
            keep = (np.asarray(ctx_q) >= 0 if ctx_q is not None
                    else slice(None))
            tel.record_batch(lat[keep],
                             {k: v[keep] for k, v in stage_latency.items()},
                             self.budget, trimmed=trimmed, skipped=skipped)
            if dense_info is not None:
                d = stats["dense"]
                for k in ("lexical", "dense_only", "fused"):
                    tel.registry.counter("modality", route=k).inc(d[k])
                tel.registry.counter("theta_skips").inc(d["theta_skips"])
                tel.registry.counter("dense_fallbacks").inc(d["fallbacks"])
        self._last_stats = stats
        return stats

    def _tel_context(self, q: int):
        """Resolve the per-row trace context: the online simulator sets
        ``telemetry.batch_context`` with queue waits, admission modes and
        real query ids around ``serve``; offline serves synthesize
        sequential qids and zero wait."""
        tel = self.telemetry
        ctx = tel.batch_context or {}
        wait = ctx.get("wait")
        modes = ctx.get("mode")
        qids = ctx.get("qid")
        budget = float(ctx.get("budget", self.budget))
        if qids is None:
            qids = tel.query_seq + np.arange(q)
            tel.query_seq += q
        return wait, modes, qids, budget

    def _record_traces(self, *, q, now, lat, stage_latency, pk, pr, pt,
                       routed, modality, theta_skip, fallback, used,
                       t_shards, faulted, delay, mult, lost, dropped,
                       coverage) -> None:
        """Build span trees for the rows the trace store would retain
        (slowest / budget-violating first; ``would_keep`` prunes the rest
        so trace building stays off the common path)."""
        tel = self.telemetry
        if tel.traces.capacity == 0:
            return
        wait, modes, qids, budget = self._tel_context(q)
        is_jass = np.zeros(q, bool)
        is_jass[routed.jass_rows] = True
        is_hedge = np.zeros(q, bool)
        is_hedge[routed.hedged_rows] = True
        timeout = self.sched.cfg.failover_timeout
        mod_name = {M_LEX: "lexical", M_DENSE: "dense", M_BOTH: "fused"}
        for r in range(q):
            if int(qids[r]) < 0:
                continue   # micro-batch pad row, not a query
            w = float(wait[r]) if wait is not None else 0.0
            total = float(lat[r]) + w
            violation = total > budget
            if not tel.traces.would_keep(total, violation):
                continue
            t0r = float(stage_latency["stage0"][r])
            root = Span("query")
            root.child("stage0", 0.0, t0r, pred_k=float(pk[r]),
                       pred_rho=float(pr[r]), pred_t=float(pt[r]))
            mirror = "jass" if is_jass[r] else "bmw"
            if is_hedge[r]:
                mirror += "+hedge"
            rmeta = dict(mirror=mirror, rho=float(routed.rho[r]),
                         k=int(routed.k[r]))
            if modality is not None:
                rmeta["modality"] = mod_name[int(modality[r])]
            root.child("route", t0r, 0.0, **rmeta)
            s1 = root.child("stage1", t0r,
                            float(stage_latency["stage1"][r]))
            for s in range(self.n_shards):
                smeta: dict = {"shard": s}
                dur = float(t_shards[s, r])
                if faulted:
                    d = float(delay[s, r])
                    if d > 0:
                        smeta["retry_wait_us"] = d
                        smeta["attempts_failed"] = (
                            int(round(d / timeout)) if timeout else 0)
                    if lost[s, r]:
                        smeta["lost"] = True
                    if dropped[s, r]:
                        smeta["dropped"] = True
                    if mult[s, r] != 1.0:
                        smeta["slowdown"] = float(mult[s, r])
                    dur = (0.0 if dropped[s, r] else
                           d + (0.0 if lost[s, r]
                                else float(t_shards[s, r] * mult[s, r])))
                s1.child("shard", t0r, dur, **smeta)
            if modality is not None and int(modality[r]) == M_BOTH:
                s1.child("fusion", 0.0, float(self.cost.fusion_us))
            if fallback is not None and fallback[r]:
                s1.child("dense_fallback", 0.0, 0.0)
            if self.delta is not None:
                s1.child("delta_scan", 0.0, float(self._delta_us))
            s2dur = float(stage_latency["stage2"][r])
            s2meta: dict = {}
            if used is not None:
                s2meta["candidates"] = int(used[r])
                if used[r] == 0:
                    s2meta["skipped"] = True
            if theta_skip is not None and theta_skip[r]:
                s2meta["theta_skip"] = True
            root.child("stage2", float(lat[r]) - s2dur, s2dur, **s2meta)
            meta = {
                "wait_us": w,
                "service_us": float(lat[r]),
                "reserve_us": float(
                    self._budget_reserve.get("stage2", 0.0)),
            }
            if modes is not None:
                meta["mode"] = str(modes[r])
            if self._tel_cache_tag is not None:
                meta["cache"] = self._tel_cache_tag
            if faulted:
                meta["coverage"] = float(coverage[r])
            tel.traces.offer(QueryTrace(
                qid=int(qids[r]), clock_us=now, latency_us=total,
                budget_us=budget, violation=violation, root=root,
                meta=meta))

    def _record_hit_traces(self, l1_hit, l2_hit, lat, t0, t2, hit_us,
                           now) -> None:
        """Traces for cache-hit rows (miss rows were traced by the
        sub-serve with a ``cache: miss`` tag)."""
        tel = self.telemetry
        if tel.traces.capacity == 0:
            return
        q = len(lat)
        wait, modes, qids, budget = self._tel_context(q)
        for r in np.flatnonzero(l1_hit | l2_hit):
            level = "l1" if l1_hit[r] else "l2"
            w = float(wait[r]) if wait is not None else 0.0
            total = float(lat[r]) + w
            violation = total > budget
            if not tel.traces.would_keep(total, violation):
                continue
            root = Span("query")
            root.child("stage0", 0.0, float(t0[r]))
            root.child("cache_lookup", float(t0[r]), float(hit_us),
                       level=level, hit=True)
            if t2[r] > 0:
                root.child("stage2", float(lat[r]) - float(t2[r]),
                           float(t2[r]))
            meta = {"wait_us": w, "service_us": float(lat[r]),
                    "cache": level,
                    "reserve_us": float(
                        self._budget_reserve.get("stage2", 0.0))}
            if modes is not None:
                meta["mode"] = str(modes[r])
            tel.traces.offer(QueryTrace(
                qid=int(qids[r]), clock_us=now, latency_us=total,
                budget_us=budget, violation=violation, root=root,
                meta=meta))

    def _export_metrics(self) -> None:
        """Mirror every cumulative stats dict and subsystem counter into
        the registry (``key=`` labels preserve the legacy key names so
        ``legacy_stats_view`` can reconstruct the old sections)."""
        reg = self.telemetry.registry
        for k, v in self.sched.stats.items():
            reg.counter("scheduler", key=k).set_total(v)
        for k, v in self._fault_counters.items():
            reg.counter("faults", key=k).set_total(v)
        reg.gauge("faults", key="clock").set(self._clock)
        for k, v in self._ingest_counters.items():
            reg.counter("ingest", key=k).set_total(v)
        reg.gauge("n_shards").set(self.n_shards)
        reg.gauge("batches").set(self._batches)
        reg.gauge("budget_us").set(self.budget)
        reg.gauge("worst_case_us").set(self.worst_case_us())
        reg.gauge("clock_us").set(self._clock)
        self.pool.export_metrics(reg)
        self.faults.export_metrics(reg)
        if self.cache is not None:
            self.cache.export_metrics(reg)
        if self.delta is not None:
            self.delta.export_metrics(reg)
            reg.gauge("ingest", key="delta_us").set(self._delta_us)
        self.telemetry.export_online()

    def snapshot(self, now: float | None = None) -> dict:
        """One scrapeable observability snapshot: every counter, gauge and
        histogram in the registry plus the retained slowest/violating
        traces with their ``why_slow`` attribution.  Deterministic — two
        same-seed runs render byte-identical JSON.  Requires an enabled
        :class:`~repro.serving.spec.TelemetrySpec`."""
        if self.telemetry is None:
            raise RuntimeError(
                "telemetry is disabled (spec.telemetry.enabled=False); "
                "enable it to export snapshots")
        self._export_metrics()
        snap = self.telemetry.registry.snapshot()
        snap["version"] = 1
        snap["spec"] = self.cascade_spec.name
        snap["clock_us"] = float(self._clock if now is None else now)
        snap["budget_us"] = float(self.budget)
        snap["worst_case_us"] = float(self.worst_case_us())
        snap["traces"] = [t.to_dict()
                          for t in self.telemetry.traces.slowest()]
        return snap

    def render_snapshot(self, fmt: str = "json",
                        now: float | None = None) -> str:
        """Render :meth:`snapshot` as ``json`` (byte-deterministic) or
        ``prom`` (Prometheus text exposition; traces are JSON-only)."""
        snap = self.snapshot(now=now)
        if fmt == "json":
            return render_json(snap)
        if fmt == "prom":
            return render_prometheus(snap)
        raise ValueError(f"unknown snapshot format {fmt!r}")

    def serve_online(self, terms: np.ndarray, mask: np.ndarray,
                     topics: np.ndarray | None = None, *,
                     traffic, online=None):
        """Serve the query log under load: event-driven arrivals
        (:class:`~repro.serving.spec.TrafficSpec`), dynamic micro-batching,
        and admission control, reporting end-to-end **response-time**
        percentiles (queueing included) up to p99.99.

        ``online`` overrides the spec's :class:`~repro.serving.spec.
        OnlineSpec`.  Returns an :class:`~repro.serving.online.simulator.
        OnlineResult`."""
        from repro.serving.online import simulate
        return simulate(self, terms, mask, topics, traffic, online)

    def worst_case_us(self) -> float:
        """The hard analytic bound on any served query's cascade latency:
        the scheduler's Stage-1 bound (which already pays ``predict_us``)
        plus the reserved worst-case Stage-2 cost.  With ``enforce_budget``
        and ``late_rho <= SchedulerConfig.max_late_rho(cost, n_shards)``
        this is at most the cascade budget — the paper's 99.99 % as a hard
        guarantee (certified on a trace by ``benchmarks/bench_tail.py``).
        The bound is scatter-gather aware: the late re-issue pays the
        per-extra-shard gather overhead, so ``max_late_rho`` shrinks as
        shards are added.  With a serving cache attached, every query
        additionally pays the lookup (``cache_hit_us``) — charging it here
        keeps the guarantee analytic with caching on (a hit costs strictly
        less than the bound; a miss costs the cascade plus the lookup).

        With the dense modality enabled the bound is the max over the
        three routes, all analytic from spec shapes alone:

        * **lexical** — the scheduler bound, unchanged (the stage-1 share
          it enforces already had ``fusion_us`` carved out);
        * **dense only** — ``predict + dense_time(max_tiles) + gather +
          retry``, plus the ρ_late-capped fallback traversal when
          ``theta_low`` is armed (the dense per-shard cost is shape-static,
          so this term needs no df tables);
        * **both + fused** — the engines run in parallel (max of the two
          stage-1 terms) plus the reserved ``fusion_us``; since the
          scheduler enforces the reduced share, this collapses back to at
          most the original stage-1 reserve.
        """
        cfg = self.sched.cfg
        base = cfg.worst_case_us(self.cost, self.n_shards)
        if self.dense is not None:
            ds = self.cascade_spec.dense
            pd = self.cost.predict_us
            gather = self.cost.gather_per_shard_us * (self.n_shards - 1)
            td = (float(self.cost.dense_time(self.dense.max_tiles()))
                  + gather + cfg.retry_us())
            fb = (float(self.cost.saat_time(
                      np.float64(cfg.resolved_late_rho()))) + gather
                  if np.isfinite(ds.theta_low) else 0.0)
            dense_bound = pd + td + fb
            both_bound = pd + max(base - pd, td) + self.cost.fusion_us
            base = max(base, dense_bound, both_bound)
        # live ingest: every query additionally scans the capacity-padded
        # delta segment (lexical + dense tiles) — the same static term the
        # serve path charges, so the bound stays analytic while feeding
        return (base + self._delta_us + self._budget_reserve["stage2"]
                + (self.cost.cache_hit_us if self.cache is not None
                   else 0.0))

    # ------------------------------------------------------------------
    # live ingest: feed → delta segment → background merge
    # ------------------------------------------------------------------

    def _refresh_dense_delta(self) -> None:
        """Re-embed the delta docs through the sealed quantized source and
        hand the capacity-padded matrix to the dense engine (ghost rows
        stay zero; the engine masks them after ranking)."""
        if self.dense is None or self.delta is None:
            return
        d = self.delta
        emb = np.zeros((d.capacity_docs, self.dense.d), np.float32)
        if d.n_docs:
            emb[:d.n_docs] = delta_doc_embeddings(
                self.cascade_spec.dense, n_sealed=d.base_docs,
                n_new=d.n_docs,
                vocab=int(np.asarray(self.index.df).shape[0]),
                topics=d.doc_topics, corpus=self.corpus)
        self.dense.set_delta(emb, d.n_docs, d.base_docs)

    def add_documents(self, feed: FeedDocs) -> int:
        """Ingest the longest admissible prefix of ``feed`` into the live
        delta segment; returns the number of docs accepted (0 = the delta
        is full — call :meth:`merge` to reseal, then re-offer the rest).
        Served results include the new docs immediately; the cache epoch
        bumps so no stale entry survives the collection change."""
        if self.delta is None:
            raise RuntimeError("live ingest is disabled "
                               "(spec.ingest.enabled=False)")
        took = self.delta.add(feed)
        if took:
            self._ingest_counters["epoch"] += 1
            self._ingest_counters["feed_batches"] += 1
            self._ingest_counters["docs_ingested"] += took
            self._refresh_dense_delta()
        return took

    def merge(self) -> int:
        """Fold the delta into the sealed collection (the background
        merge): rebuilds the index bit-identically to a from-scratch build
        over the extended corpus, re-attaches every index-derived serving
        structure, and resets the delta against the new seal.  Returns the
        number of docs merged (0 = nothing to do)."""
        if self.delta is None:
            raise RuntimeError("live ingest is disabled "
                               "(spec.ingest.enabled=False)")
        n = self.delta.n_docs
        if n == 0:
            return 0
        if self.corpus is None:
            raise RuntimeError("merge needs the corpus the sealed index "
                               "was built from")
        new_corpus, new_index = self.delta.merged(self.corpus)
        self.corpus = new_corpus
        self._attach_index(new_index)
        self.delta.reset(new_index)
        if self.dense is not None:
            self.dense.clear_delta()
        if self.ltr is not None:
            # Stage-2 ranks against the resealed collection's CSR arrays
            self.s2 = stage2_arrays(self.index, self.corpus)
            self.n_iter = csr_search_iters(int(self.index.df.max()))
        self._ingest_counters["epoch"] += 1
        self._ingest_counters["merges"] += 1
        self._ingest_counters["docs_merged"] += n
        return n

    def _adapt_routing(self):
        """Close the routing feedback loop from pool EWMAs + scheduler
        counters (``RoutingSpec.adapt_every``).

        * ``t_time`` tracks the observed mirror balance: when the BMW
          mirror's EWMA latency rises relative to JASS, the threshold drops
          and Algorithm 2 routes more traffic to the bounded mirror.
        * ``hedge_band`` widens after a window that needed late hedges
          (hedge earlier next time) and decays slowly through clean
          windows, so duplicated JASS work shrinks when the tail is quiet.
        * ``hedge_deadline`` follows the t-predictor's online quantile
          error (rolling pinball-loss EWMA): unreliable predictions →
          detect stragglers earlier; trustworthy ones → later detection,
          less duplicated JASS work.  The deadline never exceeds the
          feasibility ceiling ``(B₁ - ρ_late·c_s - gather) / B₁``, so the
          worst-case bound keeps collapsing to the budget — adaptation can
          only spend hedge work, never the guarantee.  With
          ``adapt_every=0`` the spec's fixed value is used unchanged.

        The adapted values are folded back into ``cascade_spec`` so
        ``to_json()`` names the *live* operating point.
        """
        cfg = self.sched.cfg
        changed: dict = {}
        ewma = self.pool.mirror_ewma()
        e_j, e_b = ewma[JASS], ewma[BMW]
        if e_j is not None and e_b is not None and e_j + e_b > 0:
            alpha, b1 = 0.2, cfg.budget
            target = b1 * float(np.clip(e_j / (e_j + e_b), 0.1, 0.9))
            changed["t_time"] = float(np.clip(
                (1 - alpha) * cfg.t_time + alpha * target,
                0.05 * b1, 0.95 * b1))
        d_late = self.sched.stats["late_hedged"] \
            - self._adapt_last["late_hedged"]
        d_bmw = self.sched.stats["bmw"] - self._adapt_last["bmw"]
        self._adapt_last = {"late_hedged": self.sched.stats["late_hedged"],
                            "bmw": self.sched.stats["bmw"]}
        if d_bmw > 0:
            band = cfg.hedge_band * (1.25 if d_late > 0 else 0.98)
            changed["hedge_band"] = float(np.clip(band, 0.05, 0.5))
        if self._pinball_ewma is not None:
            late = float(self.cost.saat_time(
                np.float64(cfg.resolved_late_rho())))
            gather = self.cost.gather_per_shard_us * (self.n_shards - 1)
            d_max = (cfg.budget - late - gather) / cfg.budget
            if d_max > 0.05:
                # relative quantile error of the t predictor; 2x scaling
                # so a pinball loss of half the budget already pins the
                # deadline at its floor
                err = self._pinball_ewma / cfg.budget
                d_target = float(np.clip(
                    d_max * (1.0 - min(2.0 * err, 0.8)), 0.05, d_max))
                changed["hedge_deadline"] = float(np.clip(
                    0.8 * cfg.hedge_deadline + 0.2 * d_target,
                    0.05, min(d_max, 1.0)))
        if changed:
            self.sched.cfg = replace(cfg, **changed)
            self._base_cfg = replace(self._base_cfg, **changed)
            self.cascade_spec = replace(
                self.cascade_spec,
                routing=replace(self.cascade_spec.routing, **changed))

    def stats(self) -> dict:
        """Deployment-level health: spec identity, shard layout, scheduler
        counters, replica-pool health, and the last batch's tail.

        With telemetry enabled the scalar counter sections (scheduler /
        faults / ingest) are *derived from the registry snapshot* — the
        registry is the one source of truth and this dict is a thin
        compat view over it; with telemetry disabled the legacy dicts are
        reported directly (identical values either way)."""
        tel = self.telemetry
        if tel is not None:
            self._export_metrics()
            snap = tel.registry.snapshot()
            scheduler = legacy_stats_view(snap, "scheduler")
            fault_ctr = legacy_stats_view(snap, "faults")
            ingest = legacy_stats_view(snap, "ingest")
        else:
            scheduler = dict(self.sched.stats)
            fault_ctr = dict(self._fault_counters)
            fault_ctr["clock"] = self._clock
            ingest = None
        s = {
            "spec": self.cascade_spec.name,
            "n_shards": self.n_shards,
            "shard_docs": [sp.n_docs for sp in self.shard_specs],
            "replicas": self.cascade_spec.deploy.replicas,
            "batches": self._batches,
            "scheduler": scheduler,
            "budget": {"total": self.budget,
                       "reserve": dict(self._budget_reserve),
                       "enforce": self.sched.cfg.enforce_budget,
                       "worst_case_bound": self.worst_case_us()},
            "pool": self.pool.stats(),
        }
        if self.faults.active or any(self._fault_counters.values()):
            s["faults"] = fault_ctr
        if self.delta is not None:
            if ingest is None:
                ingest = dict(self.delta.stats())
                ingest.update(self._ingest_counters)
                ingest["delta_us"] = self._delta_us
            s["ingest"] = ingest
        if self._last_stats:
            s["last_batch"] = {k: self._last_stats[k]
                               for k in ("p50", "p99", "p99.99", "max",
                                         "over_budget", "over_budget_pct")
                               if k in self._last_stats}
        return s
