"""End-to-end first-stage serving loop (single-host demonstration of the
production layout): Stage-0 features+predictions → scheduler routing →
JASS/BMW engine execution → hierarchical top-k merge → latency accounting.

The engines are the batched serving pipelines over a real IndexShard
(backend-dispatched: compiled Pallas kernels on TPU, fused-jnp elsewhere —
see ``repro.isn.backend``); on a mesh the same loop runs with
`repro.isn.shard.hybrid_serve_fn`.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core import features as F
from repro.core import gbrt
from repro.index.builder import InvertedIndex
from repro.index.postings import shard_from_index
from repro.isn.backend import query_lane_budget
from repro.isn.daat import daat_serve
from repro.isn.saat import saat_serve
from repro.serving.latency import CostModel, over_budget, percentiles
from repro.serving.scheduler import SchedulerConfig, StageZeroScheduler


@dataclass
class ServeResult:
    topk: np.ndarray
    latency: np.ndarray
    stats: dict


class HybridServer:
    """One ISN worth of the paper's hybrid system, servable end to end."""

    def __init__(self, index: InvertedIndex, models: dict,
                 cfg: SchedulerConfig, k_serve: int = 128,
                 cost: CostModel | None = None):
        self.index = index
        self.shard, self.spec = shard_from_index(index)
        self.models = models          # {"k": GBRTModel, "rho": ..., "t": ...}
        self.cost = cost or CostModel.paper_scale()
        self.sched = StageZeroScheduler(cfg, self.cost)
        self.k_serve = k_serve
        self.term_stats = jnp.asarray(index.term_stats)
        self.df = jnp.asarray(index.df)

    def stage0(self, terms: np.ndarray, mask: np.ndarray):
        x = np.asarray(F.extract(self.term_stats, self.df,
                                 jnp.asarray(terms), jnp.asarray(mask)))
        pk = np.expm1(np.asarray(gbrt.predict(self.models["k"], x)))
        pr = np.expm1(np.asarray(gbrt.predict(self.models["rho"], x)))
        pt = np.expm1(np.asarray(gbrt.predict(self.models["t"], x)))
        return pk, pr, pt

    def serve(self, terms: np.ndarray, mask: np.ndarray) -> ServeResult:
        q = terms.shape[0]
        pk, pr, pt = self.stage0(terms, mask)
        routed = self.sched.route(pk, pr, pt)
        topk = np.zeros((q, self.k_serve), np.int64)
        work_j = np.zeros(q)
        t_bmw = np.zeros(q)

        if len(routed.jass_rows):
            rows = routed.jass_rows
            res = saat_serve(self.shard, jnp.asarray(terms[rows]),
                             jnp.asarray(mask[rows]),
                             jnp.asarray(routed.rho[rows]),
                             n_docs=self.spec.n_docs, k=self.k_serve,
                             cap=int(self.sched.cfg.rho_max))
            topk[rows] = np.asarray(res.topk_docs)
            work_j[rows] = np.asarray(res.work)
        if len(routed.bmw_rows):
            rows = routed.bmw_rows
            qcap = query_lane_budget(self.index.df, terms[rows], mask[rows])
            res = daat_serve(self.shard, jnp.asarray(terms[rows]),
                             jnp.asarray(mask[rows]),
                             jnp.ones(len(rows), jnp.float32),
                             n_docs=self.spec.n_docs,
                             n_blocks=self.spec.n_blocks,
                             block_size=self.spec.block_size, k=self.k_serve,
                             cap=self.spec.max_df,
                             bcap=self.spec.max_blocks_per_term, qcap=qcap)
            topk[rows] = np.asarray(res.topk_docs)
            t_bmw[rows] = self.cost.daat_time(np.asarray(res.work),
                                              np.asarray(res.blocks))

        def jass_time(rows, rho):
            # deterministic: budget resolves to level cut; time from work —
            # one vectorized reduction over the routed rows
            lc = self.index.level_cum[terms[rows]]
            lc = lc * (mask[rows] > 0)[:, :, None]
            total = lc.sum(axis=1)                       # (R, n_levels)
            ok = total <= np.asarray(rho).reshape(-1, 1)
            lstar = np.argmax(ok, axis=1)
            w = np.where(ok.any(axis=1),
                         np.take_along_axis(total, lstar[:, None],
                                            axis=1)[:, 0], 0)
            return self.cost.saat_time(w.astype(np.float64))

        lat = self.sched.resolve_times(routed, t_bmw, jass_time)
        stats = dict(self.sched.stats)
        stats.update(percentiles(lat))
        n_over, pct = over_budget(lat, self.sched.cfg.budget)
        stats["over_budget"] = n_over
        stats["over_budget_pct"] = pct
        return ServeResult(topk=topk, latency=lat, stats=stats)
