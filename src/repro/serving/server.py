"""End-to-end serving loop (single-host demonstration of the production
layout), now a thin compatibility wrapper over the unified cascade
pipeline (``repro.serving.pipeline``).

Architecture: one query batch flows Stage-0 → routing → Stage-1 → Stage-2
as a sequence of batched array programs —

* Stage-0 features + the three GBRT predictors run as ONE fused device
  call (stacked forests, ``gbrt.predict_stacked``);
* the scheduler routes the batch (Algorithms 1/2 + hedging) with pure
  array ops;
* the routed sub-batches dispatch through the batched ``daat_serve`` /
  ``saat_serve`` engines over a real IndexShard (backend-dispatched:
  compiled Pallas kernels on TPU, fused-jnp elsewhere — see
  ``repro.isn.backend``); on a mesh the same loop runs with
  ``repro.isn.shard.hybrid_serve_fn``;
* optionally, Stage-2 re-ranks the candidate grid in one batched LTR pass
  (``repro.ltr.cascade.rerank_batched``).

``HybridServer`` keeps the historical Stage-1-only interface (the tests'
budget-guarantee suite drives it); new code should use
``repro.serving.pipeline.CascadePipeline`` directly, which also threads
per-stage latency accounting through the result so the reported tail is
the *cascade* tail.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.index.builder import InvertedIndex
from repro.serving.latency import CostModel
from repro.serving.pipeline import CascadePipeline
from repro.serving.scheduler import SchedulerConfig


@dataclass
class ServeResult:
    topk: np.ndarray
    latency: np.ndarray
    stats: dict


class HybridServer:
    """One ISN worth of the paper's hybrid system, servable end to end.

    Thin wrapper over ``CascadePipeline`` without a Stage-2 model: serves
    the first stage and reports Stage-0 + Stage-1 latency, exactly as
    before the pipeline refactor.
    """

    def __init__(self, index: InvertedIndex, models: dict,
                 cfg: SchedulerConfig, k_serve: int = 128,
                 cost: CostModel | None = None):
        self.pipeline = CascadePipeline(index, models, cfg, k_serve=k_serve,
                                        cost=cost)
        # historical attribute surface
        self.index = index
        self.shard = self.pipeline.shard
        self.spec = self.pipeline.spec
        self.models = models
        self.cost = self.pipeline.cost
        self.sched = self.pipeline.sched
        self.k_serve = k_serve

    def stage0(self, terms: np.ndarray, mask: np.ndarray):
        return self.pipeline.stage0(terms, mask)

    def serve(self, terms: np.ndarray, mask: np.ndarray) -> ServeResult:
        res = self.pipeline.serve(terms, mask)
        return ServeResult(topk=res.topk, latency=res.latency,
                           stats=res.stats)
