"""Compatibility shim: the historical Stage-1-only ``HybridServer``
interface on top of the spec-built serving stack.

``HybridServer(index, models, cfg)`` assembles a single-shard,
Stage-1-only ``CascadeSpec`` internally (via the ``CascadePipeline`` shim)
and delegates serving to ``repro.serving.system.SearchSystem`` — the same
``stage1_only`` operating point the preset registry names.  Results are
bit-identical to the pre-spec server: the tests' budget-guarantee suite
still drives this class.

New code should build a spec (or pick a preset from
``repro.configs.cascade_presets``) and use
``repro.serving.system.build_system`` directly, which adds multi-shard
scatter-gather Stage-1, replica-pool load balancing, and Stage-2.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.index.builder import InvertedIndex
from repro.serving.latency import CostModel
from repro.serving.pipeline import CascadePipeline
from repro.serving.scheduler import SchedulerConfig


@dataclass
class ServeResult:
    topk: np.ndarray
    latency: np.ndarray
    stats: dict


class HybridServer:
    """One ISN worth of the paper's hybrid system, servable end to end.

    Thin wrapper over the spec-built stack without a Stage-2 model (a
    ``stage1_only`` operating point): serves the first stage and reports
    Stage-0 + Stage-1 latency, exactly as before the spec refactor.
    """

    def __init__(self, index: InvertedIndex, models: dict,
                 cfg: SchedulerConfig, k_serve: int = 128,
                 cost: CostModel | None = None):
        self.pipeline = CascadePipeline(index, models, cfg, k_serve=k_serve,
                                        cost=cost)
        # historical attribute surface
        self.index = index
        self.shard = self.pipeline.shard
        self.spec = self.pipeline.spec
        self.models = models
        self.cost = self.pipeline.cost
        self.sched = self.pipeline.sched
        self.k_serve = k_serve

    def stage0(self, terms: np.ndarray, mask: np.ndarray):
        return self.pipeline.stage0(terms, mask)

    def serve(self, terms: np.ndarray, mask: np.ndarray) -> ServeResult:
        res = self.pipeline.serve(terms, mask)
        return ServeResult(topk=res.topk, latency=res.latency,
                           stats=res.stats)
