"""Deterministic observability for the serving cascade.

The :class:`Telemetry` facade owns one :class:`MetricsRegistry`, one
:class:`TraceStore`, and the ring of periodic online snapshots.  It is
allocated by ``SearchSystem`` only when ``TelemetrySpec.enabled`` — a
disabled spec is provably inert: no registry exists and every hook in
the serving path is guarded on ``system.telemetry is None``.
"""

from __future__ import annotations

import numpy as np

from .metrics import Counter, Gauge, LogHistogram, MetricsRegistry
from .trace import QueryTrace, Span, TraceStore, why_slow

__all__ = ["Telemetry", "MetricsRegistry", "Counter", "Gauge",
           "LogHistogram", "QueryTrace", "Span", "TraceStore", "why_slow"]


class Telemetry:
    """Registry + trace store + snapshot cadence for one SearchSystem."""

    def __init__(self, spec, budget_us: float) -> None:
        self.spec = spec
        self.budget_us = float(budget_us)
        self.registry = MetricsRegistry(
            bins_per_decade=spec.bins_per_decade, exact_n=spec.exact_n,
            hist_lo=spec.hist_lo, hist_hi=spec.hist_hi)
        self.traces = TraceStore(spec.trace_reservoir)
        self.snapshots: list[dict] = []
        # the online simulator sets this around system.serve() with
        # per-padded-row queue waits and admission modes so traces can
        # attribute response time, then clears it
        self.batch_context: dict | None = None
        self.query_seq = 0   # offline qid assignment (no simulator ids)
        self._adm = None
        self._batcher = None
        self._next_snapshot_us = (float(spec.snapshot_every_us)
                                  if spec.snapshot_every_us > 0
                                  else float("inf"))

    # -- online wiring --------------------------------------------------
    def attach_online(self, adm, batcher) -> None:
        """Keep refs to the admission controller / micro-batcher so the
        next snapshot can export their counters and policy gauges."""
        self._adm = adm
        self._batcher = batcher

    def export_online(self) -> None:
        if self._adm is not None:
            self._adm.export_metrics(self.registry)
        if self._batcher is not None:
            self._batcher.export_metrics(self.registry)

    # -- batch-level recording ------------------------------------------
    def record_batch(self, lat, stage_latency: dict, budget_us: float,
                     trimmed: int = 0, skipped: int = 0) -> None:
        """Fold one served batch into the registry: per-query service
        latency, per-stage latency histograms, violation and stage2
        degradation counters."""
        reg = self.registry
        lat = np.asarray(lat, dtype=np.float64)
        reg.counter("queries_served").inc(lat.size)
        reg.counter("batches_served").inc()
        reg.histogram("service_latency_us").observe(lat)
        n_over = int((lat > budget_us).sum())
        if n_over:
            reg.counter("budget_violations").inc(n_over)
        for name, t in stage_latency.items():
            t = np.asarray(t, dtype=np.float64)
            live = t[t > 0]
            if live.size:
                reg.histogram("stage_latency_us", stage=name).observe(live)
        if trimmed:
            reg.counter("stage2_trimmed").inc(trimmed)
        if skipped:
            reg.counter("stage2_skipped").inc(skipped)

    # -- periodic snapshots ---------------------------------------------
    def maybe_snapshot(self, system, now: float) -> bool:
        """Take a periodic snapshot if the virtual clock crossed the
        cadence boundary; bounded by ``spec.max_snapshots``."""
        if now < self._next_snapshot_us:
            return False
        if len(self.snapshots) >= self.spec.max_snapshots:
            self._next_snapshot_us = float("inf")
            return False
        self.snapshots.append(system.snapshot(now=now))
        every = float(self.spec.snapshot_every_us)
        # advance past `now` in whole cadence steps (deterministic)
        while self._next_snapshot_us <= now:
            self._next_snapshot_us += every
        return True
