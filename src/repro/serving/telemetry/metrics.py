"""Deterministic metrics core: counters, gauges, log-bucketed histograms.

Everything here is driven by values the serving path already computes on
the virtual clock — no wall time, no RNG — so a replay with the same
seeds produces a byte-identical snapshot.

The histogram is log-bucketed: bucket edges grow geometrically with
ratio ``gamma = 10 ** (1 / bins_per_decade)``.  A quantile answered from
the buckets uses the geometric midpoint of the covering bucket, clamped
to the observed [min, max], which bounds the relative error by
``sqrt(gamma) - 1`` for any value inside [lo, hi] (~1.8% at the default
64 bins/decade).  While the stream holds at most ``exact_n`` values the
histogram keeps them verbatim and answers quantiles *exactly*, matching
``np.quantile(..., method="inverted_cdf")``.
"""

from __future__ import annotations

import math
from bisect import insort

import numpy as np

__all__ = ["Counter", "Gauge", "LogHistogram", "MetricsRegistry"]


class Counter:
    """Monotone cumulative count."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError("counter increments must be >= 0")
        self.value += float(n)

    def set_total(self, v: float) -> None:
        """Mirror an externally maintained cumulative total (e.g. a legacy
        stats dict).  Must never move backwards."""
        v = float(v)
        if v < self.value - 1e-9:
            raise ValueError(
                f"counter total moved backwards: {self.value} -> {v}")
        self.value = v


class Gauge:
    """Point-in-time value (queue depth, fill fraction, EWMA...)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class LogHistogram:
    """Streaming histogram with geometric buckets and exact small-N path.

    Parameters
    ----------
    bins_per_decade:
        Buckets per factor-of-10; relative error of bucketed quantiles
        is ``sqrt(10 ** (1/bins_per_decade)) - 1``.
    exact_n:
        Keep up to this many raw values; while within, quantiles are
        exact.  The buffer is flushed into buckets on overflow.
    lo, hi:
        Bucketed range.  Values below ``lo`` (including zero) land in an
        underflow bucket whose representative is ``lo/2`` (absolute
        error <= lo); values above ``hi`` land in an overflow bucket
        represented by the tracked maximum.
    """

    def __init__(self, bins_per_decade: int = 64, exact_n: int = 256,
                 lo: float = 1e-3, hi: float = 1e7) -> None:
        if bins_per_decade <= 0:
            raise ValueError("bins_per_decade must be > 0")
        if exact_n < 0:
            raise ValueError("exact_n must be >= 0")
        if not (0 < lo < hi):
            raise ValueError("need 0 < lo < hi")
        self.bins_per_decade = int(bins_per_decade)
        self.exact_n = int(exact_n)
        self.lo = float(lo)
        self.hi = float(hi)
        self._scale = bins_per_decade / math.log(10.0)
        self._n_buckets = (
            int(math.ceil(math.log(hi / lo) * self._scale)) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._exact: list[float] | None = []  # sorted; None once flushed
        self._under = 0
        self._over = 0
        self._buckets: dict[int, int] = {}

    # -- error bound ----------------------------------------------------
    @property
    def rel_err_bound(self) -> float:
        """Guaranteed relative error of bucketed quantiles for values in
        [lo, hi]: half a bucket in log space."""
        gamma = 10.0 ** (1.0 / self.bins_per_decade)
        return math.sqrt(gamma) - 1.0

    @property
    def exact(self) -> bool:
        return self._exact is not None

    # -- ingest ---------------------------------------------------------
    def _bucket_index(self, x: float) -> int:
        # floor with an epsilon so exact edges land in the lower bucket's
        # successor deterministically across platforms
        return int(math.floor(math.log(x / self.lo) * self._scale + 1e-9))

    def _bucket_add(self, x: float) -> None:
        if x < self.lo:
            self._under += 1
        elif x > self.hi:
            self._over += 1
        else:
            i = min(self._bucket_index(x), self._n_buckets - 1)
            self._buckets[i] = self._buckets.get(i, 0) + 1

    def observe(self, values) -> None:
        arr = np.atleast_1d(np.asarray(values, dtype=np.float64)).ravel()
        if arr.size == 0:
            return
        if np.any(arr < 0):
            raise ValueError("histogram values must be >= 0")
        self.count += int(arr.size)
        self.sum += float(arr.sum())
        self.min = min(self.min, float(arr.min()))
        self.max = max(self.max, float(arr.max()))
        if self._exact is not None:
            if self.count <= self.exact_n:
                for x in arr.tolist():
                    insort(self._exact, float(x))
                return
            # flush the exact buffer into buckets, then continue bucketed
            for x in self._exact:
                self._bucket_add(x)
            self._exact = None
        for x in arr.tolist():
            self._bucket_add(float(x))

    # -- query ----------------------------------------------------------
    def quantile(self, q: float) -> float:
        """Inverted-CDF quantile: the smallest observed value whose
        cumulative count reaches ``ceil(q * N)``."""
        if not (0.0 <= q <= 1.0):
            raise ValueError("q must be in [0, 1]")
        if self.count == 0:
            return float("nan")
        rank = max(1, int(math.ceil(q * self.count)))
        if self._exact is not None:
            return float(self._exact[rank - 1])
        c = self._under
        if rank <= c:
            return min(self.lo / 2.0, self.max)
        for i in sorted(self._buckets):
            c += self._buckets[i]
            if rank <= c:
                edge_lo = self.lo * 10.0 ** (i / self.bins_per_decade)
                edge_hi = edge_lo * 10.0 ** (1.0 / self.bins_per_decade)
                rep = math.sqrt(edge_lo * edge_hi)
                return float(min(max(rep, self.min), self.max))
        return float(self.max)  # overflow bucket

    def snapshot(self) -> dict:
        if self.count == 0:
            return {"count": 0, "sum": 0.0}
        return {
            "count": int(self.count),
            "sum": float(self.sum),
            "min": float(self.min),
            "max": float(self.max),
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
            "p99.99": self.quantile(0.9999),
            "exact": bool(self.exact),
            "rel_err_bound": 0.0 if self.exact else self.rel_err_bound,
        }


def _key(name: str, labels: dict) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Flat registry keyed ``name{label="v",...}`` (labels sorted)."""

    def __init__(self, bins_per_decade: int = 64, exact_n: int = 256,
                 hist_lo: float = 1e-3, hist_hi: float = 1e7) -> None:
        self._hist_args = dict(bins_per_decade=bins_per_decade,
                               exact_n=exact_n, lo=hist_lo, hi=hist_hi)
        self.counters: dict[str, Counter] = {}
        self.gauges: dict[str, Gauge] = {}
        self.histograms: dict[str, LogHistogram] = {}

    def counter(self, name: str, **labels) -> Counter:
        k = _key(name, labels)
        c = self.counters.get(k)
        if c is None:
            c = self.counters[k] = Counter()
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        k = _key(name, labels)
        g = self.gauges.get(k)
        if g is None:
            g = self.gauges[k] = Gauge()
        return g

    def histogram(self, name: str, **labels) -> LogHistogram:
        k = _key(name, labels)
        h = self.histograms.get(k)
        if h is None:
            h = self.histograms[k] = LogHistogram(**self._hist_args)
        return h

    def snapshot(self) -> dict:
        return {
            "counters": {k: float(c.value)
                         for k, c in sorted(self.counters.items())},
            "gauges": {k: float(g.value)
                       for k, g in sorted(self.gauges.items())},
            "histograms": {k: h.snapshot()
                           for k, h in sorted(self.histograms.items())},
        }
