"""Snapshot renderers: Prometheus text format and deterministic JSON.

``render_json`` is the canonical byte-deterministic export (sorted keys,
fixed indentation, trailing newline) — two same-seed runs produce
identical bytes.  ``render_prometheus`` emits the same snapshot in the
text exposition format so any Prometheus-compatible scraper can ingest
it; histograms become summary-style quantile series.
"""

from __future__ import annotations

import json
import re

__all__ = ["render_json", "render_prometheus", "legacy_stats_view"]

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")
_KEYED = re.compile(r'^([a-zA-Z0-9_:.]+)\{(.*)\}$')


def render_json(snap: dict) -> str:
    return json.dumps(snap, indent=2, sort_keys=True, default=float) + "\n"


def _split(key: str) -> tuple[str, str]:
    """Split a registry key into (metric name, label string)."""
    m = _KEYED.match(key)
    if m:
        return m.group(1), m.group(2)
    return key, ""


def _prom_name(name: str) -> str:
    return "repro_" + _NAME_RE.sub("_", name)


def _series(name: str, labels: str, extra: str = "") -> str:
    inner = ",".join(x for x in (labels, extra) if x)
    return f"{name}{{{inner}}}" if inner else name


def render_prometheus(snap: dict) -> str:
    """Prometheus text exposition of a registry snapshot dict."""
    lines: list[str] = []
    seen: set[str] = set()

    def head(pname: str, kind: str) -> None:
        if pname not in seen:
            seen.add(pname)
            lines.append(f"# TYPE {pname} {kind}")

    for key, v in snap.get("counters", {}).items():
        name, labels = _split(key)
        pname = _prom_name(name) + "_total"
        head(pname, "counter")
        lines.append(f"{_series(pname, labels)} {v:g}")
    for key, v in snap.get("gauges", {}).items():
        name, labels = _split(key)
        pname = _prom_name(name)
        head(pname, "gauge")
        lines.append(f"{_series(pname, labels)} {v:g}")
    for key, h in snap.get("histograms", {}).items():
        name, labels = _split(key)
        pname = _prom_name(name)
        head(pname, "summary")
        for q, fld in (("0.5", "p50"), ("0.95", "p95"),
                       ("0.99", "p99"), ("0.9999", "p99.99")):
            if fld in h:
                qlabel = 'quantile="%s"' % q
                lines.append(f"{_series(pname, labels, qlabel)} "
                             f"{h[fld]:g}")
        lines.append(f"{pname}_sum{{{labels}}} {h.get('sum', 0.0):g}"
                     if labels else f"{pname}_sum {h.get('sum', 0.0):g}")
        lines.append(f"{pname}_count{{{labels}}} {h.get('count', 0)}"
                     if labels else f"{pname}_count {h.get('count', 0)}")
    return "\n".join(lines) + "\n"


def legacy_stats_view(snap: dict, section: str) -> dict:
    """Reconstruct a legacy ``stats()`` scalar section from registry
    metrics exported with a ``key="<orig-key>"`` label.

    Counters mirrored via ``reg.counter(section, key=k).set_total(v)``
    come back as ``{k: v}`` with integral values cast to int, preserving
    the shape existing tests and benches consume.
    """
    out: dict = {}
    prefix = f'{section}{{key="'
    for kind in ("counters", "gauges"):
        for key, v in snap.get(kind, {}).items():
            if key.startswith(prefix) and key.endswith('"}'):
                orig = key[len(prefix):-2]
                out[orig] = int(v) if float(v).is_integer() else float(v)
    return out
