"""Per-query span trees with bounded retention and budget attribution.

A trace is a tree of :class:`Span` objects rooted at a ``query`` span:
stage0 predict -> routing decision -> per-shard Stage-1 attempts (with
retries/failovers) -> fusion -> Stage-2 rerank/trim/skip, plus cache and
admission outcomes in the metadata.  The :class:`TraceStore` keeps only
the slowest / budget-violating traces in bounded memory, and
:func:`why_slow` names the stage that consumed the budget.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field

__all__ = ["Span", "QueryTrace", "TraceStore", "why_slow"]


@dataclass
class Span:
    """One timed node in a query's execution tree.

    ``start_us`` is relative to the query's service start on the virtual
    clock; zero-duration spans record decisions (routing, skip)."""

    name: str
    start_us: float = 0.0
    duration_us: float = 0.0
    meta: dict = field(default_factory=dict)
    children: list["Span"] = field(default_factory=list)

    def child(self, name: str, start_us: float = 0.0,
              duration_us: float = 0.0, **meta) -> "Span":
        s = Span(name, float(start_us), float(duration_us), dict(meta))
        self.children.append(s)
        return s

    def to_dict(self) -> dict:
        d = {"name": self.name, "start_us": float(self.start_us),
             "duration_us": float(self.duration_us)}
        if self.meta:
            d["meta"] = {k: self.meta[k] for k in sorted(self.meta)}
        if self.children:
            d["children"] = [c.to_dict() for c in self.children]
        return d


@dataclass
class QueryTrace:
    qid: int
    clock_us: float          # virtual-clock time the query was served
    latency_us: float        # total (wait + service for online traffic)
    budget_us: float
    violation: bool
    root: Span
    meta: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {
            "qid": int(self.qid),
            "clock_us": float(self.clock_us),
            "latency_us": float(self.latency_us),
            "budget_us": float(self.budget_us),
            "violation": bool(self.violation),
            "meta": {k: self.meta[k] for k in sorted(self.meta)},
            "spans": self.root.to_dict(),
            "why_slow": why_slow(self),
        }


class TraceStore:
    """Bounded retention of the most interesting traces.

    Priority: budget violations first, then latency; ties broken by
    arrival order (older wins) so replays are deterministic.  A min-heap
    over ``(violation, latency, -seq)`` keeps the top ``capacity``."""

    def __init__(self, capacity: int = 32) -> None:
        if capacity < 0:
            raise ValueError("capacity must be >= 0")
        self.capacity = int(capacity)
        self.offered = 0
        self.kept = 0
        self._seq = 0
        self._heap: list[tuple[tuple, int, QueryTrace]] = []

    def _priority(self, latency_us: float, violation: bool) -> tuple:
        return (1 if violation else 0, float(latency_us), -self._seq)

    def would_keep(self, latency_us: float, violation: bool) -> bool:
        """Cheap pre-check so callers can skip building span trees for
        queries that would be dropped anyway."""
        if self.capacity == 0:
            return False
        if len(self._heap) < self.capacity:
            return True
        return self._priority(latency_us, violation) > self._heap[0][0]

    def offer(self, trace: QueryTrace) -> bool:
        self.offered += 1
        if self.capacity == 0:
            return False
        pri = self._priority(trace.latency_us, trace.violation)
        self._seq += 1
        if len(self._heap) < self.capacity:
            heapq.heappush(self._heap, (pri, self._seq, trace))
            self.kept += 1
            return True
        if pri > self._heap[0][0]:
            heapq.heapreplace(self._heap, (pri, self._seq, trace))
            self.kept += 1
            return True
        return False

    def slowest(self, n: int | None = None) -> list[QueryTrace]:
        """Retained traces, most interesting first."""
        out = [t for _, _, t in
               sorted(self._heap, key=lambda e: e[0], reverse=True)]
        return out if n is None else out[:n]

    def __len__(self) -> int:
        return len(self._heap)


def why_slow(trace: QueryTrace) -> dict:
    """Attribute the query's latency to the stage that consumed it.

    Walks the top-level stage spans (plus queue wait from the trace
    metadata), compares each against its share of the budget when one is
    recorded (``reserve_us`` for stage2's reservation), and names the
    largest consumer.  Returns a dict with the culprit stage, its
    duration, its fraction of total latency, and a readable detail line.
    """
    parts: list[tuple[str, float]] = []
    wait = float(trace.meta.get("wait_us", 0.0))
    if wait > 0:
        parts.append(("queue", wait))
    for s in trace.root.children:
        if s.duration_us > 0:
            parts.append((s.name, float(s.duration_us)))
    if not parts:
        return {"stage": "none", "duration_us": 0.0, "fraction": 0.0,
                "detail": "no timed spans recorded"}
    total = max(trace.latency_us, 1e-9)
    stage, dur = max(parts, key=lambda p: p[1])
    frac = dur / total
    detail = (f"{stage} consumed {dur:.0f}us of {trace.latency_us:.0f}us "
              f"({100.0 * frac:.0f}%)")
    reserve = trace.meta.get("reserve_us")
    if stage == "stage1" and reserve is not None:
        slack = trace.budget_us - float(reserve) - dur
        detail += (f"; stage2 reserve {float(reserve):.0f}us left "
                   f"{slack:.0f}us of slack")
    if trace.violation:
        detail += f"; budget {trace.budget_us:.0f}us VIOLATED"
    return {"stage": stage, "duration_us": dur,
            "fraction": frac, "detail": detail}
