"""Two-level serving cache: exact results (L1) + Stage-1 candidates (L2).

Production query streams are heavily skewed — a small head of queries
repeats constantly (the ``retr:{tenant}:{hash(query)}`` pattern of
production retrieval stacks) — yet the cascade recomputes every repeat
from scratch.  This module is the deterministic cache a
:class:`~repro.serving.spec.CacheSpec` describes:

* **L1** — exact result cache.  The key is the *normalized query*
  (sorted active ``(term, weight)`` pairs + topic) combined with the
  resolved routing decision (mirror, clamped ρ and k — so an operating
  point whose thresholds adapted since the fill can never serve a stale
  route's results; the old entry just misses and ages out) and the
  Stage-2 parameters (``k_serve``, ``t_final`` and the effective per-query
  candidate cap).  A hit bypasses the whole cascade and costs
  ``CostModel.cache_hit_us``.
* **L2** — Stage-1 candidate cache.  Keyed on the normalized query and
  the routing decision only: a hit skips retrieval (the expensive half)
  but re-runs Stage-2, so trimmed/degraded rungs and differing re-rank
  depths still get a partial win from an earlier fill.

Both levels are capacity-bounded LRUs with **entry- and byte-limits**
(O(1) dict + doubly-linked list — no ordered-dict re-sorting, no
wall-clock reads, no RNG draws; recency is pure access order).  They are
evaluated on the same serving clock as the fault schedule:

* results served with partial coverage are **never admitted** (the fill
  guard is per-query coverage == 1);
* every entry is tagged with the **coverage/fault epoch** at fill time —
  the tuple of per-partition up/down states (plus the transient-storm
  window flag) the :class:`~repro.serving.faults.FaultInjector` reports —
  and a lookup only hits when the entry's epoch matches the current one,
  so a result cached while a partition was down can never be served after
  it heals (and a healthy-epoch result can never mask a live outage).

An inactive :class:`~repro.serving.spec.CacheSpec` never constructs this
object at all (``SearchSystem.cache is None``): zero lookups, zero RNG,
bit-identical serving — the same inertness discipline as ``FaultSpec``.
"""

from __future__ import annotations

import numpy as np

from repro.serving.spec import CacheSpec

# epoch of a fault-free deployment (FaultInjector inactive): one constant,
# so healthy fills and healthy lookups always agree
HEALTHY_EPOCH = ()


# ---------------------------------------------------------------------------
# key normalization
# ---------------------------------------------------------------------------

def normalize_query(terms_row: np.ndarray, mask_row: np.ndarray,
                    topic) -> bytes:
    """The canonical byte string naming one query: active ``(term, weight)``
    pairs sorted by term id, plus the topic scalar/vector.  Padding slots
    (mask <= 0) and term order are normalized away, so the same logical
    query hits regardless of how its row was laid out."""
    terms_row = np.asarray(terms_row)
    mask_row = np.asarray(mask_row)
    live = mask_row > 0
    t = terms_row[live].astype(np.int64)
    w = mask_row[live].astype(np.float64)
    order = np.argsort(t, kind="stable")
    parts = [t[order].tobytes(), w[order].tobytes()]
    if topic is not None:
        parts.append(np.asarray(topic, np.float64).tobytes())
    return b"|".join(parts)


def route_sig(is_jass: bool, rho: float, k: float,
              extra: bytes = b"") -> bytes:
    """The byte signature of one resolved routing decision.  ρ determines
    the SAAT traversal (the global impact-level cut) and k the Stage-2
    depth, so two serves agree bit-for-bit iff their signatures match —
    which is exactly what makes a hit safe after online threshold
    adaptation (a changed route simply misses).

    ``extra`` extends the signature with any further serve-shaping
    dimension — the dense subsystem passes its resolved modality
    (``b"|M0"``/``b"|M1"``/``b"|M2"``) so lexical, dense and fused entries
    for the same query can never collide.  The default ``b""`` keeps every
    key byte-identical to the pre-dense layout, so a disabled
    ``DenseSpec`` is provably inert at the cache layer too."""
    return (b"J" if is_jass else b"B") + np.float64(rho).tobytes() \
        + np.float64(k).tobytes() + extra


def l1_key(qkey: bytes, rsig: bytes, k_serve: int, t_final: int,
           cap: int) -> bytes:
    """Exact-result key: query + route + every Stage-2 parameter that can
    change the final list (``cap`` is the effective per-query candidate
    cap — admission's trim rung — so a trimmed result can never stand in
    for a full one)."""
    return b"1|%d|%d|%d|" % (k_serve, t_final, cap) + rsig + qkey


def l2_key(qkey: bytes, rsig: bytes) -> bytes:
    """Stage-1 candidate key: query + route only — re-rank depth is
    re-decided at hit time."""
    return b"2|" + rsig + qkey


def entry_nbytes(value) -> int:
    """Byte charge of one cached value: the array payloads (results are
    tuples of numpy rows / scalars)."""
    n = 0
    for v in value if isinstance(value, tuple) else (value,):
        if isinstance(v, np.ndarray):
            n += v.nbytes
        elif v is not None:
            n += 8
    return n


# ---------------------------------------------------------------------------
# the LRU
# ---------------------------------------------------------------------------

class _Node:
    __slots__ = ("key", "value", "nbytes", "epoch", "prev", "nxt")

    def __init__(self, key, value, nbytes, epoch):
        self.key = key
        self.value = value
        self.nbytes = nbytes
        self.epoch = epoch
        self.prev = None
        self.nxt = None


class LRUCache:
    """Entry- and byte-bounded LRU: dict for O(1) lookup, an intrusive
    doubly-linked list for O(1) recency moves and tail eviction.

    Deterministic by construction — recency is access order, eviction is
    strictly from the LRU tail, and nothing reads a clock or an RNG — so
    two replays of the same serve sequence hold identical contents.
    """

    def __init__(self, max_entries: int, max_bytes: int = 0):
        if max_entries < 0 or max_bytes < 0:
            raise ValueError("capacities must be >= 0")
        self.max_entries = int(max_entries)
        self.max_bytes = int(max_bytes)   # 0 = entries-only bound
        self._map: dict = {}
        self._head: _Node | None = None   # most recently used
        self._tail: _Node | None = None   # eviction end
        self.nbytes = 0
        self.stats = {"hits": 0, "misses": 0, "fills": 0, "updates": 0,
                      "evicted_entries": 0, "evicted_bytes": 0,
                      "epoch_misses": 0}

    def __len__(self) -> int:
        return len(self._map)

    # -- list plumbing ----------------------------------------------------
    def _unlink(self, node: _Node) -> None:
        if node.prev is not None:
            node.prev.nxt = node.nxt
        else:
            self._head = node.nxt
        if node.nxt is not None:
            node.nxt.prev = node.prev
        else:
            self._tail = node.prev
        node.prev = node.nxt = None

    def _push_front(self, node: _Node) -> None:
        node.prev, node.nxt = None, self._head
        if self._head is not None:
            self._head.prev = node
        self._head = node
        if self._tail is None:
            self._tail = node

    def _drop(self, node: _Node) -> None:
        self._unlink(node)
        del self._map[node.key]
        self.nbytes -= node.nbytes

    def _evict_to_fit(self, incoming_nbytes: int) -> None:
        """Make room for one incoming entry: evict from the LRU tail until
        both an entry slot and (when byte-bounded) the payload fit."""
        while self._tail is not None and (
                len(self._map) >= self.max_entries
                or (self.max_bytes
                    and self.nbytes + incoming_nbytes > self.max_bytes)):
            victim = self._tail
            self._drop(victim)
            self.stats["evicted_entries"] += 1
            self.stats["evicted_bytes"] += victim.nbytes

    # -- public API -------------------------------------------------------
    def get(self, key, epoch=HEALTHY_EPOCH):
        """The cached value, or ``None``.  A key present under a different
        coverage/fault epoch is dropped and reported as a miss — degraded
        and healthy serving can never poison each other."""
        node = self._map.get(key)
        if node is None:
            self.stats["misses"] += 1
            return None
        if node.epoch != epoch:
            self._drop(node)
            self.stats["epoch_misses"] += 1
            self.stats["misses"] += 1
            return None
        self._unlink(node)
        self._push_front(node)
        self.stats["hits"] += 1
        return node.value

    def contains(self, key, epoch=HEALTHY_EPOCH) -> bool:
        """Side-effect-free membership probe (no recency move, no stats):
        the admission controller's dispatch-time peek."""
        node = self._map.get(key)
        return node is not None and node.epoch == epoch

    def put(self, key, value, epoch=HEALTHY_EPOCH) -> None:
        """Insert/refresh an entry at the MRU end, evicting from the LRU
        tail until both the entry and the byte bound hold.  An entry larger
        than the whole byte budget is refused outright."""
        if self.max_entries == 0:
            return
        nbytes = entry_nbytes(value)
        if self.max_bytes and nbytes > self.max_bytes:
            return
        node = self._map.get(key)
        if node is not None:
            self.nbytes += nbytes - node.nbytes
            node.value, node.nbytes, node.epoch = value, nbytes, epoch
            self._unlink(node)
            self._push_front(node)
            self.stats["updates"] += 1
            return
        self._evict_to_fit(nbytes)
        node = _Node(key, value, nbytes, epoch)
        self._map[key] = node
        self._push_front(node)
        self.nbytes += nbytes
        self.stats["fills"] += 1

    def keys_mru(self) -> list:
        """Keys in most-recently-used-first order (tests/debug)."""
        out, node = [], self._head
        while node is not None:
            out.append(node.key)
            node = node.nxt
        return out


# ---------------------------------------------------------------------------
# the two-level serving cache
# ---------------------------------------------------------------------------

class ServingCache:
    """The :class:`CacheSpec`-shaped pair of LRUs plus serving counters.

    ``SearchSystem`` owns one of these when (and only when) the spec is
    active; every method is deterministic and RNG-free.
    """

    def __init__(self, spec: CacheSpec):
        spec.validate()
        if not spec.active:
            raise ValueError("ServingCache built from an inactive CacheSpec "
                             "— the serve path must keep cache=None instead")
        self.spec = spec
        self.l1 = (LRUCache(spec.l1_entries, spec.l1_bytes)
                   if spec.l1_entries > 0 else None)
        self.l2 = (LRUCache(spec.l2_entries, spec.l2_bytes)
                   if spec.l2_entries > 0 else None)
        self.counters = {"lookups": 0, "l1_hits": 0, "l2_hits": 0,
                         "full_misses": 0, "skipped_partial": 0}

    # -- L1 ---------------------------------------------------------------
    def l1_get(self, key: bytes, epoch):
        return self.l1.get(key, epoch) if self.l1 is not None else None

    def l1_contains(self, key: bytes, epoch) -> bool:
        return self.l1 is not None and self.l1.contains(key, epoch)

    def l1_put(self, key: bytes, value, epoch) -> None:
        if self.l1 is not None:
            self.l1.put(key, value, epoch)

    # -- L2 ---------------------------------------------------------------
    def l2_get(self, key: bytes, epoch):
        return self.l2.get(key, epoch) if self.l2 is not None else None

    def l2_put(self, key: bytes, value, epoch) -> None:
        if self.l2 is not None:
            self.l2.put(key, value, epoch)

    # -- reporting --------------------------------------------------------
    def hit_ratio(self) -> float:
        """Lifetime L1 hit ratio over every lookup so far."""
        n = self.counters["lookups"]
        return self.counters["l1_hits"] / n if n else 0.0

    def stats(self) -> dict:
        s = dict(self.counters)
        s["hit_ratio"] = self.hit_ratio()
        for name, lru in (("l1", self.l1), ("l2", self.l2)):
            s[name] = (None if lru is None else
                       {"entries": len(lru), "nbytes": lru.nbytes,
                        **lru.stats})
        return s

    def export_metrics(self, reg) -> None:
        """Mirror cache counters + per-level occupancy into a telemetry
        registry."""
        for k, v in self.counters.items():
            reg.counter("cache", key=k).set_total(v)
        reg.gauge("cache_hit_ratio").set(self.hit_ratio())
        for name, lru in (("l1", self.l1), ("l2", self.l2)):
            if lru is None:
                continue
            reg.gauge("cache_entries", level=name).set(len(lru))
            reg.gauge("cache_nbytes", level=name).set(lru.nbytes)
            for k, v in lru.stats.items():
                reg.counter("cache_level", level=name, key=k).set_total(v)


def ingest_epoch(epoch: tuple, counter: int) -> tuple:
    """Fold the live-ingest generation counter into a coverage/fault epoch.

    Every applied feed batch and every merge bumps the counter, so L1/L2
    entries filled before a mutation can never be served after it — the
    same mechanism that keeps fault-window entries from leaking across
    partition state changes.  With ingest disabled the epoch is passed
    through untouched, keeping cache behavior bit-identical.
    """
    return tuple(epoch) + (("ingest", int(counter)),)
