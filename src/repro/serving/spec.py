"""Declarative serving configuration: one typed, serializable spec tree
describes an entire deployment of the paper's multi-stage system.

The paper pitches a *unified framework* that can be "easily applied in
large-scale IR systems" across all stages; the spec is the API form of
that claim: a single :class:`CascadeSpec` names an operating point — index
layout, Stage-0 predictors, routing thresholds, Stage-2 re-ranker, kernel
backend, and the deployment shape (shards x replicas) — and
``repro.serving.system.build_system`` instantiates it.  Named operating
points live in ``repro.configs.cascade_presets``.

Every node is a frozen dataclass of JSON-plain scalars, so
``spec.to_json()`` / ``CascadeSpec.from_json()`` round-trip exactly and a
spec can be checked into a config repo, diffed, and shipped to a serving
fleet.  ``replace``-style evolution works through ``dataclasses.replace``.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field

SPEC_VERSION = 1


@dataclass(frozen=True)
class IndexSpec:
    """Index build + device-mirror layout parameters."""
    block_size: int = 64        # DAAT block-max block width (docs)
    stop_k: int = 16            # drop the stop_k most frequent terms
    tile_d: int = 128           # docs per bucketed serving tile (kernels)

    def validate(self) -> None:
        if self.tile_d % self.block_size:
            raise ValueError(f"tile_d={self.tile_d} must be a multiple of "
                             f"block_size={self.block_size}")


@dataclass(frozen=True)
class Stage0Spec:
    """Quantile-GBRT predictor training configuration (k, rho, t)."""
    n_trees: int = 48
    depth: int = 5
    tau_k: float = 0.55
    tau_rho: float = 0.45
    tau_t: float = 0.5

    def validate(self) -> None:
        if self.n_trees < 1 or self.depth < 1:
            raise ValueError("Stage0Spec needs n_trees >= 1 and depth >= 1")


@dataclass(frozen=True)
class RoutingSpec:
    """Stage-0 scheduler thresholds (paper Algorithms 1/2 + hedging)."""
    algorithm: int = 2
    budget: float = 200.0
    t_k: float = 1000.0
    t_time: float = 150.0
    rho_max: int = 1 << 20
    rho_min: int = 4096
    hedge_band: float = 0.25
    enable_hedging: bool = True
    hedge_deadline: float = 0.5  # straggler detection fraction of the budget
    late_rho: int = 0            # late-hedge re-issue ρ cap (0 = auto:
                                 # rho_min) — keep SMALL: the hard bound is
                                 # budget·hedge_deadline + ρ_late·c_s
    enforce_budget: bool = True  # cascade-wide enforcement: deadline
                                 # re-route JASS rows, trim Stage-2 grids
    adapt_every: int = 0         # batches between online threshold
                                 # adaptations from pool EWMAs (0 = off)
    calibrate: bool = False     # fit(): set t_k/t_time from the trained
                                # predictors' distribution
    failover_timeout: float = 0.0  # scatter-gather timeout (time units):
                                   # a shard request with no response by
                                   # this is declared dead and re-issued to
                                   # another healthy replica (0 = no
                                   # failover; required when faults are on)
    max_retries: int = 0         # bounded re-issues per (query, shard);
                                 # the retry budget max_retries *
                                 # failover_timeout is charged into the
                                 # worst_case_us bound

    def validate(self) -> None:
        if self.algorithm not in (1, 2):
            raise ValueError(f"algorithm must be 1 or 2, got {self.algorithm}")
        if self.budget <= 0:
            raise ValueError("budget must be positive")
        if self.rho_min > self.rho_max:
            raise ValueError("rho_min must not exceed rho_max")
        if not 0.0 < self.hedge_deadline <= 1.0:
            raise ValueError("hedge_deadline must be in (0, 1]")
        if self.late_rho < 0:
            raise ValueError("late_rho must be >= 0 (0 = auto)")
        if self.late_rho > self.rho_max:
            raise ValueError("late_rho must not exceed rho_max")
        if self.adapt_every < 0:
            raise ValueError("adapt_every must be >= 0 (0 = off)")
        if self.failover_timeout < 0:
            raise ValueError("failover_timeout must be >= 0 (0 = off)")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.max_retries > 0 and self.failover_timeout <= 0:
            raise ValueError("max_retries > 0 needs failover_timeout > 0 "
                             "(retries are issued at the timeout)")
        if (self.failover_timeout > 0
                and (1 + self.max_retries) * self.failover_timeout
                > self.budget):
            raise ValueError(
                "(1 + max_retries) * failover_timeout must fit the budget: "
                "a fully-dead partition is declared lost only after the "
                "whole retry chain times out, and that wait must stay "
                "inside the response bound")


@dataclass(frozen=True)
class Stage2Spec:
    """Candidate depth and LTR re-ranker configuration."""
    enabled: bool = True
    k_serve: int = 128          # Stage-1 retrieval depth (candidate grid C)
    t_final: int = 10           # final result-list depth
    ltr_trees: int = 48
    n_train_queries: int = 256  # queries used to fit the LTR model

    def validate(self) -> None:
        if self.k_serve < 1:
            raise ValueError("k_serve must be >= 1")
        if self.enabled and self.t_final < 1:
            raise ValueError("t_final must be >= 1 when Stage-2 is enabled")


@dataclass(frozen=True)
class BackendSpec:
    """Kernel backend + cost-model selection."""
    backend: str | None = None  # "pallas" | "interpret" | "jnp" | None=auto
    cost: str = "paper_scale"   # CostModel constructor name
    calibrate_cost: bool = True  # fit(): regress measured work→latency
                                 # pairs into the CostModel constants

    def validate(self) -> None:
        if self.backend not in (None, "pallas", "interpret", "jnp"):
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.cost not in ("paper_scale", "v5e_shard"):
            raise ValueError(f"unknown cost model {self.cost!r}")


@dataclass(frozen=True)
class OnlineSpec:
    """Online traffic policy: dynamic micro-batching + admission control.

    The offline ``serve()`` path certifies the *service-time* tail of one
    pre-formed batch; this node configures the layer that converts that
    into a **response-time** guarantee under load (queueing included):
    ``repro.serving.online`` wraps the system in a simulated clock, forms
    Stage-1 micro-batches under a ``batch_deadline_us`` / ``max_batch``
    policy, and sheds or degrades queries whose queueing delay has already
    eaten the response budget (see ``repro.serving.online.admission``).

    Time units follow the spec's ``CostModel`` (ms at ``paper_scale``).
    """
    max_batch: int = 32          # micro-batch width cap (Q axis)
    batch_deadline_us: float = 5.0   # close a batch when its oldest query
                                     # has waited this long
    bucket_q: bool = True        # pad batches to power-of-two Q buckets so
                                 # batched engine calls stay jit-cache-
                                 # friendly (pads replicate a real query
                                 # and are dropped from results)
    dispatch_us: float = 1.0     # per-batch dispatch/queue-handoff overhead
    admission: bool = True       # SLA-aware admission control + shedding
    degrade: bool = True         # allow trimmed-Stage-2 / stage1-only
                                 # service before rejecting outright
    queue_cap: int = 0           # hard queue-depth cap (0 = unbounded;
                                 # admission bounds it softly regardless)
    response_budget_us: float = 0.0  # end-to-end response-time budget,
                                     # queueing included (0 = auto: 2x the
                                     # routing budget)

    def validate(self) -> None:
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.batch_deadline_us < 0:
            raise ValueError("batch_deadline_us must be >= 0")
        if self.dispatch_us < 0:
            raise ValueError("dispatch_us must be >= 0")
        if self.queue_cap < 0:
            raise ValueError("queue_cap must be >= 0 (0 = unbounded)")
        if self.response_budget_us < 0:
            raise ValueError("response_budget_us must be >= 0 (0 = auto)")


@dataclass(frozen=True)
class FaultSpec:
    """A deterministic, seeded fault-injection schedule.

    The 99.99 % regime is exactly where machine failures, not query
    difficulty, dominate the tail — this node makes failures part of the
    *named* operating point so a guarantee can be certified under them
    (``benchmarks/bench_faults.py``).  All times are on the serving clock
    in cost-model units: the offline ``serve()`` path advances a virtual
    clock by each batch's occupancy, the online simulator drives it from
    the event loop, so one schedule means the same thing on both paths.

    ``partition=-1`` / ``replica=-1`` are wildcards (every partition /
    every replica of the partition).  Windows are half-open ``[t0, t1)``;
    use ``float("inf")`` (JSON ``Infinity``) for an open end.

    An empty schedule (the default) is **inert**: the fault layer is
    skipped entirely — no RNG draws, no pool interactions — so serving is
    bit-identical to a fault-free build.
    """
    # replica crash/recover windows: (partition, replica, t_start, t_end) —
    # requests to the replica inside the window never respond (detected at
    # the failover timeout); outside it, health probes re-admit it
    crashes: tuple = ()
    # straggler windows: (partition, replica, t_start, t_end, slowdown) —
    # the replica responds, slowdown x slower than nominal
    stragglers: tuple = ()
    # whole-partition outages: (partition, t_start, t_end) — every replica
    # of the partition is down; queries degrade to partial coverage
    outages: tuple = ()
    # transient per-request timeout probability inside [t_start, t_end)
    timeout_p: float = 0.0
    timeout_start: float = 0.0
    timeout_end: float = float("inf")
    seed: int = 0                # transient-draw RNG seed

    def __post_init__(self):
        # JSON round-trips tuples as lists; coerce back so a round-tripped
        # spec compares (and hashes) equal to the original
        for name in ("crashes", "stragglers", "outages"):
            object.__setattr__(
                self, name,
                tuple(tuple(w) for w in getattr(self, name)))

    @property
    def active(self) -> bool:
        """Whether the schedule injects anything at all."""
        return bool(self.crashes or self.stragglers or self.outages
                    or self.timeout_p > 0)

    @property
    def needs_failover(self) -> bool:
        """Whether the schedule can kill requests (and therefore needs a
        ``RoutingSpec.failover_timeout`` to detect them)."""
        return bool(self.crashes or self.outages or self.timeout_p > 0)

    def validate(self) -> None:
        def _window(p, t0, t1, r=None):
            if p < -1:
                raise ValueError(f"partition must be >= -1, got {p}")
            if r is not None and r < -1:
                raise ValueError(f"replica must be >= -1, got {r}")
            if t1 < t0:
                raise ValueError(f"fault window [{t0}, {t1}) is inverted")
        for w in self.crashes:
            if len(w) != 4:
                raise ValueError(f"crash window needs (partition, replica, "
                                 f"t_start, t_end), got {w}")
            _window(w[0], w[2], w[3], r=w[1])
        for w in self.stragglers:
            if len(w) != 5:
                raise ValueError(f"straggler window needs (partition, "
                                 f"replica, t_start, t_end, slowdown), "
                                 f"got {w}")
            _window(w[0], w[2], w[3], r=w[1])
            if w[4] < 1.0:
                raise ValueError(f"straggler slowdown must be >= 1, "
                                 f"got {w[4]}")
        for w in self.outages:
            if len(w) != 3:
                raise ValueError(f"outage window needs (partition, t_start, "
                                 f"t_end), got {w}")
            _window(w[0], w[1], w[2])
        if not 0.0 <= self.timeout_p < 1.0:
            raise ValueError("timeout_p must be in [0, 1)")
        if self.timeout_end < self.timeout_start:
            raise ValueError("timeout window is inverted")


@dataclass(frozen=True)
class CacheSpec:
    """Two-level serving cache: the skew half of a production workload.

    Production query streams are heavily skewed — a small head of queries
    repeats constantly — and a repeat should not pay the Stage-0→1→2
    cascade again.  This node names the cache half of the operating point:

    * **L1** — exact result cache keyed on the normalized query (sorted
      active term ids + weights + topic + the resolved route/ρ/k and the
      Stage-2 depth): a hit bypasses the whole cascade and costs
      ``CostModel.cache_hit_us``;
    * **L2** — Stage-1 candidate cache keyed on (normalized query, route,
      ρ) only: a hit skips retrieval but re-runs Stage-2, so trimmed /
      degraded rungs and differing re-rank depths still get a partial win.

    Both levels are deterministic capacity-bounded LRUs (entry- **and**
    byte-limits, O(1) dict+linked-list, no wall-clock reads, no RNG) in
    ``repro.serving.cache``, evaluated on the same serving clock as the
    fault schedule: partial-coverage results are never admitted, and every
    entry is tagged with the coverage/fault epoch at fill time so a result
    cached while a partition was down can never be served after it heals
    (and vice versa).

    The default (``enabled=False``) is **inert**: ``SearchSystem`` takes
    the historical serve path untouched — zero lookups, zero RNG draws,
    bit-identical serving — the same discipline as an empty ``FaultSpec``.
    """
    enabled: bool = False
    l1_entries: int = 4096       # exact-result entries (0 disables L1)
    l2_entries: int = 4096       # Stage-1 candidate entries (0 disables L2)
    l1_bytes: int = 1 << 26      # per-level byte cap (0 = entries-only)
    l2_bytes: int = 1 << 26
    hit_alpha: float = 0.2       # admission hit-ratio EWMA step (the live
                                 # hit ratio folds into the shed floor and
                                 # the observed-capacity estimate)

    @property
    def active(self) -> bool:
        """Whether any level can hold an entry at all."""
        return self.enabled and (self.l1_entries > 0 or self.l2_entries > 0)

    def validate(self) -> None:
        for name in ("l1_entries", "l2_entries", "l1_bytes", "l2_bytes"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be >= 0")
        if not 0.0 < self.hit_alpha <= 1.0:
            raise ValueError("hit_alpha must be in (0, 1]")


@dataclass(frozen=True)
class DenseSpec:
    """The dense Stage-1 modality: embedding retrieval + modality routing.

    When enabled, ``SearchSystem`` builds a :class:`~repro.dense.engine.
    DenseEngine` over the SAME doc-range partitioning as the lexical
    shards and Stage-0 dispatches every query to one of three routes from
    its predicted lexical time ``pred_t``:

    * ``pred_t <= t_dense·(1 - fuse_band)`` — **lexical** (cheap queries
      stay on the impact-ordered engines);
    * inside the band — **both + fused** (uncertain queries run both
      engines in parallel and merge by :class:`FusionSpec`);
    * ``pred_t > t_dense·(1 + fuse_band)`` — **dense only** (the
      shape-static dense cost undercuts a predicted-expensive traversal).

    Confidence-band shortcuts: a dense-involved query whose top dense
    score clears ``theta_high`` serves its Stage-1 order directly
    (rank-safe Stage-2 skip, the existing zero-grid path); a dense-only
    query below ``theta_low`` re-issues a bounded ρ-capped lexical
    fallback (priced like the late hedge, so the route stays inside
    ``worst_case_us``).  The ``inf``/``-inf`` defaults disarm both bands.

    The default (``enabled=False``) is **inert**: no engine is built, no
    embedding tables materialize, every serve path and cache key is
    bit-identical to the lexical-only system — the same discipline as
    ``FaultSpec``/``CacheSpec``.
    """
    enabled: bool = False
    embed_dim: int = 32          # synthetic-source embedding width (the
                                 # two-tower source uses the tower's output)
    tile_d: int = 512            # docs per dense-kernel grid tile
    source: str = "auto"         # auto | two_tower | synthetic
    seed: int = 0                # embedding init / synthetic-table seed
    t_dense: float = 0.0         # pred_t threshold routing toward dense
                                 # (0 = auto: track routing.t_time)
    fuse_band: float = 0.25      # both+fused band half-width around t_dense
    theta_high: float = float("inf")   # top dense score >= this: skip
                                       # Stage-2 rank-safely (inf = never)
    theta_low: float = float("-inf")   # dense-only top score < this:
                                       # bounded lexical fallback
                                       # (-inf = never)

    @property
    def active(self) -> bool:
        return self.enabled

    def validate(self) -> None:
        if self.embed_dim < 1:
            raise ValueError("embed_dim must be >= 1")
        if self.tile_d < 128 or self.tile_d % 128:
            raise ValueError("tile_d must be a positive multiple of the "
                             "128-lane width")
        if self.source not in ("auto", "two_tower", "synthetic"):
            raise ValueError(f"unknown dense source {self.source!r}")
        if self.t_dense < 0:
            raise ValueError("t_dense must be >= 0 (0 = auto)")
        if not 0.0 <= self.fuse_band <= 1.0:
            raise ValueError("fuse_band must be in [0, 1]")
        if self.theta_low > self.theta_high:
            raise ValueError("theta_low must not exceed theta_high")


@dataclass(frozen=True)
class FusionSpec:
    """How a both-routed query's lexical and dense lists merge.

    ``rrf`` is reciprocal-rank fusion (rank-only — no cross-modality score
    calibration needed); ``weighted`` min-max normalizes each list per
    query and blends by ``w_dense``.  Only consulted when
    ``DenseSpec.enabled``; both rules break score ties toward the lower
    global doc id (see ``repro.dense.fusion``).
    """
    method: str = "rrf"          # rrf | weighted
    rrf_k0: float = 60.0         # RRF rank damping constant
    w_dense: float = 0.5         # dense weight under 'weighted'

    def validate(self) -> None:
        if self.method not in ("rrf", "weighted"):
            raise ValueError(f"unknown fusion method {self.method!r}")
        if self.rrf_k0 <= 0:
            raise ValueError("rrf_k0 must be positive")
        if not 0.0 <= self.w_dense <= 1.0:
            raise ValueError("w_dense must be in [0, 1]")


@dataclass(frozen=True)
class IngestSpec:
    """Live index mutation: the feed half of a production operating point.

    When enabled, ``SearchSystem`` attaches a capacity-bounded
    :class:`~repro.index.delta.DeltaStore` — an append-only delta tile-set
    scanned by every Stage-1 engine alongside the sealed shards —
    and exposes ``add_documents()`` / ``merge()``.  The online simulator
    drives a seeded feed-arrival process on the same virtual clock as
    queries, applies ingest batches between dispatches, and triggers a
    background merge when the delta fill crosses ``merge_threshold``
    (deferred under load by the admission ladder: merge defers, then feed
    throttles, and only then do queries degrade/shed).

    The worst-case lexical delta scan (``CostModel.delta_time`` at the
    postings *capacity*) plus the dense delta-tile term is charged into
    every served query's Stage-1 latency and into ``worst_case_us``, so
    admission and the late hedge stay sound at any fill level.

    The default (``enabled=False``) is **inert**: no delta store is built,
    every serve path, cache key, and event log is bit-identical to a
    sealed-index system — the same discipline as ``FaultSpec`` /
    ``CacheSpec`` / ``DenseSpec``.
    """
    enabled: bool = False
    delta_docs: int = 512        # delta segment doc capacity
    delta_postings: int = 8192   # delta segment postings capacity (padded
                                 # array shapes; also the worst-case scan
                                 # charge — size it to the budget's slack)
    feed_qps: float = 10.0       # feed BATCH arrivals per 1000 time units
    feed_batch: int = 16         # docs per feed batch
    ingest_us: float = 2.0       # server occupancy per applied feed batch
    merge_us: float = 50.0       # server occupancy of a background merge
    merge_threshold: float = 0.75  # delta doc-fill fraction that requests
                                   # a merge (1.0 = only when full)
    seed: int = 0                # feed arrival-process seed

    @property
    def active(self) -> bool:
        return self.enabled

    def validate(self) -> None:
        if self.delta_docs < 1:
            raise ValueError("delta_docs must be >= 1")
        if self.delta_postings < 1:
            raise ValueError("delta_postings must be >= 1")
        if self.feed_qps <= 0:
            raise ValueError("feed_qps must be positive")
        if self.feed_batch < 1:
            raise ValueError("feed_batch must be >= 1")
        if self.ingest_us < 0 or self.merge_us < 0:
            raise ValueError("ingest_us/merge_us must be >= 0")
        if not 0.0 < self.merge_threshold <= 1.0:
            raise ValueError("merge_threshold must be in (0, 1]")


ARRIVALS = ("poisson", "bursty", "diurnal", "trace")


@dataclass(frozen=True)
class TrafficSpec:
    """A seeded arrival process: the workload half of an online experiment.

    Kept separate from :class:`CascadeSpec` — traffic describes the world,
    the cascade spec describes the deployment — but serialized the same way
    (JSON-plain frozen dataclass) so a load test is fully named by the
    (CascadeSpec, TrafficSpec) pair.

    ``qps`` is queries per 1000 cost-model time units, i.e. literally
    queries/second when the cost model is in milliseconds
    (``CostModel.paper_scale``).
    """
    arrival: str = "poisson"     # poisson | bursty | diurnal | trace
    qps: float = 100.0
    seed: int = 0
    # query-identity skew: each arrival's query is drawn Zipf(s=skew) over
    # the log (rank r with probability ∝ 1/r^skew), so a head of queries
    # repeats — the workload half of the serving cache.  0 = uniform replay
    # of the log in order (the historical behavior, bit-identical).  The
    # identity stream is seeded independently of the arrival-time stream,
    # so toggling skew never moves a timestamp.
    skew: float = 0.0
    # bursty (2-state MMPP): high-state rate = qps * burst_factor, dwell
    # times exponential with the given means; the low-state rate is solved
    # so the long-run mean rate stays qps
    burst_factor: float = 4.0
    burst_fraction: float = 0.1  # long-run fraction of time in the burst
    burst_dwell_us: float = 50.0  # mean burst dwell (time units)
    # diurnal: rate(t) = qps * (1 + amplitude * sin(2*pi*t/period))
    diurnal_amplitude: float = 0.5
    diurnal_period_us: float = 1000.0
    trace_path: str = ""         # "trace": replay timestamps from a JSON
                                 # list or .npy array (time units)

    def validate(self) -> None:
        if self.arrival not in ARRIVALS:
            raise ValueError(f"arrival must be one of {ARRIVALS}, "
                             f"got {self.arrival!r}")
        if self.arrival != "trace" and self.qps <= 0:
            raise ValueError("qps must be positive")
        if self.skew < 0:
            raise ValueError("skew must be >= 0 (0 = no repetition)")
        if self.arrival == "trace" and not self.trace_path:
            raise ValueError("arrival='trace' needs trace_path")
        if self.arrival == "bursty":
            if self.burst_factor < 1.0:
                raise ValueError("burst_factor must be >= 1")
            if not 0.0 < self.burst_fraction < 1.0:
                raise ValueError("burst_fraction must be in (0, 1)")
            if self.burst_factor * self.burst_fraction >= 1.0:
                raise ValueError(
                    "burst_factor * burst_fraction must be < 1 so the "
                    "off-burst rate stays positive")
            if self.burst_dwell_us <= 0:
                raise ValueError("burst_dwell_us must be positive")
        if self.arrival == "diurnal":
            if not 0.0 <= self.diurnal_amplitude < 1.0:
                raise ValueError("diurnal_amplitude must be in [0, 1)")
            if self.diurnal_period_us <= 0:
                raise ValueError("diurnal_period_us must be positive")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "TrafficSpec":
        spec = cls(**d)
        spec.validate()
        return spec

    @classmethod
    def from_json(cls, s: str) -> "TrafficSpec":
        return cls.from_dict(json.loads(s))


@dataclass(frozen=True)
class DeploySpec:
    """Deployment shape: document shards x replicas per shard.

    ``n_shards`` doc-range partitions serve Stage-1 scatter-gather;
    ``replicas`` ISN replicas back each partition (split across the
    BMW/JASS mirrors by ``jass_fraction``, re-split online every
    ``rebalance_every`` batches from the observed routing mix).
    """
    n_shards: int = 1
    replicas: int = 2
    jass_fraction: float = 0.5
    rebalance_every: int = 1    # batches between pool rebalances (0 = off)
    seed: int = 0

    def validate(self) -> None:
        if self.n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if self.replicas < 1:
            raise ValueError("replicas must be >= 1")
        if not 0.0 <= self.jass_fraction <= 1.0:
            raise ValueError("jass_fraction must be in [0, 1]")


@dataclass(frozen=True)
class TelemetrySpec:
    """Deterministic observability layer (metrics + traces + snapshots).

    Disabled by default and provably inert when disabled: the system
    allocates no registry, every instrumentation hook is guarded, and
    serving plus the online event log stay bit-identical.  All telemetry
    runs on the virtual serving clock — no wall time, no RNG — so
    same-seed replays export byte-identical snapshots.
    """
    enabled: bool = False
    bins_per_decade: int = 64   # histogram resolution; rel err ~1.8%
    exact_n: int = 256          # exact quantiles while N <= exact_n
    hist_lo: float = 1e-3       # bucketed range lower edge (us)
    hist_hi: float = 1e7        # bucketed range upper edge (us)
    trace_reservoir: int = 32   # slowest/violating traces retained
    snapshot_every_us: float = 0.0   # online snapshot cadence (0 = off)
    max_snapshots: int = 64

    @property
    def active(self) -> bool:
        return self.enabled

    def validate(self) -> None:
        if self.bins_per_decade < 1:
            raise ValueError("bins_per_decade must be >= 1")
        if self.exact_n < 0:
            raise ValueError("exact_n must be >= 0")
        if not 0 < self.hist_lo < self.hist_hi:
            raise ValueError("need 0 < hist_lo < hist_hi")
        if self.trace_reservoir < 0:
            raise ValueError("trace_reservoir must be >= 0")
        if self.snapshot_every_us < 0:
            raise ValueError("snapshot_every_us must be >= 0")
        if self.max_snapshots < 1:
            raise ValueError("max_snapshots must be >= 1")


_NODES = {"index": IndexSpec, "stage0": Stage0Spec, "routing": RoutingSpec,
          "stage2": Stage2Spec, "backend": BackendSpec, "deploy": DeploySpec,
          "online": OnlineSpec, "fault": FaultSpec, "cache": CacheSpec,
          "dense": DenseSpec, "fusion": FusionSpec, "ingest": IngestSpec,
          "telemetry": TelemetrySpec}


@dataclass(frozen=True)
class CascadeSpec:
    """The whole deployment, as one declarative value."""
    index: IndexSpec = field(default_factory=IndexSpec)
    stage0: Stage0Spec = field(default_factory=Stage0Spec)
    routing: RoutingSpec = field(default_factory=RoutingSpec)
    stage2: Stage2Spec = field(default_factory=Stage2Spec)
    backend: BackendSpec = field(default_factory=BackendSpec)
    deploy: DeploySpec = field(default_factory=DeploySpec)
    online: OnlineSpec = field(default_factory=OnlineSpec)
    fault: FaultSpec = field(default_factory=FaultSpec)
    cache: CacheSpec = field(default_factory=CacheSpec)
    dense: DenseSpec = field(default_factory=DenseSpec)
    fusion: FusionSpec = field(default_factory=FusionSpec)
    ingest: IngestSpec = field(default_factory=IngestSpec)
    telemetry: TelemetrySpec = field(default_factory=TelemetrySpec)
    name: str = "custom"

    def validate(self) -> "CascadeSpec":
        for node in _NODES:
            getattr(self, node).validate()
        if self.fault.needs_failover and self.routing.failover_timeout <= 0:
            raise ValueError(
                "the fault schedule can kill requests (crashes / outages / "
                "transient timeouts) but routing.failover_timeout is 0 — "
                "dead shard requests would hang forever; set a timeout "
                "(and max_retries) so failover is possible")
        return self

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["version"] = SPEC_VERSION
        return d

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "CascadeSpec":
        d = dict(d)
        version = d.pop("version", SPEC_VERSION)
        if version != SPEC_VERSION:
            raise ValueError(f"unsupported spec version {version}")
        kwargs = {}
        for node, node_cls in _NODES.items():
            if node in d:
                kwargs[node] = node_cls(**d.pop(node))
        kwargs.update(d)                 # remaining scalars (name)
        return cls(**kwargs).validate()

    @classmethod
    def from_json(cls, s: str) -> "CascadeSpec":
        return cls.from_dict(json.loads(s))
