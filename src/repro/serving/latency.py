"""First-stage latency model + percentile accounting.

This container has no TPU, so the tail-latency study uses a *calibrated cost
model* driven by the per-query work counters the engines report (postings
scored, blocks touched).  The constants are derived from the roofline terms
of the compiled Pallas kernels on TPU v5e (see EXPERIMENTS.md §Roofline):

impact_accumulate (SAAT):
  * HBM traffic/posting: 4 B docid + 4 B impact (int32 lanes)      = 8 B
  * MXU work/posting: one column of a (P_tile × 512) one-hot matmul
    = 512 MAC = 1024 flop
  * time/posting = max(8 B / 819 GB/s, 1024 / 197e12) ≈ max(9.8, 5.2) ps
    → memory-bound: c_s ≈ 9.8 ps/posting (we use 10 ps)

blockmax_score (DAAT):
  * HBM traffic/posting: 4 B docid + 4 B score + bound metadata     ≈ 10 B
    → c_d ≈ 12.2 ps/posting; per surviving block: tile setup + bound
    refinement ≈ 0.2 µs (grid-step overhead at ~1 GHz scalar core)
  * fixed per-query: bound accumulation + two top-k passes ≈ 20 µs

The paper's 200 ms budget on a 50 M-doc Xeon ISN maps to ≈ 200 µs on a v5e
shard at these rates (same ×10⁶ scale as postings/ISN); all experiments
report budget-relative numbers so the scale factor is transparent.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

PS = 1e-6  # picoseconds -> microseconds


@dataclass(frozen=True)
class CostModel:
    """Cascade cost model — Stage-0 prediction, Stage-1 engines and the
    Stage-2 LTR re-ranker.  Units are abstract "time units" fixed by the
    constructor used; the tail-latency study uses ``paper_scale`` (ms).

    Stage-0 is the fused three-predictor call (``predict_us`` covers all of
    k/ρ/t — the paper's < 0.75 ms budget).  Stage-2 is a fixed dispatch
    cost plus a per-candidate term: featurization is O(|q| · log df) gathers
    and GBRT inference O(trees · depth) per candidate, both linear in the
    candidate count the Stage-0 P_k prediction admits."""
    saat_fixed_us: float = 10.0
    saat_per_posting_us: float = 10.0 * PS
    daat_fixed_us: float = 20.0
    daat_per_posting_us: float = 12.2 * PS
    daat_per_block_us: float = 0.2
    predict_us: float = 0.75  # paper §5: <0.75 ms per prediction, scaled
    ltr_fixed_us: float = 5.0
    ltr_per_candidate_us: float = 0.04
    # scatter-gather: per-extra-shard fan-out/merge overhead.  A sharded
    # Stage-1 finishes at max-over-shards + this term — the tail is a max,
    # which is the paper's tail story at deployment scale.  0 keeps the
    # single-shard pipeline's accounting bit-identical.
    gather_per_shard_us: float = 0.0

    @classmethod
    def v5e_shard(cls) -> "CostModel":
        """Roofline-derived per-chip constants (µs) for a production
        196k-doc / ~59M-posting shard — see module docstring."""
        return cls()

    @classmethod
    def paper_scale(cls) -> "CostModel":
        """Milliseconds on the experiment corpus. The synthetic collection is
        ~763× smaller than ClueWeb09B (65,536 vs 50M docs), so one synthetic
        posting stands in for ~763 real ones; constants are the v5e rates ×
        763 × 1e3(µs→ns floor), tuned so the *exhaustive* DAAT median lands
        near the paper's ~30–40 ms and tails cross 200 ms — making the
        paper's 200 ms budget directly meaningful."""
        return cls(saat_fixed_us=3.0, saat_per_posting_us=6.4e-3,
                   daat_fixed_us=4.0, daat_per_posting_us=7.6e-3,
                   daat_per_block_us=25e-3, predict_us=0.75,
                   ltr_fixed_us=1.0, ltr_per_candidate_us=15e-3)

    def saat_time(self, work: np.ndarray) -> np.ndarray:
        return self.saat_fixed_us + work * self.saat_per_posting_us

    def daat_time(self, work: np.ndarray, blocks: np.ndarray) -> np.ndarray:
        return (self.daat_fixed_us + work * self.daat_per_posting_us
                + blocks * self.daat_per_block_us)

    def ltr_time(self, n_candidates: np.ndarray) -> np.ndarray:
        """Stage-2 re-ranking time from the per-query candidate count."""
        return (self.ltr_fixed_us
                + np.asarray(n_candidates, np.float64)
                * self.ltr_per_candidate_us)

    def gather_time(self, t_shards: np.ndarray) -> np.ndarray:
        """Scatter-gather Stage-1 time over an (n_shards, Q) per-shard time
        matrix: the query finishes when its *slowest* shard responds, plus
        the per-extra-shard fan-out/merge overhead."""
        t = np.asarray(t_shards, np.float64)
        return t.max(axis=0) + self.gather_per_shard_us * (t.shape[0] - 1)


def percentiles(t: np.ndarray) -> dict:
    return {
        "mean": float(np.mean(t)),
        "p50": float(np.percentile(t, 50)),
        "p95": float(np.percentile(t, 95)),
        "p99": float(np.percentile(t, 99)),
        "p99.9": float(np.percentile(t, 99.9)),
        "p99.99": float(np.percentile(t, 99.99)),
        "max": float(np.max(t)),
    }


def over_budget(t: np.ndarray, budget_us: float) -> tuple[int, float]:
    n = int(np.sum(t > budget_us))
    return n, 100.0 * n / len(t)
