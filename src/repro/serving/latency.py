"""First-stage latency model + percentile accounting.

This container has no TPU, so the tail-latency study uses a *calibrated cost
model* driven by the per-query work counters the engines report (postings
scored, blocks touched).  The constants are derived from the roofline terms
of the compiled Pallas kernels on TPU v5e (see EXPERIMENTS.md §Roofline):

impact_accumulate (SAAT):
  * HBM traffic/posting: 4 B docid + 4 B impact (int32 lanes)      = 8 B
  * MXU work/posting: one column of a (P_tile × 512) one-hot matmul
    = 512 MAC = 1024 flop
  * time/posting = max(8 B / 819 GB/s, 1024 / 197e12) ≈ max(9.8, 5.2) ps
    → memory-bound: c_s ≈ 9.8 ps/posting (we use 10 ps)

blockmax_score (DAAT):
  * HBM traffic/posting: 4 B docid + 4 B score + bound metadata     ≈ 10 B
    → c_d ≈ 12.2 ps/posting; per surviving block: tile setup + bound
    refinement ≈ 0.2 µs (grid-step overhead at ~1 GHz scalar core)
  * fixed per-query: bound accumulation + two top-k passes ≈ 20 µs

The paper's 200 ms budget on a 50 M-doc Xeon ISN maps to ≈ 200 µs on a v5e
shard at these rates (same ×10⁶ scale as postings/ISN); all experiments
report budget-relative numbers so the scale factor is transparent.

Guarantee accounting
--------------------
The cascade's hard tail bound decomposes over this model, term by term.
With ``B`` the *cascade* budget, ``d`` the detection fraction
(``hedge_deadline``), ``ρ_late`` the late-hedge cap and ``C`` the Stage-2
candidate width (``k_serve``):

    stage 0:   predict_us                              (unconditional)
    stage 1:   max(B₁,  d·B₁ + saat_fixed + ρ_late·saat_per_posting)
               where B₁ = B - predict_us - ltr_time(C)  is the scheduler's
               reserved first-stage budget
    stage 2:   ltr_fixed + C·ltr_per_candidate  =  ltr_time(C)

so total ≤ B whenever ``saat_fixed + ρ_late·saat_per_posting ≤ (1-d)·B₁``
(``SchedulerConfig.max_late_rho`` computes the largest such ρ_late).  The
roofline constants above are the *static* prior; ``CostModel.regressed``
replaces them with rates fit to measured (work, latency) pairs so the
bound is enforced against observed hardware, not the datasheet.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

PS = 1e-6  # picoseconds -> microseconds


@dataclass(frozen=True)
class CostModel:
    """Cascade cost model — Stage-0 prediction, Stage-1 engines and the
    Stage-2 LTR re-ranker.  Units are abstract "time units" fixed by the
    constructor used; the tail-latency study uses ``paper_scale`` (ms).

    Stage-0 is the fused three-predictor call (``predict_us`` covers all of
    k/ρ/t — the paper's < 0.75 ms budget).  Stage-2 is a fixed dispatch
    cost plus a per-candidate term: featurization is O(|q| · log df) gathers
    and GBRT inference O(trees · depth) per candidate, both linear in the
    candidate count the Stage-0 P_k prediction admits."""
    saat_fixed_us: float = 10.0
    saat_per_posting_us: float = 10.0 * PS
    daat_fixed_us: float = 20.0
    daat_per_posting_us: float = 12.2 * PS
    daat_per_block_us: float = 0.2
    predict_us: float = 0.75  # paper §5: <0.75 ms per prediction, scaled
    ltr_fixed_us: float = 5.0
    ltr_per_candidate_us: float = 0.04
    # scatter-gather: per-extra-shard fan-out/merge overhead.  A sharded
    # Stage-1 finishes at max-over-shards + this term — the tail is a max,
    # which is the paper's tail story at deployment scale.  0 keeps the
    # single-shard pipeline's accounting bit-identical.
    gather_per_shard_us: float = 0.0
    # result-cache lookup: key normalization + one dict probe, charged to
    # EVERY query when a ServingCache is attached (hits serve at
    # predict + this; misses pay it on top of the cascade), and added to
    # worst_case_us so the guarantee stays analytic with caching on.
    cache_hit_us: float = 0.5
    # dense Stage-1 modality: per-shard time is fixed dispatch + a term per
    # kernel grid tile — SHAPE-STATIC (every query scores every tile), so
    # the dense route's worst case is exact from the spec alone.  fusion_us
    # is the host-side list merge for both-routed queries; set_models
    # reserves it out of the scheduler's stage-1 budget so fused routes
    # stay inside the cascade bound.
    dense_fixed_us: float = 5.0
    dense_tile_us: float = 0.5
    fusion_us: float = 1.0

    @classmethod
    def v5e_shard(cls) -> "CostModel":
        """Roofline-derived per-chip constants (µs) for a production
        196k-doc / ~59M-posting shard — see module docstring."""
        return cls()

    @classmethod
    def paper_scale(cls) -> "CostModel":
        """Milliseconds on the experiment corpus. The synthetic collection is
        ~763× smaller than ClueWeb09B (65,536 vs 50M docs), so one synthetic
        posting stands in for ~763 real ones; constants are the v5e rates ×
        763 × 1e3(µs→ns floor), tuned so the *exhaustive* DAAT median lands
        near the paper's ~30–40 ms and tails cross 200 ms — making the
        paper's 200 ms budget directly meaningful."""
        return cls(saat_fixed_us=3.0, saat_per_posting_us=6.4e-3,
                   daat_fixed_us=4.0, daat_per_posting_us=7.6e-3,
                   daat_per_block_us=25e-3, predict_us=0.75,
                   ltr_fixed_us=1.0, ltr_per_candidate_us=15e-3,
                   cache_hit_us=0.05, dense_fixed_us=2.0,
                   dense_tile_us=0.05, fusion_us=0.5)

    def saat_time(self, work: np.ndarray) -> np.ndarray:
        return self.saat_fixed_us + work * self.saat_per_posting_us

    def daat_time(self, work: np.ndarray, blocks: np.ndarray) -> np.ndarray:
        return (self.daat_fixed_us + work * self.daat_per_posting_us
                + blocks * self.daat_per_block_us)

    def ltr_time(self, n_candidates: np.ndarray) -> np.ndarray:
        """Stage-2 re-ranking time from the per-query candidate count."""
        return (self.ltr_fixed_us
                + np.asarray(n_candidates, np.float64)
                * self.ltr_per_candidate_us)

    def dense_time(self, n_tiles) -> np.ndarray:
        """Per-shard dense Stage-1 time from the kernel grid tile count.
        Shape-static: a dense query's cost depends only on the shard's doc
        count and ``tile_d``, never on the query — which is what lets
        ``worst_case_us`` and the spec dry-run price dense routes exactly
        with no corpus statistics."""
        return (self.dense_fixed_us
                + np.asarray(n_tiles, np.float64) * self.dense_tile_us)

    def delta_time(self, n_postings: int) -> float:
        """Worst-case lexical delta-scan time for a capacity-``n_postings``
        live segment.  Shape-static: the charge is the segment's *capacity*,
        not its fill, so the bound never moves as documents stream in.  The
        delta pseudo-shard is scanned by whichever engine routes the query,
        so the charge takes the costlier per-posting rate plus the DAAT
        fixed cost (the larger of the two dispatch terms)."""
        return (self.daat_fixed_us
                + max(self.saat_per_posting_us, self.daat_per_posting_us)
                * float(n_postings))

    def gather_time(self, t_shards: np.ndarray) -> np.ndarray:
        """Scatter-gather Stage-1 time over an (n_shards, Q) per-shard time
        matrix: the query finishes when its *slowest* shard responds, plus
        the per-extra-shard fan-out/merge overhead."""
        t = np.asarray(t_shards, np.float64)
        return t.max(axis=0) + self.gather_per_shard_us * (t.shape[0] - 1)

    def regressed(self, *, work_saat=None, t_saat=None, work_daat=None,
                  blocks_daat=None, t_daat=None,
                  max_rel_err: float = 0.1) -> "CostModel":
        """Fold measured (work, latency) pairs back into the model.

        Least-squares fits ``t_saat ≈ f_s + w·c_s`` and
        ``t_daat ≈ f_d + w·c_d + b·c_b`` and returns a model whose
        constants are the *measured* rates, replacing the static roofline
        prior — the online half of the tail guarantee (see module
        docstring).  A fit is rejected (that term keeps its prior) when it
        produces non-positive rates or its median relative residual
        exceeds ``max_rel_err`` — a mis-instrumented trace must not relax
        the enforcement constants.
        """
        import dataclasses
        updates: dict = {}

        def _fit(a, y, names):
            sol, *_ = np.linalg.lstsq(a, y, rcond=None)
            pred = a @ sol
            rel = np.abs(pred - y) / np.maximum(np.abs(y), 1e-12)
            if np.any(sol <= 0) or float(np.median(rel)) > max_rel_err:
                return
            updates.update(zip(names, (float(s) for s in sol)))

        if t_saat is not None and work_saat is not None and len(t_saat) >= 2:
            w = np.asarray(work_saat, np.float64)
            _fit(np.stack([np.ones_like(w), w], axis=1),
                 np.asarray(t_saat, np.float64),
                 ("saat_fixed_us", "saat_per_posting_us"))
        if (t_daat is not None and work_daat is not None
                and blocks_daat is not None and len(t_daat) >= 3):
            w = np.asarray(work_daat, np.float64)
            b = np.asarray(blocks_daat, np.float64)
            _fit(np.stack([np.ones_like(w), w, b], axis=1),
                 np.asarray(t_daat, np.float64),
                 ("daat_fixed_us", "daat_per_posting_us",
                  "daat_per_block_us"))
        return dataclasses.replace(self, **updates) if updates else self


def budget_attribution(budget: float, cost: CostModel,
                       k_serve: int | None) -> dict:
    """Split a cascade budget into per-stage reserves (see *Guarantee
    accounting*): stage 0 gets the unconditional prediction cost, stage 2
    its deterministic worst case ``ltr_time(k_serve)`` (0 when Stage-2 is
    disabled — pass ``k_serve=None``), and stage 1 the remainder, which is
    the budget the scheduler's deadline re-route enforces.  The single
    source of truth for ``SearchSystem.set_models``, the spec dry-run, and
    ``bench_tail``."""
    reserve2 = (float(cost.ltr_time(np.asarray(k_serve)))
                if k_serve is not None else 0.0)
    return {"stage0": cost.predict_us,
            "stage1": max(budget - cost.predict_us - reserve2, 0.0),
            "stage2": reserve2}


def resolve_level_cut(totals: np.ndarray, rho) -> tuple[np.ndarray,
                                                        np.ndarray]:
    """(lstar, any_ok): the deepest global impact-level cut whose total
    work fits each row's ρ budget, over a (R, n_levels) cumulative-work
    table.  The single resolution policy shared by the serving system
    (``SearchSystem._jass_split``) and the spec dry-run
    (``launch.dryrun_cascade.WorkProxies``) — SAAT exactness across both
    depends on them agreeing."""
    ok = totals <= np.asarray(rho).reshape(-1, 1)
    return np.argmax(ok, axis=1), ok.any(axis=1)


def stage2_afford(cost: CostModel, remaining: np.ndarray,
                  k_serve: int) -> np.ndarray:
    """Largest per-query candidate count whose ``ltr_time`` fits in the
    remaining budget, in [0, k_serve].  0 means skip Stage-2 outright; the
    epsilon keeps an exactly-affordable ``k_serve`` from rounding down."""
    afford = np.floor((np.asarray(remaining, np.float64)
                       - cost.ltr_fixed_us)
                      / max(cost.ltr_per_candidate_us, 1e-12) + 1e-9)
    return np.clip(afford, 0, k_serve).astype(np.int64)


def percentiles(t: np.ndarray) -> dict:
    t = np.asarray(t)
    if t.size == 0:
        raise ValueError("percentiles() needs a non-empty latency array "
                         "(served batch was empty)")
    return {
        "mean": float(np.mean(t)),
        "p50": float(np.percentile(t, 50)),
        "p95": float(np.percentile(t, 95)),
        "p99": float(np.percentile(t, 99)),
        "p99.9": float(np.percentile(t, 99.9)),
        "p99.99": float(np.percentile(t, 99.99)),
        "max": float(np.max(t)),
    }


def over_budget(t: np.ndarray, budget_us: float) -> tuple[int, float]:
    """(count, percentage) of queries over budget; an empty batch has no
    violators (the seed raised ZeroDivisionError here)."""
    t = np.asarray(t)
    if t.size == 0:
        return 0, 0.0
    n = int(np.sum(t > budget_us))
    return n, 100.0 * n / t.size
