"""Deterministic fault injection for the serving cascade.

The paper's 99.99 % response-time regime is exactly where machine
failures — crashed replicas, stragglers, lost partitions — dominate the
tail, and its ISN architecture presumes replicas that can fail and be
routed around.  This module turns a :class:`~repro.serving.spec.FaultSpec`
schedule into per-request outcomes the serve path can consult:

* :meth:`FaultInjector.is_up` — is this replica reachable *now*?
  (crash windows + whole-partition outages, with ``-1`` wildcards);
* :meth:`FaultInjector.slowdown` — straggler multiplier on a successful
  response (1.0 outside any straggler window);
* :meth:`FaultInjector.transient` — one seeded per-request timeout draw
  inside the transient-storm window.

Everything is deterministic: the schedule is pure data, and transient
draws come from one seeded stream consumed in serve order — the same
``(CascadeSpec, TrafficSpec)`` pair replays bit-identically, which is what
lets ``benchmarks/bench_faults.py`` *certify* (not sample) the guarantee
under each scenario.  An inactive spec short-circuits every query at zero
cost and zero RNG draws, keeping fault-free serving bit-identical.

:func:`fault_scenario` names the canonical certification scenarios
(crash-one-replica, rolling restarts, stragglers, transient-timeout storm,
one-partition outage) sized to a deployment shape and trace horizon —
shared by the benchmark, the tests, and ``launch/serve.py
--fault-scenario``.
"""

from __future__ import annotations

import numpy as np

from repro.serving.spec import FaultSpec


def _matches(sel: int, value: int) -> bool:
    return sel == -1 or sel == value


class FaultInjector:
    """Per-request oracle over a :class:`FaultSpec` schedule."""

    def __init__(self, spec: FaultSpec, n_partitions: int):
        spec.validate()
        self.spec = spec
        self.n_partitions = n_partitions
        self.rng = np.random.RandomState(spec.seed)
        self.draws = 0           # transient draws consumed (determinism aid)

    @property
    def active(self) -> bool:
        return self.spec.active

    # ------------------------------------------------------------------
    def is_up(self, partition: int, replica_id: int, now: float) -> bool:
        """Whether a request to (partition, replica) at ``now`` can ever
        respond — False inside a crash window or a partition outage."""
        for p, t0, t1 in self.spec.outages:
            if _matches(p, partition) and t0 <= now < t1:
                return False
        for p, r, t0, t1 in self.spec.crashes:
            if (_matches(p, partition) and _matches(r, replica_id)
                    and t0 <= now < t1):
                return False
        return True

    def partition_up(self, partition: int, n_replicas: int,
                     now: float) -> bool:
        """Whether the partition has any replica the schedule leaves up —
        the ground truth behind the ``coverage >= surviving / total``
        certification."""
        return any(self.is_up(partition, r, now) for r in range(n_replicas))

    def surviving(self, n_replicas: int, now: float) -> int:
        """How many partitions the schedule leaves reachable at ``now``."""
        return sum(self.partition_up(p, n_replicas, now)
                   for p in range(self.n_partitions))

    def slowdown(self, partition: int, replica_id: int, now: float) -> float:
        """Straggler multiplier on a successful response (>= 1.0;
        overlapping windows take the worst one)."""
        m = 1.0
        for p, r, t0, t1, s in self.spec.stragglers:
            if (_matches(p, partition) and _matches(r, replica_id)
                    and t0 <= now < t1):
                m = max(m, float(s))
        return m

    def transient(self, now: float) -> bool:
        """One seeded per-request transient-timeout draw.  Draws happen
        only inside the storm window, in serve order, so a fixed seed
        replays bit-identically."""
        sp = self.spec
        if sp.timeout_p <= 0 or not (sp.timeout_start <= now
                                     < sp.timeout_end):
            return False
        self.draws += 1
        return bool(self.rng.rand() < sp.timeout_p)

    def export_metrics(self, reg) -> None:
        """Mirror the schedule shape + draw count into a telemetry
        registry (outcome counters live in SearchSystem._fault_counters)."""
        reg.gauge("fault_schedule_active").set(1.0 if self.active else 0.0)
        reg.gauge("fault_schedule", kind="crashes").set(
            len(self.spec.crashes))
        reg.gauge("fault_schedule", kind="stragglers").set(
            len(self.spec.stragglers))
        reg.gauge("fault_schedule", kind="outages").set(
            len(self.spec.outages))
        reg.counter("fault_transient_draws").set_total(self.draws)


# ---------------------------------------------------------------------------
# canonical certification scenarios
# ---------------------------------------------------------------------------

SCENARIOS = ("none", "crash_one", "rolling_restart", "stragglers",
             "timeout_storm", "partition_outage")


def fault_scenario(name: str, *, n_partitions: int, replicas: int,
                   horizon: float, seed: int = 0) -> FaultSpec:
    """The named certification scenario, sized to a deployment shape and a
    trace of ``horizon`` time units.

    ============== ======================================================
    none           empty schedule (the bit-identical control)
    crash_one      one replica of partition 0 crashes at 10 % of the
                   horizon and never recovers — failover must keep full
                   coverage
    rolling_restart each partition's replica 0 goes down for a staggered
                   window and comes back — the probe/recovery path
    stragglers     ~10 % of replicas run 8x slow for the whole trace —
                   the hedging/enforcement path
    timeout_storm  5 % transient per-request timeouts over the middle
                   half of the trace — the bounded-retry path
    partition_outage the last partition loses every replica for the
                   middle half — the partial-coverage path
    ============== ======================================================
    """
    if name == "none":
        return FaultSpec()
    if name == "crash_one":
        return FaultSpec(crashes=((0, replicas - 1, 0.1 * horizon,
                                   float("inf")),), seed=seed)
    if name == "rolling_restart":
        w = horizon / max(2 * n_partitions, 1)
        return FaultSpec(crashes=tuple(
            (p, 0, 0.1 * horizon + 2 * p * w, 0.1 * horizon + (2 * p + 1) * w)
            for p in range(n_partitions)), seed=seed)
    if name == "stragglers":
        total = n_partitions * replicas
        n_slow = max(int(round(0.1 * total)), 1)
        slow = []
        for j in range(n_slow):
            # spread the slow replicas across partitions
            p = j % n_partitions
            r = (j // n_partitions) % replicas
            slow.append((p, r, 0.0, float("inf"), 8.0))
        return FaultSpec(stragglers=tuple(slow), seed=seed)
    if name == "timeout_storm":
        return FaultSpec(timeout_p=0.05, timeout_start=0.25 * horizon,
                         timeout_end=0.75 * horizon, seed=seed)
    if name == "partition_outage":
        return FaultSpec(outages=((n_partitions - 1, 0.25 * horizon,
                                   0.75 * horizon),), seed=seed)
    raise ValueError(f"unknown fault scenario {name!r}; "
                     f"available: {SCENARIOS}")
