"""Host-side (numpy) reference engines for the two index traversal families.

These are the *oracles*: batched, exact implementations of

  * exhaustive BM25 scoring (rank-safe DAAT ground truth),
  * BMW-style block-max pruned scoring with aggression θ (two-phase:
    threshold bootstrap from the best blocks, then block-level pruning) and
    its work model (postings scored in surviving blocks),
  * JASS-style impact-ordered anytime scoring with postings budget ρ,
  * the "ideal" final-stage ranker (BM25 + latent topical affinity) that
    provides the reference lists for MED training labels.

They process the full 31k-query trace in seconds via bincount accumulators.
The JAX serving engines (`repro.isn.saat` / `repro.isn.daat`) and the Pallas
kernels are validated against these in tests.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.index.builder import InvertedIndex


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _query_postings(index: InvertedIndex, terms_row, mask_row, impact_ordered,
                    prefix=None):
    """Concatenate postings slices for one query's terms (ragged, no pad).

    Returns (docs, weights, qterm_local_idx) arrays.
    """
    docs_src = index.docs_imp if impact_ordered else index.docs
    w_src = (index.imp_sorted if impact_ordered else index.bm25_score)
    segs_d, segs_w = [], []
    for j, t in enumerate(terms_row):
        if mask_row[j] <= 0:
            continue
        lo, hi = index.offsets[t], index.offsets[t + 1]
        if prefix is not None:
            hi = lo + min(prefix[j], hi - lo)
        segs_d.append(docs_src[lo:hi])
        segs_w.append(w_src[lo:hi])
    if not segs_d:
        return (np.zeros(0, np.int64), np.zeros(0, np.float32))
    return (np.concatenate(segs_d).astype(np.int64),
            np.concatenate(segs_w).astype(np.float32))


def _batch_accumulate(index, terms, mask, rows, impact_ordered=False,
                      prefixes=None):
    """Accumulate scores for a batch of queries into a (B, N) matrix."""
    n = index.n_docs
    b = len(rows)
    keys, vals = [], []
    for i, q in enumerate(rows):
        pref = None if prefixes is None else prefixes[i]
        d, w = _query_postings(index, terms[q], mask[q], impact_ordered, pref)
        keys.append(d + i * n)
        vals.append(w)
    keys = np.concatenate(keys)
    vals = np.concatenate(vals)
    acc = np.bincount(keys, weights=vals, minlength=b * n)
    return acc.reshape(b, n), int(keys.shape[0])


def _topk_ids(acc: np.ndarray, k: int):
    """Row-wise top-k (ids desc by score). acc: (B, N)."""
    k = min(k, acc.shape[1])
    part = np.argpartition(-acc, k - 1, axis=1)[:, :k]
    ps = np.take_along_axis(acc, part, axis=1)
    order = np.argsort(-ps, axis=1, kind="stable")
    return np.take_along_axis(part, order, axis=1), np.take_along_axis(ps, order, axis=1)


# ---------------------------------------------------------------------------
# engines
# ---------------------------------------------------------------------------

def exhaustive_scores(index, terms, mask, rows):
    acc, work = _batch_accumulate(index, terms, mask, rows)
    return acc, work


def jass_scores(index, terms, mask, rows, rho):
    """Impact-ordered anytime scoring: process whole impact segments, highest
    impact first, while the postings budget allows (JASS semantics).
    ``rho`` may be a scalar or a per-query array aligned with ``rows``."""
    rho_arr = np.broadcast_to(np.asarray(rho), (len(rows),))
    prefixes, work_per_q = [], []
    for i, q in enumerate(rows):
        t = terms[q][mask[q] > 0]
        lc = index.level_cum[t]                   # (L, 256), count with imp >= l
        total = lc.sum(axis=0)                    # (256,) descending in l... (increasing as l->0)
        # most inclusive level with total postings <= rho
        ok = total <= rho_arr[i]
        lstar = int(np.argmax(ok)) if ok.any() else 256   # levels are 0..255
        if lstar >= 256:
            pref = np.zeros(len(t), np.int64)
        else:
            pref = lc[:, lstar].astype(np.int64)
        prefixes.append(pref)
        work_per_q.append(int(pref.sum()))
    acc, _ = _batch_accumulate(index, terms, mask, rows, impact_ordered=True,
                               prefixes=prefixes)
    return acc, np.asarray(work_per_q)


def jass_work_only(index, terms, mask, rho) -> np.ndarray:
    """Vectorized postings-work for JASS at per-query budgets (no scoring).

    Used for the latency model: JASS cost is a pure function of the level
    cut, so the whole 31k-query trace resolves in one gather."""
    q = terms.shape[0]
    rho_arr = np.broadcast_to(np.asarray(rho), (q,))
    lc = index.level_cum[terms] * (mask > 0)[:, :, None]    # (Q, L, 256)
    total = lc.sum(axis=1)                                  # (Q, 256)
    ok = total <= rho_arr[:, None]
    lstar = np.argmax(ok, axis=1)
    any_ok = ok.any(axis=1)
    work = total[np.arange(q), lstar]
    return np.where(any_ok, work, 0).astype(np.int64)


def bmw_scores(index, terms, mask, rows, k, theta: float = 1.0):
    """Block-max pruned scoring (two-phase TPU-style formulation).

    Phase 1: score the blocks with the largest summed block upper bounds
    (enough blocks to cover k docs) -> valid lower-bound threshold τ.
    Phase 2: score every block whose upper bound exceeds θ·τ.
    θ = 1.0 is rank-safe; θ > 1.0 trades effectiveness for fewer blocks.
    Returns (scores (B,N), work postings, surviving blocks per query).
    """
    n, bs, nb = index.n_docs, index.block_size, index.n_blocks
    scale = index.quant_scale / 255.0
    k_arr = np.broadcast_to(np.asarray(k), (len(rows),))

    accs, works, blocks_touched = [], [], []
    for qi, q in enumerate(rows):
        k = int(k_arr[qi])
        t = terms[q][mask[q] > 0]
        ub = index.block_max[t].astype(np.float32).sum(axis=0) * scale  # (nb,)
        cnt = index.block_count[t].astype(np.int64)                     # (L, nb)
        # phase 1: walk blocks in descending upper-bound order until the
        # heap can plausibly be full (>= 2k candidate docs seen), so τ is a
        # genuine k-th-best lower bound rather than 0
        order = np.argsort(-ub, kind="stable")
        cand_docs = np.minimum(cnt.sum(axis=0), bs)[order]
        need = int(np.searchsorted(np.cumsum(cand_docs), 2 * k)) + 1
        phase1 = order[:min(max(need, 4), nb)]
        in_p1 = np.zeros(nb, bool)
        in_p1[phase1] = True

        d, w = _query_postings(index, terms[q], mask[q], False)
        blk = d // bs
        acc1 = np.bincount(d, weights=np.where(in_p1[blk], w, 0.0), minlength=n)
        kk = min(k, n)
        tau = np.partition(acc1, n - kk)[n - kk]

        survive = (ub > theta * tau) | in_p1
        acc = np.bincount(d, weights=np.where(survive[blk], w, 0.0), minlength=n)
        works.append(int(cnt[:, survive].sum()))
        blocks_touched.append(int(survive.sum()))
        accs.append(acc)
    return np.stack(accs), np.asarray(works), np.asarray(blocks_touched)


def ideal_rerank(index, corpus, terms, mask, topics, rows, acc, depth: int,
                 rerank_depth: int = 1024, gamma: float = 6.0):
    """The idealized last-stage run: re-rank BM25 top candidates by BM25 +
    latent topical affinity. Returns (B, depth) reference doc ids."""
    ids, sc = _topk_ids(acc, rerank_depth)
    out = np.zeros((len(rows), depth), np.int64)
    for i, q in enumerate(rows):
        aff = corpus.doc_topics[ids[i], topics[q]]
        final = sc[i] + gamma * aff * np.maximum(sc[i].max(), 1.0) / 10.0
        order = np.argsort(-final, kind="stable")[:depth]
        out[i] = ids[i][order]
    return out


def ranks_of(acc: np.ndarray, ref_ids: np.ndarray, max_rank: int):
    """Stage-1 rank of each reference doc (capped); (B, depth) int32."""
    b, n = acc.shape
    kk = min(max_rank, n)
    top_ids, top_sc = _topk_ids(acc, kk)
    out = np.full(ref_ids.shape, 1 << 30, np.int64)
    for i in range(b):
        pos = np.full(n, 1 << 30, np.int64)
        pos[top_ids[i]] = np.arange(kk)
        out[i] = pos[ref_ids[i]]
    return out
