"""DAAT (BMW-style) block-max engine — JAX serving path.

TPU-native adaptation of Block-Max WAND: per-block upper bounds are
accumulated from the sparse block-max structure, a phase-1 pass over the
highest-bound blocks bootstraps a rank-safe threshold τ, and the exact pass
scores only blocks with ``ub > θ·τ``.  θ = 1.0 is rank-safe; θ > 1.0 is the
paper's aggression parameter.

On TPU the exact pass lowers to `repro.kernels.blockmax_score` where pruned
blocks are *skipped via predication* (`pl.when`), so latency is proportional
to surviving work — which is precisely why DAAT keeps its data-dependent
tail (the paper's Fig. 3) while budgeted SAAT does not.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.index.postings import IndexShard


class DaatResult(NamedTuple):
    topk_docs: jnp.ndarray     # (Q, k)
    topk_scores: jnp.ndarray   # (Q, k) exact BM25
    work: jnp.ndarray          # (Q,) postings in surviving blocks
    blocks: jnp.ndarray        # (Q,) surviving blocks


def _block_bounds(shard: IndexShard, terms, mask, n_blocks: int, bcap: int):
    """Accumulate per-block upper bounds and candidate counts for a query."""
    base = shard.bm_offsets[terms]
    cnt = shard.bm_offsets[terms + 1] - base
    pos = base[:, None] + jnp.arange(bcap, dtype=jnp.int32)[None, :]
    live = (jnp.arange(bcap, dtype=jnp.int32)[None, :] < cnt[:, None]) \
        & (mask[:, None] > 0)
    pos = jnp.minimum(pos, shard.bm_block_id.shape[0] - 1)
    bid = jnp.where(live, shard.bm_block_id[pos], 0)
    bmax = jnp.where(live, shard.bm_block_max[pos], 0.0)
    bcnt = jnp.where(live, shard.bm_block_cnt[pos], 0)
    ub = jnp.zeros((n_blocks,), jnp.float32).at[bid.reshape(-1)].add(bmax.reshape(-1))
    ccnt = jnp.zeros((n_blocks,), jnp.int32).at[bid.reshape(-1)].add(bcnt.reshape(-1))
    return ub, ccnt


def _masked_score(shard: IndexShard, terms, mask, survive, n_docs: int,
                  block_size: int, cap: int):
    """Exact scoring of postings whose doc block survives pruning."""
    base = shard.offsets[terms]
    df = shard.offsets[terms + 1] - base
    pos = base[:, None] + jnp.arange(cap, dtype=jnp.int32)[None, :]
    live = (jnp.arange(cap, dtype=jnp.int32)[None, :] < df[:, None]) \
        & (mask[:, None] > 0)
    pos = jnp.minimum(pos, shard.docs.shape[0] - 1)
    d = jnp.where(live, shard.docs[pos], 0)
    s = jnp.where(live, shard.score[pos], 0.0)
    keep = survive[d // block_size] & live
    s = jnp.where(keep, s, 0.0)
    d = jnp.where(keep, d, 0)
    acc = jnp.zeros((n_docs,), jnp.float32).at[d.reshape(-1)].add(s.reshape(-1))
    return acc


@functools.partial(jax.jit,
                   static_argnames=("n_docs", "n_blocks", "block_size", "k",
                                    "cap", "bcap"))
def daat_serve(shard: IndexShard, terms: jnp.ndarray, mask: jnp.ndarray,
               theta: jnp.ndarray, *, n_docs: int, n_blocks: int,
               block_size: int, k: int, cap: int, bcap: int) -> DaatResult:
    """Serve a batch of queries with block-max pruned DAAT.

    cap: static per-term postings bound (max df in shard).
    bcap: static per-term block-entry bound.
    """
    def one(terms_q, mask_q, theta_q):
        ub, ccnt = _block_bounds(shard, terms_q, mask_q, n_blocks, bcap)
        # phase 1: highest-bound blocks until >= 2k candidate docs
        cand = jnp.minimum(ccnt, block_size)
        order = jnp.argsort(-ub)
        cum = jnp.cumsum(cand[order])
        need = jnp.minimum(jnp.searchsorted(cum, 2 * k) + 1, n_blocks)
        rank = jnp.zeros((n_blocks,), jnp.int32).at[order].set(
            jnp.arange(n_blocks, dtype=jnp.int32))
        in_p1 = rank < need
        acc1 = _masked_score(shard, terms_q, mask_q, in_p1, n_docs,
                             block_size, cap)
        tau = jax.lax.top_k(acc1, k)[0][k - 1]
        survive = (ub >= theta_q * tau) | in_p1
        acc = _masked_score(shard, terms_q, mask_q, survive, n_docs,
                            block_size, cap)
        sc, ids = jax.lax.top_k(acc, k)
        work = jnp.sum(jnp.where(survive, ccnt, 0))
        return ids.astype(jnp.int32), sc, work, jnp.sum(survive.astype(jnp.int32))

    ids, sc, work, blocks = jax.lax.map(lambda args: one(*args),
                                        (terms, mask, theta))
    return DaatResult(ids, sc, work, blocks)
