"""DAAT (BMW-style) block-max engine — batched JAX serving path.

TPU-native adaptation of Block-Max WAND: per-block upper bounds are
accumulated from the sparse block-max structure, a phase-1 pass over the
highest-bound blocks bootstraps a rank-safe threshold τ, and the exact pass
scores only blocks with ``ub > θ·τ``.  θ = 1.0 is rank-safe; θ > 1.0 is the
paper's aggression parameter.

Serving pipeline (``daat_serve``)
---------------------------------
Queries are served as a batch, not one at a time: block bounds and the
phase-1 selection are vmapped, and the scoring hot loop dispatches through
a backend switch (see ``repro.isn.backend``):

* ``"pallas"`` / ``"interpret"`` — the exact pass runs on
  ``repro.kernels.blockmax_score`` over the shard's **build-time bucketed
  postings mirror** (``IndexShard.tile_*``): a (Q, n_tiles) grid where each
  step term-matches one doc-tile bucket against one query and reduces with
  a one-hot MXU matmul.  Pruned tiles are *skipped via predication*
  (``pl.when``), so latency is proportional to surviving work — which is
  precisely why DAAT keeps its data-dependent tail (the paper's Fig. 3)
  while budgeted SAAT does not.  ``interpret=True`` runs the identical
  kernel program under the Pallas interpreter on CPU (tests).
* ``"jnp"`` — vectorized batched gather + one fused scatter over the CSR
  mirror; identical results, the portable fast path on CPU hosts.

Exactly **one exact-scoring pass** runs per query: the phase-1 accumulator
is kept and the exact pass only scores blocks in ``survive \\ phase1``
(the two block sets are disjoint by construction), so no posting is ever
scored twice.  The jnp backend additionally compacts the ragged per-term
posting ranges into a (Q, qcap) lane buffer before its fused scatter, so
scatter traffic tracks the batch's actual postings rather than L·max_df
padding.  On the kernel backends top-k is the tiled hierarchical merge
(per-tile top-k over the (Q, n_tiles, TILE_D) accumulator tiles, then a
merge over per-tile candidates) — per-query traffic is O(surviving tiles ·
TILE_D), not O(n_docs); the dense jnp path keeps XLA's native batched
top-k, which is faster on CPU.

``daat_serve_laxmap`` preserves the original one-query-at-a-time
``lax.map`` + dense scatter-add reference; the parity tests and the
serving benchmark hold the batched pipeline to its output.

Caveats vs the reference: the kernel backends score *all* postings of a
matched term (the bucketed mirror has no per-term gather cap), so they
coincide with the reference only when ``cap >= max_df`` — which is how the
servers call it; duplicate query terms score once in the kernel backends
(term membership) but once per occurrence in the gather paths — query
builders emit unique terms.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.index.postings import IndexShard
from repro.isn.backend import (compact_lanes, map_query_blocks,
                               resolve_backend, topk_from_tiles)
from repro.kernels.blockmax_score.ops import blockmax_score_tiles


class DaatResult(NamedTuple):
    topk_docs: jnp.ndarray     # (Q, k)
    topk_scores: jnp.ndarray   # (Q, k) exact BM25
    work: jnp.ndarray          # (Q,) postings in surviving blocks
    blocks: jnp.ndarray        # (Q,) surviving blocks


def _block_bounds(shard: IndexShard, terms, mask, n_blocks: int, bcap: int):
    """Accumulate per-block upper bounds and candidate counts for a query."""
    base = shard.bm_offsets[terms]
    cnt = shard.bm_offsets[terms + 1] - base
    pos = base[:, None] + jnp.arange(bcap, dtype=jnp.int32)[None, :]
    live = (jnp.arange(bcap, dtype=jnp.int32)[None, :] < cnt[:, None]) \
        & (mask[:, None] > 0)
    pos = jnp.minimum(pos, shard.bm_block_id.shape[0] - 1)
    bid = jnp.where(live, shard.bm_block_id[pos], 0)
    bmax = jnp.where(live, shard.bm_block_max[pos], 0.0)
    bcnt = jnp.where(live, shard.bm_block_cnt[pos], 0)
    ub = jnp.zeros((n_blocks,), jnp.float32).at[bid.reshape(-1)].add(bmax.reshape(-1))
    ccnt = jnp.zeros((n_blocks,), jnp.int32).at[bid.reshape(-1)].add(bcnt.reshape(-1))
    return ub, ccnt


def _masked_score(shard: IndexShard, terms, mask, survive, n_docs: int,
                  block_size: int, cap: int):
    """Exact scoring of postings whose doc block survives pruning."""
    base = shard.offsets[terms]
    df = shard.offsets[terms + 1] - base
    pos = base[:, None] + jnp.arange(cap, dtype=jnp.int32)[None, :]
    live = (jnp.arange(cap, dtype=jnp.int32)[None, :] < df[:, None]) \
        & (mask[:, None] > 0)
    pos = jnp.minimum(pos, shard.docs.shape[0] - 1)
    d = jnp.where(live, shard.docs[pos], 0)
    s = jnp.where(live, shard.score[pos], 0.0)
    keep = survive[d // block_size] & live
    s = jnp.where(keep, s, 0.0)
    d = jnp.where(keep, d, 0)
    acc = jnp.zeros((n_docs,), jnp.float32).at[d.reshape(-1)].add(s.reshape(-1))
    return acc


# ---------------------------------------------------------------------------
# batched pipeline
# ---------------------------------------------------------------------------

def _block_bounds_batched(shard: IndexShard, terms, mask, n_blocks: int,
                          bcap: int):
    """Batched block bounds: one flat scatter over the whole batch's block
    entries instead of a vmapped per-query scatter."""
    q = terms.shape[0]
    base = shard.bm_offsets[terms]                           # (Q, L)
    cnt = shard.bm_offsets[terms + 1] - base
    lanes = jnp.arange(bcap, dtype=jnp.int32)
    pos = base[..., None] + lanes[None, None, :]
    live = (lanes[None, None, :] < cnt[..., None]) & (mask[..., None] > 0)
    pos = jnp.minimum(pos, shard.bm_block_id.shape[0] - 1)
    bid = jnp.where(live, shard.bm_block_id[pos], 0)
    bmax = jnp.where(live, shard.bm_block_max[pos], 0.0)
    bcnt = jnp.where(live, shard.bm_block_cnt[pos], 0)
    flat = (jnp.arange(q, dtype=jnp.int32)[:, None, None] * n_blocks
            + bid).reshape(-1)
    ub = jnp.zeros((q * n_blocks,), jnp.float32).at[flat].add(
        bmax.reshape(-1)).reshape(q, n_blocks)
    ccnt = jnp.zeros((q * n_blocks,), jnp.int32).at[flat].add(
        bcnt.reshape(-1)).reshape(q, n_blocks)
    return ub, ccnt


def _phase1_blocks(ub, ccnt, block_size: int, k: int, n_blocks: int):
    """Rank the blocks by upper bound and keep the highest-bound prefix
    holding >= 2k candidate docs — the threshold-bootstrapping phase-1 set."""
    q = ub.shape[0]
    cand = jnp.minimum(ccnt, block_size)
    order = jnp.argsort(-ub, axis=1)
    cum = jnp.cumsum(jnp.take_along_axis(cand, order, axis=1), axis=1)
    need = jnp.minimum(
        jax.vmap(lambda c: jnp.searchsorted(c, 2 * k))(cum) + 1, n_blocks)
    rank = jnp.zeros((q, n_blocks), jnp.int32).at[
        jnp.arange(q, dtype=jnp.int32)[:, None], order].set(
        jnp.broadcast_to(jnp.arange(n_blocks, dtype=jnp.int32), (q, n_blocks)))
    return rank < need[:, None]


def _gather_compact_postings(shard: IndexShard, terms, mask, cap: int,
                             qcap: int):
    """Compact the batch's ragged per-term posting ranges into (Q, qcap)
    dense lanes and gather (doc, score) once — both scoring passes reuse
    this layout, so no posting is gathered (or scored) twice."""
    base = shard.offsets[terms]                              # (Q, L)
    df = shard.offsets[terms + 1] - base
    dfs = jnp.minimum(df, cap) * (mask > 0)
    pos, live = compact_lanes(base, dfs.astype(jnp.int32), qcap)
    pos = jnp.minimum(pos, shard.docs.shape[0] - 1)
    d = jnp.where(live, shard.docs[pos], 0)
    s = jnp.where(live, shard.score[pos], 0.0)
    return d, s, live


def _score_pass(d, s, live, survive, n_docs: int, block_size: int):
    """One masked scoring pass over the compacted lanes: mask lanes whose
    block is pruned, then one fused flat scatter into the (Q, n_docs)
    accumulator — scatter traffic tracks the batch's actual postings, not
    L·max_df padding."""
    q = d.shape[0]
    keep = jnp.take_along_axis(survive, d // block_size, axis=1) & live
    s = jnp.where(keep, s, 0.0)
    d = jnp.where(keep, d, 0)
    flat = (jnp.arange(q, dtype=jnp.int32)[:, None] * n_docs + d).reshape(-1)
    return jnp.zeros((q * n_docs,), jnp.float32).at[flat].add(
        s.reshape(-1)).reshape(q, n_docs)


def _kth_score(topk_out, k: int):
    """Extract the k-th top score behind an optimization barrier: without
    it, XLA CPU sees only one top-k column consumed and re-lowers the fast
    TopK call into a full sort (~30x slower)."""
    vals, idxs = jax.lax.optimization_barrier(topk_out)
    return vals[:, k - 1]


def _daat_batched(shard: IndexShard, terms, mask, theta, *, n_docs: int,
                  n_blocks: int, block_size: int, k: int, cap: int,
                  bcap: int, qcap: int, tile_d: int, backend: str):
    ub, ccnt = _block_bounds_batched(shard, terms, mask, n_blocks, bcap)
    in_p1 = _phase1_blocks(ub, ccnt, block_size, k, n_blocks)

    if backend == "jnp":
        d, s, live = _gather_compact_postings(shard, terms, mask, cap, qcap)
        acc1 = _score_pass(d, s, live, in_p1, n_docs, block_size)
        tau = _kth_score(jax.lax.top_k(acc1, k), k)
        extra = (ub >= theta[:, None] * tau[:, None]) & ~in_p1
        acc = acc1 + _score_pass(d, s, live, extra, n_docs, block_size)
        sc, ids = jax.lax.top_k(acc, k)
    else:
        interpret = backend == "interpret"
        qterms = jnp.where(mask > 0, terms, -1).astype(jnp.int32)
        acc1_t = blockmax_score_tiles(
            shard.tile_docs, shard.tile_terms, shard.tile_scores, qterms,
            in_p1, tile_d=tile_d, block_size=block_size, n_blocks=n_blocks,
            interpret=interpret)
        tau = _kth_score(topk_from_tiles(acc1_t, k, n_docs=n_docs), k)
        extra = (ub >= theta[:, None] * tau[:, None]) & ~in_p1
        acc_t = acc1_t + blockmax_score_tiles(
            shard.tile_docs, shard.tile_terms, shard.tile_scores, qterms,
            extra, tile_d=tile_d, block_size=block_size, n_blocks=n_blocks,
            interpret=interpret)
        sc, ids = topk_from_tiles(acc_t, k, n_docs=n_docs)

    survive = in_p1 | extra
    work = jnp.sum(jnp.where(survive, ccnt, 0), axis=1)
    blocks = jnp.sum(survive.astype(jnp.int32), axis=1)
    return ids.astype(jnp.int32), sc, work, blocks


@functools.partial(jax.jit,
                   static_argnames=("n_docs", "n_blocks", "block_size", "k",
                                    "cap", "bcap", "qcap", "tile_d",
                                    "q_block", "backend"))
def daat_serve(shard: IndexShard, terms: jnp.ndarray, mask: jnp.ndarray,
               theta: jnp.ndarray, *, n_docs: int, n_blocks: int,
               block_size: int, k: int, cap: int, bcap: int,
               qcap: int | None = None, tile_d: int = 128, q_block: int = 64,
               backend: str | None = None) -> DaatResult:
    """Serve a batch of queries with block-max pruned DAAT.

    cap: static per-term postings bound (max df in shard).
    bcap: static per-term block-entry bound.
    qcap: static per-QUERY posting-lane budget for the jnp backend's
      compacted gather; must cover max_q Σ_t min(df_t, cap) over the batch
      (size it with ``repro.isn.backend.query_lane_budget``).  None falls
      back to the exact worst case L·cap.
    tile_d: docs per accumulator tile (must match the shard's bucketed
      mirror when a kernel backend runs).
    q_block: queries scored concurrently; larger batches stream through in
      q_block-sized chunks so accumulator memory stays O(q_block · n_docs).
    backend: "pallas" | "interpret" | "jnp" | None (auto: pallas on TPU,
      jnp elsewhere) — see ``repro.isn.backend``.
    """
    backend = resolve_backend(backend)
    if qcap is None:
        qcap = terms.shape[1] * cap
    qcap = min(qcap, terms.shape[1] * cap)
    fn = functools.partial(_daat_batched, shard, n_docs=n_docs,
                           n_blocks=n_blocks, block_size=block_size, k=k,
                           cap=cap, bcap=bcap, qcap=qcap, tile_d=tile_d,
                           backend=backend)
    out = map_query_blocks(fn, (terms, mask, theta), (0, 0.0, 1.0), q_block)
    return DaatResult(*out)


@functools.partial(jax.jit,
                   static_argnames=("n_docs", "n_blocks", "block_size", "k",
                                    "cap", "bcap"))
def daat_serve_laxmap(shard: IndexShard, terms: jnp.ndarray,
                      mask: jnp.ndarray, theta: jnp.ndarray, *, n_docs: int,
                      n_blocks: int, block_size: int, k: int, cap: int,
                      bcap: int) -> DaatResult:
    """One-query-at-a-time reference pipeline (`lax.map` + dense scatter-add
    + full-collection top-k).  Scores every surviving posting twice (phase-1
    rescan) — kept as the parity oracle and the benchmark baseline for the
    batched pipeline."""
    def one(terms_q, mask_q, theta_q):
        ub, ccnt = _block_bounds(shard, terms_q, mask_q, n_blocks, bcap)
        # phase 1: highest-bound blocks until >= 2k candidate docs
        cand = jnp.minimum(ccnt, block_size)
        order = jnp.argsort(-ub)
        cum = jnp.cumsum(cand[order])
        need = jnp.minimum(jnp.searchsorted(cum, 2 * k) + 1, n_blocks)
        rank = jnp.zeros((n_blocks,), jnp.int32).at[order].set(
            jnp.arange(n_blocks, dtype=jnp.int32))
        in_p1 = rank < need
        acc1 = _masked_score(shard, terms_q, mask_q, in_p1, n_docs,
                             block_size, cap)
        tau = jax.lax.top_k(acc1, k)[0][k - 1]
        survive = (ub >= theta_q * tau) | in_p1
        acc = _masked_score(shard, terms_q, mask_q, survive, n_docs,
                            block_size, cap)
        sc, ids = jax.lax.top_k(acc, k)
        work = jnp.sum(jnp.where(survive, ccnt, 0))
        return ids.astype(jnp.int32), sc, work, jnp.sum(survive.astype(jnp.int32))

    ids, sc, work, blocks = jax.lax.map(lambda args: one(*args),
                                        (terms, mask, theta))
    return DaatResult(ids, sc, work, blocks)


def daat_serve_segments(segments, terms, mask, theta, *, k, qcaps=None,
                        tile_d: int = 128, q_block: int = 64,
                        backend: str | None = None, drop=None):
    """Serve one batch over sealed + delta segments and merge the top-k.

    ``segments`` is a list of ``(shard, spec, doc_lo)`` in ascending
    global-doc order — sealed shards first, then (optionally) the live
    delta pseudo-shard, whose ``doc_lo`` is the sealed collection size.
    Each segment is scanned with its own static caps (a delta segment's
    capacity padding is inert: padded lanes sit past every term's df and
    are never gathered), and the candidates merge through
    ``merge_shard_topk``'s lower-global-doc-id tie policy.

    ``qcaps[i]``/``drop[i]`` (optional) are per-segment; ``drop`` rows
    follow segment order. Returns ``(ids, scores, works, blocks)``: the
    merged (Q, k) global result plus per-segment work/block counters.
    """
    from repro.isn.backend import merge_shard_topk

    sc_list, id_list, works, blocks = [], [], [], []
    for i, (shard, spec, doc_lo) in enumerate(segments):
        r = daat_serve(shard, terms, mask, theta, n_docs=spec.n_docs,
                       n_blocks=spec.n_blocks, block_size=spec.block_size,
                       k=k, cap=spec.max_df, bcap=spec.max_blocks_per_term,
                       qcap=None if qcaps is None else qcaps[i],
                       tile_d=tile_d, q_block=q_block, backend=backend)
        sc_list.append(r.topk_scores)
        id_list.append(r.topk_docs + doc_lo)
        works.append(r.work)
        blocks.append(r.blocks)
    if len(segments) == 1 and drop is None:
        return id_list[0], sc_list[0], works, blocks
    ids, sc = merge_shard_topk(sc_list, id_list, k, drop=drop)
    return ids, sc, works, blocks
