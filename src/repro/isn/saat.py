"""SAAT (JASS-style) anytime engine — JAX serving path.

Score-at-a-time traversal over the impact-ordered mirror.  The ρ budget is
resolved to per-term postings prefixes via the per-level cumulative counts
(JASS processes whole impact segments, highest impact first, while the
budget allows), then the prefixes are gathered and scatter-accumulated.

Cost is a deterministic function of ρ — on TPU the accumulate kernel's grid
is sized by ⌈ρ/Tile⌉, so the 200 ms worst-case guarantee is *structural*:
the compiled program cannot touch more than ρ_max postings.

The hot accumulation loop lowers to `repro.kernels.impact_accumulate` on
TPU; the jnp path below is the portable reference used on CPU and in tests.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.index.postings import IndexShard


class SaatResult(NamedTuple):
    topk_docs: jnp.ndarray     # (Q, k) local doc ids
    topk_scores: jnp.ndarray   # (Q, k) quantized-impact scores
    work: jnp.ndarray          # (Q,) postings actually scored


def _level_cut(shard: IndexShard, terms, mask, rho):
    """Most inclusive impact level whose total postings fit the budget,
    and the resulting per-term prefix lengths."""
    lc = shard.level_cum[terms] * mask[:, None].astype(jnp.int32)  # (L, 256)
    total = jnp.sum(lc, axis=0)                                    # (256,)
    ok = total <= rho
    # `total` is non-increasing in level index; first ok level = cut
    lstar = jnp.argmax(ok)
    any_ok = jnp.any(ok)
    prefix = jnp.where(any_ok, lc[:, lstar], 0)
    return prefix, jnp.where(any_ok, total[lstar], 0)


def _accumulate(shard: IndexShard, terms, prefix, n_docs: int, cap: int):
    """Gather per-term impact-ordered prefixes and scatter-add into a dense
    accumulator (the jnp oracle of the Pallas scatter-as-matmul kernel)."""
    base = shard.offsets[terms]                                   # (L,)
    pos = base[:, None] + jnp.arange(cap, dtype=jnp.int32)[None, :]
    live = jnp.arange(cap, dtype=jnp.int32)[None, :] < prefix[:, None]
    pos = jnp.minimum(pos, shard.docs_imp.shape[0] - 1)
    d = shard.docs_imp[pos]
    v = shard.imp[pos] * live.astype(jnp.int32)
    # dead lanes scatter 0 into doc 0 — harmless
    d = jnp.where(live, d, 0)
    acc = jnp.zeros((n_docs,), jnp.int32).at[d.reshape(-1)].add(v.reshape(-1))
    return acc


@functools.partial(jax.jit, static_argnames=("n_docs", "k", "cap"))
def saat_serve(shard: IndexShard, terms: jnp.ndarray, mask: jnp.ndarray,
               rho: jnp.ndarray, *, n_docs: int, k: int,
               cap: int) -> SaatResult:
    """Serve a batch of queries on one ISN shard.

    Args:
      terms: (Q, L) padded query term ids.
      mask: (Q, L) query term mask.
      rho: (Q,) per-query postings budgets (already capped at ρ_max by the
        Stage-0 scheduler; `cap` is the static ρ_max bound that sizes the
        gather, so the compiled cost is O(Q · L · cap)).
      n_docs / k / cap: static shard size, retrieval depth, per-term prefix cap.
    """
    def one(terms_q, mask_q, rho_q):
        prefix, work = _level_cut(shard, terms_q, mask_q, rho_q)
        prefix = jnp.minimum(prefix, cap)
        acc = _accumulate(shard, terms_q, prefix, n_docs, cap)
        sc, ids = jax.lax.top_k(acc, k)
        return ids.astype(jnp.int32), sc.astype(jnp.float32), work

    ids, sc, work = jax.lax.map(one_fn := lambda args: one(*args),
                                (terms, mask, rho))
    return SaatResult(ids, sc, work)
