"""SAAT (JASS-style) anytime engine — batched JAX serving path.

Score-at-a-time traversal over the impact-ordered mirror.  The ρ budget is
resolved to a per-query impact-level cut ``lstar`` (JASS processes whole
impact segments, highest impact first, while the budget allows); every
posting whose impact reaches the cut contributes to the accumulator.

Cost is a deterministic function of ρ — the compiled program cannot touch
more than ρ_max postings (gather paths) or more than the shard's bucketed
mirror (kernel paths, whose grid is fixed by the layout), so the 200 ms
worst-case guarantee is *structural*.

Serving pipeline (``saat_serve``)
---------------------------------
Queries are served as a batch through a backend switch
(see ``repro.isn.backend``):

* ``"pallas"`` / ``"interpret"`` — the accumulation dispatches through
  ``repro.kernels.impact_accumulate`` over the shard's build-time bucketed
  postings mirror (``IndexShard.tile_*``): a (Q, n_tiles) grid, one doc
  tile per step, term matching in-register, one-hot MXU matmul reduction.
  The level cut rides in as the per-query scalar ``lstar``.
  ``interpret=True`` runs the identical kernel program on CPU (tests).
* ``"jnp"`` — vectorized batched gather of the per-term impact-ordered
  prefixes plus one fused scatter; identical results on any host.

Top-k is the tiled hierarchical merge from ``repro.isn.backend`` rather
than a full-collection ``lax.top_k``.  ``saat_serve_laxmap`` preserves the
original one-query-at-a-time pipeline as parity oracle and benchmark
baseline.  Accumulation is integer, so all backends agree bit-exactly.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.index.postings import IndexShard
from repro.isn.backend import (compact_lanes, map_query_blocks,
                               resolve_backend, topk_from_tiles)
from repro.kernels.impact_accumulate.ops import impact_accumulate_tiles


class SaatResult(NamedTuple):
    topk_docs: jnp.ndarray     # (Q, k) local doc ids
    topk_scores: jnp.ndarray   # (Q, k) quantized-impact scores
    work: jnp.ndarray          # (Q,) postings actually scored


def _level_cut(shard: IndexShard, terms, mask, rho):
    """Most inclusive impact level whose total postings fit the budget.

    Returns (per-term prefix lengths, total postings, the level cut itself).
    The cut is ``n_levels`` (excluding everything) when even the sparsest
    level blows the budget."""
    lc = shard.level_cum[terms] * mask[:, None].astype(jnp.int32)  # (L, 256)
    total = jnp.sum(lc, axis=0)                                    # (256,)
    ok = total <= rho
    # `total` is non-increasing in level index; first ok level = cut
    lstar = jnp.argmax(ok)
    any_ok = jnp.any(ok)
    prefix = jnp.where(any_ok, lc[:, lstar], 0)
    work = jnp.where(any_ok, total[lstar], 0)
    lstar = jnp.where(any_ok, lstar,
                      shard.level_cum.shape[1]).astype(jnp.int32)
    return prefix, work, lstar


def _accumulate(shard: IndexShard, terms, prefix, n_docs: int, cap: int):
    """Gather per-term impact-ordered prefixes and scatter-add into a dense
    accumulator (the jnp oracle of the Pallas scatter-as-matmul kernel)."""
    base = shard.offsets[terms]                                   # (L,)
    pos = base[:, None] + jnp.arange(cap, dtype=jnp.int32)[None, :]
    live = jnp.arange(cap, dtype=jnp.int32)[None, :] < prefix[:, None]
    pos = jnp.minimum(pos, shard.docs_imp.shape[0] - 1)
    d = shard.docs_imp[pos]
    v = shard.imp[pos] * live.astype(jnp.int32)
    # dead lanes scatter 0 into doc 0 — harmless
    d = jnp.where(live, d, 0)
    acc = jnp.zeros((n_docs,), jnp.int32).at[d.reshape(-1)].add(v.reshape(-1))
    return acc


# ---------------------------------------------------------------------------
# batched pipeline
# ---------------------------------------------------------------------------

def _level_cut_batched(shard: IndexShard, terms, mask, rho):
    return jax.vmap(
        lambda t, m, r: _level_cut(shard, t, m, r))(terms, mask, rho)


def _accumulate_batched(shard: IndexShard, terms, prefix, n_docs: int,
                        cap: int):
    """Batched accumulation of the impact-ordered prefixes: compact the
    per-term prefixes into (Q, cap) dense lanes (the JASS budget guarantees
    Σ prefix ≤ ρ ≤ cap, so the compact buffer is exact), then one fused
    flat scatter into the (Q, n_docs) accumulator — O(Q · ρ) scatter
    traffic, the batched form of "cost tracks the budget"."""
    q = terms.shape[0]
    base = shard.offsets[terms]                              # (Q, L)
    pos, live = compact_lanes(base, prefix, cap)
    pos = jnp.minimum(pos, shard.docs_imp.shape[0] - 1)
    d = jnp.where(live, shard.docs_imp[pos], 0)
    v = jnp.where(live, shard.imp[pos], 0)
    flat = (jnp.arange(q, dtype=jnp.int32)[:, None] * n_docs + d).reshape(-1)
    return jnp.zeros((q * n_docs,), jnp.int32).at[flat].add(
        v.reshape(-1)).reshape(q, n_docs)


def _saat_batched(shard: IndexShard, terms, mask, rho, *, n_docs: int,
                  k: int, cap: int, tile_d: int, backend: str):
    prefix, work, lstar = _level_cut_batched(shard, terms, mask, rho)
    if backend == "jnp":
        prefix = jnp.minimum(prefix, cap)
        acc = _accumulate_batched(shard, terms, prefix, n_docs, cap)
        # top-k in f32: exact for impact sums (< 2^24) and ~30x faster than
        # XLA CPU's int32 top-k; ties keep identical float representations
        sc, ids = jax.lax.top_k(acc.astype(jnp.float32), k)
    else:
        qterms = jnp.where(mask > 0, terms, -1).astype(jnp.int32)
        acc_t = impact_accumulate_tiles(
            shard.tile_docs, shard.tile_terms, shard.tile_imps, qterms,
            lstar, tile_d=tile_d, interpret=backend == "interpret")
        sc, ids = topk_from_tiles(acc_t, k, n_docs=n_docs)
    return ids.astype(jnp.int32), sc.astype(jnp.float32), work


@functools.partial(jax.jit, static_argnames=("n_docs", "k", "cap", "tile_d",
                                             "q_block", "backend"))
def saat_serve(shard: IndexShard, terms: jnp.ndarray, mask: jnp.ndarray,
               rho: jnp.ndarray, *, n_docs: int, k: int, cap: int,
               tile_d: int = 128, q_block: int = 64,
               backend: str | None = None) -> SaatResult:
    """Serve a batch of queries on one ISN shard.

    Args:
      terms: (Q, L) padded query term ids.
      mask: (Q, L) query term mask.
      rho: (Q,) per-query postings budgets (already capped at ρ_max by the
        Stage-0 scheduler; `cap` is the static ρ_max bound that sizes the
        gather paths, so their compiled cost is O(Q · L · cap)).
      n_docs / k / cap: static shard size, retrieval depth, per-term prefix
        cap.
      tile_d: docs per accumulator tile (must match the shard's bucketed
        mirror when a kernel backend runs).
      q_block: queries scored concurrently; larger batches stream through
        in q_block-sized chunks.
      backend: "pallas" | "interpret" | "jnp" | None (auto) — see
        ``repro.isn.backend``.
    """
    backend = resolve_backend(backend)
    fn = functools.partial(_saat_batched, shard, n_docs=n_docs, k=k, cap=cap,
                           tile_d=tile_d, backend=backend)
    out = map_query_blocks(fn, (terms, mask, rho), (0, 0.0, 0), q_block)
    return SaatResult(*out)


@functools.partial(jax.jit, static_argnames=("n_docs", "k", "cap"))
def saat_serve_laxmap(shard: IndexShard, terms: jnp.ndarray,
                      mask: jnp.ndarray, rho: jnp.ndarray, *, n_docs: int,
                      k: int, cap: int) -> SaatResult:
    """One-query-at-a-time reference pipeline (`lax.map` + dense scatter-add
    + full-collection top-k) — parity oracle and benchmark baseline."""
    def one(terms_q, mask_q, rho_q):
        prefix, work, _ = _level_cut(shard, terms_q, mask_q, rho_q)
        prefix = jnp.minimum(prefix, cap)
        acc = _accumulate(shard, terms_q, prefix, n_docs, cap)
        sc, ids = jax.lax.top_k(acc, k)
        return ids.astype(jnp.int32), sc.astype(jnp.float32), work

    ids, sc, work = jax.lax.map(lambda args: one(*args), (terms, mask, rho))
    return SaatResult(ids, sc, work)


def saat_serve_segments(segments, terms, mask, rhos, *, k, cap,
                        tile_d: int = 128, q_block: int = 64,
                        backend: str | None = None, drop=None):
    """Serve one batch over sealed + delta segments and merge the top-k.

    ``segments`` is a list of ``(shard, spec, doc_lo)`` in ascending
    global-doc order (delta pseudo-shard last); ``rhos[i]`` is segment
    ``i``'s per-query postings budget — the caller resolves the global
    ρ → level-cut split across *all* segments (delta included) so the
    combined scanned prefix is exactly the budgeted work. Integer impact
    accumulation keeps the merge bit-exact across backends; a delta
    segment's capacity padding contributes zero impact and is outranked
    by the sealed segments' real candidates.

    Returns ``(ids, scores, works)`` with per-segment work counters.
    """
    from repro.isn.backend import merge_shard_topk

    sc_list, id_list, works = [], [], []
    for i, (shard, spec, doc_lo) in enumerate(segments):
        r = saat_serve(shard, terms, mask, rhos[i], n_docs=spec.n_docs,
                       k=k, cap=cap, tile_d=tile_d, q_block=q_block,
                       backend=backend)
        sc_list.append(r.topk_scores)
        id_list.append(r.topk_docs + doc_lo)
        works.append(r.work)
    if len(segments) == 1 and drop is None:
        return id_list[0], sc_list[0], works
    ids, sc = merge_shard_topk(sc_list, id_list, k, drop=drop)
    return ids, sc, works
