"""Shared plumbing for the batched first-stage serving pipeline.

Backends
--------
The engines (``daat_serve`` / ``saat_serve``) dispatch their hot loop
through one of three backends:

* ``"pallas"``   — compiled Pallas kernels over the shard's bucketed
  postings mirror (the TPU production path);
* ``"interpret"``— the same kernels under the Pallas interpreter; bit-wise
  the kernel code path, runnable on CPU — this is what the parity tests
  exercise so the kernel program itself is covered without hardware;
* ``"jnp"``      — a vectorized pure-jnp pipeline (batched gather + one
  fused scatter over the CSR mirrors) producing identical results; the
  portable fast path on CPU hosts.

``resolve_backend(None)`` picks ``"pallas"`` on TPU and ``"jnp"`` elsewhere,
so tests/CPU hosts never accidentally pay the interpreter cost and TPUs
never fall back to scatter-adds.

Tiled top-k
-----------
``topk_from_tiles`` replaces the full-collection ``lax.top_k`` with a
hierarchical merge: per-tile top-k over the (Q, n_tiles, tile_d)
accumulator tiles the kernels emit, then a top-k over the per-tile
candidates.  Exactness: a tile holds ``tile_d`` docs, so its global top-k
members are within its local top-``min(k, tile_d)``; tie-breaking (lower
doc id first) is preserved because candidates stay sorted by (tile, rank).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BACKENDS = ("pallas", "interpret", "jnp")


def query_lane_budget(df, terms, mask, round_to: int = 1024,
                      floor: int = 256) -> int:
    """Static per-query posting-lane budget for a batch (host-side helper).

    The batched jnp backend compacts each query's ragged per-term postings
    into a dense (Q, qcap) lane buffer before the fused scatter, so its cost
    tracks the *actual* postings of the batch instead of L x max_df padding.
    Callers size qcap from the batch they are about to serve (like length
    bucketing in LM serving); rounding bounds jit recompiles.
    """
    import numpy as np
    eff = np.asarray(df)[np.asarray(terms)] * (np.asarray(mask) > 0)
    need = int(eff.sum(axis=1).max()) if eff.size else 0
    return max(-(-max(need, 1) // round_to) * round_to, floor)


def resolve_backend(backend: str | None) -> str:
    """Default the serving backend from the platform; validate overrides."""
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
    return backend


def map_query_blocks(fn, args, pad_values, q_block: int):
    """Stream a query batch through ``fn`` in q_block-sized chunks.

    ``fn(*args)`` must accept per-query arrays (leading axis Q) and return a
    pytree of per-query arrays.  Batches up to ``q_block`` run in one call;
    larger ones are padded with ``pad_values`` (one scalar per arg, chosen
    so padded queries are degenerate no-ops), reshaped to (chunks, q_block,
    ...), mapped sequentially with ``lax.map`` — keeping accumulator memory
    O(q_block · n_docs) — and truncated back to Q rows.
    """
    q = args[0].shape[0]
    if q <= q_block:
        return fn(*args)
    nb = -(-q // q_block)
    pad = nb * q_block - q
    padded = [jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1),
                      constant_values=pv)
              for a, pv in zip(args, pad_values)]
    out = jax.lax.map(lambda xs: fn(*xs),
                      tuple(a.reshape((nb, q_block) + a.shape[1:])
                            for a in padded))
    return jax.tree.map(
        lambda o: o.reshape((nb * q_block,) + o.shape[2:])[:q], out)


def compact_lanes(base: jnp.ndarray, dfs: jnp.ndarray, qcap: int):
    """Compact ragged per-term posting ranges into (Q, qcap) dense lanes.

    ``base``/``dfs`` are (Q, L): the start offset and live lane count of
    each query term's postings slice.  Lane ``j`` of query ``q`` maps to the
    ``j``-th posting of the concatenated per-term prefixes — located with a
    searchsorted over the prefix cumsum, i.e. pure gathers, no sort.  Lanes
    past the query's total are dead.  This is what lets the fused scatter
    touch O(actual postings) lanes instead of O(L · max_df) padding.

    Returns (pos, live): (Q, qcap) global posting positions + live mask.
    """
    cum = jnp.cumsum(dfs, axis=1)                            # (Q, L)
    start = cum - dfs
    j = jnp.arange(qcap, dtype=jnp.int32)
    term = jax.vmap(lambda c: jnp.searchsorted(c, j, side="right"))(cum)
    term = jnp.minimum(term, dfs.shape[1] - 1).astype(jnp.int32)
    within = j[None, :] - jnp.take_along_axis(start, term, axis=1)
    pos = jnp.take_along_axis(base, term, axis=1) + within
    live = j[None, :] < cum[:, -1:]
    return pos, live


def topk_from_tiles(acc_tiles: jnp.ndarray, k: int,
                    n_docs: int | None = None):
    """Hierarchical top-k over (Q, n_tiles, tile_d) accumulator tiles.

    Returns (scores, doc_ids) of shape (Q, k) with doc ids global to the
    shard.  Matches ``lax.top_k`` over the flattened (Q, n_docs) accumulator
    exactly, including tie-breaking by lower doc id.  Pass ``n_docs`` when
    the last tile overhangs the shard so ghost lanes can never be selected.
    """
    q, n_tiles, tile_d = acc_tiles.shape
    if n_docs is not None and n_tiles * tile_d > n_docs:
        fill = (jnp.finfo(acc_tiles.dtype).min
                if jnp.issubdtype(acc_tiles.dtype, jnp.floating)
                else jnp.iinfo(acc_tiles.dtype).min)
        gid = (jnp.arange(tile_d, dtype=jnp.int32)[None, :]
               + (jnp.arange(n_tiles, dtype=jnp.int32) * tile_d)[:, None])
        acc_tiles = jnp.where(gid[None] < n_docs, acc_tiles, fill)
    kt = min(k, tile_d)
    sc_t, idx_t = jax.lax.top_k(acc_tiles, kt)            # (Q, T, kt)
    gidx = idx_t + (jnp.arange(n_tiles, dtype=jnp.int32) * tile_d)[None, :,
                                                                   None]
    sc, pos = jax.lax.top_k(sc_t.reshape(q, n_tiles * kt), k)
    ids = jnp.take_along_axis(gidx.reshape(q, n_tiles * kt), pos, axis=1)
    return sc, ids.astype(jnp.int32)


def merge_shard_topk(scores: list, ids: list, k: int, drop=None):
    """Scatter-gather merge of per-shard top-k candidate lists.

    ``scores[s]`` / ``ids[s]`` are the (Q, k_s) ranked candidates of shard
    ``s`` with ids already global to the collection.  Shards must be passed
    in ascending doc-range order: ``lax.top_k`` keeps the earliest position
    on score ties, and within a shard candidates are already (score desc,
    doc id asc), so the merged tie-break is *lower global doc id first* —
    exactly the tie-break of a single-shard top-k over the dense
    accumulator.  Returns (ids, scores) of shape (Q, k).

    ``drop`` (optional, (n_shards, Q) bool) masks out shards whose response
    was lost for a query (fault injection / partial coverage): a dropped
    shard's candidates score dtype-min and surface with id ``-1``, so a
    degraded query's list is exactly the merge over its surviving shards,
    padded with ``-1`` when fewer than ``k`` candidates survive.  With
    ``drop=None`` the computation (and result) is bit-identical to the
    three-line merge this started as.
    """
    sc = jnp.concatenate(scores, axis=1)
    di = jnp.concatenate(ids, axis=1)
    if drop is not None:
        dead = jnp.concatenate(
            [jnp.broadcast_to(jnp.asarray(drop[s])[:, None],
                              scores[s].shape) for s in range(len(scores))],
            axis=1)
        fill = (jnp.finfo(sc.dtype).min
                if jnp.issubdtype(sc.dtype, jnp.floating)
                else jnp.iinfo(sc.dtype).min)
        sc = jnp.where(dead, fill, sc)
        di = jnp.where(dead, -1, di)
    top_sc, pos = jax.lax.top_k(sc, min(k, sc.shape[1]))
    top_id = jnp.take_along_axis(di, pos, axis=1)
    return top_id, top_sc


def tiled_topk(acc: jnp.ndarray, k: int, tile_d: int = 128):
    """Tiled top-k over a dense (Q, n_docs) accumulator.

    Pads the ragged tail tile with the dtype minimum so padding can never
    enter the top-k (the accumulators are non-negative).
    """
    q, n = acc.shape
    n_tiles = -(-n // tile_d)
    pad = n_tiles * tile_d - n
    if pad:
        fill = (jnp.finfo(acc.dtype).min
                if jnp.issubdtype(acc.dtype, jnp.floating)
                else jnp.iinfo(acc.dtype).min)
        acc = jnp.pad(acc, ((0, 0), (0, pad)), constant_values=fill)
    return topk_from_tiles(acc.reshape(q, n_tiles, tile_d), k)
