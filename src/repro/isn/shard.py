"""Document-sharded distributed ISN — the paper's architecture on a mesh.

Documents shard over the "model" axis (each model-rank is one ISN index
partition holding BOTH mirrors); query batches shard over ("pod", "data").
One serve step runs the full Stage-0 pipeline *inside* the compiled program:

  features (term-stat gather) → GBRT predictions (k̂, ρ̂, t̂) → route →
  JASS mirror (ρ̂ capped at ρ_max) ∥ BMW mirror (rank-safe) →
  per-shard top-k → all-gather over "model" → global top-k merge.

The all-gather payload is k·(score, docid) per shard — a few hundred KB per
query batch, which is why the collective term in §Roofline is negligible
for retrieval serving (latency lives in the per-shard scan, where the ρ
budget bounds it).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.index.postings import IndexShard
from repro.isn.daat import daat_serve
from repro.isn.saat import saat_serve

SDS = jax.ShapeDtypeStruct


class ForestArrays(NamedTuple):
    """Flat GBRT ensemble for in-step Stage-0 inference (3 targets)."""
    feat: jnp.ndarray       # (3, T, D, W) int32
    thresh: jnp.ndarray     # (3, T, D, W) int32
    leaf: jnp.ndarray       # (3, T, 2**D) float32
    base: jnp.ndarray       # (3,) float32
    bin_edges: jnp.ndarray  # (147, B-1) float32


def forest_specs(n_targets=3, n_trees=64, depth=5, n_feats=147, n_bins=64):
    w = 2 ** (depth - 1)
    return ForestArrays(
        feat=SDS((n_targets, n_trees, depth, w), jnp.int32),
        thresh=SDS((n_targets, n_trees, depth, w), jnp.int32),
        leaf=SDS((n_targets, n_trees, 2 ** depth), jnp.float32),
        base=SDS((n_targets,), jnp.float32),
        bin_edges=SDS((n_feats, n_bins - 1), jnp.float32),
    )


def _forest_predict(fa: ForestArrays, x, target: int, depth: int):
    """Vectorized fixed-depth descent; x: (Q, F) raw features -> (Q,)."""
    xb = jnp.sum(x[:, :, None] > fa.bin_edges[None], axis=-1).astype(jnp.int32)

    def per_row(row):
        def per_tree(ft, th, lf):
            node = jnp.zeros((), jnp.int32)
            for d in range(depth):
                f = ft[d, node]
                node = node * 2 + (row[f] > th[d, node]).astype(jnp.int32)
            return lf[node]
        return jnp.sum(jax.vmap(per_tree)(fa.feat[target], fa.thresh[target],
                                          fa.leaf[target]))
    return fa.base[target] + jax.vmap(per_row)(xb)


def _stage0(fa, term_stats, df, terms, mask, depth=5):
    """147 features + three GBRT predictions, all in-graph."""
    from repro.core import features as F
    x = F.extract(term_stats, df, terms, mask)
    pk = jnp.expm1(_forest_predict(fa, x, 0, depth))
    prho = jnp.expm1(_forest_predict(fa, x, 1, depth))
    pt = jnp.expm1(_forest_predict(fa, x, 2, depth))
    return pk, prho, pt


def hybrid_serve_fn(mesh, *, n_docs_shard: int, n_model: int, k_shard: int,
                    k_global: int, rho_max: int, daat_cap: int,
                    daat_bcap: int, n_blocks: int, block_size: int,
                    t_k: float, t_time: float, forest_depth: int = 5,
                    tile_d: int = 128, backend: str | None = None):
    """Builds the shard_map'ed hybrid serve step.

    Both engines run their batched kernel-backed pipelines inside the
    compiled program; ``backend=None`` resolves per-platform (compiled
    Pallas on TPU, fused-jnp elsewhere) — see ``repro.isn.backend``.
    """

    def serve(index: IndexShard, fa: ForestArrays, term_stats, terms, mask):
        shard = jax.tree.map(lambda a: a[0], index)   # strip stacked dim
        pk, prho, pt = _stage0(fa, term_stats[0], shard.df, terms, mask,
                               forest_depth)
        route_jass = (pk > t_k) | (pt > t_time)       # Algorithm 2
        rho = jnp.clip(prho, 1024, rho_max).astype(jnp.int32)

        saat = saat_serve(shard, terms, mask, rho, n_docs=n_docs_shard,
                          k=k_shard, cap=rho_max, tile_d=tile_d,
                          backend=backend)
        theta = jnp.ones((terms.shape[0],), jnp.float32)
        daat = daat_serve(shard, terms, mask, theta, n_docs=n_docs_shard,
                          n_blocks=n_blocks, block_size=block_size,
                          k=k_shard, cap=daat_cap, bcap=daat_bcap,
                          tile_d=tile_d, backend=backend)

        ids = jnp.where(route_jass[:, None], saat.topk_docs, daat.topk_docs)
        sc = jnp.where(route_jass[:, None], saat.topk_scores,
                       daat.topk_scores)
        work = jnp.where(route_jass, saat.work, daat.work)

        # globalize doc ids and merge across ISN shards
        rank = jax.lax.axis_index("model")
        gids = ids + rank * n_docs_shard
        all_sc = jax.lax.all_gather(sc, "model", axis=1, tiled=True)
        all_ids = jax.lax.all_gather(gids, "model", axis=1, tiled=True)
        top_sc, pos = jax.lax.top_k(all_sc, k_global)
        top_ids = jnp.take_along_axis(all_ids, pos, axis=1)
        return top_ids, top_sc, work, route_jass

    axes = mesh.axis_names
    qspec = P(tuple(a for a in ("pod", "data") if a in axes))
    index_spec = IndexShard(*[P("model")] * len(IndexShard._fields))
    in_specs = (index_spec, ForestArrays(*[P()] * 5), P("model"),
                P(*qspec, None) if qspec else P(None, None),
                P(*qspec, None) if qspec else P(None, None))
    out_specs = (P(*qspec, None), P(*qspec, None), P(*qspec), P(*qspec))
    return shard_map(serve, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def _stacked_index_specs(cfg, n_model: int):
    """ShapeDtypeStructs for the per-shard index, stacked over "model"."""
    v, p, pb = cfg.vocab, cfg.postings_per_shard, cfg.block_entries_per_shard
    m = n_model
    n_docs_shard = cfg.n_docs // n_model
    nt = max(1, -(-n_docs_shard // cfg.tile_d))
    tc = cfg.tile_cap

    def s(shape, dt=jnp.int32):
        return SDS((m,) + shape, dt)

    return IndexShard(
        df=s((v,)), offsets=s((v + 1,)),
        docs_imp=s((p,)), imp=s((p,)), level_cum=s((v, cfg.n_levels)),
        docs=s((p,)), score=s((p,), jnp.float32),
        bm_offsets=s((v + 1,)), bm_block_id=s((pb,)),
        bm_block_max=s((pb,), jnp.float32), bm_block_cnt=s((pb,)),
        tile_docs=s((nt, tc)), tile_terms=s((nt, tc)),
        tile_scores=s((nt, tc), jnp.float32), tile_imps=s((nt, tc)),
    )


def build_serve_cell(arch_id, cfg, cell, mesh, rules, CellCls):
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_model = axes.get("model", 1)
    n_docs_shard = cfg.n_docs // n_model
    n_blocks = n_docs_shard // cfg.block_size
    # daat_cap bounds the gather backends' per-term lane budget (memory):
    # terms with shard df above it are TRUNCATED there, while the kernel
    # backends' bucketed mirror always scores every posting of a matched
    # term.  On shards where max_df can exceed this cap the two backends
    # therefore differ on ultra-dense terms — the kernel path being the
    # exact one; keep cap >= shard max_df wherever parity matters (the
    # servers and tests do).
    daat_cap = min(n_docs_shard, 1 << 19)
    daat_bcap = min(n_blocks, 1 << 14)

    fn = hybrid_serve_fn(
        mesh, n_docs_shard=n_docs_shard, n_model=n_model,
        k_shard=min(cfg.k_max // 4, 1024), k_global=cfg.k_max,
        rho_max=cfg.rho_max, daat_cap=daat_cap, daat_bcap=daat_bcap,
        n_blocks=n_blocks, block_size=cfg.block_size,
        t_k=1000.0, t_time=150.0, tile_d=cfg.tile_d)

    q = cfg.queries_per_step
    index = _stacked_index_specs(cfg, n_model)
    fa = forest_specs()
    term_stats = SDS((n_model, cfg.vocab, 36), jnp.float32)
    terms = SDS((q, cfg.query_len), jnp.int32)
    mask = SDS((q, cfg.query_len), jnp.float32)

    qaxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    qsh = NamedSharding(mesh, P(qaxes, None))
    q1 = NamedSharding(mesh, P(qaxes))
    ish = IndexShard(*[NamedSharding(mesh, P("model"))
                       if True else None] * len(IndexShard._fields))
    fsh = ForestArrays(*[NamedSharding(mesh, P())] * 5)
    tsh = NamedSharding(mesh, P("model"))

    meta = {"n_docs": cfg.n_docs, "postings": cfg.postings_per_shard * n_model,
            "rho_max": cfg.rho_max, "queries": q}
    return CellCls(arch_id, cell.name, "isn", "serve", fn,
                   (index, fa, term_stats, terms, mask),
                   (ish, fsh, tsh, qsh, qsh),
                   (qsh, qsh, q1, q1), (), meta)
