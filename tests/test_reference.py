"""Reference-list metric properties (RBP / RBO / MED-RBP)."""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:            # deterministic metric tests still run
    HAS_HYPOTHESIS = False

from repro.core import reference as R


def _skip_property_test():
    pytest.skip("hypothesis not installed "
                "(pip install -r requirements-dev.txt)")


def _perm_lists(rng, n, depth):
    docs = rng.permutation(n)[:depth]
    return jnp.asarray(docs)


def test_rbp_weights_sum():
    w = np.asarray(R.rbp_weights(10_000, 0.95))
    assert abs(w.sum() - 1.0) < 1e-3          # converges to 1 at depth


def test_med_identical_lists_zero():
    a = jnp.arange(50)
    assert float(R.med_rbp(a, a, 0.95)) < 1e-6


def test_med_disjoint_lists_maximal():
    a = jnp.arange(50)
    b = jnp.arange(100, 150)
    med = float(R.med_rbp(a, b, 0.95))
    w = np.asarray(R.rbp_weights(50, 0.95))
    assert abs(med - w.sum()) < 1e-5


if HAS_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(5, 40))
    def test_med_monotone_in_cutoff(seed, depth):
        """MED at cutoff k is non-increasing in k."""
        rng = np.random.RandomState(seed)
        ref = jnp.asarray(rng.permutation(1000)[:depth])
        ranks = jnp.asarray(rng.randint(0, 500, depth))
        cutoffs = jnp.asarray([1, 10, 50, 100, 200, 500])
        med = np.asarray(R.med_rbp_at_cutoffs(ref, ranks, cutoffs, 0.95))
        assert np.all(np.diff(med) <= 1e-7)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_oracle_cutoff_achieves_eps(seed):
        rng = np.random.RandomState(seed)
        depth = 30
        ref = jnp.asarray(rng.permutation(1000)[:depth])
        ranks = jnp.asarray(rng.randint(0, 256, depth))
        cutoffs = jnp.asarray([1, 2, 4, 8, 16, 32, 64, 128, 256, 512])
        eps = 0.01
        k = int(R.oracle_cutoff(ref, ranks, cutoffs, 0.95, eps))
        med_at_k = float(R.med_rbp_at_cutoffs(ref, ranks, jnp.asarray([k]),
                                              0.95)[0])
        # either eps is met, or k is the largest cutoff (unreachable)
        assert med_at_k <= eps + 1e-6 or k == 512
else:
    def test_med_monotone_in_cutoff():
        _skip_property_test()

    def test_oracle_cutoff_achieves_eps():
        _skip_property_test()


def test_rbo_identical_is_one():
    a = jnp.arange(30)
    assert abs(float(R.rbo(a, a, 0.9)) - 1.0) < 1e-5


def test_rbo_disjoint_is_zero():
    a = jnp.arange(30)
    b = jnp.arange(100, 130)
    assert float(R.rbo(a, b, 0.9)) < 1e-6


if HAS_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_rbo_symmetric(seed):
        rng = np.random.RandomState(seed)
        a = jnp.asarray(rng.permutation(100)[:20])
        b = jnp.asarray(rng.permutation(100)[:20])
        assert abs(float(R.rbo(a, b, 0.9)) - float(R.rbo(b, a, 0.9))) < 1e-5
else:
    def test_rbo_symmetric():
        _skip_property_test()


def test_overlap_padding_aware():
    a = jnp.asarray([1, 2, 3, -1, -1])
    b = jnp.asarray([3, 2, 9, 9, 9])
    assert abs(float(R.overlap(a, b)) - 2.0 / 3.0) < 1e-6
