"""Fault tolerance: checkpoint atomicity/integrity, crash-resume, elastic."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import optimizer, train_loop
from repro.train.checkpoint import CheckpointManager
from repro.train.compression import (compress_grads, decompress_grads,
                                     init_error)


def _tree(seed=0):
    rng = np.random.RandomState(seed)
    return {"a": jnp.asarray(rng.randn(16, 8), jnp.float32),
            "b": {"c": jnp.asarray(rng.randn(4), jnp.float32),
                  "d": jnp.asarray(rng.randint(0, 5, (3, 3)), jnp.int32)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(10, t, extra={"note": "x"})
    step, out, extra = mgr.restore_latest(t)
    assert step == 10 and extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_corruption_falls_back(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(1, t)
    mgr.save(2, _tree(99))
    # corrupt the newest
    npz = os.path.join(str(tmp_path), "step_0000000002", "arrays.npz")
    with open(npz, "r+b") as f:
        f.seek(100)
        f.write(b"\x00" * 64)
    step, out, _ = mgr.restore_latest(t)
    assert step == 1          # fell back to the older valid checkpoint


def test_async_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save_async(s, t)
    mgr.wait()
    assert mgr.list_steps() == [3, 4]


def test_crash_resume(tmp_path):
    """Inject a failure mid-training; a fresh run resumes from the last
    checkpoint and completes with identical final step count."""
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(64, 4), jnp.float32)
    y = x @ jnp.asarray([1.0, -1, 2, 0.5])
    params = {"w": jnp.zeros((4,), jnp.float32)}

    def loss_fn(p, batch):
        return jnp.mean((batch["x"] @ p["w"] - batch["y"]) ** 2)

    def data():
        while True:
            yield {"x": x, "y": y}

    cfg = train_loop.TrainConfig(
        steps=30, ckpt_every=10, ckpt_dir=str(tmp_path), log_every=1000,
        opt=optimizer.AdamWConfig(lr=0.2, warmup_steps=2, total_steps=30,
                                  weight_decay=0.0))
    with pytest.raises(RuntimeError, match="injected failure"):
        train_loop.run(params, loss_fn, data(), cfg, fail_at=15)
    mgr = CheckpointManager(str(tmp_path))
    assert 10 in mgr.list_steps()
    p2, _, losses = train_loop.run(params, loss_fn, data(), cfg)
    assert losses[-1] < 0.1


def test_elastic_reshard_roundtrip():
    from repro.train.elastic import reshard_tree
    mesh = jax.make_mesh((1,), ("data",))
    t = {"w": np.ones((8, 4), np.float32)}
    names = {"w": ("batch", None)}
    out = reshard_tree(t, names, {"batch": ("data",)}, mesh)
    np.testing.assert_array_equal(np.asarray(out["w"]), t["w"])


def test_gradient_compression_error_feedback():
    g = {"w": jnp.asarray(np.random.RandomState(0).randn(128) * 3,
                          jnp.float32)}
    err = init_error(g)
    q, err2 = compress_grads(g, err)
    back = decompress_grads(q)
    # int8 error bounded by scale/2 per element
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127
    assert float(jnp.max(jnp.abs(back["w"] - g["w"]))) <= scale
    # error feedback: residual equals quantization error
    np.testing.assert_allclose(np.asarray(err2["w"]),
                               np.asarray(g["w"] - back["w"]), atol=1e-6)
