"""End-to-end test of the distributed hybrid ISN serve step (shard_map):
Stage-0 in-graph GBRT + both engines + top-k merge, on real index data
over a degenerate (1,1) mesh."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import features as F
from repro.index.postings import shard_from_index
from repro.isn import oracle
from repro.isn.shard import ForestArrays, hybrid_serve_fn


def _identity_forest(n_targets=3, n_feats=147, n_bins=64, const=0.0):
    """A degenerate forest predicting `const` for every target."""
    t, d, w = 4, 5, 2 ** 4
    return ForestArrays(
        feat=jnp.zeros((n_targets, t, d, w), jnp.int32),
        thresh=jnp.full((n_targets, t, d, w), n_bins, jnp.int32),
        leaf=jnp.zeros((n_targets, t, 2 ** d), jnp.float32),
        base=jnp.full((n_targets,), const, jnp.float32),
        bin_edges=jnp.full((n_feats, n_bins - 1), 1e30, jnp.float32),
    )


def test_hybrid_serve_step_end_to_end(small_collection):
    corpus, index, ql = small_collection
    shard, spec = shard_from_index(index)
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    k_shard, k_global, rho_max = 64, 64, 4096
    fn = hybrid_serve_fn(
        mesh, n_docs_shard=spec.n_docs, n_model=1, k_shard=k_shard,
        k_global=k_global, rho_max=rho_max, daat_cap=spec.max_df,
        daat_bcap=spec.max_blocks_per_term,
        n_blocks=spec.n_blocks, block_size=spec.block_size,
        # base prediction log1p-space: expm1(12) >> t_k -> everything JASS
        t_k=1.0, t_time=1e9, forest_depth=5)

    stacked = jax.tree.map(lambda a: a[None], shard)
    fa = _identity_forest(const=12.0)
    term_stats = jnp.asarray(index.term_stats)[None]
    q = 16
    terms = jnp.asarray(ql.terms[:q])
    mask = jnp.asarray(ql.mask[:q])

    with mesh:
        ids, sc, work, route = fn(stacked, fa, term_stats, terms, mask)
    assert ids.shape == (q, k_global)
    assert bool(jnp.all(route))          # predicted k >> t_k -> all JASS
    assert int(jnp.max(work)) <= rho_max

    # compare against the numpy oracle at the same budget
    accj, wj = oracle.jass_scores(index, ql.terms, ql.mask, np.arange(q),
                                  rho_max)
    ids_o, _ = oracle._topk_ids(accj, k_global)
    overlap = np.mean([len(np.intersect1d(np.asarray(ids[i]), ids_o[i]))
                       / k_global for i in range(q)])
    assert overlap > 0.95

    # BMW route: forest predicting tiny k -> everything BMW, rank-safe
    fa_small = _identity_forest(const=0.0)
    fn2 = hybrid_serve_fn(
        mesh, n_docs_shard=spec.n_docs, n_model=1, k_shard=k_shard,
        k_global=k_global, rho_max=rho_max, daat_cap=spec.max_df,
        daat_bcap=spec.max_blocks_per_term,
        n_blocks=spec.n_blocks, block_size=spec.block_size,
        t_k=1e9, t_time=1e9)
    with mesh:
        ids2, sc2, work2, route2 = fn2(stacked, fa_small, term_stats, terms,
                                       mask)
    assert not bool(jnp.any(route2))
    acc, _ = oracle.exhaustive_scores(index, ql.terms, ql.mask, np.arange(q))
    ids_e, _ = oracle._topk_ids(acc, k_global)
    overlap2 = np.mean([len(np.intersect1d(np.asarray(ids2[i]), ids_e[i]))
                        / k_global for i in range(q)])
    assert overlap2 > 0.97
