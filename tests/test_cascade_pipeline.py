"""Parity suite for the batched end-to-end cascade pipeline.

The unified array-program cascade (fused Stage-0, batched Stage-2 LTR
re-rank, per-stage latency accounting) must reproduce the per-query
reference paths: the numpy ``qd_features`` loop, the ``rerank_loop``
cascade driver, and the pre-refactor ``HybridServer`` serving loop.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gbrt
from repro.ltr import cascade, ranker
from repro.serving.latency import CostModel
from repro.serving.pipeline import CascadePipeline
from repro.serving.scheduler import SchedulerConfig
from repro.serving.server import HybridServer


@pytest.fixture(scope="module")
def stage2(small_collection):
    corpus, index, ql = small_collection
    arrs = ranker.stage2_arrays(index, corpus)
    n_iter = ranker.csr_search_iters(int(index.df.max()))
    rng = np.random.RandomState(7)
    c = 48
    cand = np.sort(rng.choice(index.n_docs, (96, c)), axis=1).astype(np.int64)
    cand[0, 40:] = -1                       # ragged padding
    cand[3] = -1                            # fully empty candidate list
    return corpus, index, ql, arrs, n_iter, cand


@pytest.fixture(scope="module")
def ltr_model(stage2):
    corpus, index, ql, arrs, n_iter, cand = stage2
    rng = np.random.RandomState(11)
    feats = []
    for q in range(24):
        sel = cand[q][cand[q] >= 0]
        feats.append(ranker.qd_features(index, corpus, ql.terms[q],
                                        ql.mask[q], ql.topic[q], sel))
    feats = np.concatenate(feats)
    gains = (feats[:, 5] + 0.2 * feats[:, 1]
             + 0.05 * rng.randn(len(feats))).astype(np.float32)
    return ranker.train_ltr(feats, gains, n_trees=24)


# ---------------------------------------------------------------------------
# batched featurization vs the per-query numpy loop — exact
# ---------------------------------------------------------------------------

def test_qd_features_batched_matches_loop_exactly(stage2):
    corpus, index, ql, arrs, n_iter, cand = stage2
    feats = np.asarray(ranker.qd_features_batched(
        arrs, jnp.asarray(ql.terms), jnp.asarray(ql.mask),
        jnp.asarray(ql.topic), jnp.asarray(cand, jnp.int32), n_iter=n_iter))
    assert feats.shape == (96, cand.shape[1], ranker.N_LTR_FEATURES)
    for q in range(96):
        sel = cand[q] >= 0
        if not sel.any():
            continue
        ref = ranker.qd_features(index, corpus, ql.terms[q], ql.mask[q],
                                 ql.topic[q], cand[q][sel])
        np.testing.assert_array_equal(feats[q][sel], ref)


def test_rerank_batched_matches_loop_exactly(stage2, ltr_model):
    corpus, index, ql, arrs, n_iter, cand = stage2
    rng = np.random.RandomState(3)
    k_per_query = rng.randint(0, cand.shape[1] + 16, 96)
    k_per_query[5] = 0                      # k = 0 edge case
    a = cascade.rerank_batched(arrs, ltr_model, ql.terms, ql.mask, ql.topic,
                               cand, k_per_query, t_final=10, n_iter=n_iter)
    b = cascade.rerank_loop(index, corpus, ql, np.arange(96), cand,
                            k_per_query, ltr_model, t_final=10)
    np.testing.assert_array_equal(a.final, b.final)
    np.testing.assert_array_equal(a.candidates_used, b.candidates_used)


def test_rerank_batched_empty_candidates(stage2, ltr_model):
    """A query with no candidates yields the loop's zero row and used == 0."""
    corpus, index, ql, arrs, n_iter, cand = stage2
    k = np.full(96, cand.shape[1])
    res = cascade.rerank_batched(arrs, ltr_model, ql.terms, ql.mask,
                                 ql.topic, cand, k, t_final=10, n_iter=n_iter)
    assert res.candidates_used[3] == 0
    np.testing.assert_array_equal(res.final[3], np.zeros(10, np.int64))
    # short candidate lists pad the tail of the final list with -1
    res_short = cascade.rerank_batched(arrs, ltr_model, ql.terms, ql.mask,
                                       ql.topic, cand,
                                       np.full(96, 4), t_final=10,
                                       n_iter=n_iter)
    assert np.all(res_short.final[1, 4:] == -1)
    assert np.all(res_short.final[1, :4] >= 0)


# ---------------------------------------------------------------------------
# the qd_feature_gather kernel (interpret mode = the kernel program on CPU)
# ---------------------------------------------------------------------------

def test_qd_feature_gather_kernel_matches_ref():
    from repro.kernels.qd_feature_gather.ops import (qd_feature_gather,
                                                     qd_feature_gather_ref)
    rng = np.random.RandomState(0)
    q, p, c = 5, 700, 37
    lane_docs = rng.randint(-1, 60, (q, p)).astype(np.int32)
    lane_scores = np.where(lane_docs >= 0,
                           rng.random_sample((q, p)) * 6, 0).astype(np.float32)
    cand = rng.randint(-1, 60, (q, c)).astype(np.int32)
    bm, mx, cnt = qd_feature_gather(jnp.asarray(lane_docs),
                                    jnp.asarray(lane_scores),
                                    jnp.asarray(cand), p_tile=256,
                                    interpret=True)
    bm_r, mx_r, cnt_r = qd_feature_gather_ref(jnp.asarray(lane_docs),
                                              jnp.asarray(lane_scores),
                                              jnp.asarray(cand))
    np.testing.assert_allclose(np.asarray(bm), np.asarray(bm_r), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(mx), np.asarray(mx_r))
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_r))


def test_qd_features_interpret_backend_matches_jnp(stage2):
    """The kernel-backed featurizer agrees with the CSR binary-search path
    (float sums to tolerance; counts and gathers exactly)."""
    corpus, index, ql, arrs, n_iter, cand = stage2
    q = 8
    terms = jnp.asarray(ql.terms[:q])
    mask = jnp.asarray(ql.mask[:q])
    topics = jnp.asarray(ql.topic[:q])
    cd = jnp.asarray(cand[:q], jnp.int32)
    from repro.isn.backend import query_lane_budget
    qcap = query_lane_budget(index.df, ql.terms[:q], ql.mask[:q])
    a = np.asarray(ranker.qd_features_batched(arrs, terms, mask, topics, cd,
                                              n_iter=n_iter,
                                              backend="interpret", qcap=qcap))
    b = np.asarray(ranker.qd_features_batched(arrs, terms, mask, topics, cd,
                                              n_iter=n_iter, backend="jnp"))
    np.testing.assert_allclose(a, b, atol=1e-4)
    # non-sum features are exact across backends
    for col in (0, 2, 3, 5, 6, 7):
        np.testing.assert_array_equal(a[..., col], b[..., col])


# ---------------------------------------------------------------------------
# end-to-end pipeline vs the HybridServer serving loop
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def stage0_models(small_collection):
    corpus, index, ql = small_collection
    from repro.core import features as F
    x = np.asarray(F.extract(jnp.asarray(index.term_stats),
                             jnp.asarray(index.df),
                             jnp.asarray(ql.terms), jnp.asarray(ql.mask)))
    rng = np.random.RandomState(5)
    # cheap pseudo-labels: routing only needs plausible heavy-tailed targets
    eff_df = index.df[ql.terms] * (ql.mask > 0)
    base = eff_df.sum(axis=1).astype(np.float64)
    models = {}
    for name, scale, tau in (("k", 0.05, 0.55), ("rho", 0.5, 0.45),
                             ("t", 0.002, 0.5)):
        y = base * scale * np.exp(rng.randn(len(base)) * 0.3)
        models[name] = gbrt.fit(x, np.log1p(y.astype(np.float32)),
                                gbrt.GBRTParams(n_trees=24, depth=4,
                                                loss="quantile", tau=tau))
    return x, models


def test_pipeline_stage0_matches_per_model(small_collection, stage0_models):
    corpus, index, ql = small_collection
    x, models = stage0_models
    cfg = SchedulerConfig(budget=100.0)
    pipe = CascadePipeline(index, models, cfg)
    assert pipe._stacked is not None, "same-shaped ensembles must stack"
    pk, pr, pt = pipe.stage0(ql.terms, ql.mask)
    for name, got in (("k", pk), ("rho", pr), ("t", pt)):
        want = np.expm1(np.asarray(gbrt.predict(models[name],
                                                jnp.asarray(x))))
        np.testing.assert_array_equal(got, want)


def test_pipeline_matches_hybrid_server(small_collection, stage0_models):
    """Stage-1-only pipeline == HybridServer: same top-k, same latency."""
    corpus, index, ql = small_collection
    x, models = stage0_models
    cfg = SchedulerConfig(budget=100.0, rho_max=1 << 14)
    cost = CostModel.paper_scale()
    pipe = CascadePipeline(index, models, cfg, cost=cost)
    server = HybridServer(index, models,
                          SchedulerConfig(budget=100.0, rho_max=1 << 14),
                          cost=cost)
    a = pipe.serve(ql.terms, ql.mask)
    b = server.serve(ql.terms, ql.mask)
    np.testing.assert_array_equal(a.topk, b.topk)
    np.testing.assert_allclose(a.latency, b.latency)
    for key in ("jass", "bmw", "p50", "p99", "over_budget"):
        assert a.stats[key] == b.stats[key]


def test_pipeline_full_cascade_matches_loop(small_collection, stage0_models,
                                            ltr_model):
    """End-to-end: the pipeline's Stage-2 output equals running rerank_loop
    over the served Stage-1 candidates, and the cascade latency decomposes
    into the per-stage accounts."""
    corpus, index, ql = small_collection
    x, models = stage0_models
    cfg = SchedulerConfig(budget=100.0, rho_max=1 << 14)
    pipe = CascadePipeline(index, models, cfg, corpus=corpus, ltr=ltr_model,
                           k_serve=64, t_final=10)
    res = pipe.serve(ql.terms, ql.mask, ql.topic)
    assert res.final is not None and res.final.shape == (96, 10)

    routed = pipe.sched.route(*pipe.stage0(ql.terms, ql.mask))
    k2 = np.minimum(routed.k, 64)
    ref = cascade.rerank_loop(index, corpus, ql, np.arange(96),
                              res.topk, k2, ltr_model, t_final=10)
    np.testing.assert_array_equal(res.final, ref.final)
    np.testing.assert_array_equal(res.candidates_used, ref.candidates_used)

    total = (res.stage_latency["stage0"] + res.stage_latency["stage1"]
             + res.stage_latency["stage2"])
    np.testing.assert_allclose(res.latency, total)
    assert set(res.stats["stages"]) == {"stage0", "stage1", "stage2"}
    # stage-2 cost follows the candidate count
    np.testing.assert_allclose(
        res.stage_latency["stage2"],
        pipe.cost.ltr_time(res.candidates_used))


def test_cascade_budget_reserves_stage2(small_collection, stage0_models,
                                        ltr_model):
    """With an LTR model attached, the scheduler enforces Stage-1 against
    budget - Stage-0 prediction cost - worst-case Stage-2 cost, so the
    late-hedge guarantee covers the cascade; without an LTR model only the
    (unconditional) Stage-0 cost is reserved."""
    corpus, index, ql = small_collection
    x, models = stage0_models
    cfg = SchedulerConfig(budget=30.0, rho_max=1 << 14)
    pipe = CascadePipeline(index, models, cfg, corpus=corpus, ltr=ltr_model,
                           k_serve=64)
    reserve = float(pipe.cost.ltr_time(np.asarray(64)))
    assert pipe.sched.cfg.budget == pytest.approx(
        30.0 - pipe.cost.predict_us - reserve)
    assert pipe.budget == 30.0                 # reporting uses the full budget
    plain = CascadePipeline(index, models, cfg)
    assert plain.sched.cfg.budget == pytest.approx(
        30.0 - plain.cost.predict_us)
