"""SearchSystem / CascadeSpec suite: spec JSON round-trip, the preset
registry, multi-shard scatter-gather parity vs the single-shard pipeline,
compat-shim parity, and replica-pool integration.
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.configs.cascade_presets import PRESETS, get_preset
from repro.serving.pipeline import CascadePipeline
from repro.serving.scheduler import SchedulerConfig
from repro.serving.server import HybridServer
from repro.serving.spec import (BackendSpec, CascadeSpec, DeploySpec,
                                IndexSpec, RoutingSpec, Stage0Spec,
                                Stage2Spec)
from repro.serving.system import SearchSystem, build_system


# ---------------------------------------------------------------------------
# spec serialization + validation
# ---------------------------------------------------------------------------

def test_spec_json_round_trip():
    spec = CascadeSpec(
        index=IndexSpec(block_size=32, stop_k=8, tile_d=64),
        stage0=Stage0Spec(n_trees=24, depth=4, tau_k=0.6),
        routing=RoutingSpec(algorithm=1, budget=88.5, rho_max=1 << 15,
                            enable_hedging=False, calibrate=True),
        stage2=Stage2Spec(enabled=False, k_serve=96, t_final=7),
        backend=BackendSpec(backend="jnp", cost="v5e_shard"),
        deploy=DeploySpec(n_shards=3, replicas=4, jass_fraction=0.25,
                          rebalance_every=2, seed=9),
        name="round_trip",
    )
    again = CascadeSpec.from_json(spec.to_json())
    assert again == spec
    # the wire format is JSON-plain and versioned
    d = json.loads(spec.to_json())
    assert d["version"] == 1
    assert d["deploy"]["n_shards"] == 3


def test_spec_validation_rejects_bad_values():
    with pytest.raises(ValueError):
        CascadeSpec(routing=RoutingSpec(algorithm=3)).validate()
    with pytest.raises(ValueError):
        CascadeSpec(deploy=DeploySpec(n_shards=0)).validate()
    with pytest.raises(ValueError):
        CascadeSpec(backend=BackendSpec(backend="cuda")).validate()
    with pytest.raises(ValueError):
        CascadeSpec(index=IndexSpec(block_size=48, tile_d=128)).validate()


def test_preset_registry_complete():
    assert set(PRESETS) == {"paper_200ms", "throughput", "quality",
                            "stage1_only", "fault_tolerant", "cached",
                            "live_ingest", "hybrid_fusion"}
    for name in PRESETS:
        spec = get_preset(name)
        assert spec.name == name
        assert spec == CascadeSpec.from_json(spec.to_json())
    assert get_preset("stage1_only").stage2.enabled is False
    assert get_preset("throughput").routing.enable_hedging is False
    assert (get_preset("quality").stage2.k_serve
            > get_preset("throughput").stage2.k_serve)
    with pytest.raises(ValueError):
        get_preset("no_such_preset")
    # overrides replace whole nodes and re-validate
    spec = get_preset("paper_200ms", deploy=DeploySpec(n_shards=4))
    assert spec.deploy.n_shards == 4


# ---------------------------------------------------------------------------
# system construction + multi-shard parity
# ---------------------------------------------------------------------------

def _spec(n_shards, t_k=150.0, t_time=18.0, replicas=2, **kw):
    return CascadeSpec(
        routing=RoutingSpec(budget=100.0, rho_max=1 << 14, t_k=t_k,
                            t_time=t_time),
        stage2=Stage2Spec(enabled=True, k_serve=64, t_final=10),
        backend=BackendSpec(backend="jnp"),
        deploy=DeploySpec(n_shards=n_shards, replicas=replicas, **kw),
        name=f"test_{n_shards}shard",
    )


@pytest.fixture(scope="module")
def fitted(small_collection):
    """A fitted single-shard system plus the calibrated routing thresholds
    every sharded comparison system reuses (identical routing is what makes
    the parity bit-exact)."""
    corpus, index, ql = small_collection
    spec = dataclasses.replace(
        _spec(1), routing=RoutingSpec(budget=100.0, rho_max=1 << 14,
                                      calibrate=True))
    system = build_system(spec, index, corpus=corpus)
    system.fit(ql, None, seed=5)
    thresholds = (system._base_cfg.t_k, system._base_cfg.t_time)
    return corpus, index, ql, system, thresholds


def test_build_system_from_corpus_matches_index(small_collection):
    """Building from the corpus reproduces the prebuilt index layout."""
    corpus, index, ql = small_collection
    spec = dataclasses.replace(
        _spec(1), index=IndexSpec(stop_k=8), stage2=Stage2Spec(enabled=False))
    system = build_system(spec, corpus)
    assert system.index.n_docs == index.n_docs
    np.testing.assert_array_equal(system.index.df, index.df)
    with pytest.raises(TypeError):
        build_system(spec, "not a corpus")


def test_fit_trains_all_stages(fitted):
    corpus, index, ql, system, _ = fitted
    assert set(system.models) == {"k", "rho", "t"}
    assert system._stacked is not None
    assert system.ltr is not None
    pk, pr, pt = system.stage0(ql.terms, ql.mask)
    assert pk.shape == (len(ql.terms),) and np.isfinite(pk).all()


@pytest.mark.parametrize("n_shards", [1, 3])
def test_multi_shard_topk_parity(fitted, n_shards):
    """n-shard scatter-gather == single-shard top-k, final lists and
    candidate counts, bit for bit on the jnp backend (documented merge
    tie-break: lower global doc id on score ties)."""
    corpus, index, ql, system, (tk, tt) = fitted
    sharded = build_system(_spec(n_shards, tk, tt), index, corpus=corpus,
                           models=system.models, ltr=system.ltr)
    assert sharded.n_shards == n_shards
    assert sum(sp.n_docs for sp in sharded.shard_specs) == index.n_docs
    a = system.serve(ql.terms, ql.mask, ql.topic)
    b = sharded.serve(ql.terms, ql.mask, ql.topic)
    # both pools must be exercised for this to mean anything
    assert b.stats["jass"] > 0 and b.stats["bmw"] > 0
    np.testing.assert_array_equal(a.topk, b.topk)
    np.testing.assert_array_equal(a.final, b.final)
    np.testing.assert_array_equal(a.candidates_used, b.candidates_used)


def test_multi_shard_tail_is_scatter_gather_max(fitted):
    """Sharding must not increase any query's modeled Stage-1 time, and the
    slowest query must strictly improve (the max-over-shards tail)."""
    corpus, index, ql, system, (tk, tt) = fitted
    sharded = build_system(_spec(3, tk, tt), index, corpus=corpus,
                           models=system.models, ltr=system.ltr)
    a = system.serve(ql.terms, ql.mask, ql.topic)
    b = sharded.serve(ql.terms, ql.mask, ql.topic)
    assert np.all(b.stage_latency["stage1"]
                  <= a.stage_latency["stage1"] + 1e-9)
    assert b.stage_latency["stage1"].max() < a.stage_latency["stage1"].max()


def test_spec_round_trip_builds_identical_system(fitted):
    """build_system(from_json(to_json(spec))) serves bit-identical results."""
    corpus, index, ql, system, _ = fitted
    spec2 = CascadeSpec.from_json(system.cascade_spec.to_json())
    system2 = build_system(spec2, index, corpus=corpus,
                           models=system.models, ltr=system.ltr)
    a = system.serve(ql.terms, ql.mask, ql.topic)
    b = system2.serve(ql.terms, ql.mask, ql.topic)
    np.testing.assert_array_equal(a.topk, b.topk)
    np.testing.assert_array_equal(a.final, b.final)
    np.testing.assert_allclose(a.latency, b.latency)


def test_k_serve_must_fit_smallest_shard(small_collection):
    corpus, index, ql = small_collection
    spec = dataclasses.replace(
        _spec(64), stage2=Stage2Spec(enabled=True, k_serve=128))
    with pytest.raises(ValueError, match="smallest shard"):
        build_system(spec, index, corpus=corpus)


# ---------------------------------------------------------------------------
# compat shims
# ---------------------------------------------------------------------------

def test_compat_shims_match_spec_system(fitted):
    """CascadePipeline/HybridServer old signatures == a one-shard spec
    system, bit for bit."""
    corpus, index, ql, system, (tk, tt) = fitted
    cfg = SchedulerConfig(budget=100.0, rho_max=1 << 14, t_k=tk,
                          t_time=tt)
    pipe = CascadePipeline(index, system.models, cfg, corpus=corpus,
                           ltr=system.ltr, k_serve=64, t_final=10,
                           backend="jnp")
    assert isinstance(pipe, SearchSystem)
    assert pipe.n_shards == 1
    assert pipe.spec.n_docs == index.n_docs          # historical IndexShardSpec
    a = system.serve(ql.terms, ql.mask, ql.topic)
    b = pipe.serve(ql.terms, ql.mask, ql.topic)
    np.testing.assert_array_equal(a.topk, b.topk)
    np.testing.assert_array_equal(a.final, b.final)
    np.testing.assert_allclose(a.latency, b.latency)

    server = HybridServer(index, system.models, cfg, k_serve=64)
    stage1 = build_system(
        dataclasses.replace(_spec(1, tk, tt),
                            stage2=Stage2Spec(enabled=False, k_serve=64)),
        index, models=system.models)
    c = server.serve(ql.terms, ql.mask)
    d = stage1.serve(ql.terms, ql.mask)
    np.testing.assert_array_equal(c.topk, d.topk)
    np.testing.assert_allclose(c.latency, d.latency)


# ---------------------------------------------------------------------------
# replica pool integration
# ---------------------------------------------------------------------------

def test_pool_fed_by_serving_and_stats_surface(fitted, small_collection):
    corpus, index, ql = small_collection
    _, _, _, system, (tk, tt) = fitted
    sharded = build_system(_spec(3, tk, tt, rebalance_every=1), index,
                           corpus=corpus, models=system.models,
                           ltr=system.ltr)
    res = sharded.serve(ql.terms, ql.mask, ql.topic)
    st = sharded.stats()
    pool = st["pool"]
    # every query occupied one replica of every partition, and observed
    # latencies fed the EWMA estimates back
    assert pool["served"] >= len(ql.terms) * 3
    assert pool["max_inflight"] == 0                 # all completed
    assert any(v is not None for v in pool["ewma_latency"].values())
    assert res.stats["pool"]["served"] == pool["served"]
    assert st["n_shards"] == 3 and len(st["shard_docs"]) == 3
    assert st["batches"] == 1
    assert "last_batch" in st and "p99" in st["last_batch"]


def test_rebalance_exercised_by_cascade_run(fitted, small_collection):
    """With a JASS/BMW-skewed routing mix, serving itself re-splits the
    mirror ratio toward the observed mix (not only tests/test_replicas)."""
    corpus, index, ql = small_collection
    _, _, _, system, _ = fitted
    spec = dataclasses.replace(
        _spec(2, replicas=4, rebalance_every=1),
        routing=RoutingSpec(budget=100.0, rho_max=1 << 14, t_k=0.0,
                            t_time=0.0))   # pred_k > 0 routes all to JASS
    sharded = build_system(spec, index, corpus=corpus, models=system.models,
                           ltr=system.ltr)
    assert sharded.pool.stats()["jass_fraction"] == 0.5
    res = sharded.serve(ql.terms, ql.mask, ql.topic)
    assert res.stats["jass"] == len(ql.terms)
    # observed mix 100% JASS -> split clipped to the 0.8 ceiling = 3/4
    assert sharded.pool.stats()["jass_fraction"] == pytest.approx(0.75)
