"""Pallas kernels vs pure-jnp oracles (interpret=True shape/dtype sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:              # deterministic sweeps still run without it
    HAS_HYPOTHESIS = False

from repro.kernels.blockmax_score.ops import blockmax_score, blockmax_score_ref
from repro.kernels.flash_attention.kernel import flash_attention, flash_decode
from repro.kernels.flash_attention.ref import attention_ref, decode_ref
from repro.kernels.impact_accumulate.ops import (impact_accumulate,
                                                 impact_accumulate_ref)
from repro.kernels.score_histogram.ops import histogram_topk
from repro.kernels.score_histogram.kernel import score_histogram
from repro.kernels.score_histogram.ref import score_histogram_ref


# ---------------------------------------------------------------------------
# impact_accumulate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_docs,p,tile_d,cap", [
    (512, 2048, 128, 256),
    (1000, 5000, 128, 128),     # exercises overflow fallback + ragged tail
    (4096, 512, 256, 512),
    (128, 128, 128, 1024),
])
@pytest.mark.parametrize("lstar", [0, 128])
def test_impact_accumulate_matches_ref(n_docs, p, tile_d, cap, lstar):
    rng = np.random.RandomState(n_docs + p + lstar)
    docs = rng.randint(0, n_docs, p).astype(np.int32)
    docs[rng.random_sample(p) < 0.15] = -1
    imps = rng.randint(1, 256, p).astype(np.int32)
    ref = impact_accumulate_ref(jnp.asarray(docs), jnp.asarray(imps),
                                jnp.int32(lstar), n_docs)
    out = impact_accumulate(jnp.asarray(docs), jnp.asarray(imps),
                            jnp.asarray(lstar, jnp.int32), n_docs=n_docs,
                            tile_d=tile_d, cap=cap, interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


if HAS_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_impact_accumulate_property(seed):
        """Total accumulated mass == sum of surviving impacts."""
        rng = np.random.RandomState(seed)
        n_docs, p = 256, 1024
        docs = rng.randint(0, n_docs, p).astype(np.int32)
        imps = rng.randint(1, 256, p).astype(np.int32)
        lstar = int(rng.randint(0, 256))
        out = impact_accumulate(jnp.asarray(docs), jnp.asarray(imps),
                                jnp.asarray(lstar, jnp.int32), n_docs=n_docs,
                                tile_d=128, cap=256, interpret=True)
        assert int(np.asarray(out).sum()) == int(imps[imps >= lstar].sum())
else:
    def test_impact_accumulate_property():
        pytest.skip("hypothesis not installed "
                    "(pip install -r requirements-dev.txt)")


# ---------------------------------------------------------------------------
# blockmax_score
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_docs,p,bs,survive_frac", [
    (1024, 4096, 64, 0.3),
    (2000, 2000, 64, 1.0),
    (512, 8192, 128, 0.05),
])
def test_blockmax_score_matches_ref(n_docs, p, bs, survive_frac):
    rng = np.random.RandomState(p)
    docs = rng.randint(0, n_docs, p).astype(np.int32)
    docs[rng.random_sample(p) < 0.1] = -1
    scores = (rng.random_sample(p) * 8).astype(np.float32)
    nb = (n_docs + bs - 1) // bs
    survive = jnp.asarray(rng.random_sample(nb) < survive_frac)
    ref = blockmax_score_ref(jnp.asarray(docs), jnp.asarray(scores), survive,
                             n_docs, bs)
    out = blockmax_score(jnp.asarray(docs), jnp.asarray(scores), survive,
                         n_docs=n_docs, block_size=bs, tile_d=128, cap=256,
                         interpret=True)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out), atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,hkv,s,d,dtype", [
    (1, 4, 4, 128, 32, jnp.float32),     # MHA
    (2, 8, 2, 256, 64, jnp.float32),     # GQA 4:1
    (1, 8, 1, 128, 64, jnp.bfloat16),    # MQA bf16
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(b, h, hkv, s, d, dtype, causal):
    rng = np.random.RandomState(h * s)
    q = jnp.asarray(rng.randn(b, h, s, d), dtype) * 0.4
    k = jnp.asarray(rng.randn(b, hkv, s, d), dtype) * 0.4
    v = jnp.asarray(rng.randn(b, hkv, s, d), dtype)
    out = flash_attention(q, k, v, causal=causal, tq=64, tk=64,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("b,h,hkv,s,d,tk", [
    (2, 8, 2, 256, 64, 64),
    (1, 4, 4, 512, 32, 128),
])
def test_flash_decode_matches_ref(b, h, hkv, s, d, tk):
    rng = np.random.RandomState(s)
    q = jnp.asarray(rng.randn(b, h, d), jnp.float32) * 0.4
    k = jnp.asarray(rng.randn(b, hkv, s, d), jnp.float32) * 0.4
    v = jnp.asarray(rng.randn(b, hkv, s, d), jnp.float32)
    kv_len = jnp.asarray(rng.randint(1, s, b), jnp.int32)
    out = flash_decode(q, k, v, kv_len, tk=tk, interpret=True)
    ref = decode_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# ---------------------------------------------------------------------------
# score histogram / histogram top-k
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,n_bins", [(4096, 512), (8192, 2048)])
def test_histogram_matches_ref(n, n_bins):
    rng = np.random.RandomState(n)
    s = rng.randint(-1, n_bins, n).astype(np.int32)
    out = score_histogram(jnp.asarray(s), n_bins=n_bins, tile_n=512,
                          interpret=True)
    ref = score_histogram_ref(jnp.asarray(s), n_bins)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


if HAS_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.sampled_from([10, 100, 500]))
    def test_histogram_topk_exact(seed, k):
        rng = np.random.RandomState(seed)
        s = rng.randint(0, 1500, 4096).astype(np.int32)
        vals, idx = histogram_topk(jnp.asarray(s), k=k, interpret=True)
        ref = np.sort(s)[::-1][:k]
        np.testing.assert_array_equal(np.sort(np.asarray(vals))[::-1], ref)
        # indices must actually point at the returned values
        np.testing.assert_array_equal(s[np.asarray(idx)], np.asarray(vals))
else:
    def test_histogram_topk_exact():
        pytest.skip("hypothesis not installed "
                    "(pip install -r requirements-dev.txt)")
