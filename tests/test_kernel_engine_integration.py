"""Cross-layer integration: the Pallas kernel path must agree with the jnp
serving engine on real index data (the kernel IS the engine's hot loop)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.index.postings import shard_from_index
from repro.kernels.impact_accumulate.ops import (impact_accumulate,
                                                 impact_accumulate_tiles)
from repro.kernels.score_histogram.ops import histogram_topk
from repro.isn.saat import _accumulate, _level_cut, _level_cut_batched


def test_kernel_reproduces_engine_accumulator(small_collection):
    corpus, index, ql = small_collection
    shard, spec = shard_from_index(index)
    rho = 2048
    for q in range(4):
        terms = jnp.asarray(ql.terms[q])
        mask = jnp.asarray(ql.mask[q])
        prefix, work, _ = _level_cut(shard, terms, mask, jnp.asarray(rho))
        prefix = jnp.minimum(prefix, rho)
        # engine accumulator (jnp path)
        acc_engine = _accumulate(shard, terms, prefix, spec.n_docs, rho)

        # kernel path: flatten the same postings and find the level cut;
        # the budget is an impact-level mask, so feed the kernel the raw
        # gathered postings with lstar
        base = shard.offsets[terms]
        pos = base[:, None] + jnp.arange(rho)[None, :]
        live = jnp.arange(rho)[None, :] < prefix[:, None]
        pos = jnp.minimum(pos, shard.docs_imp.shape[0] - 1)
        docs = jnp.where(live, shard.docs_imp[pos], -1).reshape(-1)
        imps = jnp.where(live, shard.imp[pos], 0).reshape(-1)
        acc_kernel = impact_accumulate(docs, imps, jnp.asarray(0, jnp.int32),
                                       n_docs=spec.n_docs, tile_d=128,
                                       cap=256, interpret=True)
        np.testing.assert_array_equal(np.asarray(acc_engine),
                                      np.asarray(acc_kernel))


def test_batched_kernel_reproduces_engine_accumulator(small_collection):
    """The (Q, n_tiles) batched kernel over the build-time bucketed mirror
    must reproduce the per-query gather+scatter accumulator bit-exactly."""
    corpus, index, ql = small_collection
    shard, spec = shard_from_index(index)
    rho, q = 2048, 4
    terms = jnp.asarray(ql.terms[:q])
    mask = jnp.asarray(ql.mask[:q])
    prefix, _, lstar = _level_cut_batched(shard, terms, mask,
                                          jnp.full(q, rho))
    acc_tiles = impact_accumulate_tiles(
        shard.tile_docs, shard.tile_terms, shard.tile_imps,
        jnp.where(mask > 0, terms, -1).astype(jnp.int32), lstar,
        tile_d=spec.tile_d, interpret=True)
    acc_kernel = np.asarray(acc_tiles).reshape(q, -1)[:, :spec.n_docs]
    for i in range(q):
        acc_engine = _accumulate(shard, terms[i],
                                 jnp.minimum(prefix[i], rho), spec.n_docs,
                                 rho)
        np.testing.assert_array_equal(np.asarray(acc_engine), acc_kernel[i])


def test_histogram_topk_on_engine_scores(small_collection):
    corpus, index, ql = small_collection
    shard, spec = shard_from_index(index)
    terms = jnp.asarray(ql.terms[0])
    mask = jnp.asarray(ql.mask[0])
    prefix, _, _ = _level_cut(shard, terms, mask, jnp.asarray(4096))
    acc = _accumulate(shard, terms, jnp.minimum(prefix, 4096), spec.n_docs,
                      4096)
    import jax
    ref_v, ref_i = jax.lax.top_k(acc, 64)
    vals, idx = histogram_topk(acc, k=64, n_bins=2048, interpret=True)
    np.testing.assert_array_equal(np.sort(np.asarray(vals)),
                                  np.sort(np.asarray(ref_v)))
