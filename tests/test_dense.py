"""Dense retrieval + hybrid fusion suite: grid quantization, kernel
backend bit-parity (ragged shapes, exact ties), sharded engine vs oracle,
fusion tie policy, Stage-0 modality dispatch, theta confidence bands,
spec round-trip, worst-case bound accounting, cache interplay, and the
provable inertness of a disabled DenseSpec.
"""

import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.dense import (GRID, M_BOTH, M_DENSE, M_LEX, DenseEngine,
                         build_embeddings, embed_queries, quantize,
                         rrf_fuse, synthetic_embeddings, weighted_fuse)
from repro.index.postings import shard_ranges
from repro.kernels.dense_topk import dense_topk, dense_topk_oracle
from repro.serving.cache import route_sig
from repro.serving.spec import (BackendSpec, CacheSpec, CascadeSpec,
                                DenseSpec, DeploySpec, FusionSpec,
                                OnlineSpec, RoutingSpec, Stage2Spec,
                                TrafficSpec)
from repro.serving.system import build_system

# ---------------------------------------------------------------------------
# embeddings: the exactness contract
# ---------------------------------------------------------------------------


def test_quantize_snaps_to_grid_and_clips():
    x = np.array([0.01, -1.73205, 3.5, -9.0, 0.0])
    q = quantize(x)
    assert q.dtype == np.float32
    np.testing.assert_array_equal(q * GRID, np.rint(q * GRID))
    assert q.max() <= 2.0 and q.min() >= -2.0
    assert q[2] == 2.0 and q[3] == -2.0


def test_embed_queries_row_independent():
    _, table = synthetic_embeddings(64, 128, d=16, seed=1)
    rng = np.random.RandomState(0)
    terms = rng.randint(0, 128, size=(6, 5))
    mask = (rng.rand(6, 5) > 0.3).astype(np.float32)
    full = embed_queries(table, terms, mask)
    for i in range(6):
        row = embed_queries(table, terms[i:i + 1], mask[i:i + 1])
        np.testing.assert_array_equal(row[0], full[i])
    np.testing.assert_array_equal(full * GRID, np.rint(full * GRID))


def test_build_embeddings_source_resolution(small_collection):
    corpus, index, ql = small_collection
    doc_emb, table = build_embeddings(
        DenseSpec(enabled=True, source="synthetic", embed_dim=16),
        corpus=None, n_docs=64, vocab=128)
    assert doc_emb.shape == (64, 16) and table.shape == (128, 16)
    # auto without a corpus falls back to synthetic (same seeded tables)
    d2, t2 = build_embeddings(DenseSpec(enabled=True, embed_dim=16),
                              corpus=None, n_docs=64, vocab=128)
    np.testing.assert_array_equal(doc_emb, d2)
    # explicit two_tower without a corpus is an error, never a downgrade
    with pytest.raises(ValueError, match="two_tower"):
        build_embeddings(DenseSpec(enabled=True, source="two_tower"),
                         corpus=None, n_docs=64, vocab=128)
    dt, tt = build_embeddings(DenseSpec(enabled=True), corpus=corpus,
                              n_docs=corpus.n_docs, vocab=corpus.vocab)
    assert dt.shape[0] == corpus.n_docs and tt.shape[0] == corpus.vocab


# ---------------------------------------------------------------------------
# kernel: backend bit-parity and tie policy
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_dense():
    doc_emb, table = synthetic_embeddings(1000, 256, d=24, seed=3)
    rng = np.random.RandomState(7)
    terms = rng.randint(0, 256, size=(32, 6))
    mask = np.ones((32, 6), np.float32)
    return doc_emb, embed_queries(table, terms, mask)


@pytest.mark.parametrize("k", [1, 33, 128])
def test_kernel_backend_parity(small_dense, k):
    """interpret == jnp == numpy oracle, bitwise, on ragged shapes
    (n_docs and embed dim both non-multiples of the tile sizes)."""
    doc_emb, q_emb = small_dense
    o_sc, o_ids = dense_topk_oracle(q_emb, doc_emb, k)
    for backend in ("jnp", "interpret"):
        sc, ids = dense_topk(jnp.asarray(q_emb), jnp.asarray(doc_emb), k,
                             tile_d=512, backend=backend)
        np.testing.assert_array_equal(np.asarray(sc), o_sc)
        np.testing.assert_array_equal(np.asarray(ids, np.int64), o_ids)


def test_kernel_exact_ties_pick_lower_doc_id(small_dense):
    doc_emb, q_emb = small_dense
    dup = np.concatenate([doc_emb[:100]] * 3)      # every score 3x duplicated
    o_sc, o_ids = dense_topk_oracle(q_emb, dup, 64)
    for backend in ("jnp", "interpret"):
        sc, ids = dense_topk(jnp.asarray(q_emb), jnp.asarray(dup), 64,
                             tile_d=128, backend=backend)
        np.testing.assert_array_equal(np.asarray(sc), o_sc)
        np.testing.assert_array_equal(np.asarray(ids, np.int64), o_ids)


def test_kernel_rejects_bad_shapes(small_dense):
    doc_emb, q_emb = small_dense
    with pytest.raises(ValueError, match="k="):
        dense_topk(jnp.asarray(q_emb), jnp.asarray(doc_emb), 0)
    with pytest.raises(ValueError, match="tile_d"):
        dense_topk(jnp.asarray(q_emb), jnp.asarray(doc_emb), 8,
                   tile_d=100, backend="interpret")


# ---------------------------------------------------------------------------
# engine: sharded serve vs unsharded oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_shards", [1, 3])
def test_engine_sharded_parity(small_dense, n_shards):
    doc_emb, q_emb = small_dense
    _, table = synthetic_embeddings(1000, 256, d=24, seed=3)
    eng = DenseEngine(doc_emb, table, shard_ranges(1000, n_shards),
                      tile_d=128, backend="jnp")
    ids, sc = eng.serve(q_emb, 64)
    o_ids, o_sc = eng.oracle(q_emb, 64)
    np.testing.assert_array_equal(ids, o_ids)
    np.testing.assert_array_equal(sc, o_sc)


def test_engine_drop_mask_merges_survivors(small_dense):
    """Dropping a shard serves exactly the merge over survivors — i.e. the
    oracle over the surviving doc range."""
    doc_emb, q_emb = small_dense
    _, table = synthetic_embeddings(1000, 256, d=24, seed=3)
    ranges = shard_ranges(1000, 2)
    eng = DenseEngine(doc_emb, table, ranges, tile_d=128, backend="jnp")
    q = len(q_emb)
    drop = np.zeros((2, q), bool)
    drop[1, : q // 2] = True                      # lose shard 1 for half
    ids, sc = eng.serve(q_emb, 64, drop=drop)
    lo, hi = ranges[0]
    surv_sc, surv_ids = dense_topk_oracle(q_emb[: q // 2], doc_emb[lo:hi],
                                          64)
    np.testing.assert_array_equal(ids[: q // 2], surv_ids + lo)
    np.testing.assert_array_equal(sc[: q // 2], surv_sc)
    full_ids, full_sc = eng.oracle(q_emb, 64)
    np.testing.assert_array_equal(ids[q // 2:], full_ids[q // 2:])


# ---------------------------------------------------------------------------
# fusion
# ---------------------------------------------------------------------------


def test_rrf_prefers_docs_in_both_lists():
    lex = np.array([[10, 11, 12]])
    den = np.array([[20, 10, 21]])
    ids, sc = rrf_fuse(lex, den, 5, k0=60.0)
    assert ids[0, 0] == 10                     # only doc in both lists
    # singles rank by their one contribution: 20 (rank 0) above 11
    # (rank 1); the rank-2 contributions tie (12 lexical vs 21 dense)
    # -> lower doc id first
    assert list(ids[0, 1:5]) == [20, 11, 12, 21]
    r = 1.0 / (60.0 + np.arange(3) + 1.0)
    np.testing.assert_allclose(sc[0, 0], r[0] + r[1], rtol=1e-6)
    assert sc[0, 3] == sc[0, 4]


def test_fusion_excludes_padding_and_pads_short_lists():
    lex = np.array([[5, -1, -1]])
    den = np.array([[-1, -1, -1]])
    ids, sc = rrf_fuse(lex, den, 4)
    assert list(ids[0]) == [5, -1, -1, -1]
    assert (sc[0, 1:] == 0).all()


def test_weighted_fuse_extremes_follow_one_modality():
    lex = np.array([[1, 2, 3]])
    lex_sc = np.array([[9.0, 5.0, 1.0]])
    den = np.array([[3, 4, 5]])
    den_sc = np.array([[0.9, 0.5, 0.1]])
    # positive scores follow the favored modality's order; zero-scored
    # entries (the other list + the favored list's min) tie -> lower id
    ids_d, _ = weighted_fuse(lex, lex_sc, den, den_sc, 3, w_dense=1.0)
    assert list(ids_d[0]) == [3, 4, 1]
    ids_l, _ = weighted_fuse(lex, lex_sc, den, den_sc, 3, w_dense=0.0)
    assert list(ids_l[0]) == [1, 2, 3]


# ---------------------------------------------------------------------------
# spec
# ---------------------------------------------------------------------------


def test_dense_spec_roundtrip_with_infinite_thetas():
    spec = CascadeSpec(
        dense=DenseSpec(enabled=True, embed_dim=48, tile_d=256,
                        theta_high=0.5, theta_low=0.2),
        fusion=FusionSpec(method="weighted", w_dense=0.7),
        name="dense_rt")
    back = CascadeSpec.from_json(spec.to_json())
    assert back.dense == spec.dense and back.fusion == spec.fusion
    # defaults carry +/- infinity through JSON
    d2 = CascadeSpec.from_json(CascadeSpec(
        dense=DenseSpec(enabled=True)).to_json()).dense
    assert d2.theta_high == np.inf and d2.theta_low == -np.inf
    assert json.loads(spec.to_json())["dense"]["enabled"] is True


def test_dense_spec_validation():
    DenseSpec(enabled=True).validate()
    assert DenseSpec(enabled=True).active
    assert not DenseSpec().active
    with pytest.raises(ValueError):
        DenseSpec(enabled=True, tile_d=100).validate()
    with pytest.raises(ValueError):
        DenseSpec(enabled=True, theta_low=0.9, theta_high=0.1).validate()
    with pytest.raises(ValueError):
        DenseSpec(enabled=True, source="bm25").validate()
    with pytest.raises(ValueError):
        FusionSpec(method="borda").validate()
    with pytest.raises(ValueError):
        FusionSpec(w_dense=1.5).validate()


# ---------------------------------------------------------------------------
# system integration (small_collection, jnp backend, frozen thresholds)
# ---------------------------------------------------------------------------


def _spec(dense=None, fusion=None, cache=None, deploy=None, **routing_kw):
    routing = {"budget": 100.0, "rho_max": 1 << 14, "t_k": 150.0,
               "t_time": 18.0, "adapt_every": 0}
    routing.update(routing_kw)
    return CascadeSpec(
        routing=RoutingSpec(**routing),
        stage2=Stage2Spec(enabled=True, k_serve=32, t_final=5),
        backend=BackendSpec(backend="jnp"),
        deploy=deploy if deploy is not None else DeploySpec(),
        dense=dense if dense is not None else DenseSpec(),
        fusion=fusion if fusion is not None else FusionSpec(),
        cache=cache if cache is not None else CacheSpec(),
        online=OnlineSpec(max_batch=8, batch_deadline_us=4.0),
        name="dense_test",
    )


@pytest.fixture(scope="module")
def fitted(small_collection):
    corpus, index, ql = small_collection
    spec = dataclasses.replace(
        _spec(), routing=dataclasses.replace(_spec().routing, t_k=None,
                                             t_time=None, calibrate=True))
    system = build_system(spec, index, corpus=corpus)
    system.fit(ql, None, seed=5)
    return corpus, index, ql, system, (system._base_cfg.t_k,
                                       system._base_cfg.t_time)


def _system(fitted, dense=None, fusion=None, cache=None, deploy=None,
            **routing_kw):
    corpus, index, ql, system, (tk, tt) = fitted
    spec = _spec(dense=dense, fusion=fusion, cache=cache, deploy=deploy,
                 t_k=tk, t_time=tt, **routing_kw)
    return build_system(spec, index, corpus=corpus, models=system.models,
                        ltr=system.ltr)


def test_disabled_dense_is_bit_inert(fitted):
    """enabled=False — even with every other knob set — must be provably
    absent: identical top-k, final lists, and modeled latency."""
    corpus, index, ql, _, _ = fitted
    base = _system(fitted)
    off = _system(fitted, dense=DenseSpec(enabled=False, embed_dim=64,
                                          theta_high=0.4, theta_low=0.3),
                  fusion=FusionSpec(method="weighted", w_dense=0.9))
    assert off.dense is None
    ra = base.serve(ql.terms, ql.mask, ql.topic)
    rb = off.serve(ql.terms, ql.mask, ql.topic)
    np.testing.assert_array_equal(ra.topk, rb.topk)
    np.testing.assert_array_equal(ra.final, rb.final)
    np.testing.assert_array_equal(ra.latency, rb.latency)
    assert ra.dense is None and rb.dense is None


def test_disabled_dense_online_event_log_identical(fitted):
    corpus, index, ql, _, _ = fitted
    traffic = TrafficSpec(arrival="bursty", qps=300.0, skew=0.6, seed=9)
    oa = _system(fitted).serve_online(ql.terms, ql.mask, ql.topic,
                                      traffic=traffic)
    ob = _system(fitted, dense=DenseSpec(enabled=False, theta_high=0.4)
                 ).serve_online(ql.terms, ql.mask, ql.topic,
                                traffic=traffic)
    assert oa.event_log == ob.event_log
    assert "dense" not in oa.stats and "dense" not in ob.stats


def test_route_sig_modality_suffix():
    """The cache key's route signature embeds the resolved modality; the
    empty default keeps dense-free keys byte-identical to the pre-dense
    format."""
    base = route_sig(True, 4096.0, 64.0)
    assert route_sig(True, 4096.0, 64.0, b"") == base
    tagged = {route_sig(True, 4096.0, 64.0, b"|M%d" % m)
              for m in (M_LEX, M_DENSE, M_BOTH)}
    assert len(tagged) == 3 and base not in tagged


def test_modality_dispatch_extremes(fitted):
    corpus, index, ql, _, _ = fitted
    q = len(ql.terms)
    all_lex = _system(fitted, dense=DenseSpec(enabled=True,
                                              source="synthetic",
                                              t_dense=1e9))
    r = all_lex.serve(ql.terms, ql.mask, ql.topic)
    assert r.stats["dense"]["lexical"] == q
    np.testing.assert_array_equal(r.dense["modality"],
                                  np.full(q, M_LEX))
    all_dense = _system(fitted, dense=DenseSpec(enabled=True,
                                                source="synthetic",
                                                t_dense=1e-6))
    r2 = all_dense.serve(ql.terms, ql.mask, ql.topic)
    assert r2.stats["dense"]["dense_only"] == q
    # dense-only candidates come from the dense engine verbatim
    q_emb = all_dense.dense.embed(ql.terms, ql.mask)
    ids, _ = all_dense.dense.serve(q_emb, all_dense.k_serve)
    np.testing.assert_array_equal(r2.topk, ids)


def test_mixed_dispatch_within_bound(fitted):
    corpus, index, ql, _, _ = fitted
    for method in ("rrf", "weighted"):
        sy = _system(fitted, dense=DenseSpec(enabled=True,
                                             source="synthetic"),
                     fusion=FusionSpec(method=method))
        r = sy.serve(ql.terms, ql.mask, ql.topic)
        d = r.stats["dense"]
        assert (d["lexical"] + d["dense_only"] + d["fused"]
                == len(ql.terms))
        assert r.stats["over_budget"] == 0
        assert float(np.max(r.latency)) <= sy.worst_case_us() + 1e-9


def test_theta_high_skips_stage2_rank_safely(fitted):
    corpus, index, ql, _, _ = fitted
    sy = _system(fitted, dense=DenseSpec(enabled=True, source="synthetic",
                                         t_dense=1e-6, theta_high=-1.0))
    r = sy.serve(ql.terms, ql.mask, ql.topic)
    q = len(ql.terms)
    assert r.stats["dense"]["theta_skips"] == q
    # the skip serves the Stage-1 order: final head == top-k head
    np.testing.assert_array_equal(r.final, r.topk[:, : r.final.shape[1]])
    assert float(np.max(r.latency)) <= sy.worst_case_us() + 1e-9


def test_theta_low_falls_back_to_lexical(fitted):
    corpus, index, ql, _, _ = fitted
    sy = _system(fitted, dense=DenseSpec(enabled=True, source="synthetic",
                                         t_dense=1e-6, theta_low=10.0))
    r = sy.serve(ql.terms, ql.mask, ql.topic)
    q = len(ql.terms)
    assert r.stats["dense"]["fallbacks"] == q
    # fallback replaces dense candidates with a lexical re-issue
    q_emb = sy.dense.embed(ql.terms, ql.mask)
    d_ids, _ = sy.dense.serve(q_emb, sy.k_serve)
    assert not np.array_equal(r.topk, d_ids)
    assert float(np.max(r.latency)) <= sy.worst_case_us() + 1e-9
    assert r.stats["over_budget"] == 0


def test_worst_case_bound_accounts_for_dense_routes(fitted):
    base = _system(fitted)
    dense = _system(fitted, dense=DenseSpec(enabled=True,
                                            source="synthetic"))
    with_fb = _system(fitted, dense=DenseSpec(enabled=True,
                                              source="synthetic",
                                              theta_low=0.1))
    assert dense.worst_case_us() >= base.worst_case_us() - 1e-9
    assert dense._budget_reserve["fusion"] == dense.cost.fusion_us
    # at this collection's tile count the lexical late-hedge path (which
    # already contains a full rho_late SAAT re-issue) dominates, so the
    # theta_low fallback is absorbed by the same bound ...
    assert with_fb.worst_case_us() == dense.worst_case_us()
    # ... but once the dense route dominates (inflated tile count), a
    # finite theta_low must charge the lexical fallback on top
    for sy in (dense, with_fb):
        sy.dense.max_tiles = lambda: 100_000
    assert with_fb.worst_case_us() > dense.worst_case_us()
    fb = float(dense.cost.saat_time(
        np.float64(dense.sched.cfg.resolved_late_rho())))
    assert (with_fb.worst_case_us() - dense.worst_case_us()
            == pytest.approx(fb - dense.cost.fusion_us))


def test_multishard_dense_serve_matches_singleshard(fitted):
    corpus, index, ql, _, _ = fitted
    ds = DenseSpec(enabled=True, source="synthetic")
    one = _system(fitted, dense=ds)
    three = _system(fitted, dense=ds,
                    deploy=DeploySpec(n_shards=3, replicas=2))
    r1 = one.serve(ql.terms, ql.mask, ql.topic)
    r3 = three.serve(ql.terms, ql.mask, ql.topic)
    np.testing.assert_array_equal(r1.topk, r3.topk)
    np.testing.assert_array_equal(r1.final, r3.final)
    assert float(np.max(r3.latency)) <= three.worst_case_us() + 1e-9


def test_cache_replays_dense_results_bitwise(fitted):
    corpus, index, ql, _, _ = fitted
    sy = _system(fitted, dense=DenseSpec(enabled=True, source="synthetic",
                                         theta_high=0.55),
                 cache=CacheSpec(enabled=True))
    r1 = sy.serve(ql.terms, ql.mask, ql.topic)
    r2 = sy.serve(ql.terms, ql.mask, ql.topic)
    assert sy.cache.counters["l1_hits"] == len(ql.terms)
    np.testing.assert_array_equal(r1.topk, r2.topk)
    np.testing.assert_array_equal(r1.final, r2.final)
    np.testing.assert_array_equal(r1.dense["theta_skip"],
                                  r2.dense["theta_skip"])


def test_online_dense_stats_and_guarantee(fitted):
    corpus, index, ql, _, _ = fitted
    sy = _system(fitted, dense=DenseSpec(enabled=True, source="synthetic"))
    res = sy.serve_online(ql.terms, ql.mask, ql.topic,
                          traffic=TrafficSpec(arrival="poisson", qps=200.0,
                                              seed=4))
    d = res.stats["dense"]
    assert (d["lexical"] + d["dense_only"] + d["fused"]
            == res.stats["served"])
    assert res.stats["over_budget"] == 0
